# Convenience entry points. Tier-1 verification is just:
#     cargo build --release && cargo test -q

.PHONY: build test smoke bench-smoke artifacts bench-figures lint

build:
	cargo build --release --workspace

test:
	cargo test -q

smoke:
	cargo run --release --example quickstart

# The CI bench-smoke leg: serving comparison (sequential slots vs
# continuous batching) plus the operator hot-path report, both in quick
# mode, JSON reports under perf-reports/.
bench-smoke:
	mkdir -p perf-reports
	cargo run --release --example serve_batch -- --quick --report perf-reports/serve_batch.json
	cargo bench --bench ops_hotpath -- --quick --json perf-reports/ops_hotpath.json

# AOT-lower the tiny JAX model (L1 Pallas kernels) to HLO text + ALF
# weights under rust/artifacts/, enabling the golden_pjrt suite (which
# additionally needs a build with `--features pjrt`). Requires a
# python environment with jax; see python/compile/aot.py.
artifacts:
	python3 python/compile/aot.py --out-dir rust/artifacts

bench-figures:
	cargo bench --bench table1_membw
	cargo bench --bench fig10_single_node
	cargo bench --bench fig11_multi_node
	cargo bench --bench fig12_decode_long
	cargo bench --bench fig13_prefill

lint:
	cargo fmt --all --check
	cargo clippy --workspace --all-targets -- -D warnings
