//! Paged KV cache: fragmentation, prefix sharing and copy-on-write.
//!
//! Three layers of guarantee, bottom-up:
//! 1. the `PageArena` never strands a page under randomized
//!    alloc/extend/free/register traffic — every page stays reachable
//!    through the free list or FIFO eviction;
//! 2. forking a sequence shares its pages (refcounted) and the first
//!    divergent append copies exactly one page, with both children
//!    bit-identical to independently-decoded references;
//! 3. interleaved decoding on a small arena matches serial decoding
//!    with page recycling in between, token for token.

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions};
use arclight::graph::PageArena;
use arclight::hw::Platform;
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::util::rng::Rng;

// ---------------------------------------------------------------------------
// 1. arena-level randomized fragmentation
// ---------------------------------------------------------------------------

const TOTAL: usize = 24;
const PS: usize = 4;

/// One simulated sequence: pages it holds, tokens stored, token budget.
struct Sim {
    table: Vec<u32>,
    len: usize,
    budget: usize,
}

impl Sim {
    fn reserved(&self) -> usize {
        self.budget.div_ceil(PS) - self.table.len()
    }
}

#[test]
fn randomized_traffic_strands_no_pages() {
    let mut arena = PageArena::new(TOTAL, PS);
    let mut rng = Rng::new(0xA110C);
    let mut live: Vec<Sim> = Vec::new();
    let mut next_hash = 1u64;
    for _ in 0..4000 {
        match rng.below(10) {
            // start a sequence (reservation-based admission)
            0..=3 => {
                let budget = rng.range(1, 3 * PS + 1);
                if arena.admit(&[], budget.div_ceil(PS)).is_some() {
                    live.push(Sim { table: Vec::new(), len: 0, budget });
                }
            }
            // extend a random live sequence by one token
            4..=8 => {
                if live.is_empty() {
                    continue;
                }
                let i = rng.below(live.len());
                let s = &mut live[i];
                if s.len == s.budget {
                    continue;
                }
                if s.len % PS == 0 {
                    s.table.push(arena.alloc_page());
                }
                s.len += 1;
                // register half the completed pages (prefix index +
                // eventual FIFO eviction traffic)
                if s.len % PS == 0 && rng.below(2) == 0 {
                    arena.register(next_hash, *s.table.last().unwrap());
                    next_hash += 1;
                }
            }
            // retire a random live sequence
            _ => {
                if live.is_empty() {
                    continue;
                }
                let s = live.swap_remove(rng.below(live.len()));
                arena.unreserve(s.reserved());
                for p in s.table {
                    arena.release(p);
                }
            }
        }
        // tables never share pages here, so held pages are exactly the
        // table lengths; everything else in use is index-only cache
        let live_pages: usize = live.iter().map(|s| s.table.len()).sum();
        assert_eq!(arena.in_use_pages(), live_pages + arena.cached_pages());
        let reserved: usize = live.iter().map(Sim::reserved).sum();
        assert_eq!(arena.available_pages(), TOTAL - live_pages - reserved);
    }
    for s in live.drain(..) {
        arena.unreserve(s.reserved());
        for p in s.table {
            arena.release(p);
        }
    }
    // zero stranded pages: with no live sequence every page is free or
    // evictable, and a full-arena admission can claim all of them
    assert_eq!(arena.available_pages(), TOTAL);
    assert!(arena.admit(&[], TOTAL).is_some());
    let mut claimed: Vec<u32> = (0..TOTAL).map(|_| arena.alloc_page()).collect();
    claimed.sort_unstable();
    claimed.dedup();
    assert_eq!(claimed.len(), TOTAL, "every physical page must be reachable");
}

// ---------------------------------------------------------------------------
// 2–3. engine-level: CoW divergence and interleaved-vs-serial
// ---------------------------------------------------------------------------

fn paged_engine(batch_slots: usize, kv_pages: usize) -> Engine {
    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 2,
        platform: Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0)),
        prefill_rows: None,
        seed: 11,
        batch_slots,
        pin: false,
        page_size: PS,
        kv_pages: Some(kv_pages),
        base_node: 0,
    };
    Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap()
}

/// Feed `tokens` through one lane and return the logits of the last
/// step.
fn feed(engine: &mut Engine, seq: &arclight::frontend::SeqHandle, tokens: &[i32]) -> Vec<f32> {
    let mut logits = Vec::new();
    for &t in tokens {
        logits = engine.step_batch(&[(seq, t)]).remove(0);
    }
    logits
}

#[test]
fn fork_shares_pages_and_copies_once_on_divergence() {
    let prefix = [5i32, 6, 7, 8, 9, 10, 11, 12, 13, 14]; // 10 tokens
    let tail_a = [20i32, 21, 22, 23];
    let tail_b = [30i32, 31, 32, 33];

    let mut engine = paged_engine(4, 16);
    let parent = engine.seq_start(24).unwrap();
    feed(&mut engine, &parent, &prefix);
    let used_before = engine.kv_pages_in_use();
    assert_eq!(used_before, prefix.len().div_ceil(PS)); // 3 pages

    // fork: all pages shared, no copies yet
    let child = engine.seq_fork(&parent, 24).unwrap();
    assert_eq!(engine.seq_pos(&child), prefix.len());
    assert_eq!(engine.kv_pages_in_use(), used_before, "fork must not copy pages");

    // first divergent append lands mid-page on a shared page: exactly
    // one CoW copy, whichever lane writes first
    let mut la = engine.step_batch(&[(&parent, tail_a[0]), (&child, tail_b[0])]);
    let mut logits_b = la.remove(1);
    let mut logits_a = la.remove(0);
    assert_eq!(
        engine.kv_pages_in_use(),
        used_before + 1,
        "divergence must copy exactly the shared tail page"
    );
    for i in 1..tail_a.len() {
        let mut l = engine.step_batch(&[(&parent, tail_a[i]), (&child, tail_b[i])]);
        logits_b = l.remove(1);
        logits_a = l.remove(0);
    }

    // both children must be bit-identical to independent references
    let mut ref_a = paged_engine(4, 16);
    let sa = ref_a.seq_start(24).unwrap();
    let want_a = feed(&mut ref_a, &sa, &[&prefix[..], &tail_a[..]].concat());
    assert_eq!(logits_a, want_a, "forked parent diverged from serial reference");

    let mut ref_b = paged_engine(4, 16);
    let sb = ref_b.seq_start(24).unwrap();
    let want_b = feed(&mut ref_b, &sb, &[&prefix[..], &tail_b[..]].concat());
    assert_eq!(logits_b, want_b, "forked child diverged from serial reference");

    // RAII teardown returns every page; shared prefix pages survive
    // only as evictable cache
    let total = engine.kv_total_pages();
    drop(child);
    assert!(engine.kv_pages_in_use() >= engine.seq_pages(&parent));
    drop(parent);
    assert_eq!(engine.seqs_in_use(), 0);
    assert_eq!(engine.kv_available_pages(), total, "retired pages must all be reclaimable");
}

#[test]
fn interleaved_matches_serial_with_page_recycling() {
    // three 20-token streams on a 16-page (64-token) arena: serial runs
    // recycle pages between sequences, the interleaved run holds all
    // 15 pages at once
    let streams: [Vec<i32>; 3] = [
        (0..20).map(|k| 40 + k).collect(),
        (0..20).map(|k| 80 + 3 * k).collect(),
        (0..20).map(|k| 140 + 2 * k).collect(),
    ];

    let mut serial = paged_engine(3, 16);
    let mut want = Vec::new();
    for s in &streams {
        let h = serial.seq_start(s.len()).unwrap();
        want.push(feed(&mut serial, &h, s));
        drop(h); // pages recycle before the next sequence starts
        assert_eq!(serial.seqs_in_use(), 0);
    }

    let mut inter = paged_engine(3, 16);
    let seqs: Vec<_> = streams.iter().map(|s| inter.seq_start(s.len()).unwrap()).collect();
    let mut got: Vec<Vec<f32>> = vec![Vec::new(); 3];
    for step in 0..20 {
        let lanes: Vec<_> = seqs.iter().zip(&streams).map(|(h, s)| (h, s[step])).collect();
        let out = inter.step_batch(&lanes);
        for (g, o) in got.iter_mut().zip(out) {
            *g = o;
        }
    }
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g, w, "lane {i}: interleaved decode diverged from serial");
    }

    let total = inter.kv_total_pages();
    drop(seqs);
    assert_eq!(inter.kv_available_pages(), total);
}
