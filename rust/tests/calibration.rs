//! Measured-bandwidth calibration, end to end: the disk cache keyed by
//! the topology fingerprint (measure once, serve every later run from
//! cache), the lowering of a stored calibration into a host
//! `Platform`, and the headline **flip test** — the same machine, the
//! same thread budget, but the auto-tuner picks a *different*
//! parallelism strategy once an asymmetric measured matrix replaces
//! the symmetric SLIT placeholder. That flip is the whole point of
//! `arclight calibrate`: distance ratios say the cross-socket link is
//! fine, the STREAM measurement says it is dead, and only the measured
//! model steers the tuner away from tensor parallelism.

use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use arclight::baseline::tune;
use arclight::hw::bench::{self, Calibration};
use arclight::hw::topology::{HostNode, HostTopology};
use arclight::hw::Platform;
use arclight::model::ModelConfig;
use arclight::numa::{BandwidthSource, Topology};

/// A 2-socket machine with wide nodes (96 cpus each) and a SLIT that
/// claims the cross link is nearly as fast as local (10 vs 11).
fn wide_two_node_host() -> HostTopology {
    HostTopology {
        nodes: vec![
            HostNode { id: 0, cpus: (0..96).collect(), mem_total_kb: 1 << 20 },
            HostNode { id: 1, cpus: (96..192).collect(), mem_total_kb: 1 << 20 },
        ],
        distance: vec![vec![10, 11], vec![11, 10]],
    }
}

/// Strip the non-bandwidth noise terms (jitter, dispatch tax, barrier
/// protocol) so candidate ranking reflects the bandwidth matrix alone
/// — the quantity this test pins.
fn quiet(mut t: Topology) -> Topology {
    t.jitter = 0.0;
    t.op_dispatch = 0.0;
    t.barrier_local = 0.0;
    t.barrier_per_node = 0.0;
    t.barrier_per_thread = 0.0;
    t
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("arclight-calibration-{}-{name}", std::process::id()))
}

/// The tentpole acceptance test: a dead measured cross link flips the
/// tuner's choice away from the tensor parallelism the symmetric SLIT
/// placeholder favours.
#[test]
fn measured_matrix_flips_the_tuner_choice() {
    let host = wide_two_node_host();
    let cfg = ModelConfig::small_25m();
    let threads = 96;

    // placeholder lowering: both locals 100 GB/s, cross ≈ 91 GB/s.
    // 96 workers on one node share one 100 GB/s channel; TP2 streams
    // each weight shard from its own node (2 × 100 GB/s) and pays the
    // (placeholder-fast) link only for the small activation traffic —
    // tensor parallelism wins.
    let placeholder = quiet(host.to_topology());
    assert_eq!(placeholder.bw_source, BandwidthSource::SlitPlaceholder);
    let p = tune::auto_select(&cfg, &placeholder, threads, 0, 2).unwrap();
    assert_eq!(
        p.best.strategy.nodes_used(),
        2,
        "symmetric placeholder should pick TP2, got {} ({:.1} µs)",
        p.best.strategy.name(),
        p.best.predicted_us
    );

    // measured lowering: same machine, but the STREAM benchmark found
    // the cross link is dead (and asymmetric) — every TP candidate now
    // pays ~2500× per activation byte crossing the socket, so the
    // tuner retreats to a single node.
    let matrix = vec![vec![100.0, 0.05], vec![0.04, 95.0]];
    let measured = quiet(host.to_topology_measured(&matrix));
    assert_eq!(measured.bw_source, BandwidthSource::Measured);
    let m = tune::auto_select(&cfg, &measured, threads, 0, 2).unwrap();
    assert_eq!(
        m.best.strategy.nodes_used(),
        1,
        "dead measured link should flip to single-node, got {} ({:.1} µs)",
        m.best.strategy.name(),
        m.best.predicted_us
    );
    assert_ne!(p.best.strategy.name(), m.best.strategy.name(), "the choice must flip");

    // the flip is structural, not a tie-break: under the measured
    // model, the placeholder's winner is catastrophically slower than
    // the measured winner.
    let placeholder_choice_under_measured = m
        .candidates
        .iter()
        .find(|c| c.strategy.name() == p.best.strategy.name() && c.base_node == p.best.base_node)
        .expect("the placeholder winner is still in the measured field");
    assert!(
        placeholder_choice_under_measured.predicted_us > m.best.predicted_us * 10.0,
        "measured model must show a decisive margin: {} µs vs {} µs",
        placeholder_choice_under_measured.predicted_us,
        m.best.predicted_us
    );
}

/// Second `calibrate` run pays nothing: the fingerprint-keyed cache
/// serves the stored matrix and the measurement closure never runs.
#[test]
fn second_calibrate_run_never_remeasures() {
    let host = wide_two_node_host();
    let path = tmp("cache-hit");
    let _ = fs::remove_file(&path);
    let runs = AtomicUsize::new(0);
    let measure = |_: &HostTopology| {
        runs.fetch_add(1, Ordering::SeqCst);
        vec![vec![90.0, 20.0], vec![19.0, 88.0]]
    };

    let first = bench::calibrate_with(&host, &path, false, measure).unwrap();
    assert!(!first.from_cache);
    assert_eq!(runs.load(Ordering::SeqCst), 1);

    let second = bench::calibrate_with(&host, &path, false, |_: &HostTopology| {
        unreachable!("a fingerprint-matched cache must serve without re-measuring")
    })
    .unwrap();
    assert!(second.from_cache);
    assert_eq!(second.cal, first.cal);
    assert_eq!(runs.load(Ordering::SeqCst), 1, "zero re-measurement on the second run");

    // a different machine (one cpu offlined) invalidates the cache
    let mut changed = wide_two_node_host();
    changed.nodes[1].cpus.pop();
    let third = bench::calibrate_with(&changed, &path, false, |_: &HostTopology| {
        runs.fetch_add(1, Ordering::SeqCst);
        vec![vec![80.0, 10.0], vec![10.0, 80.0]]
    })
    .unwrap();
    assert!(!third.from_cache, "fingerprint mismatch must force a fresh measurement");
    assert_eq!(runs.load(Ordering::SeqCst), 2);
    let _ = fs::remove_file(&path);
}

/// Corrupted or truncated cache files are rejected (and fall back to
/// measurement) rather than lowering garbage into the cost model.
#[test]
fn damaged_caches_fall_back_to_measurement() {
    let host = wide_two_node_host();
    let path = tmp("damaged");
    let good = Calibration {
        fingerprint: host.fingerprint(),
        matrix_gb: vec![vec![90.0, 20.0], vec![19.0, 88.0]],
    };
    good.store(&path).unwrap();
    let text = fs::read_to_string(&path).unwrap();

    // truncation and bit-rot both fail closed
    fs::write(&path, &text[..text.len() / 2]).unwrap();
    assert!(Calibration::load(&path).is_err());
    assert!(bench::cached_matrix(&host, &path).is_none());
    fs::write(&path, text.replace("matrix_gb", "matrix_xx")).unwrap();
    assert!(bench::cached_matrix(&host, &path).is_none());

    let rebuilt = bench::calibrate_with(&host, &path, false, |_: &HostTopology| {
        good.matrix_gb.clone()
    })
    .unwrap();
    assert!(!rebuilt.from_cache, "a damaged cache must be re-measured, not trusted");
    assert_eq!(rebuilt.cal, good);
    let _ = fs::remove_file(&path);
}

/// A stored calibration re-lowers a host `Platform` to the measured
/// matrix: the full path `serve`/`run`/the benches take via
/// `--cache`, from a sysfs fixture tree on disk.
#[test]
fn platform_picks_up_a_stored_calibration() {
    // sysfs-style fixture tree for a small 2-node machine
    let root = tmp("sysfs-root");
    let _ = fs::remove_dir_all(&root);
    for (id, cpulist, dist) in [(0, "0-3", "10 20"), (1, "4-7", "20 10")] {
        let dir = root.join(format!("node{id}"));
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("cpulist"), format!("{cpulist}\n")).unwrap();
        fs::write(dir.join("distance"), format!("{dist}\n")).unwrap();
    }
    let host = HostTopology::from_root(&root).expect("fixture tree parses");

    let cache = tmp("platform-cache");
    let _ = fs::remove_file(&cache);
    let platform = Platform::from_host(host.clone());

    // no cache on disk: the SLIT placeholder stands
    let before = platform.clone().with_cached_calibration(&cache);
    assert_eq!(before.topology().bw_source, BandwidthSource::SlitPlaceholder);

    // a fingerprint-matched calibration upgrades the lowering
    Calibration {
        fingerprint: host.fingerprint(),
        matrix_gb: vec![vec![87.0, 6.5], vec![6.0, 91.0]],
    }
    .store(&cache)
    .unwrap();
    let after = platform.with_cached_calibration(&cache);
    assert_eq!(after.topology().bw_source, BandwidthSource::Measured);
    assert_eq!(after.topology().bandwidth(0, 1), 6.5e9);
    assert_eq!(after.topology().bandwidth(1, 1), 91.0e9);

    // a simulated platform is untouched by the same cache
    let sim = Platform::simulated().with_cached_calibration(&cache);
    assert_eq!(sim.topology().bw_source, BandwidthSource::Simulated);

    let _ = fs::remove_file(&cache);
    let _ = fs::remove_dir_all(&root);
}
