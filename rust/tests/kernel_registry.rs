//! Registry completeness: every `OpKind` a `ModelGraphs` build can
//! emit must resolve to a kernel whose `units()` matches the legacy
//! `sched::partition_units` row policy — the PR-2 function is pinned
//! here verbatim (as `legacy_units`) before it was deleted, so a
//! kernel silently changing its partition policy fails this suite.

use arclight::graph::{OpKind, TensorMeta};
use arclight::model::{BuildSpec, ModelConfig, ModelGraphs};
use arclight::numa::Placement;
use arclight::ops::kernel::KernelRegistry;
use arclight::sched::{BatchView, ExecParams};
use arclight::tensor::DType;

/// The pre-refactor `sched::partition_units` (PR 2), kept as the
/// behavioral pin for `Kernel::units`.
fn legacy_units(meta: &TensorMeta, params: &ExecParams) -> usize {
    use OpKind::*;
    let act_rows = meta.rows().min(params.rows.max(1));
    match &meta.op {
        Leaf => 0,
        Embed => act_rows,
        RmsNorm { .. } => act_rows,
        RmsNormHeads { heads, .. } => *heads,
        MatMul => meta.row_len(), // output features N
        Rope { heads, .. } => *heads,
        StoreKv { kv_heads, .. } => *kv_heads,
        Attention { heads, .. } => *heads,
        SliceRow { .. } => meta.row_len(),
        Silu | Add | Mul | SwiGlu | Copy | AddN => act_rows * meta.row_len(),
    }
}

fn meta(op: OpKind, shape: Vec<usize>) -> TensorMeta {
    TensorMeta {
        name: "t".into(),
        dtype: DType::F32,
        shape,
        op,
        src: vec![],
        placement: Placement::Node(0),
        buf: None,
        group: None,
    }
}

/// The exact unit-count table the old `sched/mod.rs` tests pinned,
/// replayed against registry-resolved kernels.
#[test]
fn units_table_matches_legacy_values() {
    let reg = KernelRegistry::global();
    let units =
        |m: &TensorMeta, p: &ExecParams| reg.resolve(&m.op, Some(DType::F32)).units(m, p);

    let p = ExecParams::dense(4, 2);
    assert_eq!(p.kv_len(), 6);
    assert_eq!(units(&meta(OpKind::MatMul, vec![2, 96]), &p), 96);
    let attn = OpKind::Attention { heads: 8, kv_heads: 2, head_dim: 16, max_seq: 64 };
    assert_eq!(units(&meta(attn, vec![2, 128]), &p), 8);
    assert_eq!(units(&meta(OpKind::Add, vec![2, 64]), &p), 128);
    assert_eq!(units(&meta(OpKind::RmsNorm { eps: 1e-6 }, vec![2, 64]), &p), 2);

    // a batch graph built for 8 rows running 3 active lanes
    let p = ExecParams::batched(BatchView::new(64, vec![vec![0], vec![1], vec![2]], vec![5, 0, 9]));
    assert_eq!(p.rows, 3);
    assert_eq!(units(&meta(OpKind::Embed, vec![8, 64]), &p), 3);
    assert_eq!(units(&meta(OpKind::Add, vec![8, 64]), &p), 3 * 64);
    assert_eq!(units(&meta(OpKind::RmsNorm { eps: 1e-6 }, vec![8, 64]), &p), 3);
    // matmul still partitions output features, not rows
    assert_eq!(units(&meta(OpKind::MatMul, vec![8, 96]), &p), 96);
}

/// Every op every graph construction mode emits (single, TP, prefill,
/// batched, llama-placement) resolves, and its unit policy matches the
/// legacy partitioner under dense, prefill and batched params.
#[test]
fn registry_covers_every_graph_op() {
    let specs = vec![
        BuildSpec::arclight(ModelConfig::tiny(), 1)
            .with_prefill(5)
            .with_batch(3)
            .with_sim_only(true),
        BuildSpec::arclight(ModelConfig::tiny(), 2).with_sim_only(true),
        BuildSpec::llama_cpp(ModelConfig::tiny(), 4, 4).with_sim_only(true),
    ];
    let param_sets = [
        ExecParams::dense(3, 1),
        ExecParams::dense(0, 5),
        ExecParams::batched(BatchView::new(64, vec![vec![0], vec![1]], vec![2, 0])),
    ];
    let mut checked = 0usize;
    for spec in specs {
        let m = ModelGraphs::build(spec);
        let graphs: Vec<_> = [Some(&m.decode), m.prefill.as_ref(), m.decode_batch.as_ref()]
            .into_iter()
            .flatten()
            .collect();
        for g in graphs {
            for entry in &g.exec {
                for id in entry.bundle.iter() {
                    // resolution happened at graph build; a missing
                    // kernel would have panicked there
                    let k = g.kernel(id);
                    for p in &param_sets {
                        assert_eq!(
                            k.units(g.meta(id), p),
                            legacy_units(g.meta(id), p),
                            "units mismatch for '{}' (kernel {})",
                            g.meta(id).name,
                            k.name()
                        );
                    }
                    checked += 1;
                }
            }
        }
    }
    assert!(checked > 100, "expected a real op population, checked {checked}");
}

/// The registry's kernel listing is total over the OpKind space the
/// builders emit (spot-check the names executors would log).
#[test]
fn registry_listing_names_are_unique() {
    let reg = KernelRegistry::global();
    let names: Vec<&str> = reg.kernels().iter().map(|k| k.name()).collect();
    let set: std::collections::BTreeSet<&&str> = names.iter().collect();
    assert_eq!(set.len(), names.len());
    for n in ["leaf", "embed", "rmsnorm", "rmsnorm_heads", "rope", "store_kv"] {
        assert!(names.contains(&n), "missing {n}");
    }
}
