//! Golden numerics: the native Rust engine vs the PJRT execution of the
//! AOT-lowered JAX model (which routes through the L1 Pallas kernels),
//! on identical ALF weight bytes.
//!
//! Requires `make artifacts` (skipped otherwise).

use std::path::PathBuf;

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions, Sampler};
use arclight::numa::Topology;
use arclight::runtime::{PjrtExecutor, PjrtSession};
use arclight::sched::SyncMode;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Load the PJRT session. Builds without the `pjrt` feature get the
/// stub, whose `load()` always errors — that is a skip (None). With
/// the feature enabled a load error is a genuine regression and must
/// fail the test, not skip it.
fn load_session(dir: &std::path::Path) -> Option<PjrtSession> {
    match PjrtSession::load(dir) {
        Ok(s) => Some(s),
        Err(e) if cfg!(feature = "pjrt") => panic!("PJRT session load failed: {e}"),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

fn engine(strategy: Strategy, threads: usize, prefill: Option<usize>) -> Engine {
    let dir = artifacts_dir().unwrap();
    let opts = EngineOptions {
        strategy,
        threads,
        platform: arclight::hw::Platform::Simulated(Topology::uniform(4, 4, 100.0, 25.0)),
        prefill_rows: prefill,
        seed: 0,
        batch_slots: 1,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node: 0,
    };
    Engine::from_alf(&dir.join("tiny.alf"), &opts).unwrap()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn decode_logits_match_pjrt() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let Some(session) = load_session(&dir) else {
        return;
    };
    let mut eng = engine(Strategy::arclight_single(), 2, None);

    let (k, v) = session.empty_kv().unwrap();
    let (pjrt_logits, k, v) = session.run_decode(7, 0, &k, &v).unwrap();
    let native_logits = eng.decode_step(7);
    assert_eq!(pjrt_logits.len(), native_logits.len());
    let d = max_abs_diff(&pjrt_logits, &native_logits);
    assert!(d < 1e-3, "decode logits diverge: {d}");

    // a second step exercises the KV-cache path on both sides
    let (pjrt2, _, _) = session.run_decode(42, 1, &k, &v).unwrap();
    let native2 = eng.decode_step(42);
    let d2 = max_abs_diff(&pjrt2, &native2);
    assert!(d2 < 1e-3, "step-2 logits diverge: {d2}");
}

#[test]
fn prefill_matches_pjrt() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let Some(session) = load_session(&dir) else {
        return;
    };
    let prompt: Vec<i32> =
        (0..session.manifest.prompt_len as i32).map(|i| (i * 7 + 3) % 512).collect();

    let (pjrt_logits, _, _) = session.run_prefill(&prompt).unwrap();
    let mut eng = engine(Strategy::arclight_single(), 2, Some(prompt.len()));
    let native_logits = eng.prefill(&prompt);
    let d = max_abs_diff(&pjrt_logits, &native_logits);
    assert!(d < 1e-3, "prefill logits diverge: {d}");
}

#[test]
fn tp_engine_matches_pjrt() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let Some(session) = load_session(&dir) else {
        return;
    };
    let (k, v) = session.empty_kv().unwrap();
    let (pjrt_logits, _, _) = session.run_decode(11, 0, &k, &v).unwrap();
    let mut eng = engine(Strategy::arclight_tp(2, SyncMode::SyncB), 4, None);
    let native = eng.decode_step(11);
    let d = max_abs_diff(&pjrt_logits, &native);
    assert!(d < 1e-3, "TP engine diverges from PJRT: {d}");
}

#[test]
fn greedy_generation_matches_pjrt() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let Some(session) = load_session(&dir) else {
        return;
    };
    let prompt: Vec<i32> =
        (0..session.manifest.prompt_len as i32).map(|i| (i * 13 + 1) % 512).collect();
    let pjrt_tokens = session.generate(&prompt, 12).unwrap();

    let mut eng = engine(Strategy::arclight_single(), 2, Some(prompt.len()));
    let res = eng.generate(&prompt, 12, &Sampler::greedy());
    assert_eq!(pjrt_tokens, res.tokens, "greedy token streams diverge");
}

/// The PJRT backend driven through the unified `sched::Executor` trait
/// (the same code path `arclight golden` uses) must reproduce the
/// native engine's greedy stream.
#[test]
fn executor_trait_generation_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let pjrt = match PjrtExecutor::load(&dir) {
        Ok(x) => x,
        Err(e) if cfg!(feature = "pjrt") => panic!("PJRT executor load failed: {e}"),
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let prompt: Vec<i32> = (0..pjrt.session.manifest.prompt_len as i32).collect();
    let mut eng = engine(Strategy::arclight_single(), 2, Some(prompt.len()));
    let res = eng.generate(&prompt, 8, &Sampler::greedy());

    let graph = eng.graphs.decode.clone();
    let toks = pjrt.generate_greedy(&graph, &prompt, 8);
    assert_eq!(toks, res.tokens, "Executor-trait PJRT drive diverges from native");
}
