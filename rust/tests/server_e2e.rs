//! End-to-end serving tests: TCP API → router → scheduler → engine.
//! Both schedulers are exercised: the sequential-slot baseline and the
//! continuous batcher.

use std::sync::Arc;
use std::time::Duration;

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions};
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::server::{
    BatcherConfig, ContinuousBatcher, EngineSlot, GenRequest, Router, ServerClient, ServerHandle,
};

fn tiny_engine(batch_slots: usize) -> Engine {
    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 2,
        platform: arclight::hw::Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0)),
        prefill_rows: None,
        seed: 7,
        batch_slots,
        pin: false,
        page_size: 16,
        kv_pages: None,
    };
    Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap()
}

fn start_server(slots: usize) -> (ServerHandle, Arc<Router>, Vec<std::thread::JoinHandle<()>>) {
    let router = Router::new(BatcherConfig {
        queue_capacity: 64,
        max_batch: 4,
        batch_window: Duration::from_millis(1),
    });
    let mut threads = Vec::new();
    for _ in 0..slots {
        let engine = tiny_engine(1);
        let r = router.clone();
        threads.push(std::thread::spawn(move || EngineSlot::new(engine).serve(r)));
    }
    let server = ServerHandle::start("127.0.0.1:0", router.clone()).unwrap();
    (server, router, threads)
}

fn start_continuous(
    batch_slots: usize,
) -> (ServerHandle, Arc<Router>, Vec<std::thread::JoinHandle<()>>) {
    let router = Router::new(BatcherConfig::default());
    let batcher = ContinuousBatcher::new(tiny_engine(batch_slots));
    let r = router.clone();
    let threads = vec![std::thread::spawn(move || batcher.serve(r))];
    let server = ServerHandle::start("127.0.0.1:0", router.clone()).unwrap();
    (server, router, threads)
}

#[test]
fn ping_and_generate_over_tcp() {
    let (server, router, slots) = start_server(1);
    let addr = server.addr.to_string();

    let mut c = ServerClient::connect(&addr).unwrap();
    assert!(c.ping().unwrap());

    let resp = c.generate(&GenRequest::text(1, "hello world", 6)).unwrap();
    assert_eq!(resp.tokens.len(), 6);
    assert!(resp.total_s > 0.0 && resp.ttft_s > 0.0);

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn concurrent_clients_share_slots() {
    let (server, router, slots) = start_server(2);
    let addr = server.addr.to_string();

    let mut joins = Vec::new();
    for i in 0..8u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut c = ServerClient::connect(&addr).unwrap();
            c.generate(&GenRequest::text(i + 1, "abcdef", 5)).unwrap()
        }));
    }
    for j in joins {
        let resp = j.join().unwrap();
        assert_eq!(resp.tokens.len(), 5);
    }

    let mut c = ServerClient::connect(&addr).unwrap();
    let m = c.metrics().unwrap();
    assert_eq!(m.get("requests_total").unwrap().as_usize(), Some(8));
    assert_eq!(m.get("requests_failed").unwrap().as_usize(), Some(0));
    assert!(m.get("decode_tok_per_s").unwrap().as_f64().unwrap() > 0.0);

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn identical_requests_get_identical_tokens() {
    // greedy decoding is deterministic across slots and orderings
    let (server, router, slots) = start_server(2);
    let addr = server.addr.to_string();
    let mut c1 = ServerClient::connect(&addr).unwrap();
    let mut c2 = ServerClient::connect(&addr).unwrap();
    let r1 = c1.generate(&GenRequest::text(1, "same prompt", 8)).unwrap();
    let r2 = c2.generate(&GenRequest::text(2, "same prompt", 8)).unwrap();
    assert_eq!(r1.tokens, r2.tokens);

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn hello_reports_proto_and_features() {
    let (server, router, slots) = start_server(1);
    let addr = server.addr.to_string();

    let mut c = ServerClient::connect(&addr).unwrap();
    let (proto, features) = c.hello().unwrap();
    assert_eq!(proto, 2);
    assert!(features.iter().any(|f| f == "generate"));
    assert!(features.iter().any(|f| f == "paged_kv"));
    assert!(features.iter().any(|f| f == "prefix_cache"));
    // the handshake leaves the connection usable
    assert!(c.ping().unwrap());

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn malformed_requests_get_errors_not_crashes() {
    let (server, router, slots) = start_server(1);
    let addr = server.addr.to_string();

    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // (request, expected structured error code)
    let cases = [
        ("not json\n", "bad_request"),
        ("{\"op\":\"generate\",\"max_new\":3}\n", "bad_request"),
        ("{\"op\":\"nope\"}\n", "unknown_op"),
    ];
    for (bad, code) in cases {
        stream.write_all(bad.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "expected error for {bad:?}, got {line}");
        let j = arclight::util::json::Json::parse(&line).unwrap();
        let err = j.get("error").expect("error object");
        assert_eq!(err.get("code").and_then(|c| c.as_str()), Some(code), "for {bad:?}: {line}");
        assert!(err.get("message").and_then(|m| m.as_str()).is_some(), "message for {bad:?}");
    }
    // unknown ops echo the op back for client-side diagnostics
    stream.write_all(b"{\"op\":\"nope\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = arclight::util::json::Json::parse(&line).unwrap();
    let op = j.get("error").and_then(|e| e.get("op")).and_then(|o| o.as_str());
    assert_eq!(op, Some("nope"));
    // the connection still works afterwards
    stream.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("true"));

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn continuous_server_end_to_end() {
    let (server, router, threads) = start_continuous(4);
    let addr = server.addr.to_string();

    let mut joins = Vec::new();
    for i in 0..8u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut c = ServerClient::connect(&addr).unwrap();
            c.generate(&GenRequest::text(i + 1, "continuous batch", 5)).unwrap()
        }));
    }
    for j in joins {
        let resp = j.join().unwrap();
        assert_eq!(resp.tokens.len(), 5);
    }

    let mut c = ServerClient::connect(&addr).unwrap();
    let m = c.metrics().unwrap();
    assert_eq!(m.get("requests_total").unwrap().as_usize(), Some(8));
    assert!(m.get("decode_steps").unwrap().as_usize().unwrap() > 0);
    assert!(m.get("batch_occupancy").unwrap().as_f64().unwrap() > 1.0);

    server.stop();
    drop(router);
    for t in threads {
        t.join().unwrap();
    }
}

#[test]
fn continuous_server_matches_slot_server_tokens() {
    // the scheduler must be invisible in the tokens: continuous and
    // sequential serving of the same prompt agree exactly
    let (s1, r1, t1) = start_server(1);
    let mut c1 = ServerClient::connect(&s1.addr.to_string()).unwrap();
    let a = c1.generate(&GenRequest::text(1, "the same prompt", 8)).unwrap();
    s1.stop();
    drop(r1);
    for t in t1 {
        t.join().unwrap();
    }

    let (s2, r2, t2) = start_continuous(3);
    let mut c2 = ServerClient::connect(&s2.addr.to_string()).unwrap();
    let b = c2.generate(&GenRequest::text(1, "the same prompt", 8)).unwrap();
    s2.stop();
    drop(r2);
    for t in t2 {
        t.join().unwrap();
    }
    assert_eq!(a.tokens, b.tokens);
}

#[test]
fn long_generation_clamped_to_kv_capacity() {
    let (server, router, slots) = start_server(1);
    let addr = server.addr.to_string();
    let mut c = ServerClient::connect(&addr).unwrap();
    // tiny max_seq = 64; ask for far more
    let resp = c.generate(&GenRequest::text(1, "x", 10_000)).unwrap();
    assert!(resp.tokens.len() <= 64);
    assert!(!resp.tokens.is_empty());

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}
