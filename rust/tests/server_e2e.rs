//! End-to-end serving tests: TCP API → router → scheduler → engine.
//! Both schedulers are exercised: the sequential-slot baseline and the
//! continuous batcher.

use std::sync::Arc;
use std::time::Duration;

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions};
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::server::{
    BatcherConfig, Cluster, ClusterConfig, ContinuousBatcher, EngineSlot, GenRequest, Router,
    ServerClient, ServerHandle,
};

fn tiny_engine(batch_slots: usize) -> Engine {
    tiny_engine_at(0, batch_slots)
}

fn tiny_engine_at(base_node: usize, batch_slots: usize) -> Engine {
    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 2,
        platform: arclight::hw::Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0)),
        prefill_rows: None,
        seed: 7,
        batch_slots,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node,
    };
    Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap()
}

fn start_server(slots: usize) -> (ServerHandle, Arc<Router>, Vec<std::thread::JoinHandle<()>>) {
    let router = Router::new(BatcherConfig {
        queue_capacity: 64,
        max_batch: 4,
        batch_window: Duration::from_millis(1),
    });
    let mut threads = Vec::new();
    for _ in 0..slots {
        let engine = tiny_engine(1);
        let r = router.clone();
        threads.push(std::thread::spawn(move || EngineSlot::new(engine).serve(r)));
    }
    let server = ServerHandle::start("127.0.0.1:0", router.clone()).unwrap();
    (server, router, threads)
}

fn start_continuous(
    batch_slots: usize,
) -> (ServerHandle, Arc<Router>, Vec<std::thread::JoinHandle<()>>) {
    let router = Router::new(BatcherConfig::default());
    let batcher = ContinuousBatcher::new(tiny_engine(batch_slots));
    let r = router.clone();
    let threads = vec![std::thread::spawn(move || batcher.serve(r))];
    let server = ServerHandle::start("127.0.0.1:0", router.clone()).unwrap();
    (server, router, threads)
}

#[test]
fn ping_and_generate_over_tcp() {
    let (server, router, slots) = start_server(1);
    let addr = server.addr.to_string();

    let mut c = ServerClient::connect(&addr).unwrap();
    assert!(c.ping().unwrap());

    let resp = c.generate(&GenRequest::text(1, "hello world", 6)).unwrap();
    assert_eq!(resp.tokens.len(), 6);
    assert!(resp.total_s > 0.0 && resp.ttft_s > 0.0);

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn concurrent_clients_share_slots() {
    let (server, router, slots) = start_server(2);
    let addr = server.addr.to_string();

    let mut joins = Vec::new();
    for i in 0..8u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut c = ServerClient::connect(&addr).unwrap();
            c.generate(&GenRequest::text(i + 1, "abcdef", 5)).unwrap()
        }));
    }
    for j in joins {
        let resp = j.join().unwrap();
        assert_eq!(resp.tokens.len(), 5);
    }

    let mut c = ServerClient::connect(&addr).unwrap();
    let m = c.metrics().unwrap();
    assert_eq!(m.get("requests_total").unwrap().as_usize(), Some(8));
    assert_eq!(m.get("requests_failed").unwrap().as_usize(), Some(0));
    assert!(m.get("decode_tok_per_s").unwrap().as_f64().unwrap() > 0.0);

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn identical_requests_get_identical_tokens() {
    // greedy decoding is deterministic across slots and orderings
    let (server, router, slots) = start_server(2);
    let addr = server.addr.to_string();
    let mut c1 = ServerClient::connect(&addr).unwrap();
    let mut c2 = ServerClient::connect(&addr).unwrap();
    let r1 = c1.generate(&GenRequest::text(1, "same prompt", 8)).unwrap();
    let r2 = c2.generate(&GenRequest::text(2, "same prompt", 8)).unwrap();
    assert_eq!(r1.tokens, r2.tokens);

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn hello_reports_proto_and_features() {
    let (server, router, slots) = start_server(1);
    let addr = server.addr.to_string();

    let mut c = ServerClient::connect(&addr).unwrap();
    let (proto, features) = c.hello().unwrap();
    assert_eq!(proto, 2);
    assert!(features.iter().any(|f| f == "generate"));
    assert!(features.iter().any(|f| f == "paged_kv"));
    assert!(features.iter().any(|f| f == "prefix_cache"));
    // the handshake leaves the connection usable
    assert!(c.ping().unwrap());

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn malformed_requests_get_errors_not_crashes() {
    let (server, router, slots) = start_server(1);
    let addr = server.addr.to_string();

    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // (request, expected structured error code)
    let cases = [
        ("not json\n", "bad_request"),
        ("{\"op\":\"generate\",\"max_new\":3}\n", "bad_request"),
        ("{\"op\":\"nope\"}\n", "unknown_op"),
    ];
    for (bad, code) in cases {
        stream.write_all(bad.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "expected error for {bad:?}, got {line}");
        let j = arclight::util::json::Json::parse(&line).unwrap();
        let err = j.get("error").expect("error object");
        assert_eq!(err.get("code").and_then(|c| c.as_str()), Some(code), "for {bad:?}: {line}");
        assert!(err.get("message").and_then(|m| m.as_str()).is_some(), "message for {bad:?}");
    }
    // unknown ops echo the op back for client-side diagnostics
    stream.write_all(b"{\"op\":\"nope\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = arclight::util::json::Json::parse(&line).unwrap();
    let op = j.get("error").and_then(|e| e.get("op")).and_then(|o| o.as_str());
    assert_eq!(op, Some("nope"));
    // the connection still works afterwards
    stream.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("true"));

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}

#[test]
fn continuous_server_end_to_end() {
    let (server, router, threads) = start_continuous(4);
    let addr = server.addr.to_string();

    let mut joins = Vec::new();
    for i in 0..8u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut c = ServerClient::connect(&addr).unwrap();
            c.generate(&GenRequest::text(i + 1, "continuous batch", 5)).unwrap()
        }));
    }
    for j in joins {
        let resp = j.join().unwrap();
        assert_eq!(resp.tokens.len(), 5);
    }

    let mut c = ServerClient::connect(&addr).unwrap();
    let m = c.metrics().unwrap();
    assert_eq!(m.get("requests_total").unwrap().as_usize(), Some(8));
    assert!(m.get("decode_steps").unwrap().as_usize().unwrap() > 0);
    assert!(m.get("batch_occupancy").unwrap().as_f64().unwrap() > 1.0);

    server.stop();
    drop(router);
    for t in threads {
        t.join().unwrap();
    }
}

#[test]
fn continuous_server_matches_slot_server_tokens() {
    // the scheduler must be invisible in the tokens: continuous and
    // sequential serving of the same prompt agree exactly
    let (s1, r1, t1) = start_server(1);
    let mut c1 = ServerClient::connect(&s1.addr.to_string()).unwrap();
    let a = c1.generate(&GenRequest::text(1, "the same prompt", 8)).unwrap();
    s1.stop();
    drop(r1);
    for t in t1 {
        t.join().unwrap();
    }

    let (s2, r2, t2) = start_continuous(3);
    let mut c2 = ServerClient::connect(&s2.addr.to_string()).unwrap();
    let b = c2.generate(&GenRequest::text(1, "the same prompt", 8)).unwrap();
    s2.stop();
    drop(r2);
    for t in t2 {
        t.join().unwrap();
    }
    assert_eq!(a.tokens, b.tokens);
}

/// One replica per simulated NUMA node group, each engine pinned onto
/// its group via `base_node`, all behind one TCP front door.
fn start_cluster_server(replicas: usize) -> (ServerHandle, Arc<Cluster>) {
    let plat = arclight::hw::Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0));
    let groups = plat.node_groups(Some(replicas));
    let cfg = ClusterConfig { batcher: BatcherConfig::default(), load_tolerance: 2 };
    let cluster =
        Cluster::start(&groups, cfg, |_id, nodes| Ok(tiny_engine_at(nodes[0], 3))).unwrap();
    let server = ServerHandle::start_cluster("127.0.0.1:0", cluster.clone()).unwrap();
    (server, cluster)
}

#[test]
fn cluster_generation_matches_single_engine_serial() {
    // serial reference: one engine, one prompt at a time
    let prompts = ["alpha prompt", "beta prompt", "gamma prompt", "delta prompt"];
    let (s1, r1, t1) = start_continuous(2);
    let mut c = ServerClient::connect(&s1.addr.to_string()).unwrap();
    let mut serial = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        serial.push(c.generate(&GenRequest::text(i as u64 + 1, p, 8)).unwrap().tokens);
    }
    s1.stop();
    drop(r1);
    for t in t1 {
        t.join().unwrap();
    }

    // cluster mode: the same prompts interleaved across two replicas
    let (server, cluster) = start_cluster_server(2);
    assert_eq!(cluster.n_replicas(), 2);
    let addr = server.addr.to_string();
    let mut joins = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let addr = addr.clone();
        let p = p.to_string();
        joins.push(std::thread::spawn(move || {
            let mut c = ServerClient::connect(&addr).unwrap();
            c.generate(&GenRequest::text(i as u64 + 1, &p, 8)).unwrap()
        }));
    }
    let got: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    for (i, r) in got.iter().enumerate() {
        // placement must be invisible in the tokens
        assert_eq!(r.tokens, serial[i], "prompt {i} diverged in cluster mode");
        // responses carry replica/node provenance within the fleet
        assert!(r.replica < 2, "replica {} out of range", r.replica);
        assert!(r.node < 2, "node {} out of range", r.node);
    }
    server.stop();
}

#[test]
fn single_replica_cluster_degrades_to_continuous() {
    let (server, cluster) = start_cluster_server(1);
    assert_eq!(cluster.n_replicas(), 1);
    let mut c = ServerClient::connect(&server.addr.to_string()).unwrap();
    let a = c.generate(&GenRequest::text(1, "degenerate fleet", 8)).unwrap();
    assert_eq!((a.replica, a.node), (0, 0));
    server.stop();

    let (s2, r2, t2) = start_continuous(3);
    let mut c2 = ServerClient::connect(&s2.addr.to_string()).unwrap();
    let b = c2.generate(&GenRequest::text(1, "degenerate fleet", 8)).unwrap();
    s2.stop();
    drop(r2);
    for t in t2 {
        t.join().unwrap();
    }
    assert_eq!(a.tokens, b.tokens);
}

#[test]
fn warm_prompts_route_back_to_their_replica() {
    let (server, _cluster) = start_cluster_server(2);
    let addr = server.addr.to_string();
    let mut c = ServerClient::connect(&addr).unwrap();
    // 40 bytes + BOS = 41 tokens: two completed 16-token kv pages
    let long = "this prompt spans a couple of kv pages!!";
    let a = c.generate(&GenRequest::text(1, long, 6)).unwrap();
    let b = c.generate(&GenRequest::text(2, long, 6)).unwrap();
    assert_eq!(b.replica, a.replica, "warm prompt should return to its pages");
    assert!(b.prefix_hit_tokens >= 16, "expected a prefix hit, got {}", b.prefix_hit_tokens);
    assert_eq!(a.tokens, b.tokens);
    server.stop();
}

#[test]
fn cluster_metrics_report_replica_array() {
    let (server, _cluster) = start_cluster_server(2);
    let addr = server.addr.to_string();
    let mut c = ServerClient::connect(&addr).unwrap();
    for i in 0..4u64 {
        c.generate(&GenRequest::text(i + 1, "warm the fleet", 4)).unwrap();
    }
    let m = c.metrics().unwrap();
    // top-level fields stay cluster-wide aggregates
    assert_eq!(m.get("requests_total").unwrap().as_usize(), Some(4));
    let reps = m.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps.len(), 2);
    let mut decoded = 0;
    let mut pages_total = 0;
    for (i, r) in reps.iter().enumerate() {
        assert_eq!(r.get("replica").unwrap().as_usize(), Some(i));
        assert!(r.get("node").unwrap().as_usize().unwrap() < 2);
        assert!(r.get("live_lanes").is_some());
        assert!(r.get("queue_depth").is_some());
        assert!(r.get("tokens_per_s").is_some());
        assert!(r.get("prefix_hit_tokens").is_some());
        decoded += r.get("tokens_decoded").unwrap().as_usize().unwrap();
        pages_total += r.get("kv_pages_total").unwrap().as_usize().unwrap();
    }
    assert!(decoded >= 16, "fleet decoded only {decoded} tokens");
    // the aggregate kv gauge is the sum over replicas
    assert_eq!(m.get("kv_pages_total").unwrap().as_usize(), Some(pages_total));
    server.stop();
}

#[test]
fn over_capacity_connections_get_structured_overloaded() {
    let router = Router::new(BatcherConfig::default());
    let batcher = ContinuousBatcher::new(tiny_engine(2));
    let r = router.clone();
    let threads = vec![std::thread::spawn(move || batcher.serve(r))];
    let server = ServerHandle::start_with_limit("127.0.0.1:0", router.clone(), 1).unwrap();
    let addr = server.addr.to_string();

    let mut first = ServerClient::connect(&addr).unwrap();
    assert!(first.ping().unwrap()); // the one admitted slot is now held

    // the next connection is over the cap: one structured error, close
    use std::io::BufRead;
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = std::io::BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = arclight::util::json::Json::parse(&line).unwrap();
    let code = j.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str());
    assert_eq!(code, Some("overloaded"), "got {line}");
    // the admitted connection is unaffected
    assert!(first.ping().unwrap());

    // closing the admitted connection frees the slot
    drop(first);
    let mut readmitted = false;
    for _ in 0..200 {
        std::thread::sleep(Duration::from_millis(5));
        let mut c = ServerClient::connect(&addr).unwrap();
        if c.ping().unwrap_or(false) {
            readmitted = true;
            break;
        }
    }
    assert!(readmitted, "slot never freed after the first connection closed");

    server.stop();
    drop(router);
    for t in threads {
        t.join().unwrap();
    }
}

#[test]
fn client_read_timeout_fires_on_a_silent_server() {
    // a listener that accepts and then never says anything
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let silent = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        std::thread::sleep(Duration::from_millis(500));
        drop(stream);
    });
    let mut c = ServerClient::connect_with_timeouts(
        &addr,
        Duration::from_secs(1),
        Some(Duration::from_millis(100)),
    )
    .unwrap();
    let start = std::time::Instant::now();
    assert!(c.ping().is_err(), "read from a silent server must time out");
    assert!(start.elapsed() < Duration::from_millis(450), "timeout took {:?}", start.elapsed());
    silent.join().unwrap();
}

#[test]
fn long_generation_clamped_to_kv_capacity() {
    let (server, router, slots) = start_server(1);
    let addr = server.addr.to_string();
    let mut c = ServerClient::connect(&addr).unwrap();
    // tiny max_seq = 64; ask for far more
    let resp = c.generate(&GenRequest::text(1, "x", 10_000)).unwrap();
    assert!(resp.tokens.len() <= 64);
    assert!(!resp.tokens.is_empty());

    server.stop();
    drop(router);
    for t in slots {
        t.join().unwrap();
    }
}
