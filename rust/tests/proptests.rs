//! Randomized property tests (std-only harness: deterministic seeds,
//! many cases per property — the vendored environment has no proptest).
//!
//! Invariants covered: quantization error bounds, work-partition
//! completeness, GEMM stripe composition, placement accounting
//! conservation, engine determinism across random strategy/thread
//! configurations, JSON round-tripping, and barrier stress.

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions};
use arclight::model::ModelConfig;
use arclight::numa::{Placement, Topology};
use arclight::quant;
use arclight::sched::SyncMode;
use arclight::threads::SpinBarrier;
use arclight::util::json::Json;
use arclight::util::{chunk_range, Rng};

const CASES: usize = 60;

#[test]
fn prop_q4_roundtrip_error_bounded() {
    let mut rng = Rng::new(0xA11CE);
    for _ in 0..CASES {
        let blocks = rng.range(1, 8);
        let scale = (10f32).powi(rng.range(0, 6) as i32 - 3);
        let mut x = vec![0.0f32; blocks * 32];
        rng.fill_normal(&mut x, scale);
        let mut raw = Vec::new();
        quant::quantize_row_q4_0(&x, &mut raw);
        let mut y = vec![0.0f32; x.len()];
        quant::dequantize_row_q4_0(&raw, &mut y);
        for (bi, block) in x.chunks_exact(32).enumerate() {
            let d = arclight::util::f16_to_f32(u16::from_le_bytes([raw[bi * 18], raw[bi * 18 + 1]]))
                .abs();
            for (i, &v) in block.iter().enumerate() {
                let err = (v - y[bi * 32 + i]).abs();
                assert!(err <= d + d * 0.02 + 1e-7, "err {err} > step {d}");
            }
        }
    }
}

#[test]
fn prop_chunk_range_partitions_exactly() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..CASES * 4 {
        let n = rng.below(10_000);
        let parts = rng.range(1, 300);
        let mut covered = 0usize;
        let mut prev = 0usize;
        for i in 0..parts {
            let (s, e) = chunk_range(n, parts, i);
            assert_eq!(s, prev);
            assert!(e >= s);
            // balance: no chunk exceeds ceil(n/parts)
            assert!(e - s <= n.div_ceil(parts));
            covered += e - s;
            prev = e;
        }
        assert_eq!(covered, n);
    }
}

#[test]
fn prop_gemm_stripes_compose() {
    let mut rng = Rng::new(0xCAFE);
    for _ in 0..20 {
        let m = rng.range(1, 4);
        let k = 32 * rng.range(1, 4);
        let n = rng.range(4, 24);
        let mut x = vec![0.0f32; m * k];
        let mut w = vec![0.0f32; n * k];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut w, 1.0);
        let wq = quant::quantize_matrix_q4_0(&w, n, k);

        let mut full = vec![0.0f32; m * n];
        arclight::ops::gemm::gemm_q4_0(&x, &wq, &mut full, m, k, n, 0, n);

        let parts = rng.range(2, 5);
        let mut split = vec![0.0f32; m * n];
        for p in 0..parts {
            let (a, b) = chunk_range(n, parts, p);
            arclight::ops::gemm::gemm_q4_0(&x, &wq, &mut split, m, k, n, a, b);
        }
        assert_eq!(full, split, "stripes must compose bit-exactly");
    }
}

#[test]
fn prop_placement_bytes_conserved() {
    // summing bytes_by_node over any row range must equal rows × row_bytes
    let mut rng = Rng::new(0xD00D);
    for _ in 0..CASES {
        let rows = rng.range(1, 500);
        let nodes = rng.range(1, 4);
        let row_bytes = (rng.range(1, 64) * 4) as f64;
        let placement = match rng.below(3) {
            0 => Placement::Node(rng.below(nodes)),
            1 => Placement::Interleaved(nodes),
            _ => Placement::even_shards(rows, nodes),
        };
        let r0 = rng.below(rows);
        let r1 = rng.range(r0 + 1, rows);
        let total: f64 = placement
            .bytes_by_node(r0, r1, rows, row_bytes, 4)
            .iter()
            .map(|(_, b)| b)
            .sum();
        let expect = (r1 - r0) as f64 * row_bytes;
        assert!((total - expect).abs() < 1e-6, "{placement:?}: {total} vs {expect}");
        // spread_bytes conserves too
        let spread: f64 = placement.spread_bytes(1234.5, 4).iter().map(|(_, b)| b).sum();
        assert!((spread - 1234.5).abs() < 1e-9);
    }
}

#[test]
fn prop_engine_deterministic_across_random_configs() {
    let topo = Topology::uniform(4, 4, 100.0, 25.0);
    let mut reference: Option<Vec<i32>> = None;
    let mut rng = Rng::new(0x5EED5);
    for _ in 0..6 {
        let strategy = match rng.below(4) {
            0 => Strategy::arclight_single(),
            1 => Strategy::arclight_tp(2, SyncMode::SyncA),
            2 => Strategy::arclight_tp(2, SyncMode::SyncB),
            _ => Strategy::llama_distribute(2),
        };
        let threads = rng.range(strategy.nodes_used().max(1), 8);
        let opts = EngineOptions {
            strategy,
            threads,
            platform: arclight::hw::Platform::Simulated(topo.clone()),
            prefill_rows: None,
            seed: 31,
            batch_slots: 1,
            pin: false,
            page_size: 16,
            kv_pages: None,
            base_node: 0,
        };
        let mut e = Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap();
        let res = e.generate(&[5, 9, 2], 10, &arclight::frontend::Sampler::greedy());
        match &reference {
            None => reference = Some(res.tokens),
            Some(want) => assert_eq!(
                want, &res.tokens,
                "{} with {threads} threads diverged",
                strategy.name()
            ),
        }
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    let mut rng = Rng::new(0x1AB);
    for _ in 0..CASES {
        let j = random_json(&mut rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("reparse failed: {e} for {text}"));
        assert_eq!(j, back, "roundtrip mismatch for {text}");
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0).round()),
        3 => {
            let len = rng.below(8);
            let s: String = (0..len)
                .map(|_| char::from_u32(rng.range(32, 0x24F) as u32).unwrap_or('x'))
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_barrier_stress_random_party_counts() {
    let mut rng = Rng::new(0xFA57);
    for _ in 0..10 {
        let n = rng.range(2, 8);
        let rounds = rng.range(10, 60);
        let b = std::sync::Arc::new(SpinBarrier::new(n));
        let serial = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut hs = Vec::new();
        for _ in 0..n {
            let (b, s) = (b.clone(), serial.clone());
            hs.push(std::thread::spawn(move || {
                for _ in 0..rounds {
                    if b.wait() {
                        s.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(serial.load(std::sync::atomic::Ordering::Relaxed), rounds);
    }
}

#[test]
fn prop_f16_widen_narrow_random() {
    let mut rng = Rng::new(0xF16);
    for _ in 0..CASES * 20 {
        let bits = (rng.next_u64() & 0xFFFF) as u16;
        let exp = (bits >> 10) & 0x1F;
        if exp == 0x1F {
            continue;
        }
        let x = arclight::util::f16_to_f32(bits);
        let back = arclight::util::f32_to_f16(x);
        assert!(
            back == bits || (bits == 0x8000 && back == 0x8000),
            "{bits:#06x} → {x} → {back:#06x}"
        );
    }
}
