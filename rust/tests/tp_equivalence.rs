//! Tensor parallelism is a pure execution-strategy change: for the same
//! weights, every strategy/thread-count/sync-mode must produce the same
//! logits (§3.2 correctness). These tests cross all strategies on the
//! real engine.

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions, Sampler};
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::sched::SyncMode;

fn engine(strategy: Strategy, threads: usize) -> Engine {
    let opts = EngineOptions {
        strategy,
        threads,
        platform: arclight::hw::Platform::Simulated(Topology::uniform(4, 4, 100.0, 25.0)),
        prefill_rows: None,
        seed: 99,
        batch_slots: 1,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node: 0,
    };
    Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap()
}

fn logits_after(e: &mut Engine, prompt: &[i32]) -> Vec<f32> {
    e.prefill(prompt)
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < tol, "{what}: logit {i} differs: {x} vs {y}");
    }
}

const PROMPT: [i32; 7] = [3, 14, 15, 92, 65, 35, 8];

#[test]
fn all_strategies_agree() {
    let reference = logits_after(&mut engine(Strategy::arclight_single(), 1), &PROMPT);
    for (s, t) in [
        (Strategy::arclight_single(), 4),
        (Strategy::arclight_tp(2, SyncMode::SyncA), 4),
        (Strategy::arclight_tp(2, SyncMode::SyncB), 4),
        (Strategy::arclight_tp(2, SyncMode::SyncB), 8),
        (Strategy::llama_isolate(), 4),
        (Strategy::llama_distribute(4), 8),
    ] {
        let got = logits_after(&mut engine(s, t), &PROMPT);
        assert_close(&reference, &got, 1e-3, &format!("{} t={t}", s.name()));
    }
}

#[test]
fn tp_greedy_generation_identical() {
    let mut single = engine(Strategy::arclight_single(), 2);
    let mut tp = engine(Strategy::arclight_tp(2, SyncMode::SyncB), 6);
    let a = single.generate(&PROMPT, 16, &Sampler::greedy());
    let b = tp.generate(&PROMPT, 16, &Sampler::greedy());
    assert_eq!(a.tokens, b.tokens);
}

#[test]
fn sync_modes_are_numerically_identical() {
    // Sync B changes scheduling, not math: same partition → same
    // accumulation order → bit-identical logits
    let mut a = engine(Strategy::arclight_tp(2, SyncMode::SyncA), 4);
    let mut b = engine(Strategy::arclight_tp(2, SyncMode::SyncB), 4);
    let la = logits_after(&mut a, &PROMPT);
    let lb = logits_after(&mut b, &PROMPT);
    assert_eq!(la, lb, "same worker partition must give bit-identical logits");
}

#[test]
fn four_way_tp_rejected_on_tiny() {
    // tiny has 2 kv heads: a 4-way split is not constructible
    let opts = EngineOptions {
        strategy: Strategy::arclight_tp(4, SyncMode::SyncB),
        threads: 8,
        platform: arclight::hw::Platform::Simulated(Topology::uniform(4, 4, 100.0, 25.0)),
        prefill_rows: None,
        seed: 99,
        batch_slots: 1,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node: 0,
    };
    let r = std::panic::catch_unwind(|| Engine::new_synthetic(ModelConfig::tiny(), &opts));
    assert!(r.is_err(), "tiny model must reject 4-way TP (2 kv heads)");
}

#[test]
fn small_model_four_way_tp_agrees() {
    let topo = Topology::uniform(4, 4, 100.0, 25.0);
    let mk = |s: Strategy, t: usize| {
        let opts = EngineOptions {
            strategy: s,
            threads: t,
            platform: arclight::hw::Platform::Simulated(topo.clone()),
            prefill_rows: None,
            seed: 5,
            batch_slots: 1,
            pin: false,
            page_size: 16,
            kv_pages: None,
            base_node: 0,
        };
        Engine::new_synthetic(ModelConfig::small_25m(), &opts).unwrap()
    };
    let mut single = mk(Strategy::arclight_single(), 2);
    let mut tp4 = mk(Strategy::arclight_tp(4, SyncMode::SyncB), 8);
    let a = single.decode_step(42);
    let b = tp4.decode_step(42);
    assert_close(&a, &b, 2e-3, "small 4-way TP");
}

#[test]
fn position_state_consistent_across_strategies() {
    let mut e = engine(Strategy::arclight_tp(2, SyncMode::SyncB), 4);
    assert_eq!(e.position(), 0);
    e.prefill(&PROMPT);
    assert_eq!(e.position(), PROMPT.len());
    e.decode_step(1);
    assert_eq!(e.position(), PROMPT.len() + 1);
    e.reset();
    assert_eq!(e.position(), 0);
    // after reset the same prompt gives the same logits
    let l1 = e.prefill(&PROMPT);
    e.reset();
    let l2 = e.prefill(&PROMPT);
    assert_eq!(l1, l2);
}
