//! The paper's evaluation *shapes* as executable assertions on the
//! simulated testbed (scaled-down geometry for speed; the full Qwen3-4B
//! runs live in `benches/`).

use arclight::baseline::Strategy;
use arclight::model::ModelConfig;
use arclight::numa::topology::KUNPENG920_BW;
use arclight::numa::Topology;
use arclight::report::figures::{decode_tok_s, prefill_tok_s};
use arclight::report::table1::bandwidth_table;
use arclight::sched::SyncMode;

fn cfg() -> ModelConfig {
    // the paper's actual model: sim-only builds are cheap, and decode on
    // smaller geometries is overhead-dominated rather than
    // bandwidth-bound, which would invert the effects under test
    ModelConfig::qwen3_4b()
}

#[test]
fn table1_reproduces_within_two_percent() {
    let topo = Topology::kunpeng920();
    let t = bandwidth_table(&topo, topo.cores_per_node, 1.0);
    for i in 0..4 {
        for j in 0..4 {
            let dev = (t[i][j] - KUNPENG920_BW[i][j]).abs() / KUNPENG920_BW[i][j];
            assert!(dev < 0.02, "({i},{j}) deviates {dev}");
        }
    }
}

#[test]
fn fig10_shape_scaling_and_arclight_edge() {
    let topo = Topology::kunpeng920();
    let c = cfg();
    let mut prev = 0.0;
    for threads in [6usize, 12, 24, 48] {
        let arc = decode_tok_s(&c, Strategy::arclight_single(), threads, &topo, 15, 64, 2);
        assert!(arc.tok_per_s > prev * 0.95, "scaling broke at {threads}");
        prev = arc.tok_per_s;
    }
    let arc = decode_tok_s(&c, Strategy::arclight_single(), 48, &topo, 15, 64, 2);
    let llama = decode_tok_s(&c, Strategy::llama_isolate(), 48, &topo, 15, 64, 2);
    assert!(arc.tok_per_s > llama.tok_per_s, "ArcLight must edge out llama.cpp");
    assert!(arc.tok_per_s < llama.tok_per_s * 1.35, "single-node edge should be modest");
}

#[test]
fn fig11_shape_tp_beats_llama_and_wall_exists() {
    let topo = Topology::kunpeng920();
    let c = cfg();
    for nodes in [2usize, 4] {
        let threads = 48 * nodes;
        let llama = decode_tok_s(&c, Strategy::llama_distribute(nodes), threads, &topo, 15, 64, 2);
        let tp_b = Strategy::arclight_tp(nodes, SyncMode::SyncB);
        let arc_b = decode_tok_s(&c, tp_b, threads, &topo, 15, 64, 2);
        assert!(
            arc_b.tok_per_s > llama.tok_per_s * 1.15,
            "N={nodes}: TP {} vs llama {}",
            arc_b.tok_per_s,
            llama.tok_per_s
        );
        // mechanism: ArcLight eliminates cross-node traffic
        assert!(arc_b.remote_fraction < 0.05, "TP remote fraction {}", arc_b.remote_fraction);
        assert!(llama.remote_fraction > 0.05, "llama remote fraction {}", llama.remote_fraction);
    }
    // the wall: llama.cpp at full 4-node threads does not beat its own
    // smaller configurations by much
    let llama_96 = decode_tok_s(&c, Strategy::llama_distribute(4), 96, &topo, 15, 64, 2);
    let llama_192 = decode_tok_s(&c, Strategy::llama_distribute(4), 192, &topo, 15, 64, 2);
    assert!(
        llama_192.tok_per_s < llama_96.tok_per_s * 1.15,
        "the cross-NUMA wall should cap llama.cpp scaling"
    );
}

#[test]
fn sync_b_gains_a_few_tokens_per_second() {
    let topo = Topology::kunpeng920();
    let c = cfg();
    let a = decode_tok_s(&c, Strategy::arclight_tp(4, SyncMode::SyncA), 192, &topo, 15, 64, 2);
    let b = decode_tok_s(&c, Strategy::arclight_tp(4, SyncMode::SyncB), 192, &topo, 15, 64, 2);
    let gain = b.tok_per_s - a.tok_per_s;
    assert!(gain > 0.0, "Sync B must win");
    assert!(gain < b.tok_per_s * 0.35, "Sync B's gain is an increment, not the headline");
}

#[test]
fn fig12_long_prompt_decode_slightly_slower() {
    let topo = Topology::kunpeng920();
    let c = cfg();
    let s = Strategy::arclight_tp(4, SyncMode::SyncB);
    let short = decode_tok_s(&c, s, 192, &topo, 15, 64, 2);
    let long = decode_tok_s(&c, s, 192, &topo, 300, 64, 2);
    assert!(long.tok_per_s < short.tok_per_s);
    assert!(long.tok_per_s > short.tok_per_s * 0.6);
}

#[test]
fn fig13_prefill_gain_less_pronounced() {
    let topo = Topology::kunpeng920();
    let c = cfg();
    let d_l = decode_tok_s(&c, Strategy::llama_distribute(4), 192, &topo, 300, 64, 2);
    let d_a = decode_tok_s(&c, Strategy::arclight_tp(4, SyncMode::SyncB), 192, &topo, 300, 64, 2);
    let p_l = prefill_tok_s(&c, Strategy::llama_distribute(4), 192, &topo, 300);
    let p_a = prefill_tok_s(&c, Strategy::arclight_tp(4, SyncMode::SyncB), 192, &topo, 300);
    assert!(p_a.tok_per_s >= p_l.tok_per_s * 0.98, "ArcLight should not lose prefill");
    assert!(
        p_a.tok_per_s / p_l.tok_per_s < d_a.tok_per_s / d_l.tok_per_s,
        "prefill gain must be smaller than decode gain"
    );
    // prefill is far higher throughput than decode (batch compute)
    assert!(p_a.tok_per_s > d_a.tok_per_s * 2.0);
}

#[test]
fn simulation_is_deterministic() {
    let topo = Topology::kunpeng920();
    let c = cfg();
    let a = decode_tok_s(&c, Strategy::arclight_tp(2, SyncMode::SyncB), 96, &topo, 15, 64, 3);
    let b = decode_tok_s(&c, Strategy::arclight_tp(2, SyncMode::SyncB), 96, &topo, 15, 64, 3);
    assert_eq!(a.tok_per_s, b.tok_per_s);
}
