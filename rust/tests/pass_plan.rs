//! PassPlan invariants: the compiled per-pass schedule must (a) agree
//! with the legacy per-operator walk on every count it replaced, for
//! arbitrary graphs (std-only property test, deterministic seeds), and
//! (b) survive thousands of mixed width-1 / Sync-A / Sync-B passes on
//! small pools without deadlocking or perturbing outputs — the barrier
//! topology under the single-dispatch model is exactly what a per-op
//! latch can no longer paper over.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use arclight::graph::{Graph, GraphBuilder, TensorMeta};
use arclight::memory::MemoryPool;
use arclight::numa::cost::Traffic;
use arclight::numa::{Placement, Topology};
use arclight::ops::kernel::{Kernel, OpCtx, TrafficEnv};
use arclight::ops::OpCost;
use arclight::sched::{
    ExecParams, Executor, PassPlan, RealExecutor, StepBarrier, SyncMode,
};
use arclight::tensor::{DType, TensorBundle, TensorId};
use arclight::threads::{Organization, ThreadPool};
use arclight::util::Rng;

// ---------------------------------------------------------------------------
// property: plan counts == legacy per-op walk, for arbitrary graphs
// ---------------------------------------------------------------------------

/// Random mix of width-1 matmul chains and 2-group TP regions, K kept
/// consistent with square weights.
fn random_graph(rng: &mut Rng, d: usize) -> Graph {
    let mut b = GraphBuilder::sim(vec![0, 1], Placement::Node(0));
    let x = b.leaf("x", DType::F32, vec![1, d], Placement::Node(0));
    let w = b.leaf("w", DType::F32, vec![d, d], Placement::Node(0));
    let w0 = b.leaf("w0", DType::F32, vec![d, d], Placement::Node(0));
    let w1 = b.leaf("w1", DType::F32, vec![d, d], Placement::Node(1));
    let mut cur = TensorBundle::one(x);
    for _ in 0..rng.range(1, 6) {
        if rng.below(2) == 0 {
            // serial segment
            for _ in 0..rng.range(1, 4) {
                cur = b.matmul(&cur, &TensorBundle::one(w));
            }
        } else {
            // TP region: scatter → 1..4 parallel matmuls → gather
            let parts = b.scatter(&cur);
            let mut p = parts;
            for _ in 0..rng.range(1, 4) {
                p = b.matmul(&p, &TensorBundle::new(vec![w0, w1]));
            }
            cur = b.gather(&p);
        }
    }
    b.finish().0
}

#[test]
fn prop_plan_counts_match_legacy_walk() {
    let mut rng = Rng::new(0x9A55);
    let topo = Topology::uniform(2, 2, 100.0, 25.0);
    let cores: Vec<_> = (0..4).map(|i| topo.core(i)).collect();
    let org = Organization::by_node(&cores);
    for case in 0..40 {
        let g = random_graph(&mut rng, 8);
        let params = ExecParams::dense(0, 1);
        for sync in [SyncMode::SyncA, SyncMode::SyncB] {
            let plan = PassPlan::compile(&g, &params, cores.len(), &org, sync);
            // one plan step per execution-list entry
            assert_eq!(plan.ops(), g.exec.len(), "case {case}: step count");
            // unit counts identical to asking every kernel directly, in
            // execution order (the surface executor_parity pins)
            let mut want = Vec::new();
            for entry in &g.exec {
                for id in entry.bundle.iter() {
                    want.push(g.kernel(id).units(g.meta(id), &params));
                }
            }
            assert_eq!(plan.unit_counts, want, "case {case}: unit counts");
            // part table is the flattened bundle table
            let widths: usize = g.exec.iter().map(|e| e.bundle.width()).sum();
            assert_eq!(plan.parts.len(), widths, "case {case}: parts");
            // barrier topology: width-1 steps and Sync-A steps end at
            // the global barrier; Sync-B regions are local inside and
            // global exactly at the region end
            for (si, step) in plan.steps.iter().enumerate() {
                if step.width == 1 || sync == SyncMode::SyncA {
                    assert_eq!(step.barrier, StepBarrier::Global, "case {case} step {si}");
                } else {
                    let ends = step.region_end;
                    let want = if ends { StepBarrier::Global } else { StepBarrier::Local };
                    assert_eq!(step.barrier, want, "case {case} step {si}");
                }
            }
            // the legacy walk dispatched at least as often — strictly
            // more whenever the graph has more than one entry
            let legacy = plan.legacy_dispatches();
            assert!(legacy >= 1);
            if g.exec.len() > 1 && sync == SyncMode::SyncA {
                assert!(legacy > 1, "case {case}: no reduction to prove");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// stress: thousands of mixed passes on small pools — no deadlock,
// stable outputs
// ---------------------------------------------------------------------------

type Built = (Arc<Graph>, Arc<MemoryPool>, TensorId, TensorId, Vec<TensorId>);

/// x[1,4] → matmul(w) → scatter(2) → 2×matmul chain → gather: a pass
/// mixing whole-pool steps, a TP region, and the Gather boundary.
fn mixed_tp_graph(pool: MemoryPool) -> Built {
    let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
    let x = b.leaf("x", DType::F32, vec![1, 4], Placement::Node(0));
    let w = b.leaf("w", DType::F32, vec![4, 4], Placement::Node(0));
    let w0 = b.leaf("w0", DType::F32, vec![4, 4], Placement::Node(0));
    let w1 = b.leaf("w1", DType::F32, vec![4, 4], Placement::Node(1));
    let h = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
    let hs = b.scatter(&h);
    let mut p = b.matmul(&hs, &TensorBundle::new(vec![w0, w1]));
    p = b.matmul(&p, &TensorBundle::new(vec![w0, w1]));
    let z = b.gather(&p);
    let (g, pool) = b.finish();
    (Arc::new(g), Arc::new(pool.unwrap()), x, z.single(), vec![w, w0, w1])
}

fn fill(pool: &MemoryPool, graph: &Graph, id: TensorId, data: &[f32]) {
    let b = graph.buf(id);
    unsafe {
        pool.arena(b.arena).f32s_mut(b.off, data.len()).copy_from_slice(data);
    }
}

fn read4(pool: &MemoryPool, graph: &Graph, id: TensorId) -> Vec<f32> {
    let b = graph.buf(id);
    unsafe { pool.arena(b.arena).f32s(b.off, 4).to_vec() }
}

#[test]
fn stress_mixed_barrier_passes_do_not_deadlock() {
    // two executors (Sync A / Sync B) with their own small pools over
    // the SAME graph and memory; alternate them for thousands of
    // passes and require bit-stable outputs every time
    let topo = Topology::uniform(2, 2, 100.0, 25.0);
    let cores: Vec<_> = (0..4).map(|i| topo.core(i)).collect();
    let (graph, mem, x, z, ws) = mixed_tp_graph(MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20));
    fill(&mem, &graph, x, &[1.0, 2.0, 3.0, 4.0]);
    // identity weights keep the expected output analytic: z = 2 * x
    // (gather sums two identical partial streams)
    let ident = [
        1.0, 0.0, 0.0, 0.0, //
        0.0, 1.0, 0.0, 0.0, //
        0.0, 0.0, 1.0, 0.0, //
        0.0, 0.0, 0.0, 1.0,
    ];
    for &wid in &ws {
        fill(&mem, &graph, wid, &ident);
    }
    let mk = |sync: SyncMode, cs: Vec<arclight::numa::Core>| {
        RealExecutor::new(
            mem.clone(),
            Arc::new(ThreadPool::new(cs.clone())),
            Arc::new(Organization::single(&cs)),
            Arc::new(Organization::by_node(&cs)),
            sync,
        )
    };
    // 4-worker (2 groups of 2) and 2-worker (2 groups of 1 — every
    // worker is its own group) pools; cores 0/1 are node 0, 2/3 node 1
    let tiny = vec![cores[0], cores[2]];
    let executors = [
        mk(SyncMode::SyncA, cores.clone()),
        mk(SyncMode::SyncB, cores.clone()),
        mk(SyncMode::SyncA, tiny.clone()),
        mk(SyncMode::SyncB, tiny),
    ];
    let want = vec![2.0, 4.0, 6.0, 8.0];
    let params = ExecParams::dense(0, 1);
    for pass in 0..3000usize {
        let ex = &executors[pass % executors.len()];
        let rep = ex.run(&graph, &params);
        assert_eq!(rep.dispatches, 1, "pass {pass}");
        assert_eq!(read4(&mem, &graph, z), want, "pass {pass} output drifted");
    }
    for ex in &executors {
        assert_eq!(ex.threads.dispatches(), 3000 / executors.len());
    }
}

// ---------------------------------------------------------------------------
// panic discipline: a panicking kernel must not strand peers at a
// barrier — the walk defers the panic past the barrier schedule
// ---------------------------------------------------------------------------

/// A kernel that always panics when run (its accounting facets are
/// inert) — stands in for a kernel bug mid-pass.
struct BoomKernel;

impl Kernel for BoomKernel {
    fn name(&self) -> &'static str {
        "boom"
    }

    fn units(&self, _meta: &TensorMeta, _params: &ExecParams) -> usize {
        2
    }

    fn cost(
        &self,
        _graph: &Graph,
        _id: TensorId,
        _params: &ExecParams,
        _u0: usize,
        _u1: usize,
    ) -> OpCost {
        OpCost::default()
    }

    fn traffic(
        &self,
        _graph: &Graph,
        _id: TensorId,
        _params: &ExecParams,
        _u0: usize,
        _u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        Traffic::new(env.n_nodes)
    }

    unsafe fn run(&self, _ctx: &OpCtx<'_>, _u0: usize, _u1: usize) {
        panic!("boom kernel");
    }
}

static BOOM: BoomKernel = BoomKernel;

#[test]
fn panicking_kernel_mid_pass_surfaces_without_stranding_peers() {
    // Poison ONE group's matmul inside the TP region: group 0's workers
    // panic mid-plan while group 1 and the width-1 steps continue to
    // the global barriers. Without the deferred-panic walk, group 1
    // (and the leader) would spin forever; with it, the pass completes,
    // the latch poisons and run_pass re-raises.
    let topo = Topology::uniform(2, 2, 100.0, 25.0);
    let cores: Vec<_> = (0..4).map(|i| topo.core(i)).collect();
    let (graph, mem, x, _z, ws) = mixed_tp_graph(MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20));
    fill(&mem, &graph, x, &[1.0; 4]);
    for &wid in &ws {
        fill(&mem, &graph, wid, &[0.0; 16]);
    }
    let org_tp = Arc::new(Organization::by_node(&cores));
    let params = ExecParams::dense(0, 1);
    let mut plan = PassPlan::compile(&graph, &params, cores.len(), &org_tp, SyncMode::SyncB);
    let victim = plan
        .steps
        .iter()
        .find(|s| s.width == 2 && !s.region_end)
        .expect("TP region step")
        .part0;
    plan.parts[victim].kernel = &BOOM; // group 0's stream now panics
    let plan = Arc::new(plan);
    let pool = Arc::new(ThreadPool::new(cores.clone()));
    let surfaced = catch_unwind(AssertUnwindSafe(|| {
        let (graph, mem, org, params, global) =
            (graph.clone(), mem.clone(), org_tp.clone(), params.clone(), pool.global_barrier());
        let plan = plan.clone();
        let n = cores.len();
        pool.run_pass(Arc::new(move |ctx: &arclight::threads::WorkerCtx| {
            plan.run_worker(&graph, &mem, &params, &org, n, ctx.worker, &global);
        }));
    }));
    assert!(surfaced.is_err(), "leader must re-raise the kernel panic");
    // every worker finished the pass — the pool is still serviceable
    let hits = Arc::new(std::sync::Mutex::new(0usize));
    let h2 = hits.clone();
    pool.run_pass(Arc::new(move |_: &arclight::threads::WorkerCtx| {
        *h2.lock().unwrap() += 1;
    }));
    assert_eq!(*hits.lock().unwrap(), 4);
}
