//! `hw::topology` fixture tests: the sysfs parser against synthetic
//! node trees for three machines (a 1-node laptop, a 2-node Xeon with
//! hyperthread-split cpulists, a 4-node Kunpeng-920 with offline
//! cpus), pinning the exact lowered `Topology` (nodes, cores per
//! node, distance-derived bandwidth ratios), plus the no-sysfs
//! fallback. Runs in the default feature set — the parser itself is
//! std-only and always compiled.

use std::fs;
use std::path::PathBuf;

use arclight::hw::topology::DEFAULT_LOCAL_GB;
use arclight::hw::{HostTopology, Platform};
use arclight::numa::Core;

/// A throwaway sysfs-node-style tree under the system temp dir.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(name: &str) -> Fixture {
        let root = std::env::temp_dir()
            .join(format!("arclight-hw-fixture-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).unwrap();
        Fixture { root }
    }

    fn node(&self, id: usize, cpulist: &str, mem_kb: u64, distance: &str) {
        let dir = self.root.join(format!("node{id}"));
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("cpulist"), format!("{cpulist}\n")).unwrap();
        fs::write(dir.join("distance"), format!("{distance}\n")).unwrap();
        fs::write(
            dir.join("meminfo"),
            format!("Node {id} MemFree:        1024 kB\nNode {id} MemTotal:  {mem_kb} kB\n"),
        )
        .unwrap();
    }

    fn parse(&self) -> HostTopology {
        HostTopology::from_root(&self.root).expect("fixture must parse")
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

#[test]
fn one_node_laptop() {
    let f = Fixture::new("laptop");
    f.node(0, "0-7", 16 * 1024 * 1024, "10");
    let h = f.parse();
    assert_eq!(h.n_nodes(), 1);
    assert_eq!(h.total_cpus(), 8);
    assert_eq!(h.nodes[0].cpus, (0..8).collect::<Vec<_>>());
    assert_eq!(h.nodes[0].mem_total_kb, 16 * 1024 * 1024);
    assert_eq!(h.distance, vec![vec![10]]);

    let t = h.to_topology();
    assert_eq!(t.n_nodes(), 1);
    assert_eq!(t.cores_per_node, 8);
    assert_eq!(t.n_cores(), 8);
    assert_eq!(t.bandwidth(0, 0), DEFAULT_LOCAL_GB * 1e9);
    assert_eq!(h.os_cpu(Core { id: 3, node: 0 }), Some(3));
    assert_eq!(h.os_cpu(Core { id: 8, node: 0 }), None, "past the last cpu");
}

#[test]
fn two_node_xeon_with_hyperthread_split_cpulists() {
    // a 2-socket Xeon enumerates hyperthread siblings in a second
    // block, so each node's cpulist is non-contiguous
    let f = Fixture::new("xeon");
    f.node(0, "0-11,24-35", 96 * 1024 * 1024, "10 21");
    f.node(1, "12-23,36-47", 96 * 1024 * 1024, "21 10");
    let h = f.parse();
    assert_eq!(h.n_nodes(), 2);
    assert_eq!(h.total_cpus(), 48);
    assert_eq!(h.cores_per_node(), 24);

    let t = h.to_topology();
    assert_eq!((t.n_nodes(), t.cores_per_node, t.n_cores()), (2, 24, 48));
    // bandwidth ratios come straight from the SLIT row: local/remote
    // = 21/10
    assert_eq!(t.bandwidth(0, 0), DEFAULT_LOCAL_GB * 1e9);
    assert_eq!(t.bandwidth(1, 1), DEFAULT_LOCAL_GB * 1e9);
    let ratio = t.bandwidth(0, 0) / t.bandwidth(0, 1);
    assert!((ratio - 2.1).abs() < 1e-9, "local/remote ratio {ratio}");

    // core→cpu map follows the split enumeration: node0 core 11 → cpu
    // 11 but core 12 → cpu 24; node1's first core → cpu 12
    assert_eq!(h.os_cpu(Core { id: 11, node: 0 }), Some(11));
    assert_eq!(h.os_cpu(Core { id: 12, node: 0 }), Some(24));
    assert_eq!(h.os_cpu(Core { id: 24, node: 1 }), Some(12));
    assert_eq!(h.os_cpu(Core { id: 47, node: 1 }), Some(47));
    // and the whole bind_cores surface works against the lowering
    let cores = t.bind_cores(8, true, 2);
    let map = h.cpu_map(&cores).expect("every bound core has a backing cpu");
    assert_eq!(map.len(), 8);
    assert_eq!(map[0], 0);
    assert!(map.iter().filter(|&&c| (12..24).contains(&c)).count() == 4, "{map:?}");
}

#[test]
fn four_node_kunpeng_with_offline_cpus() {
    // node2 has cpus 126-127 offline, so nodes are unequal and the
    // lowered model clamps to the minimum (46 cores/node)
    let f = Fixture::new("kunpeng");
    let mem = 128 * 1024 * 1024;
    f.node(0, "0-47", mem, "10 12 20 22");
    f.node(1, "48-95", mem, "12 10 22 24");
    f.node(2, "96-125,128-143", mem, "20 22 10 12");
    f.node(3, "144-191", mem, "22 24 12 10");
    let h = f.parse();
    assert_eq!(h.n_nodes(), 4);
    assert_eq!(h.total_cpus(), 190);
    assert_eq!(h.nodes[2].cpus.len(), 46);
    assert_eq!(h.cores_per_node(), 46);

    let t = h.to_topology();
    assert_eq!((t.n_nodes(), t.cores_per_node, t.n_cores()), (4, 46, 184));
    // distance-derived ratios: near-remote 10/12, far-remote 10/20 and
    // 10/22 off node 0
    assert_eq!(t.bandwidth(0, 0), DEFAULT_LOCAL_GB * 1e9);
    assert!((t.bandwidth(0, 1) - DEFAULT_LOCAL_GB * 1e9 * 10.0 / 12.0).abs() < 1.0);
    assert!((t.bandwidth(0, 2) - DEFAULT_LOCAL_GB * 1e9 * 10.0 / 20.0).abs() < 1.0);
    assert!((t.bandwidth(0, 3) - DEFAULT_LOCAL_GB * 1e9 * 10.0 / 22.0).abs() < 1.0);
    // the local ≈ 2x far-remote structure survives into the model
    assert!(t.bandwidth(0, 0) / t.bandwidth(0, 2) >= 2.0);

    // node2's map skips the offline pair: its 30th core is cpu 125,
    // its 31st jumps to 128
    let base2 = 2 * t.cores_per_node;
    assert_eq!(h.os_cpu(Core { id: base2 + 29, node: 2 }), Some(125));
    assert_eq!(h.os_cpu(Core { id: base2 + 30, node: 2 }), Some(128));
}

#[test]
fn fallback_when_sysfs_is_absent() {
    assert!(HostTopology::from_root(&PathBuf::from("/nonexistent/sysfs/node")).is_none());
    // an existing dir without node entries is also not a NUMA tree
    let f = Fixture::new("empty");
    assert!(HostTopology::from_root(&f.root).is_none());
    // and Platform::detect degrades to the simulated testbed whenever
    // the host layer is unavailable (always true in feature-off CI)
    if !arclight::hw::affinity::available() {
        assert_eq!(Platform::detect().name(), "simulated");
    }
}

#[test]
fn malformed_trees_are_rejected_not_misparsed() {
    // non-contiguous node ids
    let f = Fixture::new("holes");
    f.node(0, "0-3", 1024, "10 21");
    f.node(2, "4-7", 1024, "21 10");
    assert!(HostTopology::from_root(&f.root).is_none());

    // distance row shorter than the node count
    let g = Fixture::new("shortrow");
    g.node(0, "0-3", 1024, "10");
    g.node(1, "4-7", 1024, "10 21");
    assert!(HostTopology::from_root(&g.root).is_none());

    // a cpu-less node
    let e = Fixture::new("nocpus");
    e.node(0, "0-3", 1024, "10 21");
    e.node(1, "", 1024, "21 10");
    assert!(HostTopology::from_root(&e.root).is_none());
}

#[test]
fn platform_from_fixture_behaves_like_a_host() {
    let f = Fixture::new("platform");
    f.node(0, "0-3", 1024, "10 20");
    f.node(1, "4-7", 1024, "20 10");
    let p = Platform::from_host(f.parse());
    assert_eq!(p.name(), "host");
    assert!(p.is_host());
    assert!(p.supports_threads(8));
    assert!(!p.supports_threads(9));
    let cores: Vec<Core> = (0..8).map(|i| p.topology().core(i)).collect();
    assert_eq!(p.cpu_map(&cores), Some((0..8).collect()));
    // installing the first-touch map succeeds (one cpu per node) and
    // is undone so other tests see pristine global state
    assert!(p.install_membind());
    assert!(arclight::hw::membind::first_touch_installed());
    arclight::hw::membind::clear_first_touch();
}
