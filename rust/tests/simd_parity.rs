//! Scalar-vs-SIMD parity for every vectorized kernel (the tier
//! contract from `rust/KERNELS.md`).
//!
//! The scalar implementations are the oracles: each dispatcher in
//! `arclight::simd` is driven with an explicit tier argument (no
//! process-wide state is touched), over odd lengths and block-tail
//! cases, against either an f64 reference or the scalar kernel.
//!
//! Tolerance policy: per-element kernels (`scale_gain`,
//! `scale_inplace`, `axpy_rescale`, `max_f32` — and therefore the
//! whole of `softmax_rows_t`) must be **bit-exact** across tiers.
//! Reductions (`dot_f32`, the quantized dots, `sum_squares`)
//! reassociate, so they get an accumulated-rounding bound of
//! `(2n + 64)·ε_f32 · Σ|terms| + 1e-6` — a standard worst-case
//! summation-error envelope with slack for FMA-vs-mul+add differences.

use arclight::ops::{attention, gemm, norm, softmax};
use arclight::quant::{
    block_sums_q4_0, dequantize_row_q4_0, dequantize_row_q8_0, quantize_matrix_q4_0,
    quantize_row_q4_0, quantize_row_q8_0,
};
use arclight::simd::{self, KernelTier};
use arclight::util::Rng;

fn rand_vec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut r = Rng::new(seed);
    let mut v = vec![0.0; n];
    r.fill_normal(&mut v, scale);
    v
}

/// Accumulated-rounding envelope for an n-term f32 reduction whose
/// terms have total magnitude `abs_terms`.
fn red_tol(n_terms: usize, abs_terms: f64) -> f64 {
    (2.0 * n_terms as f64 + 64.0) * f32::EPSILON as f64 * abs_terms + 1e-6
}

#[test]
fn dot_f32_matches_f64_reference_across_tiers() {
    let lens = [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33, 63, 64, 65, 127, 130, 1023];
    for seed in 0..3u64 {
        for &n in &lens {
            let a = rand_vec(n, 100 + seed * 2, 1.0);
            let b = rand_vec(n, 101 + seed * 2, 1.0);
            let reference: f64 =
                a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let abs_terms: f64 =
                a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            let tol = red_tol(n, abs_terms);
            for tier in KernelTier::supported_tiers() {
                let got = simd::dot_f32(tier, &a, &b) as f64;
                assert!(
                    (got - reference).abs() <= tol,
                    "dot_f32 n={n} seed={seed} tier={tier}: {got} vs {reference} (tol {tol})"
                );
            }
        }
    }
}

#[test]
fn q4_0_presum_dot_parity_across_block_counts() {
    // k must be a multiple of the 32-element block; the interesting
    // tails are therefore odd block counts (1, 3, 5, 10 blocks)
    for &k in &[32usize, 96, 160, 320, 512] {
        for seed in 0..3u64 {
            let w = rand_vec(k, 200 + seed, 0.5);
            let x = rand_vec(k, 300 + seed, 1.0);
            let mut raw = Vec::new();
            quantize_row_q4_0(&w, &mut raw);
            let mut wd = vec![0.0f32; k];
            dequantize_row_q4_0(&raw, &mut wd);
            let reference: f64 =
                wd.iter().zip(&x).map(|(&a, &b)| a as f64 * b as f64).sum();
            // intermediate terms before the -8·Σx debias are up to
            // (15 + 8)·|x|·d per element — bound the envelope on those
            let mut abs_terms = 0.0f64;
            for (bi, xb) in x.chunks_exact(32).enumerate() {
                let d = f16(&raw[bi * 18..]).abs() as f64;
                abs_terms += d * 23.0 * xb.iter().map(|v| v.abs() as f64).sum::<f64>();
            }
            let tol = red_tol(k, abs_terms);
            let mut xsums = Vec::new();
            block_sums_q4_0(&x, &mut xsums);
            for tier in KernelTier::supported_tiers() {
                let got = simd::dot_q4_0_presum(tier, &raw, &x, &xsums) as f64;
                assert!(
                    (got - reference).abs() <= tol,
                    "q4_0 dot k={k} seed={seed} tier={tier}: {got} vs {reference} (tol {tol})"
                );
            }
        }
    }
}

#[test]
fn q8_0_dot_parity_across_block_counts() {
    for &k in &[32usize, 64, 96, 320] {
        for seed in 0..3u64 {
            let w = rand_vec(k, 400 + seed, 1.0);
            let x = rand_vec(k, 500 + seed, 1.0);
            let mut raw = Vec::new();
            quantize_row_q8_0(&w, &mut raw);
            let mut wd = vec![0.0f32; k];
            dequantize_row_q8_0(&raw, &mut wd);
            let reference: f64 =
                wd.iter().zip(&x).map(|(&a, &b)| a as f64 * b as f64).sum();
            let abs_terms: f64 =
                wd.iter().zip(&x).map(|(&a, &b)| (a as f64 * b as f64).abs()).sum();
            let tol = red_tol(k, abs_terms);
            for tier in KernelTier::supported_tiers() {
                let got = simd::dot_q8_0(tier, &raw, &x) as f64;
                assert!(
                    (got - reference).abs() <= tol,
                    "q8_0 dot k={k} seed={seed} tier={tier}: {got} vs {reference} (tol {tol})"
                );
            }
        }
    }
}

#[test]
fn rmsnorm_parity_odd_lengths() {
    // only the Σx² reduction reassociates; the apply step is
    // per-element, so the output error is the inv-rms relative error
    for &d in &[1usize, 3, 31, 32, 33, 100, 257, 1000] {
        let rows = 2usize;
        let x = rand_vec(rows * d, 600 + d as u64, 1.0);
        let g = rand_vec(d, 601, 0.5);
        let mut want = vec![0.0f32; rows * d];
        norm::rmsnorm_t(KernelTier::Scalar, &x, &g, &mut want, d, 1e-6, 0, rows);
        let rel = 4.0 * d as f64 * f32::EPSILON as f64;
        for tier in KernelTier::supported_tiers() {
            let mut got = vec![0.0f32; rows * d];
            norm::rmsnorm_t(tier, &x, &g, &mut got, d, 1e-6, 0, rows);
            for i in 0..rows * d {
                let (a, b) = (got[i] as f64, want[i] as f64);
                assert!(
                    (a - b).abs() <= rel * b.abs() + 1e-7,
                    "rmsnorm d={d} tier={tier} elem {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn softmax_rows_bit_exact_across_tiers() {
    // max is exact and the normalize is per-element, so the whole
    // kernel must be bit-identical on every tier — including the
    // zeroed tail beyond `valid` and the empty-row edge case
    for &(n, valid) in &[(8usize, 0usize), (8, 8), (17, 9), (33, 1), (64, 64), (130, 97)] {
        let rows = 3usize;
        let base = rand_vec(rows * n, 700 + n as u64, 2.0);
        let mut want = base.clone();
        softmax::softmax_rows_t(KernelTier::Scalar, &mut want, n, valid, 0, rows);
        for tier in KernelTier::supported_tiers() {
            let mut got = base.clone();
            softmax::softmax_rows_t(tier, &mut got, n, valid, 0, rows);
            for i in 0..rows * n {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "softmax n={n} valid={valid} tier={tier} elem {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }
}

#[test]
fn attention_parity_across_tiers() {
    // score dots reassociate; exp/probs amplify that only linearly, so
    // a loose relative bound holds with wide margin — including GQA
    // head sharing and an odd head_dim
    for &(heads, kvh, hd, max_seq, p0) in
        &[(4usize, 2usize, 8usize, 32usize, 17usize), (8, 8, 16, 64, 63), (3, 1, 5, 16, 7)]
    {
        let q = rand_vec(heads * hd, 800 + heads as u64, 1.0);
        let kc = rand_vec(kvh * max_seq * hd, 801, 1.0);
        let vc = rand_vec(kvh * max_seq * hd, 802, 1.0);
        let mut want = vec![0.0f32; heads * hd];
        attention::attention_t(
            KernelTier::Scalar,
            &q,
            &kc,
            &vc,
            &mut want,
            1,
            heads,
            kvh,
            hd,
            max_seq,
            p0,
            0,
            heads,
        );
        for tier in KernelTier::supported_tiers() {
            let mut got = vec![0.0f32; heads * hd];
            attention::attention_t(
                tier, &q, &kc, &vc, &mut got, 1, heads, kvh, hd, max_seq, p0, 0, heads,
            );
            for i in 0..heads * hd {
                let (a, b) = (got[i] as f64, want[i] as f64);
                assert!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "attention H={heads} kv={kvh} hd={hd} tier={tier} elem {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn gemm_q4_0_stripes_compose_bit_exactly_per_tier() {
    // row stripes [n0, n1) partition independent output rows, so
    // striped and whole-range runs must agree bitwise on any one tier
    // (this is what makes tier choice orthogonal to unit partitioning)
    let (m, k, n) = (3usize, 96usize, 17usize);
    let w = rand_vec(n * k, 900, 0.5);
    let wq = quantize_matrix_q4_0(&w, n, k);
    let x = rand_vec(m * k, 901, 1.0);
    for tier in KernelTier::supported_tiers() {
        let mut whole = vec![0.0f32; m * n];
        gemm::gemm_q4_0_t(tier, &x, &wq, &mut whole, m, k, n, 0, n);
        let mut striped = vec![0.0f32; m * n];
        for (n0, n1) in [(0usize, 5usize), (5, 6), (6, 17)] {
            gemm::gemm_q4_0_t(tier, &x, &wq, &mut striped, m, k, n, n0, n1);
        }
        for i in 0..m * n {
            assert_eq!(
                whole[i].to_bits(),
                striped[i].to_bits(),
                "tier={tier} elem {i}: {} vs {}",
                whole[i],
                striped[i]
            );
        }
    }
}

/// LE f16 at the head of a block.
fn f16(raw: &[u8]) -> f32 {
    arclight::util::f16_to_f32(u16::from_le_bytes([raw[0], raw[1]]))
}
