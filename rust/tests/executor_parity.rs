//! Executor parity: the real and simulated backends driven through the
//! unified `sched::Executor` trait must partition work identically
//! (same op count, same unit counts in the same order), and batched
//! decode routed through the trait stays token-identical to serial
//! decode (PR 2's determinism guarantee, re-pinned on the new API).

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions, Sampler};
use arclight::hw::Platform;
use arclight::model::{ModelConfig, ModelGraphs};
use arclight::numa::Topology;
use arclight::sched::{ExecParams, Executor, SyncMode};

/// Run one dense pass through both backends as `&dyn Executor` and
/// compare their per-op partition surface.
fn unit_parity(strategy: Strategy, threads: usize) {
    let topo = Topology::uniform(4, 4, 100.0, 25.0);
    let m = ModelGraphs::build(strategy.build_spec(ModelConfig::tiny(), topo.n_nodes()));
    let pool = m.pool.clone().expect("real build has buffers");
    let real = strategy.real_executor(pool, &Platform::Simulated(topo.clone()), threads, false);
    let sim = strategy.sim_executor(&topo, threads);
    let backends: [&dyn Executor; 2] = [&real, &sim];
    assert_eq!(backends[0].name(), "real");
    assert_eq!(backends[1].name(), "sim");
    for params in [ExecParams::dense(0, 1), ExecParams::dense(3, 1)] {
        let reps: Vec<_> = backends.iter().map(|e| e.run(&m.decode, &params)).collect();
        let name = strategy.name();
        assert_eq!(reps[0].ops, reps[1].ops, "{name}: op count diverged");
        assert_eq!(reps[0].ops, m.decode.exec.len(), "{name}: entries skipped");
        assert_eq!(reps[0].unit_counts, reps[1].unit_counts, "{name}: unit counts diverged");
        assert!(reps[0].unit_counts.iter().all(|&u| u > 0), "{name}: zero-unit op");
        assert!(reps[0].sim.is_none(), "{name}: real backend carries sim detail");
        assert!(reps[1].sim.is_some(), "{name}: sim backend lost its detail");
        assert!(reps[1].elapsed > 0.0);
        // both backends consume one compiled PassPlan per pass
        assert_eq!(reps[0].dispatches, 1, "{name}: real pass was not a single dispatch");
        assert_eq!(reps[1].dispatches, 1, "{name}: sim dispatch accounting diverged");
    }
}

#[test]
fn single_node_unit_parity() {
    unit_parity(Strategy::arclight_single(), 2);
}

#[test]
fn tp2_unit_parity_both_sync_modes() {
    unit_parity(Strategy::arclight_tp(2, SyncMode::SyncA), 4);
    unit_parity(Strategy::arclight_tp(2, SyncMode::SyncB), 4);
}

#[test]
fn llama_strategy_unit_parity() {
    unit_parity(Strategy::llama_isolate(), 2);
}

#[test]
fn batched_decode_token_identical_to_serial_through_trait() {
    // Engine routes every pass through its Box<dyn Executor>; the
    // continuous-batching lane must still reproduce serial decode
    // token for token.
    let opts = |slots: usize| EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 2,
        platform: Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0)),
        prefill_rows: None,
        seed: 11,
        batch_slots: slots,
        pin: false,
    };
    let mut serial = Engine::new_synthetic(ModelConfig::tiny(), &opts(1)).unwrap();
    let prompt = [5i32, 9, 2, 7];
    let want = serial.generate(&prompt, 6, &Sampler::greedy());

    let mut batched = Engine::new_synthetic(ModelConfig::tiny(), &opts(2)).unwrap();
    let seq = batched.seq_alloc().unwrap();
    let mut logits = Vec::new();
    for &t in &prompt {
        logits = batched.step_batch(&[(seq, t)]).remove(0);
    }
    let greedy = Sampler::greedy();
    let mut toks = Vec::new();
    for step in 0..6 {
        let next = greedy.sample(&logits, step);
        toks.push(next);
        if step + 1 < 6 {
            logits = batched.step_batch(&[(seq, next)]).remove(0);
        }
    }
    batched.seq_free(seq);
    assert_eq!(toks, want.tokens, "batched lane diverged from serial decode");
}
