//! Executor parity: the real and simulated backends driven through the
//! unified `sched::Executor` trait must partition work identically
//! (same op count, same unit counts in the same order), and batched
//! decode routed through the trait stays token-identical to serial
//! decode (PR 2's determinism guarantee, re-pinned on the new API).
//! The forced-tier matrix re-runs a decode step on every SIMD tier the
//! host supports and pins tier choice as orthogonal to scheduling:
//! same unit counts, near-identical logits, and a `StepReport` that
//! names the tier it ran on.

use std::sync::Mutex;

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions, Sampler};
use arclight::hw::Platform;
use arclight::model::{ModelConfig, ModelGraphs};
use arclight::numa::Topology;
use arclight::sched::{ExecParams, Executor, SyncMode};
use arclight::simd::KernelTier;

/// The active SIMD tier is process-wide; tests that force it (or that
/// compare numeric outputs across two engine runs) serialize behind
/// this lock so a concurrent tier flip can't skew the comparison.
static TIER_LOCK: Mutex<()> = Mutex::new(());

/// Run one dense pass through both backends as `&dyn Executor` and
/// compare their per-op partition surface.
fn unit_parity(strategy: Strategy, threads: usize) {
    let topo = Topology::uniform(4, 4, 100.0, 25.0);
    let m = ModelGraphs::build(strategy.build_spec(ModelConfig::tiny(), topo.n_nodes()));
    let pool = m.pool.clone().expect("real build has buffers");
    let real = strategy.real_executor(pool, &Platform::Simulated(topo.clone()), threads, false);
    let sim = strategy.sim_executor(&topo, threads);
    let backends: [&dyn Executor; 2] = [&real, &sim];
    assert_eq!(backends[0].name(), "real");
    assert_eq!(backends[1].name(), "sim");
    for params in [ExecParams::dense(0, 1), ExecParams::dense(3, 1)] {
        let reps: Vec<_> = backends.iter().map(|e| e.run(&m.decode, &params)).collect();
        let name = strategy.name();
        assert_eq!(reps[0].ops, reps[1].ops, "{name}: op count diverged");
        assert_eq!(reps[0].ops, m.decode.exec.len(), "{name}: entries skipped");
        assert_eq!(reps[0].unit_counts, reps[1].unit_counts, "{name}: unit counts diverged");
        assert!(reps[0].unit_counts.iter().all(|&u| u > 0), "{name}: zero-unit op");
        assert!(reps[0].sim.is_none(), "{name}: real backend carries sim detail");
        assert!(reps[1].sim.is_some(), "{name}: sim backend lost its detail");
        assert!(reps[1].elapsed > 0.0);
        // both backends consume one compiled PassPlan per pass
        assert_eq!(reps[0].dispatches, 1, "{name}: real pass was not a single dispatch");
        assert_eq!(reps[1].dispatches, 1, "{name}: sim dispatch accounting diverged");
    }
}

#[test]
fn single_node_unit_parity() {
    unit_parity(Strategy::arclight_single(), 2);
}

#[test]
fn tp2_unit_parity_both_sync_modes() {
    unit_parity(Strategy::arclight_tp(2, SyncMode::SyncA), 4);
    unit_parity(Strategy::arclight_tp(2, SyncMode::SyncB), 4);
}

#[test]
fn llama_strategy_unit_parity() {
    unit_parity(Strategy::llama_isolate(), 2);
}

#[test]
fn batched_decode_token_identical_to_serial_through_trait() {
    // Engine routes every pass through its Box<dyn Executor>; the
    // continuous-batching lane must still reproduce serial decode
    // token for token. (Holds across tiers too — the attention and
    // per-element kernels are bit-exact by construction — but the two
    // engines here must run on the SAME tier, hence the lock.)
    let _guard = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let opts = |slots: usize| EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 2,
        platform: Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0)),
        prefill_rows: None,
        seed: 11,
        batch_slots: slots,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node: 0,
    };
    let mut serial = Engine::new_synthetic(ModelConfig::tiny(), &opts(1)).unwrap();
    let prompt = [5i32, 9, 2, 7];
    let want = serial.generate(&prompt, 6, &Sampler::greedy());

    let mut batched = Engine::new_synthetic(ModelConfig::tiny(), &opts(2)).unwrap();
    let seq = batched.seq_start(prompt.len() + 6).unwrap();
    let mut logits = Vec::new();
    for &t in &prompt {
        logits = batched.step_batch(&[(&seq, t)]).remove(0);
    }
    let greedy = Sampler::greedy();
    let mut toks = Vec::new();
    for step in 0..6 {
        let next = greedy.sample(&logits, step);
        toks.push(next);
        if step + 1 < 6 {
            logits = batched.step_batch(&[(&seq, next)]).remove(0);
        }
    }
    drop(seq);
    assert_eq!(toks, want.tokens, "batched lane diverged from serial decode");
}

#[test]
fn forced_tier_matrix_units_and_logits_invariant() {
    // Tier choice must be orthogonal to scheduling: forcing each
    // supported tier in turn, one decode step after a short prefill
    // must report the forced tier, partition into exactly the same
    // units, and produce logits within the reduction tolerance of the
    // scalar baseline (scalar runs first in supported_tiers()).
    let _guard = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = KernelTier::active();
    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 2,
        platform: Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0)),
        prefill_rows: None,
        seed: 11,
        batch_slots: 1,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node: 0,
    };
    let mut baseline: Option<(Vec<usize>, Vec<f32>)> = None;
    for tier in KernelTier::supported_tiers() {
        KernelTier::set_active(tier).unwrap();
        let mut engine = Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap();
        engine.prefill(&[3, 1, 4, 1]);
        let logits = engine.decode_step(5);
        let rep = engine.last_step_report().expect("decode produced a report").clone();
        assert_eq!(rep.tier, tier, "StepReport must carry the forced tier");
        match &baseline {
            None => baseline = Some((rep.unit_counts, logits)),
            Some((units, want)) => {
                assert_eq!(&rep.unit_counts, units, "{tier}: unit partitioning changed with tier");
                assert_eq!(logits.len(), want.len());
                for (i, (&a, &b)) in logits.iter().zip(want).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                        "{tier}: logit {i} diverged from scalar ({a} vs {b})"
                    );
                }
            }
        }
    }
    KernelTier::set_active(prev).unwrap();
}
