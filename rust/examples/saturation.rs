//! Open-loop saturation bench for cluster serving.
//!
//! Generates a deterministic Poisson-ish arrival schedule (seeded
//! exponential inter-arrivals) and replays the *same* schedule against
//! one and then two [`Cluster`] replicas on the simulated two-node
//! testbed, sweeping the offered load. Open loop means arrivals do not
//! wait for completions — at rates past the engine's capacity the
//! queue grows and latency shows it, which is exactly the regime the
//! placement router exists for.
//!
//! The one-replica baseline is a single node-group engine — the unit
//! the cluster scales by — so the sweep isolates replica scaling from
//! engine tuning: both phases use identical per-replica geometry
//! ([`THREADS_PER_REPLICA`] workers, [`BATCH_PER_REPLICA`] lanes).
//!
//! Per (replicas, rate) point it reports p50/p99 TTFT, p50/p99 e2e
//! latency, aggregate tokens/s and tokens/s per node, and asserts the
//! headline claim: at the saturating rate, two replicas deliver
//! strictly more aggregate tokens/s than one.
//!
//!     cargo run --release --example saturation -- --quick --report out.json
//!
//! Flags: `--quick` (CI-sized run), `--report <path>` (JSON report for
//! the perf-trajectory artifact), `--trace <path>` (turn the runtime
//! tracer on and export a Chrome trace of the decode passes; the
//! report then folds in the cluster's `barrier_skew` and `drift`
//! blocks from the metrics snapshot).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions};
use arclight::hw::Platform;
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::server::{BatcherConfig, Cluster, ClusterConfig, GenRequest};
use arclight::util::json::{obj, Json};
use arclight::util::stats::Summary;
use arclight::util::Rng;

/// Per-replica engine geometry, identical in both phases.
const THREADS_PER_REPLICA: usize = 2;
const BATCH_PER_REPLICA: usize = 4;
const MAX_NEW: usize = 8;

fn build_replica(base_node: usize) -> anyhow::Result<Engine> {
    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: THREADS_PER_REPLICA,
        platform: Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0)),
        prefill_rows: None,
        seed: 7,
        batch_slots: BATCH_PER_REPLICA,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node,
    };
    Ok(Engine::new_synthetic(ModelConfig::tiny(), &opts)?)
}

/// One (replica count, offered rate) measurement.
struct Sweep {
    replicas: usize,
    nodes: usize,
    offered_rps: f64,
    completed: usize,
    decoded: usize,
    wall_s: f64,
    ttft: Summary,
    latency: Summary,
}

impl Sweep {
    fn tokens_per_s(&self) -> f64 {
        self.decoded as f64 / self.wall_s
    }

    fn to_json(&mut self) -> Json {
        let tok_s = self.tokens_per_s();
        obj(vec![
            ("replicas", self.replicas.into()),
            ("nodes", self.nodes.into()),
            ("offered_rps", self.offered_rps.into()),
            ("completed", self.completed.into()),
            ("decoded_tokens", self.decoded.into()),
            ("wall_s", self.wall_s.into()),
            ("ttft_p50_s", self.ttft.p50().into()),
            ("ttft_p99_s", self.ttft.p99().into()),
            ("latency_p50_s", self.latency.p50().into()),
            ("latency_p99_s", self.latency.p99().into()),
            ("tokens_per_s", tok_s.into()),
            ("tokens_per_s_per_node", (tok_s / self.nodes as f64).into()),
        ])
    }
}

/// Deterministic arrival offsets: seeded exponential inter-arrivals at
/// the given rate. The same (rate, n, seed) always yields the same
/// schedule, so every replica phase faces identical offered load.
fn schedule(rate_rps: f64, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(1.0 / rate_rps);
            t
        })
        .collect()
}

fn run_sweep(
    cluster: &Arc<Cluster>,
    replicas: usize,
    nodes: usize,
    rate: f64,
    n: usize,
    seed: u64,
) -> anyhow::Result<Sweep> {
    let offsets = schedule(rate, n, seed);
    // anchor slightly in the future so every client thread is parked
    // on its arrival time before the first one fires
    let t0 = Instant::now() + Duration::from_millis(20);
    let mut workers = Vec::new();
    for (i, off) in offsets.into_iter().enumerate() {
        let cluster = cluster.clone();
        let arrive = t0 + Duration::from_secs_f64(off);
        workers.push(std::thread::spawn(move || -> Result<(usize, f64, f64), String> {
            let now = Instant::now();
            if arrive > now {
                std::thread::sleep(arrive - now);
            }
            let sent = Instant::now();
            // distinct prompts: no cross-request prefix adoption, so
            // the sweep measures scheduling rather than cache luck
            let req = GenRequest::text(i as u64 + 1, &format!("req {i:04} payload"), MAX_NEW);
            let resp = cluster.submit(req)?;
            let e2e = sent.elapsed().as_secs_f64();
            // open-loop TTFT: queue wait (e2e minus the server-side
            // span) plus the engine's own time-to-first-token
            let ttft = (e2e - resp.total_s).max(0.0) + resp.ttft_s;
            Ok((resp.tokens.len(), e2e, ttft))
        }));
    }
    let mut sweep = Sweep {
        replicas,
        nodes,
        offered_rps: rate,
        completed: 0,
        decoded: 0,
        wall_s: 0.0,
        ttft: Summary::new(),
        latency: Summary::new(),
    };
    for w in workers {
        match w.join().unwrap() {
            Ok((toks, e2e, ttft)) => {
                sweep.completed += 1;
                sweep.decoded += toks;
                sweep.latency.add(e2e);
                sweep.ttft.add(ttft);
            }
            Err(e) => anyhow::bail!("open-loop request rejected: {e}"),
        }
    }
    sweep.wall_s = (Instant::now() - t0).as_secs_f64().max(1e-9);
    Ok(sweep)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let report_path = args
        .iter()
        .position(|a| a == "--report")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from);
    let trace_path = args
        .iter()
        .position(|a| a == "--trace")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from);
    if trace_path.is_some() {
        arclight::trace::set_enabled(true);
    }

    let rates: Vec<f64> = if quick { vec![20.0, 400.0] } else { vec![10.0, 50.0, 200.0, 800.0] };
    let n = if quick { 10 } else { 24 };
    let plat = Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0));
    let all_groups = plat.node_groups(None); // one group per node
    println!(
        "saturation: open-loop sweep{} | {n} requests × {MAX_NEW} new tokens per rate | \
         rates {rates:?} rps | per replica: {THREADS_PER_REPLICA} threads, \
         {BATCH_PER_REPLICA} lanes",
        if quick { " (quick)" } else { "" }
    );

    let mut sweeps: Vec<Sweep> = Vec::new();
    // the last phase's metrics snapshot: carries the barrier-skew and
    // drift blocks when the tracer is on
    let mut metrics_snapshot: Option<Json> = None;
    for r in [1usize, 2] {
        let groups = &all_groups[..r];
        let nodes: usize = groups.iter().map(Vec::len).sum();
        let cfg = ClusterConfig { batcher: BatcherConfig::default(), load_tolerance: 2 };
        let cluster = Cluster::start(groups, cfg, |_id, g| build_replica(g[0]))?;
        for (k, &rate) in rates.iter().enumerate() {
            let mut s = run_sweep(&cluster, r, nodes, rate, n, 42 + k as u64)?;
            println!(
                "[{r} replica{}] {rate:.0} rps offered: {}/{n} done, {:.1} tok/s \
                 ({:.1}/node) | ttft p50 {:.3}s p99 {:.3}s | e2e p50 {:.3}s p99 {:.3}s",
                if r == 1 { "" } else { "s" },
                s.completed,
                s.tokens_per_s(),
                s.tokens_per_s() / nodes as f64,
                s.ttft.p50(),
                s.ttft.p99(),
                s.latency.p50(),
                s.latency.p99()
            );
            sweeps.push(s);
        }
        metrics_snapshot = Some(cluster.metrics.snapshot());
        cluster.shutdown();
    }

    // the headline claim: replica scaling pays at saturating load
    let top = *rates.last().unwrap();
    let sat = |r: usize| -> f64 {
        sweeps
            .iter()
            .find(|s| s.replicas == r && s.offered_rps == top)
            .map(Sweep::tokens_per_s)
            .unwrap()
    };
    let (one, two) = (sat(1), sat(2));
    println!("saturating load ({top:.0} rps): 1 replica {one:.1} tok/s, 2 replicas {two:.1} tok/s");

    if let Some(path) = report_path {
        let report = obj(vec![
            ("benchmark", "saturation".into()),
            ("quick", quick.into()),
            ("requests_per_rate", n.into()),
            ("max_new", MAX_NEW.into()),
            ("threads_per_replica", THREADS_PER_REPLICA.into()),
            ("batch_per_replica", BATCH_PER_REPLICA.into()),
            ("rates_rps", Json::Arr(rates.iter().map(|&x| x.into()).collect())),
            ("saturating_rps", top.into()),
            ("tok_s_one_replica_saturated", one.into()),
            ("tok_s_two_replicas_saturated", two.into()),
            ("traced", trace_path.is_some().into()),
            (
                "barrier_skew",
                metrics_snapshot
                    .as_ref()
                    .and_then(|m| m.get("barrier_skew"))
                    .cloned()
                    .unwrap_or(Json::Null),
            ),
            (
                "drift",
                metrics_snapshot
                    .as_ref()
                    .and_then(|m| m.get("drift"))
                    .cloned()
                    .unwrap_or(Json::Null),
            ),
            ("sweeps", Json::Arr(sweeps.iter_mut().map(Sweep::to_json).collect())),
        ]);
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        std::fs::write(&path, report.to_string())?;
        println!("wrote report to {}", path.display());
    }

    if let Some(path) = &trace_path {
        arclight::trace::export_chrome(path)?;
        println!(
            "wrote chrome trace ({} spans collected, {} dropped) to {}",
            arclight::trace::collected_spans(),
            arclight::trace::dropped_spans(),
            path.display()
        );
    }

    assert!(
        two > one,
        "two replicas ({two:.1} tok/s) must beat one ({one:.1} tok/s) at saturating load"
    );
    println!("two replicas beat one replica at saturating load ✓");
    Ok(())
}
