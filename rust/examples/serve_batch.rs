//! End-to-end serving driver (the repo's E2E validation workload).
//!
//! Loads a small *real* model (the AOT tiny model when artifacts are
//! built — byte-identical weights to the PJRT/JAX golden path — else a
//! synthetic 25M model), starts the TCP serving stack (router + dynamic
//! batcher + engine slots), fires a batch of concurrent client
//! requests over the socket, and reports latency/throughput. When
//! artifacts are present it also cross-checks one served response
//! against PJRT token-for-token.
//!
//!     make artifacts && cargo run --release --example serve_batch

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions};
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::server::{BatcherConfig, EngineSlot, GenRequest, Router, ServerClient, ServerHandle};
use arclight::util::stats::Summary;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn build_engine(seed: u64) -> anyhow::Result<(Engine, bool)> {
    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 2,
        topo: Topology::kunpeng920(),
        prefill_rows: None,
        seed,
    };
    if let Some(dir) = artifacts_dir() {
        Ok((Engine::from_alf(&dir.join("tiny.alf"), &opts)?, true))
    } else {
        Ok((Engine::new_synthetic(ModelConfig::small_25m(), &opts)?, false))
    }
}

fn main() -> anyhow::Result<()> {
    let slots = 2usize;
    let n_requests = 16usize;
    let max_new = 24usize;

    // --- serving stack -----------------------------------------------------
    let router = Router::new(BatcherConfig::default());
    let mut slot_threads = Vec::new();
    let mut from_artifacts = false;
    for _ in 0..slots {
        let (engine, real) = build_engine(0)?;
        from_artifacts = real;
        let r = router.clone();
        slot_threads.push(std::thread::spawn(move || EngineSlot::new(engine).serve(r)));
    }
    let server = ServerHandle::start("127.0.0.1:0", router.clone())?;
    let addr = server.addr.to_string();
    println!(
        "serving {} model on {addr} with {slots} slots",
        if from_artifacts { "tiny AOT (real weights)" } else { "synthetic 25M" }
    );

    // --- batched clients ---------------------------------------------------
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for i in 0..n_requests {
        let addr = addr.clone();
        clients.push(std::thread::spawn(move || -> anyhow::Result<_> {
            let mut c = ServerClient::connect(&addr)?;
            let mut req = GenRequest::text(i as u64 + 1, "the quick brown fox", max_new);
            // pre-tokenized variant for half the requests (covers both paths)
            if i % 2 == 0 {
                req.prompt = None;
                req.tokens = Some((0..12).map(|k| (k * 17 + i as i32) % 256).collect());
            }
            let resp = c.generate(&req)?;
            Ok(resp)
        }));
    }

    let mut latency = Summary::new();
    let mut ttft = Summary::new();
    let mut decoded = 0usize;
    for c in clients {
        let resp = c.join().unwrap()?;
        latency.add(resp.total_s);
        ttft.add(resp.ttft_s);
        decoded += resp.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();

    let m = router.metrics.snapshot();
    println!("--- batch complete ---");
    println!("requests: {n_requests}, decoded tokens: {decoded}, wall: {wall:.2}s");
    println!("aggregate decode throughput: {:.1} tok/s", decoded as f64 / wall);
    println!("latency  p50 {:.3}s  p95 {:.3}s", latency.p50(), latency.p95());
    println!("ttft     p50 {:.3}s  p95 {:.3}s", ttft.p50(), ttft.p95());
    println!("server metrics: {}", m.to_string());

    // --- golden cross-check vs PJRT (when artifacts exist) ------------------
    // The PJRT session only loads in builds with the `pjrt` feature;
    // the default build's stub errors, which we treat as a skip so the
    // example still exits cleanly after a successful batch.
    if let Some(dir) = artifacts_dir() {
        match arclight::runtime::PjrtSession::load(&dir) {
            Ok(session) => {
                let prompt: Vec<i32> = (0..session.manifest.prompt_len as i32).collect();
                let want = session.generate(&prompt, 8)?;
                let mut c = ServerClient::connect(&addr)?;
                let mut req = GenRequest::text(999, "", 8);
                req.prompt = None;
                req.tokens = Some(prompt);
                let got = c.generate(&req)?;
                assert_eq!(want, got.tokens, "served tokens must match the PJRT golden path");
                println!("golden check vs PJRT: served tokens match ✓ ({want:?})");
            }
            // feature-enabled builds must surface real load failures
            Err(e) if cfg!(feature = "pjrt") => return Err(e),
            Err(e) => println!("golden check vs PJRT skipped: {e}"),
        }
    }

    server.stop();
    let _ = Arc::try_unwrap(router);
    for t in slot_threads {
        let _ = t.join();
    }
    Ok(())
}
