//! End-to-end serving driver and the continuous-batching headline
//! benchmark.
//!
//! Loads a small *real* model (the AOT tiny model when artifacts are
//! built — byte-identical weights to the PJRT/JAX golden path — else a
//! synthetic 25M model) and serves the same batch of concurrent client
//! requests over TCP twice:
//!
//! 1. **sequential-slots baseline** — 2 engine slots, each serving one
//!    whole generation at a time (the pre-continuous design);
//! 2. **continuous batching** — one engine with a *paged* KV arena
//!    sized for only [`ARENA_SEQS`] full-length sequences, every decode
//!    step a single batched graph pass. Short requests overcommit the
//!    arena (≥ 3× the slot-equivalent concurrency) and identical
//!    prompts share physical prefix pages.
//!
//! It reports aggregate tokens/s for both and asserts the continuous
//! scheduler wins, that page-granular admission overcommits the arena,
//! and that prefix sharing reports hits. When artifacts are present it
//! also cross-checks one served response against PJRT token-for-token.
//!
//!     make artifacts && cargo run --release --example serve_batch
//!
//! Flags: `--quick` (CI-sized run), `--report <path>` (write a JSON
//! report for the perf-trajectory artifact), `--pin` (detect the host
//! NUMA platform, pin workers and first-touch arenas; degrades to the
//! simulated testbed when the `host` feature is off or the machine is
//! too small — shared CI runners included).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use arclight::baseline::Strategy;
use arclight::frontend::{Engine, EngineOptions};
use arclight::hw::{membind, Platform};
use arclight::model::ModelConfig;
use arclight::server::{
    BatcherConfig, ContinuousBatcher, EngineSlot, GenRequest, Router, ServerClient, ServerHandle,
};
use arclight::util::json::{obj, Json};
use arclight::util::stats::Summary;

/// Paged-KV demo geometry: 4-token pages and an arena holding only
/// this many full-length sequences. Short requests (≤ max_seq/4
/// tokens each) must overcommit it to ≥ 3× concurrent lanes.
const PAGE_SIZE: usize = 4;
const ARENA_SEQS: usize = 2;
/// Prompt shared by the warmup and every token-path client, so later
/// admissions adopt the prefix pages the first request registered.
const SHARED_TOKENS: [i32; 6] = [9, 8, 7, 6, 5, 4];

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Resolve `--pin`: a detected host platform big enough for `threads`
/// workers (with the first-touch arena map installed), else the
/// simulated testbed. Shared runners land here via graceful pin
/// failure, not a crash.
fn resolve_platform(pin: bool, threads: usize) -> Platform {
    if !pin {
        return Platform::simulated();
    }
    let (p, note) = Platform::host_with_membind(threads);
    if let Some(why) = note {
        println!("--pin requested but {why}; running simulated");
    }
    p
}

fn build_engine(
    platform: &Platform,
    pin: bool,
    threads: usize,
    batch_slots: usize,
    page_size: usize,
    kv_pages: Option<usize>,
) -> anyhow::Result<(Engine, bool)> {
    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads,
        platform: platform.clone(),
        prefill_rows: None,
        seed: 0,
        batch_slots,
        pin,
        page_size,
        kv_pages,
        base_node: 0,
    };
    if let Some(dir) = artifacts_dir() {
        Ok((Engine::from_alf(&dir.join("tiny.alf"), &opts)?, true))
    } else {
        Ok((Engine::new_synthetic(ModelConfig::small_25m(), &opts)?, false))
    }
}

struct PhaseResult {
    name: &'static str,
    wall_s: f64,
    decoded: usize,
    agg_tok_s: f64,
    latency: Summary,
    ttft: Summary,
    metrics: Json,
}

impl PhaseResult {
    fn to_json(&mut self) -> Json {
        obj(vec![
            ("name", self.name.into()),
            ("wall_s", self.wall_s.into()),
            ("decoded_tokens", self.decoded.into()),
            ("aggregate_tok_per_s", self.agg_tok_s.into()),
            ("latency_p50_s", self.latency.p50().into()),
            ("latency_p95_s", self.latency.p95().into()),
            ("ttft_p50_s", self.ttft.p50().into()),
            ("server_metrics", self.metrics.clone()),
        ])
    }
}

/// Fire `n_requests` concurrent clients at `addr`; half text prompts,
/// half pre-tokenized (covers both request paths). Prompts are short
/// (6 tokens) so each request's page budget stays ≤ max_seq/4, and
/// identical within each path so prefix pages get shared.
fn fire_clients(
    addr: &str,
    n_requests: usize,
    max_new: usize,
) -> anyhow::Result<(f64, usize, Summary, Summary)> {
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for i in 0..n_requests {
        let addr = addr.to_string();
        clients.push(std::thread::spawn(move || -> anyhow::Result<_> {
            let mut c = ServerClient::connect(&addr)?;
            let mut req = GenRequest::text(i as u64 + 1, "short", max_new);
            if i % 2 == 0 {
                req.prompt = None;
                req.tokens = Some(SHARED_TOKENS.to_vec());
            }
            c.generate(&req)
        }));
    }
    let mut latency = Summary::new();
    let mut ttft = Summary::new();
    let mut decoded = 0usize;
    for c in clients {
        let resp = c.join().unwrap()?;
        latency.add(resp.total_s);
        ttft.add(resp.ttft_s);
        decoded += resp.tokens.len();
    }
    Ok((t0.elapsed().as_secs_f64(), decoded, latency, ttft))
}

fn run_sequential(
    platform: &Platform,
    threads_total: usize,
    slots: usize,
    n_requests: usize,
    max_new: usize,
) -> anyhow::Result<(PhaseResult, bool)> {
    let router = Router::new(BatcherConfig::default());
    let mut slot_threads = Vec::new();
    let mut from_artifacts = false;
    for _ in 0..slots {
        // never pinned: every slot engine derives the same cpu map
        // (bind_cores starts at core 0), so pinning N slot pools would
        // stack them onto the same cpus and unfairly slow the baseline
        // the continuous scheduler is measured against. The host
        // platform (and its first-touch arena placement) still applies.
        let (engine, real) = build_engine(platform, false, threads_total / slots, 1, 16, None)?;
        from_artifacts = real;
        let r = router.clone();
        slot_threads.push(std::thread::spawn(move || EngineSlot::new(engine).serve(r)));
    }
    let server = ServerHandle::start("127.0.0.1:0", router.clone())?;
    let addr = server.addr.to_string();
    let (wall_s, decoded, latency, ttft) = fire_clients(&addr, n_requests, max_new)?;
    let metrics = router.metrics.snapshot();
    server.stop();
    for t in slot_threads {
        let _ = t.join();
    }
    let _ = Arc::try_unwrap(router);
    Ok((
        PhaseResult {
            name: "sequential-slots",
            wall_s,
            decoded,
            agg_tok_s: decoded as f64 / wall_s,
            latency,
            ttft,
            metrics,
        },
        from_artifacts,
    ))
}

fn run_continuous(
    platform: &Platform,
    pin: bool,
    threads_total: usize,
    batch: usize,
    n_requests: usize,
    max_new: usize,
    kv_pages: usize,
) -> anyhow::Result<(PhaseResult, String, ServerHandle, std::thread::JoinHandle<()>)> {
    let router = Router::new(BatcherConfig::default());
    let (engine, _) =
        build_engine(platform, pin, threads_total, batch, PAGE_SIZE, Some(kv_pages))?;
    let r = router.clone();
    let batcher_thread = std::thread::spawn(move || ContinuousBatcher::new(engine).serve(r));
    let server = ServerHandle::start("127.0.0.1:0", router.clone())?;
    let addr = server.addr.to_string();
    // warm the prefix index: one request whose pages every later
    // token-path admission can adopt
    let mut warm = ServerClient::connect(&addr)?;
    let mut wreq = GenRequest::text(9_000, "", max_new);
    wreq.prompt = None;
    wreq.tokens = Some(SHARED_TOKENS.to_vec());
    let _ = warm.generate(&wreq)?;
    let (wall_s, decoded, latency, ttft) = fire_clients(&addr, n_requests, max_new)?;
    let metrics = router.metrics.snapshot();
    Ok((
        PhaseResult {
            name: "continuous",
            wall_s,
            decoded,
            agg_tok_s: decoded as f64 / wall_s,
            latency,
            ttft,
            metrics,
        },
        addr,
        server,
        batcher_thread,
    ))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let pin = args.iter().any(|a| a == "--pin");
    let report_path = args
        .iter()
        .position(|a| a == "--report")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from);

    let threads_total = 4usize;
    let batch = 8usize;
    let n_requests = if quick { 8 } else { 16 };
    // Geometry of the served model (the AOT artifact is the tiny
    // model); sizes the paged arena and the short-request budget.
    let max_seq = if artifacts_dir().is_some() {
        ModelConfig::tiny().max_seq
    } else {
        ModelConfig::small_25m().max_seq
    };
    // every request must fit in max_seq/4 tokens (prompt is 6 tokens)
    // so the ARENA_SEQS-sized arena can hold ≥ 3×ARENA_SEQS of them
    let max_new = (max_seq / 4 - 6).min(if quick { 8 } else { 24 });
    let kv_pages = ARENA_SEQS * max_seq.div_ceil(PAGE_SIZE);
    let platform = resolve_platform(pin, threads_total);
    println!(
        "serve_batch: {n_requests} concurrent requests × {max_new} new tokens, \
         {threads_total} worker threads{} | platform {} | \
         KV arena {kv_pages} pages × {PAGE_SIZE} tokens ({ARENA_SEQS} full sequences)",
        if quick { " (quick mode)" } else { "" },
        platform.name()
    );

    // --- phase 1: sequential-slot baseline ---------------------------------
    let (mut seq, from_artifacts) =
        run_sequential(&platform, threads_total, 2, n_requests, max_new)?;
    println!(
        "[{}] model: {}",
        seq.name,
        if from_artifacts { "tiny AOT (real weights)" } else { "synthetic 25M" }
    );
    println!(
        "[{}] decoded {} tok in {:.2}s → {:.1} tok/s aggregate | p50 {:.3}s p95 {:.3}s",
        seq.name,
        seq.decoded,
        seq.wall_s,
        seq.agg_tok_s,
        seq.latency.p50(),
        seq.latency.p95()
    );

    // --- phase 2: continuous batching --------------------------------------
    // node_local_bytes is a process-cumulative counter; snapshot it so
    // the report attributes only the continuous engine's arenas
    let nlb_before_continuous = membind::node_local_bytes();
    let (mut cont, addr, server, batcher_thread) =
        run_continuous(&platform, pin, threads_total, batch, n_requests, max_new, kv_pages)?;
    println!(
        "[{}] decoded {} tok in {:.2}s → {:.1} tok/s aggregate | p50 {:.3}s p95 {:.3}s | \
         occupancy {:.2}",
        cont.name,
        cont.decoded,
        cont.wall_s,
        cont.agg_tok_s,
        cont.latency.p50(),
        cont.latency.p95(),
        cont.metrics.get("batch_occupancy").and_then(Json::as_f64).unwrap_or(0.0)
    );

    let speedup = cont.agg_tok_s / seq.agg_tok_s;
    println!("continuous / sequential speedup: {speedup:.2}×");

    // --- paged-KV claims ----------------------------------------------------
    let peak_seqs =
        cont.metrics.get("peak_concurrent_seqs").and_then(Json::as_usize).unwrap_or(0);
    let prefix_hit_tokens =
        cont.metrics.get("prefix_hit_tokens").and_then(Json::as_usize).unwrap_or(0);
    let kv_page_occupancy =
        cont.metrics.get("kv_page_occupancy").and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "paged KV: peak {peak_seqs} concurrent sequences on a {ARENA_SEQS}-sequence arena | \
         {prefix_hit_tokens} prefix-hit tokens | occupancy {kv_page_occupancy:.2}"
    );
    assert!(
        peak_seqs >= 3 * ARENA_SEQS,
        "page-granular admission must overcommit the {ARENA_SEQS}-sequence arena \
         to ≥ {} short sequences (saw {peak_seqs})",
        3 * ARENA_SEQS
    );
    assert!(
        prefix_hit_tokens > 0,
        "identical prompts must share prefix pages (prefix_hit_tokens stayed 0)"
    );
    // a second identical-prefix request adopts pages the batch left in
    // the index and reports the hit on the wire
    {
        let mut c = ServerClient::connect(&addr)?;
        let mut req = GenRequest::text(9_001, "", max_new);
        req.prompt = None;
        req.tokens = Some(SHARED_TOKENS.to_vec());
        let resp = c.generate(&req)?;
        assert!(
            resp.prefix_hit_tokens > 0,
            "repeat of a served prompt must report prefix_hit_tokens on the wire"
        );
        println!(
            "repeat request adopted {} prompt tokens from shared pages ({} pages held) ✓",
            resp.prefix_hit_tokens, resp.kv_pages_used
        );
    }

    // --- golden cross-check vs PJRT (when artifacts exist) ------------------
    // The PJRT session only loads in builds with the `pjrt` feature;
    // the default build's stub errors, which we treat as a skip so the
    // example still exits cleanly after a successful batch.
    if let Some(dir) = artifacts_dir() {
        match arclight::runtime::PjrtSession::load(&dir) {
            Ok(session) => {
                let prompt: Vec<i32> = (0..session.manifest.prompt_len as i32).collect();
                let want = session.generate(&prompt, 8)?;
                let mut c = ServerClient::connect(&addr)?;
                let mut req = GenRequest::text(999, "", 8);
                req.prompt = None;
                req.tokens = Some(prompt);
                let got = c.generate(&req)?;
                assert_eq!(want, got.tokens, "served tokens must match the PJRT golden path");
                println!("golden check vs PJRT: served tokens match ✓ ({want:?})");
            }
            // feature-enabled builds must surface real load failures
            Err(e) if cfg!(feature = "pjrt") => return Err(e),
            Err(e) => println!("golden check vs PJRT skipped: {e}"),
        }
    }

    server.stop();
    let _ = batcher_thread.join();

    // --- JSON report (perf trajectory artifact) ----------------------------
    if let Some(path) = report_path {
        let pinned_workers = cont
            .metrics
            .get("pinned_workers")
            .and_then(Json::as_usize)
            .unwrap_or(0);
        let report = obj(vec![
            ("benchmark", "serve_batch".into()),
            ("quick", quick.into()),
            ("n_requests", n_requests.into()),
            ("max_new", max_new.into()),
            ("threads", threads_total.into()),
            ("batch_slots", batch.into()),
            ("kv_page_size", PAGE_SIZE.into()),
            ("kv_pages_total", kv_pages.into()),
            ("kv_page_occupancy", kv_page_occupancy.into()),
            ("prefix_hit_tokens", prefix_hit_tokens.into()),
            ("peak_concurrent_seqs", peak_seqs.into()),
            ("from_artifacts", from_artifacts.into()),
            ("platform", platform.name().into()),
            ("pinned_workers", pinned_workers.into()),
            // the continuous serving engine's node-locally placed bytes
            (
                "node_local_bytes",
                ((membind::node_local_bytes() - nlb_before_continuous) as usize).into(),
            ),
            ("speedup_continuous_vs_sequential", speedup.into()),
            ("phases", Json::Arr(vec![seq.to_json(), cont.to_json()])),
        ]);
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        std::fs::write(&path, report.to_string())?;
        println!("wrote report to {}", path.display());
    }

    // the headline claim this example exists to demonstrate
    assert!(
        speedup > 1.0,
        "continuous batching ({:.1} tok/s) must beat the sequential baseline ({:.1} tok/s)",
        cont.agg_tok_s,
        seq.agg_tok_s
    );
    println!("continuous batching beats the sequential baseline ✓");
    Ok(())
}
