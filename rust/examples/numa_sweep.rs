//! NUMA strategy sweep on the simulated 192-core testbed: the paper's
//! Figure-11-style comparison with per-strategy traffic anatomy.
//!
//!     cargo run --release --example numa_sweep

use arclight::baseline::Strategy;
use arclight::model::ModelConfig;
use arclight::numa::Topology;
use arclight::report::figures::decode_tok_s;
use arclight::sched::SyncMode;

fn main() {
    let topo = Topology::kunpeng920();
    let cfg = ModelConfig::qwen3_4b();
    println!(
        "Qwen3-4B Q4_0 on the simulated Kunpeng-920 (4 nodes × 48 cores), prompt 15, gen 256\n"
    );
    println!(
        "{:26} {:>8} {:>12} {:>10}",
        "strategy", "threads", "decode tok/s", "remote %"
    );
    let runs: Vec<(Strategy, usize)> = vec![
        (Strategy::llama_isolate(), 48),
        (Strategy::arclight_single(), 48),
        (Strategy::llama_distribute(2), 96),
        (Strategy::arclight_tp(2, SyncMode::SyncA), 96),
        (Strategy::arclight_tp(2, SyncMode::SyncB), 96),
        // llama.cpp's best multi-node operating point is *below* full
        // thread count (the cross-NUMA wall): sweep to find it
        (Strategy::llama_distribute(4), 96),
        (Strategy::llama_distribute(4), 144),
        (Strategy::llama_distribute(4), 192),
        (Strategy::arclight_tp(4, SyncMode::SyncA), 192),
        (Strategy::arclight_tp(4, SyncMode::SyncB), 192),
    ];
    let mut best_llama: f64 = 0.0;
    let mut best_arc: f64 = 0.0;
    for (s, t) in runs {
        let p = decode_tok_s(&cfg, s, t, &topo, 15, 256, 4);
        println!(
            "{:26} {:>8} {:>12.1} {:>9.1}%",
            p.strategy,
            p.threads,
            p.tok_per_s,
            p.remote_fraction * 100.0
        );
        if p.strategy.starts_with("llama") {
            best_llama = best_llama.max(p.tok_per_s);
        } else {
            best_arc = best_arc.max(p.tok_per_s);
        }
    }
    println!(
        "\nArcLight best vs llama.cpp best: +{:.0}% (paper reports up to +46%)",
        (best_arc / best_llama - 1.0) * 100.0
    );
}
