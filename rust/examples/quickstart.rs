//! Quickstart: build a small synthetic Qwen3-architecture model, run a
//! prompt through the ArcLight engine, print the output and throughput.
//!
//!     cargo run --release --example quickstart

use arclight::baseline::Strategy;
use arclight::frontend::{ByteTokenizer, Engine, EngineOptions, Sampler};
use arclight::hw::Platform;
use arclight::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    // A ~25M-parameter Qwen3-geometry model with deterministic synthetic
    // weights, Q4_0-quantized like the paper's benchmark model.
    let cfg = ModelConfig::small_25m();
    println!(
        "model: {} layers, dim {}, {} params, {:.1} MB Q4_0 weights",
        cfg.n_layers,
        cfg.dim,
        cfg.n_params(),
        cfg.q4_weight_bytes() as f64 / 1e6
    );

    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 4,
        platform: Platform::simulated(),
        prefill_rows: None,
        seed: 0,
        batch_slots: 1,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node: 0,
    };
    let mut engine = Engine::new_synthetic(cfg, &opts)?;

    let tok = ByteTokenizer;
    let prompt = tok.encode("ArcLight runs on many-core CPUs", true);
    let res = engine.generate(&prompt, 48, &Sampler::greedy());

    let head = &res.tokens[..8.min(res.tokens.len())];
    println!("generated {} tokens: {head:?}", res.tokens.len());
    println!("text (byte-decoded): {:?}", tok.decode(&res.tokens));
    println!(
        "prefill {:.1} tok/s | decode {:.1} tok/s (host wall-clock; figures use the sim testbed)",
        res.prefill_tok_per_s(),
        res.decode_tok_per_s()
    );

    // The same model under 2-node tensor parallelism must produce the
    // same tokens — TP is a pure execution-strategy change (§3.2).
    let opts_tp = EngineOptions {
        strategy: Strategy::arclight_tp(2, arclight::sched::SyncMode::SyncB),
        threads: 4,
        platform: Platform::simulated(),
        prefill_rows: None,
        seed: 0,
        batch_slots: 1,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node: 0,
    };
    let mut engine_tp = Engine::new_synthetic(ModelConfig::small_25m(), &opts_tp)?;
    let res_tp = engine_tp.generate(&prompt, 48, &Sampler::greedy());
    assert_eq!(res.tokens, res_tp.tokens, "TP must not change results");
    println!("TP(2) engine produced identical tokens ✓");

    // Continuous batching is also a pure scheduling change: the same
    // prompt decoded as one lane of a multi-sequence batch must produce
    // the same tokens as the serial loop above.
    let opts_batch = EngineOptions { batch_slots: 4, ..opts };
    let mut engine_b = Engine::new_synthetic(ModelConfig::small_25m(), &opts_batch)?;
    // `seq_start` reserves KV pages for the whole token budget up
    // front; the handle returns them to the arena when dropped (RAII).
    let seq = engine_b.seq_start(prompt.len() + 16).expect("free pages");
    let mut logits = Vec::new();
    for &t in &prompt {
        logits = engine_b.step_batch(&[(&seq, t)]).remove(0);
    }
    let mut batched_tokens = Vec::with_capacity(16);
    for step in 0..16usize {
        let next = Sampler::greedy().sample(&logits, step);
        batched_tokens.push(next);
        if step + 1 < 16 {
            logits = engine_b.step_batch(&[(&seq, next)]).remove(0);
        }
    }
    drop(seq);
    assert_eq!(&res.tokens[..16], &batched_tokens[..], "batched lane must match serial decode");
    println!("continuous-batching lane produced identical tokens ✓");
    Ok(())
}
