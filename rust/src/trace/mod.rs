//! Runtime tracing: per-worker span rings, Chrome-trace export and
//! barrier-skew rollups.
//!
//! The repo could observe *outcomes* (tokens/s, `predicted_step_us`)
//! but never *where a step's time went* — which kernel, which worker,
//! how long each thread spun at a Sync-B barrier. This module records
//! exactly that, cheaply enough to stay compiled into every build:
//!
//! * **Off by default, one load when off.** Every instrumentation site
//!   guards on [`enabled`], a single relaxed atomic load. No clock
//!   read, no ring write, no allocation happens unless tracing was
//!   switched on ([`set_enabled`]).
//! * **One fixed-capacity ring per worker thread (plus the pass
//!   leader).** Each pool worker binds itself at spawn
//!   ([`bind_worker`]) and records spans into its own single-producer
//!   ring — no locks, no contention on the hot path. Rings hold the
//!   newest [`RING_CAP`] spans; overwritten spans are counted in
//!   [`dropped_spans`], never silently lost.
//! * **Leader-side drain.** After each pass the executor calls
//!   [`finish_pass`], which appends the pass-dispatch span, drains the
//!   pool's rings (safe: the pass completion latch ordered every
//!   worker write before the drain), folds a [`PassRollup`]
//!   (per-kernel time share, per-group barrier skew — the straggler
//!   gauge) and moves the spans into the bounded collected buffer the
//!   Chrome exporter reads.
//!
//! Three span kinds exist, shared with the simulator's virtual-time
//! trace (`crate::report::trace` emits the same Chrome `trace_event`
//! schema through [`chrome_event`], so sim and host traces diff
//! against each other): `pass` (one per pool dispatch), one kernel
//! span per plan step per worker (name, unit range, entry index), and
//! `barrier.global` / `barrier.group` wait spans recorded inside
//! [`crate::threads::SpinBarrier::wait`] itself.
//!
//! [`export_chrome`] writes `{"traceEvents": [...]}` with `pid` = NUMA
//! node and `tid` = worker rank, loadable in Perfetto / `chrome://tracing`.
//! Export when the engine is quiescent (after generation, after the
//! bench sections) — the collected buffer is only appended between
//! passes, so an export mid-run just misses the pass in flight.

use std::cell::{RefCell, UnsafeCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{obj, Json};

/// Spans retained per worker ring. At one kernel span + one barrier
/// span per plan step, a ring holds the most recent ~15–20 decode
/// passes of a 120-op graph — enough for per-pass rollups (drained
/// every pass) with slack for passes the leader never drained.
pub const RING_CAP: usize = 4096;

/// Collected-span ceiling across the whole process (~12 MB at 48 B per
/// span). Beyond it, freshly drained spans are dropped and counted.
pub const MAX_COLLECTED: usize = 1 << 18;

/// Ring rank recorded for a pass leader (the executor thread).
pub const LEADER_RANK: u32 = u32::MAX;

/// What a span measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// One whole pool dispatch (leader-side, wraps the pass).
    Pass,
    /// One worker's slice of one plan step (kernel name + unit range).
    Kernel,
    /// Time spent waiting at a global or group spin barrier.
    Barrier,
}

/// One recorded span. `Copy` and allocation-free: kernel names are the
/// `&'static str` the registry resolved at graph build, timestamps are
/// nanoseconds since the process trace epoch.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// Span kind (pass / kernel / barrier wait).
    pub kind: SpanKind,
    /// Kernel name, `"pass"`, or `"barrier.global"`/`"barrier.group"`.
    pub name: &'static str,
    /// Start, nanoseconds since the trace epoch.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// TP group id (`u32::MAX` when the span is group-less: width-1
    /// steps, the global barrier, pass spans, idle workers).
    pub group: u32,
    /// Execution-list entry index (`u32::MAX` for non-kernel spans).
    pub entry: u32,
    /// First unit of the worker's range (kernel spans).
    pub u0: u32,
    /// One past the last unit of the worker's range (kernel spans).
    pub u1: u32,
}

impl Span {
    fn empty() -> Span {
        Span {
            kind: SpanKind::Kernel,
            name: "",
            start_ns: 0,
            dur_ns: 0,
            group: u32::MAX,
            entry: u32::MAX,
            u0: 0,
            u1: 0,
        }
    }
}

/// Fixed-capacity single-producer ring. The owning thread is the only
/// writer; the pass leader is the only reader, and every read happens
/// after the pool's completion latch ordered the writes (or after the
/// producer quiesced), so the unsynchronized slot accesses never race.
struct Ring {
    cap: u64,
    /// Total spans ever pushed (monotonic; slot = `head % cap`).
    head: AtomicU64,
    /// Total spans ever drained (leader-only).
    taken: AtomicU64,
    slots: Box<[UnsafeCell<Span>]>,
}

// Slots are raw cells, but the producer/consumer protocol above keeps
// accesses exclusive; `head` is the release/acquire handoff point.
unsafe impl Sync for Ring {}

impl Ring {
    fn new(cap: usize) -> Ring {
        assert!(cap > 0);
        Ring {
            cap: cap as u64,
            head: AtomicU64::new(0),
            taken: AtomicU64::new(0),
            slots: (0..cap).map(|_| UnsafeCell::new(Span::empty())).collect(),
        }
    }

    /// Producer-side push; wraps over the oldest span when full.
    fn push(&self, s: Span) {
        let h = self.head.load(Ordering::Relaxed);
        unsafe {
            *self.slots[(h % self.cap) as usize].get() = s;
        }
        self.head.store(h + 1, Ordering::Release);
    }

    /// Consumer-side drain of everything since the previous drain,
    /// oldest first, clamped to the ring capacity (wraparound keeps
    /// the *newest* spans). Returns the overwritten-span count.
    fn drain(&self, out: &mut Vec<Span>) -> u64 {
        let head = self.head.load(Ordering::Acquire);
        let taken = self.taken.load(Ordering::Relaxed);
        let avail = head - taken;
        let keep = avail.min(self.cap);
        for i in (head - keep)..head {
            out.push(unsafe { *self.slots[(i % self.cap) as usize].get() });
        }
        self.taken.store(head, Ordering::Relaxed);
        avail - keep
    }
}

/// A drained span plus the identity of the ring that produced it.
#[derive(Clone, Copy, Debug)]
struct CollectedSpan {
    rank: u32,
    node: u32,
    span: Span,
}

struct RingEntry {
    pool: u64,
    rank: u32,
    node: u32,
    ring: Arc<Ring>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static POOL_IDS: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static REGISTRY: Mutex<Vec<RingEntry>> = Mutex::new(Vec::new());
static COLLECTED: Mutex<Vec<CollectedSpan>> = Mutex::new(Vec::new());

struct TlBind {
    pool: u64,
    rank: u32,
    node: u32,
    ring: Option<Arc<Ring>>,
}

thread_local! {
    // Threads that never called `bind_worker` (tests, the main thread)
    // record into pool 0, which no executor drains — their spans stay
    // out of rollups and exports by construction.
    static TL: RefCell<TlBind> =
        const { RefCell::new(TlBind { pool: 0, rank: LEADER_RANK, node: 0, ring: None }) };
}

/// Switch tracing on or off. Enabling pre-warms the trace epoch so the
/// first span doesn't pay the `OnceLock` initialization.
pub fn set_enabled(on: bool) {
    if on {
        let _ = EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// The disabled-path guard: a single relaxed atomic load. Every
/// instrumentation site checks this before touching the clock or a
/// ring.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Nanoseconds since the process trace epoch (first use anchors it).
#[inline]
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Allocate a fresh pool identity (the drain scope of `finish_pass`).
pub fn new_pool_id() -> u64 {
    POOL_IDS.fetch_add(1, Ordering::Relaxed)
}

/// Bind the calling thread as worker `rank` (home NUMA node `node`) of
/// pool `pool`. Called once per worker at spawn; the ring itself is
/// allocated lazily on the first recorded span, so pools that are
/// never traced cost nothing beyond this thread-local store.
pub fn bind_worker(pool: u64, rank: usize, node: usize) {
    TL.with(|t| {
        let mut t = t.borrow_mut();
        t.pool = pool;
        t.rank = rank as u32;
        t.node = node as u32;
        t.ring = None;
    });
}

fn tl_push(span: Span) {
    TL.with(|t| {
        let mut t = t.borrow_mut();
        if t.ring.is_none() {
            let ring = Arc::new(Ring::new(RING_CAP));
            REGISTRY.lock().unwrap().push(RingEntry {
                pool: t.pool,
                rank: t.rank,
                node: t.node,
                ring: ring.clone(),
            });
            t.ring = Some(ring);
        }
        t.ring.as_ref().expect("ring just installed").push(span);
    });
}

/// Record one kernel span for the calling worker: step `entry` of the
/// plan, units `[u0, u1)` (equal for an idle worker), TP group
/// `group` (`u32::MAX` for width-1 steps). Callers gate on
/// [`enabled`]; `start_ns` came from [`now_ns`] before the kernel ran.
pub fn record_kernel(name: &'static str, start_ns: u64, group: u32, entry: u32, u0: u32, u1: u32) {
    let span = Span {
        kind: SpanKind::Kernel,
        name,
        start_ns,
        dur_ns: now_ns().saturating_sub(start_ns),
        group,
        entry,
        u0,
        u1,
    };
    tl_push(span);
}

/// Record the wait at a spin-barrier arrival. `tag` is the barrier's
/// scope: `u32::MAX` for the pool-global barrier, the group id for a
/// group-local one ([`crate::threads::SpinBarrier::with_tag`]).
pub fn record_barrier(tag: u32, start_ns: u64) {
    let span = Span {
        kind: SpanKind::Barrier,
        name: if tag == u32::MAX { "barrier.global" } else { "barrier.group" },
        start_ns,
        dur_ns: now_ns().saturating_sub(start_ns),
        group: tag,
        entry: u32::MAX,
        u0: 0,
        u1: 0,
    };
    tl_push(span);
}

fn leader_ring(pool: u64) -> Arc<Ring> {
    let mut reg = REGISTRY.lock().unwrap();
    if let Some(e) = reg.iter().find(|e| e.pool == pool && e.rank == LEADER_RANK) {
        return e.ring.clone();
    }
    let ring = Arc::new(Ring::new(RING_CAP));
    reg.push(RingEntry { pool, rank: LEADER_RANK, node: 0, ring: ring.clone() });
    ring
}

/// Leader-side pass epilogue: append the pass-dispatch span, drain the
/// pool's worker rings (the completion latch ordered every worker
/// write before this call), fold the rollup and move the spans into
/// the collected buffer for export. Called once per pass by the real
/// executor when tracing is enabled.
pub fn finish_pass(pool: u64, start_ns: u64) -> PassRollup {
    let end = now_ns();
    leader_ring(pool).push(Span {
        kind: SpanKind::Pass,
        name: "pass",
        start_ns,
        dur_ns: end.saturating_sub(start_ns),
        group: u32::MAX,
        entry: u32::MAX,
        u0: 0,
        u1: 0,
    });
    let mut spans: Vec<CollectedSpan> = Vec::new();
    let mut lost = 0u64;
    {
        let reg = REGISTRY.lock().unwrap();
        let mut tmp = Vec::new();
        for e in reg.iter().filter(|e| e.pool == pool) {
            tmp.clear();
            lost += e.ring.drain(&mut tmp);
            let (rank, node) = (e.rank, e.node);
            spans.extend(tmp.iter().map(|&span| CollectedSpan { rank, node, span }));
        }
    }
    if lost > 0 {
        DROPPED.fetch_add(lost, Ordering::Relaxed);
    }
    let rollup = fold(&spans);
    collect(spans);
    rollup
}

fn collect(spans: Vec<CollectedSpan>) {
    let mut c = COLLECTED.lock().unwrap();
    let room = MAX_COLLECTED.saturating_sub(c.len());
    if spans.len() > room {
        DROPPED.fetch_add((spans.len() - room) as u64, Ordering::Relaxed);
    }
    c.extend(spans.into_iter().take(room));
}

/// Spans currently held for export.
pub fn collected_spans() -> usize {
    COLLECTED.lock().unwrap().len()
}

/// Spans lost to ring wraparound or the collected-buffer ceiling.
pub fn dropped_spans() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Clear the collected buffer and the drop counter (bench phases,
/// tests). Rings keep their cursors; live workers are unaffected.
pub fn reset_collected() {
    COLLECTED.lock().unwrap().clear();
    DROPPED.store(0, Ordering::Relaxed);
}

/// Drift-detection parameters, shared by the engine (per-engine EWMA)
/// and the serving metrics (aggregate + per-replica EWMAs): smoothing
/// factor of the measured-step-time EWMA, minimum samples before a
/// verdict, and the acceptable measured/predicted ratio band outside
/// which a re-tune is recommended.
pub const DRIFT_ALPHA: f64 = 0.2;
/// Minimum EWMA samples before `retune_recommended` may fire.
pub const DRIFT_MIN_SAMPLES: usize = 8;
/// Lower bound of the acceptable measured/predicted ratio band.
pub const DRIFT_RATIO_LOW: f64 = 0.8;
/// Upper bound of the acceptable measured/predicted ratio band.
pub const DRIFT_RATIO_HIGH: f64 = 1.25;

/// Fold one measured step time (µs) into the drift EWMA.
pub fn ewma_fold(prev: Option<f64>, sample_us: f64) -> f64 {
    match prev {
        None => sample_us,
        Some(e) => e + DRIFT_ALPHA * (sample_us - e),
    }
}

/// Drift verdict: `(ratio, retune_recommended)` comparing the measured
/// EWMA against the tuner's prediction. No verdict (ratio `None`,
/// recommend `false`) without both sides, and no recommendation before
/// [`DRIFT_MIN_SAMPLES`] — a cold EWMA is noise, not drift.
pub fn drift_verdict(
    ewma_us: Option<f64>,
    predicted_us: Option<f64>,
    samples: usize,
) -> (Option<f64>, bool) {
    match (ewma_us, predicted_us) {
        (Some(e), Some(p)) if p > 0.0 => {
            let ratio = e / p;
            let retune = samples >= DRIFT_MIN_SAMPLES
                && !(DRIFT_RATIO_LOW..=DRIFT_RATIO_HIGH).contains(&ratio);
            (Some(ratio), retune)
        }
        _ => (None, false),
    }
}

/// Per-kernel share of a rollup's total kernel time.
#[derive(Clone, Debug)]
pub struct KernelStat {
    /// Kernel name (`"idle"` for steps a worker sat out).
    pub name: &'static str,
    /// Spans folded into this row.
    pub spans: usize,
    /// Summed span time across workers, microseconds.
    pub total_us: f64,
    /// `total_us` over the rollup's whole kernel time (0..=1).
    pub share: f64,
}

/// Barrier-wait skew of one TP group: the straggler gauge. Each
/// worker's group-barrier waits are summed over the window; `skew_us`
/// is the max−min across the group's workers — a large value means
/// one worker consistently arrives late (its peers burn that time
/// spinning), which is the measured case for intra-group work
/// stealing.
#[derive(Clone, Debug)]
pub struct GroupSkew {
    /// TP group id (`u32::MAX` aggregates the pool-global barrier).
    pub group: u32,
    /// Workers that recorded waits at this scope.
    pub workers: usize,
    /// Smallest per-worker summed wait, microseconds.
    pub min_wait_us: f64,
    /// Largest per-worker summed wait, microseconds.
    pub max_wait_us: f64,
    /// `max_wait_us - min_wait_us`.
    pub skew_us: f64,
}

/// Folded view of a span window (one pass, or everything collected):
/// per-kernel time share plus the per-group barrier-skew gauges.
#[derive(Clone, Debug, Default)]
pub struct PassRollup {
    /// Kernel spans folded (per pass: plan steps × pool workers).
    pub kernel_spans: usize,
    /// Barrier-wait spans folded.
    pub barrier_spans: usize,
    /// Per-kernel totals, largest share first.
    pub kernels: Vec<KernelStat>,
    /// Per-group barrier skew, group order (global barrier excluded —
    /// see `global_skew_us`).
    pub groups: Vec<GroupSkew>,
    /// Total barrier wait summed across all workers, microseconds.
    pub barrier_wait_us: f64,
    /// Max−min summed global-barrier wait across the pool's workers.
    pub global_skew_us: f64,
    /// The headline straggler gauge: the largest per-group skew, or
    /// the global skew when the window had no group barriers.
    pub skew_us: f64,
}

impl PassRollup {
    /// JSON shape shared by the metrics snapshot and the bench reports.
    pub fn to_json(&self) -> Json {
        let kernels = self
            .kernels
            .iter()
            .map(|k| {
                obj(vec![
                    ("name", k.name.into()),
                    ("spans", k.spans.into()),
                    ("total_us", k.total_us.into()),
                    ("share", k.share.into()),
                ])
            })
            .collect();
        let groups = self
            .groups
            .iter()
            .map(|g| {
                obj(vec![
                    ("group", (g.group as usize).into()),
                    ("workers", g.workers.into()),
                    ("min_wait_us", g.min_wait_us.into()),
                    ("max_wait_us", g.max_wait_us.into()),
                    ("skew_us", g.skew_us.into()),
                ])
            })
            .collect();
        obj(vec![
            ("kernel_spans", self.kernel_spans.into()),
            ("barrier_spans", self.barrier_spans.into()),
            ("barrier_wait_us", self.barrier_wait_us.into()),
            ("barrier_skew_us", self.skew_us.into()),
            ("global_skew_us", self.global_skew_us.into()),
            ("kernels", Json::Arr(kernels)),
            ("groups", Json::Arr(groups)),
        ])
    }
}

fn fold(spans: &[CollectedSpan]) -> PassRollup {
    let mut kernels: BTreeMap<&'static str, (usize, u64)> = BTreeMap::new();
    let mut waits: BTreeMap<(u32, u32), u64> = BTreeMap::new();
    let mut kernel_spans = 0;
    let mut barrier_spans = 0;
    for c in spans {
        match c.span.kind {
            SpanKind::Kernel => {
                kernel_spans += 1;
                let e = kernels.entry(c.span.name).or_insert((0, 0));
                e.0 += 1;
                e.1 += c.span.dur_ns;
            }
            SpanKind::Barrier => {
                barrier_spans += 1;
                *waits.entry((c.span.group, c.rank)).or_insert(0) += c.span.dur_ns;
            }
            SpanKind::Pass => {}
        }
    }
    let kernel_total: u64 = kernels.values().map(|&(_, ns)| ns).sum();
    let mut kernel_rows: Vec<KernelStat> = kernels
        .into_iter()
        .map(|(name, (spans, ns))| KernelStat {
            name,
            spans,
            total_us: ns as f64 / 1e3,
            share: if kernel_total > 0 { ns as f64 / kernel_total as f64 } else { 0.0 },
        })
        .collect();
    kernel_rows.sort_by(|a, b| b.total_us.total_cmp(&a.total_us));
    // per-scope worker wait sums → skew
    let mut scopes: BTreeMap<u32, Vec<u64>> = BTreeMap::new();
    for (&(group, _rank), &ns) in &waits {
        scopes.entry(group).or_default().push(ns);
    }
    let barrier_wait_us = waits.values().map(|&ns| ns as f64).sum::<f64>() / 1e3;
    let mut groups = Vec::new();
    let mut global_skew_us = 0.0;
    for (group, per_worker) in scopes {
        let min = per_worker.iter().copied().min().unwrap_or(0) as f64 / 1e3;
        let max = per_worker.iter().copied().max().unwrap_or(0) as f64 / 1e3;
        let skew = GroupSkew {
            group,
            workers: per_worker.len(),
            min_wait_us: min,
            max_wait_us: max,
            skew_us: max - min,
        };
        if group == u32::MAX {
            global_skew_us = skew.skew_us;
        } else {
            groups.push(skew);
        }
    }
    let group_skew = groups.iter().map(|g| g.skew_us).fold(0.0f64, f64::max);
    let skew_us = if groups.is_empty() { global_skew_us } else { group_skew };
    PassRollup {
        kernel_spans,
        barrier_spans,
        kernels: kernel_rows,
        groups,
        barrier_wait_us,
        global_skew_us,
        skew_us,
    }
}

/// Fold everything in the collected buffer (whole-run view for the
/// bench reports; per-pass rollups come from [`finish_pass`]).
pub fn global_rollup() -> PassRollup {
    fold(&COLLECTED.lock().unwrap())
}

/// One Chrome `trace_event` in the shared span schema: a complete
/// (`"ph": "X"`) event with microsecond `ts`/`dur`, `pid` = NUMA node,
/// `tid` = worker (or virtual lane). The simulator's virtual-time
/// trace emits through the same constructor, so sim and host traces
/// carry identical keys and diff cleanly.
pub fn chrome_event(
    name: &str,
    ts_us: f64,
    dur_us: f64,
    pid: usize,
    tid: usize,
    args: Vec<(&str, Json)>,
) -> Json {
    obj(vec![
        ("name", name.into()),
        ("ph", "X".into()),
        ("ts", ts_us.into()),
        ("dur", dur_us.into()),
        ("pid", pid.into()),
        ("tid", tid.into()),
        ("args", obj(args)),
    ])
}

/// Wrap events in the Chrome trace-file envelope.
pub fn chrome_doc(events: Vec<Json>) -> Json {
    obj(vec![("traceEvents", Json::Arr(events)), ("displayTimeUnit", "ms".into())])
}

fn kind_str(kind: SpanKind) -> &'static str {
    match kind {
        SpanKind::Pass => "pass",
        SpanKind::Kernel => "kernel",
        SpanKind::Barrier => "barrier",
    }
}

/// Serialize every collected span as Chrome `trace_event` JSON
/// (pid = NUMA node, tid = worker rank; the pass leader renders as tid
/// 1000000). Extra top-level keys (`collected_spans`, `dropped_spans`)
/// ride along — Perfetto ignores unknown keys.
pub fn chrome_json() -> String {
    let collected = COLLECTED.lock().unwrap();
    let mut events = Vec::with_capacity(collected.len());
    for c in collected.iter() {
        let s = &c.span;
        let tid = if c.rank == LEADER_RANK { 1_000_000 } else { c.rank as usize };
        let mut args: Vec<(&str, Json)> = vec![("kind", kind_str(s.kind).into())];
        if s.group != u32::MAX {
            args.push(("group", (s.group as usize).into()));
        }
        if s.kind == SpanKind::Kernel && s.entry != u32::MAX {
            args.push(("entry", (s.entry as usize).into()));
            args.push(("u0", (s.u0 as usize).into()));
            args.push(("u1", (s.u1 as usize).into()));
        }
        events.push(chrome_event(
            s.name,
            s.start_ns as f64 / 1e3,
            s.dur_ns as f64 / 1e3,
            c.node as usize,
            tid,
            args,
        ));
    }
    let mut doc = chrome_doc(events);
    if let Json::Obj(m) = &mut doc {
        m.insert("collected_spans".into(), collected.len().into());
        m.insert("dropped_spans".into(), (DROPPED.load(Ordering::Relaxed) as usize).into());
    }
    doc.to_string()
}

/// Write [`chrome_json`] to `path` (parent directories created).
pub fn export_chrome(path: &Path) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            let _ = std::fs::create_dir_all(parent);
        }
    }
    std::fs::write(path, chrome_json())?;
    Ok(())
}

/// Serializes tests that flip the process-global [`set_enabled`] flag
/// (the tracer is process-wide state; concurrent toggles would make
/// span-count assertions racy).
#[cfg(test)]
pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    fn span(entry: u32, dur_ns: u64) -> Span {
        Span {
            kind: SpanKind::Kernel,
            name: "k",
            start_ns: 0,
            dur_ns,
            group: u32::MAX,
            entry,
            u0: 0,
            u1: 1,
        }
    }

    #[test]
    fn ring_wraparound_keeps_the_newest_spans() {
        let r = Ring::new(8);
        for i in 0..20u32 {
            r.push(span(i, i as u64));
        }
        let mut out = Vec::new();
        let lost = r.drain(&mut out);
        assert_eq!(lost, 12, "20 pushed into capacity 8 → 12 overwritten");
        let entries: Vec<u32> = out.iter().map(|s| s.entry).collect();
        assert_eq!(entries, (12..20).collect::<Vec<u32>>(), "newest spans, oldest first");
        // nothing new since the drain
        let mut out2 = Vec::new();
        assert_eq!(r.drain(&mut out2), 0);
        assert!(out2.is_empty());
        // fresh pushes drain incrementally
        r.push(span(99, 1));
        let mut out3 = Vec::new();
        assert_eq!(r.drain(&mut out3), 0);
        assert_eq!(out3.len(), 1);
        assert_eq!(out3[0].entry, 99);
    }

    #[test]
    fn fold_computes_shares_and_group_skew() {
        let mk = |rank: u32, kind: SpanKind, name: &'static str, group: u32, dur_ns: u64| {
            CollectedSpan {
                rank,
                node: 0,
                span: Span { kind, name, start_ns: 0, dur_ns, group, entry: 0, u0: 0, u1: 0 },
            }
        };
        let spans = vec![
            mk(0, SpanKind::Kernel, "matmul", 0, 3_000),
            mk(1, SpanKind::Kernel, "matmul", 0, 3_000),
            mk(0, SpanKind::Kernel, "rmsnorm", 0, 2_000),
            mk(1, SpanKind::Kernel, "rmsnorm", 0, 2_000),
            // group 0: worker 0 waits 5 µs, worker 1 waits 1 µs → skew 4
            mk(0, SpanKind::Barrier, "barrier.group", 0, 5_000),
            mk(1, SpanKind::Barrier, "barrier.group", 0, 1_000),
            // global barrier: both wait 2 µs → skew 0
            mk(0, SpanKind::Barrier, "barrier.global", u32::MAX, 2_000),
            mk(1, SpanKind::Barrier, "barrier.global", u32::MAX, 2_000),
        ];
        let r = fold(&spans);
        assert_eq!(r.kernel_spans, 4);
        assert_eq!(r.barrier_spans, 4);
        assert_eq!(r.kernels[0].name, "matmul", "largest share first");
        assert!((r.kernels[0].share - 0.6).abs() < 1e-9);
        assert!((r.kernels[1].share - 0.4).abs() < 1e-9);
        assert_eq!(r.groups.len(), 1);
        assert!((r.groups[0].skew_us - 4.0).abs() < 1e-9);
        assert_eq!(r.groups[0].workers, 2);
        assert!((r.global_skew_us - 0.0).abs() < 1e-9);
        assert!((r.skew_us - 4.0).abs() < 1e-9, "headline gauge is the worst group");
        assert!((r.barrier_wait_us - 10.0).abs() < 1e-9);
        // the JSON shape the metrics snapshot embeds
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("kernel_spans").unwrap().as_usize(), Some(4));
        assert!(j.get("barrier_skew_us").unwrap().as_f64().unwrap() > 3.9);
        assert_eq!(j.get("kernels").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn fold_without_groups_falls_back_to_global_skew() {
        let spans = vec![
            CollectedSpan {
                rank: 0,
                node: 0,
                span: Span {
                    kind: SpanKind::Barrier,
                    name: "barrier.global",
                    start_ns: 0,
                    dur_ns: 7_000,
                    group: u32::MAX,
                    entry: u32::MAX,
                    u0: 0,
                    u1: 0,
                },
            },
            CollectedSpan {
                rank: 1,
                node: 0,
                span: Span {
                    kind: SpanKind::Barrier,
                    name: "barrier.global",
                    start_ns: 0,
                    dur_ns: 1_000,
                    group: u32::MAX,
                    entry: u32::MAX,
                    u0: 0,
                    u1: 0,
                },
            },
        ];
        let r = fold(&spans);
        assert!(r.groups.is_empty());
        assert!((r.global_skew_us - 6.0).abs() < 1e-9);
        assert!((r.skew_us - 6.0).abs() < 1e-9);
    }

    #[test]
    fn chrome_event_schema_has_the_required_keys() {
        let ev = chrome_event("matmul", 12.5, 3.25, 1, 4, vec![("entry", 7usize.into())]);
        let j = Json::parse(&ev.to_string()).unwrap();
        assert_eq!(j.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(j.get("ts").unwrap().as_f64(), Some(12.5));
        assert_eq!(j.get("dur").unwrap().as_f64(), Some(3.25));
        assert_eq!(j.get("pid").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("tid").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("args").unwrap().get("entry").unwrap().as_usize(), Some(7));
        let doc = chrome_doc(vec![ev]);
        let d = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(d.get("traceEvents").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn drift_verdict_needs_both_sides_samples_and_band_exit() {
        assert_eq!(drift_verdict(None, Some(100.0), 20), (None, false));
        assert_eq!(drift_verdict(Some(110.0), None, 20), (None, false));
        let (r, retune) = drift_verdict(Some(110.0), Some(100.0), 20);
        assert!((r.unwrap() - 1.1).abs() < 1e-9);
        assert!(!retune, "inside the band");
        let (_, retune) = drift_verdict(Some(250.0), Some(100.0), DRIFT_MIN_SAMPLES - 1);
        assert!(!retune, "a cold EWMA never recommends");
        let (r, retune) = drift_verdict(Some(250.0), Some(100.0), DRIFT_MIN_SAMPLES);
        assert!(retune && r.unwrap() > 2.0, "synthetic slowdown flips the flag");
        let (_, retune) = drift_verdict(Some(50.0), Some(100.0), 20);
        assert!(retune, "much faster than predicted is drift too");
        let mut e = None;
        for _ in 0..50 {
            e = Some(ewma_fold(e, 250.0));
        }
        assert!((e.unwrap() - 250.0).abs() < 1.0, "EWMA converges to the plateau");
    }

    #[test]
    fn disabled_by_default_and_toggles() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert!(!enabled(), "tracing must be off by default");
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
    }
}
