//! NUMA platform model (paper §3.1, Table 1).
//!
//! The paper's testbed is a 192-core, 4-node Kunpeng-920 server. This
//! environment has neither NUMA nor 192 cores, so the many-core platform
//! is a *deterministic simulator* (DESIGN.md "Hardware substitution"):
//!
//! * [`topology::Topology`] — nodes × cores plus the core→memory
//!   bandwidth matrix measured in the paper's Table 1;
//! * [`placement::Placement`] — which node owns each byte of a tensor
//!   (node-local, OS-interleaved, or row-sharded — first-touch and TP
//!   both resolve to row shards);
//! * [`cost::CostModel`] — charges each worker's per-op memory traffic
//!   against the bandwidth matrix (with per-channel contention) and its
//!   flops against the core's compute rate, yielding *virtual time*.
//!
//! The real-execution engine uses the same placements for arena tagging
//! but measures wall-clock; the simulator uses virtual time. All
//! strategy comparisons (ArcLight vs llama.cpp, Sync A vs Sync B) run
//! through identical graph/partition code and differ only in placement
//! and synchronization — exactly the paper's experimental variable.

pub mod cost;
pub mod placement;
pub mod topology;

pub use cost::CostModel;
pub use placement::Placement;
pub use topology::{BandwidthSource, Topology};

/// Identifier of a NUMA node (0-based).
pub type NodeId = usize;

/// A simulated core: global id plus its home node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Core {
    pub id: usize,
    pub node: NodeId,
}
