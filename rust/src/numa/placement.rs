//! Tensor→NUMA-node placement (paper §2.3, §3.1, Fig. 3 & 7).
//!
//! ArcLight binds every buffer to an explicit node ("separate buffers in
//! the local memory of each NUMA node"); llama.cpp's UMA buffer leaves
//! placement to the OS, which the paper models as first-touch /
//! page-interleaved. Both strategies reduce to one of these variants,
//! and the cost model only ever asks one question: *for a row range of
//! this tensor, how many bytes live on each node?*

use super::NodeId;

/// Where the bytes of a tensor live.
#[derive(Clone, Debug, PartialEq)]
pub enum Placement {
    /// Entire tensor in one node's local memory (ArcLight's default:
    /// tensors are bound to the node whose threads consume them).
    Node(NodeId),
    /// Pages spread evenly over the first `n` nodes (the OS-managed UMA
    /// buffer of llama.cpp under `-numa distribute`, or `numactl
    /// --interleave`). Page granularity is far below row granularity for
    /// LLM weights, so an even byte split is an accurate model.
    Interleaved(usize),
    /// Contiguous row ranges owned by different nodes — what first-touch
    /// produces when a partitioned operator touches its own slice first
    /// (llama.cpp weights, Fig. 7) and what TP produces by construction.
    /// Entries are `(first_row, end_row, node)` sorted by `first_row`,
    /// covering all rows exactly once.
    RowShards(Vec<(usize, usize, NodeId)>),
}

impl Placement {
    /// Even row-sharding of `rows` across `nodes` nodes (node ids 0..n).
    pub fn even_shards(rows: usize, nodes: usize) -> Placement {
        let mut shards = Vec::with_capacity(nodes);
        for n in 0..nodes {
            let (s, e) = crate::util::chunk_range(rows, nodes, n);
            if e > s {
                shards.push((s, e, n));
            }
        }
        Placement::RowShards(shards)
    }

    /// Bytes read from each node when a reader scans rows `[r0, r1)` of a
    /// tensor with `rows` total rows and `row_bytes` bytes per row.
    /// Returns a small vec of `(node, bytes)`.
    pub fn bytes_by_node(
        &self,
        r0: usize,
        r1: usize,
        rows: usize,
        row_bytes: f64,
        n_nodes: usize,
    ) -> Vec<(NodeId, f64)> {
        debug_assert!(r0 <= r1 && r1 <= rows.max(1));
        let span = (r1 - r0) as f64;
        match self {
            Placement::Node(n) => vec![(*n, span * row_bytes)],
            Placement::Interleaved(nn) => {
                let nn = (*nn).max(1).min(n_nodes);
                let per = span * row_bytes / nn as f64;
                (0..nn).map(|n| (n, per)).collect()
            }
            Placement::RowShards(shards) => {
                let mut out: Vec<(NodeId, f64)> = Vec::new();
                for &(s, e, node) in shards {
                    let lo = r0.max(s);
                    let hi = r1.min(e);
                    if hi > lo {
                        let b = (hi - lo) as f64 * row_bytes;
                        if let Some(entry) = out.iter_mut().find(|(n, _)| *n == node) {
                            entry.1 += b;
                        } else {
                            out.push((node, b));
                        }
                    }
                }
                out
            }
        }
    }

    /// Distribute `total_bytes` of reads across nodes proportionally to
    /// how much of the tensor each node holds — used for accesses that
    /// are not row-aligned (column stripes, random-row gathers).
    pub fn spread_bytes(&self, total_bytes: f64, n_nodes: usize) -> Vec<(NodeId, f64)> {
        match self {
            Placement::Node(n) => vec![(*n, total_bytes)],
            Placement::Interleaved(nn) => {
                let nn = (*nn).max(1).min(n_nodes);
                let per = total_bytes / nn as f64;
                (0..nn).map(|n| (n, per)).collect()
            }
            Placement::RowShards(shards) => {
                let total_rows: usize = shards.iter().map(|&(s, e, _)| e - s).sum();
                if total_rows == 0 {
                    return vec![(0, total_bytes)];
                }
                let mut out: Vec<(NodeId, f64)> = Vec::new();
                for &(s, e, node) in shards {
                    let b = total_bytes * (e - s) as f64 / total_rows as f64;
                    if let Some(entry) = out.iter_mut().find(|(n, _)| *n == node) {
                        entry.1 += b;
                    } else {
                        out.push((node, b));
                    }
                }
                out
            }
        }
    }

    /// The node owning row `r` (Interleaved → the node of the page the
    /// row's first byte falls on, approximated round-robin by row).
    pub fn node_of_row(&self, r: usize, n_nodes: usize) -> NodeId {
        match self {
            Placement::Node(n) => *n,
            Placement::Interleaved(nn) => r % (*nn).max(1).min(n_nodes),
            Placement::RowShards(shards) => shards
                .iter()
                .find(|&&(s, e, _)| r >= s && r < e)
                .map(|&(_, _, n)| n)
                .unwrap_or(0),
        }
    }

    /// True when every byte a reader on `node` touches is node-local —
    /// the property ArcLight's TP establishes (§3.2: "effectively
    /// isolating cross-node memory access").
    pub fn is_local_for(&self, node: NodeId, r0: usize, r1: usize) -> bool {
        match self {
            Placement::Node(n) => *n == node,
            Placement::Interleaved(nn) => *nn == 1 && node == 0,
            Placement::RowShards(shards) => shards
                .iter()
                .filter(|&&(s, e, _)| e > r0 && s < r1)
                .all(|&(_, _, n)| n == node),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_placement_all_local() {
        let p = Placement::Node(2);
        let b = p.bytes_by_node(0, 10, 10, 4.0, 4);
        assert_eq!(b, vec![(2, 40.0)]);
        assert!(p.is_local_for(2, 0, 10));
        assert!(!p.is_local_for(0, 0, 10));
    }

    #[test]
    fn interleaved_splits_evenly() {
        let p = Placement::Interleaved(4);
        let b = p.bytes_by_node(0, 8, 8, 2.0, 4);
        assert_eq!(b.len(), 4);
        for (_, bytes) in &b {
            assert_eq!(*bytes, 4.0);
        }
    }

    #[test]
    fn even_shards_cover_rows() {
        let p = Placement::even_shards(10, 4);
        if let Placement::RowShards(s) = &p {
            assert_eq!(s.len(), 4);
            assert_eq!(s[0], (0, 3, 0));
            assert_eq!(s[3], (8, 10, 3));
        } else {
            panic!();
        }
        // reading rows 2..9 hits nodes 0..=3
        let b = p.bytes_by_node(2, 9, 10, 1.0, 4);
        let total: f64 = b.iter().map(|(_, x)| x).sum();
        assert_eq!(total, 7.0);
    }

    #[test]
    fn shard_locality_check() {
        let p = Placement::even_shards(8, 2); // rows 0-3 node0, 4-7 node1
        assert!(p.is_local_for(0, 0, 4));
        assert!(p.is_local_for(1, 4, 8));
        assert!(!p.is_local_for(0, 0, 8));
        assert_eq!(p.node_of_row(5, 2), 1);
    }

    #[test]
    fn partial_shard_overlap_accumulates() {
        let p = Placement::RowShards(vec![(0, 4, 1), (4, 8, 1), (8, 12, 0)]);
        let b = p.bytes_by_node(2, 10, 12, 1.0, 2);
        let mut node1 = 0.0;
        let mut node0 = 0.0;
        for (n, x) in b {
            if n == 1 {
                node1 += x;
            } else {
                node0 += x;
            }
        }
        assert_eq!(node1, 6.0);
        assert_eq!(node0, 2.0);
    }
}
