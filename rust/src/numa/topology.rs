//! Simulated machine topology + the paper's measured bandwidth matrix.

use super::{Core, NodeId};

/// The 4×4 core→memory bandwidth matrix (GB/s) the paper measures on its
/// Kunpeng-920 testbed (Table 1). Local access ≈ 4× remote.
pub const KUNPENG920_BW: [[f64; 4]; 4] = [
    [102.0, 26.0, 24.0, 23.0],
    [26.0, 103.0, 23.0, 22.0],
    [24.0, 23.0, 103.0, 26.0],
    [23.0, 22.0, 26.0, 101.0],
];

/// Where a topology's bandwidth matrix came from — carried end-to-end
/// so roofline fractions and strategy choices are never silently
/// computed against the 100 GB/s placeholder scale.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BandwidthSource {
    /// Streamed per node pair on the live machine (`hw::bench`).
    Measured,
    /// SLIT-distance ratios × the `DEFAULT_LOCAL_GB` placeholder scale
    /// (`hw::topology::HostTopology::to_topology`) — ratios are real,
    /// the absolute numbers are not.
    SlitPlaceholder,
    /// A hand-written testbed matrix (the paper's Table 1, `uniform`,
    /// or an explicit test matrix).
    #[default]
    Simulated,
}

impl BandwidthSource {
    /// Stable string used in metrics and bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            BandwidthSource::Measured => "measured",
            BandwidthSource::SlitPlaceholder => "slit-placeholder",
            BandwidthSource::Simulated => "simulated",
        }
    }
}

/// Description of a simulated many-core NUMA machine.
#[derive(Clone, Debug)]
pub struct Topology {
    /// Bandwidth matrix in bytes/second: `bw[core_node][mem_node]` is the
    /// *aggregate* bandwidth available to all cores of `core_node`
    /// accessing memory on `mem_node` (shared under contention).
    pub bw: Vec<Vec<f64>>,
    /// Cores per node.
    pub cores_per_node: usize,
    /// Per-core sustained f32 compute rate (FLOP/s). Kunpeng-920 @2.6 GHz
    /// with 128-bit NEON FMA ≈ 2.6e9 × 8 ≈ 20 GFLOP/s; we derate to a
    /// sustained 16 GFLOP/s.
    pub core_flops: f64,
    /// Per-core streaming-bandwidth cap (bytes/s): one core cannot keep
    /// a node's six DDR4 channels busy (limited load/store queues and
    /// MLP), so aggregate bandwidth scales with threads until the node
    /// saturates — the rising part of the paper's Fig. 10.
    pub core_mem_bw: f64,
    /// Base cost of a barrier among threads of a single node (seconds).
    pub barrier_local: f64,
    /// Additional barrier cost per extra participating node (seconds) —
    /// cross-node cacheline ping-pong is the paper's "data
    /// synchronization overhead".
    pub barrier_per_node: f64,
    /// Per-thread increment of barrier cost (seconds) — linear fan-in.
    pub barrier_per_thread: f64,
    /// Fixed per-operator software overhead on every participating
    /// worker (dispatch, work assignment, first-touch cache warmup).
    /// Calibrated so absolute decode throughput lands in the regime the
    /// paper reports (~tens of tok/s on the 4B model).
    pub op_dispatch: f64,
    /// Amortization factor for broadcast reads in the single-row decode
    /// GEMV (many cores pulling the same small activation vector):
    /// partial dedup via shared caches. 1.0 = every core pays the full
    /// stream; calibrated against the paper's measured llama.cpp
    /// cross-NUMA penalty (§3.1/Fig. 7).
    pub bcast_amort: f64,
    /// Multiplicative load-imbalance jitter amplitude (deterministic,
    /// hash-seeded): worker op time *= 1 + U(-j, +j).
    pub jitter: f64,
    /// Seed for the deterministic jitter hash.
    pub jitter_seed: u64,
    /// Provenance of `bw` (measured, SLIT placeholder, or simulated).
    pub bw_source: BandwidthSource,
}

impl Topology {
    /// The paper's testbed: 4 nodes × 48 Kunpeng-920 cores, Table-1
    /// bandwidth matrix.
    pub fn kunpeng920() -> Self {
        Topology {
            bw: KUNPENG920_BW
                .iter()
                .map(|row| row.iter().map(|gb| gb * 1e9).collect())
                .collect(),
            cores_per_node: 48,
            core_flops: 16e9,
            // 102 GB/s node bandwidth saturates at ~40 cores
            core_mem_bw: 2.6e9,
            barrier_local: 1.2e-6,
            barrier_per_node: 2.0e-6,
            barrier_per_thread: 6.0e-9,
            op_dispatch: 12.0e-6,
            bcast_amort: 1.5,
            jitter: 0.04,
            jitter_seed: 0x5eed,
            bw_source: BandwidthSource::Simulated,
        }
    }

    /// A uniform synthetic machine: `nodes` NUMA nodes, `cores_per_node`
    /// cores, `local_gb`/`remote_gb` GB/s bandwidths.
    pub fn uniform(nodes: usize, cores_per_node: usize, local_gb: f64, remote_gb: f64) -> Self {
        let bw = (0..nodes)
            .map(|i| {
                (0..nodes)
                    .map(|j| if i == j { local_gb * 1e9 } else { remote_gb * 1e9 })
                    .collect()
            })
            .collect();
        Topology { bw, ..Topology::kunpeng920() }
            .with_cores_per_node(cores_per_node)
    }

    /// A topology from an explicit bandwidth matrix (GB/s). Cost-model
    /// calibration constants (compute rates, barrier costs, dispatch
    /// overhead) inherit the Kunpeng-920 defaults — this is how
    /// [`crate::hw::topology::HostTopology::to_topology`] lowers a
    /// detected machine into the model.
    pub fn from_bandwidth_gb(bw_gb: Vec<Vec<f64>>, cores_per_node: usize) -> Self {
        let n = bw_gb.len();
        assert!(n > 0, "bandwidth matrix needs at least one node");
        assert!(bw_gb.iter().all(|row| row.len() == n), "bandwidth matrix must be square");
        let bw = bw_gb.iter().map(|row| row.iter().map(|gb| gb * 1e9).collect()).collect();
        Topology { bw, ..Topology::kunpeng920() }.with_cores_per_node(cores_per_node)
    }

    pub fn with_cores_per_node(mut self, c: usize) -> Self {
        self.cores_per_node = c;
        self
    }

    /// Tag the bandwidth matrix's provenance (builder form, used by the
    /// `hw::topology` lowerings).
    pub fn with_bw_source(mut self, src: BandwidthSource) -> Self {
        self.bw_source = src;
        self
    }

    pub fn n_nodes(&self) -> usize {
        self.bw.len()
    }

    pub fn n_cores(&self) -> usize {
        self.n_nodes() * self.cores_per_node
    }

    pub fn node_of_core(&self, core: usize) -> NodeId {
        core / self.cores_per_node
    }

    pub fn core(&self, id: usize) -> Core {
        Core { id, node: self.node_of_core(id) }
    }

    /// Aggregate bandwidth (bytes/s) from cores of `cn` to memory of `mn`.
    pub fn bandwidth(&self, cn: NodeId, mn: NodeId) -> f64 {
        self.bw[cn][mn]
    }

    /// Cost of one barrier over `threads` threads spanning `nodes` nodes.
    pub fn barrier_cost(&self, threads: usize, nodes: usize) -> f64 {
        if threads <= 1 {
            return 0.0;
        }
        self.barrier_local
            + self.barrier_per_thread * threads as f64
            + self.barrier_per_node * nodes.saturating_sub(1) as f64
    }

    /// The cores of one node, in id order.
    pub fn cores_of_node(&self, node: NodeId) -> impl Iterator<Item = Core> + '_ {
        let base = node * self.cores_per_node;
        (base..base + self.cores_per_node).map(move |id| Core { id, node })
    }

    /// Pick `n` cores bound like llama.cpp's `-numa isolate` (fill node
    /// 0 first) or `distribute` (round-robin across nodes, as the paper
    /// describes llama.cpp's even thread binding).
    pub fn bind_cores(&self, n: usize, distribute: bool, n_nodes: usize) -> Vec<Core> {
        self.bind_cores_at(0, n, distribute, n_nodes)
    }

    /// [`Topology::bind_cores`] with the node window shifted to start at
    /// `base` — how a cluster replica binds its workers onto *its* node
    /// group instead of every engine stacking onto node 0.
    pub fn bind_cores_at(
        &self,
        base: usize,
        n: usize,
        distribute: bool,
        n_nodes: usize,
    ) -> Vec<Core> {
        assert!(base < self.n_nodes(), "base node {base} outside the machine");
        let nodes = n_nodes.min(self.n_nodes() - base).max(1);
        let mut out = Vec::with_capacity(n);
        if distribute {
            // equal share per node, contiguous inside each node
            for g in 0..nodes {
                let node = base + g;
                let (s, e) = crate::util::chunk_range(n, nodes, g);
                for i in 0..(e - s) {
                    out.push(Core { id: node * self.cores_per_node + i, node });
                }
            }
        } else {
            let first = base * self.cores_per_node;
            for i in 0..n {
                let id = first + i;
                assert!(id < self.n_cores(), "not enough cores");
                out.push(self.core(id));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kunpeng_matches_table1() {
        let t = Topology::kunpeng920();
        assert_eq!(t.n_nodes(), 4);
        assert_eq!(t.n_cores(), 192);
        assert_eq!(t.bandwidth(0, 0), 102e9);
        assert_eq!(t.bandwidth(1, 3), 22e9);
        // local ≈ 4× remote, the paper's headline observation
        let local = t.bandwidth(2, 2);
        let remote = t.bandwidth(2, 1);
        assert!(local / remote > 3.5 && local / remote < 5.0);
    }

    #[test]
    fn core_to_node_mapping() {
        let t = Topology::kunpeng920();
        assert_eq!(t.node_of_core(0), 0);
        assert_eq!(t.node_of_core(47), 0);
        assert_eq!(t.node_of_core(48), 1);
        assert_eq!(t.node_of_core(191), 3);
    }

    #[test]
    fn barrier_scales_with_span() {
        let t = Topology::kunpeng920();
        let one_node = t.barrier_cost(48, 1);
        let four_nodes = t.barrier_cost(192, 4);
        assert!(four_nodes > one_node * 2.0, "{four_nodes} vs {one_node}");
        assert_eq!(t.barrier_cost(1, 1), 0.0);
    }

    #[test]
    fn isolate_binding_fills_node0() {
        let t = Topology::kunpeng920();
        let cores = t.bind_cores(48, false, 1);
        assert!(cores.iter().all(|c| c.node == 0));
        assert_eq!(cores.len(), 48);
    }

    #[test]
    fn distribute_binding_spreads_evenly() {
        let t = Topology::kunpeng920();
        let cores = t.bind_cores(64, true, 4);
        for node in 0..4 {
            assert_eq!(cores.iter().filter(|c| c.node == node).count(), 16);
        }
        let cores2 = t.bind_cores(96, true, 2);
        assert_eq!(cores2.iter().filter(|c| c.node == 0).count(), 48);
        assert_eq!(cores2.iter().filter(|c| c.node == 1).count(), 48);
    }

    #[test]
    fn explicit_bandwidth_matrix() {
        let t = Topology::from_bandwidth_gb(vec![vec![90.0, 45.0], vec![45.0, 90.0]], 12);
        assert_eq!(t.n_nodes(), 2);
        assert_eq!(t.n_cores(), 24);
        assert_eq!(t.bandwidth(0, 1), 45e9);
        // calibration constants come from the Kunpeng-920 defaults
        assert_eq!(t.core_flops, Topology::kunpeng920().core_flops);
    }

    #[test]
    fn bandwidth_source_defaults_to_simulated() {
        assert_eq!(Topology::kunpeng920().bw_source, BandwidthSource::Simulated);
        assert_eq!(Topology::uniform(2, 4, 100.0, 25.0).bw_source, BandwidthSource::Simulated);
        let t = Topology::from_bandwidth_gb(vec![vec![90.0]], 4)
            .with_bw_source(BandwidthSource::Measured);
        assert_eq!(t.bw_source, BandwidthSource::Measured);
        assert_eq!(t.bw_source.name(), "measured");
        assert_eq!(BandwidthSource::SlitPlaceholder.name(), "slit-placeholder");
        assert_eq!(BandwidthSource::Simulated.name(), "simulated");
    }

    #[test]
    fn uniform_topology() {
        let t = Topology::uniform(2, 8, 100.0, 25.0);
        assert_eq!(t.n_cores(), 16);
        assert_eq!(t.bandwidth(0, 1), 25e9);
        assert_eq!(t.bandwidth(1, 1), 100e9);
    }
}
