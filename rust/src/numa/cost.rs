//! Virtual-time cost model (DESIGN.md "Hardware substitution").
//!
//! The simulator charges every worker's per-operator traffic against the
//! Table-1 bandwidth matrix with *channel contention*: all workers of
//! core-node `cn` reading memory-node `mn` during one operator share the
//! aggregate `bw[cn][mn]`. Compute overlaps with memory (roofline): an
//! operator's worker time is `max(compute, memory) + dispatch`, times a
//! deterministic load-imbalance jitter.

use super::topology::Topology;
use crate::util::rng::unit_hash;

/// One worker's resource demands for one operator execution.
#[derive(Clone, Debug, Default)]
pub struct Traffic {
    /// f32 FLOPs this worker executes.
    pub flops: f64,
    /// Bytes this worker reads/writes, keyed by the memory node they
    /// live on: `bytes[mem_node]`.
    pub bytes: Vec<f64>,
}

impl Traffic {
    pub fn new(n_nodes: usize) -> Self {
        Traffic { flops: 0.0, bytes: vec![0.0; n_nodes] }
    }

    pub fn add_bytes(&mut self, node: usize, bytes: f64) {
        self.bytes[node] += bytes;
    }

    pub fn add_placed(
        &mut self,
        placement: &super::Placement,
        r0: usize,
        r1: usize,
        rows: usize,
        row_bytes: f64,
    ) {
        for (node, b) in placement.bytes_by_node(r0, r1, rows, row_bytes, self.bytes.len()) {
            self.bytes[node] += b;
        }
    }

    pub fn total_bytes(&self) -> f64 {
        self.bytes.iter().sum()
    }
}

/// The cost model: a thin wrapper over [`Topology`] that turns a set of
/// per-worker [`Traffic`]s into per-worker virtual seconds.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub topo: Topology,
}

impl CostModel {
    pub fn new(topo: Topology) -> Self {
        CostModel { topo }
    }

    pub fn n_nodes(&self) -> usize {
        self.topo.n_nodes()
    }

    /// Virtual seconds each worker spends on one operator.
    ///
    /// `workers[i] = (core_id, traffic)`. `op_tag` seeds the per-(op,
    /// worker) jitter so runs are reproducible.
    pub fn op_times(&self, workers: &[(usize, Traffic)], op_tag: u64) -> Vec<f64> {
        let nn = self.n_nodes();
        // sharers[cn][mn] = number of workers on core-node cn with
        // traffic to mem-node mn during this operator
        let mut sharers = vec![vec![0usize; nn]; nn];
        for (core, t) in workers {
            let cn = self.topo.node_of_core(*core);
            for (mn, b) in t.bytes.iter().enumerate() {
                if *b > 0.0 {
                    sharers[cn][mn] += 1;
                }
            }
        }
        workers
            .iter()
            .map(|(core, t)| {
                let cn = self.topo.node_of_core(*core);
                let mut mem = 0.0;
                for (mn, b) in t.bytes.iter().enumerate() {
                    if *b > 0.0 {
                        let share = self.topo.bandwidth(cn, mn) / sharers[cn][mn] as f64;
                        mem += b / share;
                    }
                }
                // a single core cannot exceed its own streaming rate
                mem = mem.max(t.total_bytes() / self.topo.core_mem_bw);
                let compute = t.flops / self.topo.core_flops;
                let base = mem.max(compute) + self.topo.op_dispatch;
                let j = self.topo.jitter;
                let u = unit_hash(self.topo.jitter_seed, op_tag, *core as u64);
                base * (1.0 + j * (2.0 * u - 1.0))
            })
            .collect()
    }

    /// Effective streaming bandwidth (bytes/s) seen by `readers` cores of
    /// node `cn` all scanning buffers on node `mn` — the Table-1
    /// microbenchmark regenerator uses this directly.
    pub fn streaming_bandwidth(&self, cn: usize, mn: usize, readers: usize) -> f64 {
        let per = self.topo.bandwidth(cn, mn) / readers as f64;
        per * readers as f64 // aggregate: contention cancels for the aggregate number
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Placement;

    fn model() -> CostModel {
        CostModel::new(Topology::kunpeng920())
    }

    fn no_jitter() -> CostModel {
        let mut t = Topology::kunpeng920();
        t.jitter = 0.0;
        t.op_dispatch = 0.0;
        t.core_mem_bw = f64::INFINITY; // isolate channel effects
        CostModel::new(t)
    }

    #[test]
    fn per_core_cap_limits_a_lone_reader() {
        let mut topo = Topology::kunpeng920();
        topo.jitter = 0.0;
        topo.op_dispatch = 0.0;
        let m = CostModel::new(topo);
        let mut t = Traffic::new(4);
        t.add_bytes(0, 2.6e9); // one second at the per-core cap
        let out = m.op_times(&[(0, t)], 0)[0];
        assert!((out - 1.0).abs() < 1e-9, "lone reader should be core-capped: {out}");
    }

    #[test]
    fn local_faster_than_remote() {
        let m = no_jitter();
        let mut local = Traffic::new(4);
        local.add_bytes(0, 1e9);
        let mut remote = Traffic::new(4);
        remote.add_bytes(1, 1e9);
        let t = m.op_times(&[(0, local), (1, remote)], 0);
        // worker 0 reads node0 local (102 GB/s), worker 1 on node1 reads
        // node1... wait that's local too; use core 0 for both
        let mut remote2 = Traffic::new(4);
        remote2.add_bytes(1, 1e9);
        let t2 = m.op_times(&[(0, remote2)], 0);
        assert!(t[0] < t2[0], "local {} remote {}", t[0], t2[0]);
        assert!((t2[0] / t[0] - 102.0 / 26.0).abs() < 0.05);
    }

    #[test]
    fn contention_shares_channel() {
        let m = no_jitter();
        // 2 workers on node 0 both reading node 0: each sees half bw
        let mk = || {
            let mut t = Traffic::new(4);
            t.add_bytes(0, 1e9);
            t
        };
        let solo = m.op_times(&[(0, mk())], 0)[0];
        let duo = m.op_times(&[(0, mk()), (1, mk())], 0)[0];
        assert!((duo / solo - 2.0).abs() < 1e-9);
    }

    #[test]
    fn compute_memory_roofline() {
        let m = no_jitter();
        let mut t = Traffic::new(4);
        t.add_bytes(0, 102e9 * 0.001); // 1 ms of memory
        t.flops = 16e9 * 0.002; // 2 ms of compute
        let out = m.op_times(&[(0, t)], 0)[0];
        assert!((out - 0.002).abs() < 1e-9, "compute-bound op should take 2 ms, got {out}");
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let m = model();
        let mk = || {
            let mut t = Traffic::new(4);
            t.add_bytes(0, 1e8);
            t
        };
        let a = m.op_times(&[(0, mk()), (1, mk())], 7);
        let b = m.op_times(&[(0, mk()), (1, mk())], 7);
        assert_eq!(a, b);
        let c = m.op_times(&[(0, mk()), (1, mk())], 8);
        assert_ne!(a, c);
        // bounded by ±jitter (same model minus jitter/dispatch)
        let mut topo = Topology::kunpeng920();
        topo.jitter = 0.0;
        topo.op_dispatch = 0.0;
        let base = CostModel::new(topo).op_times(&[(0, mk()), (1, mk())], 7)[0];
        assert!((a[0] - base).abs() / base <= 0.041);
    }

    #[test]
    fn placed_traffic_resolves_shards() {
        let mut t = Traffic::new(4);
        let p = Placement::even_shards(8, 4);
        t.add_placed(&p, 0, 8, 8, 10.0, );
        assert_eq!(t.bytes, vec![20.0, 20.0, 20.0, 20.0]);
    }

    #[test]
    fn cross_numa_wall_factor() {
        // The paper's core observation: a worker whose activation reads
        // are 3/4 remote is far slower than one reading locally.
        let m = no_jitter();
        let mut mixed = Traffic::new(4);
        for n in 0..4 {
            mixed.add_bytes(n, 0.25e9);
        }
        let mut local = Traffic::new(4);
        local.add_bytes(0, 1e9);
        let tm = m.op_times(&[(0, mixed)], 0)[0];
        let tl = m.op_times(&[(0, local)], 0)[0];
        assert!(tm / tl > 2.5, "mixed {} local {}", tm, tl);
    }
}
