//! Wire types for the serving API (line-delimited JSON).

use crate::util::json::{obj, Json};

/// A generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct GenRequest {
    pub id: u64,
    /// Either raw text (byte-tokenized server-side) …
    pub prompt: Option<String>,
    /// … or pre-tokenized ids.
    pub tokens: Option<Vec<i32>>,
    pub max_new: usize,
    /// `None` → greedy (the paper's benchmark setting).
    pub top_k: Option<usize>,
    pub temperature: f32,
}

impl GenRequest {
    pub fn text(id: u64, prompt: &str, max_new: usize) -> Self {
        GenRequest {
            id,
            prompt: Some(prompt.to_string()),
            tokens: None,
            max_new,
            top_k: None,
            temperature: 1.0,
        }
    }

    pub fn from_json(j: &Json) -> Result<GenRequest, String> {
        let max_new = j.get("max_new").and_then(Json::as_usize).unwrap_or(32);
        let prompt = j.get("prompt").and_then(Json::as_str).map(str::to_string);
        let tokens = j.get("tokens").and_then(Json::as_arr).map(|a| {
            a.iter().filter_map(Json::as_f64).map(|x| x as i32).collect::<Vec<i32>>()
        });
        if prompt.is_none() && tokens.is_none() {
            return Err("request needs 'prompt' or 'tokens'".into());
        }
        Ok(GenRequest {
            id: j.get("id").and_then(Json::as_usize).unwrap_or(0) as u64,
            prompt,
            tokens,
            max_new,
            top_k: j.get("top_k").and_then(Json::as_usize),
            temperature: j.get("temperature").and_then(Json::as_f64).unwrap_or(1.0) as f32,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("op", "generate".into()),
            ("id", (self.id as usize).into()),
            ("max_new", self.max_new.into()),
            ("temperature", (self.temperature as f64).into()),
        ];
        if let Some(p) = &self.prompt {
            pairs.push(("prompt", p.as_str().into()));
        }
        if let Some(t) = &self.tokens {
            pairs.push(("tokens", Json::Arr(t.iter().map(|&x| Json::Num(x as f64)).collect())));
        }
        if let Some(k) = self.top_k {
            pairs.push(("top_k", k.into()));
        }
        obj(pairs)
    }
}

/// The response to a generation request.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub text: String,
    pub ttft_s: f64,
    pub total_s: f64,
    pub decode_tok_per_s: f64,
    /// Prompt tokens served from prefix-shared KV pages (0 when the
    /// prompt matched nothing in the page index).
    pub prefix_hit_tokens: usize,
    /// KV pages the sequence held at retirement.
    pub kv_pages_used: usize,
    /// Cluster replica that served the request (0 outside cluster
    /// mode).
    pub replica: usize,
    /// First NUMA node of that replica's placement group.
    pub node: usize,
}

impl GenResponse {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", (self.id as usize).into()),
            ("tokens", Json::Arr(self.tokens.iter().map(|&t| Json::Num(t as f64)).collect())),
            ("text", self.text.as_str().into()),
            ("ttft_s", self.ttft_s.into()),
            ("total_s", self.total_s.into()),
            ("decode_tok_per_s", self.decode_tok_per_s.into()),
            ("prefix_hit_tokens", self.prefix_hit_tokens.into()),
            ("kv_pages_used", self.kv_pages_used.into()),
            ("replica", self.replica.into()),
            ("node", self.node.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<GenResponse, String> {
        Ok(GenResponse {
            id: j.get("id").and_then(Json::as_usize).unwrap_or(0) as u64,
            tokens: j
                .get("tokens")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).map(|x| x as i32).collect())
                .unwrap_or_default(),
            text: j.get("text").and_then(Json::as_str).unwrap_or("").to_string(),
            ttft_s: j.get("ttft_s").and_then(Json::as_f64).unwrap_or(0.0),
            total_s: j.get("total_s").and_then(Json::as_f64).unwrap_or(0.0),
            decode_tok_per_s: j.get("decode_tok_per_s").and_then(Json::as_f64).unwrap_or(0.0),
            prefix_hit_tokens: j.get("prefix_hit_tokens").and_then(Json::as_usize).unwrap_or(0),
            kv_pages_used: j.get("kv_pages_used").and_then(Json::as_usize).unwrap_or(0),
            replica: j.get("replica").and_then(Json::as_usize).unwrap_or(0),
            node: j.get("node").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = GenRequest::text(7, "hello", 16);
        let j = r.to_json();
        let back = GenRequest::from_json(&j).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn token_request() {
        let j = Json::parse(r#"{"id":1,"tokens":[1,2,3],"max_new":4}"#).unwrap();
        let r = GenRequest::from_json(&j).unwrap();
        assert_eq!(r.tokens, Some(vec![1, 2, 3]));
        assert_eq!(r.max_new, 4);
    }

    #[test]
    fn rejects_empty() {
        let j = Json::parse(r#"{"max_new":4}"#).unwrap();
        assert!(GenRequest::from_json(&j).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let r = GenResponse {
            id: 3,
            tokens: vec![5, 6],
            text: "ab".into(),
            ttft_s: 0.1,
            total_s: 0.5,
            decode_tok_per_s: 20.0,
            prefix_hit_tokens: 16,
            kv_pages_used: 3,
            replica: 1,
            node: 2,
        };
        let j = r.to_json();
        let back = GenResponse::from_json(&j).unwrap();
        assert_eq!(back.tokens, vec![5, 6]);
        assert_eq!(back.text, "ab");
        assert_eq!(back.prefix_hit_tokens, 16);
        assert_eq!(back.kv_pages_used, 3);
        assert_eq!(back.replica, 1);
        assert_eq!(back.node, 2);
    }

    #[test]
    fn response_kv_fields_default_to_zero() {
        // Proto-1 peers omit the paged-KV fields; the client treats
        // their absence as "no sharing happened".
        let j = Json::parse(r#"{"id":2,"tokens":[9],"text":"x"}"#).unwrap();
        let back = GenResponse::from_json(&j).unwrap();
        assert_eq!(back.prefix_hit_tokens, 0);
        assert_eq!(back.kv_pages_used, 0);
        assert_eq!(back.replica, 0);
        assert_eq!(back.node, 0);
    }
}
