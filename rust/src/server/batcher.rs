//! Router + schedulers: continuous batching and the sequential-slot
//! baseline.
//!
//! Requests land in a bounded queue (backpressure: `submit` fails when
//! full). The per-request path is staged: **tokenize** (connection
//! thread) → **enqueue** → **batched steps** (scheduler thread) →
//! **detokenize** (connection thread again) — the scheduler's step
//! loop never encodes or decodes text, so slow clients cannot stall
//! the batch. Two schedulers can drain the queue:
//!
//! * [`ContinuousBatcher`] — **the** serving path: one engine whose KV
//!   pool holds `batch_slots` sequences. Every decode step is a single
//!   batched graph pass over all live sequences (one token per lane,
//!   prompt tokens chunked into spare lanes). New requests are admitted
//!   from the queue *at step boundaries* in FIFO order the moment a
//!   slot is free, and finished sequences retire without draining the
//!   batch — the batch never stops for either.
//! * [`EngineSlot`] — the llama.cpp-style baseline kept for comparison
//!   benchmarks: each slot owns a whole engine and serves its batch
//!   sequentially, one full generation at a time.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::frontend::{ByteTokenizer, Engine, Sampler, SeqHandle};
use crate::metrics::{Metrics, ReplicaStats};

use super::request::{GenRequest, GenResponse};

/// Completion cell a scheduler fills and a submitter blocks on.
pub(crate) type Done = Arc<(Mutex<Option<GenResponse>>, Condvar)>;

/// Batching/queueing parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub queue_capacity: usize,
    /// Sequential baseline only: requests pulled per wake-up.
    pub max_batch: usize,
    /// Sequential baseline only: window for co-arriving requests.
    pub batch_window: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            queue_capacity: 256,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
        }
    }
}

struct Pending {
    req: GenRequest,
    /// Prompt ids, tokenized on the connection thread (stage 1).
    tokens: Vec<i32>,
    enqueued: Instant,
    done: Done,
}

/// Shared state between submitters and schedulers.
pub struct Router {
    cfg: BatcherConfig,
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    pub metrics: Arc<Metrics>,
    stopping: AtomicBool,
    next_id: AtomicU64,
    pub batches_formed: AtomicU64,
}

impl Router {
    pub fn new(cfg: BatcherConfig) -> Arc<Router> {
        Router::with_metrics(cfg, Arc::new(Metrics::new()))
    }

    /// [`Router::new`] with a caller-supplied metrics sink — cluster
    /// replicas share one [`Metrics`] so the top-level snapshot fields
    /// stay aggregates across every replica.
    pub fn with_metrics(cfg: BatcherConfig, metrics: Arc<Metrics>) -> Arc<Router> {
        Arc::new(Router {
            cfg,
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            metrics,
            stopping: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            batches_formed: AtomicU64::new(0),
        })
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Enqueue; blocks the caller until the response is ready.
    /// Returns an error immediately when the queue is full (backpressure).
    pub fn submit(&self, req: GenRequest) -> Result<GenResponse, String> {
        let tokens = prepare_tokens(&ByteTokenizer, &req);
        self.submit_prepared(req, tokens)
    }

    /// [`Router::submit`] with tokenization already done — stage 2 of
    /// the pipeline. Blocks until the scheduler fills the completion
    /// cell, then detokenizes on the *calling* thread (stage 4).
    pub fn submit_prepared(
        &self,
        req: GenRequest,
        tokens: Vec<i32>,
    ) -> Result<GenResponse, String> {
        match self.enqueue(req, tokens) {
            Ok(done) => Ok(Router::wait_done(&done)),
            Err(e) => {
                self.metrics.record_failure();
                Err(e)
            }
        }
    }

    /// Enqueue without blocking for the response; returns the
    /// completion cell the scheduler will fill. `Err` on a full queue —
    /// the caller decides whether that is a hard failure (single
    /// router) or a failover to another replica (cluster placement),
    /// so no failure is recorded here.
    pub(crate) fn enqueue(&self, req: GenRequest, tokens: Vec<i32>) -> Result<Done, String> {
        let done: Done = Arc::new((Mutex::new(None), Condvar::new()));
        {
            let mut q = self.queue.lock().unwrap();
            if q.len() >= self.cfg.queue_capacity {
                return Err("queue full".into());
            }
            q.push_back(Pending { req, tokens, enqueued: Instant::now(), done: done.clone() });
        }
        self.notify.notify_all();
        Ok(done)
    }

    /// Block on a completion cell, then run stage 4 (detokenize) on
    /// the calling thread — scheduler threads only ever ship token ids.
    pub(crate) fn wait_done(done: &Done) -> GenResponse {
        let (lock, cv) = &**done;
        let mut slot = lock.lock().unwrap();
        while slot.is_none() {
            slot = cv.wait(slot).unwrap();
        }
        let mut resp = slot.take().unwrap();
        resp.text = ByteTokenizer.decode(&resp.tokens);
        resp
    }

    /// Pull the next batch (blocking). `None` once shut down and drained.
    /// Sequential-baseline path.
    fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if !q.is_empty() {
                break;
            }
            if self.stopping.load(Ordering::Acquire) {
                return None;
            }
            let (qq, _timeout) = self
                .notify
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = qq;
        }
        // batching window: give co-arriving requests a moment to join
        let deadline = Instant::now() + self.cfg.batch_window;
        while q.len() < self.cfg.max_batch && Instant::now() < deadline {
            let (qq, _t) = self.notify.wait_timeout(q, self.cfg.batch_window).unwrap();
            q = qq;
        }
        let take = q.len().min(self.cfg.max_batch);
        let batch: Vec<Pending> = q.drain(..take).collect();
        self.batches_formed.fetch_add(1, Ordering::Relaxed);
        Some(batch)
    }

    /// Pop one queued request without blocking (step-boundary admission).
    fn try_pop(&self) -> Option<Pending> {
        self.queue.lock().unwrap().pop_front()
    }

    /// Put an un-admittable request back at the head of the queue —
    /// admission backpressure when the KV arena cannot reserve its
    /// pages yet (FIFO order is preserved).
    fn push_front(&self, p: Pending) {
        self.queue.lock().unwrap().push_front(p);
    }

    /// Block until a request is queued; `None` once shut down and
    /// drained.
    fn wait_pending(&self) -> Option<Pending> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if let Some(p) = q.pop_front() {
                return Some(p);
            }
            if self.stopping.load(Ordering::Acquire) {
                return None;
            }
            let (qq, _t) = self.notify.wait_timeout(q, Duration::from_millis(50)).unwrap();
            q = qq;
        }
    }

    pub fn shutdown(&self) {
        self.stopping.store(true, Ordering::Release);
        self.notify.notify_all();
    }

    pub fn queue_len(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

/// Stage 1: tokenize a request on the connection thread — scheduler
/// threads only ever see token ids.
pub(crate) fn prepare_tokens(tokenizer: &ByteTokenizer, req: &GenRequest) -> Vec<i32> {
    match (&req.tokens, &req.prompt) {
        (Some(t), _) => t.clone(),
        (None, Some(text)) => tokenizer.encode(text, true),
        (None, None) => vec![crate::frontend::tokenizer::BOS],
    }
}

/// Clamp pre-tokenized ids to KV capacity and pick the sampler —
/// shared by both schedulers so they stay token-for-token comparable.
fn prepare(tokens: &[i32], req: &GenRequest, cap: usize) -> (Vec<i32>, usize, Sampler) {
    let mut prompt: Vec<i32> = tokens.iter().copied().take(cap.saturating_sub(2)).collect();
    if prompt.is_empty() {
        prompt.push(crate::frontend::tokenizer::BOS);
    }
    let max_new = req.max_new.min(cap - prompt.len().min(cap));
    // wire-supplied values must not be able to panic the scheduler
    // thread: degenerate top_k/temperature degrade to greedy
    let sampler = match req.top_k {
        Some(k) if k > 1 && req.temperature > 0.0 => Sampler::top_k(k, req.temperature, req.id),
        _ => Sampler::greedy(),
    };
    (prompt, max_new, sampler)
}

// ---------------------------------------------------------------------------
// continuous batching scheduler
// ---------------------------------------------------------------------------

/// One in-flight request inside the running batch.
struct ActiveSeq {
    pending: Pending,
    seq: SeqHandle,
    prompt: Vec<i32>,
    /// Prompt tokens fed so far (chunked prefill). Starts at the
    /// prefix-hit count: tokens adopted from shared pages are never
    /// re-fed.
    fed: usize,
    /// Prompt tokens served from shared prefix pages at admission.
    prefix_hit: usize,
    generated: Vec<i32>,
    next_token: i32,
    max_new: usize,
    sampler: Sampler,
    first_token_at: Option<Instant>,
    prefill_done_at: Option<Instant>,
}

/// Continuous-batching scheduler: owns one multi-slot engine and runs
/// the admit → step → sample → retire loop on its own OS thread.
pub struct ContinuousBatcher {
    pub engine: Engine,
    pub tokenizer: ByteTokenizer,
    /// Per-replica gauges the cluster router places against; a
    /// standalone batcher carries its own replica-0 entry.
    pub stats: Arc<ReplicaStats>,
}

impl ContinuousBatcher {
    pub fn new(engine: Engine) -> Self {
        let stats = Arc::new(ReplicaStats::new(0, vec![0]));
        ContinuousBatcher::with_stats(engine, stats)
    }

    /// [`ContinuousBatcher::new`] with cluster-assigned replica gauges
    /// (id + NUMA node group).
    pub fn with_stats(engine: Engine, stats: Arc<ReplicaStats>) -> Self {
        assert!(
            engine.batch_slots() > 1,
            "continuous batching needs an engine with batch_slots > 1"
        );
        ContinuousBatcher { engine, tokenizer: ByteTokenizer, stats }
    }

    /// Serve until the router shuts down *and* the queue and batch have
    /// drained.
    pub fn serve(mut self, router: Arc<Router>) {
        router.metrics.set_platform(self.engine.platform(), self.engine.pinned_workers());
        router.metrics.set_strategy(
            self.engine.strategy_name(),
            self.engine.bandwidth_source().name(),
            self.engine.predicted_step_us(),
        );
        router.metrics.set_kv_pages_total(self.engine.kv_total_pages());
        self.stats.kv_pages_total.store(self.engine.kv_total_pages() as u64, Ordering::Relaxed);
        router.metrics.register_replica(self.stats.clone());
        let slots = self.engine.batch_slots();
        let mut active: Vec<ActiveSeq> = Vec::new();
        loop {
            // ---- step-boundary admission (FIFO, bounded by free
            // lanes AND free KV pages) ----
            if active.is_empty() {
                match router.wait_pending() {
                    // with no live sequences the whole arena is free
                    // (or evictable), so this admission cannot fail
                    Some(p) => {
                        self.admit(p, &mut active, &router);
                    }
                    None => break, // shut down and drained
                }
            }
            while active.len() < slots {
                match router.try_pop() {
                    Some(p) => {
                        if !self.admit(p, &mut active, &router) {
                            break; // FIFO head's pages don't fit yet
                        }
                    }
                    None => break,
                }
            }
            if active.is_empty() {
                continue; // zero-work request(s) answered inline
            }
            self.step(&mut active, &router);
        }
    }

    /// Try to admit one request. Returns `false` (and re-queues it at
    /// the front) when the KV arena cannot reserve its page budget yet.
    fn admit(&mut self, p: Pending, active: &mut Vec<ActiveSeq>, router: &Router) -> bool {
        let cap = self.engine.cfg().max_seq;
        let (prompt, max_new, sampler) = prepare(&p.tokens, &p.req, cap);
        if max_new == 0 {
            // nothing to generate: answer without occupying a lane
            router.metrics.record_queue_wait(p.enqueued.elapsed().as_secs_f64());
            let resp = GenResponse {
                id: p.req.id,
                text: String::new(),
                tokens: Vec::new(),
                ttft_s: p.enqueued.elapsed().as_secs_f64(),
                total_s: p.enqueued.elapsed().as_secs_f64(),
                decode_tok_per_s: 0.0,
                prefix_hit_tokens: 0,
                kv_pages_used: 0,
                replica: self.stats.id,
                node: self.stats.home_node(),
            };
            router.metrics.record_request(prompt.len(), 0, resp.ttft_s, resp.total_s, 0.0);
            let (lock, cv) = &*p.done;
            *lock.lock().unwrap() = Some(resp);
            cv.notify_all();
            return true;
        }
        // reserve every page the sequence could ever need (prompt +
        // decode budget); prepare() clamped that to max_seq, which the
        // arena always holds, so the request can never be stuck forever
        let budget = prompt.len() + max_new;
        let Some((seq, hit)) = self.engine.seq_start_with_prompt(&prompt, budget) else {
            router.push_front(p);
            return false;
        };
        router.metrics.record_queue_wait(p.enqueued.elapsed().as_secs_f64());
        router.metrics.record_prefix_hit(hit);
        self.stats.prefix_hit_tokens.fetch_add(hit as u64, Ordering::Relaxed);
        active.push(ActiveSeq {
            pending: p,
            seq,
            prompt,
            fed: hit,
            prefix_hit: hit,
            generated: Vec::new(),
            next_token: 0,
            max_new,
            sampler,
            first_token_at: None,
            prefill_done_at: None,
        });
        true
    }

    /// One batched pass: pack lanes (decode lanes plus chunked-prefill
    /// lanes, FIFO order), run the graph, sample, retire finished
    /// sequences — without ever draining the rest of the batch.
    fn step(&mut self, active: &mut Vec<ActiveSeq>, router: &Router) {
        let slots = self.engine.batch_slots();
        // (active index, token, does this lane's logits row get sampled?)
        let mut plan: Vec<(usize, i32, bool)> = Vec::new();
        for (ai, a) in active.iter_mut().enumerate() {
            if plan.len() == slots {
                break;
            }
            if a.fed < a.prompt.len() {
                while a.fed < a.prompt.len() && plan.len() < slots {
                    let tok = a.prompt[a.fed];
                    a.fed += 1;
                    plan.push((ai, tok, a.fed == a.prompt.len()));
                }
            } else {
                plan.push((ai, a.next_token, true));
            }
        }
        let lanes: Vec<(&SeqHandle, i32)> =
            plan.iter().map(|&(ai, tok, _)| (&active[ai].seq, tok)).collect();
        let logits = self.engine.step_batch(&lanes);
        drop(lanes);
        let dispatches = self.engine.last_step_report().map(|r| r.dispatches).unwrap_or(0);
        router.metrics.record_step(plan.len(), dispatches);
        router.metrics.record_concurrency(active.len());
        router.metrics.record_kv_pages(self.engine.kv_pages_in_use());
        self.stats.kv_pages_used.store(self.engine.kv_pages_in_use() as u64, Ordering::Relaxed);
        // drift + straggler gauges off the pass that just ran: step
        // time feeds the aggregate and per-replica EWMAs; a traced
        // pass's rollup feeds the barrier-skew block
        if let Some(rep) = self.engine.last_step_report() {
            let step_us = rep.elapsed * 1e6;
            router.metrics.record_step_time(step_us);
            if let Some(roll) = &rep.trace {
                router.metrics.record_barrier_skew(roll);
            }
            self.stats.record_step_time(step_us, self.engine.predicted_step_us());
        }

        let mut finished: Vec<usize> = Vec::new();
        let mut sampled = 0u64;
        for (li, &(ai, _, sample)) in plan.iter().enumerate() {
            if !sample {
                continue;
            }
            sampled += 1;
            let a = &mut active[ai];
            if a.prefill_done_at.is_none() {
                a.prefill_done_at = Some(Instant::now());
            }
            let t = a.sampler.sample(&logits[li], a.generated.len());
            a.generated.push(t);
            a.next_token = t;
            if a.first_token_at.is_none() {
                a.first_token_at = Some(Instant::now());
            }
            let kv_full = self.engine.seq_pos(&a.seq) >= self.engine.cfg().max_seq;
            if a.generated.len() >= a.max_new || kv_full {
                finished.push(ai);
            }
        }
        self.stats.tokens_decoded.fetch_add(sampled, Ordering::Relaxed);
        self.stats.sample_window();
        for &ai in finished.iter().rev() {
            let done = active.remove(ai);
            self.retire(done, router);
        }
        // placement gauges the cluster router scores against: lanes
        // still decoding after this step plus what is committed to the
        // queue but not yet admitted
        self.stats.live_lanes.store(active.len() as u64, Ordering::Relaxed);
        self.stats.queue_depth.store(router.queue_len() as u64, Ordering::Relaxed);
    }

    fn retire(&mut self, a: ActiveSeq, router: &Router) {
        // read page accounting before the handle drops (RAII: dropping
        // `a.seq` returns every page to the arena)
        let kv_pages_used = self.engine.seq_pages(&a.seq);
        let total_s = a.pending.enqueued.elapsed().as_secs_f64();
        let ttft_s = a
            .first_token_at
            .map(|t| t.duration_since(a.pending.enqueued).as_secs_f64())
            .unwrap_or(total_s);
        let decode_s = a.prefill_done_at.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let decode_tok_per_s =
            if decode_s > 0.0 { a.generated.len() as f64 / decode_s } else { 0.0 };
        let resp = GenResponse {
            id: a.pending.req.id,
            // stage 4 (detokenize) belongs to the submitter's thread:
            // the scheduler ships ids only, Router::wait_done fills text
            text: String::new(),
            tokens: a.generated,
            ttft_s,
            total_s,
            decode_tok_per_s,
            prefix_hit_tokens: a.prefix_hit,
            kv_pages_used,
            replica: self.stats.id,
            node: self.stats.home_node(),
        };
        router.metrics.record_request(
            a.prompt.len(),
            resp.tokens.len(),
            ttft_s,
            total_s,
            decode_tok_per_s,
        );
        let (lock, cv) = &*a.pending.done;
        *lock.lock().unwrap() = Some(resp);
        cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// sequential-slot baseline
// ---------------------------------------------------------------------------

/// One engine slot: owns an [`Engine`] and serves batches until
/// shutdown, one whole generation at a time (the pre-continuous
/// design, kept as the benchmark baseline). Run on its own OS thread.
pub struct EngineSlot {
    pub engine: Engine,
    pub tokenizer: ByteTokenizer,
}

impl EngineSlot {
    pub fn new(engine: Engine) -> Self {
        EngineSlot { engine, tokenizer: ByteTokenizer }
    }

    /// Serve until the router shuts down.
    pub fn serve(mut self, router: Arc<Router>) {
        router.metrics.set_platform(self.engine.platform(), self.engine.pinned_workers());
        router.metrics.set_strategy(
            self.engine.strategy_name(),
            self.engine.bandwidth_source().name(),
            self.engine.predicted_step_us(),
        );
        while let Some(batch) = router.next_batch() {
            for p in batch {
                let resp = self.run_one(&p);
                router.metrics.record_request(
                    p.req.tokens.as_ref().map(|t| t.len()).unwrap_or_else(|| {
                        p.req.prompt.as_deref().unwrap_or("").len() + 1
                    }),
                    resp.tokens.len(),
                    resp.ttft_s,
                    resp.total_s,
                    resp.decode_tok_per_s,
                );
                let (lock, cv) = &*p.done;
                *lock.lock().unwrap() = Some(resp);
                cv.notify_all();
            }
        }
    }

    fn run_one(&mut self, p: &Pending) -> GenResponse {
        let queued = p.enqueued.elapsed().as_secs_f64();
        let cap = self.engine.cfg().max_seq;
        let (prompt, max_new, sampler) = prepare(&p.tokens, &p.req, cap);
        self.engine.reset();
        let res = self.engine.generate(&prompt, max_new, &sampler);
        GenResponse {
            id: p.req.id,
            // detokenized by the submitter (Router::wait_done)
            text: String::new(),
            tokens: res.tokens.clone(),
            ttft_s: queued + res.prefill_seconds,
            total_s: queued + res.prefill_seconds + res.decode_seconds,
            decode_tok_per_s: res.decode_tok_per_s(),
            // the sequential baseline resets the engine per request, so
            // it never shares pages across requests
            prefix_hit_tokens: 0,
            kv_pages_used: 0,
            replica: 0,
            node: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::Strategy;
    use crate::frontend::EngineOptions;
    use crate::hw::Platform;
    use crate::model::ModelConfig;
    use crate::numa::Topology;

    fn tiny_opts(batch_slots: usize) -> EngineOptions {
        EngineOptions {
            strategy: Strategy::arclight_single(),
            threads: 2,
            platform: Platform::Simulated(Topology::uniform(2, 2, 100.0, 25.0)),
            prefill_rows: None,
            seed: 1,
            batch_slots,
            pin: false,
            page_size: 16,
            kv_pages: None,
            base_node: 0,
        }
    }

    fn tiny_slot() -> EngineSlot {
        EngineSlot::new(Engine::new_synthetic(ModelConfig::tiny(), &tiny_opts(1)).unwrap())
    }

    fn tiny_continuous(slots: usize) -> ContinuousBatcher {
        let engine = Engine::new_synthetic(ModelConfig::tiny(), &tiny_opts(slots)).unwrap();
        ContinuousBatcher::new(engine)
    }

    #[test]
    fn router_serves_requests() {
        let router = Router::new(BatcherConfig {
            queue_capacity: 8,
            max_batch: 4,
            batch_window: Duration::from_millis(1),
        });
        let slot = tiny_slot();
        let r2 = router.clone();
        let h = std::thread::spawn(move || slot.serve(r2));

        let resp = router.submit(GenRequest::text(1, "hi", 4)).unwrap();
        assert_eq!(resp.tokens.len(), 4);
        assert!(resp.total_s > 0.0);

        router.shutdown();
        h.join().unwrap();
        assert_eq!(router.metrics.requests_total.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_submitters_all_served() {
        let router = Router::new(BatcherConfig::default());
        let slot = tiny_slot();
        let r2 = router.clone();
        let h = std::thread::spawn(move || slot.serve(r2));

        let mut joins = Vec::new();
        for i in 0..6 {
            let r = router.clone();
            joins.push(std::thread::spawn(move || {
                r.submit(GenRequest::text(i, "abc", 3)).unwrap()
            }));
        }
        for j in joins {
            let resp = j.join().unwrap();
            assert_eq!(resp.tokens.len(), 3);
        }
        router.shutdown();
        h.join().unwrap();
        assert_eq!(router.metrics.requests_total.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let router = Router::new(BatcherConfig {
            queue_capacity: 1,
            max_batch: 1,
            batch_window: Duration::from_millis(1),
        });
        // no scheduler is serving: fill the queue from another thread,
        // then overflow
        let r = router.clone();
        let _waiter = std::thread::spawn(move || {
            let _ = r.submit(GenRequest::text(1, "x", 1));
        });
        std::thread::sleep(Duration::from_millis(30));
        let err = router.submit(GenRequest::text(2, "y", 1));
        assert!(err.is_err());
        router.shutdown();
    }

    #[test]
    fn continuous_serves_concurrent_requests() {
        let router = Router::new(BatcherConfig::default());
        let batcher = tiny_continuous(4);
        let r2 = router.clone();
        let h = std::thread::spawn(move || batcher.serve(r2));

        let mut joins = Vec::new();
        for i in 0..6u64 {
            let r = router.clone();
            joins.push(std::thread::spawn(move || {
                r.submit(GenRequest::text(i + 1, "hello batching", 5)).unwrap()
            }));
        }
        for j in joins {
            let resp = j.join().unwrap();
            assert_eq!(resp.tokens.len(), 5);
            assert!(resp.total_s > 0.0 && resp.ttft_s > 0.0);
        }
        router.shutdown();
        h.join().unwrap();
        assert_eq!(router.metrics.requests_total.load(Ordering::Relaxed), 6);
        // the whole point: >1 lane per step on average under concurrency
        assert!(
            router.metrics.batch_occupancy() > 1.0,
            "occupancy {}",
            router.metrics.batch_occupancy()
        );
        // and every batched step was a single pool dispatch
        assert_eq!(
            router.metrics.pass_dispatches.load(Ordering::Relaxed),
            router.metrics.decode_steps.load(Ordering::Relaxed),
            "one dispatch per batched step"
        );
        assert!(router.metrics.dispatches_per_token() <= 1.0);
        // the scheduler registered its engine's platform at serve start
        let snap = router.metrics.snapshot();
        assert_eq!(snap.get("platform").unwrap().as_str(), Some("simulated"));
        assert_eq!(snap.get("pinned_workers").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn continuous_matches_sequential_tokens() {
        // the serving stack must not change tokens: continuous batching
        // with interleaved sequences == one-at-a-time generation
        let mut serial = Engine::new_synthetic(ModelConfig::tiny(), &tiny_opts(1)).unwrap();
        let tok = ByteTokenizer;
        let mut want = Vec::new();
        for text in ["first prompt", "a different second prompt", "third"] {
            serial.reset();
            let prompt = tok.encode(text, true);
            want.push(serial.generate(&prompt, 6, &Sampler::greedy()).tokens);
        }

        let router = Router::new(BatcherConfig::default());
        let batcher = tiny_continuous(3);
        let r2 = router.clone();
        let h = std::thread::spawn(move || batcher.serve(r2));
        let mut joins = Vec::new();
        for (i, text) in ["first prompt", "a different second prompt", "third"]
            .iter()
            .enumerate()
        {
            let r = router.clone();
            let text = text.to_string();
            joins.push(std::thread::spawn(move || {
                r.submit(GenRequest::text(i as u64 + 1, &text, 6)).unwrap()
            }));
        }
        let mut got: Vec<(u64, Vec<i32>)> =
            joins.into_iter().map(|j| j.join().unwrap()).map(|r| (r.id, r.tokens)).collect();
        got.sort_by_key(|(id, _)| *id);
        router.shutdown();
        h.join().unwrap();
        for (i, (_, tokens)) in got.iter().enumerate() {
            assert_eq!(tokens, &want[i], "request {} diverged under batching", i + 1);
        }
    }

    #[test]
    fn continuous_admission_is_fifo() {
        // 4 equal requests, 2 slots: the first two (by queue order) must
        // finish a whole generation before the last two can.
        let router = Router::new(BatcherConfig::default());
        let mut joins = Vec::new();
        for i in 0..4u64 {
            let r = router.clone();
            joins.push(std::thread::spawn(move || {
                // deterministic queue order: wait for the i previous
                // requests to be enqueued first
                while r.queue_len() < i as usize {
                    std::thread::sleep(Duration::from_millis(1));
                }
                r.submit(GenRequest::text(i + 1, "same work", 8)).unwrap()
            }));
        }
        // start serving only once the queue order is fixed
        while router.queue_len() < 4 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let batcher = tiny_continuous(2);
        let r2 = router.clone();
        let h = std::thread::spawn(move || batcher.serve(r2));
        let mut by_id: Vec<(u64, f64)> =
            joins.into_iter().map(|j| j.join().unwrap()).map(|r| (r.id, r.total_s)).collect();
        by_id.sort_by_key(|(id, _)| *id);
        router.shutdown();
        h.join().unwrap();
        // requests 1/2 ran in the first wave; 3/4 waited for slots
        for early in 0..2 {
            for late in 2..4 {
                assert!(
                    by_id[early].1 < by_id[late].1,
                    "FIFO violated: req {} ({:.4}s) vs req {} ({:.4}s)",
                    by_id[early].0,
                    by_id[early].1,
                    by_id[late].0,
                    by_id[late].1
                );
            }
        }
    }

    #[test]
    fn short_requests_overcommit_the_slot_equivalent_arena() {
        // the arena holds two full-length (64-token) sequences; six
        // short requests need one page each, so page-granular admission
        // runs all six concurrently where slot-granular ran two
        let mut opts = tiny_opts(6);
        opts.kv_pages = Some(8);
        let engine = Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap();
        let batcher = ContinuousBatcher::new(engine);
        let router = Router::new(BatcherConfig::default());
        let mut joins = Vec::new();
        for i in 0..6u64 {
            let r = router.clone();
            joins.push(std::thread::spawn(move || {
                r.submit(GenRequest::text(i + 1, "hi", 4)).unwrap()
            }));
        }
        // fix the queue before serving so admission sees all six
        while router.queue_len() < 6 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let r2 = router.clone();
        let h = std::thread::spawn(move || batcher.serve(r2));
        for j in joins {
            assert_eq!(j.join().unwrap().tokens.len(), 4);
        }
        router.shutdown();
        h.join().unwrap();
        assert!(
            router.metrics.peak_seqs.load(Ordering::Relaxed) >= 6,
            "page-granular admission must overcommit the 2-sequence arena (peak {})",
            router.metrics.peak_seqs.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn identical_prompts_report_prefix_hits() {
        // two requests with the same >page_size prompt: the second must
        // adopt the first's completed prefix pages
        let router = Router::new(BatcherConfig::default());
        let batcher = tiny_continuous(3);
        let r2 = router.clone();
        let h = std::thread::spawn(move || batcher.serve(r2));
        let prompt = "a shared system prompt that spans pages";
        let first = router.submit(GenRequest::text(1, prompt, 3)).unwrap();
        assert_eq!(first.prefix_hit_tokens, 0, "cold cache cannot hit");
        assert!(first.kv_pages_used >= 2, "long prompt spans pages");
        let second = router.submit(GenRequest::text(2, prompt, 3)).unwrap();
        assert!(
            second.prefix_hit_tokens > 0,
            "identical prompt must reuse prefix pages"
        );
        assert_eq!(second.tokens, first.tokens, "prefix reuse must not change tokens");
        router.shutdown();
        h.join().unwrap();
        assert!(router.metrics.prefix_hit_tokens.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn degenerate_sampler_params_cannot_panic_the_scheduler() {
        // top_k: 0 / non-positive temperature come straight off the
        // wire; they must degrade to greedy, not panic the (single)
        // scheduler thread and wedge the server
        let router = Router::new(BatcherConfig::default());
        let batcher = tiny_continuous(2);
        let r2 = router.clone();
        let h = std::thread::spawn(move || batcher.serve(r2));

        let mut req = GenRequest::text(1, "bad sampler", 3);
        req.top_k = Some(0);
        req.temperature = -1.0;
        let resp = router.submit(req).unwrap();
        assert_eq!(resp.tokens.len(), 3);

        // and the scheduler is still alive for well-formed requests
        let ok = router.submit(GenRequest::text(2, "still alive", 2)).unwrap();
        assert_eq!(ok.tokens.len(), 2);
        router.shutdown();
        h.join().unwrap();
    }

    #[test]
    fn staged_pipeline_detokenizes_on_the_submitter_thread() {
        // the scheduler ships ids only; Router::wait_done must fill the
        // text on the submitting side, identically to decoding the ids
        let router = Router::new(BatcherConfig::default());
        let batcher = tiny_continuous(2);
        let r2 = router.clone();
        let h = std::thread::spawn(move || batcher.serve(r2));
        let resp = router.submit(GenRequest::text(1, "staged", 4)).unwrap();
        assert_eq!(resp.text, ByteTokenizer.decode(&resp.tokens));
        assert!(!resp.text.is_empty());
        // pre-tokenized submission takes the same path
        let req = GenRequest::text(2, "ignored", 4);
        let tokens = prepare_tokens(&ByteTokenizer, &GenRequest::text(2, "staged", 4));
        let pre = router.submit_prepared(req, tokens).unwrap();
        assert_eq!(pre.tokens, resp.tokens, "explicit stage-1 tokens must win");
        router.shutdown();
        h.join().unwrap();
    }

    #[test]
    fn standalone_batcher_reports_replica_zero() {
        let router = Router::new(BatcherConfig::default());
        let batcher = tiny_continuous(2);
        let stats = batcher.stats.clone();
        let r2 = router.clone();
        let h = std::thread::spawn(move || batcher.serve(r2));
        let resp = router.submit(GenRequest::text(1, "provenance", 4)).unwrap();
        assert_eq!(resp.replica, 0);
        assert_eq!(resp.node, 0);
        assert!(stats.tokens_decoded.load(Ordering::Relaxed) >= 4);
        assert!(stats.kv_pages_total.load(Ordering::Relaxed) > 0);
        router.shutdown();
        h.join().unwrap();
        // serve registered its gauges: the snapshot carries one replica
        let snap = router.metrics.snapshot();
        let reps = snap.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].get("replica").unwrap().as_usize(), Some(0));
        assert!(reps[0].get("tokens_decoded").unwrap().as_usize().unwrap() >= 4);
    }

    #[test]
    fn continuous_retires_without_draining() {
        // unequal max_new: the short request must come back while the
        // long one is still decoding (strictly earlier total time), and
        // both must complete.
        let router = Router::new(BatcherConfig::default());
        let batcher = tiny_continuous(2);
        let r2 = router.clone();
        let h = std::thread::spawn(move || batcher.serve(r2));

        let r_long = router.clone();
        let long = std::thread::spawn(move || {
            r_long.submit(GenRequest::text(1, "long running request", 40)).unwrap()
        });
        // make sure the long one is admitted first
        std::thread::sleep(Duration::from_millis(20));
        let short = router.submit(GenRequest::text(2, "short", 2)).unwrap();
        let long = long.join().unwrap();
        assert_eq!(short.tokens.len(), 2);
        assert_eq!(long.tokens.len(), 40);
        assert!(
            short.total_s < long.total_s,
            "short request ({:.4}s) should retire before the long one ({:.4}s)",
            short.total_s,
            long.total_s
        );
        router.shutdown();
        h.join().unwrap();
    }
}
