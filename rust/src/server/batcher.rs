//! Router + dynamic batcher.
//!
//! Requests land in a bounded queue (backpressure: `submit` fails when
//! full). Engine *slots* — each a full engine instance with its own KV
//! cache — pull batches of up to `max_batch` requests formed within a
//! `batch_window`. A slot serves its batch sequentially (the engine
//! holds one sequence's KV state at a time), which matches llama.cpp's
//! single-slot semantics; multiple slots give concurrent sequences.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::frontend::{ByteTokenizer, Engine, Sampler};
use crate::metrics::Metrics;

use super::request::{GenRequest, GenResponse};

/// Batching/queueing parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub queue_capacity: usize,
    pub max_batch: usize,
    pub batch_window: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            queue_capacity: 256,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
        }
    }
}

struct Pending {
    req: GenRequest,
    enqueued: Instant,
    #[allow(clippy::type_complexity)]
    done: Arc<(Mutex<Option<GenResponse>>, Condvar)>,
}

/// Shared state between submitters and engine slots.
pub struct Router {
    cfg: BatcherConfig,
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    pub metrics: Arc<Metrics>,
    stopping: AtomicBool,
    next_id: AtomicU64,
    pub batches_formed: AtomicU64,
}

impl Router {
    pub fn new(cfg: BatcherConfig) -> Arc<Router> {
        Arc::new(Router {
            cfg,
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            metrics: Arc::new(Metrics::new()),
            stopping: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            batches_formed: AtomicU64::new(0),
        })
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Enqueue; blocks the caller until the response is ready.
    /// Returns an error immediately when the queue is full (backpressure).
    pub fn submit(&self, req: GenRequest) -> Result<GenResponse, String> {
        let done = Arc::new((Mutex::new(None), Condvar::new()));
        {
            let mut q = self.queue.lock().unwrap();
            if q.len() >= self.cfg.queue_capacity {
                self.metrics.record_failure();
                return Err("queue full".into());
            }
            q.push_back(Pending { req, enqueued: Instant::now(), done: done.clone() });
        }
        self.notify.notify_all();
        let (lock, cv) = &*done;
        let mut slot = lock.lock().unwrap();
        while slot.is_none() {
            slot = cv.wait(slot).unwrap();
        }
        Ok(slot.take().unwrap())
    }

    /// Pull the next batch (blocking). `None` once shut down and drained.
    fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if !q.is_empty() {
                break;
            }
            if self.stopping.load(Ordering::Acquire) {
                return None;
            }
            let (qq, _timeout) = self
                .notify
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = qq;
        }
        // batching window: give co-arriving requests a moment to join
        let deadline = Instant::now() + self.cfg.batch_window;
        while q.len() < self.cfg.max_batch && Instant::now() < deadline {
            let (qq, _t) = self.notify.wait_timeout(q, self.cfg.batch_window).unwrap();
            q = qq;
        }
        let take = q.len().min(self.cfg.max_batch);
        let batch: Vec<Pending> = q.drain(..take).collect();
        self.batches_formed.fetch_add(1, Ordering::Relaxed);
        Some(batch)
    }

    pub fn shutdown(&self) {
        self.stopping.store(true, Ordering::Release);
        self.notify.notify_all();
    }

    pub fn queue_len(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

/// One engine slot: owns an [`Engine`] and serves batches until
/// shutdown. Run on its own OS thread.
pub struct EngineSlot {
    pub engine: Engine,
    pub tokenizer: ByteTokenizer,
}

impl EngineSlot {
    pub fn new(engine: Engine) -> Self {
        EngineSlot { engine, tokenizer: ByteTokenizer }
    }

    /// Serve until the router shuts down.
    pub fn serve(mut self, router: Arc<Router>) {
        while let Some(batch) = router.next_batch() {
            for p in batch {
                let resp = self.run_one(&p);
                router.metrics.record_request(
                    p.req.tokens.as_ref().map(|t| t.len()).unwrap_or_else(|| {
                        p.req.prompt.as_deref().unwrap_or("").len() + 1
                    }),
                    resp.tokens.len(),
                    resp.ttft_s,
                    resp.total_s,
                );
                let (lock, cv) = &*p.done;
                *lock.lock().unwrap() = Some(resp);
                cv.notify_all();
            }
        }
    }

    fn run_one(&mut self, p: &Pending) -> GenResponse {
        let queued = p.enqueued.elapsed().as_secs_f64();
        let toks: Vec<i32> = match (&p.req.tokens, &p.req.prompt) {
            (Some(t), _) => t.clone(),
            (None, Some(text)) => self.tokenizer.encode(text, true),
            (None, None) => vec![crate::frontend::tokenizer::BOS],
        };
        // clamp to capacity
        let cap = self.engine.cfg().max_seq;
        let prompt: Vec<i32> = toks.into_iter().take(cap.saturating_sub(2)).collect();
        let max_new = p.req.max_new.min(cap - prompt.len().min(cap));

        let sampler = match p.req.top_k {
            None | Some(1) => Sampler::greedy(),
            Some(k) => Sampler::top_k(k, p.req.temperature, p.req.id),
        };
        self.engine.reset();
        let res = self.engine.generate(&prompt, max_new, &sampler);
        GenResponse {
            id: p.req.id,
            text: self.tokenizer.decode(&res.tokens),
            tokens: res.tokens.clone(),
            ttft_s: queued + res.prefill_seconds,
            total_s: queued + res.prefill_seconds + res.decode_seconds,
            decode_tok_per_s: res.decode_tok_per_s(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::Strategy;
    use crate::frontend::EngineOptions;
    use crate::model::ModelConfig;
    use crate::numa::Topology;

    fn tiny_slot() -> EngineSlot {
        let opts = EngineOptions {
            strategy: Strategy::arclight_single(),
            threads: 2,
            topo: Topology::uniform(2, 2, 100.0, 25.0),
            prefill_rows: None,
            seed: 1,
        };
        EngineSlot::new(Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap())
    }

    #[test]
    fn router_serves_requests() {
        let router = Router::new(BatcherConfig {
            queue_capacity: 8,
            max_batch: 4,
            batch_window: Duration::from_millis(1),
        });
        let slot = tiny_slot();
        let r2 = router.clone();
        let h = std::thread::spawn(move || slot.serve(r2));

        let resp = router.submit(GenRequest::text(1, "hi", 4)).unwrap();
        assert_eq!(resp.tokens.len(), 4);
        assert!(resp.total_s > 0.0);

        router.shutdown();
        h.join().unwrap();
        assert_eq!(router.metrics.requests_total.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_submitters_all_served() {
        let router = Router::new(BatcherConfig::default());
        let slot = tiny_slot();
        let r2 = router.clone();
        let h = std::thread::spawn(move || slot.serve(r2));

        let mut joins = Vec::new();
        for i in 0..6 {
            let r = router.clone();
            joins.push(std::thread::spawn(move || {
                r.submit(GenRequest::text(i, "abc", 3)).unwrap()
            }));
        }
        for j in joins {
            let resp = j.join().unwrap();
            assert_eq!(resp.tokens.len(), 3);
        }
        router.shutdown();
        h.join().unwrap();
        assert_eq!(router.metrics.requests_total.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let router = Router::new(BatcherConfig {
            queue_capacity: 1,
            max_batch: 1,
            batch_window: Duration::from_millis(1),
        });
        // no slot is serving: fill the queue from another thread, then overflow
        let r = router.clone();
        let _waiter = std::thread::spawn(move || {
            let _ = r.submit(GenRequest::text(1, "x", 1));
        });
        std::thread::sleep(Duration::from_millis(30));
        let err = router.submit(GenRequest::text(2, "y", 1));
        assert!(err.is_err());
        router.shutdown();
    }
}
