//! TCP front door: line-delimited JSON over a socket, plus a client.
//!
//! Protocol v2 (one JSON object per line):
//!   → {"op":"hello"}             ← {"proto":2,"features":[…]}
//!   → {"op":"generate","prompt":"...","max_new":32, ...}
//!   ← {"id":…, "tokens":[…], "text":"…", "ttft_s":…,
//!      "prefix_hit_tokens":…, "kv_pages_used":…, …}
//!   → {"op":"metrics"}           ← metrics snapshot
//!   → {"op":"ping"}              ← {"ok":true}
//!
//! Failures are structured objects so clients can branch on a stable
//! code instead of parsing prose:
//!   ← {"error":{"code":"unknown_op","message":"…","op":"…"}}
//!   ← {"error":{"code":"bad_request","message":"…"}}
//! Proto-1 peers sent a bare string under "error"; the client helper
//! accepts both shapes.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

use super::batcher::Router;
use super::request::{GenRequest, GenResponse};

/// A running server (listener thread + connection threads).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    router: Arc<Router>,
    stopping: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// Bind and start accepting. Engine slots must be started
    /// separately (`EngineSlot::serve`) on the same router.
    pub fn start(addr: &str, router: Arc<Router>) -> Result<ServerHandle> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stopping = Arc::new(AtomicBool::new(false));
        let stop2 = stopping.clone();
        let router2 = router.clone();
        let accept_thread = std::thread::Builder::new()
            .name("arclight-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let r = router2.clone();
                            std::thread::spawn(move || handle_conn(stream, r));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(ServerHandle { addr: local, router, stopping, accept_thread: Some(accept_thread) })
    }

    pub fn router(&self) -> Arc<Router> {
        self.router.clone()
    }

    pub fn stop(mut self) {
        self.stopping.store(true, Ordering::Release);
        self.router.shutdown();
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, router: Arc<Router>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = dispatch(&line, &router);
        let mut out = reply.to_string();
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Wire protocol revision reported by the `hello` handshake.
pub const PROTO_VERSION: usize = 2;

/// Capabilities a v2 server advertises in the `hello` reply.
pub const PROTO_FEATURES: [&str; 5] = ["generate", "metrics", "ping", "paged_kv", "prefix_cache"];

/// Structured protocol error (`extra` carries op-specific context).
fn proto_err(code: &str, message: String, extra: Vec<(&str, Json)>) -> Json {
    let mut body = vec![("code", code.into()), ("message", message.into())];
    body.extend(extra);
    obj(vec![("error", obj(body))])
}

fn dispatch(line: &str, router: &Arc<Router>) -> Json {
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return proto_err("bad_request", format!("bad json: {e}"), vec![]),
    };
    match parsed.get("op").and_then(Json::as_str) {
        Some("hello") => obj(vec![
            ("proto", PROTO_VERSION.into()),
            ("features", Json::Arr(PROTO_FEATURES.iter().map(|&f| f.into()).collect())),
        ]),
        Some("ping") => obj(vec![("ok", true.into())]),
        Some("metrics") => router.metrics.snapshot(),
        Some("generate") | None => match GenRequest::from_json(&parsed) {
            Ok(mut req) => {
                if req.id == 0 {
                    req.id = router.fresh_id();
                }
                match router.submit(req) {
                    Ok(resp) => resp.to_json(),
                    Err(e) => proto_err("rejected", e, vec![]),
                }
            }
            Err(e) => proto_err("bad_request", e, vec![]),
        },
        Some(other) => {
            proto_err("unknown_op", format!("unknown op '{other}'"), vec![("op", other.into())])
        }
    }
}

/// Blocking client for tests, examples and the CLI.
pub struct ServerClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ServerClient {
    pub fn connect(addr: &str) -> Result<ServerClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let writer = stream.try_clone()?;
        Ok(ServerClient { reader: BufReader::new(stream), writer })
    }

    fn roundtrip(&mut self, msg: &Json) -> Result<Json> {
        let mut line = msg.to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Json::parse(&reply).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        Ok(self.roundtrip(&obj(vec![("op", "ping".into())]))?
            .get("ok")
            .and_then(Json::as_bool)
            .unwrap_or(false))
    }

    /// Protocol handshake: `(proto, features)`. A proto-1 server has
    /// no `hello` op and answers with an error — reported as proto 1
    /// with no features so callers can downgrade.
    pub fn hello(&mut self) -> Result<(usize, Vec<String>)> {
        let j = self.roundtrip(&obj(vec![("op", "hello".into())]))?;
        if j.get("error").is_some() {
            return Ok((1, Vec::new()));
        }
        let proto = j.get("proto").and_then(Json::as_usize).unwrap_or(1);
        let features = j
            .get("features")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_str).map(str::to_string).collect())
            .unwrap_or_default();
        Ok((proto, features))
    }

    pub fn metrics(&mut self) -> Result<Json> {
        self.roundtrip(&obj(vec![("op", "metrics".into())]))
    }

    pub fn generate(&mut self, req: &GenRequest) -> Result<GenResponse> {
        let j = self.roundtrip(&req.to_json())?;
        if let Some(e) = j.get("error") {
            // proto 2 sends {code, message}; proto 1 sent a bare string
            let code = e.get("code").and_then(Json::as_str).unwrap_or("error");
            let msg = e
                .get("message")
                .and_then(Json::as_str)
                .or_else(|| e.as_str())
                .unwrap_or("unknown error");
            anyhow::bail!("server error ({code}): {msg}");
        }
        GenResponse::from_json(&j).map_err(|e| anyhow::anyhow!(e))
    }
}
