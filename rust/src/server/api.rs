//! TCP front door: line-delimited JSON over a socket, plus a client.
//!
//! Protocol v2 (one JSON object per line):
//!   → {"op":"hello"}             ← {"proto":2,"features":[…]}
//!   → {"op":"generate","prompt":"...","max_new":32, ...}
//!   ← {"id":…, "tokens":[…], "text":"…", "ttft_s":…,
//!      "prefix_hit_tokens":…, "kv_pages_used":…, …}
//!   → {"op":"metrics"}           ← metrics snapshot
//!   → {"op":"ping"}              ← {"ok":true}
//!
//! Failures are structured objects so clients can branch on a stable
//! code instead of parsing prose:
//!   ← {"error":{"code":"unknown_op","message":"…","op":"…"}}
//!   ← {"error":{"code":"bad_request","message":"…"}}
//!   ← {"error":{"code":"overloaded","message":"…"}}  (connection cap)
//! Proto-1 peers sent a bare string under "error"; the client helper
//! accepts both shapes.
//!
//! Each connection runs on its own thread, bounded by a concurrency
//! cap: over-capacity connects are answered with a structured
//! `overloaded` error and closed instead of piling up threads. The
//! connection thread owns stages 1 and 4 of the request pipeline
//! (tokenize / detokenize); scheduler threads never touch text.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::metrics::Metrics;
use crate::util::json::{obj, Json};

use super::batcher::Router;
use super::cluster::Cluster;
use super::request::{GenRequest, GenResponse};

/// Default cap on concurrent connection threads.
pub const DEFAULT_MAX_CONNS: usize = 64;

/// What a connection thread submits requests to: the single-engine
/// router, or the cluster's placement layer.
#[derive(Clone)]
enum Target {
    Single(Arc<Router>),
    Cluster(Arc<Cluster>),
}

impl Target {
    fn submit(&self, req: GenRequest) -> Result<GenResponse, String> {
        match self {
            Target::Single(r) => r.submit(req),
            Target::Cluster(c) => c.submit(req),
        }
    }

    fn fresh_id(&self) -> u64 {
        match self {
            Target::Single(r) => r.fresh_id(),
            Target::Cluster(c) => c.fresh_id(),
        }
    }

    fn metrics(&self) -> Arc<Metrics> {
        match self {
            Target::Single(r) => r.metrics.clone(),
            Target::Cluster(c) => c.metrics.clone(),
        }
    }

    fn shutdown(&self) {
        match self {
            Target::Single(r) => r.shutdown(),
            Target::Cluster(c) => c.shutdown(),
        }
    }
}

/// A running server (listener thread + bounded connection threads).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    target: Target,
    stopping: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// Bind and start accepting on a single-engine router. Schedulers
    /// must be started separately on the same router.
    pub fn start(addr: &str, router: Arc<Router>) -> Result<ServerHandle> {
        ServerHandle::start_with(addr, Target::Single(router), DEFAULT_MAX_CONNS)
    }

    /// [`ServerHandle::start`] with an explicit connection cap.
    pub fn start_with_limit(
        addr: &str,
        router: Arc<Router>,
        max_conns: usize,
    ) -> Result<ServerHandle> {
        ServerHandle::start_with(addr, Target::Single(router), max_conns)
    }

    /// Bind and start accepting on a [`Cluster`] (replica schedulers
    /// are already running — `Cluster::start` spawned them).
    pub fn start_cluster(addr: &str, cluster: Arc<Cluster>) -> Result<ServerHandle> {
        ServerHandle::start_with(addr, Target::Cluster(cluster), DEFAULT_MAX_CONNS)
    }

    fn start_with(addr: &str, target: Target, max_conns: usize) -> Result<ServerHandle> {
        assert!(max_conns >= 1, "connection cap must admit at least one connection");
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stopping = Arc::new(AtomicBool::new(false));
        let stop2 = stopping.clone();
        let target2 = target.clone();
        let live = Arc::new(AtomicUsize::new(0));
        let accept_thread = std::thread::Builder::new()
            .name("arclight-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            // bounded connection concurrency: admit or
                            // reject with a structured error, never
                            // queue unbounded threads
                            if live.fetch_add(1, Ordering::AcqRel) >= max_conns {
                                live.fetch_sub(1, Ordering::AcqRel);
                                let mut line = proto_err(
                                    "overloaded",
                                    format!("connection limit {max_conns} reached"),
                                    vec![],
                                )
                                .to_string();
                                line.push('\n');
                                let _ = stream.write_all(line.as_bytes());
                                continue; // drop the stream: close
                            }
                            let t = target2.clone();
                            let live2 = live.clone();
                            std::thread::spawn(move || {
                                handle_conn(stream, t);
                                live2.fetch_sub(1, Ordering::AcqRel);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(ServerHandle { addr: local, target, stopping, accept_thread: Some(accept_thread) })
    }

    /// The metrics sink of whatever this server fronts.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.target.metrics()
    }

    pub fn stop(mut self) {
        self.stopping.store(true, Ordering::Release);
        self.target.shutdown();
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, target: Target) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = dispatch(&line, &target);
        let mut out = reply.to_string();
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Wire protocol revision reported by the `hello` handshake.
pub const PROTO_VERSION: usize = 2;

/// Capabilities a v2 server advertises in the `hello` reply.
pub const PROTO_FEATURES: [&str; 7] =
    ["generate", "metrics", "ping", "paged_kv", "prefix_cache", "cluster", "drift"];

/// Structured protocol error (`extra` carries op-specific context).
fn proto_err(code: &str, message: String, extra: Vec<(&str, Json)>) -> Json {
    let mut body = vec![("code", code.into()), ("message", message.into())];
    body.extend(extra);
    obj(vec![("error", obj(body))])
}

fn dispatch(line: &str, target: &Target) -> Json {
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return proto_err("bad_request", format!("bad json: {e}"), vec![]),
    };
    match parsed.get("op").and_then(Json::as_str) {
        Some("hello") => obj(vec![
            ("proto", PROTO_VERSION.into()),
            ("features", Json::Arr(PROTO_FEATURES.iter().map(|&f| f.into()).collect())),
        ]),
        Some("ping") => obj(vec![("ok", true.into())]),
        Some("metrics") => target.metrics().snapshot(),
        Some("generate") | None => match GenRequest::from_json(&parsed) {
            Ok(mut req) => {
                if req.id == 0 {
                    req.id = target.fresh_id();
                }
                match target.submit(req) {
                    Ok(resp) => resp.to_json(),
                    Err(e) => proto_err("rejected", e, vec![]),
                }
            }
            Err(e) => proto_err("bad_request", e, vec![]),
        },
        Some(other) => {
            proto_err("unknown_op", format!("unknown op '{other}'"), vec![("op", other.into())])
        }
    }
}

/// Default connect timeout of [`ServerClient::connect`].
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Default read timeout of [`ServerClient::connect`] — generous enough
/// for a saturated batch to turn a generation around, small enough
/// that a wedged server cannot hang a client forever.
pub const READ_TIMEOUT: Duration = Duration::from_secs(120);

/// Blocking client for tests, examples and the CLI.
pub struct ServerClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ServerClient {
    pub fn connect(addr: &str) -> Result<ServerClient> {
        ServerClient::connect_with_timeouts(addr, CONNECT_TIMEOUT, Some(READ_TIMEOUT))
    }

    /// [`ServerClient::connect`] with explicit connect/read timeouts.
    /// `read_timeout: None` blocks reads forever (the pre-timeout
    /// behavior, for debugger-friendly sessions).
    pub fn connect_with_timeouts(
        addr: &str,
        connect_timeout: Duration,
        read_timeout: Option<Duration>,
    ) -> Result<ServerClient> {
        use std::net::ToSocketAddrs;
        let sockaddr = addr
            .to_socket_addrs()
            .with_context(|| format!("resolve {addr}"))?
            .next()
            .ok_or_else(|| anyhow::anyhow!("no address for {addr}"))?;
        let stream = TcpStream::connect_timeout(&sockaddr, connect_timeout)
            .with_context(|| format!("connect {addr}"))?;
        stream.set_read_timeout(read_timeout)?;
        let writer = stream.try_clone()?;
        Ok(ServerClient { reader: BufReader::new(stream), writer })
    }

    fn roundtrip(&mut self, msg: &Json) -> Result<Json> {
        let mut line = msg.to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Json::parse(&reply).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        Ok(self.roundtrip(&obj(vec![("op", "ping".into())]))?
            .get("ok")
            .and_then(Json::as_bool)
            .unwrap_or(false))
    }

    /// Protocol handshake: `(proto, features)`. A proto-1 server has
    /// no `hello` op and answers with an error — reported as proto 1
    /// with no features so callers can downgrade.
    pub fn hello(&mut self) -> Result<(usize, Vec<String>)> {
        let j = self.roundtrip(&obj(vec![("op", "hello".into())]))?;
        if j.get("error").is_some() {
            return Ok((1, Vec::new()));
        }
        let proto = j.get("proto").and_then(Json::as_usize).unwrap_or(1);
        let features = j
            .get("features")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_str).map(str::to_string).collect())
            .unwrap_or_default();
        Ok((proto, features))
    }

    pub fn metrics(&mut self) -> Result<Json> {
        self.roundtrip(&obj(vec![("op", "metrics".into())]))
    }

    pub fn generate(&mut self, req: &GenRequest) -> Result<GenResponse> {
        let j = self.roundtrip(&req.to_json())?;
        if let Some(e) = j.get("error") {
            // proto 2 sends {code, message}; proto 1 sent a bare string
            let code = e.get("code").and_then(Json::as_str).unwrap_or("error");
            let msg = e
                .get("message")
                .and_then(Json::as_str)
                .or_else(|| e.as_str())
                .unwrap_or("unknown error");
            anyhow::bail!("server error ({code}): {msg}");
        }
        GenResponse::from_json(&j).map_err(|e| anyhow::anyhow!(e))
    }
}
