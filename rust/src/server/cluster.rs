//! Cluster serving: one [`ContinuousBatcher`] replica per NUMA node
//! group behind a placement router.
//!
//! The paper's single engine spans the whole machine; at serving
//! concurrency it is often better to split the machine into replicas —
//! each engine pinned to its own node group with a node-local KV arena
//! — and place requests across them. Placement scores every replica by
//!
//! * **prefix affinity** — the longest run of the prompt's completed
//!   pages already in the replica's prefix index (the FNV rolling-hash
//!   key the paged KV cache registers); routing a warm prompt back to
//!   the replica that holds its pages skips that much prefill, and
//! * **load** — lanes decoding now plus requests committed to the
//!   replica's queue; affinity may override load only inside a small
//!   tolerance band, so one hot prefix cannot starve the fleet.
//!
//! The per-connection path stays staged exactly like the single-router
//! server: the connection thread tokenizes (stage 1), the cluster
//! places and enqueues (stage 2), the chosen replica's scheduler runs
//! batched steps (stage 3), and the connection thread detokenizes the
//! reply (stage 4). Responses carry `replica`/`node` provenance.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::frontend::{ByteTokenizer, Engine, PrefixProbe};
use crate::metrics::{Metrics, ReplicaStats};

use super::batcher::{prepare_tokens, BatcherConfig, ContinuousBatcher, Router};
use super::request::{GenRequest, GenResponse};

/// Cluster-wide serving parameters.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Queue/batch parameters applied to every replica's router.
    pub batcher: BatcherConfig,
    /// Prefix affinity may pull a request onto a replica whose load is
    /// at most `min_load + load_tolerance`; beyond the band, load wins.
    pub load_tolerance: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { batcher: BatcherConfig::default(), load_tolerance: 2 }
    }
}

/// Per-replica inputs to one placement decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicaScore {
    /// Prompt tokens resident in the replica's prefix index (longest
    /// completed-page run from the start of the prompt).
    pub hit_tokens: usize,
    /// Lanes decoding plus queued requests at scoring time.
    pub load: usize,
}

/// The placement policy, pure and deterministic: among replicas whose
/// load is within `tolerance` of the least-loaded one, pick the
/// longest prefix run; break ties toward lower load, then lower index.
/// Cold prompts (no hits anywhere) therefore go to the least-loaded
/// replica, and a single replica is always index 0.
pub fn pick_replica(scores: &[ReplicaScore], tolerance: usize) -> usize {
    assert!(!scores.is_empty(), "placement needs at least one replica");
    let min_load = scores.iter().map(|s| s.load).min().unwrap();
    scores
        .iter()
        .enumerate()
        .filter(|(_, s)| s.load <= min_load + tolerance)
        .min_by_key(|&(i, s)| (std::cmp::Reverse(s.hit_tokens), s.load, i))
        .map(|(i, _)| i)
        .unwrap()
}

struct Replica {
    router: Arc<Router>,
    probe: PrefixProbe,
    stats: Arc<ReplicaStats>,
}

impl Replica {
    fn score(&self, tokens: &[i32]) -> ReplicaScore {
        ReplicaScore {
            hit_tokens: self.probe.prefix_run_tokens(tokens),
            // read the queue live rather than the sampled gauge: the
            // gauge only refreshes at step boundaries, and placement
            // must see requests committed a microsecond ago
            load: self.stats.live_lanes.load(Ordering::Relaxed) as usize + self.router.queue_len(),
        }
    }
}

/// A fleet of [`ContinuousBatcher`] replicas, one per NUMA node group,
/// behind the placement policy. All replicas share one [`Metrics`], so
/// the top-level snapshot fields aggregate the whole cluster while the
/// `replicas` array breaks them out per node group.
pub struct Cluster {
    replicas: Vec<Replica>,
    pub metrics: Arc<Metrics>,
    tolerance: usize,
    threads: Mutex<Vec<JoinHandle<()>>>,
    next_id: AtomicU64,
}

impl Cluster {
    /// Build one engine per node group via `build(replica_id, nodes)`
    /// and start a scheduler thread for each. The builder is expected
    /// to pin the engine onto its group (set `base_node` to
    /// `nodes[0]`); the cluster only wires routers, probes and gauges.
    pub fn start<F>(groups: &[Vec<usize>], cfg: ClusterConfig, mut build: F) -> Result<Arc<Cluster>>
    where
        F: FnMut(usize, &[usize]) -> Result<Engine>,
    {
        assert!(!groups.is_empty(), "cluster needs at least one node group");
        let metrics = Arc::new(Metrics::new());
        let mut replicas = Vec::with_capacity(groups.len());
        let mut threads = Vec::with_capacity(groups.len());
        for (id, nodes) in groups.iter().enumerate() {
            let engine = build(id, nodes)?;
            let stats = Arc::new(ReplicaStats::new(id, nodes.clone()));
            let probe = engine.prefix_probe();
            let router = Router::with_metrics(cfg.batcher, metrics.clone());
            let batcher = ContinuousBatcher::with_stats(engine, stats.clone());
            let r = router.clone();
            threads.push(std::thread::spawn(move || batcher.serve(r)));
            replicas.push(Replica { router, probe, stats });
        }
        Ok(Arc::new(Cluster {
            replicas,
            metrics,
            tolerance: cfg.load_tolerance,
            threads: Mutex::new(threads),
            next_id: AtomicU64::new(1),
        }))
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Serve one request: tokenize here (stage 1, the caller's thread),
    /// score and enqueue on the chosen replica (stage 2), block for the
    /// scheduler's ids (stage 3) and detokenize on the way out (stage
    /// 4). A full queue fails over to the other replicas in load order
    /// before reporting backpressure.
    pub fn submit(&self, req: GenRequest) -> Result<GenResponse, String> {
        let tokens = prepare_tokens(&ByteTokenizer, &req);
        let scores: Vec<ReplicaScore> = self.replicas.iter().map(|r| r.score(&tokens)).collect();
        let first = pick_replica(&scores, self.tolerance);
        // failover order: the placed replica, then the rest by load
        let mut order: Vec<usize> = (0..self.replicas.len()).collect();
        order.sort_by_key(|&i| (i != first, scores[i].load, i));
        for &i in &order {
            match self.replicas[i].router.enqueue(req.clone(), tokens.clone()) {
                Ok(done) => return Ok(Router::wait_done(&done)),
                Err(_) => continue, // queue full — try the next replica
            }
        }
        self.metrics.record_failure();
        Err("queue full".into())
    }

    /// Stop every replica and join its scheduler thread (idempotent).
    pub fn shutdown(&self) {
        for r in &self.replicas {
            r.router.shutdown();
        }
        for t in self.threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(hit_tokens: usize, load: usize) -> ReplicaScore {
        ReplicaScore { hit_tokens, load }
    }

    #[test]
    fn single_replica_always_wins() {
        assert_eq!(pick_replica(&[s(0, 7)], 2), 0);
        assert_eq!(pick_replica(&[s(64, 0)], 0), 0);
    }

    #[test]
    fn cold_prompts_go_to_least_loaded() {
        assert_eq!(pick_replica(&[s(0, 3), s(0, 1), s(0, 2)], 2), 1);
        // tie on load → lowest index
        assert_eq!(pick_replica(&[s(0, 2), s(0, 2)], 2), 0);
    }

    #[test]
    fn affinity_wins_within_the_tolerance_band() {
        // replica 1 holds 32 prefix tokens and is only 2 busier than
        // the least-loaded replica: affinity overrides load
        assert_eq!(pick_replica(&[s(0, 1), s(32, 3)], 2), 1);
        // equal hits inside the band → lower load wins
        assert_eq!(pick_replica(&[s(16, 3), s(16, 1)], 2), 1);
    }

    #[test]
    fn load_wins_beyond_the_tolerance_band() {
        // same 32-token run, but the warm replica is 3 over the
        // minimum with tolerance 2: it is filtered out
        assert_eq!(pick_replica(&[s(0, 1), s(32, 4)], 2), 0);
        // tolerance 0 is strict least-loaded with affinity tie-break
        assert_eq!(pick_replica(&[s(8, 1), s(32, 1), s(0, 0)], 0), 2);
    }
}
