//! Serving layer: request router, dynamic batcher and a TCP/JSON API.
//!
//! ArcLight's paper stops at the decode loop; a deployable system needs
//! a request path. This module provides one in the shape of
//! llama.cpp's server / vLLM's router, scaled to this engine: a bounded
//! request queue with backpressure, N engine *slots* (each owning its
//! own KV cache) pulling work, a batching window for queue fairness,
//! and a line-delimited JSON protocol over TCP. Python is nowhere on
//! this path.

pub mod api;
pub mod batcher;
pub mod request;

pub use api::{ServerClient, ServerHandle};
pub use batcher::{BatcherConfig, EngineSlot, Router};
pub use request::{GenRequest, GenResponse};
