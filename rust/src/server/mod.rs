//! Serving layer: request router, continuous batcher and a TCP/JSON
//! API.
//!
//! ArcLight's paper stops at the decode loop; a deployable system needs
//! a request path. This module provides one in the shape of vLLM's
//! router, scaled to this engine: a bounded request queue with
//! backpressure feeding a **continuous batcher** — one engine whose KV
//! pool holds many sequences, admitting queued requests into the
//! running batch at decode-step boundaries and retiring finished ones
//! without draining it. The pre-continuous sequential-slot scheduler
//! ([`EngineSlot`]) is kept as the benchmark baseline. The wire
//! protocol is line-delimited JSON over TCP; Python is nowhere on this
//! path.
//!
//! [`Cluster`] scales the same design across the machine: one batcher
//! replica per NUMA node group, each with its own engine and KV arena,
//! behind a placement router that scores replicas by load and prefix
//! affinity. Connection threads tokenize and detokenize; scheduler
//! threads only ever step batches.

pub mod api;
pub mod batcher;
pub mod cluster;
pub mod request;

pub use api::{ServerClient, ServerHandle};
pub use batcher::{BatcherConfig, ContinuousBatcher, EngineSlot, Router};
pub use cluster::{pick_replica, Cluster, ClusterConfig, ReplicaScore};
pub use request::{GenRequest, GenResponse};
