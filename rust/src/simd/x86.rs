//! x86-64 vector implementations of the hot kernels (AVX2+FMA, and
//! AVX-512F behind the `simd-avx512` cargo feature).
//!
//! Every function here is `unsafe` with a `# Safety` contract: the
//! caller must have verified the required CPU features (normally via
//! [`super::KernelTier::supported`] — the dispatchers in [`super`] only
//! route here for a supported tier). Per-element kernels reproduce the
//! scalar IEEE expression lane-for-lane (multiply + add, no FMA);
//! reductions use wide FMA accumulators and are covered by the
//! tolerance policy in `rust/KERNELS.md`.

use crate::util::f16_to_f32;
use core::arch::x86_64::*;

/// Horizontal sum of the 8 lanes of an AVX register.
#[target_feature(enable = "avx2")]
unsafe fn hsum256(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps(v, 1);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
    _mm_cvtss_f32(s)
}

/// AVX2+FMA dot product over `a.len()` elements.
///
/// # Safety
/// CPU must support AVX2 and FMA; `a` and `b` must have equal length.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        acc1 =
            _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i + 8)), _mm256_loadu_ps(bp.add(i + 8)), acc1);
        i += 16;
    }
    if i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        i += 8;
    }
    let mut sum = hsum256(_mm256_add_ps(acc0, acc1));
    while i < n {
        sum += *ap.add(i) * *bp.add(i);
        i += 1;
    }
    sum
}

/// AVX2+FMA Q4_0 GEMV dot with precomputed per-block activation sums
/// (same presum identity as [`crate::quant::dot_q4_0_f32_presum`]).
///
/// # Safety
/// CPU must support AVX2 and FMA. `raw` must hold `raw.len() / 18`
/// whole Q4_0 blocks, `x` at least `32 * blocks` elements and `xsums`
/// at least `blocks` entries.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn dot_q4_0_presum_avx2(raw: &[u8], x: &[f32], xsums: &[f32]) -> f32 {
    let blocks = raw.len() / 18;
    debug_assert!(x.len() >= blocks * 32);
    debug_assert!(xsums.len() >= blocks);
    let mask = _mm_set1_epi8(0x0F);
    let mut acc = _mm256_setzero_ps();
    let mut dsum = 0.0f32;
    for bi in 0..blocks {
        let bp = raw.as_ptr().add(bi * 18);
        let d = f16_to_f32(u16::from_le_bytes([*bp, *bp.add(1)]));
        let qs = _mm_loadu_si128(bp.add(2) as *const __m128i);
        // elems 0..16 are the low nibbles, elems 16..32 the high ones
        let lo = _mm_and_si128(qs, mask);
        let hi = _mm_and_si128(_mm_srli_epi16(qs, 4), mask);
        let xp = x.as_ptr().add(bi * 32);
        let mut t = _mm256_mul_ps(
            _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(lo)),
            _mm256_loadu_ps(xp),
        );
        t = _mm256_fmadd_ps(
            _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128(lo, 8))),
            _mm256_loadu_ps(xp.add(8)),
            t,
        );
        t = _mm256_fmadd_ps(
            _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(hi)),
            _mm256_loadu_ps(xp.add(16)),
            t,
        );
        t = _mm256_fmadd_ps(
            _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128(hi, 8))),
            _mm256_loadu_ps(xp.add(24)),
            t,
        );
        acc = _mm256_fmadd_ps(_mm256_set1_ps(d), t, acc);
        dsum += d * *xsums.as_ptr().add(bi);
    }
    hsum256(acc) - 8.0 * dsum
}

/// AVX2+FMA Q8_0 GEMV dot (same contract as
/// [`crate::quant::dot_q8_0_f32`]).
///
/// # Safety
/// CPU must support AVX2 and FMA. `raw` must hold `raw.len() / 34`
/// whole Q8_0 blocks and `x` at least `32 * blocks` elements.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn dot_q8_0_avx2(raw: &[u8], x: &[f32]) -> f32 {
    let blocks = raw.len() / 34;
    debug_assert!(x.len() >= blocks * 32);
    let mut acc = _mm256_setzero_ps();
    for bi in 0..blocks {
        let bp = raw.as_ptr().add(bi * 34);
        let d = f16_to_f32(u16::from_le_bytes([*bp, *bp.add(1)]));
        let qs = _mm256_loadu_si256(bp.add(2) as *const __m256i);
        let lo = _mm256_castsi256_si128(qs);
        let hi = _mm256_extracti128_si256(qs, 1);
        let xp = x.as_ptr().add(bi * 32);
        let mut t = _mm256_mul_ps(
            _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(lo)),
            _mm256_loadu_ps(xp),
        );
        t = _mm256_fmadd_ps(
            _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_srli_si128(lo, 8))),
            _mm256_loadu_ps(xp.add(8)),
            t,
        );
        t = _mm256_fmadd_ps(
            _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(hi)),
            _mm256_loadu_ps(xp.add(16)),
            t,
        );
        t = _mm256_fmadd_ps(
            _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_srli_si128(hi, 8))),
            _mm256_loadu_ps(xp.add(24)),
            t,
        );
        acc = _mm256_fmadd_ps(_mm256_set1_ps(d), t, acc);
    }
    hsum256(acc)
}

/// AVX2+FMA `Σ x[i]²`.
///
/// # Safety
/// CPU must support AVX2 and FMA.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn sum_squares_avx2(x: &[f32]) -> f32 {
    let n = x.len();
    let xp = x.as_ptr();
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(xp.add(i));
        acc = _mm256_fmadd_ps(v, v, acc);
        i += 8;
    }
    let mut sum = hsum256(acc);
    while i < n {
        let v = *xp.add(i);
        sum += v * v;
        i += 1;
    }
    sum
}

/// AVX2 `out[i] = x[i] * s * g[i]` — bit-exact with the scalar loop
/// (two ordered multiplies per lane, no FMA).
///
/// # Safety
/// CPU must support AVX2; the three slices must have equal length.
#[target_feature(enable = "avx2")]
pub unsafe fn scale_gain_avx2(x: &[f32], g: &[f32], out: &mut [f32], s: f32) {
    let n = x.len();
    let sv = _mm256_set1_ps(s);
    let mut i = 0usize;
    while i + 8 <= n {
        let t = _mm256_mul_ps(_mm256_loadu_ps(x.as_ptr().add(i)), sv);
        let t = _mm256_mul_ps(t, _mm256_loadu_ps(g.as_ptr().add(i)));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), t);
        i += 8;
    }
    while i < n {
        out[i] = x[i] * s * g[i];
        i += 1;
    }
}

/// AVX2 max over a slice (`NEG_INFINITY` when empty). Exact for the
/// finite inputs the softmax/attention paths produce.
///
/// # Safety
/// CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn max_f32_avx2(x: &[f32]) -> f32 {
    let n = x.len();
    let xp = x.as_ptr();
    let mut m = _mm256_set1_ps(f32::NEG_INFINITY);
    let mut i = 0usize;
    while i + 8 <= n {
        m = _mm256_max_ps(m, _mm256_loadu_ps(xp.add(i)));
        i += 8;
    }
    let lo = _mm256_castps256_ps128(m);
    let hi = _mm256_extractf128_ps(m, 1);
    let s = _mm_max_ps(lo, hi);
    let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_max_ss(s, _mm_shuffle_ps(s, s, 1));
    let mut best = _mm_cvtss_f32(s);
    while i < n {
        best = best.max(*xp.add(i));
        i += 1;
    }
    best
}

/// AVX2 `x[i] *= s` — bit-exact with the scalar loop.
///
/// # Safety
/// CPU must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn scale_inplace_avx2(x: &mut [f32], s: f32) {
    let n = x.len();
    let sv = _mm256_set1_ps(s);
    let mut i = 0usize;
    while i + 8 <= n {
        let p = x.as_mut_ptr().add(i);
        _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), sv));
        i += 8;
    }
    while i < n {
        x[i] *= s;
        i += 1;
    }
}

/// AVX2 `acc[i] = acc[i] * corr + p * v[i]` — multiply + add per lane
/// (deliberately **not** FMA) so the lanes match the scalar online
/// softmax recurrence bit for bit.
///
/// # Safety
/// CPU must support AVX2; `acc` and `v` must have equal length.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_rescale_avx2(acc: &mut [f32], corr: f32, p: f32, v: &[f32]) {
    debug_assert_eq!(acc.len(), v.len());
    let n = acc.len();
    let cv = _mm256_set1_ps(corr);
    let pv = _mm256_set1_ps(p);
    let mut i = 0usize;
    while i + 8 <= n {
        let ap = acc.as_mut_ptr().add(i);
        let t = _mm256_add_ps(
            _mm256_mul_ps(_mm256_loadu_ps(ap), cv),
            _mm256_mul_ps(pv, _mm256_loadu_ps(v.as_ptr().add(i))),
        );
        _mm256_storeu_ps(ap, t);
        i += 8;
    }
    while i < n {
        acc[i] = acc[i] * corr + p * v[i];
        i += 1;
    }
}

#[cfg(feature = "simd-avx512")]
mod avx512 {
    //! 512-bit variants of the three GEMV dot products. Gated behind
    //! the `simd-avx512` cargo feature because the `_mm512_*`
    //! intrinsics stabilized well above this crate's MSRV.

    use crate::util::f16_to_f32;
    use core::arch::x86_64::*;

    /// AVX-512F dot product over `a.len()` elements.
    ///
    /// # Safety
    /// CPU must support AVX-512F; `a` and `b` must have equal length.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot_f32_avx512(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm512_setzero_ps();
        let mut acc1 = _mm512_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm512_fmadd_ps(_mm512_loadu_ps(ap.add(i)), _mm512_loadu_ps(bp.add(i)), acc0);
            acc1 = _mm512_fmadd_ps(
                _mm512_loadu_ps(ap.add(i + 16)),
                _mm512_loadu_ps(bp.add(i + 16)),
                acc1,
            );
            i += 32;
        }
        if i + 16 <= n {
            acc0 = _mm512_fmadd_ps(_mm512_loadu_ps(ap.add(i)), _mm512_loadu_ps(bp.add(i)), acc0);
            i += 16;
        }
        let mut sum = _mm512_reduce_add_ps(_mm512_add_ps(acc0, acc1));
        while i < n {
            sum += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        sum
    }

    /// AVX-512F Q4_0 presum dot: one 16-lane vector per nibble half.
    ///
    /// # Safety
    /// Same contract as [`super::dot_q4_0_presum_avx2`] with AVX-512F.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot_q4_0_presum_avx512(raw: &[u8], x: &[f32], xsums: &[f32]) -> f32 {
        let blocks = raw.len() / 18;
        debug_assert!(x.len() >= blocks * 32);
        debug_assert!(xsums.len() >= blocks);
        let mask = _mm_set1_epi8(0x0F);
        let mut acc = _mm512_setzero_ps();
        let mut dsum = 0.0f32;
        for bi in 0..blocks {
            let bp = raw.as_ptr().add(bi * 18);
            let d = f16_to_f32(u16::from_le_bytes([*bp, *bp.add(1)]));
            let qs = _mm_loadu_si128(bp.add(2) as *const __m128i);
            let lo = _mm_and_si128(qs, mask);
            let hi = _mm_and_si128(_mm_srli_epi16(qs, 4), mask);
            let xp = x.as_ptr().add(bi * 32);
            let mut t = _mm512_mul_ps(
                _mm512_cvtepi32_ps(_mm512_cvtepu8_epi32(lo)),
                _mm512_loadu_ps(xp),
            );
            t = _mm512_fmadd_ps(
                _mm512_cvtepi32_ps(_mm512_cvtepu8_epi32(hi)),
                _mm512_loadu_ps(xp.add(16)),
                t,
            );
            acc = _mm512_fmadd_ps(_mm512_set1_ps(d), t, acc);
            dsum += d * *xsums.as_ptr().add(bi);
        }
        _mm512_reduce_add_ps(acc) - 8.0 * dsum
    }

    /// AVX-512F Q8_0 dot: one 16-lane vector per 16-byte half block.
    ///
    /// # Safety
    /// Same contract as [`super::dot_q8_0_avx2`] with AVX-512F.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot_q8_0_avx512(raw: &[u8], x: &[f32]) -> f32 {
        let blocks = raw.len() / 34;
        debug_assert!(x.len() >= blocks * 32);
        let mut acc = _mm512_setzero_ps();
        for bi in 0..blocks {
            let bp = raw.as_ptr().add(bi * 34);
            let d = f16_to_f32(u16::from_le_bytes([*bp, *bp.add(1)]));
            let qs = _mm256_loadu_si256(bp.add(2) as *const __m256i);
            let lo = _mm256_castsi256_si128(qs);
            let hi = _mm256_extracti128_si256(qs, 1);
            let xp = x.as_ptr().add(bi * 32);
            let mut t = _mm512_mul_ps(
                _mm512_cvtepi32_ps(_mm512_cvtepi8_epi32(lo)),
                _mm512_loadu_ps(xp),
            );
            t = _mm512_fmadd_ps(
                _mm512_cvtepi32_ps(_mm512_cvtepi8_epi32(hi)),
                _mm512_loadu_ps(xp.add(16)),
                t,
            );
            acc = _mm512_fmadd_ps(_mm512_set1_ps(d), t, acc);
        }
        _mm512_reduce_add_ps(acc)
    }
}

#[cfg(feature = "simd-avx512")]
pub use avx512::{dot_f32_avx512, dot_q4_0_presum_avx512, dot_q8_0_avx512};
