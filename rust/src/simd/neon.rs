//! aarch64 NEON implementations of the f32 primitives.
//!
//! Only the cheap 128-bit f32 paths are vectorized here (dot, sum of
//! squares, max, the per-element scale/axpy kernels); the quantized
//! dot products stay on the scalar tier for NEON — see `rust/KERNELS.md`
//! for the rationale. Per-element kernels reproduce the scalar IEEE
//! expression lane-for-lane (multiply + add, no fused contraction).

use core::arch::aarch64::*;

/// NEON dot product over `a.len()` elements.
///
/// # Safety
/// CPU must support NEON; `a` and `b` must have equal length.
#[target_feature(enable = "neon")]
pub unsafe fn dot_f32_neon(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 8 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4)));
        i += 8;
    }
    if i + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
        i += 4;
    }
    let mut sum = vaddvq_f32(vaddq_f32(acc0, acc1));
    while i < n {
        sum += *ap.add(i) * *bp.add(i);
        i += 1;
    }
    sum
}

/// NEON `Σ x[i]²`.
///
/// # Safety
/// CPU must support NEON.
#[target_feature(enable = "neon")]
pub unsafe fn sum_squares_neon(x: &[f32]) -> f32 {
    let n = x.len();
    let xp = x.as_ptr();
    let mut acc = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 4 <= n {
        let v = vld1q_f32(xp.add(i));
        acc = vfmaq_f32(acc, v, v);
        i += 4;
    }
    let mut sum = vaddvq_f32(acc);
    while i < n {
        let v = *xp.add(i);
        sum += v * v;
        i += 1;
    }
    sum
}

/// NEON `out[i] = x[i] * s * g[i]` — bit-exact with the scalar loop.
///
/// # Safety
/// CPU must support NEON; the three slices must have equal length.
#[target_feature(enable = "neon")]
pub unsafe fn scale_gain_neon(x: &[f32], g: &[f32], out: &mut [f32], s: f32) {
    let n = x.len();
    let sv = vdupq_n_f32(s);
    let mut i = 0usize;
    while i + 4 <= n {
        let t = vmulq_f32(vld1q_f32(x.as_ptr().add(i)), sv);
        let t = vmulq_f32(t, vld1q_f32(g.as_ptr().add(i)));
        vst1q_f32(out.as_mut_ptr().add(i), t);
        i += 4;
    }
    while i < n {
        out[i] = x[i] * s * g[i];
        i += 1;
    }
}

/// NEON max over a slice (`NEG_INFINITY` when empty). Exact for the
/// finite inputs the softmax/attention paths produce.
///
/// # Safety
/// CPU must support NEON.
#[target_feature(enable = "neon")]
pub unsafe fn max_f32_neon(x: &[f32]) -> f32 {
    let n = x.len();
    let xp = x.as_ptr();
    let mut m = vdupq_n_f32(f32::NEG_INFINITY);
    let mut i = 0usize;
    while i + 4 <= n {
        m = vmaxq_f32(m, vld1q_f32(xp.add(i)));
        i += 4;
    }
    let mut best = vmaxvq_f32(m);
    while i < n {
        best = best.max(*xp.add(i));
        i += 1;
    }
    best
}

/// NEON `x[i] *= s` — bit-exact with the scalar loop.
///
/// # Safety
/// CPU must support NEON.
#[target_feature(enable = "neon")]
pub unsafe fn scale_inplace_neon(x: &mut [f32], s: f32) {
    let n = x.len();
    let sv = vdupq_n_f32(s);
    let mut i = 0usize;
    while i + 4 <= n {
        let p = x.as_mut_ptr().add(i);
        vst1q_f32(p, vmulq_f32(vld1q_f32(p), sv));
        i += 4;
    }
    while i < n {
        x[i] *= s;
        i += 1;
    }
}

/// NEON `acc[i] = acc[i] * corr + p * v[i]` — multiply + add per lane
/// (deliberately **not** fused) so the lanes match the scalar online
/// softmax recurrence bit for bit.
///
/// # Safety
/// CPU must support NEON; `acc` and `v` must have equal length.
#[target_feature(enable = "neon")]
pub unsafe fn axpy_rescale_neon(acc: &mut [f32], corr: f32, p: f32, v: &[f32]) {
    debug_assert_eq!(acc.len(), v.len());
    let n = acc.len();
    let cv = vdupq_n_f32(corr);
    let pv = vdupq_n_f32(p);
    let mut i = 0usize;
    while i + 4 <= n {
        let ap = acc.as_mut_ptr().add(i);
        let t = vaddq_f32(
            vmulq_f32(vld1q_f32(ap), cv),
            vmulq_f32(pv, vld1q_f32(v.as_ptr().add(i))),
        );
        vst1q_f32(ap, t);
        i += 4;
    }
    while i < n {
        acc[i] = acc[i] * corr + p * v[i];
        i += 1;
    }
}
