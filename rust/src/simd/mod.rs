//! Runtime-dispatched SIMD kernel tiers (see `rust/KERNELS.md`).
//!
//! The hot kernels — the Q4_0/Q8_0 GEMV dot products, the f32 dot,
//! RMSNorm, softmax and the attention inner loops — exist in up to four
//! implementations, one per [`KernelTier`]. The tier is detected once
//! per process ([`KernelTier::active`], overridable with `--tier` /
//! `ARCLIGHT_TIER`) and threaded through the dispatch functions below;
//! the scalar implementations in [`crate::quant`] and [`crate::ops`]
//! stay untouched as the **parity oracle** every vectorized path is
//! tested against (`tests/simd_parity.rs`).
//!
//! ## Determinism contract
//!
//! Per-element kernels (`scale_gain`, `scale_inplace`, `axpy_rescale`,
//! `max_f32`) are **bit-exact** across tiers: each output lane is the
//! same IEEE expression the scalar loop evaluates (multiply + add, no
//! FMA contraction). Only the reductions (`dot_*`, `sum_squares`)
//! reassociate and may differ from scalar within the documented
//! tolerance (KERNELS.md §Tolerance). Within one process the tier is
//! fixed, so run-to-run determinism (batched == serial decode) holds
//! on every tier.

#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod x86;

use std::sync::atomic::{AtomicU8, Ordering};

/// Vector-instruction tier of the hot kernels.
///
/// Resolved per-kernel by the registry ([`crate::ops::Kernel::tier`]):
/// vectorized kernels report the process-wide [`KernelTier::active`]
/// tier, kernels without a vector path report `Scalar`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelTier {
    /// Portable scalar Rust — available everywhere; the parity oracle.
    #[default]
    Scalar,
    /// 256-bit AVX2 + FMA (x86-64, runtime-detected).
    Avx2,
    /// 512-bit AVX-512F (x86-64; needs the `simd-avx512` build feature
    /// — the 512-bit intrinsics stabilized above this crate's MSRV).
    Avx512,
    /// 128-bit NEON (aarch64). Covers the f32 primitives; the quantized
    /// dot products stay scalar on this tier (KERNELS.md).
    Neon,
}

/// Sentinel for "not resolved yet" in the process-wide tier cell.
const TIER_UNSET: u8 = u8::MAX;
static ACTIVE: AtomicU8 = AtomicU8::new(TIER_UNSET);

impl KernelTier {
    /// Every tier, in dispatch-preference order (widest last).
    pub const ALL: [KernelTier; 4] =
        [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512, KernelTier::Neon];

    /// Stable lower-case name (CLI values, report/JSON fields).
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Avx512 => "avx512",
            KernelTier::Neon => "neon",
        }
    }

    /// Parse a [`KernelTier::name`] string (the `--tier` CLI value).
    pub fn parse(s: &str) -> Option<KernelTier> {
        match s {
            "scalar" => Some(KernelTier::Scalar),
            "avx2" => Some(KernelTier::Avx2),
            "avx512" => Some(KernelTier::Avx512),
            "neon" => Some(KernelTier::Neon),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> KernelTier {
        match v {
            1 => KernelTier::Avx2,
            2 => KernelTier::Avx512,
            3 => KernelTier::Neon,
            _ => KernelTier::Scalar,
        }
    }

    /// Whether this tier can run on the current machine **and** build
    /// (AVX-512 additionally requires the `simd-avx512` cargo feature).
    pub fn supported(self) -> bool {
        match self {
            KernelTier::Scalar => true,
            KernelTier::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            KernelTier::Avx512 => {
                #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                        && std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                }
                #[cfg(not(all(target_arch = "x86_64", feature = "simd-avx512")))]
                {
                    false
                }
            }
            KernelTier::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }

    /// Best tier available on this machine: AVX-512 (when compiled in)
    /// over AVX2 over NEON over scalar.
    pub fn detect() -> KernelTier {
        for t in [KernelTier::Avx512, KernelTier::Avx2, KernelTier::Neon] {
            if t.supported() {
                return t;
            }
        }
        KernelTier::Scalar
    }

    /// The process-wide tier the vectorized kernels dispatch on.
    ///
    /// Resolved once: the `ARCLIGHT_TIER` environment variable (when it
    /// names a supported tier) wins, otherwise [`KernelTier::detect`].
    /// [`KernelTier::set_active`] (the `--tier` CLI flag) overrides it.
    pub fn active() -> KernelTier {
        match ACTIVE.load(Ordering::Relaxed) {
            TIER_UNSET => {
                let t = Self::initial();
                ACTIVE.store(t as u8, Ordering::Relaxed);
                t
            }
            v => Self::from_u8(v),
        }
    }

    fn initial() -> KernelTier {
        if let Ok(name) = std::env::var("ARCLIGHT_TIER") {
            match Self::parse(&name) {
                Some(t) if t.supported() => return t,
                Some(t) => eprintln!(
                    "note: ARCLIGHT_TIER={} not supported on this host; using detected tier",
                    t.name()
                ),
                None if name == "auto" => {}
                None => eprintln!("note: unknown ARCLIGHT_TIER='{name}'; using detected tier"),
            }
        }
        Self::detect()
    }

    /// Force the process-wide tier (the `--tier` override). Fails when
    /// the tier is not supported on this machine or build, so parity
    /// runs can't silently execute the wrong code path.
    pub fn set_active(tier: KernelTier) -> Result<(), String> {
        if !tier.supported() {
            let hint = if tier == KernelTier::Avx512 && !cfg!(feature = "simd-avx512") {
                " (build with --features simd-avx512)"
            } else {
                ""
            };
            return Err(format!("kernel tier '{}' not supported on this host{hint}", tier.name()));
        }
        ACTIVE.store(tier as u8, Ordering::Relaxed);
        Ok(())
    }

    /// Tiers usable on this machine, scalar first — what the parity
    /// test matrices iterate over.
    pub fn supported_tiers() -> Vec<KernelTier> {
        Self::ALL.iter().copied().filter(|t| t.supported()).collect()
    }
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Tier-dispatched f32 dot product (reduction — reassociates).
/// Scalar arm is [`crate::ops::gemm::dot_f32`], the oracle.
#[inline]
pub fn dot_f32(tier: KernelTier, a: &[f32], b: &[f32]) -> f32 {
    debug_assert!(tier.supported(), "dispatch on unsupported tier {tier}");
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::dot_f32_avx2(a, b) },
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        KernelTier::Avx512 => unsafe { x86::dot_f32_avx512(a, b) },
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::dot_f32_neon(a, b) },
        _ => crate::ops::gemm::dot_f32(a, b),
    }
}

/// Tier-dispatched Q4_0 presum dot (reduction — reassociates). Scalar
/// arm is [`crate::quant::dot_q4_0_f32_presum`], the oracle. NEON falls
/// back to scalar (nibble unpack is not worth it on 128-bit lanes).
#[inline]
pub fn dot_q4_0_presum(tier: KernelTier, raw: &[u8], x: &[f32], xsums: &[f32]) -> f32 {
    debug_assert!(tier.supported(), "dispatch on unsupported tier {tier}");
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::dot_q4_0_presum_avx2(raw, x, xsums) },
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        KernelTier::Avx512 => unsafe { x86::dot_q4_0_presum_avx512(raw, x, xsums) },
        _ => crate::quant::dot_q4_0_f32_presum(raw, x, xsums),
    }
}

/// Tier-dispatched Q8_0 dot (reduction — reassociates). Scalar arm is
/// [`crate::quant::dot_q8_0_f32`], the oracle. NEON falls back to
/// scalar.
#[inline]
pub fn dot_q8_0(tier: KernelTier, raw: &[u8], x: &[f32]) -> f32 {
    debug_assert!(tier.supported(), "dispatch on unsupported tier {tier}");
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::dot_q8_0_avx2(raw, x) },
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        KernelTier::Avx512 => unsafe { x86::dot_q8_0_avx512(raw, x) },
        _ => crate::quant::dot_q8_0_f32(raw, x),
    }
}

/// Tier-dispatched `Σ x[i]²` (reduction — reassociates): the RMSNorm
/// mean-square numerator. The AVX tiers share the 256-bit path (the op
/// is bandwidth-bound; only the GEMV dots get true 512-bit variants).
#[inline]
pub fn sum_squares(tier: KernelTier, x: &[f32]) -> f32 {
    debug_assert!(tier.supported(), "dispatch on unsupported tier {tier}");
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::sum_squares_avx2(x) },
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        KernelTier::Avx512 => unsafe { x86::sum_squares_avx2(x) },
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::sum_squares_neon(x) },
        _ => x.iter().map(|v| v * v).sum::<f32>(),
    }
}

/// `out[i] = x[i] * s * g[i]` — the RMSNorm apply step. Per-element:
/// bit-exact across tiers (same multiply order as the scalar loop).
#[inline]
pub fn scale_gain(tier: KernelTier, x: &[f32], g: &[f32], out: &mut [f32], s: f32) {
    debug_assert!(tier.supported(), "dispatch on unsupported tier {tier}");
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), out.len());
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::scale_gain_avx2(x, g, out, s) },
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        KernelTier::Avx512 => unsafe { x86::scale_gain_avx2(x, g, out, s) },
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::scale_gain_neon(x, g, out, s) },
        _ => {
            for i in 0..x.len() {
                out[i] = x[i] * s * g[i];
            }
        }
    }
}

/// Max over a slice (`NEG_INFINITY` when empty) — the softmax and
/// online-attention running max. Exact: max never rounds, so every
/// tier returns the same value for finite inputs.
#[inline]
pub fn max_f32(tier: KernelTier, x: &[f32]) -> f32 {
    debug_assert!(tier.supported(), "dispatch on unsupported tier {tier}");
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::max_f32_avx2(x) },
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        KernelTier::Avx512 => unsafe { x86::max_f32_avx2(x) },
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::max_f32_neon(x) },
        _ => x.iter().copied().fold(f32::NEG_INFINITY, f32::max),
    }
}

/// `x[i] *= s` — the softmax normalize step. Per-element: bit-exact
/// across tiers.
#[inline]
pub fn scale_inplace(tier: KernelTier, x: &mut [f32], s: f32) {
    debug_assert!(tier.supported(), "dispatch on unsupported tier {tier}");
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::scale_inplace_avx2(x, s) },
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        KernelTier::Avx512 => unsafe { x86::scale_inplace_avx2(x, s) },
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::scale_inplace_neon(x, s) },
        _ => {
            for v in x.iter_mut() {
                *v *= s;
            }
        }
    }
}

/// `acc[i] = acc[i] * corr + p * v[i]` — the online-softmax rescale +
/// accumulate of the attention inner loop. Per-element and implemented
/// as multiply + add (**no FMA**) on every tier, so it is bit-exact
/// with the scalar recurrence — the batched == serial determinism
/// contract depends on this.
#[inline]
pub fn axpy_rescale(tier: KernelTier, acc: &mut [f32], corr: f32, p: f32, v: &[f32]) {
    debug_assert!(tier.supported(), "dispatch on unsupported tier {tier}");
    debug_assert_eq!(acc.len(), v.len());
    match tier {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::axpy_rescale_avx2(acc, corr, p, v) },
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        KernelTier::Avx512 => unsafe { x86::axpy_rescale_avx2(acc, corr, p, v) },
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::axpy_rescale_neon(acc, corr, p, v) },
        _ => {
            for i in 0..acc.len() {
                acc[i] = acc[i] * corr + p * v[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for t in KernelTier::ALL {
            assert_eq!(KernelTier::parse(t.name()), Some(t));
            assert_eq!(format!("{t}"), t.name());
        }
        assert_eq!(KernelTier::parse("sse9000"), None);
    }

    #[test]
    fn scalar_always_supported_and_detect_is_supported() {
        assert!(KernelTier::Scalar.supported());
        assert!(KernelTier::detect().supported());
        let tiers = KernelTier::supported_tiers();
        assert_eq!(tiers[0], KernelTier::Scalar);
        assert!(tiers.contains(&KernelTier::detect()));
    }

    #[test]
    fn set_active_rejects_unsupported() {
        // at most one of AVX2 / NEON can be supported on one machine,
        // so at least one rejection path is exercised everywhere
        for t in [KernelTier::Avx2, KernelTier::Neon] {
            if !t.supported() {
                assert!(KernelTier::set_active(t).is_err());
            }
        }
        #[cfg(not(feature = "simd-avx512"))]
        {
            let err = KernelTier::set_active(KernelTier::Avx512).unwrap_err();
            assert!(err.contains("avx512"), "{err}");
        }
    }

    #[test]
    fn active_is_stable_and_supported() {
        let a = KernelTier::active();
        assert!(a.supported());
        assert_eq!(KernelTier::active(), a);
    }

    #[test]
    fn default_is_scalar() {
        assert_eq!(KernelTier::default(), KernelTier::Scalar);
    }
}
