//! ArcLight CLI — the leader entrypoint.
//!
//! Subcommands:
//!   generate  write a synthetic ALF model file
//!   run       load a model and generate text (quickstart)
//!   serve     start the TCP serving API (continuous batching by
//!             default; --mode slots for the sequential baseline;
//!             --replicas N|auto for per-NUMA-node engine replicas)
//!   report    regenerate the paper's Table 1 / Figures 10–13
//!   probe     print the simulated machine + bandwidth matrix
//!   topo      print the detected host NUMA topology vs the simulated
//!             testbed (host feature; falls back to simulated), plus
//!             the cached measured bandwidth matrix when one exists
//!   calibrate measure the host's node-pair bandwidth matrix (STREAM
//!             triad) and cache it keyed by topology fingerprint
//!             (--quick for a smoke run, --force to re-measure,
//!             --root for a sysfs fixture tree)
//!   trace     export a Chrome-trace of one simulated decode step
//!   golden    cross-check the native engine against PJRT artifacts
//!
//! Engine-building commands (`run`, `serve`) accept `--platform
//! sim|host` and `--pin`: `--pin` implies host detection, binds each
//! pool worker to its core's OS cpu and first-touches arenas onto
//! their tagged node. Both degrade to the simulated testbed when the
//! host layer is unavailable or too small for `--threads`. On a host
//! platform with a matching calibration cache (`--cache` to override
//! the location), the lowered cost model carries the *measured*
//! bandwidth matrix instead of the SLIT-ratio placeholder.
//!
//! `--strategy auto` asks the auto-tuner to enumerate candidate
//! strategies (TP width × sync discipline × node placement) through
//! the virtual-time cost model and run the cheapest.
//!
//! `run` and `serve` accept `--trace <path>`: turn on the runtime
//! tracer and export a Chrome `trace_event` JSON (open in Perfetto or
//! chrome://tracing) with per-worker kernel spans and barrier-wait
//! spans. `run` prints a skew/drift one-liner on exit; `serve`
//! rewrites the trace file every few seconds while running.
//!
//! Every subcommand accepts `--tier scalar|avx2|avx512|neon|auto` to
//! force the SIMD kernel tier (default: auto-detect at startup; scalar
//! is the parity oracle). `avx512` additionally needs the
//! `simd-avx512` cargo feature.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use arclight::baseline::{tune, Strategy};
use arclight::frontend::{ByteTokenizer, Engine, EngineOptions, Sampler};
use arclight::hw::{self, Platform};
use arclight::model::{synth, ModelConfig};
use arclight::numa::{BandwidthSource, Topology};
use arclight::report;
use arclight::runtime::PjrtExecutor;
use arclight::sched::SyncMode;
use arclight::simd::KernelTier;
use arclight::server::{
    BatcherConfig, Cluster, ClusterConfig, ContinuousBatcher, EngineSlot, Router, ServerHandle,
};

/// Tiny std-only flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // boolean flags (`--pin`) may be followed directly by
                // the next `--flag`; only a non-flag token is a value
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        flags.insert(key.to_string(), "true".into());
                        i += 1;
                    }
                }
            } else {
                bail!("unexpected argument '{a}'");
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Boolean flag: present and not explicitly `false`/`0`.
    fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some(v) if v != "false" && v != "0")
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

/// Resolve `--tier` into the process-wide SIMD tier. No flag (or
/// `auto`) keeps the startup detection; an unknown or unsupported tier
/// is an error rather than a silent fallback.
fn apply_tier(args: &Args) -> Result<()> {
    match args.get("tier") {
        None | Some("auto") => Ok(()),
        Some(name) => {
            let tier = KernelTier::parse(name).ok_or_else(|| {
                anyhow::anyhow!("unknown tier '{name}' (scalar|avx2|avx512|neon|auto)")
            })?;
            KernelTier::set_active(tier).map_err(|e| anyhow::anyhow!(e))
        }
    }
}

fn preset(name: &str) -> Result<ModelConfig> {
    Ok(match name {
        "tiny" => ModelConfig::tiny(),
        "small" | "small-25m" => ModelConfig::small_25m(),
        "qwen3-4b" => ModelConfig::qwen3_4b(),
        other => bail!("unknown preset '{other}' (tiny|small|qwen3-4b)"),
    })
}

fn strategy(args: &Args) -> Result<Strategy> {
    let nodes = args.usize("nodes", 1);
    Ok(match args.str_or("strategy", "arclight") {
        "arclight" if nodes <= 1 => Strategy::arclight_single(),
        "arclight" => Strategy::arclight_tp(nodes, sync_mode(args)?),
        "llama-isolate" => Strategy::llama_isolate(),
        "llama-distribute" => Strategy::llama_distribute(nodes.max(2)),
        "auto" => bail!("--strategy auto is resolved by the caller, not here"),
        other => bail!("unknown strategy '{other}' (arclight|llama-isolate|llama-distribute|auto)"),
    })
}

/// Whether the user asked the auto-tuner to pick the strategy.
fn is_auto(args: &Args) -> bool {
    args.str_or("strategy", "arclight") == "auto"
}

/// The model geometry the auto-tuner costs — the same `--model`
/// resolution as `build_model`, without building an engine.
fn model_cfg(args: &Args) -> Result<ModelConfig> {
    match args.get("model") {
        Some(path) if path.ends_with(".alf") => {
            let alf = arclight::model::AlfFile::open(&PathBuf::from(path))?;
            ModelConfig::from_json(&alf.config)
                .map_err(|e| anyhow::anyhow!("bad ALF config: {e}"))
        }
        Some(name) => preset(name),
        None => Ok(ModelConfig::small_25m()),
    }
}

/// The calibration-cache location: `--cache <path>` or the per-user
/// default.
fn cache_path(args: &Args) -> PathBuf {
    args.get("cache").map(PathBuf::from).unwrap_or_else(hw::bench::default_cache_path)
}

/// Run the auto-tuner over the node window `[base, base+window)` of
/// `topo` and report the verdict on stderr.
fn tune_window(
    args: &Args,
    topo: &Topology,
    threads: usize,
    base: usize,
    window: usize,
) -> Result<tune::TuneResult> {
    let cfg = model_cfg(args)?;
    let t = tune::auto_select(&cfg, topo, threads, base, window)
        .map_err(|e| anyhow::anyhow!("--strategy auto: {e}"))?;
    eprintln!(
        "auto strategy: {} @ node {} — predicted {:.1} µs/step ({} candidate(s), {} bandwidth)",
        t.best.strategy.name(),
        t.best.base_node,
        t.best.predicted_us,
        t.candidates.len(),
        topo.bw_source.name()
    );
    Ok(t)
}

fn sync_mode(args: &Args) -> Result<SyncMode> {
    match args.str_or("sync", "b") {
        "a" | "A" => Ok(SyncMode::SyncA),
        "b" | "B" => Ok(SyncMode::SyncB),
        other => bail!("unknown sync mode '{other}'"),
    }
}

/// Resolve `--platform sim|host` / `--pin` into a [`Platform`],
/// degrading to the simulated testbed (with a note) when host
/// detection is unavailable or the machine is smaller than `threads`.
fn platform_opt(args: &Args, threads: usize) -> Platform {
    let pin = args.flag("pin");
    let choice = args.str_or("platform", if pin { "host" } else { "sim" });
    if choice != "host" {
        return Platform::simulated();
    }
    match Platform::host_for(threads) {
        Ok(p) => {
            // a cached measured matrix (fingerprint-matched) upgrades
            // the lowering; otherwise the SLIT placeholder stands
            let p = p.with_cached_calibration(&cache_path(args));
            if p.topology().bw_source == BandwidthSource::Measured {
                eprintln!(
                    "note: using measured bandwidth matrix from {}",
                    cache_path(args).display()
                );
            }
            p
        }
        Err(why) => {
            eprintln!("note: {why}; using the simulated Kunpeng-920 testbed");
            Platform::simulated()
        }
    }
}

/// Engine options plus, when `--strategy auto` ran the tuner, the
/// winner's predicted step time (µs) for reports/metrics.
fn engine_opts(args: &Args) -> Result<(EngineOptions, Option<f64>)> {
    let threads = args.usize("threads", 4);
    let pin = args.flag("pin");
    let platform = platform_opt(args, threads);
    if platform.is_host() {
        // node-local arena placement applies to every host-platform
        // engine, pinned or not (slot baselines keep it after dropping
        // --pin); must precede engine construction — arenas are placed
        // at build
        platform.install_membind();
    }
    let (strategy, base_node, predicted) = if is_auto(args) {
        let topo = platform.topology();
        let t = tune_window(args, topo, threads, 0, topo.n_nodes())?;
        (t.best.strategy, t.best.base_node, Some(t.best.predicted_us))
    } else {
        (strategy(args)?, 0, None)
    };
    Ok((
        EngineOptions {
            strategy,
            threads,
            platform,
            prefill_rows: args.get("prefill-rows").and_then(|v| v.parse().ok()),
            seed: args.usize("seed", 0) as u64,
            batch_slots: args.usize("batch", 1),
            pin,
            page_size: args.usize("page-size", 16),
            kv_pages: args.get("kv-pages").and_then(|v| v.parse().ok()),
            base_node,
        },
        predicted,
    ))
}

/// `--model` resolution shared by the single-engine and cluster paths.
fn build_model(args: &Args, opts: &EngineOptions) -> Result<Engine> {
    match args.get("model") {
        Some(path) if path.ends_with(".alf") => Engine::from_alf(&PathBuf::from(path), opts),
        Some(name) => Engine::new_synthetic(preset(name)?, opts),
        None => Engine::new_synthetic(ModelConfig::small_25m(), opts),
    }
}

/// Resolve `--trace <path>`: turns the process-wide runtime tracer on
/// and returns where the Chrome trace should be written. Must run
/// before engines are built so pool workers bind their span rings
/// while tracing is already live.
fn trace_out(args: &Args) -> Result<Option<PathBuf>> {
    match args.get("trace") {
        None => Ok(None),
        Some("true") => bail!("--trace needs an output path, e.g. --trace out.json"),
        Some(p) => {
            arclight::trace::set_enabled(true);
            Ok(Some(PathBuf::from(p)))
        }
    }
}

/// Park the serving main thread; with `--trace`, rewrite the Chrome
/// trace every few seconds so the file tracks the newest spans.
fn serve_idle(trace_path: Option<PathBuf>) -> ! {
    loop {
        match &trace_path {
            Some(path) => {
                std::thread::sleep(std::time::Duration::from_secs(10));
                if let Err(e) = arclight::trace::export_chrome(path) {
                    eprintln!("warning: trace export failed: {e}");
                }
            }
            None => std::thread::sleep(std::time::Duration::from_secs(3600)),
        }
    }
}

fn load_engine(args: &Args) -> Result<Engine> {
    let (opts, predicted) = engine_opts(args)?;
    let mut engine = build_model(args, &opts)?;
    engine.set_predicted_step_us(predicted);
    Ok(engine)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = preset(args.str_or("preset", "small"))?;
    let out = PathBuf::from(args.str_or("out", "model.alf"));
    let seed = args.usize("seed", 0) as u64;
    synth::generate_alf(&cfg, seed, &out)?;
    println!(
        "wrote {} ({} params, {:.1} MB Q4_0 weights)",
        out.display(),
        cfg.n_params(),
        cfg.q4_weight_bytes() as f64 / 1e6
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let trace_path = trace_out(args)?;
    let mut engine = load_engine(args)?;
    let tok = ByteTokenizer;
    let prompt_text = args.str_or("prompt", "The many-core machine hummed");
    let max_new = args.usize("max-new", 64);
    let prompt = tok.encode(prompt_text, true);
    let sampler = match args.get("top-k").and_then(|v| v.parse::<usize>().ok()) {
        None | Some(1) => Sampler::greedy(),
        Some(k) => Sampler::top_k(k, 0.9, args.usize("seed", 0) as u64),
    };
    let res = engine.generate(&prompt, max_new, &sampler);
    println!("{}", tok.decode(&res.tokens));
    eprintln!(
        "prefill: {} tok in {:.3}s ({:.1} tok/s) | decode: {} tok in {:.3}s ({:.1} tok/s)",
        res.prefill_tokens,
        res.prefill_seconds,
        res.prefill_tok_per_s(),
        res.decode_tokens,
        res.decode_seconds,
        res.decode_tok_per_s()
    );
    if let Some(path) = trace_path {
        arclight::trace::export_chrome(&path)?;
        let roll = arclight::trace::global_rollup();
        let ratio = engine
            .drift_ratio()
            .map(|r| format!("{r:.2}"))
            .unwrap_or_else(|| "n/a".into());
        eprintln!(
            "trace: {} kernel + {} barrier spans -> {} | worst group skew {:.1} µs \
             (global {:.1} µs) | drift ratio {ratio} (retune recommended: {})",
            roll.kernel_spans,
            roll.barrier_spans,
            path.display(),
            roll.skew_us,
            roll.global_skew_us,
            engine.retune_recommended()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:8763");
    let trace_path = trace_out(args)?;
    let bcfg = BatcherConfig {
        queue_capacity: args.usize("queue", 256),
        max_batch: args.usize("max-batch", 8),
        batch_window: std::time::Duration::from_millis(args.usize("window-ms", 2) as u64),
    };
    if args.get("replicas").is_some() {
        if args.str_or("mode", "continuous") != "continuous" {
            bail!("--replicas implies --mode continuous");
        }
        return serve_cluster(args, addr, bcfg, trace_path);
    }
    let router = Router::new(bcfg);
    match args.str_or("mode", "continuous") {
        "continuous" => {
            // one engine, one KV pool, --batch concurrent sequences
            let batch = args.usize("batch", 8).max(2);
            let mut flags = args.flags.clone();
            flags.insert("batch".into(), batch.to_string());
            let engine = load_engine(&Args { flags }).context("building batched engine")?;
            let r = router.clone();
            std::thread::spawn(move || ContinuousBatcher::new(engine).serve(r));
            let server = ServerHandle::start(addr, router)?;
            println!(
                "arclight serving on {} (continuous batching, {batch} slots); Ctrl-C to stop",
                server.addr
            );
        }
        "slots" => {
            // sequential-slot baseline: N engines, one request at a time
            let slots = args.usize("slots", 2);
            // Every slot engine derives the same cpu map (bind_cores
            // starts at core 0), so pinning N > 1 of them would stack
            // N pools onto the same cpus. Keep the host platform (and
            // first-touch placement) but drop the pin — `--pin`
            // implied `--platform host`, so pin that choice explicitly
            // before removing the flag.
            let mut flags = args.flags.clone();
            if args.flag("pin") && slots > 1 {
                eprintln!(
                    "note: --pin disabled for --mode slots: {slots} slot engines would pin \
                     to the same cpus (oversubscription); host platform kept"
                );
                flags.entry("platform".into()).or_insert_with(|| "host".into());
                flags.remove("pin");
            }
            let slot_args = Args { flags };
            for i in 0..slots {
                let engine =
                    load_engine(&slot_args).with_context(|| format!("building slot {i}"))?;
                let r = router.clone();
                std::thread::spawn(move || EngineSlot::new(engine).serve(r));
            }
            let server = ServerHandle::start(addr, router)?;
            println!(
                "arclight serving on {} with {slots} sequential slot(s); Ctrl-C to stop",
                server.addr
            );
        }
        other => bail!("unknown serve mode '{other}' (continuous|slots)"),
    }
    serve_idle(trace_path)
}

/// `serve --replicas N|auto`: one continuous-batching engine per NUMA
/// node group, behind the cluster's placement router. Each replica is
/// built with `base_node` at its group's first node, so its workers
/// (and, with `--pin`, its arenas) live on its own nodes.
fn serve_cluster(
    args: &Args,
    addr: &str,
    bcfg: BatcherConfig,
    trace_path: Option<PathBuf>,
) -> Result<()> {
    // bare `--replicas` parses as the boolean "true" → auto
    let want = match args.str_or("replicas", "auto") {
        "auto" | "true" => None,
        n => match n.parse::<usize>() {
            Ok(v) => Some(v),
            Err(_) => bail!("--replicas takes a count or 'auto', got '{n}'"),
        },
    };
    let batch = args.usize("batch", 8).max(2);
    let (mut opts, predicted) = engine_opts(args)?;
    opts.batch_slots = batch;
    // grouping consults the (possibly measured) bandwidth matrix, so
    // nodes behind an unusually slow link get their own replica
    let groups = opts.platform.node_groups(want);
    let auto = is_auto(args);
    let cfg = ClusterConfig { batcher: bcfg, load_tolerance: args.usize("tolerance", 2) };
    let cluster = Cluster::start(&groups, cfg, |id, nodes| {
        let mut o = opts.clone();
        o.base_node = nodes[0];
        let mut predicted = predicted;
        if auto {
            // re-tune inside this replica's node window: the
            // machine-wide winner may not fit (or be optimal for) a
            // smaller group
            let t = tune_window(args, o.platform.topology(), o.threads, nodes[0], nodes.len())
                .with_context(|| format!("tuning replica {id}"))?;
            o.strategy = t.best.strategy;
            o.base_node = t.best.base_node;
            predicted = Some(t.best.predicted_us);
        }
        let mut e = build_model(args, &o)?;
        e.set_predicted_step_us(predicted);
        Ok(e)
    })?;
    let server = ServerHandle::start_cluster(addr, cluster.clone())?;
    println!(
        "arclight serving on {} ({} replica(s) × {batch} slots over node groups {:?}); \
         Ctrl-C to stop",
        server.addr,
        cluster.n_replicas(),
        groups
    );
    serve_idle(trace_path)
}

fn cmd_report(args: &Args, which: &str) -> Result<()> {
    let topo = Topology::kunpeng920();
    let cfg = preset(args.str_or("preset", "qwen3-4b"))?;
    let samples = args.usize("samples", 4);
    match which {
        "table1" => {
            let t = report::table1::bandwidth_table(&topo, topo.cores_per_node, 1.0);
            print!("{}", report::table1::render(&t));
        }
        "fig10" => {
            let series = report::figures::fig10(&cfg, &topo, samples);
            print!(
                "{}",
                report::render_table(
                    "Figure 10: decode tok/s, single NUMA node (prompt 15, gen 256)",
                    "threads",
                    &series
                )
            );
        }
        "fig11" => {
            for nodes in [2usize, 4] {
                let series = report::figures::fig11(&cfg, &topo, nodes, samples);
                let title =
                    format!("Figure 11 (N={nodes}): decode tok/s, multi-NUMA (prompt 15, gen 256)");
                print!("{}", report::render_table(&title, "threads", &series));
            }
        }
        "fig12" => {
            for nodes in [2usize, 4] {
                let series = report::figures::fig12(&cfg, &topo, nodes, samples);
                print!(
                    "{}",
                    report::render_table(
                        &format!("Figure 12 (N={nodes}): decode tok/s, prompt 300"),
                        "threads",
                        &series
                    )
                );
            }
        }
        "fig13" => {
            for nodes in [2usize, 4] {
                let series = report::figures::fig13(&cfg, &topo, nodes);
                print!(
                    "{}",
                    report::render_table(
                        &format!("Figure 13 (N={nodes}): prefill tok/s, prompt 300"),
                        "threads",
                        &series
                    )
                );
            }
        }
        "all" => {
            for f in ["table1", "fig10", "fig11", "fig12", "fig13"] {
                cmd_report(args, f)?;
                println!();
            }
        }
        other => bail!("unknown report '{other}' (table1|fig10|fig11|fig12|fig13|all)"),
    }
    Ok(())
}

fn cmd_probe(args: &Args) -> Result<()> {
    let topo = Topology::kunpeng920();
    println!(
        "simulated platform: {} NUMA nodes × {} cores = {} cores",
        topo.n_nodes(),
        topo.cores_per_node,
        topo.n_cores()
    );
    println!("core f32 rate: {:.1} GFLOP/s", topo.core_flops / 1e9);
    let readers = args.usize("readers", topo.cores_per_node);
    let t = report::table1::bandwidth_table(&topo, readers, 1.0);
    print!("{}", report::table1::render(&t));
    Ok(())
}

/// `arclight topo`: the detected host NUMA machine (with its measured
/// bandwidth matrix, when calibrated) next to the simulated testbed
/// the figures run on.
fn cmd_topo(args: &Args) -> Result<()> {
    println!("host pinning support compiled in: {}", hw::affinity::available());
    println!(
        "kernel tier: {} active ({} detected)",
        KernelTier::active(),
        KernelTier::detect()
    );
    let cache = cache_path(args);
    let detected = Platform::detect().with_cached_calibration(&cache);
    match &detected {
        Platform::Host { host, topo } => {
            println!(
                "detected host platform: {} NUMA node(s), {} online cpu(s)",
                host.n_nodes(),
                host.total_cpus()
            );
            for n in &host.nodes {
                println!(
                    "  node {}: {:3} cpus [{}]  mem {:.1} GiB",
                    n.id,
                    n.cpus.len(),
                    hw::topology::format_cpulist(&n.cpus),
                    n.mem_total_kb as f64 / (1024.0 * 1024.0)
                );
            }
            println!("  SLIT distances:");
            for row in &host.distance {
                let cells: Vec<String> = row.iter().map(|d| format!("{d:3}")).collect();
                println!("    {}", cells.join(" "));
            }
            println!(
                "  lowered model: {} nodes x {} cores, local bw {:.0} GB/s ({} bandwidth)",
                topo.n_nodes(),
                topo.cores_per_node,
                topo.bandwidth(0, 0) / 1e9,
                topo.bw_source.name()
            );
            match hw::bench::Calibration::load(&cache) {
                Ok(cal) if cal.fingerprint == host.fingerprint() => {
                    print_matrix("  measured node-pair bandwidth (GB/s)", &cal.matrix_gb);
                }
                Ok(_) => {
                    eprintln!(
                        "warning: calibration cache {} was measured on a different topology \
                         (fingerprint mismatch) — re-run `arclight calibrate`",
                        cache.display()
                    );
                }
                Err(_) => {
                    println!(
                        "  no usable calibration cache at {} — run `arclight calibrate` to \
                         measure real bandwidths",
                        cache.display()
                    );
                }
            }
        }
        Platform::Simulated(_) => {
            println!(
                "no host NUMA topology detected (feature `host` off, non-Linux, or no sysfs \
                 tree) — engines fall back to the simulated testbed"
            );
        }
    }
    let sim = Topology::kunpeng920();
    println!(
        "simulated testbed (paper): {} NUMA nodes x {} cores = {} cores, local {:.0} / \
         remote ~{:.0} GB/s",
        sim.n_nodes(),
        sim.cores_per_node,
        sim.n_cores(),
        sim.bandwidth(0, 0) / 1e9,
        sim.bandwidth(0, 1) / 1e9
    );
    Ok(())
}

/// Render a node-pair GB/s matrix (rows: core node, cols: mem node).
fn print_matrix(title: &str, m: &[Vec<f64>]) {
    println!("{title}:");
    let header: Vec<String> = (0..m.len()).map(|j| format!("{j:>8}")).collect();
    println!("    core\\mem {}", header.join(""));
    for (i, row) in m.iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|g| format!("{g:8.1}")).collect();
        println!("    node {i:<4}{}", cells.join(""));
    }
}

/// `arclight calibrate`: measure (or load from cache) the node-pair
/// bandwidth matrix and store it keyed by the topology fingerprint.
fn cmd_calibrate(args: &Args) -> Result<()> {
    let host = match args.get("root") {
        Some(root) => hw::HostTopology::from_root(std::path::Path::new(root))
            .ok_or_else(|| anyhow::anyhow!("no NUMA topology under {root}"))?,
        None => hw::HostTopology::discover().ok_or_else(|| {
            anyhow::anyhow!(
                "no host NUMA topology detected (feature `host` off, non-Linux, or no sysfs \
                 tree); pass --root <dir> to calibrate against a fixture tree"
            )
        })?,
    };
    let quick = args.flag("quick");
    let opts = if quick { hw::bench::BenchOpts::quick() } else { hw::bench::BenchOpts::default() };
    let path = cache_path(args);
    let out = hw::bench::calibrate(&host, &path, args.flag("force"), &opts)?;
    println!("topology fingerprint: {}", out.cal.fingerprint);
    println!(
        "cache {}: {}",
        path.display(),
        if out.from_cache { "hit (zero re-measurement)" } else { "measured and stored" }
    );
    if quick && !out.from_cache {
        eprintln!("note: --quick numbers are cache-hot smoke values, not real bandwidths");
    }
    print_matrix("measured node-pair bandwidth (GB/s)", &out.cal.matrix_gb);
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let topo = Topology::kunpeng920();
    let cfg = preset(args.str_or("preset", "qwen3-4b"))?;
    let s = strategy(args)?;
    let threads = args.usize("threads", 192);
    let spec = s.build_spec(cfg, topo.n_nodes()).with_sim_only(true);
    let m = arclight::model::ModelGraphs::build(spec);
    let cores = s.bind_cores(&topo, threads);
    let (_, tp) = s.organizations(&cores);
    let events = arclight::report::trace::trace_pass(
        &m.decode,
        &arclight::numa::CostModel::new(topo),
        &cores,
        &tp,
        arclight::sched::ExecParams::dense(args.usize("pos", 100), 1),
    );
    let out = args.str_or("out", "decode_trace.json");
    std::fs::write(out, arclight::report::trace::to_chrome_json(&events))?;
    let total: f64 = events.iter().map(|e| e.start_us + e.dur_us).fold(0.0, f64::max);
    println!(
        "wrote {} events ({:.2} ms virtual decode step) to {out} — open in chrome://tracing",
        events.len(),
        total / 1e3
    );
    Ok(())
}

fn cmd_golden(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let pjrt = PjrtExecutor::load(&dir)?;
    let prompt: Vec<i32> = (0..pjrt.session.manifest.prompt_len as i32).collect();
    let max_new = 8usize;

    let opts = EngineOptions {
        strategy: Strategy::arclight_single(),
        threads: 2,
        platform: Platform::simulated(),
        prefill_rows: Some(prompt.len()),
        seed: 0,
        batch_slots: 1,
        pin: false,
        page_size: 16,
        kv_pages: None,
        base_node: 0,
    };
    let mut engine = Engine::from_alf(&dir.join("tiny.alf"), &opts)?;
    let res = engine.generate(&prompt, max_new, &Sampler::greedy());

    // Drive the PJRT backend through the same object-safe `Executor`
    // API the native engine routes every pass through.
    let graph = engine.graphs.decode.clone();
    let pjrt_tokens = pjrt.generate_greedy(&graph, &prompt, max_new);
    if pjrt_tokens == res.tokens {
        println!("golden check OK: native engine matches PJRT ({pjrt_tokens:?})");
        Ok(())
    } else {
        bail!("golden mismatch: pjrt {pjrt_tokens:?} vs native {:?}", res.tokens)
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(String::as_str) else {
        eprintln!(
            "usage: arclight <generate|run|serve|report|probe|topo|calibrate|trace|golden> \
             [--flags]"
        );
        std::process::exit(2);
    };
    let rest = Args::parse(&argv[1..])?;
    apply_tier(&rest)?;
    match cmd {
        "generate" => cmd_generate(&rest),
        "run" => cmd_run(&rest),
        "serve" => cmd_serve(&rest),
        "report" => {
            let which = rest.str_or("figure", "all").to_string();
            cmd_report(&rest, &which)
        }
        "probe" => cmd_probe(&rest),
        "topo" => cmd_topo(&rest),
        "calibrate" => cmd_calibrate(&rest),
        "trace" => cmd_trace(&rest),
        "golden" => cmd_golden(&rest),
        other => bail!("unknown command '{other}'"),
    }
}
