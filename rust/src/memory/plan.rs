//! Activation memory planning — double buffering vs linear (Fig. 4).
//!
//! The planner answers one question at graph-build time: *which
//! activation arena does tensor T go to?* ArcLight alternates two
//! buffers by layer parity; the ablation baseline gives every activation
//! its own slot (what a naive static graph does). The footprint gap is
//! the paper's "significantly lowering runtime memory consumption".

/// Activation placement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    /// Two arenas alternated by layer parity (ArcLight, Fig. 4).
    DoubleBuffered,
    /// One linear region, every tensor gets a fresh slot (ablation).
    Linear,
}

/// Tracks activation allocation bookkeeping during graph construction
/// and reports the peak footprint each policy needs.
#[derive(Clone, Debug)]
pub struct ActivationPlanner {
    mode: PlanMode,
    /// Peak bytes of each parity buffer (double-buffered mode).
    peak: [usize; 2],
    /// Bytes currently allocated in each parity buffer for the layer
    /// being built.
    cur: [usize; 2],
    /// Total bytes in linear mode.
    linear_total: usize,
    layer: usize,
}

impl ActivationPlanner {
    pub fn new(mode: PlanMode) -> Self {
        ActivationPlanner { mode, peak: [0; 2], cur: [0; 2], linear_total: 0, layer: 0 }
    }

    pub fn mode(&self) -> PlanMode {
        self.mode
    }

    /// Current layer parity (selects the activation arena).
    pub fn parity(&self) -> usize {
        self.layer & 1
    }

    /// Enter layer `i`: in double-buffered mode the parity buffer that is
    /// about to be reused is recycled (its previous tenants — layer
    /// `i-2`'s activations — are dead by graph construction order).
    pub fn enter_layer(&mut self, layer: usize) {
        self.layer = layer;
        if self.mode == PlanMode::DoubleBuffered {
            self.cur[layer & 1] = 0;
        }
    }

    /// Record an activation allocation of `bytes`; returns the parity
    /// arena index to allocate in (always 0 in linear mode).
    pub fn note_alloc(&mut self, bytes: usize) -> usize {
        let aligned = crate::util::align_up(bytes, 64);
        match self.mode {
            PlanMode::DoubleBuffered => {
                let p = self.parity();
                self.cur[p] += aligned;
                self.peak[p] = self.peak[p].max(self.cur[p]);
                p
            }
            PlanMode::Linear => {
                self.linear_total += aligned;
                0
            }
        }
    }

    /// Peak activation footprint this plan requires (bytes).
    pub fn footprint(&self) -> usize {
        match self.mode {
            PlanMode::DoubleBuffered => self.peak[0] + self.peak[1],
            PlanMode::Linear => self.linear_total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simulate(mode: PlanMode, layers: usize, per_layer: usize) -> usize {
        let mut p = ActivationPlanner::new(mode);
        for l in 0..layers {
            p.enter_layer(l);
            for _ in 0..4 {
                p.note_alloc(per_layer / 4);
            }
        }
        p.footprint()
    }

    #[test]
    fn double_buffering_is_constant_in_depth() {
        let d8 = simulate(PlanMode::DoubleBuffered, 8, 1 << 20);
        let d32 = simulate(PlanMode::DoubleBuffered, 32, 1 << 20);
        assert_eq!(d8, d32);
    }

    #[test]
    fn linear_grows_with_depth() {
        let l8 = simulate(PlanMode::Linear, 8, 1 << 20);
        let l32 = simulate(PlanMode::Linear, 32, 1 << 20);
        assert_eq!(l32, 4 * l8);
    }

    #[test]
    fn double_buffering_saves_memory() {
        // the paper's Fig. 4 claim, in numbers: 36 layers → 18× saving
        let db = simulate(PlanMode::DoubleBuffered, 36, 1 << 20);
        let lin = simulate(PlanMode::Linear, 36, 1 << 20);
        assert_eq!(lin / db, 18);
    }

    #[test]
    fn parity_alternates() {
        let mut p = ActivationPlanner::new(PlanMode::DoubleBuffered);
        p.enter_layer(0);
        assert_eq!(p.note_alloc(100), 0);
        p.enter_layer(1);
        assert_eq!(p.note_alloc(100), 1);
        p.enter_layer(2);
        assert_eq!(p.note_alloc(100), 0);
    }
}
