//! A NUMA-tagged bump arena.
//!
//! Buffers are carved out single-threaded at *plan* time (`&mut self`);
//! at *execution* time many worker threads read and write disjoint
//! regions concurrently through raw-pointer views. The partitioner is
//! responsible for disjointness (each worker owns a distinct row range
//! of each output tensor); the unsafe accessors document that contract.

use std::cell::UnsafeCell;

use crate::numa::NodeId;
use crate::util::align_up;

const ALIGN: usize = 64;

/// A reference to a byte range inside one arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BufRef {
    pub arena: usize,
    pub off: usize,
    pub len: usize,
}

impl BufRef {
    /// Number of f32 elements this buffer holds.
    pub fn f32_len(&self) -> usize {
        self.len / 4
    }
}

/// Fixed-capacity bump allocator tagged with its home NUMA node.
pub struct Arena {
    node: NodeId,
    used: usize,
    data: UnsafeCell<Box<[u8]>>,
}

// Safety: concurrent access goes through the unsafe slice accessors whose
// callers guarantee disjointness (see module docs).
unsafe impl Sync for Arena {}
unsafe impl Send for Arena {}

impl Arena {
    /// Allocate the zeroed backing store through
    /// [`crate::hw::membind::alloc_arena`] — the one centralized place
    /// arena *placement* is decided. The old `vec![0u8; capacity]`
    /// path hid a first-touch hazard: whichever thread faulted the
    /// pages in decided which NUMA node they landed on, regardless of
    /// the `node` tag. The membind path allocates untouched kernel
    /// zero pages and (when a placement map is installed) faults them
    /// in from a thread pinned to `node`.
    pub fn new(node: NodeId, capacity: usize) -> Self {
        Arena {
            node,
            used: 0,
            data: UnsafeCell::new(crate::hw::membind::alloc_arena(node, capacity)),
        }
    }

    pub fn node(&self) -> NodeId {
        self.node
    }

    pub fn capacity(&self) -> usize {
        unsafe { (&*self.data.get()).len() }
    }

    pub fn used(&self) -> usize {
        self.used
    }

    /// Bump-allocate `bytes` (64-byte aligned). Panics on exhaustion:
    /// pools are sized up front from the model definition.
    pub fn alloc(&mut self, bytes: usize) -> usize {
        let off = align_up(self.used, ALIGN);
        assert!(
            off + bytes <= self.capacity(),
            "arena on node {} exhausted: need {} at {}, capacity {}",
            self.node,
            bytes,
            off,
            self.capacity()
        );
        self.used = off + bytes;
        off
    }

    /// Recycle the whole arena (activation buffers between steps).
    pub fn reset(&mut self) {
        self.used = 0;
    }

    /// Rewind the bump pointer (double-buffering: layer `i` reclaims the
    /// space layer `i-2` used; the planner guarantees those tensors are
    /// dead). Panics if rewinding forward.
    pub fn rewind(&mut self, to: usize) {
        assert!(to <= self.used, "rewind {} past used {}", to, self.used);
        self.used = to;
    }

    /// Immutable f32 view of `[off, off+len*4)`.
    ///
    /// # Safety
    /// No concurrent writer may overlap the range; `off` must be 4-aligned
    /// and within capacity.
    pub unsafe fn f32s(&self, off: usize, len: usize) -> &[f32] {
        debug_assert!(off % 4 == 0 && off + len * 4 <= self.capacity());
        let base = (*self.data.get()).as_ptr().add(off) as *const f32;
        std::slice::from_raw_parts(base, len)
    }

    /// Mutable f32 view.
    ///
    /// # Safety
    /// The range must be disjoint from every other live view (the op
    /// partitioner hands each worker a distinct row range).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn f32s_mut(&self, off: usize, len: usize) -> &mut [f32] {
        debug_assert!(off % 4 == 0 && off + len * 4 <= self.capacity());
        let base = (*self.data.get()).as_mut_ptr().add(off) as *mut f32;
        std::slice::from_raw_parts_mut(base, len)
    }

    /// Immutable byte view (quantized weights).
    ///
    /// # Safety
    /// As [`Arena::f32s`].
    pub unsafe fn bytes(&self, off: usize, len: usize) -> &[u8] {
        debug_assert!(off + len <= self.capacity());
        let base = (*self.data.get()).as_ptr().add(off);
        std::slice::from_raw_parts(base, len)
    }

    /// Mutable byte view.
    ///
    /// # Safety
    /// As [`Arena::f32s_mut`].
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn bytes_mut(&self, off: usize, len: usize) -> &mut [u8] {
        debug_assert!(off + len <= self.capacity());
        let base = (*self.data.get()).as_mut_ptr().add(off);
        std::slice::from_raw_parts_mut(base, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_aligned_and_monotonic() {
        let mut a = Arena::new(0, 4096);
        let x = a.alloc(10);
        let y = a.alloc(10);
        assert_eq!(x % 64, 0);
        assert_eq!(y % 64, 0);
        assert!(y >= x + 10);
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn alloc_panics_on_exhaustion() {
        let mut a = Arena::new(0, 128);
        a.alloc(100);
        a.alloc(100);
    }

    #[test]
    fn views_roundtrip() {
        let mut a = Arena::new(1, 1024);
        let off = a.alloc(16 * 4);
        unsafe {
            let w = a.f32s_mut(off, 16);
            for (i, v) in w.iter_mut().enumerate() {
                *v = i as f32;
            }
            let r = a.f32s(off, 16);
            assert_eq!(r[7], 7.0);
        }
    }

    #[test]
    fn disjoint_concurrent_writes() {
        let mut a = Arena::new(0, 4096);
        let off = a.alloc(64 * 4);
        let a = std::sync::Arc::new(a);
        let mut handles = Vec::new();
        for t in 0..4usize {
            let a = a.clone();
            handles.push(std::thread::spawn(move || unsafe {
                let s = a.f32s_mut(off + t * 16 * 4, 16);
                for v in s.iter_mut() {
                    *v = t as f32;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        unsafe {
            let all = a.f32s(off, 64);
            for t in 0..4 {
                assert!(all[t * 16..(t + 1) * 16].iter().all(|&v| v == t as f32));
            }
        }
    }

    #[test]
    fn fresh_arena_reads_zero() {
        // the membind allocation path must preserve the zeroed-storage
        // contract the old vec![0u8; capacity] provided
        let mut a = Arena::new(2, 4096);
        let off = a.alloc(256 * 4);
        unsafe {
            assert!(a.f32s(off, 256).iter().all(|&v| v == 0.0));
            assert!(a.bytes(0, 4096).iter().all(|&b| b == 0));
        }
        assert_eq!(a.node(), 2);
        // zero-capacity arenas are legal (unused KV pools)
        let z = Arena::new(0, 0);
        assert_eq!(z.capacity(), 0);
    }

    #[test]
    fn reset_recycles() {
        let mut a = Arena::new(0, 256);
        a.alloc(64);
        a.reset();
        assert_eq!(a.used(), 0);
        assert_eq!(a.alloc(64), 0);
    }
}
