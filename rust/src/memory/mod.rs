//! Memory manager (paper §2.3, Figs. 3–4).
//!
//! ArcLight pre-allocates a memory pool at startup and carves weight and
//! activation tensors out of it. Unlike llama.cpp's single UMA buffer,
//! the pool keeps **separate arenas per NUMA node** so tensor→node
//! binding is explicit, plus a **double-buffered activation region**:
//! layer `i`'s activations live in buffer `i % 2`, halving activation
//! footprint relative to linear per-tensor allocation.
//!
//! In this reproduction the "NUMA node" of an arena is a tag consumed by
//! the cost model (the host has one node); the allocation discipline —
//! pools, alignment, parity switching, no allocation on the hot path —
//! is the real ArcLight design.

pub mod arena;
pub mod plan;

pub use arena::{Arena, BufRef};
pub use plan::{ActivationPlanner, PlanMode};

use crate::numa::NodeId;

/// The engine's memory pool: per-node weight arenas, per-node KV arenas
/// and per-node × per-parity activation arenas.
pub struct MemoryPool {
    arenas: Vec<Arena>,
    weight: Vec<usize>,
    kv: Vec<usize>,
    /// `act[node][parity]`
    act: Vec<[usize; 2]>,
}

impl MemoryPool {
    /// Pre-allocate for `n_nodes` nodes with the given per-node budgets
    /// (bytes). Panics later on exhaustion — ArcLight sizes pools from
    /// the model definition before inference starts.
    pub fn new(n_nodes: usize, weight_bytes: usize, kv_bytes: usize, act_bytes: usize) -> Self {
        let mut arenas = Vec::new();
        let mut weight = Vec::new();
        let mut kv = Vec::new();
        let mut act = Vec::new();
        for node in 0..n_nodes {
            weight.push(arenas.len());
            arenas.push(Arena::new(node, weight_bytes));
            kv.push(arenas.len());
            arenas.push(Arena::new(node, kv_bytes));
            let a = arenas.len();
            arenas.push(Arena::new(node, act_bytes));
            let b = arenas.len();
            arenas.push(Arena::new(node, act_bytes));
            act.push([a, b]);
        }
        MemoryPool { arenas, weight, kv, act }
    }

    pub fn arena(&self, id: usize) -> &Arena {
        &self.arenas[id]
    }

    pub fn arena_mut(&mut self, id: usize) -> &mut Arena {
        &mut self.arenas[id]
    }

    pub fn weight_arena(&self, node: NodeId) -> usize {
        self.weight[node]
    }

    pub fn kv_arena(&self, node: NodeId) -> usize {
        self.kv[node]
    }

    pub fn act_arena(&self, node: NodeId, parity: usize) -> usize {
        self.act[node][parity & 1]
    }

    /// Allocate in a specific arena; returns a [`BufRef`].
    pub fn alloc(&mut self, arena: usize, bytes: usize) -> BufRef {
        let off = self.arenas[arena].alloc(bytes);
        BufRef { arena, off, len: bytes }
    }

    /// Total bytes currently allocated across all arenas (footprint
    /// metric for the double-buffering ablation).
    pub fn allocated_bytes(&self) -> usize {
        self.arenas.iter().map(|a| a.used()).sum()
    }

    /// Reset the two activation arenas (between decode steps the
    /// activation region is recycled wholesale — no per-tensor frees).
    pub fn reset_activations(&mut self) {
        for pair in &self.act {
            for &id in pair {
                self.arenas[id].reset();
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.weight.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_layout_per_node() {
        let p = MemoryPool::new(2, 1024, 512, 256);
        assert_eq!(p.n_nodes(), 2);
        assert_ne!(p.weight_arena(0), p.weight_arena(1));
        assert_ne!(p.act_arena(0, 0), p.act_arena(0, 1));
        assert_eq!(p.act_arena(0, 2), p.act_arena(0, 0)); // parity wraps
        assert_eq!(p.arena(p.weight_arena(1)).node(), 1);
    }

    #[test]
    fn alloc_and_reset() {
        let mut p = MemoryPool::new(1, 1024, 0, 128);
        let a = p.act_arena(0, 0);
        let r1 = p.alloc(a, 64);
        let r2 = p.alloc(a, 32);
        assert_ne!(r1.off, r2.off);
        assert!(p.allocated_bytes() >= 96);
        p.reset_activations();
        let r3 = p.alloc(a, 64);
        assert_eq!(r3.off, r1.off); // recycled from the start
    }
}
