//! Serving metrics: latency histograms, token-throughput counters and
//! continuous-batching gauges (queue wait, batch occupancy).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::stats::Summary;

/// Length of the sliding window behind `tokens_per_s_window` — long
/// enough to smooth step-boundary jitter, short enough that the gauge
/// reflects *current* load instead of decaying toward 0 across idle
/// gaps the way the lifetime rate does.
pub const TOKENS_WINDOW_S: f64 = 10.0;

/// Per-replica serving gauges — one per [`crate::server::Cluster`]
/// engine. The cluster's router reads `live_lanes`/`queue_depth` for
/// placement; the metrics snapshot renders one entry per replica under
/// `replicas` while the top-level [`Metrics`] fields stay aggregates
/// across the whole cluster.
pub struct ReplicaStats {
    /// Replica index within the cluster (0 for a single engine).
    pub id: usize,
    /// NUMA nodes of the replica's placement group.
    pub nodes: Vec<usize>,
    /// Lanes decoding in the replica's running batch (gauge).
    pub live_lanes: AtomicU64,
    /// Requests waiting in the replica's admission queue (gauge).
    pub queue_depth: AtomicU64,
    /// Tokens this replica decoded since serve start.
    pub tokens_decoded: AtomicU64,
    /// Prompt tokens this replica served from prefix-shared KV pages.
    pub prefix_hit_tokens: AtomicU64,
    /// KV pages held in this replica's arena after its last step.
    pub kv_pages_used: AtomicU64,
    /// Total pages in this replica's KV arena.
    pub kv_pages_total: AtomicU64,
    started: Instant,
    /// `(elapsed_s, total tokens_decoded)` samples taken at step
    /// boundaries, pruned to [`TOKENS_WINDOW_S`] — the windowed
    /// throughput gauge.
    window: Mutex<VecDeque<(f64, u64)>>,
    /// EWMA of this replica's measured decode-step time (µs), stored
    /// as f64 bits (NaN = no samples yet). Only the replica's batcher
    /// thread writes; the snapshot thread reads.
    step_ewma_bits: AtomicU64,
    /// Decode steps folded into the EWMA.
    step_samples: AtomicU64,
    /// The replica engine's tuner prediction (µs), f64 bits (NaN =
    /// explicit strategy, no prediction).
    predicted_bits: AtomicU64,
}

impl ReplicaStats {
    pub fn new(id: usize, nodes: Vec<usize>) -> Self {
        ReplicaStats {
            id,
            nodes,
            live_lanes: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            tokens_decoded: AtomicU64::new(0),
            prefix_hit_tokens: AtomicU64::new(0),
            kv_pages_used: AtomicU64::new(0),
            kv_pages_total: AtomicU64::new(0),
            started: Instant::now(),
            window: Mutex::new(VecDeque::new()),
            step_ewma_bits: AtomicU64::new(f64::NAN.to_bits()),
            step_samples: AtomicU64::new(0),
            predicted_bits: AtomicU64::new(f64::NAN.to_bits()),
        }
    }

    /// First node of the placement group — the `node` stamped into
    /// response provenance.
    pub fn home_node(&self) -> usize {
        self.nodes.first().copied().unwrap_or(0)
    }

    /// Instantaneous load the router scores: lanes decoding now plus
    /// requests already committed to this replica's queue.
    pub fn load(&self) -> usize {
        (self.live_lanes.load(Ordering::Relaxed) + self.queue_depth.load(Ordering::Relaxed))
            as usize
    }

    /// Lifetime decode rate of this replica (token/s since serve
    /// start). Decays toward 0 across idle gaps — pair it with
    /// [`ReplicaStats::tokens_per_s_window`] for current load.
    pub fn tokens_per_s(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed == 0.0 {
            return 0.0;
        }
        self.tokens_decoded.load(Ordering::Relaxed) as f64 / elapsed
    }

    /// Sample the windowed-throughput gauge (called by the replica's
    /// batcher at step boundaries): record one `(elapsed, total
    /// tokens)` point and prune samples that fell out of the window.
    pub fn sample_window(&self) {
        let now = self.started.elapsed().as_secs_f64();
        let total = self.tokens_decoded.load(Ordering::Relaxed);
        let mut w = self.window.lock().unwrap();
        w.push_back((now, total));
        while let Some(&(t, _)) = w.front() {
            if now - t > TOKENS_WINDOW_S && w.len() > 2 {
                w.pop_front();
            } else {
                break;
            }
        }
    }

    /// Decode throughput over the recent sampling window (token/s):
    /// token delta over time delta of the retained samples. 0 until
    /// two samples exist.
    pub fn tokens_per_s_window(&self) -> f64 {
        let w = self.window.lock().unwrap();
        match (w.front(), w.back()) {
            (Some(&(t0, c0)), Some(&(t1, c1))) if t1 > t0 => (c1 - c0) as f64 / (t1 - t0),
            _ => 0.0,
        }
    }

    /// Fold one measured decode-step time (µs) into this replica's
    /// EWMA and refresh the engine's tuner prediction next to it.
    /// Called by the replica's batcher thread only.
    pub fn record_step_time(&self, us: f64, predicted_us: Option<f64>) {
        let next = crate::trace::ewma_fold(self.step_ewma_us(), us);
        self.step_ewma_bits.store(next.to_bits(), Ordering::Relaxed);
        self.step_samples.fetch_add(1, Ordering::Relaxed);
        self.predicted_bits
            .store(predicted_us.unwrap_or(f64::NAN).to_bits(), Ordering::Relaxed);
    }

    /// EWMA of this replica's measured decode-step time (µs); `None`
    /// before the first recorded step.
    pub fn step_ewma_us(&self) -> Option<f64> {
        let v = f64::from_bits(self.step_ewma_bits.load(Ordering::Relaxed));
        if v.is_nan() {
            None
        } else {
            Some(v)
        }
    }

    fn predicted_step_us(&self) -> Option<f64> {
        let v = f64::from_bits(self.predicted_bits.load(Ordering::Relaxed));
        if v.is_nan() {
            None
        } else {
            Some(v)
        }
    }

    /// This replica's drift verdict: `(measured/predicted ratio,
    /// retune_recommended)` per [`crate::trace::drift_verdict`].
    pub fn drift(&self) -> (Option<f64>, bool) {
        crate::trace::drift_verdict(
            self.step_ewma_us(),
            self.predicted_step_us(),
            self.step_samples.load(Ordering::Relaxed) as usize,
        )
    }

    /// One entry of the snapshot's `replicas` array.
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let load = |c: &AtomicU64| c.load(Ordering::Relaxed) as usize;
        let (drift_ratio, retune) = self.drift();
        obj(vec![
            ("replica", self.id.into()),
            ("node", self.home_node().into()),
            ("nodes", self.nodes.clone().into()),
            ("live_lanes", load(&self.live_lanes).into()),
            ("queue_depth", load(&self.queue_depth).into()),
            ("tokens_decoded", load(&self.tokens_decoded).into()),
            ("tokens_per_s", self.tokens_per_s().into()),
            ("tokens_per_s_window", self.tokens_per_s_window().into()),
            ("prefix_hit_tokens", load(&self.prefix_hit_tokens).into()),
            ("kv_pages_used", load(&self.kv_pages_used).into()),
            ("kv_pages_total", load(&self.kv_pages_total).into()),
            ("step_ewma_us", self.step_ewma_us().map(Json::from).unwrap_or(Json::Null)),
            ("drift_ratio", drift_ratio.map(Json::from).unwrap_or(Json::Null)),
            ("retune_recommended", retune.into()),
        ])
    }
}

/// Process-wide serving metrics (shared by server workers).
#[derive(Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub requests_failed: AtomicU64,
    pub tokens_prefilled: AtomicU64,
    pub tokens_decoded: AtomicU64,
    /// Batched decode steps executed (continuous batching).
    pub decode_steps: AtomicU64,
    /// Lanes summed over all steps; occupancy = lanes / steps.
    pub decode_lanes: AtomicU64,
    /// Pool dispatches summed over all steps. With the compiled-pass
    /// scheduler this is 1 per step, so `dispatches_per_token` ≈
    /// 1/lanes — the legacy per-op walk paid ≈`ops` per step.
    pub pass_dispatches: AtomicU64,
    /// Workers the serving engine's pool pinned to host cpus.
    pub pinned_workers: AtomicU64,
    /// High-water mark of concurrently live sequences (paged KV lets
    /// this exceed the slot count of the dense-era scheduler).
    pub peak_seqs: AtomicU64,
    /// Prompt tokens served from prefix-shared KV pages instead of
    /// being prefilled (summed over all admitted requests).
    pub prefix_hit_tokens: AtomicU64,
    /// KV pages held by live sequences after the last batched step.
    pub kv_pages_used: AtomicU64,
    /// Total pages in the serving engine's KV arena.
    pub kv_pages_total: AtomicU64,
    /// Execution platform of the serving engine (`"simulated"` /
    /// `"host"`; empty until a scheduler registers its engine).
    platform: Mutex<&'static str>,
    /// Strategy the serving engine runs (explicit or auto-selected;
    /// empty until a scheduler registers its engine).
    strategy_chosen: Mutex<String>,
    /// Provenance of the bandwidth matrix behind the engine's topology
    /// (`"measured"` / `"slit-placeholder"` / `"simulated"`).
    bandwidth_source: Mutex<&'static str>,
    /// Auto-tuner step-time prediction (µs) when `--strategy auto`
    /// picked the strategy; `None` otherwise.
    predicted_step_us: Mutex<Option<f64>>,
    latency: Mutex<Summary>,
    ttft: Mutex<Summary>,
    /// Enqueue → admission into the running batch.
    queue_wait: Mutex<Summary>,
    /// Per-request decode throughput (token/s), for p50/p95 reporting
    /// next to the process-wide aggregate.
    req_decode_tok_s: Mutex<Summary>,
    /// Registered cluster replicas, in id order. Empty outside cluster
    /// serving; when populated, the snapshot's `kv_pages_*` aggregates
    /// sum over these instead of the process-wide gauges (each replica
    /// owns its own arena).
    replicas: Mutex<Vec<Arc<ReplicaStats>>>,
    start: Mutex<Option<Instant>>,
    /// Aggregate drift state: `(EWMA of measured decode-step time in
    /// µs, samples folded)` — compared against `predicted_step_us` in
    /// the snapshot's `drift` block. Per-replica EWMAs live in
    /// [`ReplicaStats`].
    step_drift: Mutex<(Option<f64>, usize)>,
    /// Barrier-skew gauges folded from traced passes (`None` until a
    /// traced pass reported a rollup).
    barrier_skew: Mutex<Option<SkewAgg>>,
}

/// Folded barrier-skew gauges across traced passes (the straggler
/// gauge feeding the snapshot's `barrier_skew` block).
#[derive(Clone, Copy, Debug, Default)]
struct SkewAgg {
    last_us: f64,
    max_us: f64,
    last_global_us: f64,
    last_barrier_wait_us: f64,
    samples: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { start: Mutex::new(Some(Instant::now())), ..Default::default() }
    }

    pub fn record_request(
        &self,
        prefill_tokens: usize,
        decode_tokens: usize,
        ttft_s: f64,
        total_s: f64,
        decode_tok_per_s: f64,
    ) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
        self.tokens_prefilled.fetch_add(prefill_tokens as u64, Ordering::Relaxed);
        self.tokens_decoded.fetch_add(decode_tokens as u64, Ordering::Relaxed);
        self.latency.lock().unwrap().add(total_s);
        self.ttft.lock().unwrap().add(ttft_s);
        if decode_tok_per_s > 0.0 {
            self.req_decode_tok_s.lock().unwrap().add(decode_tok_per_s);
        }
    }

    pub fn record_failure(&self) {
        self.requests_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Register the serving engine's execution platform and pin count
    /// (called by each scheduler at serve start). Last registration
    /// wins: with several sequential slot engines the values describe
    /// one engine's pool, not a sum across slots.
    pub fn set_platform(&self, platform: &'static str, pinned_workers: usize) {
        *self.platform.lock().unwrap() = platform;
        self.pinned_workers.store(pinned_workers as u64, Ordering::Relaxed);
    }

    /// Register the serving engine's strategy and bandwidth provenance
    /// (same last-registration-wins contract as
    /// [`Metrics::set_platform`]).
    pub fn set_strategy(
        &self,
        strategy: &str,
        bandwidth_source: &'static str,
        predicted_step_us: Option<f64>,
    ) {
        *self.strategy_chosen.lock().unwrap() = strategy.to_string();
        *self.bandwidth_source.lock().unwrap() = bandwidth_source;
        *self.predicted_step_us.lock().unwrap() = predicted_step_us;
    }

    /// One continuous-batching step that processed `lanes` lanes with
    /// `dispatches` pool dispatches (1 under the PassPlan scheduler).
    pub fn record_step(&self, lanes: usize, dispatches: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.decode_lanes.fetch_add(lanes as u64, Ordering::Relaxed);
        self.pass_dispatches.fetch_add(dispatches as u64, Ordering::Relaxed);
    }

    /// Fold one measured decode-step time (µs) into the aggregate
    /// drift EWMA (the hook the per-phase re-tuner consumes via the
    /// snapshot's `drift` block).
    pub fn record_step_time(&self, us: f64) {
        let mut d = self.step_drift.lock().unwrap();
        d.0 = Some(crate::trace::ewma_fold(d.0, us));
        d.1 += 1;
    }

    /// Fold a traced pass's rollup into the barrier-skew gauges (only
    /// called when runtime tracing is enabled — untraced serving never
    /// takes this lock).
    pub fn record_barrier_skew(&self, rollup: &crate::trace::PassRollup) {
        let mut s = self.barrier_skew.lock().unwrap();
        let agg = s.get_or_insert_with(SkewAgg::default);
        agg.last_us = rollup.skew_us;
        agg.max_us = agg.max_us.max(rollup.skew_us);
        agg.last_global_us = rollup.global_skew_us;
        agg.last_barrier_wait_us = rollup.barrier_wait_us;
        agg.samples += 1;
    }

    /// Enqueue → admission latency of one request.
    pub fn record_queue_wait(&self, seconds: f64) {
        self.queue_wait.lock().unwrap().add(seconds);
    }

    /// Live-sequence count after an admission or batched step; keeps
    /// the concurrency high-water mark.
    pub fn record_concurrency(&self, live: usize) {
        self.peak_seqs.fetch_max(live as u64, Ordering::Relaxed);
    }

    /// Prompt tokens one admission adopted from prefix-shared pages.
    pub fn record_prefix_hit(&self, tokens: usize) {
        self.prefix_hit_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    /// KV pages held by live sequences (gauge, sampled per step).
    pub fn record_kv_pages(&self, used: usize) {
        self.kv_pages_used.store(used as u64, Ordering::Relaxed);
    }

    /// Arena capacity of the serving engine (set once at serve start).
    pub fn set_kv_pages_total(&self, total: usize) {
        self.kv_pages_total.store(total as u64, Ordering::Relaxed);
    }

    /// Register one cluster replica's gauges. Re-registering an id
    /// replaces its entry (serve restart in-process); entries stay in
    /// id order so the snapshot array is deterministic.
    pub fn register_replica(&self, stats: Arc<ReplicaStats>) {
        let mut reps = self.replicas.lock().unwrap();
        reps.retain(|r| r.id != stats.id);
        reps.push(stats);
        reps.sort_by_key(|r| r.id);
    }

    /// Registered replicas, in id order (empty outside cluster serving).
    pub fn replica_stats(&self) -> Vec<Arc<ReplicaStats>> {
        self.replicas.lock().unwrap().clone()
    }

    /// Fraction of the KV arena held by live sequences (0 when the
    /// arena size was never registered).
    pub fn kv_page_occupancy(&self) -> f64 {
        let total = self.kv_pages_total.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.kv_pages_used.load(Ordering::Relaxed) as f64 / total as f64
    }

    /// Mean lanes per batched step since startup (0 when no batched
    /// steps ran — e.g. the sequential baseline).
    pub fn batch_occupancy(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.decode_lanes.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// Mean pool dispatches per processed token (0 when no batched
    /// steps ran). The dispatch-tax gauge: 1/lanes under the compiled
    /// per-pass scheduler, ≈ops under a per-op dispatcher.
    pub fn dispatches_per_token(&self) -> f64 {
        let lanes = self.decode_lanes.load(Ordering::Relaxed);
        if lanes == 0 {
            return 0.0;
        }
        self.pass_dispatches.load(Ordering::Relaxed) as f64 / lanes as f64
    }

    /// Aggregate decode throughput since startup (token/s).
    pub fn decode_throughput(&self) -> f64 {
        let elapsed = self
            .start
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        if elapsed == 0.0 {
            return 0.0;
        }
        self.tokens_decoded.load(Ordering::Relaxed) as f64 / elapsed
    }

    /// Render a JSON snapshot (the `/metrics`-style endpoint).
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let mut lat = self.latency.lock().unwrap().clone();
        let mut ttft = self.ttft.lock().unwrap().clone();
        let mut qw = self.queue_wait.lock().unwrap().clone();
        let mut rate = self.req_decode_tok_s.lock().unwrap().clone();
        let load = |c: &AtomicU64| c.load(Ordering::Relaxed) as usize;
        let mut platform = *self.platform.lock().unwrap();
        if platform.is_empty() {
            platform = "unset";
        }
        // KV arenas are per-replica in cluster mode: aggregate over the
        // registered replicas when there are any, else fall back to the
        // process-wide gauges the single-engine schedulers maintain.
        let reps = self.replica_stats();
        let (kv_used, kv_total) = if reps.is_empty() {
            (load(&self.kv_pages_used), load(&self.kv_pages_total))
        } else {
            let sum = |f: fn(&ReplicaStats) -> &AtomicU64| {
                reps.iter().map(|r| f(r).load(Ordering::Relaxed) as usize).sum::<usize>()
            };
            (sum(|r| &r.kv_pages_used), sum(|r| &r.kv_pages_total))
        };
        let kv_occ = if kv_total == 0 { 0.0 } else { kv_used as f64 / kv_total as f64 };
        let strategy = {
            let s = self.strategy_chosen.lock().unwrap();
            if s.is_empty() { "unset".to_string() } else { s.clone() }
        };
        let mut bw_source = *self.bandwidth_source.lock().unwrap();
        if bw_source.is_empty() {
            bw_source = "unset";
        }
        let predicted_opt = *self.predicted_step_us.lock().unwrap();
        let predicted = predicted_opt.map(Json::from).unwrap_or(Json::Null);
        // drift: measured step-time EWMA vs the tuner's prediction —
        // the per-phase re-tuner's hook
        let (drift_ewma, drift_samples) = *self.step_drift.lock().unwrap();
        let (drift_ratio, retune) =
            crate::trace::drift_verdict(drift_ewma, predicted_opt, drift_samples);
        let drift = obj(vec![
            ("measured_step_ewma_us", drift_ewma.map(Json::from).unwrap_or(Json::Null)),
            ("predicted_step_us", predicted_opt.map(Json::from).unwrap_or(Json::Null)),
            ("ratio", drift_ratio.map(Json::from).unwrap_or(Json::Null)),
            ("samples", drift_samples.into()),
            ("retune_recommended", retune.into()),
        ]);
        let barrier_skew = match *self.barrier_skew.lock().unwrap() {
            Some(a) => obj(vec![
                ("last_skew_us", a.last_us.into()),
                ("max_skew_us", a.max_us.into()),
                ("last_global_skew_us", a.last_global_us.into()),
                ("last_barrier_wait_us", a.last_barrier_wait_us.into()),
                ("samples", (a.samples as usize).into()),
            ]),
            None => Json::Null,
        };
        obj(vec![
            ("platform", platform.into()),
            ("strategy_chosen", strategy.into()),
            ("bandwidth_source", bw_source.into()),
            ("predicted_step_us", predicted),
            // SIMD tier the vectorized kernels dispatch on (process-wide)
            ("kernel_tier", crate::simd::KernelTier::active().name().into()),
            ("pinned_workers", load(&self.pinned_workers).into()),
            // bytes of arena storage faulted in node-locally (host
            // first-touch placement; 0 on the simulated platform)
            ("node_local_bytes", (crate::hw::membind::node_local_bytes() as usize).into()),
            ("requests_total", load(&self.requests_total).into()),
            ("requests_failed", load(&self.requests_failed).into()),
            ("tokens_prefilled", load(&self.tokens_prefilled).into()),
            ("tokens_decoded", load(&self.tokens_decoded).into()),
            ("decode_tok_per_s", self.decode_throughput().into()),
            ("req_decode_tok_per_s_p50", rate.p50().into()),
            ("decode_steps", load(&self.decode_steps).into()),
            ("batch_occupancy", self.batch_occupancy().into()),
            ("peak_concurrent_seqs", load(&self.peak_seqs).into()),
            ("prefix_hit_tokens", load(&self.prefix_hit_tokens).into()),
            ("kv_pages_used", kv_used.into()),
            ("kv_pages_total", kv_total.into()),
            ("kv_page_occupancy", kv_occ.into()),
            ("replicas", Json::Arr(reps.iter().map(|r| r.snapshot()).collect())),
            ("pass_dispatches", load(&self.pass_dispatches).into()),
            ("dispatches_per_token", self.dispatches_per_token().into()),
            ("drift", drift),
            ("barrier_skew", barrier_skew),
            ("queue_wait_p50_s", qw.p50().into()),
            ("queue_wait_p95_s", qw.p95().into()),
            ("queue_wait_p99_s", qw.p99().into()),
            ("latency_p50_s", lat.p50().into()),
            ("latency_p95_s", lat.p95().into()),
            ("latency_p99_s", lat.p99().into()),
            ("ttft_p50_s", ttft.p50().into()),
            ("ttft_p95_s", ttft.p95().into()),
            ("ttft_p99_s", ttft.p99().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(15, 256, 0.1, 1.0, 256.0);
        m.record_request(15, 128, 0.2, 0.6, 213.0);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.get("requests_total").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("requests_failed").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("tokens_decoded").unwrap().as_usize(), Some(384));
        let p50 = s.get("latency_p50_s").unwrap().as_f64().unwrap();
        assert!((p50 - 0.8).abs() < 1e-9);
    }

    #[test]
    fn platform_fields_reported() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.get("platform").unwrap().as_str(), Some("unset"));
        let tier = s.get("kernel_tier").unwrap().as_str().unwrap();
        assert!(!tier.is_empty(), "kernel_tier must name the active tier");
        assert_eq!(tier, crate::simd::KernelTier::active().name());
        assert_eq!(s.get("pinned_workers").unwrap().as_usize(), Some(0));
        assert!(s.get("node_local_bytes").unwrap().as_usize().is_some());
        m.set_platform("simulated", 3);
        let s = m.snapshot();
        assert_eq!(s.get("platform").unwrap().as_str(), Some("simulated"));
        assert_eq!(s.get("pinned_workers").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn strategy_fields_reported() {
        let m = Metrics::new();
        // unregistered: labeled unset, prediction null
        let s = m.snapshot();
        assert_eq!(s.get("strategy_chosen").unwrap().as_str(), Some("unset"));
        assert_eq!(s.get("bandwidth_source").unwrap().as_str(), Some("unset"));
        assert_eq!(s.get("predicted_step_us").unwrap(), &crate::util::json::Json::Null);
        // explicit strategy: name + provenance, no prediction
        m.set_strategy("arclight-tp4-syncB", "simulated", None);
        let s = m.snapshot();
        assert_eq!(s.get("strategy_chosen").unwrap().as_str(), Some("arclight-tp4-syncB"));
        assert_eq!(s.get("bandwidth_source").unwrap().as_str(), Some("simulated"));
        assert_eq!(s.get("predicted_step_us").unwrap(), &crate::util::json::Json::Null);
        // auto-selected: the tuner's prediction is surfaced
        m.set_strategy("arclight", "measured", Some(412.5));
        let s = m.snapshot();
        assert_eq!(s.get("bandwidth_source").unwrap().as_str(), Some("measured"));
        assert_eq!(s.get("predicted_step_us").unwrap().as_f64(), Some(412.5));
    }

    #[test]
    fn throughput_positive_after_tokens() {
        let m = Metrics::new();
        m.record_request(1, 100, 0.0, 0.1, 1000.0);
        assert!(m.decode_throughput() > 0.0);
    }

    #[test]
    fn occupancy_is_lanes_per_step() {
        let m = Metrics::new();
        assert_eq!(m.batch_occupancy(), 0.0);
        m.record_step(4, 1);
        m.record_step(2, 1);
        m.record_step(3, 1);
        assert!((m.batch_occupancy() - 3.0).abs() < 1e-9);
        let s = m.snapshot();
        assert_eq!(s.get("decode_steps").unwrap().as_usize(), Some(3));
        assert!((s.get("batch_occupancy").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn dispatches_per_token_tracks_the_pass_model() {
        let m = Metrics::new();
        assert_eq!(m.dispatches_per_token(), 0.0); // guarded, not NaN
        // 3 steps × 1 dispatch over 9 decoded lanes → 1/3 per token
        m.record_step(4, 1);
        m.record_step(2, 1);
        m.record_step(3, 1);
        assert!((m.dispatches_per_token() - 1.0 / 3.0).abs() < 1e-9);
        let s = m.snapshot();
        assert_eq!(s.get("pass_dispatches").unwrap().as_usize(), Some(3));
        let dpt = s.get("dispatches_per_token").unwrap().as_f64().unwrap();
        assert!((dpt - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn paged_kv_gauges_reported() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.get("kv_page_occupancy").unwrap().as_f64(), Some(0.0)); // guarded
        m.set_kv_pages_total(16);
        m.record_kv_pages(4);
        m.record_prefix_hit(32);
        m.record_prefix_hit(16);
        m.record_concurrency(3);
        m.record_concurrency(7);
        m.record_concurrency(5); // high-water mark keeps 7
        let s = m.snapshot();
        assert_eq!(s.get("kv_pages_total").unwrap().as_usize(), Some(16));
        assert_eq!(s.get("kv_pages_used").unwrap().as_usize(), Some(4));
        let occ = s.get("kv_page_occupancy").unwrap().as_f64().unwrap();
        assert!((occ - 0.25).abs() < 1e-9);
        assert_eq!(s.get("prefix_hit_tokens").unwrap().as_usize(), Some(48));
        assert_eq!(s.get("peak_concurrent_seqs").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn replica_array_reported_and_kv_aggregated() {
        let m = Metrics::new();
        // no replicas registered: the array is empty and the legacy
        // process-wide gauges feed the aggregates
        m.set_kv_pages_total(16);
        m.record_kv_pages(4);
        let s = m.snapshot();
        assert_eq!(s.get("replicas").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(s.get("kv_pages_total").unwrap().as_usize(), Some(16));
        // register two replicas out of order; snapshot sorts by id and
        // sums their arenas instead of the legacy gauges
        let r1 = Arc::new(ReplicaStats::new(1, vec![2, 3]));
        let r0 = Arc::new(ReplicaStats::new(0, vec![0, 1]));
        r0.kv_pages_total.store(32, Ordering::Relaxed);
        r0.kv_pages_used.store(8, Ordering::Relaxed);
        r0.live_lanes.store(3, Ordering::Relaxed);
        r0.queue_depth.store(2, Ordering::Relaxed);
        r1.kv_pages_total.store(32, Ordering::Relaxed);
        r1.kv_pages_used.store(24, Ordering::Relaxed);
        r1.tokens_decoded.store(100, Ordering::Relaxed);
        m.register_replica(r1.clone());
        m.register_replica(r0.clone());
        assert_eq!(r0.load(), 5);
        assert_eq!(r1.home_node(), 2);
        let s = m.snapshot();
        let reps = s.get("replicas").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[0].get("replica").unwrap().as_usize(), Some(0));
        assert_eq!(reps[0].get("node").unwrap().as_usize(), Some(0));
        assert_eq!(reps[0].get("live_lanes").unwrap().as_usize(), Some(3));
        assert_eq!(reps[0].get("queue_depth").unwrap().as_usize(), Some(2));
        assert_eq!(reps[1].get("node").unwrap().as_usize(), Some(2));
        assert_eq!(reps[1].get("tokens_decoded").unwrap().as_usize(), Some(100));
        assert!(reps[1].get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(s.get("kv_pages_used").unwrap().as_usize(), Some(32));
        assert_eq!(s.get("kv_pages_total").unwrap().as_usize(), Some(64));
        let occ = s.get("kv_page_occupancy").unwrap().as_f64().unwrap();
        assert!((occ - 0.5).abs() < 1e-9);
        // re-registering an id replaces, never duplicates
        m.register_replica(Arc::new(ReplicaStats::new(0, vec![0])));
        assert_eq!(m.replica_stats().len(), 2);
    }

    #[test]
    fn queue_wait_percentiles_reported() {
        let m = Metrics::new();
        m.record_queue_wait(0.010);
        m.record_queue_wait(0.030);
        let s = m.snapshot();
        let p50 = s.get("queue_wait_p50_s").unwrap().as_f64().unwrap();
        assert!((p50 - 0.020).abs() < 1e-9);
    }

    #[test]
    fn p99_percentiles_reported() {
        let m = Metrics::new();
        for i in 0..100 {
            let v = (i + 1) as f64 / 100.0;
            m.record_request(1, 1, v / 2.0, v, 10.0);
            m.record_queue_wait(v / 10.0);
        }
        let s = m.snapshot();
        let p95 = s.get("latency_p95_s").unwrap().as_f64().unwrap();
        let p99 = s.get("latency_p99_s").unwrap().as_f64().unwrap();
        assert!(p99 > p95, "p99 must sit above p95 on a spread sample");
        assert!(s.get("ttft_p99_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(s.get("queue_wait_p99_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn drift_block_flips_retune_on_synthetic_slowdown() {
        let m = Metrics::new();
        // no prediction, no samples: a null verdict, never a retune
        let s = m.snapshot();
        let d = s.get("drift").unwrap();
        assert_eq!(d.get("retune_recommended").unwrap().as_bool(), Some(false));
        assert_eq!(d.get("ratio").unwrap(), &crate::util::json::Json::Null);
        // tuner predicted 100 µs, measured plateau is 250 µs: the EWMA
        // crosses the band once warm and the flag flips
        m.set_strategy("arclight", "measured", Some(100.0));
        for _ in 0..10 {
            m.record_step_time(250.0);
        }
        let s = m.snapshot();
        let d = s.get("drift").unwrap();
        assert!(d.get("measured_step_ewma_us").unwrap().as_f64().unwrap() > 200.0);
        assert!(d.get("ratio").unwrap().as_f64().unwrap() > 2.0);
        assert_eq!(d.get("samples").unwrap().as_usize(), Some(10));
        assert_eq!(d.get("retune_recommended").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn drift_stays_quiet_when_measured_matches_prediction() {
        let m = Metrics::new();
        m.set_strategy("arclight", "measured", Some(100.0));
        for _ in 0..20 {
            m.record_step_time(105.0);
        }
        let d = m.snapshot();
        let d = d.get("drift").unwrap().clone();
        assert!((d.get("ratio").unwrap().as_f64().unwrap() - 1.05).abs() < 0.02);
        assert_eq!(d.get("retune_recommended").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn barrier_skew_block_folds_traced_rollups() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().get("barrier_skew").unwrap(), &crate::util::json::Json::Null);
        let roll = crate::trace::PassRollup {
            skew_us: 12.0,
            global_skew_us: 3.0,
            barrier_wait_us: 40.0,
            ..Default::default()
        };
        m.record_barrier_skew(&roll);
        m.record_barrier_skew(&crate::trace::PassRollup { skew_us: 5.0, ..roll.clone() });
        let s = m.snapshot();
        let b = s.get("barrier_skew").unwrap();
        assert_eq!(b.get("last_skew_us").unwrap().as_f64(), Some(5.0));
        assert_eq!(b.get("max_skew_us").unwrap().as_f64(), Some(12.0));
        assert_eq!(b.get("last_global_skew_us").unwrap().as_f64(), Some(3.0));
        assert_eq!(b.get("samples").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn windowed_tokens_per_s_survives_idle_gaps() {
        let r = ReplicaStats::new(0, vec![0]);
        assert_eq!(r.tokens_per_s_window(), 0.0, "no samples yet");
        r.tokens_decoded.store(0, Ordering::Relaxed);
        r.sample_window();
        std::thread::sleep(std::time::Duration::from_millis(20));
        r.tokens_decoded.store(100, Ordering::Relaxed);
        r.sample_window();
        let windowed = r.tokens_per_s_window();
        assert!(windowed > 0.0, "window rate must be positive after decoding");
        // the snapshot carries both rates plus the drift fields
        let s = r.snapshot();
        assert!(s.get("tokens_per_s_window").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(s.get("step_ewma_us").unwrap(), &crate::util::json::Json::Null);
        assert_eq!(s.get("retune_recommended").unwrap().as_bool(), Some(false));
        // replica drift flips on a synthetic slowdown, like the aggregate
        for _ in 0..10 {
            r.record_step_time(250.0, Some(100.0));
        }
        let (ratio, retune) = r.drift();
        assert!(ratio.unwrap() > 2.0);
        assert!(retune);
        let s = r.snapshot();
        assert_eq!(s.get("retune_recommended").unwrap().as_bool(), Some(true));
        assert!(s.get("drift_ratio").unwrap().as_f64().unwrap() > 2.0);
    }
}
