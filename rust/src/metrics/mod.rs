//! Serving metrics: latency histograms and token-throughput counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Summary;

/// Process-wide serving metrics (shared by server workers).
#[derive(Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub requests_failed: AtomicU64,
    pub tokens_prefilled: AtomicU64,
    pub tokens_decoded: AtomicU64,
    latency: Mutex<Summary>,
    ttft: Mutex<Summary>,
    start: Mutex<Option<Instant>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { start: Mutex::new(Some(Instant::now())), ..Default::default() }
    }

    pub fn record_request(&self, prefill_tokens: usize, decode_tokens: usize,
                          ttft_s: f64, total_s: f64) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
        self.tokens_prefilled.fetch_add(prefill_tokens as u64, Ordering::Relaxed);
        self.tokens_decoded.fetch_add(decode_tokens as u64, Ordering::Relaxed);
        self.latency.lock().unwrap().add(total_s);
        self.ttft.lock().unwrap().add(ttft_s);
    }

    pub fn record_failure(&self) {
        self.requests_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Aggregate decode throughput since startup (token/s).
    pub fn decode_throughput(&self) -> f64 {
        let elapsed = self
            .start
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        if elapsed == 0.0 {
            return 0.0;
        }
        self.tokens_decoded.load(Ordering::Relaxed) as f64 / elapsed
    }

    /// Render a JSON snapshot (the `/metrics`-style endpoint).
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::obj;
        let mut lat = self.latency.lock().unwrap().clone();
        let mut ttft = self.ttft.lock().unwrap().clone();
        obj(vec![
            ("requests_total", (self.requests_total.load(Ordering::Relaxed) as usize).into()),
            ("requests_failed", (self.requests_failed.load(Ordering::Relaxed) as usize).into()),
            ("tokens_prefilled", (self.tokens_prefilled.load(Ordering::Relaxed) as usize).into()),
            ("tokens_decoded", (self.tokens_decoded.load(Ordering::Relaxed) as usize).into()),
            ("decode_tok_per_s", self.decode_throughput().into()),
            ("latency_p50_s", lat.p50().into()),
            ("latency_p95_s", lat.p95().into()),
            ("ttft_p50_s", ttft.p50().into()),
            ("ttft_p95_s", ttft.p95().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(15, 256, 0.1, 1.0);
        m.record_request(15, 128, 0.2, 0.6);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.get("requests_total").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("requests_failed").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("tokens_decoded").unwrap().as_usize(), Some(384));
        let p50 = s.get("latency_p50_s").unwrap().as_f64().unwrap();
        assert!((p50 - 0.8).abs() < 1e-9);
    }

    #[test]
    fn throughput_positive_after_tokens() {
        let m = Metrics::new();
        m.record_request(1, 100, 0.0, 0.1);
        assert!(m.decode_throughput() > 0.0);
    }
}
