//! Serving metrics: latency histograms, token-throughput counters and
//! continuous-batching gauges (queue wait, batch occupancy).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::stats::Summary;

/// Per-replica serving gauges — one per [`crate::server::Cluster`]
/// engine. The cluster's router reads `live_lanes`/`queue_depth` for
/// placement; the metrics snapshot renders one entry per replica under
/// `replicas` while the top-level [`Metrics`] fields stay aggregates
/// across the whole cluster.
pub struct ReplicaStats {
    /// Replica index within the cluster (0 for a single engine).
    pub id: usize,
    /// NUMA nodes of the replica's placement group.
    pub nodes: Vec<usize>,
    /// Lanes decoding in the replica's running batch (gauge).
    pub live_lanes: AtomicU64,
    /// Requests waiting in the replica's admission queue (gauge).
    pub queue_depth: AtomicU64,
    /// Tokens this replica decoded since serve start.
    pub tokens_decoded: AtomicU64,
    /// Prompt tokens this replica served from prefix-shared KV pages.
    pub prefix_hit_tokens: AtomicU64,
    /// KV pages held in this replica's arena after its last step.
    pub kv_pages_used: AtomicU64,
    /// Total pages in this replica's KV arena.
    pub kv_pages_total: AtomicU64,
    started: Instant,
}

impl ReplicaStats {
    pub fn new(id: usize, nodes: Vec<usize>) -> Self {
        ReplicaStats {
            id,
            nodes,
            live_lanes: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            tokens_decoded: AtomicU64::new(0),
            prefix_hit_tokens: AtomicU64::new(0),
            kv_pages_used: AtomicU64::new(0),
            kv_pages_total: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// First node of the placement group — the `node` stamped into
    /// response provenance.
    pub fn home_node(&self) -> usize {
        self.nodes.first().copied().unwrap_or(0)
    }

    /// Instantaneous load the router scores: lanes decoding now plus
    /// requests already committed to this replica's queue.
    pub fn load(&self) -> usize {
        (self.live_lanes.load(Ordering::Relaxed) + self.queue_depth.load(Ordering::Relaxed))
            as usize
    }

    /// Decode throughput of this replica since serve start (token/s).
    pub fn tokens_per_s(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed == 0.0 {
            return 0.0;
        }
        self.tokens_decoded.load(Ordering::Relaxed) as f64 / elapsed
    }

    /// One entry of the snapshot's `replicas` array.
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::obj;
        let load = |c: &AtomicU64| c.load(Ordering::Relaxed) as usize;
        obj(vec![
            ("replica", self.id.into()),
            ("node", self.home_node().into()),
            ("nodes", self.nodes.clone().into()),
            ("live_lanes", load(&self.live_lanes).into()),
            ("queue_depth", load(&self.queue_depth).into()),
            ("tokens_decoded", load(&self.tokens_decoded).into()),
            ("tokens_per_s", self.tokens_per_s().into()),
            ("prefix_hit_tokens", load(&self.prefix_hit_tokens).into()),
            ("kv_pages_used", load(&self.kv_pages_used).into()),
            ("kv_pages_total", load(&self.kv_pages_total).into()),
        ])
    }
}

/// Process-wide serving metrics (shared by server workers).
#[derive(Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub requests_failed: AtomicU64,
    pub tokens_prefilled: AtomicU64,
    pub tokens_decoded: AtomicU64,
    /// Batched decode steps executed (continuous batching).
    pub decode_steps: AtomicU64,
    /// Lanes summed over all steps; occupancy = lanes / steps.
    pub decode_lanes: AtomicU64,
    /// Pool dispatches summed over all steps. With the compiled-pass
    /// scheduler this is 1 per step, so `dispatches_per_token` ≈
    /// 1/lanes — the legacy per-op walk paid ≈`ops` per step.
    pub pass_dispatches: AtomicU64,
    /// Workers the serving engine's pool pinned to host cpus.
    pub pinned_workers: AtomicU64,
    /// High-water mark of concurrently live sequences (paged KV lets
    /// this exceed the slot count of the dense-era scheduler).
    pub peak_seqs: AtomicU64,
    /// Prompt tokens served from prefix-shared KV pages instead of
    /// being prefilled (summed over all admitted requests).
    pub prefix_hit_tokens: AtomicU64,
    /// KV pages held by live sequences after the last batched step.
    pub kv_pages_used: AtomicU64,
    /// Total pages in the serving engine's KV arena.
    pub kv_pages_total: AtomicU64,
    /// Execution platform of the serving engine (`"simulated"` /
    /// `"host"`; empty until a scheduler registers its engine).
    platform: Mutex<&'static str>,
    /// Strategy the serving engine runs (explicit or auto-selected;
    /// empty until a scheduler registers its engine).
    strategy_chosen: Mutex<String>,
    /// Provenance of the bandwidth matrix behind the engine's topology
    /// (`"measured"` / `"slit-placeholder"` / `"simulated"`).
    bandwidth_source: Mutex<&'static str>,
    /// Auto-tuner step-time prediction (µs) when `--strategy auto`
    /// picked the strategy; `None` otherwise.
    predicted_step_us: Mutex<Option<f64>>,
    latency: Mutex<Summary>,
    ttft: Mutex<Summary>,
    /// Enqueue → admission into the running batch.
    queue_wait: Mutex<Summary>,
    /// Per-request decode throughput (token/s), for p50/p95 reporting
    /// next to the process-wide aggregate.
    req_decode_tok_s: Mutex<Summary>,
    /// Registered cluster replicas, in id order. Empty outside cluster
    /// serving; when populated, the snapshot's `kv_pages_*` aggregates
    /// sum over these instead of the process-wide gauges (each replica
    /// owns its own arena).
    replicas: Mutex<Vec<Arc<ReplicaStats>>>,
    start: Mutex<Option<Instant>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { start: Mutex::new(Some(Instant::now())), ..Default::default() }
    }

    pub fn record_request(
        &self,
        prefill_tokens: usize,
        decode_tokens: usize,
        ttft_s: f64,
        total_s: f64,
        decode_tok_per_s: f64,
    ) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
        self.tokens_prefilled.fetch_add(prefill_tokens as u64, Ordering::Relaxed);
        self.tokens_decoded.fetch_add(decode_tokens as u64, Ordering::Relaxed);
        self.latency.lock().unwrap().add(total_s);
        self.ttft.lock().unwrap().add(ttft_s);
        if decode_tok_per_s > 0.0 {
            self.req_decode_tok_s.lock().unwrap().add(decode_tok_per_s);
        }
    }

    pub fn record_failure(&self) {
        self.requests_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Register the serving engine's execution platform and pin count
    /// (called by each scheduler at serve start). Last registration
    /// wins: with several sequential slot engines the values describe
    /// one engine's pool, not a sum across slots.
    pub fn set_platform(&self, platform: &'static str, pinned_workers: usize) {
        *self.platform.lock().unwrap() = platform;
        self.pinned_workers.store(pinned_workers as u64, Ordering::Relaxed);
    }

    /// Register the serving engine's strategy and bandwidth provenance
    /// (same last-registration-wins contract as
    /// [`Metrics::set_platform`]).
    pub fn set_strategy(
        &self,
        strategy: &str,
        bandwidth_source: &'static str,
        predicted_step_us: Option<f64>,
    ) {
        *self.strategy_chosen.lock().unwrap() = strategy.to_string();
        *self.bandwidth_source.lock().unwrap() = bandwidth_source;
        *self.predicted_step_us.lock().unwrap() = predicted_step_us;
    }

    /// One continuous-batching step that processed `lanes` lanes with
    /// `dispatches` pool dispatches (1 under the PassPlan scheduler).
    pub fn record_step(&self, lanes: usize, dispatches: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.decode_lanes.fetch_add(lanes as u64, Ordering::Relaxed);
        self.pass_dispatches.fetch_add(dispatches as u64, Ordering::Relaxed);
    }

    /// Enqueue → admission latency of one request.
    pub fn record_queue_wait(&self, seconds: f64) {
        self.queue_wait.lock().unwrap().add(seconds);
    }

    /// Live-sequence count after an admission or batched step; keeps
    /// the concurrency high-water mark.
    pub fn record_concurrency(&self, live: usize) {
        self.peak_seqs.fetch_max(live as u64, Ordering::Relaxed);
    }

    /// Prompt tokens one admission adopted from prefix-shared pages.
    pub fn record_prefix_hit(&self, tokens: usize) {
        self.prefix_hit_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    /// KV pages held by live sequences (gauge, sampled per step).
    pub fn record_kv_pages(&self, used: usize) {
        self.kv_pages_used.store(used as u64, Ordering::Relaxed);
    }

    /// Arena capacity of the serving engine (set once at serve start).
    pub fn set_kv_pages_total(&self, total: usize) {
        self.kv_pages_total.store(total as u64, Ordering::Relaxed);
    }

    /// Register one cluster replica's gauges. Re-registering an id
    /// replaces its entry (serve restart in-process); entries stay in
    /// id order so the snapshot array is deterministic.
    pub fn register_replica(&self, stats: Arc<ReplicaStats>) {
        let mut reps = self.replicas.lock().unwrap();
        reps.retain(|r| r.id != stats.id);
        reps.push(stats);
        reps.sort_by_key(|r| r.id);
    }

    /// Registered replicas, in id order (empty outside cluster serving).
    pub fn replica_stats(&self) -> Vec<Arc<ReplicaStats>> {
        self.replicas.lock().unwrap().clone()
    }

    /// Fraction of the KV arena held by live sequences (0 when the
    /// arena size was never registered).
    pub fn kv_page_occupancy(&self) -> f64 {
        let total = self.kv_pages_total.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.kv_pages_used.load(Ordering::Relaxed) as f64 / total as f64
    }

    /// Mean lanes per batched step since startup (0 when no batched
    /// steps ran — e.g. the sequential baseline).
    pub fn batch_occupancy(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.decode_lanes.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// Mean pool dispatches per processed token (0 when no batched
    /// steps ran). The dispatch-tax gauge: 1/lanes under the compiled
    /// per-pass scheduler, ≈ops under a per-op dispatcher.
    pub fn dispatches_per_token(&self) -> f64 {
        let lanes = self.decode_lanes.load(Ordering::Relaxed);
        if lanes == 0 {
            return 0.0;
        }
        self.pass_dispatches.load(Ordering::Relaxed) as f64 / lanes as f64
    }

    /// Aggregate decode throughput since startup (token/s).
    pub fn decode_throughput(&self) -> f64 {
        let elapsed = self
            .start
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        if elapsed == 0.0 {
            return 0.0;
        }
        self.tokens_decoded.load(Ordering::Relaxed) as f64 / elapsed
    }

    /// Render a JSON snapshot (the `/metrics`-style endpoint).
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let mut lat = self.latency.lock().unwrap().clone();
        let mut ttft = self.ttft.lock().unwrap().clone();
        let mut qw = self.queue_wait.lock().unwrap().clone();
        let mut rate = self.req_decode_tok_s.lock().unwrap().clone();
        let load = |c: &AtomicU64| c.load(Ordering::Relaxed) as usize;
        let mut platform = *self.platform.lock().unwrap();
        if platform.is_empty() {
            platform = "unset";
        }
        // KV arenas are per-replica in cluster mode: aggregate over the
        // registered replicas when there are any, else fall back to the
        // process-wide gauges the single-engine schedulers maintain.
        let reps = self.replica_stats();
        let (kv_used, kv_total) = if reps.is_empty() {
            (load(&self.kv_pages_used), load(&self.kv_pages_total))
        } else {
            let sum = |f: fn(&ReplicaStats) -> &AtomicU64| {
                reps.iter().map(|r| f(r).load(Ordering::Relaxed) as usize).sum::<usize>()
            };
            (sum(|r| &r.kv_pages_used), sum(|r| &r.kv_pages_total))
        };
        let kv_occ = if kv_total == 0 { 0.0 } else { kv_used as f64 / kv_total as f64 };
        let strategy = {
            let s = self.strategy_chosen.lock().unwrap();
            if s.is_empty() { "unset".to_string() } else { s.clone() }
        };
        let mut bw_source = *self.bandwidth_source.lock().unwrap();
        if bw_source.is_empty() {
            bw_source = "unset";
        }
        let predicted = self
            .predicted_step_us
            .lock()
            .unwrap()
            .map(Json::from)
            .unwrap_or(Json::Null);
        obj(vec![
            ("platform", platform.into()),
            ("strategy_chosen", strategy.into()),
            ("bandwidth_source", bw_source.into()),
            ("predicted_step_us", predicted),
            // SIMD tier the vectorized kernels dispatch on (process-wide)
            ("kernel_tier", crate::simd::KernelTier::active().name().into()),
            ("pinned_workers", load(&self.pinned_workers).into()),
            // bytes of arena storage faulted in node-locally (host
            // first-touch placement; 0 on the simulated platform)
            ("node_local_bytes", (crate::hw::membind::node_local_bytes() as usize).into()),
            ("requests_total", load(&self.requests_total).into()),
            ("requests_failed", load(&self.requests_failed).into()),
            ("tokens_prefilled", load(&self.tokens_prefilled).into()),
            ("tokens_decoded", load(&self.tokens_decoded).into()),
            ("decode_tok_per_s", self.decode_throughput().into()),
            ("req_decode_tok_per_s_p50", rate.p50().into()),
            ("decode_steps", load(&self.decode_steps).into()),
            ("batch_occupancy", self.batch_occupancy().into()),
            ("peak_concurrent_seqs", load(&self.peak_seqs).into()),
            ("prefix_hit_tokens", load(&self.prefix_hit_tokens).into()),
            ("kv_pages_used", kv_used.into()),
            ("kv_pages_total", kv_total.into()),
            ("kv_page_occupancy", kv_occ.into()),
            ("replicas", Json::Arr(reps.iter().map(|r| r.snapshot()).collect())),
            ("pass_dispatches", load(&self.pass_dispatches).into()),
            ("dispatches_per_token", self.dispatches_per_token().into()),
            ("queue_wait_p50_s", qw.p50().into()),
            ("queue_wait_p95_s", qw.p95().into()),
            ("latency_p50_s", lat.p50().into()),
            ("latency_p95_s", lat.p95().into()),
            ("ttft_p50_s", ttft.p50().into()),
            ("ttft_p95_s", ttft.p95().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(15, 256, 0.1, 1.0, 256.0);
        m.record_request(15, 128, 0.2, 0.6, 213.0);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.get("requests_total").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("requests_failed").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("tokens_decoded").unwrap().as_usize(), Some(384));
        let p50 = s.get("latency_p50_s").unwrap().as_f64().unwrap();
        assert!((p50 - 0.8).abs() < 1e-9);
    }

    #[test]
    fn platform_fields_reported() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.get("platform").unwrap().as_str(), Some("unset"));
        let tier = s.get("kernel_tier").unwrap().as_str().unwrap();
        assert!(!tier.is_empty(), "kernel_tier must name the active tier");
        assert_eq!(tier, crate::simd::KernelTier::active().name());
        assert_eq!(s.get("pinned_workers").unwrap().as_usize(), Some(0));
        assert!(s.get("node_local_bytes").unwrap().as_usize().is_some());
        m.set_platform("simulated", 3);
        let s = m.snapshot();
        assert_eq!(s.get("platform").unwrap().as_str(), Some("simulated"));
        assert_eq!(s.get("pinned_workers").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn strategy_fields_reported() {
        let m = Metrics::new();
        // unregistered: labeled unset, prediction null
        let s = m.snapshot();
        assert_eq!(s.get("strategy_chosen").unwrap().as_str(), Some("unset"));
        assert_eq!(s.get("bandwidth_source").unwrap().as_str(), Some("unset"));
        assert_eq!(s.get("predicted_step_us").unwrap(), &crate::util::json::Json::Null);
        // explicit strategy: name + provenance, no prediction
        m.set_strategy("arclight-tp4-syncB", "simulated", None);
        let s = m.snapshot();
        assert_eq!(s.get("strategy_chosen").unwrap().as_str(), Some("arclight-tp4-syncB"));
        assert_eq!(s.get("bandwidth_source").unwrap().as_str(), Some("simulated"));
        assert_eq!(s.get("predicted_step_us").unwrap(), &crate::util::json::Json::Null);
        // auto-selected: the tuner's prediction is surfaced
        m.set_strategy("arclight", "measured", Some(412.5));
        let s = m.snapshot();
        assert_eq!(s.get("bandwidth_source").unwrap().as_str(), Some("measured"));
        assert_eq!(s.get("predicted_step_us").unwrap().as_f64(), Some(412.5));
    }

    #[test]
    fn throughput_positive_after_tokens() {
        let m = Metrics::new();
        m.record_request(1, 100, 0.0, 0.1, 1000.0);
        assert!(m.decode_throughput() > 0.0);
    }

    #[test]
    fn occupancy_is_lanes_per_step() {
        let m = Metrics::new();
        assert_eq!(m.batch_occupancy(), 0.0);
        m.record_step(4, 1);
        m.record_step(2, 1);
        m.record_step(3, 1);
        assert!((m.batch_occupancy() - 3.0).abs() < 1e-9);
        let s = m.snapshot();
        assert_eq!(s.get("decode_steps").unwrap().as_usize(), Some(3));
        assert!((s.get("batch_occupancy").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn dispatches_per_token_tracks_the_pass_model() {
        let m = Metrics::new();
        assert_eq!(m.dispatches_per_token(), 0.0); // guarded, not NaN
        // 3 steps × 1 dispatch over 9 decoded lanes → 1/3 per token
        m.record_step(4, 1);
        m.record_step(2, 1);
        m.record_step(3, 1);
        assert!((m.dispatches_per_token() - 1.0 / 3.0).abs() < 1e-9);
        let s = m.snapshot();
        assert_eq!(s.get("pass_dispatches").unwrap().as_usize(), Some(3));
        let dpt = s.get("dispatches_per_token").unwrap().as_f64().unwrap();
        assert!((dpt - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn paged_kv_gauges_reported() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.get("kv_page_occupancy").unwrap().as_f64(), Some(0.0)); // guarded
        m.set_kv_pages_total(16);
        m.record_kv_pages(4);
        m.record_prefix_hit(32);
        m.record_prefix_hit(16);
        m.record_concurrency(3);
        m.record_concurrency(7);
        m.record_concurrency(5); // high-water mark keeps 7
        let s = m.snapshot();
        assert_eq!(s.get("kv_pages_total").unwrap().as_usize(), Some(16));
        assert_eq!(s.get("kv_pages_used").unwrap().as_usize(), Some(4));
        let occ = s.get("kv_page_occupancy").unwrap().as_f64().unwrap();
        assert!((occ - 0.25).abs() < 1e-9);
        assert_eq!(s.get("prefix_hit_tokens").unwrap().as_usize(), Some(48));
        assert_eq!(s.get("peak_concurrent_seqs").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn replica_array_reported_and_kv_aggregated() {
        let m = Metrics::new();
        // no replicas registered: the array is empty and the legacy
        // process-wide gauges feed the aggregates
        m.set_kv_pages_total(16);
        m.record_kv_pages(4);
        let s = m.snapshot();
        assert_eq!(s.get("replicas").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(s.get("kv_pages_total").unwrap().as_usize(), Some(16));
        // register two replicas out of order; snapshot sorts by id and
        // sums their arenas instead of the legacy gauges
        let r1 = Arc::new(ReplicaStats::new(1, vec![2, 3]));
        let r0 = Arc::new(ReplicaStats::new(0, vec![0, 1]));
        r0.kv_pages_total.store(32, Ordering::Relaxed);
        r0.kv_pages_used.store(8, Ordering::Relaxed);
        r0.live_lanes.store(3, Ordering::Relaxed);
        r0.queue_depth.store(2, Ordering::Relaxed);
        r1.kv_pages_total.store(32, Ordering::Relaxed);
        r1.kv_pages_used.store(24, Ordering::Relaxed);
        r1.tokens_decoded.store(100, Ordering::Relaxed);
        m.register_replica(r1.clone());
        m.register_replica(r0.clone());
        assert_eq!(r0.load(), 5);
        assert_eq!(r1.home_node(), 2);
        let s = m.snapshot();
        let reps = s.get("replicas").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[0].get("replica").unwrap().as_usize(), Some(0));
        assert_eq!(reps[0].get("node").unwrap().as_usize(), Some(0));
        assert_eq!(reps[0].get("live_lanes").unwrap().as_usize(), Some(3));
        assert_eq!(reps[0].get("queue_depth").unwrap().as_usize(), Some(2));
        assert_eq!(reps[1].get("node").unwrap().as_usize(), Some(2));
        assert_eq!(reps[1].get("tokens_decoded").unwrap().as_usize(), Some(100));
        assert!(reps[1].get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(s.get("kv_pages_used").unwrap().as_usize(), Some(32));
        assert_eq!(s.get("kv_pages_total").unwrap().as_usize(), Some(64));
        let occ = s.get("kv_page_occupancy").unwrap().as_f64().unwrap();
        assert!((occ - 0.5).abs() < 1e-9);
        // re-registering an id replaces, never duplicates
        m.register_replica(Arc::new(ReplicaStats::new(0, vec![0])));
        assert_eq!(m.replica_stats().len(), 2);
    }

    #[test]
    fn queue_wait_percentiles_reported() {
        let m = Metrics::new();
        m.record_queue_wait(0.010);
        m.record_queue_wait(0.030);
        let s = m.snapshot();
        let p50 = s.get("queue_wait_p50_s").unwrap().as_f64().unwrap();
        assert!((p50 - 0.020).abs() < 1e-9);
    }
}
