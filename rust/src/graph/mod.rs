//! Forward graph builder (paper §2.5, appendix A.1).
//!
//! ArcLight uses a *static* computation graph: the frontend composes
//! tensor-operation interfaces which append nodes to a sequential
//! container as they are constructed — model-definition order **is**
//! topological order, so no sorting pass is needed. The container holds
//! [`TensorBundle`]s and supports the paper's four construction modes:
//!
//! * **Serial** — a 1-bundle follows a 1-bundle (normal ops);
//! * **Scatter** — a G-bundle follows a 1-bundle (enter a TP region);
//! * **Parallel** — a G-bundle follows a G-bundle element-wise (ops
//!   inside a TP region);
//! * **Gather** — a 1-bundle follows a G-bundle (leave a TP region).
//!
//! Graph-level KV-cache management (create/set/get) lives in
//! [`kv_cache`].

pub mod builder;
pub mod kv_cache;
pub mod node;

pub use builder::GraphBuilder;
pub use kv_cache::{KvCacheSet, SlotAllocator};
pub use node::{OpKind, TensorMeta};

use crate::memory::BufRef;
use crate::tensor::{TensorBundle, TensorId};

/// One entry of the static execution list: the bundle of tensors whose
/// producing ops run "at the same position" — width 1 in single-graph
/// mode, width G inside a TP region (one per subgraph).
#[derive(Clone, Debug)]
pub struct ExecEntry {
    pub bundle: TensorBundle,
}

/// The static computation graph: a tensor table plus the execution list.
#[derive(Default)]
pub struct Graph {
    pub tensors: Vec<TensorMeta>,
    pub exec: Vec<ExecEntry>,
}

impl Graph {
    pub fn meta(&self, id: TensorId) -> &TensorMeta {
        &self.tensors[id.index()]
    }

    pub fn meta_mut(&mut self, id: TensorId) -> &mut TensorMeta {
        &mut self.tensors[id.index()]
    }

    pub fn buf(&self, id: TensorId) -> BufRef {
        self.meta(id).buf.expect("tensor has no buffer")
    }

    pub fn find(&self, name: &str) -> Option<TensorId> {
        self.tensors
            .iter()
            .position(|t| t.name == name)
            .map(|i| TensorId(i as u32))
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    /// Verify the model-definition-order invariant the paper relies on:
    /// every source of every executed node appears earlier in the list
    /// (or is a leaf). Returns the violating node if any.
    pub fn check_topological(&self) -> Result<(), String> {
        let mut seen = vec![false; self.tensors.len()];
        for (i, t) in self.tensors.iter().enumerate() {
            if matches!(t.op, node::OpKind::Leaf) {
                seen[i] = true;
            }
        }
        for entry in &self.exec {
            for id in entry.bundle.iter() {
                for &src in &self.meta(id).src {
                    if !seen[src.index()] {
                        return Err(format!(
                            "node '{}' uses '{}' before it is produced",
                            self.meta(id).name,
                            self.meta(src).name
                        ));
                    }
                }
                seen[id.index()] = true;
            }
        }
        Ok(())
    }
}
