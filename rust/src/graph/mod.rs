//! Forward graph builder (paper §2.5, appendix A.1).
//!
//! ArcLight uses a *static* computation graph: the frontend composes
//! tensor-operation interfaces which append nodes to a sequential
//! container as they are constructed — model-definition order **is**
//! topological order, so no sorting pass is needed. The container holds
//! [`TensorBundle`]s and supports the paper's four construction modes:
//!
//! * **Serial** — a 1-bundle follows a 1-bundle (normal ops);
//! * **Scatter** — a G-bundle follows a 1-bundle (enter a TP region);
//! * **Parallel** — a G-bundle follows a G-bundle element-wise (ops
//!   inside a TP region);
//! * **Gather** — a 1-bundle follows a G-bundle (leave a TP region).
//!
//! Graph-level KV-cache management (create/set/get) lives in
//! [`kv_cache`].

pub mod builder;
pub mod kv_cache;
pub mod node;

pub use builder::GraphBuilder;
pub use kv_cache::{KvCacheSet, KvSpec, PageArena, PageTable};
pub use node::{OpKind, TensorMeta};

use crate::memory::BufRef;
use crate::ops::kernel::{Kernel, KernelRegistry};
use crate::tensor::{TensorBundle, TensorId};

/// One entry of the static execution list: the bundle of tensors whose
/// producing ops run "at the same position" — width 1 in single-graph
/// mode, width G inside a TP region (one per subgraph).
#[derive(Clone, Debug)]
pub struct ExecEntry {
    pub bundle: TensorBundle,
}

/// The static computation graph: a tensor table plus the execution list.
#[derive(Default)]
pub struct Graph {
    pub tensors: Vec<TensorMeta>,
    pub exec: Vec<ExecEntry>,
    /// Kernel resolved for each tensor's producing op (parallel to
    /// `tensors`; filled once by [`Graph::resolve_kernels`] at build).
    kernels: Vec<&'static dyn Kernel>,
}

impl Graph {
    pub fn meta(&self, id: TensorId) -> &TensorMeta {
        &self.tensors[id.index()]
    }

    /// Resolve the kernel for every tensor through the
    /// [`KernelRegistry`]. Called once by `GraphBuilder::finish`;
    /// executors then dispatch through [`Graph::kernel`] with no per-op
    /// `OpKind` matching. Unexecutable graphs (e.g. i32 matmul weights)
    /// are rejected here, at build time.
    pub fn resolve_kernels(&mut self) {
        let reg = KernelRegistry::global();
        self.kernels = self
            .tensors
            .iter()
            .map(|t| {
                let wdtype = t.src.get(1).map(|s| self.tensors[s.index()].dtype);
                reg.resolve(&t.op, wdtype)
            })
            .collect();
    }

    /// The kernel executing tensor `id`'s producing operator (resolved
    /// at graph build — panics on a graph that never ran
    /// [`Graph::resolve_kernels`]).
    pub fn kernel(&self, id: TensorId) -> &'static dyn Kernel {
        self.kernels[id.index()]
    }

    pub fn meta_mut(&mut self, id: TensorId) -> &mut TensorMeta {
        &mut self.tensors[id.index()]
    }

    pub fn buf(&self, id: TensorId) -> BufRef {
        self.meta(id).buf.expect("tensor has no buffer")
    }

    pub fn find(&self, name: &str) -> Option<TensorId> {
        self.tensors
            .iter()
            .position(|t| t.name == name)
            .map(|i| TensorId(i as u32))
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    /// Verify the model-definition-order invariant the paper relies on:
    /// every source of every executed node appears earlier in the list
    /// (or is a leaf). Returns the violating node if any.
    pub fn check_topological(&self) -> Result<(), String> {
        let mut seen = vec![false; self.tensors.len()];
        for (i, t) in self.tensors.iter().enumerate() {
            if matches!(t.op, node::OpKind::Leaf) {
                seen[i] = true;
            }
        }
        for entry in &self.exec {
            for id in entry.bundle.iter() {
                for &src in &self.meta(id).src {
                    if !seen[src.index()] {
                        return Err(format!(
                            "node '{}' uses '{}' before it is produced",
                            self.meta(id).name,
                            self.meta(src).name
                        ));
                    }
                }
                seen[id.index()] = true;
            }
        }
        Ok(())
    }
}
