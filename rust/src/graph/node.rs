//! Tensor headers and operation kinds.

use crate::memory::BufRef;
use crate::numa::Placement;
use crate::tensor::{DType, TensorId};

/// The operation producing a tensor (graph node type). Parameters that
/// are fixed at graph-build time ride in the variant; per-step values
/// (current position, kv length) come from the scheduler's `ExecParams`.
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    /// No producer: weights, inputs, KV caches.
    Leaf,
    /// src: [emb_table, tokens] → [rows, d] f32.
    Embed,
    /// src: [x, gain]; RMS-normalize rows.
    RmsNorm { eps: f32 },
    /// src: [x, gain]; per-head RMSNorm (Qwen3 QK-norm).
    RmsNormHeads { eps: f32, heads: usize, head_dim: usize },
    /// src: [x, w] → x·wᵀ. Weight may be F32/Q4_0/Q8_0.
    MatMul,
    /// src: `[x]`; rotary embedding at position `pos0 + row`.
    Rope { theta: f32, heads: usize, head_dim: usize },
    /// src: [kv_rows, cache-leaf]; writes rows into the cache at the
    /// current position. Output aliases the cache buffer.
    StoreKv { kv_heads: usize, head_dim: usize, max_seq: usize },
    /// src: [q, k_cache, v_cache] → [rows, heads*head_dim].
    Attention { heads: usize, kv_heads: usize, head_dim: usize, max_seq: usize },
    /// src: `[a]` → silu(a).
    Silu,
    /// src: [a, b] → a + b.
    Add,
    /// src: [a, b] → a * b.
    Mul,
    /// src: [gate, up] → silu(gate) * up (fused).
    SwiGlu,
    /// src: `[x]` → copy (Scatter desugars to per-node copies).
    Copy,
    /// src: [x ([rows, d])] → `x[row]` as [1, d] (prefill takes the last
    /// row before the LM head so logits are computed once, not ×rows).
    SliceRow { row: usize },
    /// src: [p_0, ..., p_{G-1}] → Σ p_g (the Gather reduction).
    AddN,
}

impl OpKind {
    pub fn is_leaf(&self) -> bool {
        matches!(self, OpKind::Leaf)
    }

    /// Human name for traces and error messages.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Leaf => "leaf",
            OpKind::Embed => "embed",
            OpKind::RmsNorm { .. } => "rmsnorm",
            OpKind::RmsNormHeads { .. } => "rmsnorm_heads",
            OpKind::MatMul => "matmul",
            OpKind::Rope { .. } => "rope",
            OpKind::StoreKv { .. } => "store_kv",
            OpKind::Attention { .. } => "attention",
            OpKind::Silu => "silu",
            OpKind::Add => "add",
            OpKind::Mul => "mul",
            OpKind::SwiGlu => "swiglu",
            OpKind::Copy => "copy",
            OpKind::SliceRow { .. } => "slice_row",
            OpKind::AddN => "add_n",
        }
    }
}

/// A tensor header (paper §2.2): metadata + source links + placement +
/// the data-area reference assigned by the memory manager.
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub op: OpKind,
    pub src: Vec<TensorId>,
    /// Which NUMA node(s) own the bytes — drives both arena selection
    /// (real execution) and the bandwidth cost model (simulation).
    pub placement: Placement,
    /// Data area; `None` until the memory planner assigns one (leaf
    /// inputs of the simulator-only path keep `None`).
    pub buf: Option<BufRef>,
    /// TP subgraph index (`None` = single-graph mode / all groups).
    pub group: Option<usize>,
}

impl TensorMeta {
    pub fn bytes(&self) -> usize {
        self.dtype.tensor_bytes(&self.shape)
    }

    pub fn rows(&self) -> usize {
        crate::tensor::rows(&self.shape)
    }

    pub fn row_len(&self) -> usize {
        crate::tensor::row_len(&self.shape)
    }

    pub fn numel(&self) -> usize {
        crate::tensor::numel(&self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_byte_math() {
        let m = TensorMeta {
            name: "w".into(),
            dtype: DType::Q4_0,
            shape: vec![4, 64],
            op: OpKind::Leaf,
            src: vec![],
            placement: Placement::Node(0),
            buf: None,
            group: None,
        };
        assert_eq!(m.bytes(), 4 * 2 * 18);
        assert_eq!(m.rows(), 4);
        assert_eq!(m.row_len(), 64);
    }

    #[test]
    fn op_names_unique_enough() {
        assert_eq!(OpKind::MatMul.name(), "matmul");
        assert!(OpKind::Leaf.is_leaf());
        assert!(!OpKind::Add.is_leaf());
    }
}
