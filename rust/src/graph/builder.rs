//! The graph builder: tensor-operation interfaces that append nodes to
//! the static execution list as they are called (appendix A.1).
//!
//! Every interface takes and returns [`TensorBundle`]s, so the same
//! model-definition code builds the single graph (bundles of width 1)
//! and the TP parallel subgraphs (width G) — the paper's
//! `tensor_ptrs` design. Activation buffers are carved from the
//! NUMA-local arenas with layer-parity double buffering (§2.3).

use crate::memory::{MemoryPool, PlanMode};
use crate::numa::{NodeId, Placement};
use crate::tensor::{DType, TensorBundle, TensorId};

use super::node::{OpKind, TensorMeta};
use super::{ExecEntry, Graph};

/// Builder state. `sim_only = true` skips buffer allocation entirely —
/// used for paper-scale geometries that exist only inside the
/// virtual-time simulator.
pub struct GraphBuilder {
    pub graph: Graph,
    pool: Option<MemoryPool>,
    plan_mode: PlanMode,
    sim_only: bool,
    /// NUMA node of each TP group (group g's activations live here).
    group_nodes: Vec<NodeId>,
    /// Placement for single-mode activations (ArcLight: Node(0);
    /// llama.cpp baseline: Interleaved).
    act_placement: Placement,
    /// Bump marks for layer-parity rewinding: `marks[node][parity]`.
    /// Captured lazily on the first `enter_layer` of each parity, so
    /// activations allocated before the layer loop (the embedding
    /// output feeding the residual stream) are never reclaimed.
    layer_marks: Vec<[Option<usize>; 2]>,
    cur_layer: usize,
    /// Peak activation bytes per (node, parity) — footprint reporting.
    peaks: Vec<[usize; 2]>,
}

impl GraphBuilder {
    pub fn new(
        pool: Option<MemoryPool>,
        group_nodes: Vec<NodeId>,
        act_placement: Placement,
    ) -> Self {
        let n_nodes = pool.as_ref().map(|p| p.n_nodes()).unwrap_or_else(|| {
            group_nodes.iter().copied().max().unwrap_or(0) + 1
        });
        GraphBuilder {
            graph: Graph::default(),
            pool,
            plan_mode: PlanMode::DoubleBuffered,
            sim_only: false,
            group_nodes,
            act_placement,
            layer_marks: vec![[None; 2]; n_nodes],
            cur_layer: 0,
            peaks: vec![[0; 2]; n_nodes],
        }
    }

    /// Simulator-only builder: no real memory, placements only.
    pub fn sim(group_nodes: Vec<NodeId>, act_placement: Placement) -> Self {
        let mut b = GraphBuilder::new(None, group_nodes, act_placement);
        b.sim_only = true;
        b
    }

    pub fn with_plan_mode(mut self, mode: PlanMode) -> Self {
        self.plan_mode = mode;
        self
    }

    pub fn n_groups(&self) -> usize {
        self.group_nodes.len().max(1)
    }

    pub fn group_node(&self, g: usize) -> NodeId {
        self.group_nodes.get(g).copied().unwrap_or(0)
    }

    pub fn finish(mut self) -> (Graph, Option<MemoryPool>) {
        debug_assert!(self.graph.check_topological().is_ok());
        self.graph.resolve_kernels();
        (self.graph, self.pool)
    }

    /// Peak activation footprint in bytes across all nodes/parities.
    pub fn activation_footprint(&self) -> usize {
        self.peaks.iter().map(|p| p[0] + p[1]).sum()
    }

    // ---- leaves ------------------------------------------------------------

    fn push_meta(&mut self, meta: TensorMeta) -> TensorId {
        let id = TensorId(self.graph.tensors.len() as u32);
        self.graph.tensors.push(meta);
        id
    }

    /// A weight/KV/input leaf allocated in the weight arena of its
    /// primary node (no exec entry; data filled by the weight loader).
    pub fn leaf(
        &mut self,
        name: &str,
        dtype: DType,
        shape: Vec<usize>,
        placement: Placement,
    ) -> TensorId {
        let buf = if self.sim_only {
            None
        } else {
            let node = placement.node_of_row(0, self.n_pool_nodes());
            let bytes = dtype.tensor_bytes(&shape);
            let pool = self.pool.as_mut().expect("pool");
            let arena = pool.weight_arena(node);
            Some(pool.alloc(arena, bytes))
        };
        self.push_meta(TensorMeta {
            name: name.into(),
            dtype,
            shape,
            op: OpKind::Leaf,
            src: vec![],
            placement,
            buf,
            group: None,
        })
    }

    /// A KV-cache leaf in the KV arena (persistent across steps).
    pub fn kv_leaf(&mut self, name: &str, shape: Vec<usize>, placement: Placement) -> TensorId {
        let buf = if self.sim_only {
            None
        } else {
            let node = placement.node_of_row(0, self.n_pool_nodes());
            let bytes = DType::F32.tensor_bytes(&shape);
            let pool = self.pool.as_mut().expect("pool");
            let arena = pool.kv_arena(node);
            Some(pool.alloc(arena, bytes))
        };
        self.push_meta(TensorMeta {
            name: name.into(),
            dtype: DType::F32,
            shape,
            op: OpKind::Leaf,
            src: vec![],
            placement,
            buf,
            group: None,
        })
    }

    /// Import a leaf (same buffer) from another graph — prefill and
    /// decode graphs share weight and cache storage.
    pub fn import_leaf(&mut self, meta: &TensorMeta) -> TensorId {
        assert!(meta.op.is_leaf());
        self.push_meta(meta.clone())
    }

    fn n_pool_nodes(&self) -> usize {
        self.pool.as_ref().map(|p| p.n_nodes()).unwrap_or(self.layer_marks.len())
    }

    // ---- layer parity ------------------------------------------------------

    /// Enter layer `i`: rewind the parity-`i%2` activation arenas to
    /// their base marks (layer `i-2`'s activations are dead — Fig. 4).
    /// The mark for each parity is captured on first entry, protecting
    /// pre-loop activations (the embedding output) from reclamation.
    pub fn enter_layer(&mut self, layer: usize) {
        self.cur_layer = layer;
        if self.plan_mode != PlanMode::DoubleBuffered {
            return;
        }
        let parity = layer & 1;
        if let Some(pool) = self.pool.as_mut() {
            for node in 0..pool.n_nodes() {
                let arena = pool.act_arena(node, parity);
                match self.layer_marks[node][parity] {
                    Some(mark) => pool.arena_mut(arena).rewind(mark),
                    None => self.layer_marks[node][parity] = Some(pool.arena(arena).used()),
                }
            }
        }
    }

    fn parity(&self) -> usize {
        self.cur_layer & 1
    }

    // ---- activations -------------------------------------------------------

    /// Allocate an activation tensor and append its node. `group = None`
    /// → single mode (executes on the whole pool, placed per the default
    /// activation placement); `group = Some(g)` → subgraph g, placed on
    /// that group's node.
    #[allow(clippy::too_many_arguments)]
    fn push_op(
        &mut self,
        name: String,
        dtype: DType,
        shape: Vec<usize>,
        op: OpKind,
        src: Vec<TensorId>,
        group: Option<usize>,
        alias: Option<crate::memory::BufRef>,
    ) -> TensorId {
        let placement = match group {
            Some(g) => Placement::Node(self.group_node(g)),
            None => self.act_placement.clone(),
        };
        let buf = if self.sim_only {
            None
        } else if let Some(a) = alias {
            Some(a)
        } else {
            let node = placement.node_of_row(0, self.n_pool_nodes());
            let bytes = dtype.tensor_bytes(&shape);
            let parity = self.parity();
            let pool = self.pool.as_mut().expect("pool");
            let arena = pool.act_arena(node, parity);
            let r = pool.alloc(arena, bytes);
            let used = pool.arena(arena).used();
            self.peaks[node][parity] = self.peaks[node][parity].max(used);
            Some(r)
        };
        self.push_meta(TensorMeta { name, dtype, shape, op, src, placement, buf, group })
    }

    fn push_entry(&mut self, ids: Vec<TensorId>) {
        self.graph.exec.push(ExecEntry { bundle: TensorBundle::new(ids) });
    }

    // ---- op interfaces (bundle-level, the paper's module API) -------------

    /// Elementwise/unary helper: apply `op` pairing each part of `x`
    /// (and optionally `y`) — Serial mode at width 1, Parallel mode at
    /// width G.
    fn zip_op(
        &mut self,
        tag: &str,
        op: OpKind,
        dtype: DType,
        out_shape_of: impl Fn(&Graph, TensorId) -> Vec<usize>,
        srcs: Vec<&TensorBundle>,
    ) -> TensorBundle {
        let width = srcs[0].width();
        for s in &srcs {
            assert_eq!(s.width(), width, "bundle width mismatch in {tag}");
        }
        let mut out = Vec::with_capacity(width);
        for part in 0..width {
            let src: Vec<TensorId> = srcs.iter().map(|b| b.get(part)).collect();
            let shape = out_shape_of(&self.graph, src[0]);
            let group = if width > 1 { Some(part) } else { self.graph.meta(src[0]).group };
            let name = format!("{tag}.{}.{part}", self.graph.tensors.len());
            let id = self.push_op(name, dtype, shape, op.clone(), src, group, None);
            out.push(id);
        }
        self.push_entry(out.clone());
        TensorBundle::new(out)
    }

    /// Embedding lookup: tokens `[rows]` i32 × table [vocab, d] → [rows, d].
    pub fn embed(&mut self, table: &TensorBundle, tokens: &TensorBundle) -> TensorBundle {
        let d = self.graph.meta(table.single()).row_len();
        let rows = self.graph.meta(tokens.single()).numel();
        let src = vec![table.single(), tokens.single()];
        let id = self.push_op(
            format!("embed.{}", self.graph.tensors.len()),
            DType::F32,
            vec![rows, d],
            OpKind::Embed,
            src,
            None,
            None,
        );
        self.push_entry(vec![id]);
        TensorBundle::one(id)
    }

    /// RMSNorm: x [rows, d] × gain `[d]` → [rows, d].
    pub fn rmsnorm(&mut self, x: &TensorBundle, g: &TensorBundle, eps: f32) -> TensorBundle {
        self.zip_op(
            "rmsnorm",
            OpKind::RmsNorm { eps },
            DType::F32,
            |gr, x| gr.meta(x).shape.clone(),
            vec![x, g],
        )
    }

    /// Per-head RMSNorm (QK-norm).
    pub fn rmsnorm_heads(
        &mut self,
        x: &TensorBundle,
        g: &TensorBundle,
        heads: usize,
        head_dim: usize,
        eps: f32,
    ) -> TensorBundle {
        self.zip_op(
            "qknorm",
            OpKind::RmsNormHeads { eps, heads, head_dim },
            DType::F32,
            |gr, x| gr.meta(x).shape.clone(),
            vec![x, g],
        )
    }

    /// Matmul: x [rows, k] × w [n, k] → [rows, n]. In TP mode both
    /// bundles have width G and part g runs on group g (Parallel mode).
    pub fn matmul(&mut self, x: &TensorBundle, w: &TensorBundle) -> TensorBundle {
        assert_eq!(x.width(), w.width(), "matmul bundle widths");
        let mut out = Vec::with_capacity(x.width());
        for (part, (xs, ws)) in x.zip(w).enumerate() {
            let rows = self.graph.meta(xs).rows();
            let n = self.graph.meta(ws).rows();
            let k = self.graph.meta(ws).row_len();
            assert_eq!(
                self.graph.meta(xs).row_len(),
                k,
                "matmul K mismatch: {} vs {}",
                self.graph.meta(xs).name,
                self.graph.meta(ws).name
            );
            let group = if x.width() > 1 { Some(part) } else { self.graph.meta(xs).group };
            let name = format!("matmul.{}.{part}", self.graph.tensors.len());
            let id = self.push_op(
                name,
                DType::F32,
                vec![rows, n],
                OpKind::MatMul,
                vec![xs, ws],
                group,
                None,
            );
            out.push(id);
        }
        self.push_entry(out.clone());
        TensorBundle::new(out)
    }

    /// RoPE on [rows, heads*head_dim].
    pub fn rope(
        &mut self,
        x: &TensorBundle,
        heads: usize,
        head_dim: usize,
        theta: f32,
    ) -> TensorBundle {
        self.zip_op(
            "rope",
            OpKind::Rope { theta, heads, head_dim },
            DType::F32,
            |gr, x| gr.meta(x).shape.clone(),
            vec![x],
        )
    }

    /// Store new K/V rows into the cache; output aliases the cache.
    pub fn store_kv(
        &mut self,
        kv: &TensorBundle,
        cache: &TensorBundle,
        kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
    ) -> TensorBundle {
        assert_eq!(kv.width(), cache.width());
        let mut out = Vec::with_capacity(kv.width());
        for (part, (ks, cs)) in kv.zip(cache).enumerate() {
            let group = if kv.width() > 1 { Some(part) } else { self.graph.meta(ks).group };
            let alias = self.graph.meta(cs).buf;
            let shape = self.graph.meta(cs).shape.clone();
            let placement = self.graph.meta(cs).placement.clone();
            let name = format!("store_kv.{}.{part}", self.graph.tensors.len());
            let alias = alias.or(Some(crate::memory::BufRef { arena: 0, off: 0, len: 0 }));
            let id = self.push_op(
                name,
                DType::F32,
                shape,
                OpKind::StoreKv { kv_heads, head_dim, max_seq },
                vec![ks, cs],
                group,
                alias,
            );
            // placement must mirror the cache, not the group default
            self.graph.meta_mut(id).placement = placement;
            out.push(id);
        }
        self.push_entry(out.clone());
        TensorBundle::new(out)
    }

    /// Attention over the cache: q [rows, heads*hd] → [rows, heads*hd].
    #[allow(clippy::too_many_arguments)]
    pub fn attention(
        &mut self,
        q: &TensorBundle,
        k_cache: &TensorBundle,
        v_cache: &TensorBundle,
        heads: usize,
        kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
    ) -> TensorBundle {
        self.zip_op(
            "attn",
            OpKind::Attention { heads, kv_heads, head_dim, max_seq },
            DType::F32,
            |gr, q| gr.meta(q).shape.clone(),
            vec![q, k_cache, v_cache],
        )
    }

    pub fn silu(&mut self, x: &TensorBundle) -> TensorBundle {
        self.zip_op("silu", OpKind::Silu, DType::F32, |g, x| g.meta(x).shape.clone(), vec![x])
    }

    pub fn add(&mut self, a: &TensorBundle, b: &TensorBundle) -> TensorBundle {
        self.zip_op("add", OpKind::Add, DType::F32, |g, x| g.meta(x).shape.clone(), vec![a, b])
    }

    pub fn mul(&mut self, a: &TensorBundle, b: &TensorBundle) -> TensorBundle {
        self.zip_op("mul", OpKind::Mul, DType::F32, |g, x| g.meta(x).shape.clone(), vec![a, b])
    }

    /// Fused silu(gate)·up.
    pub fn swiglu(&mut self, gate: &TensorBundle, up: &TensorBundle) -> TensorBundle {
        let shape = |g: &Graph, x: TensorId| g.meta(x).shape.clone();
        self.zip_op("swiglu", OpKind::SwiGlu, DType::F32, shape, vec![gate, up])
    }

    /// Take one row of a [rows, d] tensor as [1, d] (prefill extracts
    /// the last position before the LM head).
    pub fn slice_row(&mut self, x: &TensorBundle, row: usize) -> TensorBundle {
        let xid = x.single();
        let d = self.graph.meta(xid).row_len();
        let group = self.graph.meta(xid).group;
        let id = self.push_op(
            format!("slice_row.{}", self.graph.tensors.len()),
            DType::F32,
            vec![1, d],
            OpKind::SliceRow { row },
            vec![xid],
            group,
            None,
        );
        self.push_entry(vec![id]);
        TensorBundle::one(id)
    }

    /// **Scatter** (§3.3): copy a single tensor into each group's local
    /// memory, reconfiguring execution into G parallel subgraphs.
    pub fn scatter(&mut self, x: &TensorBundle) -> TensorBundle {
        let xid = x.single();
        let g = self.n_groups();
        if g == 1 {
            return x.clone();
        }
        let shape = self.graph.meta(xid).shape.clone();
        let mut out = Vec::with_capacity(g);
        for part in 0..g {
            let name = format!("scatter.{}.{part}", self.graph.tensors.len());
            let id = self.push_op(
                name,
                DType::F32,
                shape.clone(),
                OpKind::Copy,
                vec![xid],
                Some(part),
                None,
            );
            out.push(id);
        }
        self.push_entry(out.clone());
        TensorBundle::new(out)
    }

    /// **Gather** (§3.3): sum the G partial outputs back into one tensor
    /// and return the pool to single-group execution.
    pub fn gather(&mut self, parts: &TensorBundle) -> TensorBundle {
        if parts.is_single() {
            return parts.clone();
        }
        let shape = self.graph.meta(parts.get(0)).shape.clone();
        let src: Vec<TensorId> = parts.iter().collect();
        let id = self.push_op(
            format!("gather.{}", self.graph.tensors.len()),
            DType::F32,
            shape,
            OpKind::AddN,
            src,
            None,
            None,
        );
        self.push_entry(vec![id]);
        TensorBundle::one(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_pool() -> MemoryPool {
        MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20)
    }

    fn leafy(b: &mut GraphBuilder, name: &str, shape: Vec<usize>) -> TensorBundle {
        TensorBundle::one(b.leaf(name, DType::F32, shape, Placement::Node(0)))
    }

    #[test]
    fn serial_chain_builds_in_order() {
        let mut b = GraphBuilder::new(Some(small_pool()), vec![0], Placement::Node(0));
        let x = leafy(&mut b, "x", vec![1, 64]);
        let g = leafy(&mut b, "g", vec![64]);
        let w = leafy(&mut b, "w", vec![32, 64]);
        let h = b.rmsnorm(&x, &g, 1e-6);
        let y = b.matmul(&h, &w);
        assert_eq!(b.graph.meta(y.single()).shape, vec![1, 32]);
        let (graph, _) = b.finish();
        assert_eq!(graph.exec.len(), 2);
        assert!(graph.check_topological().is_ok());
    }

    #[test]
    fn scatter_parallel_gather_modes() {
        let mut b = GraphBuilder::new(Some(small_pool()), vec![0, 1], Placement::Node(0));
        let x = leafy(&mut b, "x", vec![1, 64]);
        let w0 = b.leaf("w0", DType::F32, vec![32, 64], Placement::Node(0));
        let w1 = b.leaf("w1", DType::F32, vec![32, 64], Placement::Node(1));
        let ws = TensorBundle::new(vec![w0, w1]);
        let xs = b.scatter(&x); // 1 → 2
        assert_eq!(xs.width(), 2);
        let ys = b.matmul(&xs, &ws); // parallel
        assert_eq!(ys.width(), 2);
        let z = b.gather(&ys); // 2 → 1
        assert!(z.is_single());
        // subgraph tensors are placed on their group's node
        assert_eq!(b.graph.meta(ys.get(0)).placement, Placement::Node(0));
        assert_eq!(b.graph.meta(ys.get(1)).placement, Placement::Node(1));
        assert_eq!(b.graph.meta(ys.get(1)).group, Some(1));
        let (graph, _) = b.finish();
        assert!(graph.check_topological().is_ok());
        // exec list: scatter entry (width 2), matmul entry (width 2), gather (1)
        assert_eq!(graph.exec[0].bundle.width(), 2);
        assert_eq!(graph.exec[1].bundle.width(), 2);
        assert_eq!(graph.exec[2].bundle.width(), 1);
    }

    #[test]
    fn single_group_scatter_is_identity() {
        let mut b = GraphBuilder::new(Some(small_pool()), vec![0], Placement::Node(0));
        let x = leafy(&mut b, "x", vec![1, 8]);
        let xs = b.scatter(&x);
        assert_eq!(xs, x);
        let z = b.gather(&xs);
        assert_eq!(z, x);
        assert_eq!(b.graph.exec.len(), 0);
    }

    #[test]
    fn layer_parity_reuses_arena_space() {
        let mut b = GraphBuilder::new(Some(small_pool()), vec![0], Placement::Node(0));
        let x = leafy(&mut b, "x", vec![1, 64]);
        let g = leafy(&mut b, "g", vec![64]);
        b.enter_layer(0);
        let h0 = b.rmsnorm(&x, &g, 1e-6);
        let off0 = b.graph.buf(h0.single()).off;
        b.enter_layer(1);
        let _h1 = b.rmsnorm(&h0, &g, 1e-6);
        b.enter_layer(2);
        let h2 = b.rmsnorm(&x, &g, 1e-6);
        // layer 2 reuses layer 0's arena offsets (parity rewind)
        assert_eq!(b.graph.buf(h2.single()).off, off0);
    }

    #[test]
    fn sim_builder_has_no_buffers() {
        let mut b = GraphBuilder::sim(vec![0, 1, 2, 3], Placement::Node(0));
        let x = TensorBundle::one(b.leaf("x", DType::F32, vec![1, 128], Placement::Node(0)));
        let xs = b.scatter(&x);
        assert_eq!(xs.width(), 4);
        assert!(b.graph.meta(xs.get(2)).buf.is_none());
    }

    #[test]
    fn matmul_rejects_k_mismatch() {
        let mut b = GraphBuilder::new(Some(small_pool()), vec![0], Placement::Node(0));
        let x = leafy(&mut b, "x", vec![1, 64]);
        let w = leafy(&mut b, "w", vec![32, 128]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| b.matmul(&x, &w)));
        assert!(r.is_err());
    }

    #[test]
    fn activation_footprint_reported() {
        let mut b = GraphBuilder::new(Some(small_pool()), vec![0], Placement::Node(0));
        let x = leafy(&mut b, "x", vec![4, 256]);
        let g = leafy(&mut b, "g", vec![256]);
        b.enter_layer(0);
        b.rmsnorm(&x, &g, 1e-6);
        assert!(b.activation_footprint() >= 4 * 256 * 4);
    }
}
