//! KV-cache management (paper §2.5: "creation, injection (set) and
//! retrieval (get)").
//!
//! Caches are persistent leaves in the KV arenas. Under TP the cache is
//! sharded by KV head across NUMA nodes — each subgraph only ever
//! touches its node-local shard, so decode attention never crosses the
//! NUMA boundary (§3.2: W_k/W_v are head-partitioned).

use crate::numa::Placement;
use crate::tensor::{TensorBundle, TensorId};

use super::builder::GraphBuilder;

/// The K and V cache bundles of one transformer layer.
#[derive(Clone, Debug)]
pub struct LayerKv {
    pub k: TensorBundle,
    pub v: TensorBundle,
    /// KV heads held by each part (== kv_heads / G).
    pub heads_per_part: usize,
}

/// All layers' caches for one model instance.
pub struct KvCacheSet {
    pub layers: Vec<LayerKv>,
    pub max_seq: usize,
}

impl KvCacheSet {
    /// Create caches: one leaf per layer per TP part, shaped
    /// `[kv_heads/G, max_seq, head_dim]`, placed on the part's node.
    /// With `G == 1` the placement argument overrides (llama.cpp's
    /// interleaved UMA cache vs ArcLight's node-local cache).
    pub fn create(
        b: &mut GraphBuilder,
        n_layers: usize,
        kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        single_placement: Placement,
    ) -> KvCacheSet {
        let g = b.n_groups();
        assert!(kv_heads % g == 0, "kv_heads {kv_heads} not divisible by {g} groups");
        let hpp = kv_heads / g;
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let mut ks = Vec::with_capacity(g);
            let mut vs = Vec::with_capacity(g);
            for part in 0..g {
                let placement = if g == 1 {
                    single_placement.clone()
                } else {
                    Placement::Node(b.group_node(part))
                };
                let shape = vec![hpp, max_seq, head_dim];
                ks.push(b.kv_leaf(&format!("kv.{l}.k.{part}"), shape.clone(), placement.clone()));
                vs.push(b.kv_leaf(&format!("kv.{l}.v.{part}"), shape, placement));
            }
            layers.push(LayerKv {
                k: TensorBundle::new(ks),
                v: TensorBundle::new(vs),
                heads_per_part: hpp,
            });
        }
        KvCacheSet { layers, max_seq }
    }

    pub fn layer(&self, l: usize) -> &LayerKv {
        &self.layers[l]
    }

    /// Every cache tensor id (weight-loader / reset iteration).
    pub fn all_ids(&self) -> Vec<TensorId> {
        self.layers
            .iter()
            .flat_map(|l| l.k.iter().chain(l.v.iter()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::MemoryPool;
    use crate::tensor::DType;

    #[test]
    fn tp_cache_is_sharded_by_head() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let kv = KvCacheSet::create(&mut b, 2, 4, 16, 32, Placement::Node(0));
        assert_eq!(kv.layers.len(), 2);
        assert_eq!(kv.layer(0).k.width(), 2);
        assert_eq!(kv.layer(0).heads_per_part, 2);
        let m = b.graph.meta(kv.layer(0).k.get(1));
        assert_eq!(m.shape, vec![2, 32, 16]);
        assert_eq!(m.placement, Placement::Node(1));
        assert_eq!(m.dtype, DType::F32);
    }

    #[test]
    fn single_mode_uses_given_placement() {
        let pool = MemoryPool::new(4, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let kv = KvCacheSet::create(&mut b, 1, 4, 8, 16, Placement::Interleaved(4));
        let m = b.graph.meta(kv.layer(0).k.single());
        assert_eq!(m.placement, Placement::Interleaved(4));
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn indivisible_heads_rejected() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        KvCacheSet::create(&mut b, 1, 3, 8, 16, Placement::Node(0));
    }

    #[test]
    fn all_ids_enumerates_every_shard() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let kv = KvCacheSet::create(&mut b, 3, 2, 8, 16, Placement::Node(0));
        assert_eq!(kv.all_ids().len(), 3 * 2 * 2);
    }
}
