//! KV-cache management (paper §2.5: "creation, injection (set) and
//! retrieval (get)").
//!
//! Caches are persistent leaves in the KV arenas. Under TP the cache is
//! sharded by KV head across NUMA nodes — each subgraph only ever
//! touches its node-local shard, so decode attention never crosses the
//! NUMA boundary (§3.2: W_k/W_v are head-partitioned).
//!
//! For continuous batching the cache is a **pool**: each layer's leaf
//! holds `slots` logical sequence slots of `max_seq` positions carved
//! from one arena allocation (`[kv_heads/G, slots·max_seq, head_dim]`).
//! Slot `s` owns cache positions `[s·max_seq, (s+1)·max_seq)`; the
//! engine allocates a slot when a request starts and frees it when the
//! request finishes ([`SlotAllocator`]). Stale bytes in a recycled slot
//! are harmless: a sequence's attention span only ever covers positions
//! it has itself stored this lifetime.

use crate::numa::Placement;
use crate::tensor::{TensorBundle, TensorId};

use super::builder::GraphBuilder;

/// The K and V cache bundles of one transformer layer.
#[derive(Clone, Debug)]
pub struct LayerKv {
    pub k: TensorBundle,
    pub v: TensorBundle,
    /// KV heads held by each part (== kv_heads / G).
    pub heads_per_part: usize,
}

/// All layers' caches for one model instance.
pub struct KvCacheSet {
    pub layers: Vec<LayerKv>,
    /// Positions per sequence slot.
    pub max_seq: usize,
    /// Sequence slots carved from the pool (1 = classic single-sequence).
    pub slots: usize,
}

impl KvCacheSet {
    /// Create single-sequence caches (`slots == 1`); see
    /// [`KvCacheSet::create_pooled`].
    pub fn create(
        b: &mut GraphBuilder,
        n_layers: usize,
        kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        single_placement: Placement,
    ) -> KvCacheSet {
        Self::create_pooled(b, n_layers, kv_heads, head_dim, max_seq, 1, single_placement)
    }

    /// Create caches: one leaf per layer per TP part, shaped
    /// `[kv_heads/G, slots·max_seq, head_dim]`, placed on the part's
    /// node. With `G == 1` the placement argument overrides (llama.cpp's
    /// interleaved UMA cache vs ArcLight's node-local cache).
    #[allow(clippy::too_many_arguments)]
    pub fn create_pooled(
        b: &mut GraphBuilder,
        n_layers: usize,
        kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        slots: usize,
        single_placement: Placement,
    ) -> KvCacheSet {
        let g = b.n_groups();
        assert!(kv_heads % g == 0, "kv_heads {kv_heads} not divisible by {g} groups");
        assert!(slots >= 1, "a KV pool needs at least one slot");
        let hpp = kv_heads / g;
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let mut ks = Vec::with_capacity(g);
            let mut vs = Vec::with_capacity(g);
            for part in 0..g {
                let placement = if g == 1 {
                    single_placement.clone()
                } else {
                    Placement::Node(b.group_node(part))
                };
                let shape = vec![hpp, slots * max_seq, head_dim];
                ks.push(b.kv_leaf(&format!("kv.{l}.k.{part}"), shape.clone(), placement.clone()));
                vs.push(b.kv_leaf(&format!("kv.{l}.v.{part}"), shape, placement));
            }
            layers.push(LayerKv {
                k: TensorBundle::new(ks),
                v: TensorBundle::new(vs),
                heads_per_part: hpp,
            });
        }
        KvCacheSet { layers, max_seq, slots }
    }

    pub fn layer(&self, l: usize) -> &LayerKv {
        &self.layers[l]
    }

    /// Total cache positions per kv head (`slots · max_seq`) — the
    /// stride every attention/store op over this pool uses.
    pub fn capacity(&self) -> usize {
        self.slots * self.max_seq
    }

    /// First cache position of sequence slot `s`.
    pub fn slot_base(&self, s: usize) -> usize {
        debug_assert!(s < self.slots);
        s * self.max_seq
    }

    /// Every cache tensor id (weight-loader / reset iteration).
    pub fn all_ids(&self) -> Vec<TensorId> {
        self.layers
            .iter()
            .flat_map(|l| l.k.iter().chain(l.v.iter()))
            .collect()
    }
}

/// Free-list of sequence slots in the KV pool. Purely bookkeeping — no
/// bytes move on alloc/free (see the module docs for why recycled slots
/// need no zeroing).
#[derive(Clone, Debug)]
pub struct SlotAllocator {
    free: Vec<usize>,
    slots: usize,
}

impl SlotAllocator {
    pub fn new(slots: usize) -> Self {
        // pop() hands out low slot indices first
        SlotAllocator { free: (0..slots).rev().collect(), slots }
    }

    pub fn alloc(&mut self) -> Option<usize> {
        self.free.pop()
    }

    pub fn free(&mut self, slot: usize) {
        assert!(slot < self.slots, "slot {slot} out of range");
        assert!(!self.free.contains(&slot), "double free of slot {slot}");
        self.free.push(slot);
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Whether `slot` is currently unallocated.
    pub fn is_free(&self, slot: usize) -> bool {
        self.free.contains(&slot)
    }

    pub fn in_use(&self) -> usize {
        self.slots - self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::MemoryPool;
    use crate::tensor::DType;

    #[test]
    fn tp_cache_is_sharded_by_head() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let kv = KvCacheSet::create(&mut b, 2, 4, 16, 32, Placement::Node(0));
        assert_eq!(kv.layers.len(), 2);
        assert_eq!(kv.layer(0).k.width(), 2);
        assert_eq!(kv.layer(0).heads_per_part, 2);
        let m = b.graph.meta(kv.layer(0).k.get(1));
        assert_eq!(m.shape, vec![2, 32, 16]);
        assert_eq!(m.placement, Placement::Node(1));
        assert_eq!(m.dtype, DType::F32);
    }

    #[test]
    fn single_mode_uses_given_placement() {
        let pool = MemoryPool::new(4, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let kv = KvCacheSet::create(&mut b, 1, 4, 8, 16, Placement::Interleaved(4));
        let m = b.graph.meta(kv.layer(0).k.single());
        assert_eq!(m.placement, Placement::Interleaved(4));
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn indivisible_heads_rejected() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        KvCacheSet::create(&mut b, 1, 3, 8, 16, Placement::Node(0));
    }

    #[test]
    fn all_ids_enumerates_every_shard() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let kv = KvCacheSet::create(&mut b, 3, 2, 8, 16, Placement::Node(0));
        assert_eq!(kv.all_ids().len(), 3 * 2 * 2);
    }

    #[test]
    fn pooled_cache_carves_slot_spans() {
        let pool = MemoryPool::new(1, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let kv = KvCacheSet::create_pooled(&mut b, 2, 2, 8, 16, 4, Placement::Node(0));
        assert_eq!(kv.capacity(), 64);
        assert_eq!(kv.slot_base(3), 48);
        let m = b.graph.meta(kv.layer(1).k.single());
        assert_eq!(m.shape, vec![2, 64, 8]);
    }

    #[test]
    fn slot_allocator_recycles() {
        let mut a = SlotAllocator::new(3);
        assert_eq!(a.available(), 3);
        let s0 = a.alloc().unwrap();
        let s1 = a.alloc().unwrap();
        assert_eq!((s0, s1), (0, 1));
        assert_eq!(a.in_use(), 2);
        a.free(s0);
        assert_eq!(a.alloc().unwrap(), 0);
        let s2 = a.alloc().unwrap();
        assert_eq!(s2, 2);
        assert!(a.alloc().is_none());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn slot_double_free_rejected() {
        let mut a = SlotAllocator::new(2);
        let s = a.alloc().unwrap();
        a.free(s);
        a.free(s);
    }
}
