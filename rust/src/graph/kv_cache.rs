//! KV-cache management (paper §2.5: "creation, injection (set) and
//! retrieval (get)").
//!
//! Caches are persistent leaves in the KV arenas. Under TP the cache is
//! sharded by KV head across NUMA nodes — each subgraph only ever
//! touches its node-local shard, so decode attention never crosses the
//! NUMA boundary (§3.2: W_k/W_v are head-partitioned).
//!
//! For continuous batching the cache is a **paged pool**: each layer's
//! leaf holds `pages · page_size` token positions carved from one arena
//! allocation (`[kv_heads/G, pages·page_size, head_dim]`). A *page* is
//! `page_size` consecutive physical positions; sequences map logical
//! position `p` to physical position `table[p / P]·P + p % P` through a
//! per-sequence [`PageTable`]. Page indices address the same offset in
//! every layer shard, so a page inherits each shard's NUMA placement —
//! TP keeps a KV head's pages node-local exactly as before. The
//! [`PageArena`] is the refcounted free-list plus the prefix index that
//! lets identical prompt prefixes share physical pages across
//! sequences (copy-on-write happens one level up, in the engine, which
//! owns the buffers). Stale bytes in a recycled page are harmless: a
//! sequence's attention gather only ever visits pages its table names,
//! at offsets it has itself stored this lifetime.

use std::collections::{HashMap, VecDeque};

use crate::numa::Placement;
use crate::tensor::{TensorBundle, TensorId};

use super::builder::GraphBuilder;

/// A sequence's logical→physical page mapping: entry `i` is the
/// physical page backing logical positions `[i·P, (i+1)·P)`.
pub type PageTable = Vec<u32>;

/// The K and V cache bundles of one transformer layer.
#[derive(Clone, Debug)]
pub struct LayerKv {
    pub k: TensorBundle,
    pub v: TensorBundle,
    /// KV heads held by each part (== kv_heads / G).
    pub heads_per_part: usize,
}

/// Everything [`KvCacheSet::create`] needs, replacing the old
/// seven-positional-argument constructors. Build one with
/// [`KvSpec::for_model`] and chain the setters:
///
/// ```ignore
/// let spec = KvSpec::for_model(layers, kv_heads, head_dim, max_seq)
///     .page_size(16)
///     .pages(64)
///     .placement(Placement::Node(0));
/// let kv = KvCacheSet::create(&mut b, &spec);
/// ```
#[derive(Clone, Debug)]
pub struct KvSpec {
    pub layers: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Longest single sequence (logical positions per sequence).
    pub max_seq: usize,
    /// Physical pages in the arena (capacity = `pages · page_size`).
    pub pages: usize,
    /// Tokens per page per layer-shard.
    pub page_size: usize,
    /// Placement of single-group caches (TP shards always go to their
    /// part's node).
    pub placement: Placement,
}

impl KvSpec {
    /// Defaults: page size 16, arena sized for exactly one full-length
    /// sequence, node-0 placement.
    pub fn for_model(layers: usize, kv_heads: usize, head_dim: usize, max_seq: usize) -> KvSpec {
        let page_size = 16usize.min(max_seq.max(1));
        KvSpec {
            layers,
            kv_heads,
            head_dim,
            max_seq,
            pages: max_seq.div_ceil(page_size),
            page_size,
            placement: Placement::Node(0),
        }
    }

    /// Set the page size and re-derive `pages` to keep the current
    /// whole-sequence capacity.
    pub fn page_size(mut self, page_size: usize) -> KvSpec {
        assert!(page_size >= 1, "page size must be at least 1 token");
        let seqs = (self.pages * self.page_size).div_ceil(self.max_seq.max(1)).max(1);
        self.page_size = page_size;
        self.pages = seqs * self.max_seq.div_ceil(page_size);
        self
    }

    /// Set the physical page count directly.
    pub fn pages(mut self, pages: usize) -> KvSpec {
        assert!(pages >= 1, "a page arena needs at least one page");
        self.pages = pages;
        self
    }

    /// Size the arena for `n` concurrent full-length sequences.
    pub fn slots(self, n: usize) -> KvSpec {
        assert!(n >= 1, "a KV pool needs at least one slot");
        let per_seq = self.max_seq.div_ceil(self.page_size);
        self.pages(n * per_seq)
    }

    pub fn placement(mut self, placement: Placement) -> KvSpec {
        self.placement = placement;
        self
    }
}

/// All layers' caches for one model instance.
pub struct KvCacheSet {
    pub layers: Vec<LayerKv>,
    /// Longest single sequence (logical positions per sequence).
    pub max_seq: usize,
    /// Physical pages carved from each layer leaf.
    pub pages: usize,
    /// Tokens per page.
    pub page_size: usize,
}

impl KvCacheSet {
    /// Create caches: one leaf per layer per TP part, shaped
    /// `[kv_heads/G, pages·page_size, head_dim]`, placed on the part's
    /// node. With `G == 1` the spec's placement applies (llama.cpp's
    /// interleaved UMA cache vs ArcLight's node-local cache).
    pub fn create(b: &mut GraphBuilder, spec: &KvSpec) -> KvCacheSet {
        let g = b.n_groups();
        assert!(spec.kv_heads % g == 0, "kv_heads {} not divisible by {g} groups", spec.kv_heads);
        assert!(spec.pages >= 1, "a page arena needs at least one page");
        assert!(spec.page_size >= 1, "page size must be at least 1 token");
        assert!(
            spec.pages * spec.page_size >= spec.max_seq,
            "page arena ({} pages x {}) smaller than one {}-token sequence",
            spec.pages,
            spec.page_size,
            spec.max_seq
        );
        let hpp = spec.kv_heads / g;
        let capacity = spec.pages * spec.page_size;
        let mut layers = Vec::with_capacity(spec.layers);
        for l in 0..spec.layers {
            let mut ks = Vec::with_capacity(g);
            let mut vs = Vec::with_capacity(g);
            for part in 0..g {
                let placement = if g == 1 {
                    spec.placement.clone()
                } else {
                    Placement::Node(b.group_node(part))
                };
                let shape = vec![hpp, capacity, spec.head_dim];
                ks.push(b.kv_leaf(&format!("kv.{l}.k.{part}"), shape.clone(), placement.clone()));
                vs.push(b.kv_leaf(&format!("kv.{l}.v.{part}"), shape, placement));
            }
            layers.push(LayerKv {
                k: TensorBundle::new(ks),
                v: TensorBundle::new(vs),
                heads_per_part: hpp,
            });
        }
        KvCacheSet {
            layers,
            max_seq: spec.max_seq,
            pages: spec.pages,
            page_size: spec.page_size,
        }
    }

    pub fn layer(&self, l: usize) -> &LayerKv {
        &self.layers[l]
    }

    /// Total cache positions per kv head (`pages · page_size`) — the
    /// stride every attention/store op over this pool uses.
    pub fn capacity(&self) -> usize {
        self.pages * self.page_size
    }

    /// Every cache tensor id (weight-loader / reset iteration).
    pub fn all_ids(&self) -> Vec<TensorId> {
        self.layers
            .iter()
            .flat_map(|l| l.k.iter().chain(l.v.iter()))
            .collect()
    }
}

/// Refcounted physical-page allocator with a prefix-sharing index.
/// Purely bookkeeping — no bytes move on alloc/free (see the module
/// docs for why recycled pages need no zeroing).
///
/// Three kinds of reference hold a page: live sequence tables, the
/// prefix index (a completed page registered under the rolling hash of
/// every token up to its end survives its sequences, so later requests
/// with the same prompt prefix can adopt it), and nothing else. A page
/// whose only holder is the index is *evictable*: [`PageArena::admit`]
/// counts `free + evictable` as available capacity and
/// [`PageArena::alloc_page`] evicts the oldest registration when the
/// free list runs dry.
///
/// Admission is **reservation-based**: a sequence reserves every page
/// it may ever need up front (minus pages adopted from the index), so
/// a sequence that was admitted can never hit out-of-memory
/// mid-decode.
#[derive(Clone, Debug, Default)]
pub struct PageArena {
    page_size: usize,
    /// Holders per page: sequence tables + 1 if registered in `index`.
    refs: Vec<u32>,
    /// Pages with `refs == 0`; pop() hands out low indices first.
    free: Vec<u32>,
    /// Pages promised to admitted sequences but not yet allocated.
    reserved: usize,
    /// Rolling prefix hash → completed page holding that prefix's last
    /// `page_size` tokens.
    index: HashMap<u64, u32>,
    /// Reverse map of `index` (None = unregistered).
    hash_of: Vec<Option<u64>>,
    /// Registration order, for FIFO eviction.
    fifo: VecDeque<u32>,
}

impl PageArena {
    pub fn new(pages: usize, page_size: usize) -> PageArena {
        assert!(pages >= 1 && page_size >= 1, "page arena needs pages and a page size");
        PageArena {
            page_size,
            refs: vec![0; pages],
            free: (0..pages as u32).rev().collect(),
            reserved: 0,
            index: HashMap::new(),
            hash_of: vec![None; pages],
            fifo: VecDeque::new(),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn total_pages(&self) -> usize {
        self.refs.len()
    }

    /// Pages referenced by at least one holder (sequence or index).
    pub fn in_use_pages(&self) -> usize {
        self.refs.len() - self.free.len()
    }

    /// Pages held *only* by the prefix index (reclaimable on demand).
    pub fn cached_pages(&self) -> usize {
        self.fifo.iter().filter(|&&p| self.refs[p as usize] == 1).count()
    }

    /// Pages an admission could still claim: free + evictable − already
    /// promised to other admitted sequences.
    pub fn available_pages(&self) -> usize {
        (self.free.len() + self.cached_pages()).saturating_sub(self.reserved)
    }

    /// Admit a sequence needing `total_pages` pages over its lifetime.
    /// `prefix_hashes[i]` is the rolling hash after logical page `i`
    /// completed; the longest indexed run is adopted (shared, refcount
    /// bumped) and only the remainder is reserved. Returns the adopted
    /// pages, or `None` when the arena cannot promise the remainder —
    /// the caller should retry after other sequences retire.
    pub fn admit(&mut self, prefix_hashes: &[u64], total_pages: usize) -> Option<Vec<u32>> {
        let mut hits: Vec<u32> = Vec::new();
        for h in prefix_hashes {
            match self.index.get(h) {
                Some(&p) if !hits.contains(&p) => hits.push(p),
                _ => break,
            }
        }
        loop {
            let fresh = total_pages - hits.len();
            // adopting an index-only page pins it (no longer evictable)
            let pinned = hits.iter().filter(|&&p| self.refs[p as usize] == 1).count();
            if self.free.len() + self.cached_pages() >= self.reserved + fresh + pinned {
                self.reserved += fresh;
                for &p in &hits {
                    self.refs[p as usize] += 1;
                }
                return Some(hits);
            }
            // a shorter shared run pins fewer cached pages; retry
            // without hits before giving up entirely
            if hits.is_empty() {
                return None;
            }
            hits.clear();
        }
    }

    /// Claim one page out of an existing reservation. Never fails: the
    /// reservation accounting guarantees a free or evictable page.
    pub fn alloc_page(&mut self) -> u32 {
        assert!(self.reserved > 0, "page allocated without a reservation");
        self.reserved -= 1;
        if let Some(p) = self.free.pop() {
            return p;
        }
        // evict the oldest index-only registration
        let mut scanned = 0;
        let n = self.fifo.len();
        while scanned < n {
            let p = self.fifo.pop_front().expect("fifo tracked registrations");
            scanned += 1;
            if self.hash_of[p as usize].is_none() {
                continue; // stale entry, already unregistered
            }
            if self.refs[p as usize] == 1 {
                self.unregister(p);
                self.refs[p as usize] = 0;
                return p;
            }
            self.fifo.push_back(p); // still shared by a live sequence
        }
        panic!("page reservation accounting violated: no free or evictable page");
    }

    /// Return pages a dropped sequence promised but never claimed.
    pub fn unreserve(&mut self, pages: usize) {
        debug_assert!(pages <= self.reserved, "unreserve of pages never reserved");
        self.reserved = self.reserved.saturating_sub(pages);
    }

    /// Add a holder to `page` (prefix adoption outside `admit`, or a
    /// fork sharing its parent's table).
    pub fn retain(&mut self, page: u32) {
        assert!(self.refs[page as usize] > 0, "retain of an unheld page {page}");
        self.refs[page as usize] += 1;
    }

    /// Drop one holder of `page`; a page with no holders left returns
    /// to the free list.
    pub fn release(&mut self, page: u32) {
        let r = &mut self.refs[page as usize];
        assert!(*r > 0, "double free of page {page}");
        *r -= 1;
        if *r == 0 {
            debug_assert!(self.hash_of[page as usize].is_none());
            self.free.push(page);
        }
    }

    /// How many holders `page` currently has (CoW triggers at > 1).
    pub fn holders(&self, page: u32) -> u32 {
        self.refs[page as usize]
    }

    /// Register a just-completed page under the rolling hash of every
    /// token up to its end. The index becomes a holder, so the page
    /// survives its sequences until evicted. First registration of a
    /// hash wins; re-registering a page under a new hash is rejected.
    pub fn register(&mut self, hash: u64, page: u32) {
        if self.hash_of[page as usize].is_some() || self.index.contains_key(&hash) {
            return;
        }
        assert!(self.refs[page as usize] > 0, "registering an unheld page {page}");
        self.refs[page as usize] += 1;
        self.hash_of[page as usize] = Some(hash);
        self.index.insert(hash, page);
        self.fifo.push_back(page);
    }

    /// Look up a completed-prefix page without adopting it.
    pub fn lookup(&self, hash: u64) -> Option<u32> {
        self.index.get(&hash).copied()
    }

    /// Drop every prefix registration (engine reset).
    pub fn clear_index(&mut self) {
        let pages: Vec<u32> = self.index.values().copied().collect();
        for p in pages {
            self.unregister(p);
            let r = &mut self.refs[p as usize];
            *r -= 1;
            if *r == 0 {
                self.free.push(p);
            }
        }
        self.fifo.clear();
    }

    fn unregister(&mut self, page: u32) {
        if let Some(h) = self.hash_of[page as usize].take() {
            self.index.remove(&h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::MemoryPool;
    use crate::tensor::DType;

    fn spec(layers: usize, kv_heads: usize, head_dim: usize, max_seq: usize) -> KvSpec {
        KvSpec::for_model(layers, kv_heads, head_dim, max_seq)
    }

    #[test]
    fn tp_cache_is_sharded_by_head() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let kv = KvCacheSet::create(&mut b, &spec(2, 4, 16, 32));
        assert_eq!(kv.layers.len(), 2);
        assert_eq!(kv.layer(0).k.width(), 2);
        assert_eq!(kv.layer(0).heads_per_part, 2);
        let m = b.graph.meta(kv.layer(0).k.get(1));
        assert_eq!(m.shape, vec![2, 32, 16]);
        assert_eq!(m.placement, Placement::Node(1));
        assert_eq!(m.dtype, DType::F32);
    }

    #[test]
    fn single_mode_uses_given_placement() {
        let pool = MemoryPool::new(4, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let s = spec(1, 4, 8, 16).placement(Placement::Interleaved(4));
        let kv = KvCacheSet::create(&mut b, &s);
        let m = b.graph.meta(kv.layer(0).k.single());
        assert_eq!(m.placement, Placement::Interleaved(4));
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn indivisible_heads_rejected() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        KvCacheSet::create(&mut b, &spec(1, 3, 8, 16));
    }

    #[test]
    fn all_ids_enumerates_every_shard() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let kv = KvCacheSet::create(&mut b, &spec(3, 2, 8, 16));
        assert_eq!(kv.all_ids().len(), 3 * 2 * 2);
    }

    #[test]
    fn pooled_cache_carves_pages() {
        let pool = MemoryPool::new(1, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        // 4 slots of a 16-token sequence at page size 8 = 8 pages
        let kv = KvCacheSet::create(&mut b, &spec(2, 2, 8, 16).page_size(8).slots(4));
        assert_eq!(kv.pages, 8);
        assert_eq!(kv.capacity(), 64);
        let m = b.graph.meta(kv.layer(1).k.single());
        assert_eq!(m.shape, vec![2, 64, 8]);
    }

    #[test]
    #[should_panic(expected = "smaller than one")]
    fn undersized_arena_rejected() {
        let pool = MemoryPool::new(1, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        KvCacheSet::create(&mut b, &spec(1, 2, 8, 64).page_size(8).pages(2));
    }

    #[test]
    fn arena_reserves_allocs_and_recycles() {
        let mut a = PageArena::new(4, 8);
        assert_eq!(a.available_pages(), 4);
        let hits = a.admit(&[], 3).unwrap();
        assert!(hits.is_empty());
        assert_eq!(a.available_pages(), 1);
        let p0 = a.alloc_page();
        let p1 = a.alloc_page();
        assert_eq!((p0, p1), (0, 1));
        assert_eq!(a.in_use_pages(), 2);
        // a second admission can't overcommit the remaining page
        assert!(a.admit(&[], 2).is_none());
        assert!(a.admit(&[], 1).is_some());
        a.release(p0);
        a.unreserve(1); // the un-claimed third page of the first admit
        assert_eq!(a.alloc_page(), 0, "freed page recycles low-first");
        a.release(p1);
        a.release(0);
        assert_eq!(a.in_use_pages(), 0);
        assert_eq!(a.available_pages(), 4);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn page_double_free_rejected() {
        let mut a = PageArena::new(2, 8);
        a.admit(&[], 1).unwrap();
        let p = a.alloc_page();
        a.release(p);
        a.release(p);
    }

    #[test]
    fn prefix_index_shares_and_evicts() {
        let mut a = PageArena::new(3, 4);
        a.admit(&[], 2).unwrap();
        let p = a.alloc_page();
        a.register(0xfeed, p);
        assert_eq!(a.holders(p), 2);
        a.release(p); // sequence retires; index keeps the page alive
        assert_eq!(a.cached_pages(), 1);
        assert_eq!(a.lookup(0xfeed), Some(p));

        // a new identical-prefix admission adopts the cached page
        let hits = a.admit(&[0xfeed], 2).unwrap();
        assert_eq!(hits, vec![p]);
        assert_eq!(a.holders(p), 2);
        assert_eq!(a.cached_pages(), 0, "adopted page is pinned");

        // release everything; demand for the whole arena then evicts
        // the registration (free pages go first, cached page last)
        a.release(p);
        a.unreserve(2); // one unclaimed page from each admission
        let hits = a.admit(&[], 3).unwrap();
        assert!(hits.is_empty());
        let claimed = [a.alloc_page(), a.alloc_page(), a.alloc_page()];
        assert_eq!(claimed[2], p, "cached page evicted under demand, free pages first");
        assert_eq!(a.lookup(0xfeed), None);
    }
}
