//! The worker thread pool.
//!
//! Workers are spawned once (before inference) and bound to *simulated*
//! cores — the `Core` tag flows into the cost model; on the real host
//! the OS schedules them freely. Jobs are closures dispatched to an
//! explicit subset of workers; the scheduler composes them with group /
//! global barriers to realize Sync-A or Sync-B execution (§3.4).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::SpinBarrier;
use crate::numa::Core;

/// Per-worker identity visible to job closures.
#[derive(Clone, Copy, Debug)]
pub struct WorkerCtx {
    /// Index of this worker within the pool (== simulated core order).
    pub worker: usize,
    /// The simulated core this worker is bound to.
    pub core: Core,
}

type Job = Box<dyn FnOnce(&WorkerCtx) + Send>;

enum Msg {
    Run(Job, Arc<Latch>),
    Shutdown,
}

/// Countdown latch for leader-side completion waits.
pub struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.cv.wait(r).unwrap();
        }
    }
}

/// Fixed pool of workers bound to simulated cores.
pub struct ThreadPool {
    senders: Vec<Sender<Msg>>,
    handles: Vec<JoinHandle<()>>,
    cores: Vec<Core>,
    global_barrier: Arc<SpinBarrier>,
    jobs_dispatched: AtomicUsize,
}

impl ThreadPool {
    /// Spawn one worker per core.
    pub fn new(cores: Vec<Core>) -> Self {
        let n = cores.len();
        assert!(n > 0);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (i, core) in cores.iter().copied().enumerate() {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            let ctx = WorkerCtx { worker: i, core };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("arclight-w{i}"))
                    .spawn(move || {
                        while let Ok(msg) = rx.recv() {
                            match msg {
                                Msg::Run(job, latch) => {
                                    job(&ctx);
                                    latch.count_down();
                                }
                                Msg::Shutdown => break,
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            senders,
            handles,
            cores,
            global_barrier: Arc::new(SpinBarrier::new(n)),
            jobs_dispatched: AtomicUsize::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.cores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cores.is_empty()
    }

    pub fn cores(&self) -> &[Core] {
        &self.cores
    }

    /// Barrier spanning every worker of the pool (the paper's *global
    /// barrier*, Fig. 6). Valid only inside jobs dispatched to **all**
    /// workers.
    pub fn global_barrier(&self) -> Arc<SpinBarrier> {
        self.global_barrier.clone()
    }

    /// Total jobs dispatched (metrics).
    pub fn jobs_dispatched(&self) -> usize {
        self.jobs_dispatched.load(Ordering::Relaxed)
    }

    /// Run `f` on the given workers and block until all finish.
    /// `f(ctx)` — rank/size bookkeeping is the caller's (the scheduler
    /// knows each worker's group assignment).
    pub fn run_on<F>(&self, workers: &[usize], f: Arc<F>)
    where
        F: Fn(&WorkerCtx) + Send + Sync + 'static,
    {
        let latch = Arc::new(Latch::new(workers.len()));
        for &w in workers {
            let f = f.clone();
            let job: Job = Box::new(move |ctx| f(ctx));
            self.senders[w]
                .send(Msg::Run(job, latch.clone()))
                .expect("worker alive");
        }
        self.jobs_dispatched.fetch_add(workers.len(), Ordering::Relaxed);
        latch.wait();
    }

    /// Run `f` on every worker.
    pub fn run_all<F>(&self, f: Arc<F>)
    where
        F: Fn(&WorkerCtx) + Send + Sync + 'static,
    {
        let all: Vec<usize> = (0..self.len()).collect();
        self.run_on(&all, f);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    fn cores(n: usize) -> Vec<Core> {
        let t = Topology::uniform(2, n.div_ceil(2), 100.0, 25.0);
        (0..n).map(|i| t.core(i)).collect()
    }

    #[test]
    fn run_all_touches_every_worker() {
        let pool = ThreadPool::new(cores(6));
        let hits = Arc::new(Mutex::new(vec![0usize; 6]));
        let h2 = hits.clone();
        pool.run_all(Arc::new(move |ctx: &WorkerCtx| {
            h2.lock().unwrap()[ctx.worker] += 1;
        }));
        assert_eq!(*hits.lock().unwrap(), vec![1; 6]);
    }

    #[test]
    fn run_on_subset_only() {
        let pool = ThreadPool::new(cores(4));
        let hits = Arc::new(Mutex::new(vec![0usize; 4]));
        let h2 = hits.clone();
        pool.run_on(&[1, 3], Arc::new(move |ctx: &WorkerCtx| {
            h2.lock().unwrap()[ctx.worker] += 1;
        }));
        assert_eq!(*hits.lock().unwrap(), vec![0, 1, 0, 1]);
    }

    #[test]
    fn global_barrier_synchronizes_all() {
        let pool = ThreadPool::new(cores(4));
        let gb = pool.global_barrier();
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = counter.clone();
        pool.run_all(Arc::new(move |_ctx: &WorkerCtx| {
            c2.fetch_add(1, Ordering::SeqCst);
            gb.wait();
            // all four increments must be visible after the barrier
            assert_eq!(c2.load(Ordering::SeqCst), 4);
        }));
    }

    #[test]
    fn worker_core_binding_matches_order() {
        let cs = cores(4);
        let pool = ThreadPool::new(cs.clone());
        let seen = Arc::new(Mutex::new(vec![None; 4]));
        let s2 = seen.clone();
        pool.run_all(Arc::new(move |ctx: &WorkerCtx| {
            s2.lock().unwrap()[ctx.worker] = Some(ctx.core);
        }));
        let seen = seen.lock().unwrap();
        for (i, c) in cs.iter().enumerate() {
            assert_eq!(seen[i], Some(*c));
        }
    }

    #[test]
    fn sequential_jobs_do_not_deadlock() {
        let pool = ThreadPool::new(cores(3));
        for _ in 0..100 {
            pool.run_all(Arc::new(|_: &WorkerCtx| {}));
        }
        assert_eq!(pool.jobs_dispatched(), 300);
    }
}
