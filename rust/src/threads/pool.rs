//! The worker thread pool.
//!
//! Workers are spawned once (before inference) and carry a *simulated*
//! [`Core`] tag that flows into the cost model. By default the OS
//! schedules them freely; [`ThreadPool::with_affinity`] additionally
//! binds each worker to a real OS cpu via
//! [`crate::hw::affinity::pin_current_thread`] **before it serves its
//! first job**, so the persistent-worker pass loop stops migrating
//! mid-pass on real NUMA hosts. Pinning is best effort: per-worker
//! success is recorded and surfaced ([`ThreadPool::pinned_workers`]);
//! a failed pin leaves the worker running unpinned. Workers are named
//! `arclight-w{rank}-n{node}` so `perf`/`htop` sessions on real hosts
//! attribute time to nodes. Two dispatch shapes exist:
//!
//! * [`ThreadPool::run_on`]/[`ThreadPool::run_all`] — a boxed closure
//!   per worker with a completion latch. General-purpose, but one call
//!   per operator is the dispatch tax the scheduler no longer pays.
//! * [`ThreadPool::run_pass`] — the persistent-worker entry point: one
//!   *shared* job (an `Arc` clone per worker, no per-op boxing) that
//!   every worker runs to completion, typically walking a compiled
//!   [`crate::sched::PassPlan`] and synchronizing on the global/group
//!   spin barriers itself. One call == one pool dispatch per pass.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::SpinBarrier;
use crate::numa::Core;

/// Per-worker identity visible to job closures.
#[derive(Clone, Copy, Debug)]
pub struct WorkerCtx {
    /// Index of this worker within the pool (== simulated core order).
    pub worker: usize,
    /// The simulated core this worker is bound to.
    pub core: Core,
}

type Job = Box<dyn FnOnce(&WorkerCtx) + Send>;
type SharedJob = Arc<dyn Fn(&WorkerCtx) + Send + Sync>;

enum Msg {
    Run(Job, Arc<Latch>),
    RunShared(SharedJob, Arc<Latch>),
    Shutdown,
}

/// Countdown latch for leader-side completion waits, poisoned when a
/// worker's job panicked (the worker survives; the leader surfaces).
pub struct Latch {
    remaining: Mutex<usize>,
    poisoned: AtomicBool,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), poisoned: AtomicBool::new(false), cv: Condvar::new() }
    }

    fn count_down(&self, panicked: bool) {
        if panicked {
            self.poisoned.store(true, Ordering::Release);
        }
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every party counted down; `true` when any of them
    /// panicked (the caller must surface this, not swallow it).
    fn wait(&self) -> bool {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.cv.wait(r).unwrap();
        }
        self.poisoned.load(Ordering::Acquire)
    }
}

/// Fixed pool of workers bound to simulated cores.
pub struct ThreadPool {
    senders: Vec<Sender<Msg>>,
    handles: Vec<JoinHandle<()>>,
    cores: Vec<Core>,
    global_barrier: Arc<SpinBarrier>,
    jobs_dispatched: AtomicUsize,
    dispatches: AtomicUsize,
    /// Per-worker host-pin outcome (`false` everywhere when spawned
    /// without a cpu map or when pinning is unavailable).
    pinned: Vec<bool>,
    /// Trace identity of this pool: the scope `trace::finish_pass`
    /// drains. Workers bind their thread-local span rings to it at
    /// spawn; distinct pools (cluster replicas) never share rings.
    trace_pool: u64,
}

impl ThreadPool {
    /// Spawn one worker per core (no host pinning).
    pub fn new(cores: Vec<Core>) -> Self {
        Self::with_affinity(cores, None)
    }

    /// Spawn one worker per core; when `cpu_map` is given, worker `i`
    /// pins itself to OS cpu `cpu_map[i]` before serving its first
    /// job. The constructor blocks until every worker has reported its
    /// pin outcome, so [`ThreadPool::pinned_workers`] is exact from
    /// the moment the pool exists. A failed pin (restricted mask,
    /// stub build) leaves that worker running unpinned.
    pub fn with_affinity(cores: Vec<Core>, cpu_map: Option<Vec<usize>>) -> Self {
        let n = cores.len();
        assert!(n > 0);
        if let Some(map) = &cpu_map {
            assert_eq!(map.len(), n, "cpu map must cover every worker");
        }
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        let pin_state: Arc<Vec<AtomicBool>> =
            Arc::new((0..n).map(|_| AtomicBool::new(false)).collect());
        let started = Arc::new(Latch::new(n));
        let trace_pool = crate::trace::new_pool_id();
        for (i, core) in cores.iter().copied().enumerate() {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            let ctx = WorkerCtx { worker: i, core };
            let pin_cpu = cpu_map.as_ref().map(|m| m[i]);
            let pin_state = pin_state.clone();
            let started = started.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("arclight-w{i}-n{}", core.node))
                    .spawn(move || {
                        if let Some(cpu) = pin_cpu {
                            if crate::hw::affinity::pin_current_thread(cpu) {
                                pin_state[i].store(true, Ordering::Release);
                            }
                        }
                        crate::trace::bind_worker(trace_pool, i, core.node);
                        started.count_down(false);
                        while let Ok(msg) = rx.recv() {
                            // A panicking job must not kill the worker
                            // (the pool would deadlock every later
                            // dispatch): catch, poison the latch, keep
                            // serving. The leader re-raises.
                            match msg {
                                Msg::Run(job, latch) => {
                                    let r = catch_unwind(AssertUnwindSafe(|| job(&ctx)));
                                    latch.count_down(r.is_err());
                                }
                                Msg::RunShared(job, latch) => {
                                    let r = catch_unwind(AssertUnwindSafe(|| job(&ctx)));
                                    latch.count_down(r.is_err());
                                }
                                Msg::Shutdown => break,
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        started.wait();
        let pinned = pin_state.iter().map(|b| b.load(Ordering::Acquire)).collect();
        ThreadPool {
            senders,
            handles,
            cores,
            global_barrier: Arc::new(SpinBarrier::new(n)),
            jobs_dispatched: AtomicUsize::new(0),
            dispatches: AtomicUsize::new(0),
            pinned,
            trace_pool,
        }
    }

    /// Trace identity of this pool (the drain scope of
    /// [`crate::trace::finish_pass`]).
    pub fn trace_pool_id(&self) -> u64 {
        self.trace_pool
    }

    pub fn len(&self) -> usize {
        self.cores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cores.is_empty()
    }

    pub fn cores(&self) -> &[Core] {
        &self.cores
    }

    /// Per-worker host-pin outcome, in worker order.
    pub fn pinned(&self) -> &[bool] {
        &self.pinned
    }

    /// Workers successfully pinned to a host cpu (0 without a cpu map
    /// or on builds where pinning is unavailable).
    pub fn pinned_workers(&self) -> usize {
        self.pinned.iter().filter(|&&p| p).count()
    }

    /// Barrier spanning every worker of the pool (the paper's *global
    /// barrier*, Fig. 6). Valid only inside jobs dispatched to **all**
    /// workers.
    pub fn global_barrier(&self) -> Arc<SpinBarrier> {
        self.global_barrier.clone()
    }

    /// Total per-worker jobs dispatched (metrics).
    pub fn jobs_dispatched(&self) -> usize {
        self.jobs_dispatched.load(Ordering::Relaxed)
    }

    /// Dispatch *events* issued (one per `run_on`/`run_all`/`run_pass`
    /// call, regardless of worker count) — the counter the per-pass
    /// scheduler is measured by: one pass, one dispatch.
    pub fn dispatches(&self) -> usize {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Run `f` on the given workers and block until all finish.
    /// `f(ctx)` — rank/size bookkeeping is the caller's (the scheduler
    /// knows each worker's group assignment). Panics if any worker's
    /// job panicked (the latch surfaces the poisoned state instead of
    /// deadlocking the leader; the workers themselves survive).
    pub fn run_on<F>(&self, workers: &[usize], f: Arc<F>)
    where
        F: Fn(&WorkerCtx) + Send + Sync + 'static,
    {
        // count before blocking on the latch so a concurrent metrics
        // reader never observes a leader mid-wait on an uncounted job
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        self.jobs_dispatched.fetch_add(workers.len(), Ordering::Relaxed);
        let latch = Arc::new(Latch::new(workers.len()));
        for &w in workers {
            let f = f.clone();
            let job: Job = Box::new(move |ctx| f(ctx));
            self.senders[w]
                .send(Msg::Run(job, latch.clone()))
                .expect("worker alive");
        }
        if latch.wait() {
            panic!("worker panicked during a dispatched job (latch poisoned)");
        }
    }

    /// Run `f` on every worker.
    pub fn run_all<F>(&self, f: Arc<F>)
    where
        F: Fn(&WorkerCtx) + Send + Sync + 'static,
    {
        let all: Vec<usize> = (0..self.len()).collect();
        self.run_on(&all, f);
    }

    /// Persistent-worker pass entry point: hand every worker the
    /// **same** shared job — one `Arc` clone per worker, no per-op
    /// closure boxing — and block until all finish. One call is one
    /// pool dispatch; the job typically walks a compiled
    /// [`crate::sched::PassPlan`], doing its own global/group barrier
    /// synchronization between operators. Panics if any worker
    /// panicked mid-pass (poisoned latch), like [`ThreadPool::run_on`].
    /// Caveat: a job that synchronizes on barriers must keep its
    /// barrier discipline panic-safe itself, or peers stall at the
    /// barrier before the latch can surface anything —
    /// `PassPlan::run_worker` does (it defers a caught kernel panic,
    /// finishes the barrier walk, then re-raises).
    pub fn run_pass<F>(&self, f: Arc<F>)
    where
        F: Fn(&WorkerCtx) + Send + Sync + 'static,
    {
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        self.jobs_dispatched.fetch_add(self.len(), Ordering::Relaxed);
        let latch = Arc::new(Latch::new(self.len()));
        let shared: SharedJob = f;
        for tx in &self.senders {
            tx.send(Msg::RunShared(shared.clone(), latch.clone()))
                .expect("worker alive");
        }
        if latch.wait() {
            panic!("worker panicked during a dispatched pass (latch poisoned)");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    fn cores(n: usize) -> Vec<Core> {
        let t = Topology::uniform(2, n.div_ceil(2), 100.0, 25.0);
        (0..n).map(|i| t.core(i)).collect()
    }

    #[test]
    fn run_all_touches_every_worker() {
        let pool = ThreadPool::new(cores(6));
        let hits = Arc::new(Mutex::new(vec![0usize; 6]));
        let h2 = hits.clone();
        pool.run_all(Arc::new(move |ctx: &WorkerCtx| {
            h2.lock().unwrap()[ctx.worker] += 1;
        }));
        assert_eq!(*hits.lock().unwrap(), vec![1; 6]);
    }

    #[test]
    fn run_on_subset_only() {
        let pool = ThreadPool::new(cores(4));
        let hits = Arc::new(Mutex::new(vec![0usize; 4]));
        let h2 = hits.clone();
        pool.run_on(&[1, 3], Arc::new(move |ctx: &WorkerCtx| {
            h2.lock().unwrap()[ctx.worker] += 1;
        }));
        assert_eq!(*hits.lock().unwrap(), vec![0, 1, 0, 1]);
    }

    #[test]
    fn global_barrier_synchronizes_all() {
        let pool = ThreadPool::new(cores(4));
        let gb = pool.global_barrier();
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = counter.clone();
        pool.run_all(Arc::new(move |_ctx: &WorkerCtx| {
            c2.fetch_add(1, Ordering::SeqCst);
            gb.wait();
            // all four increments must be visible after the barrier
            assert_eq!(c2.load(Ordering::SeqCst), 4);
        }));
    }

    #[test]
    fn worker_core_binding_matches_order() {
        let cs = cores(4);
        let pool = ThreadPool::new(cs.clone());
        let seen = Arc::new(Mutex::new(vec![None; 4]));
        let s2 = seen.clone();
        pool.run_all(Arc::new(move |ctx: &WorkerCtx| {
            s2.lock().unwrap()[ctx.worker] = Some(ctx.core);
        }));
        let seen = seen.lock().unwrap();
        for (i, c) in cs.iter().enumerate() {
            assert_eq!(seen[i], Some(*c));
        }
    }

    #[test]
    fn sequential_jobs_do_not_deadlock() {
        let pool = ThreadPool::new(cores(3));
        for _ in 0..100 {
            pool.run_all(Arc::new(|_: &WorkerCtx| {}));
        }
        assert_eq!(pool.jobs_dispatched(), 300);
        assert_eq!(pool.dispatches(), 100);
    }

    #[test]
    fn run_pass_reaches_every_worker_in_one_dispatch() {
        let pool = ThreadPool::new(cores(5));
        let hits = Arc::new(Mutex::new(vec![0usize; 5]));
        let h2 = hits.clone();
        pool.run_pass(Arc::new(move |ctx: &WorkerCtx| {
            h2.lock().unwrap()[ctx.worker] += 1;
        }));
        assert_eq!(*hits.lock().unwrap(), vec![1; 5]);
        assert_eq!(pool.dispatches(), 1, "one pass == one dispatch");
        assert_eq!(pool.jobs_dispatched(), 5);
    }

    #[test]
    fn run_pass_supports_barrier_phases_inside_one_dispatch() {
        // the plan-walk shape: many barrier-separated phases under a
        // single dispatch, with cross-phase visibility guaranteed
        let pool = ThreadPool::new(cores(4));
        let gb = pool.global_barrier();
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = counter.clone();
        pool.run_pass(Arc::new(move |_ctx: &WorkerCtx| {
            for phase in 1..=16usize {
                c2.fetch_add(1, Ordering::SeqCst);
                gb.wait();
                assert_eq!(c2.load(Ordering::SeqCst), 4 * phase);
                gb.wait();
            }
        }));
        assert_eq!(counter.load(Ordering::SeqCst), 64);
        assert_eq!(pool.dispatches(), 1);
    }

    #[test]
    fn unpinned_pool_reports_zero_pinned_workers() {
        let pool = ThreadPool::new(cores(4));
        assert_eq!(pool.pinned_workers(), 0);
        assert_eq!(pool.pinned(), &[false; 4]);
    }

    #[test]
    fn pinning_degrades_gracefully_and_pool_still_serves() {
        // cpu map targeting cpus 0..n: on host builds the pins may or
        // may not succeed (restricted runners); on stub builds they
        // all fail. Either way the pool must be fully functional and
        // the count must be consistent with the per-worker outcomes.
        let cs = cores(3);
        let pool = ThreadPool::with_affinity(cs, Some(vec![0, 1, 2]));
        assert_eq!(pool.pinned().len(), 3);
        let n_pinned = pool.pinned().iter().filter(|&&p| p).count();
        assert_eq!(pool.pinned_workers(), n_pinned);
        if !crate::hw::affinity::available() {
            assert_eq!(n_pinned, 0, "stub builds must never report pinned workers");
        }
        let hits = Arc::new(Mutex::new(vec![0usize; 3]));
        let h2 = hits.clone();
        pool.run_pass(Arc::new(move |ctx: &WorkerCtx| {
            h2.lock().unwrap()[ctx.worker] += 1;
        }));
        assert_eq!(*hits.lock().unwrap(), vec![1; 3]);
    }

    #[test]
    #[should_panic(expected = "cpu map must cover every worker")]
    fn short_cpu_map_is_rejected() {
        let _ = ThreadPool::with_affinity(cores(4), Some(vec![0, 1]));
    }

    #[test]
    #[should_panic(expected = "latch poisoned")]
    fn panicking_job_surfaces_instead_of_deadlocking() {
        let pool = ThreadPool::new(cores(2));
        pool.run_on(&[0], Arc::new(|_: &WorkerCtx| panic!("kernel bug")));
    }

    #[test]
    fn panicking_pass_surfaces_and_pool_survives() {
        let pool = ThreadPool::new(cores(3));
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run_pass(Arc::new(|ctx: &WorkerCtx| {
                if ctx.worker == 1 {
                    panic!("bad pass");
                }
            }));
        }));
        assert!(r.is_err(), "leader must re-raise a mid-pass panic");
        let c = Arc::new(AtomicUsize::new(0));
        let c2 = c.clone();
        pool.run_pass(Arc::new(move |_: &WorkerCtx| {
            c2.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(c.load(Ordering::SeqCst), 3, "pool must keep serving passes");
    }

    #[test]
    fn workers_survive_a_panicked_job() {
        let pool = ThreadPool::new(cores(2));
        let poisoned = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_on(&[0, 1], Arc::new(|ctx: &WorkerCtx| {
                if ctx.worker == 0 {
                    panic!("one bad worker");
                }
            }));
        }));
        assert!(poisoned.is_err(), "leader must re-raise the worker panic");
        // the pool still serves jobs afterwards — no dead worker thread
        let hits = Arc::new(Mutex::new(vec![0usize; 2]));
        let h2 = hits.clone();
        pool.run_all(Arc::new(move |ctx: &WorkerCtx| {
            h2.lock().unwrap()[ctx.worker] += 1;
        }));
        assert_eq!(*hits.lock().unwrap(), vec![1, 1]);
    }
}
