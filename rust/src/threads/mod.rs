//! Thread manager (paper §2.4, Figs. 5–6).
//!
//! A fixed set of worker threads is created before inference begins and
//! organized through *logical views*: the pool can run as one group
//! (every worker executes a slice of the same operator — the llama.cpp
//! model) or be split into `n` groups that execute `n` independent
//! operator streams (tensor-parallel subgraphs). Reconfiguration is an
//! explicit, cheap operation (the paper's Scatter/Gather operators call
//! it at TP region boundaries).
//!
//! Synchronization (Fig. 6):
//! * **local barrier** — among the workers of one group, passed after
//!   every operator of that group's stream;
//! * **global barrier** — across the entire pool, passed at TP region
//!   boundaries (and after every operator in Sync-A mode, §3.4).
//!
//! The scheduler drives the pool through
//! [`pool::ThreadPool::run_pass`]: one shared job per pass whose
//! workers walk a compiled [`crate::sched::PassPlan`], firing the
//! barriers above themselves — per-operator job dispatch exists only
//! for ad-hoc work ([`pool::ThreadPool::run_on`]).

pub mod barrier;
pub mod group;
pub mod pool;

pub use barrier::SpinBarrier;
pub use group::{GroupView, Organization};
pub use pool::{ThreadPool, WorkerCtx};
