//! Sense-reversing spin barrier.
//!
//! `std::sync::Barrier` allocates a mutex + condvar and cannot be
//! re-pointed at a different thread count; inference frameworks use
//! spinning barriers because operator bodies are microseconds long and
//! the same threads re-synchronize thousands of times per token. The
//! sense-reversing design needs one atomic round trip per thread per
//! phase and is reusable immediately.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

pub struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    sense: AtomicBool,
    /// Trace scope: the TP group id this barrier belongs to, or
    /// `u32::MAX` for a pool-global (untagged) barrier. Only read when
    /// the tracer is enabled.
    tag: u32,
}

impl SpinBarrier {
    pub fn new(n: usize) -> Self {
        Self::with_tag(n, u32::MAX)
    }

    /// A barrier tagged with its trace scope (group id); group-local
    /// barriers are built with their group id so barrier-wait spans can
    /// be attributed to the right Sync-B group.
    pub fn with_tag(n: usize, tag: u32) -> Self {
        assert!(n > 0);
        SpinBarrier { n, count: AtomicUsize::new(0), sense: AtomicBool::new(false), tag }
    }

    pub fn parties(&self) -> usize {
        self.n
    }

    /// Block until all `n` parties arrive. Returns `true` for exactly one
    /// caller per phase (the "serial" thread, llama.cpp's convention for
    /// post-op bookkeeping). When tracing is enabled ([`crate::trace`])
    /// the wait is recorded as a barrier span attributed to this
    /// barrier's scope tag; the disabled path costs one relaxed load.
    pub fn wait(&self) -> bool {
        if crate::trace::enabled() {
            let t0 = crate::trace::now_ns();
            let serial = self.wait_core();
            crate::trace::record_barrier(self.tag, t0);
            serial
        } else {
            self.wait_core()
        }
    }

    fn wait_core(&self) -> bool {
        let my_sense = !self.sense.load(Ordering::Relaxed);
        let arrived = self.count.fetch_add(1, Ordering::AcqRel) + 1;
        if arrived == self.n {
            self.count.store(0, Ordering::Relaxed);
            self.sense.store(my_sense, Ordering::Release);
            true
        } else {
            // Spin with yield: worker counts can exceed host cores (the
            // simulated machine is bigger than the real one), so a pure
            // spin would livelock a 1-core host.
            let mut spins = 0u32;
            while self.sense.load(Ordering::Acquire) != my_sense {
                spins += 1;
                if spins > 64 {
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
            }
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn single_party_is_trivially_serial() {
        let b = SpinBarrier::new(1);
        assert!(b.wait());
        assert!(b.wait());
    }

    #[test]
    fn all_threads_pass_and_one_is_serial() {
        let n = 8;
        let b = Arc::new(SpinBarrier::new(n));
        let serial = Arc::new(AtomicUsize::new(0));
        let passed = Arc::new(AtomicUsize::new(0));
        let mut hs = Vec::new();
        for _ in 0..n {
            let (b, serial, passed) = (b.clone(), serial.clone(), passed.clone());
            hs.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    if b.wait() {
                        serial.fetch_add(1, Ordering::Relaxed);
                    }
                    passed.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(serial.load(Ordering::Relaxed), 50);
        assert_eq!(passed.load(Ordering::Relaxed), 50 * n);
    }

    #[test]
    fn barrier_orders_phases() {
        // No thread may enter phase k+1 before all finished phase k.
        let n = 4;
        let b = Arc::new(SpinBarrier::new(n));
        let phase_counts: Arc<Vec<AtomicUsize>> =
            Arc::new((0..20).map(|_| AtomicUsize::new(0)).collect());
        let mut hs = Vec::new();
        for _ in 0..n {
            let (b, pc) = (b.clone(), phase_counts.clone());
            hs.push(std::thread::spawn(move || {
                for phase in 0..20 {
                    pc[phase].fetch_add(1, Ordering::SeqCst);
                    b.wait();
                    // after the barrier, everyone must have bumped this phase
                    assert_eq!(pc[phase].load(Ordering::SeqCst), n);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }
}
