//! Multi-view thread organization (paper §2.4, Fig. 5).
//!
//! An [`Organization`] is a partition of the pool's workers into logical
//! groups, each with its own local barrier. The pool itself is never
//! reconfigured — views are cheap value objects built at initialization
//! or at Scatter/Gather boundaries ("explicit interfaces and operators
//! are provided to dynamically reconfigure the internal thread
//! organization").

use std::sync::Arc;

use super::SpinBarrier;
use crate::numa::{Core, NodeId};

/// One logical thread group: a set of pool worker indices plus the local
/// barrier they synchronize on after each operator of their stream.
#[derive(Clone)]
pub struct GroupView {
    pub id: usize,
    /// Pool worker indices, in rank order (`rank = position`).
    pub workers: Vec<usize>,
    /// The NUMA node this group is anchored to (TP groups are node-local
    /// by construction; a whole-pool group reports node of worker 0).
    pub node: NodeId,
    barrier: Arc<SpinBarrier>,
}

impl GroupView {
    pub fn new(id: usize, workers: Vec<usize>, node: NodeId) -> Self {
        assert!(!workers.is_empty());
        let barrier = Arc::new(SpinBarrier::with_tag(workers.len(), id as u32));
        GroupView { id, workers, node, barrier }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Rank of a pool worker inside this group, if it belongs.
    pub fn rank_of(&self, worker: usize) -> Option<usize> {
        self.workers.iter().position(|&w| w == worker)
    }

    /// The group-local barrier (paper Fig. 6 "local barrier").
    pub fn barrier(&self) -> &Arc<SpinBarrier> {
        &self.barrier
    }
}

/// A complete view over the pool: disjoint groups covering a subset (or
/// all) of the workers.
#[derive(Clone)]
pub struct Organization {
    pub groups: Vec<GroupView>,
    /// Reverse map: worker → (group index, rank) — `None` for workers
    /// idle under this view.
    assignment: Vec<Option<(usize, usize)>>,
}

impl Organization {
    pub fn from_groups(groups: Vec<GroupView>, pool_size: usize) -> Self {
        let mut assignment = vec![None; pool_size];
        for (gi, g) in groups.iter().enumerate() {
            for (rank, &w) in g.workers.iter().enumerate() {
                assert!(assignment[w].is_none(), "worker {w} in two groups");
                assignment[w] = Some((gi, rank));
            }
        }
        Organization { groups, assignment }
    }

    /// The single-group view: the whole pool executes one operator
    /// stream (non-TP mode, llama.cpp's only mode).
    pub fn single(cores: &[Core]) -> Self {
        let workers: Vec<usize> = (0..cores.len()).collect();
        let node = cores.first().map(|c| c.node).unwrap_or(0);
        Organization::from_groups(vec![GroupView::new(0, workers, node)], cores.len())
    }

    /// One group per NUMA node (the Scatter operator's reconfiguration
    /// for cross-NUMA TP, §3.3): workers are grouped by the node of
    /// their bound core.
    pub fn by_node(cores: &[Core]) -> Self {
        let mut nodes: Vec<NodeId> = cores.iter().map(|c| c.node).collect();
        nodes.sort_unstable();
        nodes.dedup();
        let groups = nodes
            .iter()
            .enumerate()
            .map(|(gi, &node)| {
                let ws: Vec<usize> = cores
                    .iter()
                    .enumerate()
                    .filter(|(_, c)| c.node == node)
                    .map(|(i, _)| i)
                    .collect();
                GroupView::new(gi, ws, node)
            })
            .collect();
        Organization::from_groups(groups, cores.len())
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Which (group, rank) a pool worker holds under this view.
    pub fn assignment(&self, worker: usize) -> Option<(usize, usize)> {
        self.assignment.get(worker).copied().flatten()
    }

    /// Number of distinct NUMA nodes spanned by all groups (barrier cost
    /// input).
    pub fn nodes_spanned(&self, cores: &[Core]) -> usize {
        let mut nodes: Vec<NodeId> = self
            .groups
            .iter()
            .flat_map(|g| g.workers.iter().map(|&w| cores[w].node))
            .collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    fn cores_2x4() -> Vec<Core> {
        let t = Topology::uniform(2, 4, 100.0, 25.0);
        (0..8).map(|i| t.core(i)).collect()
    }

    #[test]
    fn single_view_one_group() {
        let cs = cores_2x4();
        let org = Organization::single(&cs);
        assert_eq!(org.n_groups(), 1);
        assert_eq!(org.groups[0].size(), 8);
        assert_eq!(org.assignment(5), Some((0, 5)));
    }

    #[test]
    fn by_node_groups_are_node_local() {
        let cs = cores_2x4();
        let org = Organization::by_node(&cs);
        assert_eq!(org.n_groups(), 2);
        for g in &org.groups {
            for &w in &g.workers {
                assert_eq!(cs[w].node, g.node);
            }
        }
        assert_eq!(org.nodes_spanned(&cs), 2);
    }

    #[test]
    fn ranks_are_positions() {
        let g = GroupView::new(0, vec![4, 6, 7], 1);
        assert_eq!(g.rank_of(6), Some(1));
        assert_eq!(g.rank_of(5), None);
    }

    #[test]
    #[should_panic(expected = "two groups")]
    fn overlapping_groups_rejected() {
        let a = GroupView::new(0, vec![0, 1], 0);
        let b = GroupView::new(1, vec![1, 2], 0);
        Organization::from_groups(vec![a, b], 4);
    }

    #[test]
    fn local_barrier_sized_to_group() {
        let org = Organization::by_node(&cores_2x4());
        assert_eq!(org.groups[0].barrier().parties(), 4);
    }
}
