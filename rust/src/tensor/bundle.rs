//! `TensorBundle` — the paper's `tensor_ptrs` (appendix A.1).
//!
//! ArcLight extends the tensor-pointer type used by module interfaces to
//! a *bundle* of pointers so the same `linear(...)`/`attention(...)`
//! builder functions construct either a single graph (bundle of one) or
//! N parallel subgraphs (bundle of N) without a TP-specific rewrite.
//! Scatter turns a 1-bundle into an N-bundle; Gather folds an N-bundle
//! back to 1.

use super::TensorId;

/// A set of tensor ids, one per parallel subgraph (N == 1 outside TP
/// regions). Supports "mutual assignment with a single tensor pointer"
/// (paper A.1): `From<TensorId>` and `single()` convert back and forth.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorBundle {
    ids: Vec<TensorId>,
}

impl TensorBundle {
    pub fn new(ids: Vec<TensorId>) -> Self {
        assert!(!ids.is_empty(), "empty bundle");
        TensorBundle { ids }
    }

    /// Bundle of one — the non-TP case.
    pub fn one(id: TensorId) -> Self {
        TensorBundle { ids: vec![id] }
    }

    /// Number of parallel subgraphs this bundle spans.
    pub fn width(&self) -> usize {
        self.ids.len()
    }

    pub fn is_single(&self) -> bool {
        self.ids.len() == 1
    }

    /// The single tensor id; panics when called on a TP bundle —
    /// mirrors the paper's implicit-conversion contract.
    pub fn single(&self) -> TensorId {
        assert!(self.is_single(), "bundle of {} used as single tensor", self.ids.len());
        self.ids[0]
    }

    pub fn get(&self, part: usize) -> TensorId {
        self.ids[part]
    }

    pub fn ids(&self) -> &[TensorId] {
        &self.ids
    }

    pub fn iter(&self) -> impl Iterator<Item = TensorId> + '_ {
        self.ids.iter().copied()
    }

    /// Pair up two bundles of the same width (Parallel construction mode).
    pub fn zip<'a>(
        &'a self,
        other: &'a TensorBundle,
    ) -> impl Iterator<Item = (TensorId, TensorId)> + 'a {
        assert_eq!(self.width(), other.width(), "bundle width mismatch");
        self.ids.iter().copied().zip(other.ids.iter().copied())
    }
}

impl From<TensorId> for TensorBundle {
    fn from(id: TensorId) -> Self {
        TensorBundle::one(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_conversion() {
        let b: TensorBundle = TensorId(3).into();
        assert!(b.is_single());
        assert_eq!(b.single(), TensorId(3));
    }

    #[test]
    #[should_panic(expected = "used as single")]
    fn wide_bundle_is_not_single() {
        TensorBundle::new(vec![TensorId(0), TensorId(1)]).single();
    }

    #[test]
    fn zip_pairs() {
        let a = TensorBundle::new(vec![TensorId(0), TensorId(1)]);
        let b = TensorBundle::new(vec![TensorId(2), TensorId(3)]);
        let pairs: Vec<_> = a.zip(&b).collect();
        assert_eq!(pairs, vec![(TensorId(0), TensorId(2)), (TensorId(1), TensorId(3))]);
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn zip_requires_same_width() {
        let a = TensorBundle::one(TensorId(0));
        let b = TensorBundle::new(vec![TensorId(1), TensorId(2)]);
        let _ = a.zip(&b).count();
    }
}
