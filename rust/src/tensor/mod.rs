//! Tensor library (paper §2.2).
//!
//! An ArcLight tensor is split into a *header* (name, shape, dtype,
//! producing operation, source links — everything the graph builder and
//! scheduler need) and a *data area* (a contiguous range inside one of
//! the memory manager's NUMA-local arenas). This module owns the header
//! side: [`DType`], shapes, [`TensorId`] handles and the
//! [`TensorBundle`] (`tensor_ptrs` in the paper's appendix A.1) that
//! lets one module interface serve both single-graph and
//! tensor-parallel construction.

pub mod bundle;
pub mod dtype;

pub use bundle::TensorBundle;
pub use dtype::DType;

/// Index of a tensor header inside a [`crate::graph::Graph`]'s tensor
/// table. ArcLight's C++ uses raw `tensor*`; an index is the idiomatic
/// Rust equivalent (stable across reallocation, trivially Copy).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u32);

impl TensorId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Number of elements of a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Rows = product of all leading dims; the last dim is the contiguous
/// axis every operator iterates over.
pub fn rows(shape: &[usize]) -> usize {
    if shape.is_empty() {
        1
    } else {
        shape[..shape.len() - 1].iter().product()
    }
}

/// Last (contiguous) dimension, 1 for scalars.
pub fn row_len(shape: &[usize]) -> usize {
    shape.last().copied().unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_helpers() {
        assert_eq!(numel(&[2, 3, 4]), 24);
        assert_eq!(rows(&[2, 3, 4]), 6);
        assert_eq!(row_len(&[2, 3, 4]), 4);
        assert_eq!(numel(&[]), 1);
        assert_eq!(rows(&[]), 1);
        assert_eq!(row_len(&[]), 1);
        assert_eq!(rows(&[5]), 1);
    }
}
