//! Element types supported by the engine.

use std::fmt;

/// Q4_0 block geometry (ggml-compatible): 32 elements / 18 bytes.
pub const QK4_0: usize = 32;
pub const Q4_0_BLOCK_BYTES: usize = 18;

/// Q8_0 block geometry: 32 elements / 34 bytes (f16 scale + 32 i8).
pub const QK8_0: usize = 32;
pub const Q8_0_BLOCK_BYTES: usize = 34;

/// Tensor element type. Quantized types are only legal as the *weight*
/// side of matmuls; activations are always `F32`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    /// ggml Q4_0: blocks of 32 along the last (contraction) axis,
    /// 18 bytes per block (f16 scale + 16 nibble bytes).
    Q4_0,
    /// ggml Q8_0: blocks of 32, 34 bytes per block (f16 scale + 32×i8).
    Q8_0,
}

impl DType {
    /// Bytes needed to store `k` contiguous elements of this type.
    /// For quantized types `k` must be a multiple of the block size.
    pub fn row_bytes(self, k: usize) -> usize {
        match self {
            DType::F32 | DType::I32 => k * 4,
            DType::Q4_0 => {
                debug_assert!(k % QK4_0 == 0, "Q4_0 row length {k} not a multiple of 32");
                k / QK4_0 * Q4_0_BLOCK_BYTES
            }
            DType::Q8_0 => {
                debug_assert!(k % QK8_0 == 0, "Q8_0 row length {k} not a multiple of 32");
                k / QK8_0 * Q8_0_BLOCK_BYTES
            }
        }
    }

    /// Total bytes for a tensor of `shape` stored row-contiguously.
    pub fn tensor_bytes(self, shape: &[usize]) -> usize {
        super::rows(shape) * self.row_bytes(super::row_len(shape))
    }

    /// Effective bytes per element (fractional for quantized types) —
    /// the quantity the bandwidth cost model charges per element read.
    pub fn bytes_per_element(self) -> f64 {
        match self {
            DType::F32 | DType::I32 => 4.0,
            DType::Q4_0 => Q4_0_BLOCK_BYTES as f64 / QK4_0 as f64, // 0.5625
            DType::Q8_0 => Q8_0_BLOCK_BYTES as f64 / QK8_0 as f64, // 1.0625
        }
    }

    pub fn is_quantized(self) -> bool {
        matches!(self, DType::Q4_0 | DType::Q8_0)
    }

    /// Parse the manifest/ALF dtype string.
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            "q4_0" => Some(DType::Q4_0),
            "q8_0" => Some(DType::Q8_0),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::Q4_0 => "q4_0",
            DType::Q8_0 => "q8_0",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_bytes() {
        assert_eq!(DType::F32.row_bytes(10), 40);
        assert_eq!(DType::Q4_0.row_bytes(32), 18);
        assert_eq!(DType::Q4_0.row_bytes(64), 36);
        assert_eq!(DType::Q8_0.row_bytes(32), 34);
    }

    #[test]
    fn tensor_bytes() {
        assert_eq!(DType::F32.tensor_bytes(&[2, 3]), 24);
        assert_eq!(DType::Q4_0.tensor_bytes(&[4, 64]), 4 * 36);
        assert_eq!(DType::F32.tensor_bytes(&[]), 4); // scalar
    }

    #[test]
    fn bytes_per_element_matches_q4_paper_math() {
        // Qwen3-4B ≈ 4e9 params → ~2.26 GB in Q4_0; sanity check the ratio
        assert!((DType::Q4_0.bytes_per_element() - 0.5625).abs() < 1e-12);
    }

    #[test]
    fn parse_roundtrip() {
        for d in [DType::F32, DType::I32, DType::Q4_0, DType::Q8_0] {
            assert_eq!(DType::parse(&d.to_string()), Some(d));
        }
        assert_eq!(DType::parse("f64"), None);
    }
}
