//! Byte-level tokenizer.
//!
//! The paper benchmarks with Qwen3's BPE vocabulary, but tokenization is
//! orthogonal to every system under study (throughput is tokens/s for
//! *any* token stream). A byte-level scheme keeps the repo dependency-
//! free while remaining a real, lossless tokenizer: token `b` is byte
//! `b`, with BOS/EOS appended at 256/257.

pub const BOS: i32 = 256;
pub const EOS: i32 = 257;

/// Lossless byte tokenizer (vocab must be ≥ 258).
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn vocab_floor() -> usize {
        258
    }

    pub fn encode(&self, text: &str, add_bos: bool) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        if add_bos {
            out.push(BOS);
        }
        out.extend(text.bytes().map(|b| b as i32));
        out
    }

    /// Decode ignores special tokens and re-assembles UTF-8 losslessly
    /// (invalid sequences become U+FFFD).
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let toks = t.encode("hello", true);
        assert_eq!(toks[0], BOS);
        assert_eq!(toks.len(), 6);
        assert_eq!(t.decode(&toks), "hello");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer;
        let s = "héllo ∞ 中文";
        assert_eq!(t.decode(&t.encode(s, false)), s);
    }

    #[test]
    fn specials_skipped_in_decode() {
        let t = ByteTokenizer;
        assert_eq!(t.decode(&[BOS, 104, 105, EOS]), "hi");
    }
}
