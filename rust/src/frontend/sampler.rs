//! Token sampling: greedy and top-k/temperature (the paper benches with
//! `--top-k 1`, i.e. greedy — deterministic throughput runs).

use crate::util::Rng;

#[derive(Clone, Debug)]
pub enum Sampler {
    /// Argmax (the paper's benchmark setting).
    Greedy,
    /// Top-k with temperature; deterministic given the seed.
    TopK { k: usize, temperature: f32, rng_seed: u64 },
}

impl Sampler {
    pub fn greedy() -> Self {
        Sampler::Greedy
    }

    pub fn top_k(k: usize, temperature: f32, seed: u64) -> Self {
        assert!(k >= 1 && temperature > 0.0);
        Sampler::TopK { k, temperature, rng_seed: seed }
    }

    /// Pick the next token. `step` keeps Top-K deterministic per
    /// position without carrying mutable state.
    pub fn sample(&self, logits: &[f32], step: usize) -> i32 {
        match self {
            Sampler::Greedy => argmax(logits) as i32,
            Sampler::TopK { k, temperature, rng_seed } => {
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                let kk = (*k).min(logits.len());
                idx.select_nth_unstable_by(kk - 1, |&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
                });
                idx.truncate(kk);
                // softmax over the top-k at the given temperature
                let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f32> =
                    idx.iter().map(|&i| ((logits[i] - m) / temperature).exp()).collect();
                let total: f32 = weights.iter().sum();
                let mut rng = Rng::new(rng_seed.wrapping_add(step as u64));
                let mut r = rng.next_f32() * total;
                for (i, w) in idx.iter().zip(&weights) {
                    if r <= *w {
                        return *i as i32;
                    }
                    r -= w;
                }
                idx[0] as i32
            }
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let s = Sampler::greedy();
        assert_eq!(s.sample(&[0.1, 3.0, -1.0, 2.9], 0), 1);
    }

    #[test]
    fn top1_equals_greedy() {
        let s = Sampler::top_k(1, 1.0, 42);
        let logits = [0.5, 2.0, 1.0, -3.0];
        assert_eq!(s.sample(&logits, 0), 1);
        assert_eq!(s.sample(&logits, 9), 1);
    }

    #[test]
    fn topk_is_deterministic_per_step() {
        let s = Sampler::top_k(3, 0.8, 7);
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        assert_eq!(s.sample(&logits, 5), s.sample(&logits, 5));
    }

    #[test]
    fn topk_only_returns_topk_tokens() {
        let s = Sampler::top_k(2, 1.0, 1);
        let logits = [10.0, -50.0, 9.5, -50.0];
        for step in 0..50 {
            let t = s.sample(&logits, step);
            assert!(t == 0 || t == 2, "sampled {t}");
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let s = Sampler::top_k(4, 0.05, 3);
        let logits = [1.0, 2.0, 3.0, 4.0];
        let hits = (0..100).filter(|&st| s.sample(&logits, st) == 3).count();
        assert!(hits > 95, "{hits}");
    }
}
