//! Decoding frontend (paper §2.1): weight loading, the autoregressive
//! decode loop, sampling, and a byte-level tokenizer. The frontend sits
//! on the engine's streamlined API (graphs + executor) and never touches
//! operator internals.

pub mod engine;
pub mod sampler;
pub mod tokenizer;

pub use engine::{Engine, EngineOptions, GenerationResult, PrefixProbe, SeqHandle};
pub use sampler::Sampler;
pub use tokenizer::ByteTokenizer;
