//! The inference engine: graphs + thread pool + executor + decode loop.
//!
//! `Engine` is the real-execution object behind the CLI, the examples
//! and the serving layer. It owns the worker pool (created once, before
//! inference — §2.4), the model graphs and the weight storage, and
//! exposes two frontend APIs:
//!
//! * the classic single-sequence loop (`prefill`, `decode_step`,
//!   `generate`), and
//! * the multi-sequence API behind continuous batching (`seq_start` /
//!   `step_batch`): sequences are admitted against a **paged** KV
//!   arena (admission reserves every page the sequence may ever need,
//!   so decode can never hit out-of-memory mid-flight) and hold an
//!   RAII [`SeqHandle`] that returns their pages on drop. Identical
//!   prompt prefixes across sequences share physical pages through a
//!   rolling-hash index, copied on first divergent append (CoW).
//!   Per-lane arithmetic is identical to the single-sequence path, so
//!   interleaved decode is token-for-token equal to serial decode.
//!
//! The single-sequence loop writes physical cache positions directly
//! (its KV span is the whole arena) and must not be interleaved with
//! live paged sequences without an [`Engine::reset`] in between.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::baseline::Strategy;
use crate::graph::{PageArena, PageTable};
use crate::hw::Platform;
use crate::numa::BandwidthSource;
use crate::memory::MemoryPool;
use crate::model::synth;
use crate::model::{AlfFile, ModelConfig, ModelGraphs};
use crate::sched::{BatchView, ExecParams, Executor, StepReport};

use super::sampler::Sampler;

/// Construction options.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub strategy: Strategy,
    pub threads: usize,
    /// Machine source: the simulated cost-model testbed (default) or a
    /// host detected via [`Platform::detect`].
    pub platform: Platform,
    /// Build a one-pass prefill graph for prompts of exactly this
    /// length (other lengths fall back to token-by-token prefill).
    pub prefill_rows: Option<usize>,
    /// Synthetic weight seed when no ALF file is given.
    pub seed: u64,
    /// Concurrent decode lanes; > 1 builds the batched decode graph
    /// and enables the multi-sequence API (continuous batching).
    pub batch_slots: usize,
    /// Pin each pool worker to the OS cpu backing its assigned core
    /// (host platform only; best effort — see `hw::affinity`).
    pub pin: bool,
    /// Tokens per KV page.
    pub page_size: usize,
    /// KV arena size in pages; `None` sizes it for `batch_slots`
    /// full-length sequences.
    pub kv_pages: Option<usize>,
    /// First NUMA node of this engine's placement window: cores are
    /// bound and node-addressed tensors placed starting here instead of
    /// node 0. Cluster replicas use it to claim disjoint node groups on
    /// one machine; 0 (the default) is the classic whole-machine engine.
    pub base_node: usize,
}

impl EngineOptions {
    pub fn quick(strategy: Strategy, threads: usize) -> Self {
        EngineOptions { strategy, threads, ..Default::default() }
    }
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            strategy: Strategy::arclight_single(),
            threads: 1,
            platform: Platform::simulated(),
            prefill_rows: None,
            seed: 0,
            batch_slots: 1,
            pin: false,
            page_size: 16,
            kv_pages: None,
            base_node: 0,
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// One step of the rolling FNV-1a prefix hash. Keyed over the full
/// token history, so a hash identifies one exact prompt prefix.
fn fnv_step(h: u64, tok: i32) -> u64 {
    (h ^ (tok as u32 as u64)).wrapping_mul(FNV_PRIME)
}

/// Rolling hash after every *completed* page of `tokens`.
fn page_hashes(tokens: &[i32], page_size: usize) -> Vec<u64> {
    let mut h = FNV_OFFSET;
    let mut out = Vec::new();
    for (i, &t) in tokens.iter().enumerate() {
        h = fnv_step(h, t);
        if (i + 1) % page_size == 0 {
            out.push(h);
        }
    }
    out
}

/// Per-sequence pager state.
#[derive(Debug)]
struct SeqState {
    table: PageTable,
    /// Tokens ingested so far (logical length).
    len: usize,
    /// Rolling FNV-1a hash of the full token history.
    hash: u64,
    /// Pages still promised by the admission reservation.
    reserved: usize,
    /// Admission budget: the sequence may ingest at most this many
    /// tokens (the reservation covers exactly this span).
    budget: usize,
    /// Prompt tokens served from shared prefix pages at admission.
    prefix_hit: usize,
    alive: bool,
}

/// Paged-KV bookkeeping shared between the engine and every live
/// [`SeqHandle`] (which releases its pages through it on drop).
#[derive(Debug)]
pub struct KvPager {
    arena: PageArena,
    seqs: Vec<SeqState>,
    free_ids: Vec<usize>,
    /// Bumped by [`Engine::reset`]; handles from an older generation
    /// no-op on drop instead of corrupting fresh refcounts.
    generation: u64,
}

impl KvPager {
    fn new(pages: usize, page_size: usize) -> KvPager {
        KvPager {
            arena: PageArena::new(pages, page_size),
            seqs: Vec::new(),
            free_ids: Vec::new(),
            generation: 0,
        }
    }

    fn reset(&mut self) {
        let (pages, ps) = (self.arena.total_pages(), self.arena.page_size());
        self.arena = PageArena::new(pages, ps);
        self.seqs.clear();
        self.free_ids.clear();
        self.generation += 1;
    }

    fn new_seq(&mut self, st: SeqState) -> usize {
        match self.free_ids.pop() {
            Some(id) => {
                self.seqs[id] = st;
                id
            }
            None => {
                self.seqs.push(st);
                self.seqs.len() - 1
            }
        }
    }

    fn retire(&mut self, id: usize) {
        let st = &mut self.seqs[id];
        if !st.alive {
            return;
        }
        st.alive = false;
        let table = std::mem::take(&mut st.table);
        let reserved = std::mem::replace(&mut st.reserved, 0);
        for p in table {
            self.arena.release(p);
        }
        self.arena.unreserve(reserved);
        self.free_ids.push(id);
    }

    fn live(&self) -> usize {
        self.seqs.iter().filter(|s| s.alive).count()
    }

    fn state(&self, h: &SeqHandle) -> &SeqState {
        assert_eq!(h.generation, self.generation, "sequence handle from a reset engine");
        let st = &self.seqs[h.id];
        assert!(st.alive, "sequence {} already retired", h.id);
        st
    }
}

/// RAII handle to a live sequence. Dropping it returns the sequence's
/// pages and the unclaimed remainder of its admission reservation to
/// the arena, so no error or retire path can leak KV memory.
#[derive(Debug)]
pub struct SeqHandle {
    pager: Arc<Mutex<KvPager>>,
    id: usize,
    generation: u64,
}

impl SeqHandle {
    /// Pager-internal sequence id (diagnostics only — ids recycle).
    pub fn id(&self) -> usize {
        self.id
    }
}

impl Drop for SeqHandle {
    fn drop(&mut self) {
        let mut pg = self.pager.lock().unwrap();
        if pg.generation == self.generation {
            pg.retire(self.id);
        }
    }
}

/// Read-only, thread-safe view of an engine's prefix-page index —
/// the cluster router's KV-affinity signal. Cloning is cheap (it
/// shares the pager behind the engine's own `Arc<Mutex>`), and probing
/// never mutates the index: unlike admission, a probe must not bump
/// FIFO recency or take pages.
#[derive(Clone)]
pub struct PrefixProbe {
    pager: Arc<Mutex<KvPager>>,
    page_size: usize,
}

impl PrefixProbe {
    /// Prompt tokens of `tokens` this engine could serve from shared
    /// prefix pages right now — the longest *leading* run of completed
    /// pages present in the index, capped (like admission) strictly
    /// below the whole prompt so the last token is always recomputed.
    pub fn prefix_run_tokens(&self, tokens: &[i32]) -> usize {
        if tokens.is_empty() {
            return 0;
        }
        let ps = self.page_size;
        let hashes = page_hashes(tokens, ps);
        let max_adopt = (tokens.len() - 1) / ps;
        let pg = self.pager.lock().unwrap();
        let mut run = 0usize;
        for h in &hashes[..max_adopt.min(hashes.len())] {
            if pg.arena.lookup(*h).is_none() {
                break;
            }
            run += 1;
        }
        run * ps
    }
}

/// Timing + output of one generation call.
#[derive(Clone, Debug)]
pub struct GenerationResult {
    pub tokens: Vec<i32>,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
}

impl GenerationResult {
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.decode_seconds == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.decode_seconds
        }
    }

    pub fn prefill_tok_per_s(&self) -> f64 {
        if self.prefill_seconds == 0.0 {
            0.0
        } else {
            self.prefill_tokens as f64 / self.prefill_seconds
        }
    }
}

/// The real-execution engine.
pub struct Engine {
    pub graphs: ModelGraphs,
    /// Shared weight/KV/activation storage the graphs were planned on.
    pool: Arc<MemoryPool>,
    /// The backend every pass goes through — held as a trait object so
    /// the decode loop is backend-agnostic (`sched::Executor`).
    executor: Box<dyn Executor + Send + Sync>,
    /// Cursor of the classic single-sequence API (physical span 0..).
    pos: usize,
    /// Paged-KV bookkeeping shared with every live [`SeqHandle`].
    pager: Arc<Mutex<KvPager>>,
    /// Report of the most recent graph pass (dispatch accounting,
    /// unit counts) — the observability hook the serving metrics and
    /// the one-dispatch-per-pass assertions read.
    last_report: Option<StepReport>,
    /// Platform the engine was built on (`"simulated"` / `"host"`).
    platform_name: &'static str,
    /// Workers the pool successfully pinned to host cpus.
    pinned_workers: usize,
    /// Name of the strategy the engine was built with — stamped onto
    /// every [`StepReport`] (executors don't know their strategy).
    strategy_name: String,
    /// Provenance of the bandwidth matrix behind the engine's topology.
    bw_source: BandwidthSource,
    /// Auto-tuner prediction (µs/step) when `--strategy auto` chose
    /// the strategy; `None` for explicit strategies.
    predicted_step_us: Option<f64>,
    /// EWMA of measured decode-step time (µs) — the drift-detection
    /// input compared against `predicted_step_us`. Prefill passes are
    /// excluded: the prediction is a decode-step quantity.
    step_ewma_us: Option<f64>,
    /// Decode passes folded into the EWMA (a cold EWMA never
    /// recommends a re-tune).
    step_samples: usize,
}

impl Engine {
    /// Build with synthetic weights.
    pub fn new_synthetic(cfg: ModelConfig, opts: &EngineOptions) -> Result<Engine> {
        let mut e = Self::build(cfg, opts)?;
        synth::fill_synthetic(&e.graphs, opts.seed)?;
        e.reset();
        Ok(e)
    }

    /// Build from an ALF weight file (geometry read from the file).
    pub fn from_alf(path: &std::path::Path, opts: &EngineOptions) -> Result<Engine> {
        let alf = AlfFile::open(path)?;
        let cfg = ModelConfig::from_json(&alf.config)
            .map_err(|e| anyhow::anyhow!("bad ALF config: {e}"))?;
        let mut e = Self::build(cfg, opts)?;
        synth::load_alf(&e.graphs, &alf)?;
        e.reset();
        Ok(e)
    }

    fn build(cfg: ModelConfig, opts: &EngineOptions) -> Result<Engine> {
        if opts.threads == 0 {
            bail!("at least one thread required");
        }
        if opts.threads < opts.strategy.nodes_used() {
            bail!(
                "strategy {} spans {} NUMA nodes but only {} thread(s) were given",
                opts.strategy.name(),
                opts.strategy.nodes_used(),
                opts.threads
            );
        }
        if opts.batch_slots == 0 {
            bail!("batch_slots must be at least 1");
        }
        let total_nodes = opts.platform.topology().n_nodes();
        if opts.base_node + opts.strategy.nodes_used() > total_nodes {
            bail!(
                "strategy {} spans nodes {}..{} but the machine has only {} node(s)",
                opts.strategy.name(),
                opts.base_node,
                opts.base_node + opts.strategy.nodes_used(),
                total_nodes
            );
        }
        let mut spec = opts
            .strategy
            .build_spec(cfg, total_nodes)
            .with_batch(opts.batch_slots)
            .with_page_size(opts.page_size)
            .with_base_node(opts.base_node);
        if let Some(pages) = opts.kv_pages {
            spec = spec.with_kv_pages(pages);
        }
        if let Some(rows) = opts.prefill_rows {
            spec = spec.with_prefill(rows);
        }
        let graphs = ModelGraphs::build(spec);
        let pool = graphs.pool.clone().expect("real engine needs buffers");
        let executor = opts.strategy.real_executor_on(
            pool.clone(),
            &opts.platform,
            opts.threads,
            opts.pin,
            opts.base_node,
        );
        let pinned_workers = executor.threads.pinned_workers();
        let pager = Arc::new(Mutex::new(KvPager::new(graphs.kv_pages, graphs.kv_page_size)));
        Ok(Engine {
            graphs,
            pool,
            executor: Box::new(executor),
            pos: 0,
            pager,
            last_report: None,
            platform_name: opts.platform.name(),
            pinned_workers,
            strategy_name: opts.strategy.name(),
            bw_source: opts.platform.topology().bw_source,
            predicted_step_us: None,
            step_ewma_us: None,
            step_samples: 0,
        })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.graphs.cfg
    }

    /// The [`StepReport`] of the most recent pass (`None` before the
    /// first). Every pass through any backend updates it; the batcher
    /// reads `dispatches` off it for the serve metrics.
    pub fn last_step_report(&self) -> Option<&StepReport> {
        self.last_report.as_ref()
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    /// The platform the engine was built on (`"simulated"`/`"host"`) —
    /// recorded into serving metrics and bench JSON.
    pub fn platform(&self) -> &'static str {
        self.platform_name
    }

    /// Pool workers successfully pinned to host cpus (0 on the
    /// simulated platform or when pinning was off/failed).
    pub fn pinned_workers(&self) -> usize {
        self.pinned_workers
    }

    /// Name of the strategy every pass runs under (e.g.
    /// `"arclight-tp4-syncB"`) — what `--strategy auto` resolved to,
    /// or the explicit CLI choice.
    pub fn strategy_name(&self) -> &str {
        &self.strategy_name
    }

    /// Provenance of the bandwidth matrix behind the engine's topology
    /// (measured / SLIT placeholder / simulated).
    pub fn bandwidth_source(&self) -> BandwidthSource {
        self.bw_source
    }

    /// The auto-tuner's predicted step time (µs) when it chose the
    /// strategy; `None` for explicit strategies.
    pub fn predicted_step_us(&self) -> Option<f64> {
        self.predicted_step_us
    }

    /// Record the auto-tuner's prediction for the chosen strategy so
    /// reports and metrics can surface predicted vs measured.
    pub fn set_predicted_step_us(&mut self, us: Option<f64>) {
        self.predicted_step_us = us;
    }

    /// EWMA of measured decode-step time (µs); `None` before the first
    /// decode pass.
    pub fn step_ewma_us(&self) -> Option<f64> {
        self.step_ewma_us
    }

    /// Decode passes folded into the step-time EWMA.
    pub fn step_samples(&self) -> usize {
        self.step_samples
    }

    /// Measured/predicted step-time ratio (`None` without a tuner
    /// prediction or before the first decode pass).
    pub fn drift_ratio(&self) -> Option<f64> {
        crate::trace::drift_verdict(self.step_ewma_us, self.predicted_step_us, self.step_samples).0
    }

    /// Whether measured decode-step times drifted out of the acceptable
    /// band around the tuner's `predicted_step_us` — the hook a
    /// per-phase re-tuner consumes (see [`crate::trace::drift_verdict`]
    /// for the band and warm-up rules).
    pub fn retune_recommended(&self) -> bool {
        crate::trace::drift_verdict(self.step_ewma_us, self.predicted_step_us, self.step_samples).1
    }

    /// Fold the just-completed decode pass into the step-time EWMA.
    fn note_decode_step(&mut self) {
        if let Some(rep) = &self.last_report {
            self.step_ewma_us = Some(crate::trace::ewma_fold(self.step_ewma_us, rep.elapsed * 1e6));
            self.step_samples += 1;
        }
    }

    /// Stamp strategy/bandwidth provenance (and any tuner prediction)
    /// onto a fresh pass report — executors can't: they see cores and
    /// organizations, not the strategy that derived them.
    fn stamp(&self, mut rep: StepReport) -> StepReport {
        rep.strategy = self.strategy_name.clone();
        rep.bandwidth_source = self.bw_source;
        rep.predicted_step_us = self.predicted_step_us;
        rep
    }

    /// Clear the KV cache, rewind to position 0 and invalidate every
    /// live sequence (their handles become inert; dropping them is a
    /// no-op). The prefix index is cleared too.
    pub fn reset(&mut self) {
        synth::reset_kv(&self.graphs);
        self.pos = 0;
        self.pager.lock().unwrap().reset();
    }

    // ---- multi-sequence API (continuous batching) --------------------------

    /// Concurrent decode lanes (1 = single-sequence engine).
    pub fn batch_slots(&self) -> usize {
        self.graphs.batch_slots()
    }

    /// Live sequences.
    pub fn seqs_in_use(&self) -> usize {
        self.pager.lock().unwrap().live()
    }

    /// Physical pages in the KV arena.
    pub fn kv_total_pages(&self) -> usize {
        self.pager.lock().unwrap().arena.total_pages()
    }

    /// Pages currently held by sequences or the prefix index.
    pub fn kv_pages_in_use(&self) -> usize {
        self.pager.lock().unwrap().arena.in_use_pages()
    }

    /// Pages a new admission could still claim.
    pub fn kv_available_pages(&self) -> usize {
        self.pager.lock().unwrap().arena.available_pages()
    }

    /// Tokens per KV page.
    pub fn kv_page_size(&self) -> usize {
        self.graphs.kv_page_size
    }

    /// A [`PrefixProbe`] over this engine's prefix-page index. The
    /// probe stays valid (and current) while the engine lives on
    /// another thread — the cluster router scores replicas with it
    /// without touching the engines themselves.
    pub fn prefix_probe(&self) -> PrefixProbe {
        PrefixProbe { pager: self.pager.clone(), page_size: self.graphs.kv_page_size }
    }

    /// Start a sequence that may ingest up to `max_tokens` tokens,
    /// reserving every page it could ever need. `None` when the arena
    /// cannot promise that many pages (admission backpressure — retry
    /// after other sequences retire).
    pub fn seq_start(&mut self, max_tokens: usize) -> Option<SeqHandle> {
        self.seq_start_with_prompt(&[], max_tokens).map(|(h, _)| h)
    }

    /// [`Engine::seq_start`] with prefix reuse: completed pages whose
    /// rolling token-hash matches a prior sequence's `prompt` prefix
    /// are adopted instead of recomputed. Returns the handle plus the
    /// number of prompt tokens already in cache — the caller feeds
    /// only `prompt[hit..]` (always at least the last token, so the
    /// first sampled logits are computed, never stale).
    pub fn seq_start_with_prompt(
        &mut self,
        prompt: &[i32],
        max_tokens: usize,
    ) -> Option<(SeqHandle, usize)> {
        let max_seq = self.cfg().max_seq;
        assert!(
            max_tokens >= 1 && max_tokens <= max_seq,
            "sequence budget {max_tokens} outside the {max_seq}-token KV span"
        );
        assert!(prompt.len() <= max_tokens, "prompt longer than the sequence budget");
        let ps = self.graphs.kv_page_size;
        let all_hashes = page_hashes(prompt, ps);
        // adopt strictly less than the whole prompt: the last prompt
        // token must be fed to produce the first logits
        let max_adopt = if prompt.is_empty() { 0 } else { (prompt.len() - 1) / ps };
        let mut pg = self.pager.lock().unwrap();
        let total = max_tokens.div_ceil(ps);
        let hits = pg.arena.admit(&all_hashes[..max_adopt.min(all_hashes.len())], total)?;
        let hit_tokens = hits.len() * ps;
        let hash = if hits.is_empty() { FNV_OFFSET } else { all_hashes[hits.len() - 1] };
        let reserved = total - hits.len();
        let generation = pg.generation;
        let id = pg.new_seq(SeqState {
            table: hits,
            len: hit_tokens,
            hash,
            reserved,
            budget: max_tokens,
            prefix_hit: hit_tokens,
            alive: true,
        });
        drop(pg);
        Some((SeqHandle { pager: self.pager.clone(), id, generation }, hit_tokens))
    }

    /// Fork a live sequence: the child shares every parent page
    /// (including a partially-filled tail page) and reserves enough
    /// fresh pages to reach `max_tokens`, counting one for the
    /// copy-on-write of the shared tail on its first divergent append.
    pub fn seq_fork(&mut self, parent: &SeqHandle, max_tokens: usize) -> Option<SeqHandle> {
        let max_seq = self.cfg().max_seq;
        assert!(
            max_tokens >= 1 && max_tokens <= max_seq,
            "fork budget {max_tokens} outside the {max_seq}-token KV span"
        );
        let ps = self.graphs.kv_page_size;
        let mut pg = self.pager.lock().unwrap();
        let (table, len, hash) = {
            let st = pg.state(parent);
            (st.table.clone(), st.len, st.hash)
        };
        assert!(len <= max_tokens, "fork budget {max_tokens} below parent length {len}");
        let reserve = max_tokens.div_ceil(ps) - len / ps;
        pg.arena.admit(&[], reserve)?;
        for &p in &table {
            pg.arena.retain(p);
        }
        let generation = pg.generation;
        let id = pg.new_seq(SeqState {
            table,
            len,
            hash,
            reserved: reserve,
            budget: max_tokens,
            prefix_hit: 0,
            alive: true,
        });
        drop(pg);
        Some(SeqHandle { pager: self.pager.clone(), id, generation })
    }

    /// Tokens ingested so far by a live sequence.
    pub fn seq_pos(&self, h: &SeqHandle) -> usize {
        self.pager.lock().unwrap().state(h).len
    }

    /// Physical pages a live sequence's table currently names.
    pub fn seq_pages(&self, h: &SeqHandle) -> usize {
        self.pager.lock().unwrap().state(h).table.len()
    }

    /// Prompt tokens this sequence adopted from shared prefix pages.
    pub fn seq_prefix_hit(&self, h: &SeqHandle) -> usize {
        self.pager.lock().unwrap().state(h).prefix_hit
    }

    /// Copy one physical page's rows across every KV cache leaf — the
    /// byte-moving half of CoW divergence (bookkeeping is the pager's).
    fn copy_kv_page(&self, src: u32, dst: u32) {
        let ps = self.graphs.kv_page_size;
        let graph = &self.graphs.decode;
        for &id in &self.graphs.kv_ids {
            let meta = graph.meta(id);
            let (heads, capacity, hd) = (meta.shape[0], meta.shape[1], meta.shape[2]);
            let buf = graph.buf(id);
            let f = unsafe { self.pool.arena(buf.arena).f32s_mut(buf.off, buf.len / 4) };
            for h in 0..heads {
                let base = h * capacity * hd;
                let s0 = base + src as usize * ps * hd;
                let d0 = base + dst as usize * ps * hd;
                f.copy_within(s0..s0 + ps * hd, d0);
            }
        }
    }

    /// One continuous-batching step: each lane feeds `token` to its
    /// sequence at that sequence's next position, all lanes in a single
    /// graph pass. Several lanes may name the *same* sequence — they
    /// ingest consecutive positions of it (chunked prefill inside a
    /// running batch). A lane crossing into a fresh page claims one
    /// from its reservation; a lane appending into a page it shares
    /// with another holder copies it first (CoW). Pages completed this
    /// step are registered in the prefix index. Returns next-token
    /// logits per lane.
    ///
    /// Panics when the engine was built without `batch_slots > 1`, when
    /// more lanes than slots are passed, on a lane for a retired or
    /// stale sequence, or when a lane would overflow its sequence's
    /// admitted token budget.
    pub fn step_batch(&mut self, lanes: &[(&SeqHandle, i32)]) -> Vec<Vec<f32>> {
        let slots = self.batch_slots();
        let graph = self
            .graphs
            .decode_batch
            .clone()
            .expect("engine built without batch slots (set EngineOptions::batch_slots > 1)");
        assert!(
            !lanes.is_empty() && lanes.len() <= slots,
            "step of {} lanes on a {slots}-slot engine",
            lanes.len()
        );
        let ps = self.graphs.kv_page_size;
        let mut tables = Vec::with_capacity(lanes.len());
        let mut pos = Vec::with_capacity(lanes.len());
        let mut toks = vec![0i32; slots];
        {
            let mut pg = self.pager.lock().unwrap();
            for (r, (seq, tok)) in lanes.iter().enumerate() {
                pg.state(seq); // generation + liveness checks
                let s = seq.id;
                let p = pg.seqs[s].len;
                let budget = pg.seqs[s].budget;
                assert!(p < budget, "sequence {s} KV span full ({budget})");
                let pi = p / ps;
                if p % ps == 0 {
                    debug_assert_eq!(pg.seqs[s].table.len(), pi, "table out of step with len");
                    let page = pg.arena.alloc_page();
                    let st = &mut pg.seqs[s];
                    st.reserved -= 1;
                    st.table.push(page);
                } else {
                    let page = pg.seqs[s].table[pi];
                    if pg.arena.holders(page) > 1 {
                        // first divergent append into a shared page
                        let fresh = pg.arena.alloc_page();
                        self.copy_kv_page(page, fresh);
                        pg.arena.release(page);
                        let st = &mut pg.seqs[s];
                        st.reserved -= 1;
                        st.table[pi] = fresh;
                    }
                }
                let st = &mut pg.seqs[s];
                st.hash = fnv_step(st.hash, *tok);
                st.len = p + 1;
                let (h, page) = (st.hash, st.table[pi]);
                if st.len % ps == 0 {
                    pg.arena.register(h, page);
                }
                tables.push(pg.seqs[s].table.clone());
                pos.push(p);
                toks[r] = *tok;
            }
        }
        let tokens_id = self.graphs.decode_batch_tokens.expect("batch tokens leaf");
        self.write_tokens(&graph, tokens_id, &toks);
        let params = ExecParams::batched(BatchView::new(ps, tables, pos));
        self.last_report = Some(self.stamp(self.executor.run(&graph, &params)));
        self.note_decode_step();
        let logits_id = self.graphs.decode_batch_logits.expect("batch logits");
        let all = self.read_logits(&graph, logits_id);
        let vocab = self.cfg().vocab;
        (0..lanes.len()).map(|r| all[r * vocab..(r + 1) * vocab].to_vec()).collect()
    }

    fn write_tokens(&self, graph: &crate::graph::Graph, id: crate::tensor::TensorId, toks: &[i32]) {
        let buf = graph.buf(id);
        assert_eq!(buf.len, toks.len() * 4);
        unsafe {
            let dst = self.pool.arena(buf.arena).bytes_mut(buf.off, buf.len);
            for (i, t) in toks.iter().enumerate() {
                dst[i * 4..(i + 1) * 4].copy_from_slice(&t.to_le_bytes());
            }
        }
    }

    fn read_logits(&self, graph: &crate::graph::Graph, id: crate::tensor::TensorId) -> Vec<f32> {
        let buf = graph.buf(id);
        unsafe { self.pool.arena(buf.arena).f32s(buf.off, buf.len / 4).to_vec() }
    }

    /// One decode step: ingest `token` at the current position, return
    /// the next-token logits.
    pub fn decode_step(&mut self, token: i32) -> Vec<f32> {
        assert!(self.pos < self.cfg().max_seq, "KV cache full");
        let graph = self.graphs.decode.clone();
        self.write_tokens(&graph, self.graphs.decode_tokens, &[token]);
        let params = ExecParams::dense(self.pos, 1);
        self.last_report = Some(self.stamp(self.executor.run(&graph, &params)));
        self.note_decode_step();
        self.pos += 1;
        self.read_logits(&graph, self.graphs.decode_logits)
    }

    /// Ingest a prompt; returns logits for the position after the last
    /// prompt token. Uses the one-pass prefill graph when its shape
    /// matches, decode steps otherwise.
    pub fn prefill(&mut self, tokens: &[i32]) -> Vec<f32> {
        assert!(!tokens.is_empty());
        let cap = self.cfg().max_seq;
        assert!(self.pos + tokens.len() <= cap, "prompt exceeds KV capacity");
        if let (Some(pg), Some(ptoks), Some(plogits)) =
            (&self.graphs.prefill, self.graphs.prefill_tokens, self.graphs.prefill_logits)
        {
            let rows = pg.meta(ptoks).numel();
            if rows == tokens.len() && self.pos == 0 {
                let pg = pg.clone();
                self.write_tokens(&pg, ptoks, tokens);
                let params = ExecParams::dense(0, rows);
                self.last_report = Some(self.stamp(self.executor.run(&pg, &params)));
                self.pos = rows;
                return self.read_logits(&pg, plogits);
            }
        }
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.decode_step(t);
        }
        logits
    }

    /// Autoregressive generation with timing (the paper's benchmark
    /// loop: prompt ingestion, then `max_new` greedy/top-k steps).
    pub fn generate(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        sampler: &Sampler,
    ) -> GenerationResult {
        let t0 = Instant::now();
        let mut logits = self.prefill(prompt);
        let prefill_seconds = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let mut tokens = Vec::with_capacity(max_new);
        for step in 0..max_new {
            let next = sampler.sample(&logits, step);
            tokens.push(next);
            if self.pos >= self.cfg().max_seq {
                break;
            }
            if step + 1 < max_new {
                logits = self.decode_step(next);
            }
        }
        let decode_seconds = t1.elapsed().as_secs_f64();
        GenerationResult {
            decode_tokens: tokens.len(),
            prefill_tokens: prompt.len(),
            tokens,
            prefill_seconds,
            decode_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    fn tiny_engine(strategy: Strategy, threads: usize, prefill: Option<usize>) -> Engine {
        tiny_engine_slots(strategy, threads, prefill, 1)
    }

    fn tiny_engine_slots(
        strategy: Strategy,
        threads: usize,
        prefill: Option<usize>,
        batch_slots: usize,
    ) -> Engine {
        let opts = EngineOptions {
            strategy,
            threads,
            platform: Platform::Simulated(Topology::uniform(4, 4, 100.0, 25.0)),
            prefill_rows: prefill,
            seed: 42,
            batch_slots,
            pin: false,
            page_size: 16,
            kv_pages: None,
            base_node: 0,
        };
        Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap()
    }

    /// Continuous-batching driver: feed every prompt one token per step
    /// (so the sequences genuinely interleave inside each pass), then
    /// decode all of them together until each has `max_new` tokens.
    fn drive_batched(engine: &mut Engine, prompts: &[&[i32]], max_new: usize) -> Vec<Vec<i32>> {
        let n = prompts.len();
        let cap = engine.cfg().max_seq;
        let seqs: Vec<SeqHandle> = prompts
            .iter()
            .map(|p| engine.seq_start((p.len() + max_new).min(cap)).unwrap())
            .collect();
        let sampler = Sampler::greedy();
        let mut fed = vec![0usize; n];
        let mut next_tok = vec![0i32; n];
        let mut done = vec![false; n];
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); n];
        while done.iter().any(|d| !d) {
            let mut lanes: Vec<(&SeqHandle, i32)> = Vec::new();
            let mut owners: Vec<(usize, bool)> = Vec::new();
            for i in 0..n {
                if done[i] || lanes.len() == engine.batch_slots() {
                    continue;
                }
                if fed[i] < prompts[i].len() {
                    lanes.push((&seqs[i], prompts[i][fed[i]]));
                    fed[i] += 1;
                    owners.push((i, fed[i] == prompts[i].len()));
                } else {
                    lanes.push((&seqs[i], next_tok[i]));
                    owners.push((i, true));
                }
            }
            let logits = engine.step_batch(&lanes);
            for (li, &(i, sample)) in owners.iter().enumerate() {
                if !sample {
                    continue;
                }
                let t = sampler.sample(&logits[li], out[i].len());
                out[i].push(t);
                next_tok[i] = t;
                if out[i].len() == max_new || engine.seq_pos(&seqs[i]) >= engine.cfg().max_seq {
                    done[i] = true;
                }
            }
        }
        out
    }

    #[test]
    fn decode_issues_one_pool_dispatch_per_pass() {
        // the PassPlan contract: a whole decode pass (hundreds of
        // operators on real models) is a single ThreadPool dispatch
        let mut e = tiny_engine(Strategy::arclight_single(), 2, None);
        assert!(e.last_step_report().is_none());
        for t in [5, 9, 2] {
            e.decode_step(t);
            let rep = e.last_step_report().expect("pass ran");
            assert_eq!(rep.dispatches, 1, "decode pass must be one dispatch");
            assert_eq!(rep.ops, e.graphs.decode.exec.len());
            assert!(rep.ops > 1, "plan must cover many operators");
        }
        // TP decode (both barrier topologies in one pass) too
        let mut tp = tiny_engine(
            Strategy::arclight_tp(2, crate::sched::SyncMode::SyncB),
            4,
            None,
        );
        tp.decode_step(5);
        assert_eq!(tp.last_step_report().unwrap().dispatches, 1);
        // and the batched graph
        let mut b = tiny_engine_slots(Strategy::arclight_single(), 2, None, 2);
        let s = b.seq_start(4).unwrap();
        b.step_batch(&[(&s, 7)]);
        assert_eq!(b.last_step_report().unwrap().dispatches, 1);
    }

    #[test]
    fn reports_carry_strategy_and_bandwidth_provenance() {
        let mut e = tiny_engine(Strategy::arclight_single(), 2, None);
        e.decode_step(1);
        let rep = e.last_step_report().unwrap();
        assert_eq!(rep.strategy, "arclight");
        assert_eq!(rep.bandwidth_source, crate::numa::BandwidthSource::Simulated);
        assert_eq!(rep.predicted_step_us, None);
        assert_eq!(e.strategy_name(), "arclight");
        // a tuner prediction propagates to every subsequent report
        e.set_predicted_step_us(Some(123.5));
        e.decode_step(2);
        assert_eq!(e.last_step_report().unwrap().predicted_step_us, Some(123.5));
        // TP strategies stamp their full name
        let mut tp = tiny_engine(
            Strategy::arclight_tp(2, crate::sched::SyncMode::SyncB),
            4,
            None,
        );
        tp.decode_step(3);
        assert_eq!(tp.last_step_report().unwrap().strategy, "arclight-tp2-syncB");
    }

    #[test]
    fn pass_plans_cached_per_graph_and_batch_shape() {
        // plan-cache contract: same (graph, rows) reuses the compiled
        // plan; a batch-shape change recompiles (and re-caches)
        let mut e = tiny_engine_slots(Strategy::arclight_single(), 2, None, 3);
        let s = e.seq_start(8).unwrap();
        e.step_batch(&[(&s, 1)]);
        assert!(!e.last_step_report().unwrap().plan_cached, "first shape must compile");
        e.step_batch(&[(&s, 2)]);
        assert!(e.last_step_report().unwrap().plan_cached, "same shape must reuse the plan");
        let s2 = e.seq_start(8).unwrap();
        e.step_batch(&[(&s, 3), (&s2, 4)]);
        assert!(!e.last_step_report().unwrap().plan_cached, "new batch shape must recompile");
        e.step_batch(&[(&s, 5), (&s2, 6)]);
        assert!(e.last_step_report().unwrap().plan_cached);
        // dropping back to the old shape hits its retained entry
        e.step_batch(&[(&s2, 7)]);
        assert!(e.last_step_report().unwrap().plan_cached);
        // the single-sequence decode graph is a distinct cache entry
        let mut d = tiny_engine(Strategy::arclight_single(), 2, None);
        d.decode_step(1);
        assert!(!d.last_step_report().unwrap().plan_cached);
        d.decode_step(2);
        assert!(d.last_step_report().unwrap().plan_cached);
    }

    #[test]
    fn engine_reports_platform_and_pinning() {
        let e = tiny_engine(Strategy::arclight_single(), 2, None);
        assert_eq!(e.platform(), "simulated");
        assert_eq!(e.pinned_workers(), 0, "simulated platform never pins");
    }

    #[test]
    fn decode_produces_finite_logits() {
        let mut e = tiny_engine(Strategy::arclight_single(), 2, None);
        let logits = e.decode_step(5);
        assert_eq!(logits.len(), 512);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(e.position(), 1);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut e1 = tiny_engine(Strategy::arclight_single(), 1, None);
        let mut e4 = tiny_engine(Strategy::arclight_single(), 4, None);
        let a = e1.decode_step(7);
        let b = e4.decode_step(7);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn prefill_graph_matches_stepwise_prefill() {
        let mut fast = tiny_engine(Strategy::arclight_single(), 2, Some(5));
        let mut slow = tiny_engine(Strategy::arclight_single(), 2, None);
        let prompt = [1, 2, 3, 4, 5];
        let a = fast.prefill(&prompt);
        let b = slow.prefill(&prompt);
        assert_eq!(fast.position(), 5);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn tp_matches_single_node() {
        let mut single = tiny_engine(Strategy::arclight_single(), 2, None);
        let mut tp = tiny_engine(
            Strategy::arclight_tp(2, crate::sched::SyncMode::SyncB),
            4,
            None,
        );
        let prompt = [3, 1, 4, 1, 5];
        let a = single.prefill(&prompt);
        let b = tp.prefill(&prompt);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn generation_is_deterministic_and_resettable() {
        let mut e = tiny_engine(Strategy::arclight_single(), 2, None);
        let prompt = [10, 20, 30];
        let r1 = e.generate(&prompt, 8, &Sampler::greedy());
        e.reset();
        let r2 = e.generate(&prompt, 8, &Sampler::greedy());
        assert_eq!(r1.tokens, r2.tokens);
        assert_eq!(r1.decode_tokens, 8);
    }

    #[test]
    fn llama_strategy_also_decodes() {
        let mut e = tiny_engine(Strategy::llama_distribute(2), 4, None);
        let logits = e.decode_step(9);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn batched_interleaved_decode_matches_serial() {
        // serial reference: two generations, one at a time
        let mut serial = tiny_engine(Strategy::arclight_single(), 2, None);
        let p1: &[i32] = &[5, 9, 2];
        let p2: &[i32] = &[7, 7, 1, 3];
        let r1 = serial.generate(p1, 6, &Sampler::greedy());
        serial.reset();
        let r2 = serial.generate(p2, 6, &Sampler::greedy());

        // continuous: both sequences interleaved in every batched pass
        let mut batched = tiny_engine_slots(Strategy::arclight_single(), 2, None, 3);
        let out = drive_batched(&mut batched, &[p1, p2], 6);
        assert_eq!(out[0], r1.tokens, "sequence 1 diverged under batching");
        assert_eq!(out[1], r2.tokens, "sequence 2 diverged under batching");
    }

    #[test]
    fn single_lane_step_matches_decode_step() {
        let mut a = tiny_engine_slots(Strategy::arclight_single(), 2, None, 2);
        let mut b = tiny_engine(Strategy::arclight_single(), 2, None);
        let s = a.seq_start(16).unwrap();
        for t in [3i32, 14, 15] {
            let la = a.step_batch(&[(&s, t)]).remove(0);
            let lb = b.decode_step(t);
            assert_eq!(la, lb, "lane logits diverged at token {t}");
        }
        assert_eq!(a.seq_pos(&s), 3);
    }

    #[test]
    fn pages_exhaust_and_recycle_on_drop() {
        // arena defaults to 2 full-length sequences' worth of pages
        let mut e = tiny_engine_slots(Strategy::arclight_single(), 2, None, 2);
        let cap = e.cfg().max_seq;
        let s0 = e.seq_start(cap).unwrap();
        let s1 = e.seq_start(cap).unwrap();
        assert!(e.seq_start(1).is_none(), "overcommitted admission must be refused");
        assert_eq!(e.seqs_in_use(), 2);
        e.step_batch(&[(&s0, 1), (&s1, 2)]);
        assert_eq!(e.seq_pos(&s0), 1);
        assert_eq!(e.kv_pages_in_use(), 2, "one page claimed per started sequence");
        // dropping the handle returns pages and reservation (RAII)
        drop(s0);
        assert_eq!(e.seqs_in_use(), 1);
        let s0b = e.seq_start(cap).unwrap();
        assert_eq!(e.seq_pos(&s0b), 0);
    }

    #[test]
    fn identical_prompts_share_prefix_pages() {
        // page size 16: a 20-token prompt completes one shareable page
        let mut e = tiny_engine_slots(Strategy::arclight_single(), 2, None, 3);
        let prompt: Vec<i32> = (0..20).collect();
        let feed = |e: &mut Engine, s: &SeqHandle, toks: &[i32]| -> Vec<f32> {
            let mut last = Vec::new();
            for &t in toks {
                last = e.step_batch(&[(s, t)]).remove(0);
            }
            last
        };
        let (s1, h1) = e.seq_start_with_prompt(&prompt, 24).unwrap();
        assert_eq!(h1, 0, "cold prefix index must not hit");
        let l1 = feed(&mut e, &s1, &prompt);
        let used = e.kv_pages_in_use();
        let (s2, h2) = e.seq_start_with_prompt(&prompt, 24).unwrap();
        assert_eq!(h2, 16, "second identical prompt adopts the completed page");
        let l2 = feed(&mut e, &s2, &prompt[h2..]);
        assert_eq!(l1, l2, "prefix-hit logits must be bit-identical to the cold path");
        assert_eq!(e.kv_pages_in_use(), used + 1, "only the tail page is new");
        assert_eq!(e.seq_prefix_hit(&s2), 16);
        assert_eq!(e.seq_prefix_hit(&s1), 0);
    }

    #[test]
    fn recycled_slot_reproduces_fresh_results() {
        // a slot that served a long sequence must serve a new one
        // identically to a never-used slot (stale KV is never read)
        let mut e = tiny_engine_slots(Strategy::arclight_single(), 2, None, 2);
        let p: &[i32] = &[11, 4, 8];
        let first = drive_batched(&mut e, &[&[9, 9, 9, 9, 9, 9]], 8);
        assert_eq!(first.len(), 1);
        let reused = drive_batched(&mut e, &[p], 5);
        let mut fresh = tiny_engine(Strategy::arclight_single(), 2, None);
        let want = fresh.generate(p, 5, &Sampler::greedy());
        assert_eq!(reused[0], want.tokens);
    }

    #[test]
    #[should_panic(expected = "KV span full")]
    fn lane_past_budget_panics() {
        let mut e = tiny_engine_slots(Strategy::arclight_single(), 2, None, 2);
        let s = e.seq_start(e.cfg().max_seq).unwrap();
        for t in 0..(e.cfg().max_seq + 1) {
            e.step_batch(&[(&s, t as i32)]);
        }
    }

    #[test]
    fn prefix_probe_sees_registered_pages_without_mutating() {
        let mut e = tiny_engine_slots(Strategy::arclight_single(), 2, None, 2);
        let prompt: Vec<i32> = (0..20).collect();
        let probe = e.prefix_probe();
        assert_eq!(probe.prefix_run_tokens(&prompt), 0, "cold index must report no run");
        let (s, _) = e.seq_start_with_prompt(&prompt, 24).unwrap();
        for &t in &prompt {
            e.step_batch(&[(&s, t)]);
        }
        // one completed 16-token page is registered; the probe reports
        // exactly what admission would adopt, however often it is asked
        let used = e.kv_pages_in_use();
        assert_eq!(probe.prefix_run_tokens(&prompt), 16);
        assert_eq!(probe.prefix_run_tokens(&prompt), 16);
        assert_eq!(e.kv_pages_in_use(), used, "probing must not claim pages");
        // a divergent prompt shares no prefix
        let other: Vec<i32> = (100..120).collect();
        assert_eq!(probe.prefix_run_tokens(&other), 0);
        // short prompts never complete a page
        assert_eq!(probe.prefix_run_tokens(&prompt[..8]), 0);
        // reset invalidates the index and the probe follows
        e.reset();
        assert_eq!(probe.prefix_run_tokens(&prompt), 0);
    }

    #[test]
    fn base_node_engine_matches_node0_tokens() {
        // the same model built on node 1 of a 4-node machine must
        // generate identical tokens to the classic node-0 engine
        let mut a = tiny_engine(Strategy::arclight_single(), 2, None);
        let opts = EngineOptions {
            strategy: Strategy::arclight_single(),
            threads: 2,
            platform: Platform::Simulated(Topology::uniform(4, 4, 100.0, 25.0)),
            prefill_rows: None,
            seed: 42,
            batch_slots: 1,
            pin: false,
            page_size: 16,
            kv_pages: None,
            base_node: 1,
        };
        let mut b = Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap();
        let prompt = [4, 8, 15, 16];
        let ra = a.generate(&prompt, 6, &Sampler::greedy());
        let rb = b.generate(&prompt, 6, &Sampler::greedy());
        assert_eq!(ra.tokens, rb.tokens, "placement shift must not change arithmetic");
        // a window that falls off the machine is refused at build
        let bad = EngineOptions { base_node: 4, ..opts };
        assert!(Engine::new_synthetic(ModelConfig::tiny(), &bad).is_err());
    }

    #[test]
    fn tp_batched_decode_matches_serial() {
        // TP(2) batched engine must agree with the single-node serial one
        let mut serial = tiny_engine(Strategy::arclight_single(), 2, None);
        let p: &[i32] = &[3, 1, 4];
        let want = serial.generate(p, 5, &Sampler::greedy());
        let mut tp = tiny_engine_slots(
            Strategy::arclight_tp(2, crate::sched::SyncMode::SyncB),
            4,
            None,
            2,
        );
        let out = drive_batched(&mut tp, &[p], 5);
        assert_eq!(out[0], want.tokens);
    }
}
