//! The inference engine: graphs + thread pool + executor + decode loop.
//!
//! `Engine` is the real-execution object behind the CLI, the examples
//! and the serving layer. It owns the worker pool (created once, before
//! inference — §2.4), the model graphs and the weight storage, and
//! exposes the frontend API: `prefill`, `decode_step`, `generate`.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::baseline::Strategy;
use crate::model::synth;
use crate::model::{AlfFile, ModelConfig, ModelGraphs};
use crate::numa::Topology;
use crate::sched::{ExecParams, RealExecutor};
use crate::threads::ThreadPool;

use super::sampler::Sampler;

/// Construction options.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub strategy: Strategy,
    pub threads: usize,
    pub topo: Topology,
    /// Build a one-pass prefill graph for prompts of exactly this
    /// length (other lengths fall back to token-by-token prefill).
    pub prefill_rows: Option<usize>,
    /// Synthetic weight seed when no ALF file is given.
    pub seed: u64,
}

impl EngineOptions {
    pub fn quick(strategy: Strategy, threads: usize) -> Self {
        EngineOptions {
            strategy,
            threads,
            topo: Topology::kunpeng920(),
            prefill_rows: None,
            seed: 0,
        }
    }
}

/// Timing + output of one generation call.
#[derive(Clone, Debug)]
pub struct GenerationResult {
    pub tokens: Vec<i32>,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
}

impl GenerationResult {
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.decode_seconds == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.decode_seconds
        }
    }

    pub fn prefill_tok_per_s(&self) -> f64 {
        if self.prefill_seconds == 0.0 {
            0.0
        } else {
            self.prefill_tokens as f64 / self.prefill_seconds
        }
    }
}

/// The real-execution engine.
pub struct Engine {
    pub graphs: ModelGraphs,
    executor: RealExecutor,
    pos: usize,
}

impl Engine {
    /// Build with synthetic weights.
    pub fn new_synthetic(cfg: ModelConfig, opts: &EngineOptions) -> Result<Engine> {
        let mut e = Self::build(cfg, opts)?;
        synth::fill_synthetic(&e.graphs, opts.seed)?;
        e.reset();
        Ok(e)
    }

    /// Build from an ALF weight file (geometry read from the file).
    pub fn from_alf(path: &std::path::Path, opts: &EngineOptions) -> Result<Engine> {
        let alf = AlfFile::open(path)?;
        let cfg = ModelConfig::from_json(&alf.config)
            .map_err(|e| anyhow::anyhow!("bad ALF config: {e}"))?;
        let mut e = Self::build(cfg, opts)?;
        synth::load_alf(&e.graphs, &alf)?;
        e.reset();
        Ok(e)
    }

    fn build(cfg: ModelConfig, opts: &EngineOptions) -> Result<Engine> {
        if opts.threads == 0 {
            bail!("at least one thread required");
        }
        if opts.threads < opts.strategy.nodes_used() {
            bail!(
                "strategy {} spans {} NUMA nodes but only {} thread(s) were given",
                opts.strategy.name(),
                opts.strategy.nodes_used(),
                opts.threads
            );
        }
        let total_nodes = opts.topo.n_nodes();
        let mut spec = opts.strategy.build_spec(cfg, total_nodes);
        if let Some(rows) = opts.prefill_rows {
            spec = spec.with_prefill(rows);
        }
        let graphs = ModelGraphs::build(spec);
        let pool = graphs.pool.clone().expect("real engine needs buffers");

        let cores = opts.strategy.bind_cores(&opts.topo, opts.threads);
        let (single, tp) = opts.strategy.organizations(&cores);
        let threads = Arc::new(ThreadPool::new(cores));
        let executor = RealExecutor::new(
            pool,
            threads,
            Arc::new(single),
            Arc::new(tp),
            opts.strategy.sync(),
        );
        Ok(Engine { graphs, executor, pos: 0 })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.graphs.cfg
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    /// Clear the KV cache and rewind to position 0.
    pub fn reset(&mut self) {
        synth::reset_kv(&self.graphs);
        self.pos = 0;
    }

    fn write_tokens(&self, graph: &crate::graph::Graph, id: crate::tensor::TensorId, toks: &[i32]) {
        let buf = graph.buf(id);
        assert_eq!(buf.len, toks.len() * 4);
        let pool = self.executor.pool.clone();
        unsafe {
            let dst = pool.arena(buf.arena).bytes_mut(buf.off, buf.len);
            for (i, t) in toks.iter().enumerate() {
                dst[i * 4..(i + 1) * 4].copy_from_slice(&t.to_le_bytes());
            }
        }
    }

    fn read_logits(&self, graph: &crate::graph::Graph, id: crate::tensor::TensorId) -> Vec<f32> {
        let buf = graph.buf(id);
        unsafe {
            self.executor.pool.arena(buf.arena).f32s(buf.off, buf.len / 4).to_vec()
        }
    }

    /// One decode step: ingest `token` at the current position, return
    /// the next-token logits.
    pub fn decode_step(&mut self, token: i32) -> Vec<f32> {
        assert!(self.pos < self.cfg().max_seq, "KV cache full");
        let graph = self.graphs.decode.clone();
        self.write_tokens(&graph, self.graphs.decode_tokens, &[token]);
        let params = ExecParams { pos: self.pos, rows: 1 };
        self.executor.run(&graph, params);
        self.pos += 1;
        self.read_logits(&graph, self.graphs.decode_logits)
    }

    /// Ingest a prompt; returns logits for the position after the last
    /// prompt token. Uses the one-pass prefill graph when its shape
    /// matches, decode steps otherwise.
    pub fn prefill(&mut self, tokens: &[i32]) -> Vec<f32> {
        assert!(!tokens.is_empty());
        assert!(self.pos + tokens.len() <= self.cfg().max_seq, "prompt exceeds KV capacity");
        if let (Some(pg), Some(ptoks), Some(plogits)) =
            (&self.graphs.prefill, self.graphs.prefill_tokens, self.graphs.prefill_logits)
        {
            let rows = pg.meta(ptoks).numel();
            if rows == tokens.len() && self.pos == 0 {
                let pg = pg.clone();
                self.write_tokens(&pg, ptoks, tokens);
                let params = ExecParams { pos: 0, rows };
                self.executor.run(&pg, params);
                self.pos = rows;
                return self.read_logits(&pg, plogits);
            }
        }
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.decode_step(t);
        }
        logits
    }

    /// Autoregressive generation with timing (the paper's benchmark
    /// loop: prompt ingestion, then `max_new` greedy/top-k steps).
    pub fn generate(&mut self, prompt: &[i32], max_new: usize, sampler: &Sampler) -> GenerationResult {
        let t0 = Instant::now();
        let mut logits = self.prefill(prompt);
        let prefill_seconds = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let mut tokens = Vec::with_capacity(max_new);
        for step in 0..max_new {
            let next = sampler.sample(&logits, step);
            tokens.push(next);
            if self.pos >= self.cfg().max_seq {
                break;
            }
            if step + 1 < max_new {
                logits = self.decode_step(next);
            }
        }
        let decode_seconds = t1.elapsed().as_secs_f64();
        GenerationResult {
            decode_tokens: tokens.len(),
            prefill_tokens: prompt.len(),
            tokens,
            prefill_seconds,
            decode_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numa::Topology;

    fn tiny_engine(strategy: Strategy, threads: usize, prefill: Option<usize>) -> Engine {
        let opts = EngineOptions {
            strategy,
            threads,
            topo: Topology::uniform(4, 4, 100.0, 25.0),
            prefill_rows: prefill,
            seed: 42,
        };
        Engine::new_synthetic(ModelConfig::tiny(), &opts).unwrap()
    }

    #[test]
    fn decode_produces_finite_logits() {
        let mut e = tiny_engine(Strategy::arclight_single(), 2, None);
        let logits = e.decode_step(5);
        assert_eq!(logits.len(), 512);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(e.position(), 1);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut e1 = tiny_engine(Strategy::arclight_single(), 1, None);
        let mut e4 = tiny_engine(Strategy::arclight_single(), 4, None);
        let a = e1.decode_step(7);
        let b = e4.decode_step(7);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn prefill_graph_matches_stepwise_prefill() {
        let mut fast = tiny_engine(Strategy::arclight_single(), 2, Some(5));
        let mut slow = tiny_engine(Strategy::arclight_single(), 2, None);
        let prompt = [1, 2, 3, 4, 5];
        let a = fast.prefill(&prompt);
        let b = slow.prefill(&prompt);
        assert_eq!(fast.position(), 5);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn tp_matches_single_node() {
        let mut single = tiny_engine(Strategy::arclight_single(), 2, None);
        let mut tp = tiny_engine(
            Strategy::arclight_tp(2, crate::sched::SyncMode::SyncB),
            4,
            None,
        );
        let prompt = [3, 1, 4, 1, 5];
        let a = single.prefill(&prompt);
        let b = tp.prefill(&prompt);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn generation_is_deterministic_and_resettable() {
        let mut e = tiny_engine(Strategy::arclight_single(), 2, None);
        let prompt = [10, 20, 30];
        let r1 = e.generate(&prompt, 8, &Sampler::greedy());
        e.reset();
        let r2 = e.generate(&prompt, 8, &Sampler::greedy());
        assert_eq!(r1.tokens, r2.tokens);
        assert_eq!(r1.decode_tokens, 8);
    }

    #[test]
    fn llama_strategy_also_decodes() {
        let mut e = tiny_engine(Strategy::llama_distribute(2), 4, None);
        let logits = e.decode_step(9);
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}
