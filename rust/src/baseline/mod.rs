//! Execution strategies: ArcLight and the llama.cpp comparator.
//!
//! The paper benches `llama-cli ... -numa isolate|distribute` (appendix
//! A.3) against ArcLight with cross-NUMA TP. Both run the *same* model
//! graph code here; a [`Strategy`] only decides
//!
//! * where tensors are placed (NUMA-aware vs UMA/first-touch),
//! * how threads are bound to cores (`isolate` fills node 0,
//!   `distribute` spreads evenly),
//! * whether the graph contains TP subgraphs, and
//! * the synchronization discipline (Sync A/B vs llama.cpp's global
//!   barrier after every operator).

pub mod tune;

use std::sync::Arc;

use crate::hw::Platform;
use crate::memory::{MemoryPool, PlanMode};
use crate::model::{BuildSpec, ModelConfig};
use crate::numa::{Core, CostModel, Topology};
use crate::sched::{RealExecutor, SimExecutor, SyncMode};
use crate::threads::{Organization, ThreadPool};

/// llama.cpp's `-numa` flag (appendix A.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LlamaNuma {
    /// All threads on one node (single-node baseline).
    Isolate,
    /// Threads evenly bound across `n` nodes; memory left to the OS.
    Distribute(usize),
}

/// A complete execution strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// ArcLight: NUMA-aware placement; TP across `nodes` when > 1.
    ArcLight { nodes: usize, sync: SyncMode },
    /// The llama.cpp comparator.
    LlamaCpp { numa: LlamaNuma },
}

impl Strategy {
    pub fn arclight_single() -> Self {
        Strategy::ArcLight { nodes: 1, sync: SyncMode::SyncB }
    }

    pub fn arclight_tp(nodes: usize, sync: SyncMode) -> Self {
        Strategy::ArcLight { nodes, sync }
    }

    pub fn llama_isolate() -> Self {
        Strategy::LlamaCpp { numa: LlamaNuma::Isolate }
    }

    pub fn llama_distribute(nodes: usize) -> Self {
        Strategy::LlamaCpp { numa: LlamaNuma::Distribute(nodes) }
    }

    /// Human name used in benchmark tables.
    pub fn name(&self) -> String {
        match self {
            Strategy::ArcLight { nodes: 1, .. } => "arclight".into(),
            Strategy::ArcLight { nodes, sync: SyncMode::SyncA } => {
                format!("arclight-tp{nodes}-syncA")
            }
            Strategy::ArcLight { nodes, sync: SyncMode::SyncB } => {
                format!("arclight-tp{nodes}-syncB")
            }
            Strategy::LlamaCpp { numa: LlamaNuma::Isolate } => "llama.cpp-isolate".into(),
            Strategy::LlamaCpp { numa: LlamaNuma::Distribute(n) } => {
                format!("llama.cpp-distribute{n}")
            }
        }
    }

    /// Number of NUMA nodes the strategy spans.
    pub fn nodes_used(&self) -> usize {
        match self {
            Strategy::ArcLight { nodes, .. } => *nodes,
            Strategy::LlamaCpp { numa: LlamaNuma::Isolate } => 1,
            Strategy::LlamaCpp { numa: LlamaNuma::Distribute(n) } => *n,
        }
    }

    /// The build spec for this strategy on a machine with `total_nodes`.
    pub fn build_spec(&self, cfg: ModelConfig, total_nodes: usize) -> BuildSpec {
        let mut spec = match self {
            Strategy::ArcLight { nodes, .. } => BuildSpec::arclight(cfg, *nodes),
            Strategy::LlamaCpp { numa } => {
                let nodes = match numa {
                    LlamaNuma::Isolate => 1,
                    LlamaNuma::Distribute(n) => *n,
                };
                BuildSpec::llama_cpp(cfg, nodes, total_nodes)
            }
        };
        spec.n_nodes = total_nodes;
        spec.plan_mode = PlanMode::DoubleBuffered;
        spec
    }

    /// Bind `threads` workers to simulated cores.
    pub fn bind_cores(&self, topo: &Topology, threads: usize) -> Vec<Core> {
        self.bind_cores_at(topo, threads, 0)
    }

    /// [`Strategy::bind_cores`] with the node window starting at `base`
    /// — a cluster replica binds onto its own node group instead of
    /// every engine stacking onto node 0.
    pub fn bind_cores_at(&self, topo: &Topology, threads: usize, base: usize) -> Vec<Core> {
        match self {
            Strategy::ArcLight { nodes, .. } => {
                topo.bind_cores_at(base, threads, *nodes > 1, *nodes)
            }
            Strategy::LlamaCpp { numa: LlamaNuma::Isolate } => {
                topo.bind_cores_at(base, threads, false, 1)
            }
            Strategy::LlamaCpp { numa: LlamaNuma::Distribute(n) } => {
                topo.bind_cores_at(base, threads, true, *n)
            }
        }
    }

    /// Thread organizations: (single view, TP view).
    pub fn organizations(&self, cores: &[Core]) -> (Organization, Organization) {
        let single = Organization::single(cores);
        let tp = match self {
            Strategy::ArcLight { nodes, .. } if *nodes > 1 => Organization::by_node(cores),
            _ => Organization::single(cores),
        };
        (single, tp)
    }

    pub fn sync(&self) -> SyncMode {
        match self {
            Strategy::ArcLight { sync, .. } => *sync,
            // llama.cpp has only the global-barrier discipline
            Strategy::LlamaCpp { .. } => SyncMode::SyncA,
        }
    }

    /// Build the real (wall-clock) backend for this strategy: bind
    /// `threads` workers to cores of the platform's topology, derive
    /// the single/TP organizations and wrap them with the memory pool.
    /// On a detected [`Platform::Host`] with `pin` set, each worker
    /// additionally pins itself to the OS cpu backing its `Core`
    /// (best effort — see `hw::affinity`). The engine and the parity
    /// tests drive the result through the `sched::Executor` trait.
    pub fn real_executor(
        &self,
        pool: Arc<MemoryPool>,
        platform: &Platform,
        threads: usize,
        pin: bool,
    ) -> RealExecutor {
        self.real_executor_on(pool, platform, threads, pin, 0)
    }

    /// [`Strategy::real_executor`] with workers bound starting at NUMA
    /// node `base` — cluster replicas get disjoint core sets (and thus
    /// disjoint pin maps) instead of stacking onto node 0.
    pub fn real_executor_on(
        &self,
        pool: Arc<MemoryPool>,
        platform: &Platform,
        threads: usize,
        pin: bool,
        base: usize,
    ) -> RealExecutor {
        let cores = self.bind_cores_at(platform.topology(), threads, base);
        let cpu_map = if pin { platform.cpu_map(&cores) } else { None };
        let (single, tp) = self.organizations(&cores);
        let workers = Arc::new(ThreadPool::with_affinity(cores, cpu_map));
        RealExecutor::new(pool, workers, Arc::new(single), Arc::new(tp), self.sync())
    }

    /// Build the virtual-time backend for this strategy on `topo` —
    /// the same binding/organization derivation as
    /// [`Strategy::real_executor`], charged to the cost model instead.
    pub fn sim_executor(&self, topo: &Topology, threads: usize) -> SimExecutor {
        self.sim_executor_at(topo, threads, 0)
    }

    /// [`Strategy::sim_executor`] with the node window starting at
    /// `base` — the auto-tuner costs candidate placements anywhere on
    /// the machine, not just node 0.
    pub fn sim_executor_at(&self, topo: &Topology, threads: usize, base: usize) -> SimExecutor {
        let cores = self.bind_cores_at(topo, threads, base);
        let (single, tp) = self.organizations(&cores);
        SimExecutor::new(CostModel::new(topo.clone()), cores, single, tp, self.sync())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_distinct() {
        let all = [
            Strategy::arclight_single(),
            Strategy::arclight_tp(4, SyncMode::SyncA),
            Strategy::arclight_tp(4, SyncMode::SyncB),
            Strategy::llama_isolate(),
            Strategy::llama_distribute(4),
        ];
        let names: std::collections::BTreeSet<String> = all.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), all.len());
    }

    #[test]
    fn arclight_tp_groups_by_node() {
        let topo = Topology::kunpeng920();
        let s = Strategy::arclight_tp(4, SyncMode::SyncB);
        let cores = s.bind_cores(&topo, 64);
        let (_, tp) = s.organizations(&cores);
        assert_eq!(tp.n_groups(), 4);
    }

    #[test]
    fn llama_distribute_spreads_but_one_group() {
        let topo = Topology::kunpeng920();
        let s = Strategy::llama_distribute(4);
        let cores = s.bind_cores(&topo, 64);
        assert_eq!(cores.iter().filter(|c| c.node == 3).count(), 16);
        let (_, tp) = s.organizations(&cores);
        assert_eq!(tp.n_groups(), 1); // no subgraphs in llama.cpp
        assert_eq!(s.sync(), SyncMode::SyncA);
    }

    #[test]
    fn isolate_uses_node0_only() {
        let topo = Topology::kunpeng920();
        let cores = Strategy::llama_isolate().bind_cores(&topo, 48);
        assert!(cores.iter().all(|c| c.node == 0));
    }

    #[test]
    fn build_specs_differ_in_placement() {
        use crate::numa::Placement;
        let arc = Strategy::arclight_single().build_spec(ModelConfig::tiny(), 4);
        let llama = Strategy::llama_isolate().build_spec(ModelConfig::tiny(), 4);
        assert_eq!(arc.act_placement, Placement::Node(0));
        assert_eq!(llama.act_placement, Placement::Interleaved(4));
    }
}
