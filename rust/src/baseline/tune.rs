//! Strategy auto-selection over the virtual-time cost model.
//!
//! With a *measured* bandwidth matrix lowered into the topology
//! (`hw::bench`), `SimExecutor`'s virtual time is trustworthy enough
//! to rank strategies — so instead of asking the user to guess a TP
//! width, `--strategy auto` enumerates the candidate space the paper
//! explores by hand (tensor-parallel width × Sync A/B discipline ×
//! node-window placement), costs one representative decode step per
//! candidate through the exact graph-build + binding path the engine
//! would use, and picks the cheapest.
//!
//! The search is deliberately small and exhaustive: a machine has
//! single-digit NUMA nodes, so the candidate count is O(nodes²) and
//! each costing is one virtual-time pass over a `sim_only` graph (no
//! weight buffers are allocated). Determinism: the simulator's jitter
//! is hash-seeded, so equal inputs always pick the same winner.

use crate::model::{ModelConfig, ModelGraphs};
use crate::numa::Topology;
use crate::sched::{ExecParams, Executor, SyncMode};

use super::Strategy;

/// One costed point of the search space.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub strategy: Strategy,
    /// First NUMA node of the strategy's window.
    pub base_node: usize,
    /// Virtual time of one representative decode step, in µs.
    pub predicted_us: f64,
}

/// The tuner's verdict: the winner plus the full ranked field (for
/// `arclight topo` / debugging — the margins matter, not just the
/// argmin).
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: Candidate,
    /// Every feasible candidate, sorted cheapest-first.
    pub candidates: Vec<Candidate>,
}

/// Whether `s` can bind `threads` workers in the `[base, base+width)`
/// node window of `topo` — mirrors the assertions of
/// `Topology::bind_cores_at` so infeasible candidates are skipped
/// instead of panicking mid-search.
fn fits(topo: &Topology, s: &Strategy, threads: usize, base: usize) -> bool {
    let w = s.nodes_used();
    if base + w > topo.n_nodes() || threads < w {
        return false;
    }
    if w > 1 {
        // distributed binding puts ceil(threads/w) workers on each node
        threads.div_ceil(w) <= topo.cores_per_node
    } else {
        // isolate binding takes consecutive cores from the window start
        base * topo.cores_per_node + threads <= topo.n_cores()
    }
}

/// Virtual time (µs) of one representative decode step of `cfg` under
/// strategy `s` with `threads` workers based at node `base` — the same
/// `build_spec`/`bind_cores_at` path `frontend::Engine::build` takes,
/// so the tuner costs exactly what the engine would run.
pub fn predict_step_us(
    cfg: &ModelConfig,
    topo: &Topology,
    s: Strategy,
    threads: usize,
    base: usize,
) -> f64 {
    let spec = s
        .build_spec(cfg.clone(), topo.n_nodes())
        .with_sim_only(true)
        .with_base_node(base);
    let graphs = ModelGraphs::build(spec);
    let exec = s.sim_executor_at(topo, threads, base);
    // cost a mid-context step: attention traffic grows with position,
    // so position 0 would bias toward strategies that skimp on KV
    // bandwidth
    let pos = (cfg.max_seq / 2).clamp(1, cfg.max_seq.saturating_sub(1));
    let rep = exec.run(&graphs.decode, &ExecParams::dense(pos, 1));
    rep.elapsed * 1e6
}

/// Enumerate and cost every feasible strategy for `cfg` with `threads`
/// workers inside the node window `[base, base + window_nodes)`
/// (clamped to the machine), returning the cheapest. The window is the
/// whole machine for `run`/`serve`, or one replica's node group for
/// cluster serving. Candidates:
///
/// * single-node ArcLight at every window offset (threads may spill
///   past one node — that's the isolate shape);
/// * ArcLight TP at every width `2..=window` × {Sync B, Sync A} × every
///   in-window offset.
///
/// `Err` when nothing fits (more threads than the window has cores).
pub fn auto_select(
    cfg: &ModelConfig,
    topo: &Topology,
    threads: usize,
    base: usize,
    window_nodes: usize,
) -> Result<TuneResult, String> {
    let n = topo.n_nodes();
    if base >= n {
        return Err(format!("auto-tune window base {base} out of range (machine has {n} nodes)"));
    }
    let window = window_nodes.clamp(1, n - base);
    let mut candidates = Vec::new();
    for width in 1..=window {
        let strategies: &[Strategy] = if width == 1 {
            &[Strategy::ArcLight { nodes: 1, sync: SyncMode::SyncB }]
        } else {
            &[
                Strategy::ArcLight { nodes: width, sync: SyncMode::SyncB },
                Strategy::ArcLight { nodes: width, sync: SyncMode::SyncA },
            ]
        };
        for &s in strategies {
            for off in 0..=(window - width) {
                let b = base + off;
                if !fits(topo, &s, threads, b) {
                    continue;
                }
                let predicted_us = predict_step_us(cfg, topo, s, threads, b);
                candidates.push(Candidate { strategy: s, base_node: b, predicted_us });
            }
        }
    }
    if candidates.is_empty() {
        return Err(format!(
            "no strategy fits {threads} threads in nodes {base}..{} ({} cores/node)",
            base + window,
            topo.cores_per_node
        ));
    }
    candidates.sort_by(|a, b| {
        a.predicted_us
            .partial_cmp(&b.predicted_us)
            .expect("virtual times are finite")
    });
    Ok(TuneResult { best: candidates[0].clone(), candidates })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_respects_window_and_core_budget() {
        let topo = Topology::kunpeng920(); // 4 × 48
        let single = Strategy::arclight_single();
        let tp2 = Strategy::arclight_tp(2, SyncMode::SyncB);
        assert!(fits(&topo, &single, 48, 0));
        assert!(fits(&topo, &single, 96, 2)); // spills node 2 → 3
        assert!(!fits(&topo, &single, 97, 2)); // past the machine
        assert!(fits(&topo, &tp2, 96, 2));
        assert!(!fits(&topo, &tp2, 96, 3)); // window past the machine
        assert!(!fits(&topo, &tp2, 98, 0)); // 49 > cores_per_node
        assert!(!fits(&topo, &tp2, 1, 0)); // fewer threads than nodes
    }

    #[test]
    fn auto_select_enumerates_and_ranks() {
        let cfg = ModelConfig::tiny();
        let topo = Topology::kunpeng920();
        let t = auto_select(&cfg, &topo, 8, 0, 4).unwrap();
        // widths 1..=4 at every offset: 4 + 3·2 + 2·2 + 1·2 = 16
        assert_eq!(t.candidates.len(), 16);
        // ranked cheapest-first, winner at the head
        assert!(t.candidates.windows(2).all(|w| w[0].predicted_us <= w[1].predicted_us));
        assert_eq!(t.best.strategy.name(), t.candidates[0].strategy.name());
        assert!(t.best.predicted_us.is_finite() && t.best.predicted_us > 0.0);
        // deterministic: same inputs, same winner and same cost
        let again = auto_select(&cfg, &topo, 8, 0, 4).unwrap();
        assert_eq!(again.best.strategy.name(), t.best.strategy.name());
        assert_eq!(again.best.predicted_us, t.best.predicted_us);
    }

    #[test]
    fn auto_select_honors_the_window() {
        let cfg = ModelConfig::tiny();
        let topo = Topology::kunpeng920();
        // a one-node window at node 2: only single-node offsets
        let t = auto_select(&cfg, &topo, 8, 2, 1).unwrap();
        assert_eq!(t.candidates.len(), 1);
        assert_eq!(t.best.base_node, 2);
        assert_eq!(t.best.strategy.nodes_used(), 1);
        // windows and bases out of range are errors, not panics
        assert!(auto_select(&cfg, &topo, 8, 4, 1).is_err());
        assert!(auto_select(&cfg, &topo, 10_000, 0, 4).is_err());
    }
}
