//! Drive the PJRT (L2) backend through the unified
//! [`Executor`](crate::sched::Executor) API.
//!
//! [`PjrtExecutor`] adapts [`PjrtSession`] — which executes the
//! AOT-lowered HLO over its own literals — to the same object-safe
//! `Executor` trait the native `RealExecutor` and `SimExecutor`
//! implement, so the golden cross-checks (`arclight golden`, the
//! golden integration tests) drive all three backends through one
//! code path instead of a PJRT-shaped side door.
//!
//! PJRT does not share the native engine's arena storage, so the graph
//! argument of `run` is not interpreted (the session executes its own
//! compiled program); tokens are staged with [`PjrtExecutor::feed`]
//! and logits read back with [`PjrtExecutor::logits`]. One `run` with
//! `params.rows > 1` executes the prefill entry point over that many
//! staged tokens; `rows == 1` decodes one staged token at the
//! session's KV cursor.
//!
//! Builds without the `pjrt` feature compile this against the stub
//! session, whose `load()` always errors — the executor then exists as
//! a type (the trait object keeps compiling everywhere) but can never
//! be constructed.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::frontend::Sampler;
use crate::graph::Graph;
use crate::sched::{ExecParams, Executor, StepReport};

use super::pjrt::{Literal, PjrtSession};

/// KV cursor + staged token/logit state of the driven session.
struct DriveState {
    pending: VecDeque<i32>,
    pos: usize,
    kv: Option<(Literal, Literal)>,
    logits: Vec<f32>,
}

/// The PJRT backend behind the `Executor` trait (golden/diagnostic
/// path — backend failures panic rather than corrupting the
/// comparison).
pub struct PjrtExecutor {
    pub session: PjrtSession,
    state: Mutex<DriveState>,
}

impl PjrtExecutor {
    /// Load artifacts and compile the session. Fails when the
    /// artifacts are absent or the build carries only the stub session
    /// (no `pjrt` feature / no real bindings).
    pub fn load(artifacts_dir: &Path) -> Result<PjrtExecutor> {
        Ok(PjrtExecutor {
            session: PjrtSession::load(artifacts_dir)?,
            state: Mutex::new(DriveState {
                pending: VecDeque::new(),
                pos: 0,
                kv: None,
                logits: Vec::new(),
            }),
        })
    }

    /// Stage tokens for the next pass(es).
    pub fn feed(&self, tokens: &[i32]) {
        self.state.lock().unwrap().pending.extend(tokens.iter().copied());
    }

    /// Logits produced by the most recent pass.
    pub fn logits(&self) -> Vec<f32> {
        self.state.lock().unwrap().logits.clone()
    }

    /// KV positions ingested so far.
    pub fn position(&self) -> usize {
        self.state.lock().unwrap().pos
    }

    /// Greedy generation routed through the `Executor` trait: one
    /// prefill pass over `prompt`, then `max_new` argmax-sampled
    /// decode passes. The shared drive loop behind `arclight golden`
    /// and the golden integration tests (the trait-level mirror of
    /// `PjrtSession::generate`), so the CLI check and the test suite
    /// can never drift apart in drive semantics.
    pub fn generate_greedy(&self, graph: &Arc<Graph>, prompt: &[i32], max_new: usize) -> Vec<i32> {
        let backend: &dyn Executor = self;
        let greedy = Sampler::greedy();
        self.feed(prompt);
        backend.run(graph, &ExecParams::dense(0, prompt.len()));
        let mut logits = self.logits();
        let mut out = Vec::with_capacity(max_new);
        for step in 0..max_new {
            let next = greedy.sample(&logits, step);
            out.push(next);
            if step + 1 < max_new {
                self.feed(&[next]);
                backend.run(graph, &ExecParams::dense(prompt.len() + step, 1));
                logits = self.logits();
            }
        }
        out
    }
}

impl Executor for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// One pass over the compiled HLO; `elapsed` is host wall-clock
    /// seconds. The first pass whose `rows` equals the manifest's
    /// prompt length runs the prefill entry point (so a 1-token prompt
    /// still exercises the prefill HLO); every other pass decodes one
    /// staged token. Panics when no token was staged or the PJRT
    /// backend errors — this is the golden path, not a serving path.
    fn run(&self, _graph: &Arc<Graph>, params: &ExecParams) -> StepReport {
        let t0 = Instant::now();
        let mut st = self.state.lock().unwrap();
        let prompt_len = self.session.manifest.prompt_len;
        if params.rows > 1 || (st.pos == 0 && params.rows == prompt_len) {
            assert_eq!(st.pos, 0, "PJRT prefill must be the first pass");
            assert_eq!(
                params.rows,
                prompt_len,
                "PJRT prefill is compiled for a fixed prompt length"
            );
            assert!(
                st.pending.len() >= params.rows,
                "only {} of {} prefill tokens staged (PjrtExecutor::feed)",
                st.pending.len(),
                params.rows
            );
            let toks: Vec<i32> = st.pending.drain(..params.rows).collect();
            let (logits, k, v) = self.session.run_prefill(&toks).expect("pjrt prefill");
            st.kv = Some((k, v));
            st.pos = params.rows;
            st.logits = logits;
        } else {
            let tok = st.pending.pop_front().expect("no token staged (PjrtExecutor::feed)");
            // first decode without a prefill starts from empty caches
            let (k, v) =
                st.kv.take().unwrap_or_else(|| self.session.empty_kv().expect("pjrt kv init"));
            let pos = st.pos as i32;
            let (logits, k2, v2) = self.session.run_decode(tok, pos, &k, &v).expect("pjrt decode");
            st.kv = Some((k2, v2));
            st.pos += 1;
            st.logits = logits;
        }
        StepReport {
            elapsed: t0.elapsed().as_secs_f64(),
            ops: 1,
            unit_counts: Vec::new(),
            // one device execution per pass — the PJRT analogue of the
            // native backends' single pool dispatch
            dispatches: 1,
            // the HLO is AOT-compiled; there is no per-pass plan to cache
            plan_cached: false,
            // native SIMD tiers don't apply to XLA-compiled execution
            tier: crate::simd::KernelTier::Scalar,
            sim: None,
            // strategy/bandwidth provenance is engine-stamped
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Compile-time proof the PJRT backend is usable as a trait
    /// object alongside the native executors.
    fn _assert_object_safe(ex: &PjrtExecutor) -> &dyn Executor {
        ex
    }

    #[test]
    fn load_without_artifacts_fails_cleanly_through_the_trait_type() {
        // Under the default build this exercises the stub session
        // ("pjrt feature disabled"); under `--features pjrt` with the
        // vendored shim it exercises the missing-artifacts /
        // shim-bindings error. Either way the unified backend type
        // reports a clear error instead of pretending to execute.
        let err = match PjrtExecutor::load(Path::new("does-not-exist-artifacts")) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("PjrtExecutor loaded without artifacts"),
        };
        assert!(!err.is_empty());
    }
}
