//! PJRT session: compile HLO text once, run decode/prefill as functions
//! over literals.
//!
//! Interchange is HLO *text* (see `aot.py` / DESIGN.md): jax ≥ 0.5
//! serializes HloModuleProto with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{ElementType, PjRtClient, PjRtLoadedExecutable};

pub use xla::Literal;

use crate::model::AlfFile;
use crate::quant::dequantize_row_q4_0;
use crate::tensor::DType;
use crate::util::f16_to_f32;

use super::artifacts::Manifest;

/// A compiled entry point plus the pre-built weight literals it takes.
pub struct PjrtModel {
    exe: PjRtLoadedExecutable,
    /// Literals for every *weight* argument, in positional order.
    weight_args: Vec<Literal>,
    /// Names of the trailing runtime arguments, in order.
    pub runtime_args: Vec<String>,
}

/// The PJRT CPU session: client + decode/prefill models.
pub struct PjrtSession {
    pub manifest: Manifest,
    pub decode: PjrtModel,
    pub prefill: PjrtModel,
    pub kv_shape: Vec<usize>,
}

impl PjrtSession {
    /// Load artifacts (manifest + HLO text + ALF weights) and compile.
    pub fn load(artifacts_dir: &Path) -> Result<PjrtSession> {
        let manifest = Manifest::load(artifacts_dir)?;
        let alf = AlfFile::open(&manifest.weights_file)?;
        let client = PjRtClient::cpu().context("PJRT CPU client")?;

        let build = |ep: &super::artifacts::EntryPoint| -> Result<PjrtModel> {
            let proto = xla::HloModuleProto::from_text_file(
                ep.hlo_path.to_str().context("hlo path")?,
            )
            .with_context(|| format!("parsing {}", ep.hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).context("PJRT compile")?;

            let mut weight_args = Vec::new();
            let mut runtime_args = Vec::new();
            for (spec, is_u8) in &ep.args {
                if is_runtime_arg(&spec.name) {
                    runtime_args.push(spec.name.clone());
                    continue;
                }
                if !runtime_args.is_empty() {
                    bail!("weight arg '{}' after runtime args", spec.name);
                }
                weight_args.push(weight_literal(&alf, &spec.name, &spec.shape, *is_u8)?);
            }
            Ok(PjrtModel { exe, weight_args, runtime_args })
        };

        let decode = build(&manifest.decode)?;
        let prefill = build(&manifest.prefill)?;
        let cfg = &manifest.config;
        let kv_shape = vec![
            cfg.get("n_layers").and_then(crate::util::json::Json::as_usize).unwrap_or(2),
            cfg.get("n_kv_heads").and_then(crate::util::json::Json::as_usize).unwrap_or(2),
            cfg.get("max_seq").and_then(crate::util::json::Json::as_usize).unwrap_or(64),
            cfg.get("head_dim").and_then(crate::util::json::Json::as_usize).unwrap_or(16),
        ];
        Ok(PjrtSession { manifest, decode, prefill, kv_shape })
    }

    /// Run prefill: tokens → (logits, k_caches, v_caches).
    pub fn run_prefill(&self, tokens: &[i32]) -> Result<(Vec<f32>, Literal, Literal)> {
        if tokens.len() != self.manifest.prompt_len {
            bail!("prefill expects exactly {} tokens", self.manifest.prompt_len);
        }
        let toks = Literal::vec1(tokens);
        let mut args: Vec<&Literal> = self.prefill.weight_args.iter().collect();
        args.push(&toks);
        let out = self.prefill.exe.execute::<&Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let parts = out.to_tuple()?;
        unpack_outputs(parts)
    }

    /// Run one decode step: (token, pos, caches) → (logits, caches).
    pub fn run_decode(
        &self,
        token: i32,
        pos: i32,
        k: &Literal,
        v: &Literal,
    ) -> Result<(Vec<f32>, Literal, Literal)> {
        let tok = Literal::scalar(token);
        let pos = Literal::scalar(pos);
        let mut args: Vec<&Literal> = self.decode.weight_args.iter().collect();
        args.push(&tok);
        args.push(&pos);
        args.push(k);
        args.push(v);
        let out = self.decode.exe.execute::<&Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let parts = out.to_tuple()?;
        unpack_outputs(parts)
    }

    /// Zero-filled KV cache literals.
    pub fn empty_kv(&self) -> Result<(Literal, Literal)> {
        let n: usize = self.kv_shape.iter().product();
        let zeros = vec![0u8; n * 4];
        let k =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &self.kv_shape, &zeros)?;
        let v =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &self.kv_shape, &zeros)?;
        Ok((k, v))
    }

    /// Full autoregressive generation through PJRT (golden reference).
    pub fn generate(&self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let (mut logits, mut k, mut v) = self.run_prefill(prompt)?;
        let mut pos = prompt.len() as i32;
        let mut out = Vec::new();
        for _ in 0..max_new {
            let next = argmax(&logits) as i32;
            out.push(next);
            let (l2, k2, v2) = self.run_decode(next, pos, &k, &v)?;
            logits = l2;
            k = k2;
            v = v2;
            pos += 1;
        }
        Ok(out)
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

fn is_runtime_arg(name: &str) -> bool {
    matches!(name, "token" | "pos" | "tokens" | "k_caches" | "v_caches")
}

fn unpack_outputs(mut parts: Vec<Literal>) -> Result<(Vec<f32>, Literal, Literal)> {
    if parts.len() != 3 {
        bail!("expected 3 outputs, got {}", parts.len());
    }
    let v = parts.pop().unwrap();
    let k = parts.pop().unwrap();
    let logits = parts.pop().unwrap().to_vec::<f32>()?;
    Ok((logits, k, v))
}

/// Build the literal for one weight argument from the ALF file.
///
/// Manifest arg names map onto ALF tensors: `layers.0.wq.qs` /
/// `layers.0.wq.d` are the packed-nibble and scale views of the Q4_0
/// tensor `layers.0.wq`; everything else is a raw f32 tensor.
fn weight_literal(alf: &AlfFile, name: &str, shape: &[usize], is_u8: bool) -> Result<Literal> {
    if let Some(base) = name.strip_suffix(".qs") {
        let t = alf.tensor(base)?;
        let raw = alf.payload(t);
        // extract the 16 nibble bytes of each 18-byte block
        let mut qs = Vec::with_capacity(raw.len() / 18 * 16);
        for block in raw.chunks_exact(18) {
            qs.extend_from_slice(&block[2..]);
        }
        if !is_u8 {
            bail!("{name}: expected u8");
        }
        return Ok(Literal::create_from_shape_and_untyped_data(ElementType::U8, shape, &qs)?);
    }
    if let Some(base) = name.strip_suffix(".d") {
        let t = alf.tensor(base)?;
        let raw = alf.payload(t);
        // f16 scale of each block, widened to f32 (matching the python
        // side's d.astype(np.float32))
        let mut d = Vec::with_capacity(raw.len() / 18);
        for block in raw.chunks_exact(18) {
            d.push(f16_to_f32(u16::from_le_bytes([block[0], block[1]])));
        }
        let bytes: Vec<u8> = d.iter().flat_map(|x| x.to_le_bytes()).collect();
        return Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, &bytes)?);
    }
    let t = alf.tensor(name)?;
    match t.dtype {
        DType::F32 => Ok(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            shape,
            alf.payload(t),
        )?),
        DType::Q4_0 => {
            // fully dequantized fallback (unused by the current manifest)
            let k = crate::tensor::row_len(&t.shape);
            let n = crate::tensor::rows(&t.shape);
            let mut out = vec![0.0f32; n * k];
            for r in 0..n {
                dequantize_row_q4_0(alf.rows(t, r, r + 1), &mut out[r * k..(r + 1) * k]);
            }
            let bytes: Vec<u8> = out.iter().flat_map(|x| x.to_le_bytes()).collect();
            Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, &bytes)?)
        }
        other => bail!("unsupported ALF dtype {other} for '{name}'"),
    }
}
