//! Stub PJRT session compiled when the `pjrt` cargo feature is off.
//!
//! Mirrors the public surface of the real [`super::pjrt`]
//! (`PjrtSession`, `PjrtModel`, `Literal`) so every consumer — the
//! golden tests, `arclight golden`, `serve_batch` — compiles
//! unchanged. `load()` always fails with a clear message; since every
//! other method is only reachable through a loaded session, the
//! `unreachable!`s cannot fire.

use std::path::Path;

use anyhow::{bail, Result};

use super::artifacts::Manifest;

/// Placeholder for `xla::Literal`.
pub struct Literal;

/// Placeholder for the compiled entry point.
pub struct PjrtModel;

/// Stub session: carries the manifest type for API parity but can
/// never be constructed.
pub struct PjrtSession {
    pub manifest: Manifest,
    pub decode: PjrtModel,
    pub prefill: PjrtModel,
    pub kv_shape: Vec<usize>,
}

impl PjrtSession {
    /// Always fails: the binary was built without the `pjrt` feature.
    pub fn load(artifacts_dir: &Path) -> Result<PjrtSession> {
        bail!(
            "PJRT runtime unavailable: this build has the `pjrt` cargo feature disabled \
             (artifacts dir: {}). Rebuild with `--features pjrt` in an environment that \
             vendors the `xla` crate — see rust/README.md.",
            artifacts_dir.display()
        );
    }

    pub fn run_prefill(&self, _tokens: &[i32]) -> Result<(Vec<f32>, Literal, Literal)> {
        unreachable!("stub PjrtSession cannot be constructed");
    }

    pub fn run_decode(
        &self,
        _token: i32,
        _pos: i32,
        _k: &Literal,
        _v: &Literal,
    ) -> Result<(Vec<f32>, Literal, Literal)> {
        unreachable!("stub PjrtSession cannot be constructed");
    }

    pub fn empty_kv(&self) -> Result<(Literal, Literal)> {
        unreachable!("stub PjrtSession cannot be constructed");
    }

    pub fn generate(&self, _prompt: &[i32], _max_new: usize) -> Result<Vec<i32>> {
        unreachable!("stub PjrtSession cannot be constructed");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        // no unwrap_err(): PjrtSession has no Debug impl
        let Err(err) = PjrtSession::load(Path::new("artifacts")) else {
            panic!("stub load unexpectedly succeeded");
        };
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
