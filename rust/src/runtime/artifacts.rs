//! AOT artifact manifest (`artifacts/manifest.json`).
//!
//! The manifest records the exact flattened argument order of each HLO
//! entry point (jax flattens the parameter pytree in sorted-key order)
//! so the runtime can assemble PJRT literals positionally from the ALF
//! weight file.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::tensor::DType;
use crate::util::json::Json;

/// One argument of an HLO entry point.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    fn from_json(j: &Json) -> Result<ArgSpec> {
        let name = j.get("name").and_then(Json::as_str).context("arg name")?.to_string();
        let dt = j.get("dtype").and_then(Json::as_str).context("arg dtype")?;
        let dtype = match dt {
            "u8" => DType::I32, // placeholder — u8 handled specially by the loader
            other => DType::parse(other).with_context(|| format!("dtype {other}"))?,
        };
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("arg shape")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        Ok(ArgSpec { name, dtype, shape })
    }

    /// The raw dtype string (the manifest distinguishes u8 from i32).
    pub fn is_u8(j: &Json) -> bool {
        j.get("dtype").and_then(Json::as_str) == Some("u8")
    }
}

/// One entry point: ordered args + outputs.
#[derive(Clone, Debug)]
pub struct EntryPoint {
    pub args: Vec<(ArgSpec, bool)>, // (spec, is_u8)
    pub hlo_path: PathBuf,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: Json,
    pub weights_file: PathBuf,
    pub prompt_len: usize,
    pub decode: EntryPoint,
    pub prefill: EntryPoint,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let entry = |key: &str, file: &str| -> Result<EntryPoint> {
            let args = j
                .get(key)
                .and_then(|d| d.get("args"))
                .and_then(Json::as_arr)
                .with_context(|| format!("{key}.args"))?
                .iter()
                .map(|a| Ok((ArgSpec::from_json(a)?, ArgSpec::is_u8(a))))
                .collect::<Result<Vec<_>>>()?;
            Ok(EntryPoint { args, hlo_path: dir.join(file) })
        };
        Ok(Manifest {
            config: j.get("config").cloned().context("config")?,
            weights_file: dir.join(
                j.get("weights_file").and_then(Json::as_str).unwrap_or("tiny.alf"),
            ),
            prompt_len: j.get("prompt_len").and_then(Json::as_usize).unwrap_or(16),
            decode: entry("decode", "decode.hlo.txt")?,
            prefill: entry("prefill", "prefill.hlo.txt")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.prompt_len, 16);
        // decode args: weights… + token, pos, k_caches, v_caches
        let names: Vec<&str> = m.decode.args.iter().map(|(a, _)| a.name.as_str()).collect();
        assert!(names.contains(&"token"));
        assert!(names.contains(&"pos"));
        assert!(names.last() == Some(&"v_caches"));
        // weight args appear before runtime args (pytree order)
        let tok_idx = names.iter().position(|n| *n == "token").unwrap();
        assert!(names[..tok_idx].iter().any(|n| n.starts_with("layers.0.")));
    }
}
