//! PJRT runtime: load the AOT-compiled L2 artifacts and execute them
//! from Rust (the L3↔L2 bridge).
//!
//! `python/compile/aot.py` lowers the JAX model (which calls the L1
//! Pallas kernels) to HLO **text**; this module compiles that text on
//! the PJRT CPU client and feeds it weights/caches/tokens as literals.
//! Python never runs at serving time. The golden integration tests
//! compare the native engine against this path on identical ALF bytes.

pub mod artifacts;
pub mod exec;

/// The real PJRT bridge binds to the vendored `xla` (xla_extension)
/// crate, which only the fully-vendored evaluation environment ships.
/// Default builds compile an API-identical stub whose `load()` returns
/// an error, so the golden integration tests skip cleanly when the
/// artifacts (or the feature) are absent.
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{ArgSpec, Manifest};
pub use exec::PjrtExecutor;
pub use pjrt::{PjrtModel, PjrtSession};
