//! Block quantization — ggml-compatible Q4_0 / Q8_0 (paper §4 runs
//! Qwen3-4B in Q4_0 with a Q4_0 KV cache).
//!
//! Layouts are byte-identical with llama.cpp and with the Python writer
//! (`python/compile/quantize.py`):
//!
//! * **Q4_0** — 32 elements → 18 bytes: little-endian f16 scale `d`,
//!   then 16 bytes where byte `i` packs element `i` (low nibble) and
//!   element `i+16` (high nibble); `x[i] = (q[i] - 8) * d`.
//! * **Q8_0** — 32 elements → 34 bytes: f16 scale then 32 signed bytes;
//!   `x[i] = q[i] * d`.
//!
//! The quantization rule mirrors `quantize_row_q4_0`: the scale comes
//! from the *signed* value with the largest magnitude (`d = max / -8`),
//! keeping the asymmetric [-8, 7] codebook anchored on the dominant
//! sign.
//!
//! The scalar kernels here are the **parity oracles** for the SIMD
//! tiers in [`crate::simd`]: every vectorized Q4_0/Q8_0 dot is tested
//! against these implementations (see `tests/simd_parity.rs` and
//! `rust/KERNELS.md` for the tolerance policy).

// every public item in the quantization ABI must state its contract —
// the byte layouts here are load-bearing for llama.cpp compatibility
#![deny(missing_docs)]

use crate::tensor::dtype::{Q4_0_BLOCK_BYTES, Q8_0_BLOCK_BYTES, QK4_0, QK8_0};
use crate::util::{f16_to_f32, f32_to_f16};

/// Quantize one row (`k % 32 == 0`) into a Q4_0 byte stream appended to
/// `out`. Matches the Python `quantize_q4_0` bit-for-bit.
pub fn quantize_row_q4_0(x: &[f32], out: &mut Vec<u8>) {
    assert!(x.len() % QK4_0 == 0, "row length {} not a multiple of 32", x.len());
    for block in x.chunks_exact(QK4_0) {
        // signed max-|x| value
        let mut maxv = 0.0f32;
        let mut amax = 0.0f32;
        for &v in block {
            if v.abs() > amax {
                amax = v.abs();
                maxv = v;
            }
        }
        let d = maxv / -8.0;
        let d16 = f32_to_f16(d);
        // quantize against the f16-rounded scale, matching the python
        // reference (which uses f16→f32 of d for the inverse)
        let d_used = f16_to_f32(d16);
        let id = if d_used != 0.0 { 1.0 / d_used } else { 0.0 };
        out.extend_from_slice(&d16.to_le_bytes());
        for i in 0..16 {
            let q = |v: f32| -> u8 { (v * id + 8.5).clamp(0.0, 15.0) as u8 };
            let lo = q(block[i]);
            let hi = q(block[i + 16]);
            out.push(lo | (hi << 4));
        }
    }
}

/// Dequantize a Q4_0 byte stream into `out` (f32), one block per 18 bytes.
pub fn dequantize_row_q4_0(raw: &[u8], out: &mut [f32]) {
    assert_eq!(raw.len() % Q4_0_BLOCK_BYTES, 0);
    assert_eq!(out.len(), raw.len() / Q4_0_BLOCK_BYTES * QK4_0);
    for (bi, block) in raw.chunks_exact(Q4_0_BLOCK_BYTES).enumerate() {
        let d = f16_to_f32(u16::from_le_bytes([block[0], block[1]]));
        let dst = &mut out[bi * QK4_0..(bi + 1) * QK4_0];
        for i in 0..16 {
            let b = block[2 + i];
            dst[i] = ((b & 0x0F) as i32 - 8) as f32 * d;
            dst[i + 16] = ((b >> 4) as i32 - 8) as f32 * d;
        }
    }
}

/// Dot product of a Q4_0 row with an f32 activation — the decode GEMV
/// inner loop. Reads each quantized byte exactly once (the paper's
/// bandwidth-bound hot path); block-wise FMA accumulation in f32.
#[inline]
pub fn dot_q4_0_f32(raw: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(raw.len() % Q4_0_BLOCK_BYTES, 0);
    debug_assert_eq!(x.len(), raw.len() / Q4_0_BLOCK_BYTES * QK4_0);
    let mut acc = 0.0f32;
    for (block, xb) in raw.chunks_exact(Q4_0_BLOCK_BYTES).zip(x.chunks_exact(QK4_0)) {
        let d = f16_to_f32(u16::from_le_bytes([block[0], block[1]]));
        let xsum: f32 = xb.iter().sum();
        acc += (dot_block_q4(block, xb) - 8.0 * xsum) * d;
    }
    acc
}

/// Per-block sums of an activation row (`Σ x` over each 32-element
/// block). Computed once per GEMV row and shared across all weight rows
/// by [`dot_q4_0_f32_presum`] — hoisting the `-8·Σx` bias correction
/// out of the N-row loop (§Perf optimization 1).
pub fn block_sums_q4_0(x: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend(x.chunks_exact(QK4_0).map(|b| b.iter().sum::<f32>()));
}

/// [`dot_q4_0_f32`] with precomputed block sums (the GEMM fast path).
#[inline]
pub fn dot_q4_0_f32_presum(raw: &[u8], x: &[f32], xsums: &[f32]) -> f32 {
    debug_assert_eq!(raw.len() % Q4_0_BLOCK_BYTES, 0);
    debug_assert_eq!(xsums.len(), raw.len() / Q4_0_BLOCK_BYTES);
    let mut acc = 0.0f32;
    for ((block, xb), &xsum) in raw
        .chunks_exact(Q4_0_BLOCK_BYTES)
        .zip(x.chunks_exact(QK4_0))
        .zip(xsums)
    {
        let d = f16_to_f32(u16::from_le_bytes([block[0], block[1]]));
        acc += (dot_block_q4(block, xb) - 8.0 * xsum) * d;
    }
    acc
}

/// Unbiased nibble·x contraction of one 18-byte block against 32
/// activations: `Σ q_lo[i]·x[i] + Σ q_hi[i]·x[i+16]` with four
/// accumulators and fixed-size views (bounds-check free, keeps the
/// auto-vectorizer fed).
#[inline(always)]
fn dot_block_q4(block: &[u8], xb: &[f32]) -> f32 {
    let qs: &[u8; 16] = block[2..18].try_into().unwrap();
    let x0: &[f32; 16] = xb[..16].try_into().unwrap();
    let x1: &[f32; 16] = xb[16..32].try_into().unwrap();
    let mut s = [0.0f32; 4];
    for i in 0..4 {
        let j = i * 4;
        s[0] += (qs[j] & 0x0F) as f32 * x0[j] + (qs[j] >> 4) as f32 * x1[j];
        s[1] += (qs[j + 1] & 0x0F) as f32 * x0[j + 1] + (qs[j + 1] >> 4) as f32 * x1[j + 1];
        s[2] += (qs[j + 2] & 0x0F) as f32 * x0[j + 2] + (qs[j + 2] >> 4) as f32 * x1[j + 2];
        s[3] += (qs[j + 3] & 0x0F) as f32 * x0[j + 3] + (qs[j + 3] >> 4) as f32 * x1[j + 3];
    }
    (s[0] + s[1]) + (s[2] + s[3])
}

/// Quantize one row into Q8_0 (used for the quantized KV-cache path).
pub fn quantize_row_q8_0(x: &[f32], out: &mut Vec<u8>) {
    assert!(x.len() % QK8_0 == 0);
    for block in x.chunks_exact(QK8_0) {
        let amax = block.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let d = amax / 127.0;
        let d16 = f32_to_f16(d);
        let d_used = f16_to_f32(d16);
        let id = if d_used != 0.0 { 1.0 / d_used } else { 0.0 };
        out.extend_from_slice(&d16.to_le_bytes());
        for &v in block {
            out.push((v * id).round().clamp(-127.0, 127.0) as i8 as u8);
        }
    }
}

/// Dequantize a Q8_0 byte stream.
pub fn dequantize_row_q8_0(raw: &[u8], out: &mut [f32]) {
    assert_eq!(raw.len() % Q8_0_BLOCK_BYTES, 0);
    assert_eq!(out.len(), raw.len() / Q8_0_BLOCK_BYTES * QK8_0);
    for (bi, block) in raw.chunks_exact(Q8_0_BLOCK_BYTES).enumerate() {
        let d = f16_to_f32(u16::from_le_bytes([block[0], block[1]]));
        let dst = &mut out[bi * QK8_0..(bi + 1) * QK8_0];
        for i in 0..QK8_0 {
            dst[i] = (block[2 + i] as i8) as f32 * d;
        }
    }
}

/// Dot product of a Q8_0 row with f32 activations.
#[inline]
pub fn dot_q8_0_f32(raw: &[u8], x: &[f32]) -> f32 {
    debug_assert_eq!(raw.len() % Q8_0_BLOCK_BYTES, 0);
    let mut acc = 0.0f32;
    for (bi, block) in raw.chunks_exact(Q8_0_BLOCK_BYTES).enumerate() {
        let d = f16_to_f32(u16::from_le_bytes([block[0], block[1]]));
        let xb = &x[bi * QK8_0..(bi + 1) * QK8_0];
        let mut s = 0.0f32;
        for i in 0..QK8_0 {
            s += (block[2 + i] as i8) as f32 * xb[i];
        }
        acc += s * d;
    }
    acc
}

/// Quantize a whole [n, k] matrix row-wise into a Q4_0 stream.
pub fn quantize_matrix_q4_0(w: &[f32], n: usize, k: usize) -> Vec<u8> {
    assert_eq!(w.len(), n * k);
    let mut out = Vec::with_capacity(n * k / QK4_0 * Q4_0_BLOCK_BYTES);
    for row in w.chunks_exact(k) {
        quantize_row_q4_0(row, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_row(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, scale);
        v
    }

    #[test]
    fn q4_roundtrip_error_bound() {
        // worst case one full step (asymmetric codebook) + f16 slack
        for seed in 0..8 {
            let x = rand_row(256, seed, 1.0);
            let mut raw = Vec::new();
            quantize_row_q4_0(&x, &mut raw);
            let mut y = vec![0.0; 256];
            dequantize_row_q4_0(&raw, &mut y);
            for (bi, block) in x.chunks_exact(32).enumerate() {
                let d = f16_to_f32(u16::from_le_bytes([raw[bi * 18], raw[bi * 18 + 1]])).abs();
                for (i, &v) in block.iter().enumerate() {
                    let err = (v - y[bi * 32 + i]).abs();
                    assert!(err <= d * 1.0 + d * 1e-2 + 1e-6, "err {err} vs step {d}");
                }
            }
        }
    }

    #[test]
    fn q4_known_block() {
        // max magnitude -16 at position 5 → d = 2.0, that element → nibble 0
        let mut x = vec![0.0f32; 32];
        x[5] = -16.0;
        let mut raw = Vec::new();
        quantize_row_q4_0(&x, &mut raw);
        let d = f16_to_f32(u16::from_le_bytes([raw[0], raw[1]]));
        assert_eq!(d, 2.0);
        let mut y = vec![0.0; 32];
        dequantize_row_q4_0(&raw, &mut y);
        assert_eq!(y[5], -16.0);
        assert_eq!(y[0], 0.0);
    }

    #[test]
    fn q4_zero_block() {
        let x = vec![0.0f32; 32];
        let mut raw = Vec::new();
        quantize_row_q4_0(&x, &mut raw);
        let mut y = vec![1.0; 32];
        dequantize_row_q4_0(&raw, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn q4_dot_matches_dequant_dot() {
        let w = rand_row(320, 3, 0.5);
        let x = rand_row(320, 4, 1.0);
        let mut raw = Vec::new();
        quantize_row_q4_0(&w, &mut raw);
        let mut wd = vec![0.0; 320];
        dequantize_row_q4_0(&raw, &mut wd);
        let expect: f32 = wd.iter().zip(&x).map(|(a, b)| a * b).sum();
        let got = dot_q4_0_f32(&raw, &x);
        assert!((expect - got).abs() <= 1e-4 * expect.abs().max(1.0), "{expect} vs {got}");
    }

    #[test]
    fn q8_roundtrip_tighter_than_q4() {
        let x = rand_row(128, 9, 1.0);
        let mut r4 = Vec::new();
        let mut r8 = Vec::new();
        quantize_row_q4_0(&x, &mut r4);
        quantize_row_q8_0(&x, &mut r8);
        let mut y4 = vec![0.0; 128];
        let mut y8 = vec![0.0; 128];
        dequantize_row_q4_0(&r4, &mut y4);
        dequantize_row_q8_0(&r8, &mut y8);
        let e4: f32 = x.iter().zip(&y4).map(|(a, b)| (a - b).abs()).sum();
        let e8: f32 = x.iter().zip(&y8).map(|(a, b)| (a - b).abs()).sum();
        assert!(e8 < e4 * 0.25, "q8 {e8} vs q4 {e4}");
    }

    #[test]
    fn q8_dot_matches() {
        let w = rand_row(64, 5, 1.0);
        let x = rand_row(64, 6, 1.0);
        let mut raw = Vec::new();
        quantize_row_q8_0(&w, &mut raw);
        let mut wd = vec![0.0; 64];
        dequantize_row_q8_0(&raw, &mut wd);
        let expect: f32 = wd.iter().zip(&x).map(|(a, b)| a * b).sum();
        let got = dot_q8_0_f32(&raw, &x);
        assert!((expect - got).abs() < 1e-4);
    }

    #[test]
    fn matrix_stream_is_row_major_blocks() {
        let k = 64;
        let w = rand_row(3 * k, 7, 1.0);
        let raw = quantize_matrix_q4_0(&w, 3, k);
        assert_eq!(raw.len(), 3 * 2 * 18);
        // row 1's stream equals quantizing row 1 alone
        let mut solo = Vec::new();
        quantize_row_q4_0(&w[k..2 * k], &mut solo);
        assert_eq!(&raw[36..72], &solo[..]);
    }

    #[test]
    fn sizes_match_dtype_math() {
        use crate::tensor::DType;
        let raw = quantize_matrix_q4_0(&vec![0.0; 8 * 96], 8, 96);
        assert_eq!(raw.len(), DType::Q4_0.tensor_bytes(&[8, 96]));
    }
}
