//! The `Kernel` abstraction: *what* an operator computes, separated
//! from *how* executors partition, cost and place the work — the seam
//! behind the paper's "compatibility with arbitrary CPU devices" (§1).
//!
//! One [`Kernel`] implementation exists per [`OpKind`] variant (matmul
//! further split by weight dtype — see [`super::kernels`]). A kernel
//! answers four questions about its operator:
//!
//! * [`Kernel::units`] — how many work units the operator partitions
//!   across its thread group (the §2.7 row policy: matmul partitions
//!   output features, attention/rope partition heads, element-wise ops
//!   partition flat elements);
//! * [`Kernel::cost`] — the analytic (FLOPs, bytes) profile of a unit
//!   range, the contract between real execution and the simulator;
//! * [`Kernel::traffic`] — the per-NUMA-node byte attribution of a
//!   unit range for the virtual-time cost model;
//! * [`Kernel::run`] — real execution of a unit range over the arena
//!   views of [`OpCtx`].
//!
//! Kernels are stateless singletons registered in [`KernelRegistry`]
//! and resolved **once per graph** at build time
//! ([`crate::graph::Graph::resolve_kernels`]); executors dispatch
//! through [`crate::graph::Graph::kernel`] and never match on
//! [`OpKind`] themselves.

use crate::graph::{Graph, OpKind, TensorMeta};
use crate::memory::MemoryPool;
use crate::numa::cost::Traffic;
use crate::ops::OpCost;
use crate::sched::ExecParams;
use crate::simd::KernelTier;
use crate::tensor::{DType, TensorId};

use super::kernels as k;

/// Execution context of one operator instance — the **only** place the
/// unsafe arena-view plumbing lives.
///
/// # Safety contract
///
/// The raw-pointer views returned by [`OpCtx::f32s_mut`] (and friends)
/// are sound because of two invariants upheld together:
///
/// 1. a kernel's `run(ctx, u0, u1)` writes only the output region its
///    unit range owns and treats every input as read-only;
/// 2. the executors hand concurrent workers **disjoint** unit ranges
///    via [`crate::util::chunk_range`] —
///    [`crate::sched::debug_check_partition`] asserts in debug builds
///    that those ranges are non-overlapping and tile `[0, units)`.
pub struct OpCtx<'a> {
    pub graph: &'a Graph,
    pub pool: &'a MemoryPool,
    /// The tensor whose producing operator is being executed.
    pub id: TensorId,
    pub params: &'a ExecParams,
}

impl<'a> OpCtx<'a> {
    /// Header of the output tensor.
    pub fn meta(&self) -> &'a TensorMeta {
        self.graph.meta(self.id)
    }

    /// The `i`-th source tensor of the operator.
    pub fn src(&self, i: usize) -> TensorId {
        self.meta().src[i]
    }

    /// Immutable f32 view of a tensor's whole buffer.
    ///
    /// # Safety
    /// No concurrent writer may overlap the range (see the type-level
    /// safety contract).
    pub unsafe fn f32s(&self, id: TensorId) -> &'a [f32] {
        let b = self.graph.buf(id);
        self.pool.arena(b.arena).f32s(b.off, b.len / 4)
    }

    /// Mutable f32 view of a tensor's whole buffer.
    ///
    /// # Safety
    /// The written region must be disjoint from every other live view
    /// (the unit partition guarantees this for well-behaved kernels).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn f32s_mut(&self, id: TensorId) -> &'a mut [f32] {
        let b = self.graph.buf(id);
        self.pool.arena(b.arena).f32s_mut(b.off, b.len / 4)
    }

    /// Immutable byte view (quantized weights).
    ///
    /// # Safety
    /// As [`OpCtx::f32s`].
    pub unsafe fn bytes(&self, id: TensorId) -> &'a [u8] {
        let b = self.graph.buf(id);
        self.pool.arena(b.arena).bytes(b.off, b.len)
    }

    /// Immutable i32 view (token buffers).
    ///
    /// # Safety
    /// As [`OpCtx::f32s`].
    pub unsafe fn i32s(&self, id: TensorId) -> &'a [i32] {
        let b = self.graph.buf(id);
        let raw = self.pool.arena(b.arena).bytes(b.off, b.len);
        std::slice::from_raw_parts(raw.as_ptr() as *const i32, raw.len() / 4)
    }
}

/// Simulator-side environment for one worker's traffic derivation.
#[derive(Clone, Copy, Debug)]
pub struct TrafficEnv {
    /// NUMA nodes on the simulated machine.
    pub n_nodes: usize,
    /// Workers on the same NUMA node executing this operator (shared
    /// activation streams amortize over them — see the matmul kernel).
    pub co_readers: usize,
    /// Cache-dedup amortization of broadcast reads at m = 1.
    pub bcast_amort: f64,
}

/// One operator implementation: unit policy, analytic profile, NUMA
/// traffic attribution and real execution. Implementations are
/// stateless singletons (op parameters ride in [`OpKind`]); resolution
/// happens once per graph through [`KernelRegistry::resolve`].
pub trait Kernel: Send + Sync {
    /// Short name for traces, reports and error messages.
    fn name(&self) -> &'static str;

    /// Work units this operator partitions across its thread group —
    /// the row policy of §2.7. Row counts come from tensor shapes,
    /// clamped to the pass's active rows so a partially-filled batch
    /// graph partitions correctly.
    fn units(&self, meta: &TensorMeta, params: &ExecParams) -> usize;

    /// Analytic resource profile of one worker computing units
    /// `[u0, u1)` — the contract between real execution and the
    /// virtual-time simulator.
    fn cost(
        &self,
        graph: &Graph,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
    ) -> OpCost;

    /// Per-NUMA-node byte/FLOP attribution of units `[u0, u1)`; node
    /// attribution comes from each source tensor's placement. Callers
    /// should prefer [`op_traffic`], which clips empty ranges.
    fn traffic(
        &self,
        graph: &Graph,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
        env: &TrafficEnv,
    ) -> Traffic;

    /// Execute units `[u0, u1)` for real.
    ///
    /// # Safety
    /// Caller must guarantee the [`OpCtx`] disjointness contract:
    /// concurrent invocations carry non-overlapping unit ranges, and
    /// `u0 <= u1 <= self.units(...)`.
    unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize);

    /// The SIMD tier this kernel's [`Kernel::run`] dispatches on.
    ///
    /// Vectorized kernels (matmul, rmsnorm, attention) override this to
    /// report the process-wide [`KernelTier::active`] tier; kernels
    /// with no vector path keep the default and report `Scalar`.
    fn tier(&self) -> KernelTier {
        KernelTier::Scalar
    }
}

impl std::fmt::Debug for dyn Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Kernel({})", self.name())
    }
}

/// Traffic of one worker computing units `[u0, u1)` of tensor `id`
/// (empty ranges yield empty traffic).
pub fn op_traffic(
    graph: &Graph,
    id: TensorId,
    params: &ExecParams,
    u0: usize,
    u1: usize,
    env: &TrafficEnv,
) -> Traffic {
    if u0 >= u1 {
        return Traffic::new(env.n_nodes);
    }
    graph.kernel(id).traffic(graph, id, params, u0, u1, env)
}

/// The kernel registry: maps an [`OpKind`] (plus the weight dtype for
/// matmul) to its singleton [`Kernel`]. Resolution is done once at
/// graph build; the hot path only sees resolved `&'static dyn Kernel`
/// references.
pub struct KernelRegistry(());

static REGISTRY: KernelRegistry = KernelRegistry(());

impl KernelRegistry {
    /// The process-wide registry.
    pub fn global() -> &'static KernelRegistry {
        &REGISTRY
    }

    /// Every registered kernel (completeness introspection for tests).
    pub fn kernels(&self) -> &'static [&'static dyn Kernel] {
        &k::ALL
    }

    /// Resolve the kernel for `op`. `weight_dtype` is the dtype of the
    /// operator's second source when it has one — only matmul keys on
    /// it (F32 / Q4_0 / Q8_0 variants).
    ///
    /// Panics on an unsupported combination (e.g. i32 matmul weights):
    /// graphs that cannot execute are rejected at build time, not
    /// mid-pass.
    pub fn resolve(&self, op: &OpKind, weight_dtype: Option<DType>) -> &'static dyn Kernel {
        match op {
            OpKind::Leaf => &k::LEAF,
            OpKind::Embed => &k::EMBED,
            OpKind::RmsNorm { .. } => &k::RMSNORM,
            OpKind::RmsNormHeads { .. } => &k::RMSNORM_HEADS,
            OpKind::MatMul => match weight_dtype {
                Some(DType::F32) => &k::MATMUL_F32,
                Some(DType::Q4_0) => &k::MATMUL_Q4_0,
                Some(DType::Q8_0) => &k::MATMUL_Q8_0,
                other => panic!("no matmul kernel for weight dtype {other:?}"),
            },
            OpKind::Rope { .. } => &k::ROPE,
            OpKind::StoreKv { .. } => &k::STORE_KV,
            OpKind::Attention { .. } => &k::ATTENTION,
            OpKind::Silu => &k::SILU,
            OpKind::Add => &k::ADD,
            OpKind::Mul => &k::MUL,
            OpKind::SwiGlu => &k::SWIGLU,
            OpKind::Copy => &k::COPY,
            OpKind::SliceRow { .. } => &k::SLICE_ROW,
            OpKind::AddN => &k::ADD_N,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lists_one_kernel_per_op_variant() {
        let reg = KernelRegistry::global();
        let names: std::collections::BTreeSet<&str> =
            reg.kernels().iter().map(|k| k.name()).collect();
        assert_eq!(names.len(), reg.kernels().len(), "duplicate kernel names");
        for n in ["embed", "matmul_q4_0", "attention", "add_n"] {
            assert!(names.contains(n), "missing kernel '{n}'");
        }
    }

    #[test]
    fn matmul_resolution_keys_on_weight_dtype() {
        let reg = KernelRegistry::global();
        assert_eq!(reg.resolve(&OpKind::MatMul, Some(DType::F32)).name(), "matmul_f32");
        assert_eq!(reg.resolve(&OpKind::MatMul, Some(DType::Q4_0)).name(), "matmul_q4_0");
        assert_eq!(reg.resolve(&OpKind::MatMul, Some(DType::Q8_0)).name(), "matmul_q8_0");
    }

    #[test]
    #[should_panic(expected = "no matmul kernel")]
    fn i32_matmul_weights_rejected_at_resolution() {
        KernelRegistry::global().resolve(&OpKind::MatMul, Some(DType::I32));
    }

    #[test]
    fn registry_resolves_tier_per_kernel() {
        // vectorized kernels report the process-wide active tier;
        // kernels without a vector path stay scalar
        let reg = KernelRegistry::global();
        let active = KernelTier::active();
        for op in [
            reg.resolve(&OpKind::MatMul, Some(DType::Q4_0)),
            reg.resolve(&OpKind::MatMul, Some(DType::F32)),
            reg.resolve(&OpKind::RmsNorm { eps: 1e-6 }, None),
            reg.resolve(
                &OpKind::Attention { heads: 2, kv_heads: 2, head_dim: 4, max_seq: 8 },
                None,
            ),
        ] {
            assert_eq!(op.tier(), active, "{} tier", op.name());
        }
        assert_eq!(reg.resolve(&OpKind::Leaf, None).tier(), KernelTier::Scalar);
        assert_eq!(reg.resolve(&OpKind::Add, None).tier(), KernelTier::Scalar);
    }
}
