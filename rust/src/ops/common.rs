//! Hardware-agnostic operators (paper §2.7: "organized in common.cpp").

/// Copy rows `[r0, r1)` of `src` ([rows, d]) into the same rows of `dst`.
pub fn copy_rows(src: &[f32], dst: &mut [f32], d: usize, r0: usize, r1: usize) {
    dst[r0 * d..r1 * d].copy_from_slice(&src[r0 * d..r1 * d]);
}

/// Embedding lookup: for tokens `[t0, t1)` copy `emb[token[t]]` into row
/// `t` of `out`. `emb` is [vocab, d] f32.
pub fn embed_rows(
    emb: &[f32],
    tokens: &[i32],
    out: &mut [f32],
    d: usize,
    t0: usize,
    t1: usize,
) {
    for t in t0..t1 {
        let tok = tokens[t] as usize;
        out[t * d..(t + 1) * d].copy_from_slice(&emb[tok * d..(tok + 1) * d]);
    }
}

/// Accumulate: `dst[i] += src[i]` over [e0, e1) — the Gather operator's
/// partial-sum reduction (§3.3: "collects and sums the output tensors
/// from all subgraphs").
pub fn accumulate(src: &[f32], dst: &mut [f32], e0: usize, e1: usize) {
    for i in e0..e1 {
        dst[i] += src[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_row_range() {
        let src = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut dst = vec![0.0; 6];
        copy_rows(&src, &mut dst, 2, 1, 3);
        assert_eq!(dst, vec![0.0, 0.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn embed_looks_up_rows() {
        let emb = vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]; // vocab 3, d 2
        let tokens = vec![2i32, 0, 1];
        let mut out = vec![9.0; 6];
        embed_rows(&emb, &tokens, &mut out, 2, 0, 3);
        assert_eq!(out, vec![2.0, 2.0, 0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn accumulate_sums() {
        let src = vec![1.0, 1.0, 1.0];
        let mut dst = vec![1.0, 2.0, 3.0];
        accumulate(&src, &mut dst, 0, 3);
        assert_eq!(dst, vec![2.0, 3.0, 4.0]);
        accumulate(&src, &mut dst, 1, 2);
        assert_eq!(dst, vec![2.0, 4.0, 4.0]);
    }
}
