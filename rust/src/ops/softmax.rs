//! Numerically-stable row softmax.
//!
//! The tier-dispatched variant vectorizes only the row max and the
//! final normalize multiply; the `exp` + running-sum loop stays scalar
//! on every tier, so `softmax_rows_t` is **bit-exact** across tiers
//! (max is exact, the multiply is per-element).

use crate::simd::{self, KernelTier};

/// Softmax rows `[r0, r1)` of `x` ([rows, n]) in place, over the first
/// `valid` entries of each row (entries beyond `valid` are forced to 0 —
/// the KV cache holds `max_seq` slots but only `kv_len` are live).
/// Scalar tier — the parity oracle for [`softmax_rows_t`].
pub fn softmax_rows(x: &mut [f32], n: usize, valid: usize, r0: usize, r1: usize) {
    softmax_rows_t(KernelTier::Scalar, x, n, valid, r0, r1);
}

/// [`softmax_rows`] with the row max and normalize steps dispatched on
/// `tier`. Bit-exact with the scalar kernel on every tier.
pub fn softmax_rows_t(
    tier: KernelTier,
    x: &mut [f32],
    n: usize,
    valid: usize,
    r0: usize,
    r1: usize,
) {
    debug_assert!(valid <= n);
    for r in r0..r1 {
        let row = &mut x[r * n..(r + 1) * n];
        let m = simd::max_f32(tier, &row[..valid]);
        let mut sum = 0.0;
        for v in row[..valid].iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = if sum > 0.0 { 1.0 / sum } else { 0.0 };
        simd::scale_inplace(tier, &mut row[..valid], inv);
        for v in row[valid..].iter_mut() {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        softmax_rows(&mut x, 4, 4, 0, 1);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x[3] > x[2] && x[2] > x[1]);
    }

    #[test]
    fn stable_for_large_values() {
        let mut x = vec![1000.0, 1001.0];
        softmax_rows(&mut x, 2, 2, 0, 1);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!((x[0] + x[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn masks_beyond_valid() {
        let mut x = vec![1.0, 1.0, 99.0, 99.0];
        softmax_rows(&mut x, 4, 2, 0, 1);
        assert_eq!(x[2], 0.0);
        assert_eq!(x[3], 0.0);
        assert!((x[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn row_range_respected() {
        let mut x = vec![1.0; 8];
        softmax_rows(&mut x, 4, 4, 1, 2);
        assert_eq!(&x[..4], &[1.0; 4]);
        assert!((x[4] - 0.25).abs() < 1e-6);
    }
}
