//! RMSNorm — full-row and per-head (Qwen3 QK-norm) variants.
//!
//! Each kernel exists as a scalar parity oracle (`rmsnorm`,
//! `rmsnorm_heads`) and a tier-dispatched variant (`*_t`) whose
//! mean-square reduction may reassociate; the gain apply step
//! (`x[i] * inv * g[i]`) is bit-exact across tiers.

use crate::simd::{self, KernelTier};

/// RMSNorm rows `[r0, r1)` of `x` ([rows, d]) into `out` with gain `g`.
/// Scalar tier — the parity oracle for [`rmsnorm_t`].
pub fn rmsnorm(
    x: &[f32],
    g: &[f32],
    out: &mut [f32],
    d: usize,
    eps: f32,
    r0: usize,
    r1: usize,
) {
    rmsnorm_t(KernelTier::Scalar, x, g, out, d, eps, r0, r1);
}

/// [`rmsnorm`] with the sum-of-squares reduction and gain apply
/// dispatched on `tier`.
#[allow(clippy::too_many_arguments)]
pub fn rmsnorm_t(
    tier: KernelTier,
    x: &[f32],
    g: &[f32],
    out: &mut [f32],
    d: usize,
    eps: f32,
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(g.len(), d);
    for r in r0..r1 {
        let xr = &x[r * d..(r + 1) * d];
        let or = &mut out[r * d..(r + 1) * d];
        let ms: f32 = simd::sum_squares(tier, xr) / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        simd::scale_gain(tier, xr, g, or, inv);
    }
}

/// Per-head RMSNorm over `head_dim` segments (Qwen3's q_norm/k_norm):
/// `x` is [rows, heads*head_dim]; the gain `g` is `[head_dim]`, shared by
/// all heads. Normalizes heads `[h0, h1)` of every row. Scalar tier —
/// the parity oracle for [`rmsnorm_heads_t`].
#[allow(clippy::too_many_arguments)]
pub fn rmsnorm_heads(
    x: &[f32],
    g: &[f32],
    out: &mut [f32],
    rows: usize,
    heads: usize,
    head_dim: usize,
    eps: f32,
    h0: usize,
    h1: usize,
) {
    rmsnorm_heads_t(KernelTier::Scalar, x, g, out, rows, heads, head_dim, eps, h0, h1);
}

/// [`rmsnorm_heads`] with the per-head reduction and gain apply
/// dispatched on `tier`.
#[allow(clippy::too_many_arguments)]
pub fn rmsnorm_heads_t(
    tier: KernelTier,
    x: &[f32],
    g: &[f32],
    out: &mut [f32],
    rows: usize,
    heads: usize,
    head_dim: usize,
    eps: f32,
    h0: usize,
    h1: usize,
) {
    debug_assert_eq!(g.len(), head_dim);
    let d = heads * head_dim;
    for r in 0..rows {
        for h in h0..h1 {
            let base = r * d + h * head_dim;
            let xr = &x[base..base + head_dim];
            let ms: f32 = simd::sum_squares(tier, xr) / head_dim as f32;
            let inv = 1.0 / (ms + eps).sqrt();
            let or = &mut out[base..base + head_dim];
            simd::scale_gain(tier, xr, g, or, inv);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v = vec![0.0; n];
        r.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn unit_rms_rows() {
        let d = 64;
        let x = rand_vec(3 * d, 1);
        let g = vec![1.0; d];
        let mut out = vec![0.0; 3 * d];
        rmsnorm(&x, &g, &mut out, d, 1e-6, 0, 3);
        for r in 0..3 {
            let row = &out[r * d..(r + 1) * d];
            let rms: f32 = (row.iter().map(|v| v * v).sum::<f32>() / d as f32).sqrt();
            assert!((rms - 1.0).abs() < 1e-3, "rms {rms}");
        }
    }

    #[test]
    fn gain_is_applied() {
        let d = 4;
        let x = vec![2.0, 2.0, 2.0, 2.0];
        let g = vec![0.5, 1.0, 2.0, 0.0];
        let mut out = vec![0.0; 4];
        rmsnorm(&x, &g, &mut out, d, 0.0, 0, 1);
        // rms = 2 → normalized = 1
        assert!((out[0] - 0.5).abs() < 1e-6);
        assert!((out[2] - 2.0).abs() < 1e-6);
        assert_eq!(out[3], 0.0);
    }

    #[test]
    fn row_range_respected() {
        let d = 8;
        let x = rand_vec(4 * d, 2);
        let g = vec![1.0; d];
        let mut out = vec![f32::NAN; 4 * d];
        rmsnorm(&x, &g, &mut out, d, 1e-6, 1, 3);
        assert!(out[0].is_nan());
        assert!(out[d].is_finite());
        assert!(out[3 * d].is_nan());
    }

    #[test]
    fn per_head_norm_matches_rowwise_on_each_head() {
        let (heads, hd) = (4, 16);
        let x = rand_vec(2 * heads * hd, 3);
        let g = rand_vec(hd, 4);
        let mut out = vec![0.0; x.len()];
        rmsnorm_heads(&x, &g, &mut out, 2, heads, hd, 1e-6, 0, heads);
        // reference: treat each (row, head) segment as a row
        let mut expect = vec![0.0; x.len()];
        for seg in 0..(2 * heads) {
            rmsnorm(&x[seg * hd..(seg + 1) * hd], &g,
                    &mut expect[seg * hd..(seg + 1) * hd], hd, 1e-6, 0, 1);
        }
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn head_range_partition_composes() {
        let (heads, hd) = (6, 8);
        let x = rand_vec(heads * hd, 5);
        let g = vec![1.0; hd];
        let mut full = vec![0.0; x.len()];
        rmsnorm_heads(&x, &g, &mut full, 1, heads, hd, 1e-6, 0, heads);
        let mut split = vec![0.0; x.len()];
        rmsnorm_heads(&x, &g, &mut split, 1, heads, hd, 1e-6, 0, 2);
        rmsnorm_heads(&x, &g, &mut split, 1, heads, hd, 1e-6, 2, 6);
        assert_eq!(full, split);
    }
}
