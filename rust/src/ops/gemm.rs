//! GEMM / GEMV kernels — the decode hot path.
//!
//! Weights are row-major `[N, K]` (each output feature is one weight
//! row, ggml convention) in f32 or Q4_0; activations are `[M, K]` f32.
//! Every kernel computes output *rows `[n0, n1)` for all `M`* so a
//! thread group partitions the N axis — the exact partition Fig. 7
//! draws for llama.cpp and §3.2 reuses for TP shards.
//!
//! The inner loop reads each quantized weight byte exactly once
//! (`dot_q4_0_f32`): on real hardware this is the bandwidth-bound
//! stream the whole paper is about.

use crate::simd::{self, KernelTier};
use crate::tensor::dtype::{Q4_0_BLOCK_BYTES, Q8_0_BLOCK_BYTES, QK4_0, QK8_0};

/// f32 GEMM: `out[m, n] = Σ_k x[m, k] · w[n, k]` for `n ∈ [n0, n1)`.
/// `out` is the full `[M, N]` buffer; this call writes columns
/// `n0..n1` of each row. Scalar tier — the parity oracle for
/// [`gemm_f32_t`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32(
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    n0: usize,
    n1: usize,
) {
    gemm_f32_t(KernelTier::Scalar, x, w, out, m, k, n, n0, n1);
}

/// [`gemm_f32`] with the inner dot product dispatched on `tier`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_t(
    tier: KernelTier,
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    n0: usize,
    n1: usize,
) {
    debug_assert!(x.len() >= m * k);
    debug_assert_eq!(w.len(), n * k);
    debug_assert!(out.len() >= m * n);
    for mi in 0..m {
        let xr = &x[mi * k..(mi + 1) * k];
        let or = &mut out[mi * n..(mi + 1) * n];
        for ni in n0..n1 {
            let wr = &w[ni * k..(ni + 1) * k];
            or[ni] = simd::dot_f32(tier, xr, wr);
        }
    }
}

/// Q4_0 GEMM: weight rows are Q4_0 streams of `k/32*18` bytes.
///
/// The activation row's per-block sums are computed once and shared by
/// all `n1 - n0` weight rows (`dot_q4_0_f32_presum`), hoisting the Q4_0
/// bias correction out of the hot loop. Scalar tier — the parity
/// oracle for [`gemm_q4_0_t`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_q4_0(
    x: &[f32],
    w: &[u8],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    n0: usize,
    n1: usize,
) {
    gemm_q4_0_t(KernelTier::Scalar, x, w, out, m, k, n, n0, n1);
}

/// [`gemm_q4_0`] with the presum dot product dispatched on `tier`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_q4_0_t(
    tier: KernelTier,
    x: &[f32],
    w: &[u8],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    n0: usize,
    n1: usize,
) {
    let row_bytes = k / QK4_0 * Q4_0_BLOCK_BYTES;
    debug_assert!(x.len() >= m * k);
    debug_assert_eq!(w.len(), n * row_bytes);
    debug_assert!(out.len() >= m * n);
    let mut xsums = Vec::with_capacity(k / QK4_0);
    for mi in 0..m {
        let xr = &x[mi * k..(mi + 1) * k];
        crate::quant::block_sums_q4_0(xr, &mut xsums);
        let or = &mut out[mi * n..(mi + 1) * n];
        for ni in n0..n1 {
            let wr = &w[ni * row_bytes..(ni + 1) * row_bytes];
            or[ni] = simd::dot_q4_0_presum(tier, wr, xr, &xsums);
        }
    }
}

/// Q8_0 GEMM (quantized-KV attention scores use this layout). Scalar
/// tier — the parity oracle for [`gemm_q8_0_t`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_q8_0(
    x: &[f32],
    w: &[u8],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    n0: usize,
    n1: usize,
) {
    gemm_q8_0_t(KernelTier::Scalar, x, w, out, m, k, n, n0, n1);
}

/// [`gemm_q8_0`] with the block dot product dispatched on `tier`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_q8_0_t(
    tier: KernelTier,
    x: &[f32],
    w: &[u8],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    n0: usize,
    n1: usize,
) {
    let row_bytes = k / QK8_0 * Q8_0_BLOCK_BYTES;
    debug_assert!(out.len() >= m * n);
    for mi in 0..m {
        let xr = &x[mi * k..(mi + 1) * k];
        let or = &mut out[mi * n..(mi + 1) * n];
        for ni in n0..n1 {
            let wr = &w[ni * row_bytes..(ni + 1) * row_bytes];
            or[ni] = simd::dot_q8_0(tier, wr, xr);
        }
    }
}

/// Unrolled f32 dot product (the auto-vectorizer's favourite shape).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_matrix_q4_0;
    use crate::util::Rng;

    fn naive(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0; m * n];
        for mi in 0..m {
            for ni in 0..n {
                let mut s = 0.0;
                for ki in 0..k {
                    s += x[mi * k + ki] * w[ni * k + ki];
                }
                out[mi * n + ni] = s;
            }
        }
        out
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v = vec![0.0; n];
        r.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn f32_matches_naive() {
        let (m, k, n) = (3, 64, 17);
        let x = rand_vec(m * k, 1);
        let w = rand_vec(n * k, 2);
        let mut out = vec![0.0; m * n];
        gemm_f32(&x, &w, &mut out, m, k, n, 0, n);
        let expect = naive(&x, &w, m, k, n);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn partial_stripe_writes_only_range() {
        let (m, k, n) = (2, 32, 8);
        let x = rand_vec(m * k, 3);
        let w = rand_vec(n * k, 4);
        let mut out = vec![f32::NAN; m * n];
        gemm_f32(&x, &w, &mut out, m, k, n, 2, 5);
        for mi in 0..m {
            for ni in 0..n {
                let v = out[mi * n + ni];
                if (2..5).contains(&ni) {
                    assert!(v.is_finite());
                } else {
                    assert!(v.is_nan());
                }
            }
        }
    }

    #[test]
    fn stripes_compose_to_full_gemm() {
        // two disjoint stripes (as two workers would compute) == full
        let (m, k, n) = (1, 96, 10);
        let x = rand_vec(m * k, 5);
        let w = rand_vec(n * k, 6);
        let mut full = vec![0.0; m * n];
        gemm_f32(&x, &w, &mut full, m, k, n, 0, n);
        let mut split = vec![0.0; m * n];
        gemm_f32(&x, &w, &mut split, m, k, n, 0, 4);
        gemm_f32(&x, &w, &mut split, m, k, n, 4, n);
        assert_eq!(full, split);
    }

    #[test]
    fn q4_matches_dequantized_f32_gemm() {
        let (m, k, n) = (2, 128, 6);
        let x = rand_vec(m * k, 7);
        let w = rand_vec(n * k, 8);
        let wq = quantize_matrix_q4_0(&w, n, k);
        let mut wd = vec![0.0; n * k];
        for ni in 0..n {
            crate::quant::dequantize_row_q4_0(
                &wq[ni * (k / 32 * 18)..(ni + 1) * (k / 32 * 18)],
                &mut wd[ni * k..(ni + 1) * k],
            );
        }
        let expect = naive(&x, &wd, m, k, n);
        let mut out = vec![0.0; m * n];
        gemm_q4_0(&x, &wq, &mut out, m, k, n, 0, n);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn q8_roundtrip_gemv() {
        let k = 64;
        let n = 4;
        let w = rand_vec(n * k, 9);
        let x = rand_vec(k, 10);
        let mut wq = Vec::new();
        for r in w.chunks_exact(k) {
            crate::quant::quantize_row_q8_0(r, &mut wq);
        }
        let mut out = vec![0.0; n];
        gemm_q8_0(&x, &wq, &mut out, 1, k, n, 0, n);
        let expect = naive(&x, &w, 1, k, n);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 0.05 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn dot_handles_tails() {
        let a = rand_vec(7, 11);
        let b = rand_vec(7, 12);
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot_f32(&a, &b) - expect).abs() < 1e-5);
    }
}
