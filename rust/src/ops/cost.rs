//! Analytic (FLOPs, bytes) profiles per operator — the contract between
//! real execution and the virtual-time simulator.
//!
//! Each function describes the resources one worker consumes when it
//! computes its share of an operator. Byte counts are what the operator
//! *streams from memory*, which for the bandwidth-bound decode path is
//! the quantity that determines throughput (paper §3.1).

use crate::tensor::DType;

/// Resource profile of a worker's share of one operator.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCost {
    /// Floating-point operations (multiply-adds count as 2).
    pub flops: f64,
    /// Bytes streamed from the weight-like operand (partitioned rows).
    pub weight_bytes: f64,
    /// Bytes streamed from activation inputs.
    pub input_bytes: f64,
    /// Bytes written to the output.
    pub output_bytes: f64,
}

impl OpCost {
    pub fn total_bytes(&self) -> f64 {
        self.weight_bytes + self.input_bytes + self.output_bytes
    }
}

/// GEMM over output rows `[n0, n1)`: x [m, k] · w[n, k]ᵀ stripe.
pub fn gemm(m: usize, k: usize, n0: usize, n1: usize, wdtype: DType) -> OpCost {
    let rows = (n1 - n0) as f64;
    OpCost {
        flops: 2.0 * m as f64 * k as f64 * rows,
        weight_bytes: rows * k as f64 * wdtype.bytes_per_element(),
        input_bytes: m as f64 * k as f64 * 4.0,
        output_bytes: m as f64 * rows * 4.0,
    }
}

/// RMSNorm over rows `[r0, r1)` of a [rows, d] activation.
pub fn rmsnorm(d: usize, r0: usize, r1: usize) -> OpCost {
    let rows = (r1 - r0) as f64;
    OpCost {
        flops: rows * d as f64 * 3.0,
        weight_bytes: d as f64 * 4.0, // the gain vector
        input_bytes: rows * d as f64 * 4.0,
        output_bytes: rows * d as f64 * 4.0,
    }
}

/// RoPE on heads `[h0, h1)` of [rows, heads*hd] (in place).
pub fn rope(rows: usize, head_dim: usize, h0: usize, h1: usize) -> OpCost {
    let elems = rows as f64 * (h1 - h0) as f64 * head_dim as f64;
    OpCost {
        flops: elems * 6.0, // sin/cos amortized + 4 mul/add per pair
        weight_bytes: 0.0,
        input_bytes: elems * 4.0,
        output_bytes: elems * 4.0,
    }
}

/// Attention for query heads `[h0, h1)` over `kv_len` cached positions.
/// The KV stream is the "weight-like" operand: each of the worker's kv
/// heads streams `kv_len · head_dim` K and V elements.
#[allow(clippy::too_many_arguments)]
pub fn attention(
    rows: usize,
    heads: usize,
    kv_heads: usize,
    head_dim: usize,
    kv_len: usize,
    kv_dtype: DType,
    h0: usize,
    h1: usize,
) -> OpCost {
    let rep = (heads / kv_heads).max(1);
    let my_heads = (h1 - h0) as f64;
    // distinct kv heads this worker touches (adjacent query heads share)
    let my_kv_heads = ((h1.div_ceil(rep)) - (h0 / rep)) as f64;
    let qk_flops = 2.0 * rows as f64 * my_heads * kv_len as f64 * head_dim as f64;
    OpCost {
        flops: 2.0 * qk_flops + 4.0 * rows as f64 * my_heads * kv_len as f64,
        weight_bytes: 2.0 * my_kv_heads * kv_len as f64 * head_dim as f64
            * kv_dtype.bytes_per_element(),
        input_bytes: rows as f64 * my_heads * head_dim as f64 * 4.0,
        output_bytes: rows as f64 * my_heads * head_dim as f64 * 4.0,
    }
}

/// KV store for kv heads `[h0, h1)` of `rows` new tokens.
pub fn store_kv(rows: usize, head_dim: usize, h0: usize, h1: usize) -> OpCost {
    let elems = rows as f64 * (h1 - h0) as f64 * head_dim as f64;
    OpCost { flops: 0.0, weight_bytes: 0.0, input_bytes: elems * 4.0, output_bytes: elems * 4.0 }
}

/// Element-wise binary/unary op over `[e0, e1)` flat elements.
/// `inputs` = number of input streams (1 for silu/copy, 2 for add/mul).
pub fn elementwise(inputs: usize, e0: usize, e1: usize) -> OpCost {
    let elems = (e1 - e0) as f64;
    OpCost {
        flops: elems * 2.0,
        weight_bytes: 0.0,
        input_bytes: elems * 4.0 * inputs as f64,
        output_bytes: elems * 4.0,
    }
}

/// Embedding lookup of `[t0, t1)` tokens from a [vocab, d] f32 table.
pub fn embed(d: usize, t0: usize, t1: usize) -> OpCost {
    let elems = (t1 - t0) as f64 * d as f64;
    OpCost { flops: 0.0, weight_bytes: elems * 4.0, input_bytes: 0.0, output_bytes: elems * 4.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_q4_weight_stream_matches_paper_math() {
        // one decode token over a [2560, 2560] Q4_0 matmul reads
        // 2560·2560·0.5625 ≈ 3.69 MB of weights
        let c = gemm(1, 2560, 0, 2560, DType::Q4_0);
        assert!((c.weight_bytes - 2560.0 * 2560.0 * 0.5625).abs() < 1.0);
        assert_eq!(c.flops, 2.0 * 2560.0 * 2560.0);
    }

    #[test]
    fn gemm_partition_is_linear_in_rows() {
        let half = gemm(1, 256, 0, 128, DType::F32);
        let full = gemm(1, 256, 0, 256, DType::F32);
        assert!((full.weight_bytes - 2.0 * half.weight_bytes).abs() < 1e-9);
        assert!((full.flops - 2.0 * half.flops).abs() < 1e-9);
        // input activation is NOT partitioned: both read all of x
        assert_eq!(full.input_bytes, half.input_bytes);
    }

    #[test]
    fn attention_kv_stream_grows_with_kv_len() {
        let short = attention(1, 4, 2, 64, 16, DType::F32, 0, 4);
        let long = attention(1, 4, 2, 64, 256, DType::F32, 0, 4);
        assert!((long.weight_bytes / short.weight_bytes - 16.0).abs() < 1e-9);
    }

    #[test]
    fn attention_gqa_dedups_kv_heads() {
        // 4 query heads on 2 kv heads: workers covering heads 0..2 touch
        // kv head 0 only
        let c = attention(1, 4, 2, 8, 10, DType::F32, 0, 2);
        let full = attention(1, 4, 2, 8, 10, DType::F32, 0, 4);
        assert!((full.weight_bytes / c.weight_bytes - 2.0).abs() < 1e-9);
    }

    #[test]
    fn elementwise_input_streams() {
        assert_eq!(elementwise(2, 0, 100).input_bytes, 800.0);
        assert_eq!(elementwise(1, 0, 100).input_bytes, 400.0);
    }

    #[test]
    fn decode_step_is_weight_dominated() {
        // sanity: for one token on a 4B-geometry layer, GEMM weight bytes
        // dwarf everything else — the premise of the paper's analysis
        let d = 2560;
        let ffn = 9728;
        let mut weight = 0.0;
        let mut other = 0.0;
        for (n, k) in [(d, d), (d, ffn), (ffn, d), (ffn, d)] {
            let c = gemm(1, k, 0, n, DType::Q4_0);
            weight += c.weight_bytes;
            other += c.input_bytes + c.output_bytes;
        }
        assert!(weight / other > 100.0);
    }
}
