//! Operator library (paper §2.7).
//!
//! Hardware-agnostic operations (copy/reshape) live in [`common`];
//! CPU-hot operations (GEMM, attention, norms) have row/head-partitioned
//! entry points: every function computes an explicit `[r0, r1)` slice of
//! the output so the thread manager can hand disjoint ranges to the
//! workers of a group — the same work-splitting llama.cpp's compute
//! threads use, made explicit.
//!
//! The [`kernel::Kernel`] trait ties the pieces together: one
//! implementation per graph [`crate::graph::OpKind`] (see [`kernels`])
//! owns its unit policy, analytic cost ([`cost`]), NUMA traffic
//! attribution and real execution, registered in
//! [`kernel::KernelRegistry`] and resolved once per graph at build
//! time. Executors dispatch through the trait and carry no per-op
//! knowledge.
//!
//! The paper reuses llama.cpp's NEON kernels; this reproduction ships
//! portable Rust with identical block layouts (`crate::quant`) and an
//! L1 Pallas kernel for the TPU mapping (DESIGN.md
//! §Hardware-Adaptation). [`cost`] carries each operator's analytic
//! (flops, bytes) profile — the contract between real execution and the
//! virtual-time simulator.
//!
//! The hot operators additionally come in `_t` variants
//! ([`gemm::gemm_q4_0_t`], [`norm::rmsnorm_t`],
//! [`attention::attention_t`], …) taking a [`crate::simd::KernelTier`]
//! first: the tier-less entry points are the **scalar oracles**
//! (unchanged semantics, what the parity suites pin against) and the
//! `_t` forms dispatch their inner loops onto the process-active SIMD
//! tier. Tier choice never affects unit partitioning; see
//! `rust/KERNELS.md` for per-kernel contracts and tolerances.

pub mod attention;
pub mod common;
pub mod cost;
pub mod elementwise;
pub mod gemm;
pub mod kernel;
pub mod kernels;
pub mod norm;
pub mod rope;
pub mod softmax;

pub use cost::OpCost;
pub use kernel::{Kernel, KernelRegistry, OpCtx, TrafficEnv};
