//! Rotary position embedding — NeoX/Qwen half-split convention, matching
//! `python/compile/kernels/ref.py::rope` exactly (pairs are
//! `(x[i], x[i + d/2])` within each head).

/// Apply RoPE to heads `[h0, h1)` of `x` ([rows, heads*head_dim]); row
/// `r` is at absolute position `pos0 + r`. In-place.
#[allow(clippy::too_many_arguments)]
pub fn rope(
    x: &mut [f32],
    rows: usize,
    heads: usize,
    head_dim: usize,
    pos0: usize,
    theta: f32,
    h0: usize,
    h1: usize,
) {
    debug_assert_eq!(x.len(), rows * heads * head_dim);
    debug_assert!(head_dim % 2 == 0);
    let half = head_dim / 2;
    let d = heads * head_dim;
    for r in 0..rows {
        let pos = (pos0 + r) as f32;
        for h in h0..h1 {
            let base = r * d + h * head_dim;
            for i in 0..half {
                let freq = theta.powf(-(i as f32) / half as f32);
                let ang = pos * freq;
                let (sin, cos) = ang.sin_cos();
                let a = x[base + i];
                let b = x[base + i + half];
                x[base + i] = a * cos - b * sin;
                x[base + i + half] = b * cos + a * sin;
            }
        }
    }
}

/// RoPE with an explicit position per row (continuous batching: row `r`
/// belongs to its own sequence at position `pos[r]`). Only the first
/// `pos.len()` rows of `x` are touched. In-place.
pub fn rope_rows(
    x: &mut [f32],
    heads: usize,
    head_dim: usize,
    pos: &[usize],
    theta: f32,
    h0: usize,
    h1: usize,
) {
    debug_assert!(x.len() >= pos.len() * heads * head_dim);
    debug_assert!(head_dim % 2 == 0);
    let half = head_dim / 2;
    let d = heads * head_dim;
    for (r, &p) in pos.iter().enumerate() {
        let pf = p as f32;
        for h in h0..h1 {
            let base = r * d + h * head_dim;
            for i in 0..half {
                let freq = theta.powf(-(i as f32) / half as f32);
                let ang = pf * freq;
                let (sin, cos) = ang.sin_cos();
                let a = x[base + i];
                let b = x[base + i + half];
                x[base + i] = a * cos - b * sin;
                x[base + i + half] = b * cos + a * sin;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v = vec![0.0; n];
        r.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn position_zero_is_identity() {
        let mut x = rand_vec(2 * 16, 1);
        let orig = x.clone();
        rope(&mut x, 1, 2, 16, 0, 1e6, 0, 2);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rotation_preserves_pair_norm() {
        let hd = 32;
        let mut x = rand_vec(hd, 2);
        let orig = x.clone();
        rope(&mut x, 1, 1, hd, 17, 1e6, 0, 1);
        let half = hd / 2;
        for i in 0..half {
            let n0 = orig[i].hypot(orig[i + half]);
            let n1 = x[i].hypot(x[i + half]);
            assert!((n0 - n1).abs() < 1e-5);
        }
    }

    #[test]
    fn matches_reference_formula() {
        // independent reimplementation straight from ref.py
        let hd = 8;
        let half = hd / 2;
        let theta = 1e6f32;
        let pos = 5usize;
        let x0 = rand_vec(hd, 3);
        let mut x = x0.clone();
        rope(&mut x, 1, 1, hd, pos, theta, 0, 1);
        for i in 0..half {
            let freq = 1.0 / theta.powf(i as f32 / half as f32);
            let ang = pos as f32 * freq;
            let expect_a = x0[i] * ang.cos() - x0[i + half] * ang.sin();
            let expect_b = x0[i + half] * ang.cos() + x0[i] * ang.sin();
            assert!((x[i] - expect_a).abs() < 1e-5);
            assert!((x[i + half] - expect_b).abs() < 1e-5);
        }
    }

    #[test]
    fn rows_get_consecutive_positions() {
        let hd = 4;
        let x0 = rand_vec(hd, 4);
        // two identical rows at pos0=3 → row1 must equal applying pos 4
        let mut two = [x0.clone(), x0.clone()].concat();
        rope(&mut two, 2, 1, hd, 3, 1e4, 0, 1);
        let mut one = x0.clone();
        rope(&mut one, 1, 1, hd, 4, 1e4, 0, 1);
        for i in 0..hd {
            assert!((two[hd + i] - one[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn per_row_positions_match_dense_rope() {
        // three rows at unrelated positions == three dense calls
        let (heads, hd) = (2, 8);
        let x0 = rand_vec(3 * heads * hd, 6);
        let mut batched = x0.clone();
        rope_rows(&mut batched, heads, hd, &[11, 0, 4], 1e6, 0, heads);
        for (r, p) in [(0usize, 11usize), (1, 0), (2, 4)] {
            let mut one = x0[r * heads * hd..(r + 1) * heads * hd].to_vec();
            rope(&mut one, 1, heads, hd, p, 1e6, 0, heads);
            assert_eq!(&batched[r * heads * hd..(r + 1) * heads * hd], &one[..]);
        }
    }

    #[test]
    fn rope_rows_leaves_padding_rows_untouched() {
        let (heads, hd) = (1, 4);
        let x0 = rand_vec(2 * hd, 7);
        let mut x = x0.clone();
        rope_rows(&mut x, heads, hd, &[3], 1e6, 0, heads);
        assert_eq!(&x[hd..], &x0[hd..]);
    }

    #[test]
    fn head_range_partition_composes() {
        let (heads, hd) = (4, 8);
        let x0 = rand_vec(heads * hd, 5);
        let mut full = x0.clone();
        rope(&mut full, 1, heads, hd, 9, 1e6, 0, heads);
        let mut split = x0.clone();
        rope(&mut split, 1, heads, hd, 9, 1e6, 0, 1);
        rope(&mut split, 1, heads, hd, 9, 1e6, 1, heads);
        assert_eq!(full, split);
    }
}
