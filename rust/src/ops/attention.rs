//! Fused attention over the KV cache (paper §2.7 "FlashAttention").
//!
//! Online-softmax streaming over KV positions — the score row never
//! materializes beyond a running (max, sum, acc) triple, mirroring the
//! L1 Pallas kernel. Partitioned by *query head* `[h0, h1)`: heads are
//! independent, which is also how the TP plan shards attention across
//! NUMA nodes (W_q/W_k/W_v are head-partitioned, §3.2).
//!
//! Layout: `q` is [rows, heads*head_dim] (rows = new tokens);
//! `k_cache`/`v_cache` are [kv_heads, max_seq, head_dim]; GQA maps query
//! head `h` to kv head `h / (heads / kv_heads)`.
//!
//! Tier dispatch: the score dot product and the rescale-accumulate
//! (`acc[i] = acc[i]·corr + p·v[i]`) are the vectorized inner loops.
//! The axpy stays multiply + add on every tier, so only the dot
//! reduction reassociates — the batched == serial determinism contract
//! (see [`attention_rows`]) holds on every tier.

use crate::sched::BatchView;
use crate::simd::{self, KernelTier};

/// Decode/prefill attention for query heads `[h0, h1)`.
///
/// Row `r` of `q` sits at absolute position `pos0 + r` and attends
/// causally to cache positions `0..=pos0+r`. Scalar tier — the parity
/// oracle for [`attention_t`].
#[allow(clippy::too_many_arguments)]
pub fn attention(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    out: &mut [f32],
    rows: usize,
    heads: usize,
    kv_heads: usize,
    head_dim: usize,
    max_seq: usize,
    pos0: usize,
    h0: usize,
    h1: usize,
) {
    attention_t(
        KernelTier::Scalar,
        q,
        k_cache,
        v_cache,
        out,
        rows,
        heads,
        kv_heads,
        head_dim,
        max_seq,
        pos0,
        h0,
        h1,
    );
}

/// [`attention`] with the dot/axpy inner loops dispatched on `tier`.
#[allow(clippy::too_many_arguments)]
pub fn attention_t(
    tier: KernelTier,
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    out: &mut [f32],
    rows: usize,
    heads: usize,
    kv_heads: usize,
    head_dim: usize,
    max_seq: usize,
    pos0: usize,
    h0: usize,
    h1: usize,
) {
    debug_assert_eq!(q.len(), rows * heads * head_dim);
    debug_assert_eq!(k_cache.len(), kv_heads * max_seq * head_dim);
    debug_assert_eq!(out.len(), rows * heads * head_dim);
    let rep = heads / kv_heads;
    let scale = 1.0 / (head_dim as f32).sqrt();
    let d = heads * head_dim;

    // accumulator reused across rows/heads (no allocation in the loop)
    let mut acc = vec![0.0f32; head_dim];
    for r in 0..rows {
        let kv_len = pos0 + r + 1; // causal horizon for this query row
        for h in h0..h1 {
            let kvh = h / rep;
            let qv = &q[r * d + h * head_dim..r * d + (h + 1) * head_dim];
            let kbase = kvh * max_seq * head_dim;
            let vbase = kbase;

            // online softmax
            let mut m = f32::NEG_INFINITY;
            let mut l = 0.0f32;
            acc.fill(0.0);
            for t in 0..kv_len {
                let kv = &k_cache[kbase + t * head_dim..kbase + (t + 1) * head_dim];
                let s = simd::dot_f32(tier, qv, kv) * scale;
                let m_new = m.max(s);
                let corr = if m.is_finite() { (m - m_new).exp() } else { 0.0 };
                let p = (s - m_new).exp();
                l = l * corr + p;
                let vv = &v_cache[vbase + t * head_dim..vbase + (t + 1) * head_dim];
                simd::axpy_rescale(tier, &mut acc, corr, p, vv);
                m = m_new;
            }
            let inv = if l > 0.0 { 1.0 / l } else { 0.0 };
            let or = &mut out[r * d + h * head_dim..r * d + (h + 1) * head_dim];
            for i in 0..head_dim {
                or[i] = acc[i] * inv;
            }
        }
    }
}

/// Write new K/V rows into the cache: `src` is [rows, kv_heads*head_dim]
/// laid out per token; cache slot `pos0 + r` of each kv head receives
/// the corresponding segment. Partitioned by kv head `[h0, h1)`.
#[allow(clippy::too_many_arguments)]
pub fn store_kv(
    src: &[f32],
    cache: &mut [f32],
    rows: usize,
    kv_heads: usize,
    head_dim: usize,
    max_seq: usize,
    pos0: usize,
    h0: usize,
    h1: usize,
) {
    debug_assert_eq!(src.len(), rows * kv_heads * head_dim);
    debug_assert!(pos0 + rows <= max_seq);
    let d = kv_heads * head_dim;
    for r in 0..rows {
        for h in h0..h1 {
            let from = &src[r * d + h * head_dim..r * d + (h + 1) * head_dim];
            let to_base = h * max_seq * head_dim + (pos0 + r) * head_dim;
            cache[to_base..to_base + head_dim].copy_from_slice(from);
        }
    }
}

/// Multi-sequence decode attention (continuous batching): row `r` of
/// `q` is one token of a sequence whose KV lives in the pages named by
/// `batch.tables[r]`; it attends causally to logical positions
/// `[0, batch.pos[r]]`, gathered page by page in logical order. The
/// caches span the *whole* paged pool: `[kv_heads, capacity, head_dim]`
/// with `capacity` = pages × page_size. Partitioned by query head
/// `[h0, h1)`.
///
/// Per-row arithmetic (dot order, online-softmax recurrence) is
/// identical to [`attention`] — the page indirection changes *where*
/// each logical position is read from, never the order positions are
/// visited — so a batched step is bit-equal to the serial
/// single-sequence step: the determinism contract the batcher tests
/// pin down. Scalar tier — the parity oracle for [`attention_rows_t`].
#[allow(clippy::too_many_arguments)]
pub fn attention_rows(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    out: &mut [f32],
    heads: usize,
    kv_heads: usize,
    head_dim: usize,
    capacity: usize,
    batch: &BatchView,
    h0: usize,
    h1: usize,
) {
    attention_rows_t(
        KernelTier::Scalar,
        q,
        k_cache,
        v_cache,
        out,
        heads,
        kv_heads,
        head_dim,
        capacity,
        batch,
        h0,
        h1,
    );
}

/// [`attention_rows`] with the dot/axpy inner loops dispatched on
/// `tier`. The per-row arithmetic matches [`attention_t`] on the same
/// tier, so batched == serial holds tier by tier.
#[allow(clippy::too_many_arguments)]
pub fn attention_rows_t(
    tier: KernelTier,
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    out: &mut [f32],
    heads: usize,
    kv_heads: usize,
    head_dim: usize,
    capacity: usize,
    batch: &BatchView,
    h0: usize,
    h1: usize,
) {
    let rows = batch.rows();
    let ps = batch.page_size;
    debug_assert!(q.len() >= rows * heads * head_dim);
    debug_assert_eq!(k_cache.len(), kv_heads * capacity * head_dim);
    debug_assert!(out.len() >= rows * heads * head_dim);
    let rep = heads / kv_heads;
    let scale = 1.0 / (head_dim as f32).sqrt();
    let d = heads * head_dim;

    let mut acc = vec![0.0f32; head_dim];
    for r in 0..rows {
        let table = &batch.tables[r];
        let kv_len = batch.pos[r] + 1;
        debug_assert!(table.len() * ps >= kv_len);
        for h in h0..h1 {
            let kvh = h / rep;
            let qv = &q[r * d + h * head_dim..r * d + (h + 1) * head_dim];
            let head_base = kvh * capacity * head_dim;

            let mut m = f32::NEG_INFINITY;
            let mut l = 0.0f32;
            acc.fill(0.0);
            // page-by-page gather; `t` walks logical positions strictly
            // in order, so the online-softmax recurrence is identical
            // to a contiguous cache
            let mut t = 0usize;
            for &page in table {
                if t >= kv_len {
                    break;
                }
                let n = (kv_len - t).min(ps);
                debug_assert!((page as usize + 1) * ps <= capacity);
                let base = head_base + page as usize * ps * head_dim;
                for i in 0..n {
                    let kv = &k_cache[base + i * head_dim..base + (i + 1) * head_dim];
                    let s = simd::dot_f32(tier, qv, kv) * scale;
                    let m_new = m.max(s);
                    let corr = if m.is_finite() { (m - m_new).exp() } else { 0.0 };
                    let p = (s - m_new).exp();
                    l = l * corr + p;
                    let vv = &v_cache[base + i * head_dim..base + (i + 1) * head_dim];
                    simd::axpy_rescale(tier, &mut acc, corr, p, vv);
                    m = m_new;
                }
                t += n;
            }
            let inv = if l > 0.0 { 1.0 / l } else { 0.0 };
            let or = &mut out[r * d + h * head_dim..r * d + (h + 1) * head_dim];
            for i in 0..head_dim {
                or[i] = acc[i] * inv;
            }
        }
    }
}

/// Multi-sequence KV store: row `r` of `src` lands in the physical
/// cache position its page table maps `batch.pos[r]` to
/// ([`BatchView::slot`]). Cache layout as in [`attention_rows`].
/// Partitioned by kv head `[h0, h1)`.
#[allow(clippy::too_many_arguments)]
pub fn store_kv_rows(
    src: &[f32],
    cache: &mut [f32],
    kv_heads: usize,
    head_dim: usize,
    capacity: usize,
    batch: &BatchView,
    h0: usize,
    h1: usize,
) {
    let rows = batch.rows();
    debug_assert!(src.len() >= rows * kv_heads * head_dim);
    let d = kv_heads * head_dim;
    for r in 0..rows {
        let slot = batch.slot(r);
        debug_assert!(slot < capacity);
        for h in h0..h1 {
            let from = &src[r * d + h * head_dim..r * d + (h + 1) * head_dim];
            let to_base = h * capacity * head_dim + slot * head_dim;
            cache[to_base..to_base + head_dim].copy_from_slice(from);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::softmax::softmax_rows;
    use crate::util::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v = vec![0.0; n];
        r.fill_normal(&mut v, 1.0);
        v
    }

    /// Naive reference: materialize scores, mask, softmax, weight V.
    #[allow(clippy::too_many_arguments)]
    fn naive(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        rows: usize,
        heads: usize,
        kv_heads: usize,
        hd: usize,
        max_seq: usize,
        pos0: usize,
    ) -> Vec<f32> {
        let rep = heads / kv_heads;
        let d = heads * hd;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = vec![0.0; rows * d];
        for r in 0..rows {
            let kv_len = pos0 + r + 1;
            for h in 0..heads {
                let kvh = h / rep;
                let qv = &q[r * d + h * hd..r * d + (h + 1) * hd];
                let mut scores = vec![0.0f32; kv_len];
                for t in 0..kv_len {
                    let kr = &k[kvh * max_seq * hd + t * hd..kvh * max_seq * hd + (t + 1) * hd];
                    scores[t] = qv.iter().zip(kr).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                softmax_rows(&mut scores, kv_len, kv_len, 0, 1);
                for t in 0..kv_len {
                    let vr = &v[kvh * max_seq * hd + t * hd..kvh * max_seq * hd + (t + 1) * hd];
                    for i in 0..hd {
                        out[r * d + h * hd + i] += scores[t] * vr[i];
                    }
                }
            }
        }
        out
    }

    #[test]
    fn decode_matches_naive() {
        let (heads, kvh, hd, max_seq) = (4, 2, 8, 32);
        let q = rand_vec(heads * hd, 1);
        let k = rand_vec(kvh * max_seq * hd, 2);
        let v = rand_vec(kvh * max_seq * hd, 3);
        let mut out = vec![0.0; heads * hd];
        attention(&q, &k, &v, &mut out, 1, heads, kvh, hd, max_seq, 9, 0, heads);
        let expect = naive(&q, &k, &v, 1, heads, kvh, hd, max_seq, 9);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn prefill_rows_are_causal() {
        let (heads, kvh, hd, max_seq, rows) = (2, 1, 4, 16, 5);
        let q = rand_vec(rows * heads * hd, 4);
        let k = rand_vec(kvh * max_seq * hd, 5);
        let v = rand_vec(kvh * max_seq * hd, 6);
        let mut out = vec![0.0; rows * heads * hd];
        attention(&q, &k, &v, &mut out, rows, heads, kvh, hd, max_seq, 0, 0, heads);
        let expect = naive(&q, &k, &v, rows, heads, kvh, hd, max_seq, 0);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
        // row 0 attends only to position 0: independent check
        let mut solo = vec![0.0; heads * hd];
        attention(&q[..heads * hd], &k, &v, &mut solo, 1, heads, kvh, hd, max_seq, 0, 0, heads);
        for (a, b) in solo.iter().zip(&out[..heads * hd]) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn head_partition_composes() {
        let (heads, kvh, hd, max_seq) = (4, 4, 8, 8);
        let q = rand_vec(heads * hd, 7);
        let k = rand_vec(kvh * max_seq * hd, 8);
        let v = rand_vec(kvh * max_seq * hd, 9);
        let mut full = vec![0.0; heads * hd];
        attention(&q, &k, &v, &mut full, 1, heads, kvh, hd, max_seq, 5, 0, heads);
        let mut split = vec![0.0; heads * hd];
        attention(&q, &k, &v, &mut split, 1, heads, kvh, hd, max_seq, 5, 0, 1);
        attention(&q, &k, &v, &mut split, 1, heads, kvh, hd, max_seq, 5, 1, 4);
        assert_eq!(full, split);
    }

    #[test]
    fn store_then_attend_roundtrip() {
        let (kvh, hd, max_seq) = (2, 4, 8);
        let mut cache = vec![0.0f32; kvh * max_seq * hd];
        let t0 = rand_vec(kvh * hd, 10);
        let t1 = rand_vec(kvh * hd, 11);
        store_kv(&t0, &mut cache, 1, kvh, hd, max_seq, 0, 0, kvh);
        store_kv(&t1, &mut cache, 1, kvh, hd, max_seq, 1, 0, kvh);
        // cache slot (head 1, pos 1) must hold t1's head-1 segment
        let got = &cache[1 * max_seq * hd + 1 * hd..1 * max_seq * hd + 2 * hd];
        assert_eq!(got, &t1[hd..2 * hd]);
    }

    #[test]
    fn paged_sequences_match_independent_caches() {
        // two sequences scattered across non-contiguous pages of one
        // pool must reproduce two independent contiguous caches
        // bit-for-bit (pages of 4 positions; seq 0 = pages [0, 2],
        // seq 1 = pages [3, 1] — deliberately out of order)
        let (heads, kvh, hd, seq, ps) = (2, 2, 4, 8, 4);
        let capacity = 2 * seq;
        let tables = [vec![0u32, 2], vec![3u32, 1]];
        let mut pool_k = vec![0.0f32; kvh * capacity * hd];
        let mut pool_v = vec![0.0f32; kvh * capacity * hd];
        let mut solo_k = [vec![0.0f32; kvh * seq * hd], vec![0.0f32; kvh * seq * hd]];
        let mut solo_v = [vec![0.0f32; kvh * seq * hd], vec![0.0f32; kvh * seq * hd]];

        // interleave tokens of the two sequences, crossing a page
        // boundary for seq 0 (positions 3 then 4 land on page 0 / 2)
        let lanes = [(0usize, 0usize), (1, 0), (0, 1), (1, 1), (0, 2), (0, 3), (0, 4)];
        for (li, &(s, p)) in lanes.iter().enumerate() {
            let kv = rand_vec(kvh * hd, 20 + li as u64);
            let view = BatchView::new(ps, vec![tables[s].clone()], vec![p]);
            store_kv_rows(&kv, &mut pool_k, kvh, hd, capacity, &view, 0, kvh);
            store_kv_rows(&kv, &mut pool_v, kvh, hd, capacity, &view, 0, kvh);
            store_kv(&kv, &mut solo_k[s], 1, kvh, hd, seq, p, 0, kvh);
            store_kv(&kv, &mut solo_v[s], 1, kvh, hd, seq, p, 0, kvh);
        }

        // one batched attention step over both sequences at once
        let q = rand_vec(2 * heads * hd, 30);
        let mut batched = vec![0.0f32; 2 * heads * hd];
        let view = BatchView::new(ps, vec![tables[0].clone(), tables[1].clone()], vec![4, 1]);
        attention_rows(
            &q, &pool_k, &pool_v, &mut batched, heads, kvh, hd, capacity, &view, 0, heads,
        );
        for (s, pos) in [(0usize, 4usize), (1, 1)] {
            let mut solo = vec![0.0f32; heads * hd];
            attention(
                &q[s * heads * hd..(s + 1) * heads * hd],
                &solo_k[s],
                &solo_v[s],
                &mut solo,
                1,
                heads,
                kvh,
                hd,
                seq,
                pos,
                0,
                heads,
            );
            assert_eq!(&batched[s * heads * hd..(s + 1) * heads * hd], &solo[..]);
        }
    }

    #[test]
    fn gqa_heads_share_kv() {
        // 4 query heads, 1 kv head: all query heads see the same K/V, so
        // identical q segments give identical outputs
        let (heads, kvh, hd, max_seq) = (4, 1, 4, 4);
        let seg = rand_vec(hd, 12);
        let q: Vec<f32> = (0..heads).flat_map(|_| seg.clone()).collect();
        let k = rand_vec(kvh * max_seq * hd, 13);
        let v = rand_vec(kvh * max_seq * hd, 14);
        let mut out = vec![0.0; heads * hd];
        attention(&q, &k, &v, &mut out, 1, heads, kvh, hd, max_seq, 2, 0, heads);
        for h in 1..heads {
            assert_eq!(&out[..hd], &out[h * hd..(h + 1) * hd]);
        }
    }
}
