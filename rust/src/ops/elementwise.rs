//! Element-wise operators: add, mul, SiLU, SwiGLU fusion.
//!
//! All kernels operate on an explicit element range `[e0, e1)` so
//! groups partition flat activations evenly.

/// `out[i] = a[i] + b[i]` over [e0, e1).
pub fn add(a: &[f32], b: &[f32], out: &mut [f32], e0: usize, e1: usize) {
    for i in e0..e1 {
        out[i] = a[i] + b[i];
    }
}

/// `out[i] = a[i] * b[i]` over [e0, e1).
pub fn mul(a: &[f32], b: &[f32], out: &mut [f32], e0: usize, e1: usize) {
    for i in e0..e1 {
        out[i] = a[i] * b[i];
    }
}

/// SiLU: x * sigmoid(x).
#[inline]
pub fn silu_scalar(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// `out[i] = silu(a[i])` over [e0, e1).
pub fn silu(a: &[f32], out: &mut [f32], e0: usize, e1: usize) {
    for i in e0..e1 {
        out[i] = silu_scalar(a[i]);
    }
}

/// Fused SwiGLU gate: `out[i] = silu(gate[i]) * up[i]` — saves one full
/// activation pass vs separate silu+mul (used by the perf-optimized
/// graph; both forms are tested equivalent).
pub fn swiglu(gate: &[f32], up: &[f32], out: &mut [f32], e0: usize, e1: usize) {
    for i in e0..e1 {
        out[i] = silu_scalar(gate[i]) * up[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v = vec![0.0; n];
        r.fill_normal(&mut v, 2.0);
        v
    }

    #[test]
    fn add_mul_ranges() {
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![10.0, 20.0, 30.0];
        let mut out = vec![0.0; 3];
        add(&a, &b, &mut out, 1, 3);
        assert_eq!(out, vec![0.0, 22.0, 33.0]);
        mul(&a, &b, &mut out, 0, 2);
        assert_eq!(out, vec![10.0, 40.0, 33.0]);
    }

    #[test]
    fn silu_known_values() {
        assert_eq!(silu_scalar(0.0), 0.0);
        assert!((silu_scalar(1.0) - 0.731_058_6).abs() < 1e-6);
        assert!(silu_scalar(-10.0).abs() < 1e-3);
        // large positive ≈ identity
        assert!((silu_scalar(20.0) - 20.0).abs() < 1e-4);
    }

    #[test]
    fn swiglu_equals_silu_then_mul() {
        let g = rand_vec(64, 1);
        let u = rand_vec(64, 2);
        let mut fused = vec![0.0; 64];
        swiglu(&g, &u, &mut fused, 0, 64);
        let mut s = vec![0.0; 64];
        silu(&g, &mut s, 0, 64);
        let mut unfused = vec![0.0; 64];
        mul(&s, &u, &mut unfused, 0, 64);
        assert_eq!(fused, unfused);
    }
}
