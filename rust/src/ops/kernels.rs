//! Kernel implementations: one [`Kernel`] per [`OpKind`] variant
//! (matmul per weight dtype).
//!
//! Each impl owns all four facets of its operator — unit policy,
//! analytic cost, NUMA traffic attribution and real execution — which
//! used to live in three hand-synchronized `match OpKind` sites
//! (`sched::exec_op::run_op`, `sched::partition_units`,
//! `sched::traffic::op_traffic`). Adding an operator now means adding
//! one kernel here and one [`super::kernel::KernelRegistry::resolve`]
//! arm; executors pick the change up without edits.
//!
//! Byte formulas delegate to [`super::cost`]; node attribution comes
//! from each source tensor's placement. Matmul weight rows and
//! attention KV heads use exact row-range attribution (placement
//! alignment is the paper's whole point); secondary streams use
//! proportional spreading.

use crate::graph::{Graph, OpKind, TensorMeta};
use crate::numa::cost::Traffic;
use crate::numa::Placement;
use crate::sched::ExecParams;
use crate::simd::KernelTier;
use crate::tensor::TensorId;

use super::cost as oc;
use super::kernel::{Kernel, OpCtx, TrafficEnv};
use super::OpCost;
use super::{attention, common, elementwise, gemm, norm, rope};

pub(crate) static LEAF: LeafKernel = LeafKernel;
pub(crate) static EMBED: EmbedKernel = EmbedKernel;
pub(crate) static RMSNORM: RmsNormKernel = RmsNormKernel;
pub(crate) static RMSNORM_HEADS: RmsNormHeadsKernel = RmsNormHeadsKernel;
pub(crate) static MATMUL_F32: MatMulF32Kernel = MatMulF32Kernel;
pub(crate) static MATMUL_Q4_0: MatMulQ40Kernel = MatMulQ40Kernel;
pub(crate) static MATMUL_Q8_0: MatMulQ80Kernel = MatMulQ80Kernel;
pub(crate) static ROPE: RopeKernel = RopeKernel;
pub(crate) static STORE_KV: StoreKvKernel = StoreKvKernel;
pub(crate) static ATTENTION: AttentionKernel = AttentionKernel;
pub(crate) static SILU: SiluKernel = SiluKernel;
pub(crate) static ADD: AddKernel = AddKernel;
pub(crate) static MUL: MulKernel = MulKernel;
pub(crate) static SWIGLU: SwiGluKernel = SwiGluKernel;
pub(crate) static COPY: CopyKernel = CopyKernel;
pub(crate) static SLICE_ROW: SliceRowKernel = SliceRowKernel;
pub(crate) static ADD_N: AddNKernel = AddNKernel;

pub(crate) static ALL: [&dyn Kernel; 17] = [
    &LEAF,
    &EMBED,
    &RMSNORM,
    &RMSNORM_HEADS,
    &MATMUL_F32,
    &MATMUL_Q4_0,
    &MATMUL_Q8_0,
    &ROPE,
    &STORE_KV,
    &ATTENTION,
    &SILU,
    &ADD,
    &MUL,
    &SWIGLU,
    &COPY,
    &SLICE_ROW,
    &ADD_N,
];

// ---------------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------------

/// Rows of the output actually computed this pass: tensor rows clamped
/// to the active lanes of a partially-filled batch step (and sliced
/// tails like the prefill last-row logits).
fn act_rows(meta: &TensorMeta, params: &ExecParams) -> usize {
    meta.rows().min(params.rows.max(1))
}

/// Flat-element unit count of element-wise operators.
fn flat_units(meta: &TensorMeta, params: &ExecParams) -> usize {
    act_rows(meta, params) * meta.row_len()
}

fn spread_into(t: &mut Traffic, placement: &Placement, bytes: f64) {
    let n = t.bytes.len();
    for (node, b) in placement.spread_bytes(bytes, n) {
        t.add_bytes(node, b);
    }
}

// ---------------------------------------------------------------------------
// Leaf
// ---------------------------------------------------------------------------

/// No producer: weights, inputs, KV caches. Zero units, zero work.
pub struct LeafKernel;

impl Kernel for LeafKernel {
    fn name(&self) -> &'static str {
        "leaf"
    }

    fn units(&self, _meta: &TensorMeta, _params: &ExecParams) -> usize {
        0
    }

    fn cost(
        &self,
        _graph: &Graph,
        _id: TensorId,
        _params: &ExecParams,
        _u0: usize,
        _u1: usize,
    ) -> OpCost {
        OpCost::default()
    }

    fn traffic(
        &self,
        _graph: &Graph,
        _id: TensorId,
        _params: &ExecParams,
        _u0: usize,
        _u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        Traffic::new(env.n_nodes)
    }

    unsafe fn run(&self, _ctx: &OpCtx<'_>, _u0: usize, _u1: usize) {}
}

// ---------------------------------------------------------------------------
// Embed
// ---------------------------------------------------------------------------

/// src: [emb_table, tokens] → [rows, d] f32; units = token rows.
pub struct EmbedKernel;

impl Kernel for EmbedKernel {
    fn name(&self) -> &'static str {
        "embed"
    }

    fn units(&self, meta: &TensorMeta, params: &ExecParams) -> usize {
        act_rows(meta, params)
    }

    fn cost(
        &self,
        graph: &Graph,
        id: TensorId,
        _params: &ExecParams,
        u0: usize,
        u1: usize,
    ) -> OpCost {
        oc::embed(graph.meta(id).row_len(), u0, u1)
    }

    fn traffic(
        &self,
        graph: &Graph,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        let meta = graph.meta(id);
        let c = self.cost(graph, id, params, u0, u1);
        let mut t = Traffic::new(env.n_nodes);
        t.flops += c.flops;
        spread_into(&mut t, &graph.meta(meta.src[0]).placement, c.weight_bytes);
        spread_into(&mut t, &meta.placement, c.output_bytes);
        t
    }

    unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize) {
        let table = ctx.f32s(ctx.src(0));
        let tokens = ctx.i32s(ctx.src(1));
        let out = ctx.f32s_mut(ctx.id);
        let d = ctx.meta().row_len();
        common::embed_rows(table, tokens, out, d, u0, u1);
    }
}

// ---------------------------------------------------------------------------
// RmsNorm
// ---------------------------------------------------------------------------

/// src: [x, gain]; RMS-normalize rows. Units = rows.
pub struct RmsNormKernel;

impl Kernel for RmsNormKernel {
    fn name(&self) -> &'static str {
        "rmsnorm"
    }

    fn units(&self, meta: &TensorMeta, params: &ExecParams) -> usize {
        act_rows(meta, params)
    }

    fn cost(
        &self,
        graph: &Graph,
        id: TensorId,
        _params: &ExecParams,
        u0: usize,
        u1: usize,
    ) -> OpCost {
        oc::rmsnorm(graph.meta(id).row_len(), u0, u1)
    }

    fn traffic(
        &self,
        graph: &Graph,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        let meta = graph.meta(id);
        let d = meta.row_len();
        let c = self.cost(graph, id, params, u0, u1);
        let mut t = Traffic::new(env.n_nodes);
        t.flops += c.flops;
        let x = graph.meta(meta.src[0]);
        t.add_placed(&x.placement, u0, u1, x.rows().max(1), d as f64 * 4.0);
        spread_into(&mut t, &graph.meta(meta.src[1]).placement, c.weight_bytes);
        t.add_placed(&meta.placement, u0, u1, meta.rows().max(1), d as f64 * 4.0);
        t
    }

    unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize) {
        let eps = match &ctx.meta().op {
            OpKind::RmsNorm { eps } => *eps,
            other => unreachable!("rmsnorm kernel on {}", other.name()),
        };
        let x = ctx.f32s(ctx.src(0));
        let g = ctx.f32s(ctx.src(1));
        let out = ctx.f32s_mut(ctx.id);
        norm::rmsnorm_t(self.tier(), x, g, out, ctx.meta().row_len(), eps, u0, u1);
    }

    fn tier(&self) -> KernelTier {
        KernelTier::active()
    }
}

// ---------------------------------------------------------------------------
// RmsNormHeads (Qwen3 QK-norm)
// ---------------------------------------------------------------------------

/// src: [x, gain]; per-head RMSNorm. Units = heads.
pub struct RmsNormHeadsKernel;

impl Kernel for RmsNormHeadsKernel {
    fn name(&self) -> &'static str {
        "rmsnorm_heads"
    }

    fn units(&self, meta: &TensorMeta, _params: &ExecParams) -> usize {
        match &meta.op {
            OpKind::RmsNormHeads { heads, .. } => *heads,
            other => unreachable!("rmsnorm_heads kernel on {}", other.name()),
        }
    }

    fn cost(
        &self,
        graph: &Graph,
        id: TensorId,
        _params: &ExecParams,
        u0: usize,
        u1: usize,
    ) -> OpCost {
        let meta = graph.meta(id);
        let head_dim = match &meta.op {
            OpKind::RmsNormHeads { head_dim, .. } => *head_dim,
            other => unreachable!("rmsnorm_heads kernel on {}", other.name()),
        };
        let elems = (meta.rows() * (u1 - u0) * head_dim) as f64;
        OpCost {
            flops: elems * 3.0,
            weight_bytes: 0.0,
            input_bytes: elems * 4.0,
            output_bytes: elems * 4.0,
        }
    }

    fn traffic(
        &self,
        graph: &Graph,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        let meta = graph.meta(id);
        let c = self.cost(graph, id, params, u0, u1);
        let mut t = Traffic::new(env.n_nodes);
        t.flops += c.flops;
        spread_into(&mut t, &graph.meta(meta.src[0]).placement, c.input_bytes);
        spread_into(&mut t, &meta.placement, c.output_bytes);
        t
    }

    unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize) {
        let (eps, heads, head_dim) = match &ctx.meta().op {
            OpKind::RmsNormHeads { eps, heads, head_dim } => (*eps, *heads, *head_dim),
            other => unreachable!("rmsnorm_heads kernel on {}", other.name()),
        };
        let x = ctx.f32s(ctx.src(0));
        let g = ctx.f32s(ctx.src(1));
        let out = ctx.f32s_mut(ctx.id);
        let rows = act_rows(ctx.meta(), ctx.params);
        norm::rmsnorm_heads_t(self.tier(), x, g, out, rows, heads, head_dim, eps, u0, u1);
    }

    fn tier(&self) -> KernelTier {
        KernelTier::active()
    }
}

// ---------------------------------------------------------------------------
// MatMul (per weight dtype)
// ---------------------------------------------------------------------------

/// Analytic profile shared by the matmul variants; `m` is the full row
/// count of the activation operand (the simulator charges the built
/// graph shape — active-lane clamping is a real-execution concern).
fn matmul_cost(graph: &Graph, id: TensorId, u0: usize, u1: usize) -> OpCost {
    let meta = graph.meta(id);
    let x = graph.meta(meta.src[0]);
    let w = graph.meta(meta.src[1]);
    oc::gemm(x.rows(), w.row_len(), u0, u1, w.dtype)
}

/// NUMA attribution shared by the matmul variants.
fn matmul_traffic(graph: &Graph, id: TensorId, u0: usize, u1: usize, env: &TrafficEnv) -> Traffic {
    let meta = graph.meta(id);
    let x = graph.meta(meta.src[0]);
    let w = graph.meta(meta.src[1]);
    let k = w.row_len();
    let n = w.rows();
    let m = x.rows();
    let c = oc::gemm(m, k, u0, u1, w.dtype);
    let mut t = Traffic::new(env.n_nodes);
    t.flops += c.flops;
    // exact row-range attribution for the dominant weight stream
    t.add_placed(&w.placement, u0, u1, n, w.dtype.row_bytes(k) as f64);
    // x is read in full by every worker of the stripe; with m > 1
    // (prefill) the blocked-GEMM stream amortizes over the node's L3;
    // at m = 1 (decode) partial cache dedup applies
    let amortize = if m > 1 {
        env.co_readers.max(1) as f64
    } else {
        env.bcast_amort.max(1.0)
    };
    spread_into(&mut t, &x.placement, c.input_bytes / amortize);
    spread_into(&mut t, &meta.placement, c.output_bytes);
    t
}

/// GEMM dimensions for real execution: `m` clamps to the pass's active
/// rows so a partially-filled batch step does no wasted work.
fn matmul_run_dims(ctx: &OpCtx<'_>) -> (usize, usize, usize) {
    let w = ctx.graph.meta(ctx.src(1));
    let m = ctx.graph.meta(ctx.src(0)).rows().min(ctx.params.rows.max(1));
    (m, w.row_len(), w.rows())
}

macro_rules! matmul_kernel {
    ($kernel:ident, $name:literal, $weights:ident, $gemm:path) => {
        #[doc = concat!("src: [x, w] → x·wᵀ with ", $name, " weights; units = output features.")]
        pub struct $kernel;

        impl Kernel for $kernel {
            fn name(&self) -> &'static str {
                $name
            }

            fn units(&self, meta: &TensorMeta, _params: &ExecParams) -> usize {
                meta.row_len()
            }

            fn cost(
                &self,
                graph: &Graph,
                id: TensorId,
                _params: &ExecParams,
                u0: usize,
                u1: usize,
            ) -> OpCost {
                matmul_cost(graph, id, u0, u1)
            }

            fn traffic(
                &self,
                graph: &Graph,
                id: TensorId,
                _params: &ExecParams,
                u0: usize,
                u1: usize,
                env: &TrafficEnv,
            ) -> Traffic {
                matmul_traffic(graph, id, u0, u1, env)
            }

            unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize) {
                let (m, k, n) = matmul_run_dims(ctx);
                let x = ctx.f32s(ctx.src(0));
                let w = ctx.$weights(ctx.src(1));
                let out = ctx.f32s_mut(ctx.id);
                $gemm(self.tier(), x, w, out, m, k, n, u0, u1);
            }

            fn tier(&self) -> KernelTier {
                KernelTier::active()
            }
        }
    };
}

matmul_kernel!(MatMulF32Kernel, "matmul_f32", f32s, gemm::gemm_f32_t);
matmul_kernel!(MatMulQ40Kernel, "matmul_q4_0", bytes, gemm::gemm_q4_0_t);
matmul_kernel!(MatMulQ80Kernel, "matmul_q8_0", bytes, gemm::gemm_q8_0_t);

// ---------------------------------------------------------------------------
// Rope
// ---------------------------------------------------------------------------

/// src: `[x]`; rotary embedding. Units = heads.
pub struct RopeKernel;

impl Kernel for RopeKernel {
    fn name(&self) -> &'static str {
        "rope"
    }

    fn units(&self, meta: &TensorMeta, _params: &ExecParams) -> usize {
        match &meta.op {
            OpKind::Rope { heads, .. } => *heads,
            other => unreachable!("rope kernel on {}", other.name()),
        }
    }

    fn cost(
        &self,
        graph: &Graph,
        id: TensorId,
        _params: &ExecParams,
        u0: usize,
        u1: usize,
    ) -> OpCost {
        let meta = graph.meta(id);
        let head_dim = match &meta.op {
            OpKind::Rope { head_dim, .. } => *head_dim,
            other => unreachable!("rope kernel on {}", other.name()),
        };
        oc::rope(meta.rows(), head_dim, u0, u1)
    }

    fn traffic(
        &self,
        graph: &Graph,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        let meta = graph.meta(id);
        let c = self.cost(graph, id, params, u0, u1);
        let mut t = Traffic::new(env.n_nodes);
        t.flops += c.flops;
        spread_into(&mut t, &graph.meta(meta.src[0]).placement, c.input_bytes);
        spread_into(&mut t, &meta.placement, c.output_bytes);
        t
    }

    unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize) {
        let (theta, heads, head_dim) = match &ctx.meta().op {
            OpKind::Rope { theta, heads, head_dim } => (*theta, *heads, *head_dim),
            other => unreachable!("rope kernel on {}", other.name()),
        };
        let x = ctx.f32s(ctx.src(0));
        let out = ctx.f32s_mut(ctx.id);
        // copy the head range, then rotate in place
        let rows = act_rows(ctx.meta(), ctx.params);
        let d = heads * head_dim;
        for r in 0..rows {
            let lo = r * d + u0 * head_dim;
            let hi = r * d + u1 * head_dim;
            out[lo..hi].copy_from_slice(&x[lo..hi]);
        }
        match &ctx.params.batch {
            Some(bv) => rope::rope_rows(out, heads, head_dim, &bv.pos, theta, u0, u1),
            None => rope::rope(out, rows, heads, head_dim, ctx.params.pos, theta, u0, u1),
        }
    }
}

// ---------------------------------------------------------------------------
// StoreKv
// ---------------------------------------------------------------------------

/// src: [kv_rows, cache-leaf]; writes rows into the cache at the
/// current position (output aliases the cache buffer). Units = kv heads.
pub struct StoreKvKernel;

impl Kernel for StoreKvKernel {
    fn name(&self) -> &'static str {
        "store_kv"
    }

    fn units(&self, meta: &TensorMeta, _params: &ExecParams) -> usize {
        match &meta.op {
            OpKind::StoreKv { kv_heads, .. } => *kv_heads,
            other => unreachable!("store_kv kernel on {}", other.name()),
        }
    }

    fn cost(
        &self,
        graph: &Graph,
        id: TensorId,
        _params: &ExecParams,
        u0: usize,
        u1: usize,
    ) -> OpCost {
        let meta = graph.meta(id);
        let head_dim = match &meta.op {
            OpKind::StoreKv { head_dim, .. } => *head_dim,
            other => unreachable!("store_kv kernel on {}", other.name()),
        };
        oc::store_kv(graph.meta(meta.src[0]).rows(), head_dim, u0, u1)
    }

    fn traffic(
        &self,
        graph: &Graph,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        let meta = graph.meta(id);
        let c = self.cost(graph, id, params, u0, u1);
        let mut t = Traffic::new(env.n_nodes);
        t.flops += c.flops;
        spread_into(&mut t, &graph.meta(meta.src[0]).placement, c.input_bytes);
        // writes land in the cache (src[1])
        spread_into(&mut t, &graph.meta(meta.src[1]).placement, c.output_bytes);
        t
    }

    unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize) {
        let (kv_heads, head_dim, max_seq) = match &ctx.meta().op {
            OpKind::StoreKv { kv_heads, head_dim, max_seq } => (*kv_heads, *head_dim, *max_seq),
            other => unreachable!("store_kv kernel on {}", other.name()),
        };
        let kv = ctx.f32s(ctx.src(0));
        // output aliases the cache (src[1]) buffer
        let cache = ctx.f32s_mut(ctx.src(1));
        let rows = ctx.graph.meta(ctx.src(0)).rows().min(ctx.params.rows.max(1));
        match &ctx.params.batch {
            Some(bv) => {
                attention::store_kv_rows(kv, cache, kv_heads, head_dim, max_seq, bv, u0, u1)
            }
            None => attention::store_kv(
                kv,
                cache,
                rows,
                kv_heads,
                head_dim,
                max_seq,
                ctx.params.pos,
                u0,
                u1,
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Attention
// ---------------------------------------------------------------------------

/// src: [q, k_cache, v_cache] → [rows, heads*head_dim]. Units = query
/// heads; the KV stream is the weight-like operand.
pub struct AttentionKernel;

impl AttentionKernel {
    fn geometry(meta: &TensorMeta) -> (usize, usize, usize, usize) {
        match &meta.op {
            OpKind::Attention { heads, kv_heads, head_dim, max_seq } => {
                (*heads, *kv_heads, *head_dim, *max_seq)
            }
            other => unreachable!("attention kernel on {}", other.name()),
        }
    }
}

impl Kernel for AttentionKernel {
    fn name(&self) -> &'static str {
        "attention"
    }

    fn units(&self, meta: &TensorMeta, _params: &ExecParams) -> usize {
        Self::geometry(meta).0
    }

    fn cost(
        &self,
        graph: &Graph,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
    ) -> OpCost {
        let meta = graph.meta(id);
        let (heads, kv_heads, head_dim, max_seq) = Self::geometry(meta);
        let kv_len = params.kv_len().min(max_seq);
        oc::attention(
            graph.meta(meta.src[0]).rows(),
            heads,
            kv_heads,
            head_dim,
            kv_len,
            graph.meta(meta.src[1]).dtype,
            u0,
            u1,
        )
    }

    fn traffic(
        &self,
        graph: &Graph,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        let meta = graph.meta(id);
        let (heads, kv_heads, head_dim, max_seq) = Self::geometry(meta);
        let kv_len = params.kv_len().min(max_seq);
        let c = self.cost(graph, id, params, u0, u1);
        let mut t = Traffic::new(env.n_nodes);
        t.flops += c.flops;
        spread_into(&mut t, &graph.meta(meta.src[0]).placement, c.input_bytes);
        // exact attribution of the K/V streams: kv head h occupies row
        // block [h*max_seq, h*max_seq + kv_len) of the cache
        let rep = (heads / kv_heads).max(1);
        let kvh0 = u0 / rep;
        let kvh1 = u1.div_ceil(rep);
        let kc = graph.meta(meta.src[1]);
        let vc = graph.meta(meta.src[2]);
        let cache_rows = kv_heads * max_seq;
        for h in kvh0..kvh1 {
            let r0 = h * max_seq;
            t.add_placed(&kc.placement, r0, r0 + kv_len, cache_rows, (head_dim * 4) as f64);
            t.add_placed(&vc.placement, r0, r0 + kv_len, cache_rows, (head_dim * 4) as f64);
        }
        spread_into(&mut t, &meta.placement, c.output_bytes);
        t
    }

    unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize) {
        let (heads, kv_heads, head_dim, max_seq) = Self::geometry(ctx.meta());
        let q = ctx.f32s(ctx.src(0));
        let k = ctx.f32s(ctx.src(1));
        let v = ctx.f32s(ctx.src(2));
        let out = ctx.f32s_mut(ctx.id);
        let rows = ctx.graph.meta(ctx.src(0)).rows().min(ctx.params.rows.max(1));
        match &ctx.params.batch {
            Some(bv) => attention::attention_rows_t(
                self.tier(),
                q,
                k,
                v,
                out,
                heads,
                kv_heads,
                head_dim,
                max_seq,
                bv,
                u0,
                u1,
            ),
            None => attention::attention_t(
                self.tier(),
                q,
                k,
                v,
                out,
                rows,
                heads,
                kv_heads,
                head_dim,
                max_seq,
                ctx.params.pos,
                u0,
                u1,
            ),
        }
    }

    fn tier(&self) -> KernelTier {
        KernelTier::active()
    }
}

// ---------------------------------------------------------------------------
// element-wise family (flat-element units)
// ---------------------------------------------------------------------------

/// Traffic of a one-input streaming op (silu/copy/slice_row).
fn unary_stream_traffic(
    graph: &Graph,
    id: TensorId,
    u0: usize,
    u1: usize,
    env: &TrafficEnv,
) -> Traffic {
    let meta = graph.meta(id);
    let c = oc::elementwise(1, u0, u1);
    let mut t = Traffic::new(env.n_nodes);
    t.flops += c.flops;
    spread_into(&mut t, &graph.meta(meta.src[0]).placement, c.input_bytes);
    spread_into(&mut t, &meta.placement, c.output_bytes);
    t
}

/// Traffic of a two-input streaming op (add/mul/swiglu).
fn binary_stream_traffic(
    graph: &Graph,
    id: TensorId,
    u0: usize,
    u1: usize,
    env: &TrafficEnv,
) -> Traffic {
    let meta = graph.meta(id);
    let c = oc::elementwise(2, u0, u1);
    let mut t = Traffic::new(env.n_nodes);
    t.flops += c.flops;
    spread_into(&mut t, &graph.meta(meta.src[0]).placement, c.input_bytes / 2.0);
    spread_into(&mut t, &graph.meta(meta.src[1]).placement, c.input_bytes / 2.0);
    spread_into(&mut t, &meta.placement, c.output_bytes);
    t
}

macro_rules! elementwise_kernel {
    ($kernel:ident, $name:literal, $inputs:literal, $traffic:ident,
     |$ctx:ident, $u0:ident, $u1:ident| $body:expr) => {
        #[doc = concat!("Element-wise `", $name, "` over flat-element units.")]
        pub struct $kernel;

        impl Kernel for $kernel {
            fn name(&self) -> &'static str {
                $name
            }

            fn units(&self, meta: &TensorMeta, params: &ExecParams) -> usize {
                flat_units(meta, params)
            }

            fn cost(
                &self,
                _graph: &Graph,
                _id: TensorId,
                _params: &ExecParams,
                u0: usize,
                u1: usize,
            ) -> OpCost {
                oc::elementwise($inputs, u0, u1)
            }

            fn traffic(
                &self,
                graph: &Graph,
                id: TensorId,
                _params: &ExecParams,
                u0: usize,
                u1: usize,
                env: &TrafficEnv,
            ) -> Traffic {
                $traffic(graph, id, u0, u1, env)
            }

            unsafe fn run(&self, $ctx: &OpCtx<'_>, $u0: usize, $u1: usize) {
                $body
            }
        }
    };
}

elementwise_kernel!(SiluKernel, "silu", 1, unary_stream_traffic, |ctx, u0, u1| {
    let a = ctx.f32s(ctx.src(0));
    let out = ctx.f32s_mut(ctx.id);
    elementwise::silu(a, out, u0, u1);
});

elementwise_kernel!(AddKernel, "add", 2, binary_stream_traffic, |ctx, u0, u1| {
    let a = ctx.f32s(ctx.src(0));
    let b = ctx.f32s(ctx.src(1));
    let out = ctx.f32s_mut(ctx.id);
    elementwise::add(a, b, out, u0, u1);
});

elementwise_kernel!(MulKernel, "mul", 2, binary_stream_traffic, |ctx, u0, u1| {
    let a = ctx.f32s(ctx.src(0));
    let b = ctx.f32s(ctx.src(1));
    let out = ctx.f32s_mut(ctx.id);
    elementwise::mul(a, b, out, u0, u1);
});

elementwise_kernel!(SwiGluKernel, "swiglu", 2, binary_stream_traffic, |ctx, u0, u1| {
    let g = ctx.f32s(ctx.src(0));
    let u = ctx.f32s(ctx.src(1));
    let out = ctx.f32s_mut(ctx.id);
    elementwise::swiglu(g, u, out, u0, u1);
});

elementwise_kernel!(CopyKernel, "copy", 1, unary_stream_traffic, |ctx, u0, u1| {
    let a = ctx.f32s(ctx.src(0));
    let out = ctx.f32s_mut(ctx.id);
    out[u0..u1].copy_from_slice(&a[u0..u1]);
});

// ---------------------------------------------------------------------------
// SliceRow
// ---------------------------------------------------------------------------

/// src: [x ([rows, d])] → `x[row]` as [1, d]. Units = d.
pub struct SliceRowKernel;

impl Kernel for SliceRowKernel {
    fn name(&self) -> &'static str {
        "slice_row"
    }

    fn units(&self, meta: &TensorMeta, _params: &ExecParams) -> usize {
        meta.row_len()
    }

    fn cost(
        &self,
        _graph: &Graph,
        _id: TensorId,
        _params: &ExecParams,
        u0: usize,
        u1: usize,
    ) -> OpCost {
        oc::elementwise(1, u0, u1)
    }

    fn traffic(
        &self,
        graph: &Graph,
        id: TensorId,
        _params: &ExecParams,
        u0: usize,
        u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        unary_stream_traffic(graph, id, u0, u1, env)
    }

    unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize) {
        let row = match &ctx.meta().op {
            OpKind::SliceRow { row } => *row,
            other => unreachable!("slice_row kernel on {}", other.name()),
        };
        let a = ctx.f32s(ctx.src(0));
        let out = ctx.f32s_mut(ctx.id);
        let d = ctx.meta().row_len();
        out[u0..u1].copy_from_slice(&a[row * d + u0..row * d + u1]);
    }
}

// ---------------------------------------------------------------------------
// AddN (the Gather reduction)
// ---------------------------------------------------------------------------

/// src: [p_0, ..., p_{G-1}] → Σ p_g. Units = flat elements.
pub struct AddNKernel;

impl Kernel for AddNKernel {
    fn name(&self) -> &'static str {
        "add_n"
    }

    fn units(&self, meta: &TensorMeta, params: &ExecParams) -> usize {
        flat_units(meta, params)
    }

    fn cost(
        &self,
        graph: &Graph,
        id: TensorId,
        _params: &ExecParams,
        u0: usize,
        u1: usize,
    ) -> OpCost {
        let streams = graph.meta(id).src.len() as f64;
        let elems = (u1 - u0) as f64;
        OpCost {
            flops: elems * streams,
            weight_bytes: 0.0,
            input_bytes: elems * 4.0 * streams,
            output_bytes: elems * 4.0,
        }
    }

    fn traffic(
        &self,
        graph: &Graph,
        id: TensorId,
        _params: &ExecParams,
        u0: usize,
        u1: usize,
        env: &TrafficEnv,
    ) -> Traffic {
        let meta = graph.meta(id);
        let units = u1 - u0;
        let bytes = (units * 4) as f64;
        let mut t = Traffic::new(env.n_nodes);
        t.flops += (units * meta.src.len()) as f64;
        for s in &meta.src {
            spread_into(&mut t, &graph.meta(*s).placement, bytes);
        }
        spread_into(&mut t, &meta.placement, bytes);
        t
    }

    unsafe fn run(&self, ctx: &OpCtx<'_>, u0: usize, u1: usize) {
        let out = ctx.f32s_mut(ctx.id);
        let src = &ctx.meta().src;
        let first = ctx.f32s(src[0]);
        out[u0..u1].copy_from_slice(&first[u0..u1]);
        for s in &src[1..] {
            let p = ctx.f32s(*s);
            common::accumulate(p, out, u0, u1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::memory::MemoryPool;
    use crate::ops::kernel::op_traffic;
    use crate::sched::BatchView;
    use crate::tensor::{DType, TensorBundle};

    fn env2() -> TrafficEnv {
        TrafficEnv { n_nodes: 2, co_readers: 1, bcast_amort: 1.0 }
    }

    unsafe fn f32s<'a>(pool: &'a MemoryPool, graph: &Graph, id: TensorId) -> &'a [f32] {
        let b = graph.buf(id);
        pool.arena(b.arena).f32s(b.off, b.len / 4)
    }

    #[allow(clippy::mut_from_ref)]
    unsafe fn f32s_mut<'a>(pool: &'a MemoryPool, graph: &Graph, id: TensorId) -> &'a mut [f32] {
        let b = graph.buf(id);
        pool.arena(b.arena).f32s_mut(b.off, b.len / 4)
    }

    /// Execute units `[u0, u1)` of `id` through its resolved kernel.
    fn run_units(
        graph: &Graph,
        pool: &MemoryPool,
        id: TensorId,
        params: &ExecParams,
        u0: usize,
        u1: usize,
    ) {
        if u0 >= u1 {
            return;
        }
        let ctx = OpCtx { graph, pool, id, params };
        unsafe { graph.kernel(id).run(&ctx, u0, u1) }
    }

    /// Build a tiny graph, fill leaves, execute serially, check numbers.
    #[test]
    fn serial_execution_of_small_chain() {
        let pool = MemoryPool::new(1, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 4], Placement::Node(0));
        let w = b.leaf("w", DType::F32, vec![2, 4], Placement::Node(0));
        let y = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let z = b.add(&y, &y);
        let (graph, pool) = b.finish();
        let pool = pool.unwrap();

        unsafe {
            f32s_mut(&pool, &graph, x).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
            f32s_mut(&pool, &graph, w)
                .copy_from_slice(&[1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        }
        let params = ExecParams::dense(0, 1);
        for entry in &graph.exec {
            for id in entry.bundle.iter() {
                let units = graph.kernel(id).units(graph.meta(id), &params);
                run_units(&graph, &pool, id, &params, 0, units);
            }
        }
        unsafe {
            assert_eq!(f32s(&pool, &graph, y.single()), &[1.0, 2.0]);
            assert_eq!(f32s(&pool, &graph, z.single()), &[2.0, 4.0]);
        }
    }

    #[test]
    fn addn_sums_partials() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let p0 = b.leaf("p0", DType::F32, vec![1, 4], Placement::Node(0));
        let p1 = b.leaf("p1", DType::F32, vec![1, 4], Placement::Node(1));
        let z = b.gather(&TensorBundle::new(vec![p0, p1]));
        let (graph, pool) = b.finish();
        let pool = pool.unwrap();
        unsafe {
            f32s_mut(&pool, &graph, p0).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
            f32s_mut(&pool, &graph, p1).copy_from_slice(&[10.0, 20.0, 30.0, 40.0]);
        }
        let params = ExecParams::dense(0, 1);
        run_units(&graph, &pool, z.single(), &params, 0, 4);
        unsafe {
            assert_eq!(f32s(&pool, &graph, z.single()), &[11.0, 22.0, 33.0, 44.0]);
        }
    }

    #[test]
    fn batched_store_kv_targets_per_row_slots() {
        // paged cache of 2 pages × 4 positions; two rows land in their
        // own page's position (page 0 pos 2, page 1 pos 0)
        let pool = MemoryPool::new(1, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let kvsrc = b.leaf("kv", DType::F32, vec![2, 4], Placement::Node(0));
        let cache = b.kv_leaf("cache", vec![1, 8, 4], Placement::Node(0));
        let stored = b.store_kv(&TensorBundle::one(kvsrc), &TensorBundle::one(cache), 1, 4, 8);
        let (graph, pool) = b.finish();
        let pool = pool.unwrap();
        unsafe {
            f32s_mut(&pool, &graph, kvsrc)
                .copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        }
        let view = BatchView::new(4, vec![vec![0], vec![1]], vec![2, 0]);
        let params = ExecParams::batched(view);
        run_units(&graph, &pool, stored.single(), &params, 0, 1);
        unsafe {
            let c = f32s(&pool, &graph, cache);
            // row 0 → page 0 position 2
            assert_eq!(&c[2 * 4..3 * 4], &[1.0, 2.0, 3.0, 4.0]);
            // row 1 → page 1 (base 4) position 0
            assert_eq!(&c[4 * 4..5 * 4], &[5.0, 6.0, 7.0, 8.0]);
        }
    }

    #[test]
    fn store_kv_aliases_cache() {
        let pool = MemoryPool::new(1, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let kvsrc = b.leaf("kv", DType::F32, vec![1, 2 * 4], Placement::Node(0));
        let cache = b.kv_leaf("cache", vec![2, 8, 4], Placement::Node(0));
        let stored = b.store_kv(&TensorBundle::one(kvsrc), &TensorBundle::one(cache), 2, 4, 8);
        let (graph, pool) = b.finish();
        let pool = pool.unwrap();
        assert_eq!(graph.buf(stored.single()), graph.buf(cache));
        unsafe {
            f32s_mut(&pool, &graph, kvsrc)
                .copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        }
        let params = ExecParams::dense(3, 1);
        run_units(&graph, &pool, stored.single(), &params, 0, 2);
        unsafe {
            let c = f32s(&pool, &graph, cache);
            // head 0 slot 3
            assert_eq!(&c[3 * 4..4 * 4], &[1.0, 2.0, 3.0, 4.0]);
            // head 1 slot 3 (head stride = 8 slots × 4)
            assert_eq!(&c[8 * 4 + 3 * 4..8 * 4 + 4 * 4], &[5.0, 6.0, 7.0, 8.0]);
        }
    }

    // --- traffic attribution (ported from the old sched::traffic) ----------

    #[test]
    fn matmul_weight_bytes_go_to_weight_node() {
        let mut b = GraphBuilder::sim(vec![0, 1], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 64], Placement::Node(0));
        let w = b.leaf("w", DType::Q4_0, vec![32, 64], Placement::Node(1));
        let y = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let (g, _) = b.finish();
        let t = op_traffic(&g, y.single(), &ExecParams::dense(0, 1), 0, 32, &env2());
        // weights (36 B/row × 32 rows) on node 1
        assert!(t.bytes[1] >= 32.0 * 36.0);
        // activation (64×4) on node 0
        assert!(t.bytes[0] >= 256.0);
        assert_eq!(t.flops, 2.0 * 64.0 * 32.0);
    }

    #[test]
    fn matmul_row_range_attribution_is_exact() {
        // weights sharded: rows 0..16 node0, 16..32 node1; a worker doing
        // rows 0..16 must read weights ONLY from node 0
        let mut b = GraphBuilder::sim(vec![0, 1], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 64], Placement::Node(0));
        let w = b.leaf("w", DType::F32, vec![32, 64], Placement::even_shards(32, 2));
        let y = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let (g, _) = b.finish();
        let t = op_traffic(&g, y.single(), &ExecParams::dense(0, 1), 0, 16, &env2());
        // node1 gets only output-spread bytes (output on node 0) → 0
        assert_eq!(t.bytes[1], 0.0);
    }

    #[test]
    fn attention_kv_stream_is_charged_to_cache_node() {
        let mut b = GraphBuilder::sim(vec![0, 1], Placement::Node(0));
        let q = b.leaf("q", DType::F32, vec![1, 64], Placement::Node(0));
        let kc = b.kv_leaf("k", vec![2, 16, 16], Placement::Node(1));
        let vc = b.kv_leaf("v", vec![2, 16, 16], Placement::Node(1));
        let o = b.attention(
            &TensorBundle::one(q),
            &TensorBundle::one(kc),
            &TensorBundle::one(vc),
            4,
            2,
            16,
            16,
        );
        let (g, _) = b.finish();
        let p = ExecParams::dense(7, 1);
        let t = op_traffic(&g, o.single(), &p, 0, 4, &env2());
        // kv_len = 8; 2 kv heads × 8 pos × 16 dim × 4 B × 2 (K+V)
        let expect = 2.0 * 8.0 * 16.0 * 4.0 * 2.0;
        assert!((t.bytes[1] - expect).abs() < 1e-6, "{} vs {expect}", t.bytes[1]);
    }

    #[test]
    fn partition_halves_traffic() {
        let mut b = GraphBuilder::sim(vec![0], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 64], Placement::Node(0));
        let w = b.leaf("w", DType::Q4_0, vec![32, 64], Placement::Node(0));
        let y = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let (g, _) = b.finish();
        let e = TrafficEnv { n_nodes: 1, co_readers: 1, bcast_amort: 1.0 };
        let full = op_traffic(&g, y.single(), &ExecParams::dense(0, 1), 0, 32, &e);
        let half = op_traffic(&g, y.single(), &ExecParams::dense(0, 1), 0, 16, &e);
        // weight stream halves; activation stream does not
        let w_bytes = 32.0 * 36.0;
        assert!(full.bytes[0] - half.bytes[0] > w_bytes / 2.0 * 0.9);
        assert!(full.flops / half.flops > 1.99 && full.flops / half.flops < 2.01);
    }

    #[test]
    fn empty_unit_range_yields_empty_traffic() {
        let mut b = GraphBuilder::sim(vec![0], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 64], Placement::Node(0));
        let w = b.leaf("w", DType::Q4_0, vec![32, 64], Placement::Node(0));
        let y = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let (g, _) = b.finish();
        let e = TrafficEnv { n_nodes: 1, co_readers: 1, bcast_amort: 1.0 };
        let t = op_traffic(&g, y.single(), &ExecParams::dense(0, 1), 5, 5, &e);
        assert_eq!(t.total_bytes(), 0.0);
        assert_eq!(t.flops, 0.0);
    }
}
