//! Chrome-trace export of a simulated pass (`chrome://tracing` /
//! Perfetto format): one track per simulated NUMA node, one slice per
//! operator execution — the observability tool behind the §Perf
//! analysis of where a decode step's virtual time goes.

use crate::graph::Graph;
use crate::numa::CostModel;
use crate::ops::kernel::{op_traffic, TrafficEnv};
use crate::sched::{ExecParams, PassPlan, SyncMode};
use crate::threads::Organization;
use crate::util::chunk_range;
use crate::util::json::Json;

/// One traced operator execution.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: String,
    /// Virtual start/duration in microseconds.
    pub start_us: f64,
    pub dur_us: f64,
    /// Track: the NUMA node whose workers ran this slice.
    pub node: usize,
    pub group: usize,
}

/// Trace one pass over the graph with per-*group* granularity (a slice
/// per operator per thread group, placed at the group's clock).
/// Compiles the same [`PassPlan`] the executors consume (Sync-B
/// discipline) so traced unit partitions match executed ones exactly.
pub fn trace_pass(
    graph: &Graph,
    model: &CostModel,
    cores: &[crate::numa::Core],
    org_tp: &Organization,
    params: ExecParams,
) -> Vec<TraceEvent> {
    let plan = PassPlan::compile(graph, &params, cores.len(), org_tp, SyncMode::SyncB);
    let nn = model.n_nodes();
    let w = cores.len();
    let mut clocks = vec![0.0f64; w];
    let mut events = Vec::new();
    let mut per_node = vec![0usize; nn];
    for c in cores {
        per_node[c.node] += 1;
    }

    for step in &plan.steps {
        let ei = step.entry;
        if step.width == 1 {
            let part = &plan.parts[step.part0];
            let meta = graph.meta(part.id);
            let start = clocks.iter().copied().fold(0.0, f64::max);
            let workers: Vec<(usize, crate::numa::cost::Traffic)> = cores
                .iter()
                .enumerate()
                .map(|(wi, c)| {
                    let (u0, u1) = chunk_range(part.units, w, wi);
                    let env = TrafficEnv {
                        n_nodes: nn,
                        co_readers: per_node[c.node],
                        bcast_amort: model.topo.bcast_amort,
                    };
                    (c.id, op_traffic(graph, part.id, &params, u0, u1, &env))
                })
                .collect();
            let times = model.op_times(&workers, ei as u64);
            let dur = times.iter().copied().fold(0.0, f64::max);
            for c in clocks.iter_mut() {
                *c = start + dur;
            }
            events.push(TraceEvent {
                name: format!("{} ({})", meta.name, meta.op.name()),
                start_us: start * 1e6,
                dur_us: dur * 1e6,
                node: 0,
                group: usize::MAX, // whole pool
            });
        } else {
            for (gi, g) in org_tp.groups.iter().enumerate() {
                let part = &plan.parts[step.part0 + gi];
                let meta = graph.meta(part.id);
                let start = g.workers.iter().map(|&wk| clocks[wk]).fold(0.0, f64::max);
                let workers: Vec<(usize, crate::numa::cost::Traffic)> = g
                    .workers
                    .iter()
                    .enumerate()
                    .map(|(rank, &wk)| {
                        let (u0, u1) = chunk_range(part.units, g.size(), rank);
                        let env = TrafficEnv {
                            n_nodes: nn,
                            co_readers: per_node[cores[wk].node],
                            bcast_amort: model.topo.bcast_amort,
                        };
                        (cores[wk].id, op_traffic(graph, part.id, &params, u0, u1, &env))
                    })
                    .collect();
                let times = model.op_times(&workers, ei as u64);
                let dur = times.iter().copied().fold(0.0, f64::max);
                for &wk in &g.workers {
                    clocks[wk] = start + dur;
                }
                events.push(TraceEvent {
                    name: format!("{} ({})", meta.name, meta.op.name()),
                    start_us: start * 1e6,
                    dur_us: dur * 1e6,
                    node: g.node,
                    group: gi,
                });
            }
        }
    }
    events
}

/// Serialize as Chrome trace JSON (load in `chrome://tracing` or
/// Perfetto). Built on the runtime tracer's shared span schema
/// ([`crate::trace::chrome_event`]): pid = NUMA node, tid = lane
/// (0 = whole pool, group g renders as g+1), `args.kind` = "kernel" —
/// so a virtual-time trace of a pass diffs field-for-field against a
/// host trace of the same pass.
pub fn to_chrome_json(events: &[TraceEvent]) -> String {
    let arr: Vec<Json> = events
        .iter()
        .map(|e| {
            let tid = if e.group == usize::MAX { 0 } else { e.group + 1 };
            let mut args: Vec<(&str, Json)> =
                vec![("kind", "kernel".into()), ("virtual", true.into())];
            if e.group != usize::MAX {
                args.push(("group", e.group.into()));
            }
            crate::trace::chrome_event(&e.name, e.start_us, e.dur_us, e.node, tid, args)
        })
        .collect();
    crate::trace::chrome_doc(arr).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::Strategy;
    use crate::model::{ModelConfig, ModelGraphs};
    use crate::numa::Topology;
    use crate::sched::SyncMode;

    #[test]
    fn trace_covers_all_entries_and_is_monotonic() {
        let topo = Topology::kunpeng920();
        let s = Strategy::arclight_tp(2, SyncMode::SyncB);
        let m = ModelGraphs::build(
            s.build_spec(ModelConfig::tiny(), 4).with_sim_only(true),
        );
        let cores = s.bind_cores(&topo, 8);
        let (_, tp) = s.organizations(&cores);
        let events = trace_pass(
            &m.decode,
            &CostModel::new(topo),
            &cores,
            &tp,
            ExecParams::dense(3, 1),
        );
        // every exec entry yields ≥1 event; TP entries yield one per group
        assert!(events.len() >= m.decode.exec.len());
        for e in &events {
            assert!(e.dur_us > 0.0, "{} has zero duration", e.name);
            assert!(e.start_us >= 0.0);
        }
        // single-mode events are globally ordered
        let singles: Vec<&TraceEvent> = events.iter().filter(|e| e.group == usize::MAX).collect();
        for w in singles.windows(2) {
            assert!(w[1].start_us >= w[0].start_us + w[0].dur_us - 1e-6);
        }
    }

    #[test]
    fn chrome_json_parses_back() {
        let events = vec![TraceEvent {
            name: "matmul.q".into(),
            start_us: 1.5,
            dur_us: 12.0,
            node: 2,
            group: 1,
        }];
        let s = to_chrome_json(&events);
        let j = crate::util::json::Json::parse(&s).unwrap();
        let arr = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(arr[0].get("dur").unwrap().as_f64(), Some(12.0));
        // shared span schema with the runtime tracer: pid = node,
        // tid = lane (group 1 -> 2), kind tagged in args
        assert_eq!(arr[0].get("pid").unwrap().as_usize(), Some(2));
        assert_eq!(arr[0].get("tid").unwrap().as_usize(), Some(2));
        assert_eq!(arr[0].get("args").unwrap().get("kind").unwrap().as_str(), Some("kernel"));
    }
}
