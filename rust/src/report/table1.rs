//! Table 1: memory access speed (GB/s) for every core-node ×
//! memory-node combination.
//!
//! The paper measures this with a streaming microbenchmark on its
//! Kunpeng-920 box; we regenerate it by running the *same experiment
//! against the simulator*: all cores of node `i` stream a large buffer
//! homed on node `j`, and the observed aggregate GB/s is reported.
//! Recovering the configured matrix end-to-end validates the
//! contention model (shared channels must cancel out exactly).

use crate::numa::cost::Traffic;
use crate::numa::{CostModel, Topology};

/// Aggregate streaming bandwidth matrix (GB/s): `out[i][j]` = cores of
/// node `i` reading memory of node `j`.
pub fn bandwidth_table(topo: &Topology, readers_per_node: usize, buffer_gb: f64) -> Vec<Vec<f64>> {
    let mut topo = topo.clone();
    topo.jitter = 0.0; // the paper's microbench reports steady-state
    topo.op_dispatch = 0.0;
    let n = topo.n_nodes();
    let model = CostModel::new(topo.clone());
    let bytes_total = buffer_gb * 1e9;
    let mut out = vec![vec![0.0; n]; n];
    for cn in 0..n {
        for mn in 0..n {
            // every reader core scans its slice of the buffer
            let per_reader = bytes_total / readers_per_node as f64;
            let workers: Vec<(usize, Traffic)> = (0..readers_per_node)
                .map(|i| {
                    let core = cn * topo.cores_per_node + i;
                    let mut t = Traffic::new(n);
                    t.add_bytes(mn, per_reader);
                    (core, t)
                })
                .collect();
            let times = model.op_times(&workers, 1);
            let elapsed = times.iter().copied().fold(0.0, f64::max);
            out[cn][mn] = bytes_total / elapsed / 1e9;
        }
    }
    out
}

/// Render in the paper's layout.
pub fn render(table: &[Vec<f64>]) -> String {
    use std::fmt::Write;
    let n = table.len();
    let mut s = String::new();
    let _ = writeln!(s, "# Table 1: memory access speed (GB/s), cores × memory node");
    let _ = write!(s, "{:>10}", "cores\\mem");
    for j in 0..n {
        let _ = write!(s, "  node {j:>3}");
    }
    let _ = writeln!(s);
    for (i, row) in table.iter().enumerate() {
        let _ = write!(s, "{:>10}", format!("node {i}"));
        for v in row {
            let _ = write!(s, "  {v:>8.0}");
        }
        let _ = writeln!(s);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_the_configured_matrix() {
        let topo = Topology::kunpeng920();
        let t = bandwidth_table(&topo, 48, 1.0);
        for i in 0..4 {
            for j in 0..4 {
                let expect = topo.bandwidth(i, j) / 1e9;
                assert!(
                    (t[i][j] - expect).abs() < 0.5,
                    "({i},{j}): {} vs {expect}",
                    t[i][j]
                );
            }
        }
    }

    #[test]
    fn local_is_about_4x_remote() {
        let t = bandwidth_table(&Topology::kunpeng920(), 48, 0.5);
        let ratio = t[0][0] / t[0][3];
        assert!(ratio > 3.5 && ratio < 5.0, "ratio {ratio}");
    }

    #[test]
    fn render_has_all_rows() {
        let t = bandwidth_table(&Topology::kunpeng920(), 8, 0.1);
        let s = render(&t);
        assert_eq!(s.lines().count(), 6);
        assert!(s.contains("node 3"));
    }
}
