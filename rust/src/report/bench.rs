//! Bench-row model shared by the kernel benches (`ops_hotpath`).
//!
//! A [`BenchRow`] carries the measured p50 latency of one kernel plus
//! the analytic bytes-touched figure from [`crate::ops::cost`], so the
//! JSON report can state achieved GB/s and the roofline fraction
//! against a node's memory bandwidth
//! ([`crate::numa::Topology::bandwidth`]) instead of bare elapsed
//! times. Keeping the row
//! construction in the library (the bench binaries are compiled with
//! `test = false`) lets the traffic-model plumbing be pinned by unit
//! tests — the `bytes_touched`-missing-for-attention regression lives
//! in [`tests`].

use crate::util::json::{obj, Json};

/// One benchmarked kernel: measured latency plus the analytic traffic
/// model that turns it into achieved GB/s.
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// Row label (kernel + shape, e.g. `"gemv_q4_0 n=2048 k=2048"`).
    pub name: String,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// Bytes touched per iteration per the [`crate::ops::cost`] model;
    /// `None` for rows without a byte model (e.g. end-to-end decode).
    pub bytes_touched: Option<f64>,
    /// SIMD tier the kernel dispatched on (`KernelTier::name`).
    pub tier: &'static str,
}

impl BenchRow {
    /// Achieved GB/s: bytes over p50, `None` without a byte model or a
    /// positive measurement.
    pub fn gbs(&self) -> Option<f64> {
        match self.bytes_touched {
            Some(b) if self.p50_s > 0.0 => Some(b / self.p50_s / 1e9),
            _ => None,
        }
    }

    /// JSON row for the bench report. `node_bw` is one NUMA node's
    /// local memory bandwidth in bytes/s; rows with a byte model get
    /// `bytes_touched`, `gbs` and `roofline_frac` fields.
    pub fn to_json(&self, node_bw: f64) -> Json {
        let mut fields = vec![
            ("name", Json::from(self.name.as_str())),
            ("p50_s", self.p50_s.into()),
            ("tier", self.tier.into()),
        ];
        if let Some(b) = self.bytes_touched {
            fields.push(("bytes_touched", b.into()));
        }
        if let Some(g) = self.gbs() {
            fields.push(("gbs", g.into()));
            if node_bw > 0.0 {
                fields.push(("roofline_frac", (g * 1e9 / node_bw).into()));
            }
        }
        obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn attention_rows_carry_bytes_touched() {
        // regression: the --quick JSON used to omit bytes_touched for
        // attention kernels because the cost.rs traffic model was never
        // threaded into the bench row; the GB/s column needs it
        let bytes = crate::ops::cost::attention(1, 16, 8, 64, 96, DType::F32, 0, 16).total_bytes();
        assert!(bytes > 0.0);
        let row = BenchRow {
            name: "attention kv=96".into(),
            p50_s: 1e-4,
            bytes_touched: Some(bytes),
            tier: "scalar",
        };
        let j = row.to_json(100.0e9);
        assert_eq!(j.get("bytes_touched").unwrap().as_f64(), Some(bytes));
        let gbs = j.get("gbs").unwrap().as_f64().unwrap();
        assert!((gbs - bytes / 1e-4 / 1e9).abs() < 1e-9);
        let frac = j.get("roofline_frac").unwrap().as_f64().unwrap();
        assert!((frac - gbs * 1e9 / 100.0e9).abs() < 1e-12);
        assert_eq!(j.get("tier").unwrap().as_str(), Some("scalar"));
    }

    #[test]
    fn rows_without_byte_model_omit_gbs() {
        let row =
            BenchRow { name: "decode e2e".into(), p50_s: 0.01, bytes_touched: None, tier: "avx2" };
        assert!(row.gbs().is_none());
        let j = row.to_json(100.0e9);
        assert!(j.get("bytes_touched").is_none());
        assert!(j.get("gbs").is_none());
        assert!(j.get("roofline_frac").is_none());
        assert_eq!(j.get("p50_s").unwrap().as_f64(), Some(0.01));
    }

    #[test]
    fn zero_time_rows_guard_against_inf() {
        let row = BenchRow {
            name: "degenerate".into(),
            p50_s: 0.0,
            bytes_touched: Some(1e6),
            tier: "scalar",
        };
        assert!(row.gbs().is_none());
        let j = row.to_json(100.0e9);
        assert!(j.get("gbs").is_none());
    }
}
