//! Regeneration of the paper's evaluation artifacts (Table 1,
//! Figures 10–13) on the simulated testbed.
//!
//! Each function returns structured series and can pretty-print the
//! same rows the paper reports. Absolute numbers are simulator
//! estimates (see DESIGN.md "Hardware substitution"); the *shape* —
//! who wins, by what factor, where the crossovers sit — is the
//! reproduction target.

pub mod bench;
pub mod figures;
pub mod table1;
pub mod trace;

pub use bench::BenchRow;
pub use figures::{decode_tok_s, prefill_tok_s, FigureSeries, SimPoint};
pub use table1::bandwidth_table;

/// Pretty-print a set of series as an aligned text table:
/// rows = x values, columns = series.
pub fn render_table(title: &str, xlabel: &str, series: &[FigureSeries]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "# {title}");
    let _ = write!(out, "{xlabel:>12}");
    for s in series {
        let _ = write!(out, "  {:>22}", s.label);
    }
    let _ = writeln!(out);
    let xs = &series[0].xs;
    for (i, x) in xs.iter().enumerate() {
        let _ = write!(out, "{x:>12}");
        for s in series {
            match s.ys.get(i) {
                Some(y) => {
                    let _ = write!(out, "  {y:>22.2}");
                }
                None => {
                    let _ = write!(out, "  {:>22}", "-");
                }
            }
        }
        let _ = writeln!(out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let s = vec![
            FigureSeries { label: "a".into(), xs: vec![6.0, 12.0], ys: vec![1.0, 2.0] },
            FigureSeries { label: "b".into(), xs: vec![6.0, 12.0], ys: vec![3.0, 4.0] },
        ];
        let t = render_table("T", "threads", &s);
        assert!(t.contains("# T"));
        assert!(t.lines().count() >= 3);
        assert!(t.contains("3.00"));
    }
}
