//! Figures 10–13: decode/prefill throughput sweeps on the simulated
//! testbed (Qwen3-4B Q4_0, the paper's §4 setup).

use crate::baseline::Strategy;
use crate::model::{ModelConfig, ModelGraphs};
use crate::numa::Topology;
use crate::sched::{ExecParams, Executor};

/// One measured configuration.
#[derive(Clone, Debug)]
pub struct SimPoint {
    pub strategy: String,
    pub threads: usize,
    pub tok_per_s: f64,
    pub remote_fraction: f64,
}

/// A plot series: y = tok/s over x = thread count.
#[derive(Clone, Debug)]
pub struct FigureSeries {
    pub label: String,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
}

/// Decode throughput (token/s) of one configuration: prompt ingested,
/// then `gen` steps. Step latency is sampled at `samples` evenly-spaced
/// positions (attention cost is linear in KV length, so the sampled
/// mean matches the full sum). The simulator is driven through the
/// backend-agnostic `Executor` trait.
pub fn decode_tok_s(
    cfg: &ModelConfig,
    strategy: Strategy,
    threads: usize,
    topo: &Topology,
    prompt: usize,
    gen: usize,
    samples: usize,
) -> SimPoint {
    let spec = strategy.build_spec(cfg.clone(), topo.n_nodes()).with_sim_only(true);
    let m = ModelGraphs::build(spec);
    let ex = strategy.sim_executor(topo, threads);

    let samples = samples.max(1).min(gen);
    let mut total = 0.0;
    let mut remote = 0.0;
    for s in 0..samples {
        let pos = prompt + (gen - 1) * s / samples.max(1);
        let rep = ex.run(&m.decode, &ExecParams::dense(pos, 1).with_seed(s as u64 + 1));
        total += rep.elapsed;
        remote += rep.remote_fraction();
    }
    let mean_step = total / samples as f64;
    SimPoint {
        strategy: strategy.name(),
        threads,
        tok_per_s: 1.0 / mean_step,
        remote_fraction: remote / samples as f64,
    }
}

/// Prefill throughput (token/s): one pass over `prompt` tokens.
pub fn prefill_tok_s(
    cfg: &ModelConfig,
    strategy: Strategy,
    threads: usize,
    topo: &Topology,
    prompt: usize,
) -> SimPoint {
    let spec = strategy
        .build_spec(cfg.clone(), topo.n_nodes())
        .with_sim_only(true)
        .with_prefill(prompt);
    let m = ModelGraphs::build(spec);
    let ex = strategy.sim_executor(topo, threads);
    let rep = ex.run(
        m.prefill.as_ref().expect("prefill graph"),
        &ExecParams::dense(0, prompt).with_seed(1),
    );
    SimPoint {
        strategy: strategy.name(),
        threads,
        tok_per_s: prompt as f64 / rep.elapsed,
        remote_fraction: rep.remote_fraction(),
    }
}

/// Sweep one strategy over thread counts → a plot series.
#[allow(clippy::too_many_arguments)]
pub fn decode_series(
    cfg: &ModelConfig,
    strategy: Strategy,
    thread_counts: &[usize],
    topo: &Topology,
    prompt: usize,
    gen: usize,
    samples: usize,
) -> FigureSeries {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &t in thread_counts {
        let p = decode_tok_s(cfg, strategy, t, topo, prompt, gen, samples);
        xs.push(t as f64);
        ys.push(p.tok_per_s);
    }
    FigureSeries { label: strategy.name(), xs, ys }
}

/// Figure 10: single NUMA node, threads 6→48, ArcLight vs llama.cpp.
pub fn fig10(cfg: &ModelConfig, topo: &Topology, samples: usize) -> Vec<FigureSeries> {
    let threads = [6, 12, 24, 36, 48];
    vec![
        decode_series(cfg, Strategy::llama_isolate(), &threads, topo, 15, 256, samples),
        decode_series(cfg, Strategy::arclight_single(), &threads, topo, 15, 256, samples),
    ]
}

/// Figure 11: 2 and 4 NUMA nodes, llama.cpp-distribute vs ArcLight-TP
/// (both sync modes). Thread counts are per-machine totals.
pub fn fig11(
    cfg: &ModelConfig,
    topo: &Topology,
    nodes: usize,
    samples: usize,
) -> Vec<FigureSeries> {
    let per_node = [12, 24, 48];
    let threads: Vec<usize> = per_node.iter().map(|t| t * nodes).collect();
    use crate::sched::SyncMode;
    let tp_a = Strategy::arclight_tp(nodes, SyncMode::SyncA);
    let tp_b = Strategy::arclight_tp(nodes, SyncMode::SyncB);
    vec![
        decode_series(cfg, Strategy::llama_distribute(nodes), &threads, topo, 15, 256, samples),
        decode_series(cfg, tp_a, &threads, topo, 15, 256, samples),
        decode_series(cfg, tp_b, &threads, topo, 15, 256, samples),
    ]
}

/// Figure 12: decode with a 300-token prompt (multi-node).
pub fn fig12(
    cfg: &ModelConfig,
    topo: &Topology,
    nodes: usize,
    samples: usize,
) -> Vec<FigureSeries> {
    let per_node = [12, 24, 48];
    let threads: Vec<usize> = per_node.iter().map(|t| t * nodes).collect();
    use crate::sched::SyncMode;
    let tp_b = Strategy::arclight_tp(nodes, SyncMode::SyncB);
    vec![
        decode_series(cfg, Strategy::llama_distribute(nodes), &threads, topo, 300, 256, samples),
        decode_series(cfg, tp_b, &threads, topo, 300, 256, samples),
    ]
}

/// Figure 13: prefill throughput with a 300-token prompt (multi-node).
pub fn fig13(cfg: &ModelConfig, topo: &Topology, nodes: usize) -> Vec<FigureSeries> {
    let per_node = [12, 24, 48];
    let threads: Vec<usize> = per_node.iter().map(|t| t * nodes).collect();
    use crate::sched::SyncMode;
    let mut out = Vec::new();
    for strategy in [
        Strategy::llama_distribute(nodes),
        Strategy::arclight_tp(nodes, SyncMode::SyncB),
    ] {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &t in &threads {
            let p = prefill_tok_s(cfg, strategy, t, topo, 300);
            xs.push(t as f64);
            ys.push(p.tok_per_s);
        }
        out.push(FigureSeries { label: strategy.name(), xs, ys });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scaled-down geometry so report tests stay fast; same shape
    /// properties as the 4B run (bandwidth-bound decode).
    fn small() -> ModelConfig {
        ModelConfig {
            dim: 512,
            n_layers: 4,
            n_heads: 8,
            n_kv_heads: 4,
            head_dim: 64,
            ffn_dim: 1536,
            vocab: 8192,
            max_seq: 512,
            rope_theta: 1e6,
            norm_eps: 1e-6,
        }
    }

    #[test]
    fn arclight_beats_llama_on_four_nodes() {
        let cfg = small();
        let topo = Topology::kunpeng920();
        let llama = decode_tok_s(&cfg, Strategy::llama_distribute(4), 192, &topo, 15, 256, 2);
        let arc = decode_tok_s(
            &cfg,
            Strategy::arclight_tp(4, crate::sched::SyncMode::SyncB),
            192,
            &topo,
            15,
            256,
            2,
        );
        assert!(
            arc.tok_per_s > llama.tok_per_s * 1.1,
            "arclight {} vs llama {}",
            arc.tok_per_s,
            llama.tok_per_s
        );
        // the mechanism: ArcLight's remote traffic share is far lower
        assert!(
            arc.remote_fraction < llama.remote_fraction * 0.8,
            "remote {} vs {}",
            arc.remote_fraction,
            llama.remote_fraction
        );
    }

    #[test]
    fn throughput_scales_with_threads_single_node() {
        let cfg = small();
        let topo = Topology::kunpeng920();
        let t6 = decode_tok_s(&cfg, Strategy::arclight_single(), 6, &topo, 15, 64, 2);
        let t48 = decode_tok_s(&cfg, Strategy::arclight_single(), 48, &topo, 15, 64, 2);
        assert!(t48.tok_per_s > t6.tok_per_s, "{} vs {}", t48.tok_per_s, t6.tok_per_s);
    }

    #[test]
    fn prefill_is_compute_heavier_than_decode() {
        // prefill advantage of TP is smaller than decode advantage (§A.2)
        let cfg = small();
        let topo = Topology::kunpeng920();
        let tp = Strategy::arclight_tp(4, crate::sched::SyncMode::SyncB);
        let d_l = decode_tok_s(&cfg, Strategy::llama_distribute(4), 192, &topo, 300, 64, 2);
        let d_a = decode_tok_s(&cfg, tp, 192, &topo, 300, 64, 2);
        let p_l = prefill_tok_s(&cfg, Strategy::llama_distribute(4), 192, &topo, 300);
        let p_a = prefill_tok_s(&cfg, tp, 192, &topo, 300);
        let decode_gain = d_a.tok_per_s / d_l.tok_per_s;
        let prefill_gain = p_a.tok_per_s / p_l.tok_per_s;
        assert!(
            prefill_gain < decode_gain,
            "prefill gain {prefill_gain} should be below decode gain {decode_gain}"
        );
    }
}
