//! Host platform layer: real topology discovery, core pinning and
//! node-local memory placement.
//!
//! Everything NUMA elsewhere in the crate is a *model*: the
//! [`crate::numa::Topology`] the cost model charges against, the
//! `Core` tags workers carry, the node tags on arenas. This module is
//! where the model meets a real machine:
//!
//! * [`topology`] — discover nodes/cpus/distances from the Linux sysfs
//!   tree (fixture-injectable, so it unit-tests in CI) and lower them
//!   into the existing `Topology` so the cost model, strategy binding
//!   and every bench work unchanged on detected hardware;
//! * [`affinity`] — `sched_setaffinity` pinning for pool workers (the
//!   ROADMAP "core pinning" item), best effort, surfaced per worker;
//! * [`membind`] — first-touch (and optional `mbind`) placement so an
//!   arena's pages physically live on its tagged node;
//! * [`bench`] — STREAM-triad measurement of the real node-pair
//!   bandwidth matrix plus its fingerprint-keyed on-disk cache, so the
//!   lowering can carry *measured* numbers instead of the SLIT-ratio
//!   placeholder scale.
//!
//! The whole layer is gated on the `host` cargo feature and Linux;
//! feature-off / off-Linux builds compile the same API as no-op stubs
//! (detection returns the simulated fallback, pinning returns
//! `false`), so nothing above this module needs a `cfg`.
//!
//! [`Platform`] is the engine-facing handle: *where does the machine
//! description come from* — the hand-written simulated testbed or the
//! detected host.

pub mod affinity;
pub mod bench;
pub mod membind;
pub mod topology;

use std::sync::Arc;

pub use topology::{HostNode, HostTopology};

use crate::numa::{Core, Topology};

/// The machine source an engine executes against.
///
/// Both variants expose the same [`Topology`] model — strategies,
/// the cost model and plan compilation are platform-agnostic; only
/// worker pinning and arena placement behave differently.
#[derive(Clone, Debug)]
pub enum Platform {
    /// The cost-model testbed (default: the paper's Kunpeng-920).
    /// Workers are never pinned; arena nodes are tags for the
    /// simulator.
    Simulated(Topology),
    /// A machine detected from sysfs, lowered into the same model.
    /// Workers can pin to the backing OS cpus and arenas can
    /// first-touch onto their tagged node.
    Host {
        /// The raw detected machine (cpu lists, memory, distances).
        host: Arc<HostTopology>,
        /// Its lowering into the cost-model [`Topology`].
        topo: Topology,
    },
}

impl Platform {
    /// The default simulated testbed (the paper's 4-node Kunpeng-920).
    pub fn simulated() -> Platform {
        Platform::Simulated(Topology::kunpeng920())
    }

    /// Detect the host machine; falls back to [`Platform::simulated`]
    /// when detection is unavailable (feature off, non-Linux, no sysfs
    /// NUMA tree).
    pub fn detect() -> Platform {
        match HostTopology::discover() {
            Some(h) => Platform::from_host(h),
            None => Platform::simulated(),
        }
    }

    /// Wrap an already-parsed host topology (fixture tests, custom
    /// roots).
    pub fn from_host(host: HostTopology) -> Platform {
        let topo = host.to_topology();
        Platform::Host { host: Arc::new(host), topo }
    }

    /// `"simulated"` or `"host"` — recorded in metrics and bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            Platform::Simulated(_) => "simulated",
            Platform::Host { .. } => "host",
        }
    }

    pub fn is_host(&self) -> bool {
        matches!(self, Platform::Host { .. })
    }

    /// The cost-model view every strategy binds against.
    pub fn topology(&self) -> &Topology {
        match self {
            Platform::Simulated(t) => t,
            Platform::Host { topo, .. } => topo,
        }
    }

    /// OS cpus backing `cores`, in worker order. `None` on the
    /// simulated platform (nothing to pin to) or when a core has no
    /// backing cpu — callers run unpinned.
    pub fn cpu_map(&self, cores: &[Core]) -> Option<Vec<usize>> {
        match self {
            Platform::Simulated(_) => None,
            Platform::Host { host, .. } => host.cpu_map(cores),
        }
    }

    /// Whether the platform is big enough to bind `threads` workers.
    /// Detected hosts can be smaller than what a strategy asks for
    /// (laptops, CI runners); callers degrade to the simulated testbed.
    pub fn supports_threads(&self, threads: usize) -> bool {
        threads <= self.topology().n_cores()
    }

    /// Detect the host and check it can bind `threads` workers — the
    /// shared `--pin` resolution path of the CLI and the benches.
    /// `Err` carries the reason the caller should print before
    /// falling back to [`Platform::simulated`]. Does **not** install
    /// the first-touch map: callers that pin memory call
    /// [`Platform::install_membind`] themselves, before engine build.
    pub fn host_for(threads: usize) -> Result<Platform, String> {
        let p = Platform::detect();
        if !p.is_host() {
            return Err(
                "no host NUMA topology detected (feature `host` off, non-Linux, or no sysfs \
                 tree)"
                    .into(),
            );
        }
        if !p.supports_threads(threads) {
            return Err(format!(
                "detected host has {} cpus < {} requested threads",
                p.topology().n_cores(),
                threads
            ));
        }
        Ok(p)
    }

    /// One-call `--pin` resolution for benches/examples:
    /// [`Platform::host_for`] plus [`Platform::install_membind`] on
    /// success. Returns the platform to run on and, on fallback to
    /// the simulated testbed, the reason for the caller to print.
    pub fn host_with_membind(threads: usize) -> (Platform, Option<String>) {
        match Platform::host_for(threads) {
            Ok(p) => {
                p.install_membind();
                (p, None)
            }
            Err(why) => (Platform::simulated(), Some(why)),
        }
    }

    /// Partition the machine's NUMA nodes into contiguous groups — the
    /// placement domains of a [`crate::server::Cluster`] — consulting
    /// the topology's bandwidth matrix (measured, when a calibration
    /// has been lowered in) so nodes behind an unusually slow link are
    /// never grouped with fast ones.
    ///
    /// `None` (`serve --replicas auto`): adjacent nodes merge into one
    /// replica only when the link between them runs at ≥ half local
    /// bandwidth; on the paper's testbed (remote ≈ ¼ local) and any
    /// similarly NUMA-sharp machine this stays one replica per node.
    ///
    /// `Some(r)` is clamped to `[1, n_nodes]` and picks, among all
    /// contiguous `r`-way splits, the one maximizing the slowest
    /// intra-group link (ties keep the even chunk split). Every node
    /// lands in exactly one group, in order.
    pub fn node_groups(&self, replicas: Option<usize>) -> Vec<Vec<usize>> {
        let topo = self.topology();
        let n = topo.n_nodes();
        // min of both directions: one slow direction is enough to make
        // co-placement pay the slow lane on every broadcast
        let link = |a: usize, b: usize| topo.bandwidth(a, b).min(topo.bandwidth(b, a));
        match replicas {
            None => {
                let mut groups: Vec<Vec<usize>> = vec![vec![0]];
                for node in 1..n {
                    let prev = *groups.last().unwrap().last().unwrap();
                    let local = topo.bandwidth(node, node).min(topo.bandwidth(prev, prev));
                    if link(prev, node) >= 0.5 * local {
                        groups.last_mut().unwrap().push(node);
                    } else {
                        groups.push(vec![node]);
                    }
                }
                groups
            }
            Some(r) => {
                let r = r.clamp(1, n);
                // a split's score is its slowest intra-group pair
                // (singletons are unconstrained)
                let score = |groups: &[Vec<usize>]| {
                    groups
                        .iter()
                        .flat_map(|g| {
                            (0..g.len()).flat_map(move |i| {
                                (i + 1..g.len()).map(move |j| link(g[i], g[j]))
                            })
                        })
                        .fold(f64::INFINITY, f64::min)
                };
                let chunked: Vec<Vec<usize>> = (0..r)
                    .map(|i| {
                        let (s, e) = crate::util::chunk_range(n, r, i);
                        (s..e).collect()
                    })
                    .collect();
                let mut best_score = score(&chunked);
                let mut best = chunked;
                for sizes in compositions(n, r) {
                    let mut groups = Vec::with_capacity(r);
                    let mut next = 0;
                    for sz in sizes {
                        groups.push((next..next + sz).collect::<Vec<usize>>());
                        next += sz;
                    }
                    let s = score(&groups);
                    if s > best_score {
                        best_score = s;
                        best = groups;
                    }
                }
                best
            }
        }
    }

    /// Re-lower a detected host against the calibration cache at
    /// `cache`: when a measured matrix with a matching fingerprint is
    /// on disk, the platform's [`Topology`] is rebuilt from it (and
    /// tagged [`crate::numa::BandwidthSource::Measured`]). Load-only —
    /// never measures; a missing or stale cache, or a simulated
    /// platform, passes through unchanged. This is the startup rung of
    /// the fallback ladder: measured → SLIT placeholder → simulated.
    pub fn with_cached_calibration(self, cache: &std::path::Path) -> Platform {
        match self {
            Platform::Host { host, topo } => match bench::cached_matrix(&host, cache) {
                Some(m) => {
                    let topo = host.to_topology_measured(&m);
                    Platform::Host { host, topo }
                }
                None => Platform::Host { host, topo },
            },
            p => p,
        }
    }

    /// Install this platform's first-touch placement map for
    /// [`crate::memory::Arena`] allocation (one representative cpu per
    /// node). Must run **before** the engine is built — arenas are
    /// allocated during graph planning. Returns `false` (and installs
    /// nothing) on the simulated platform.
    pub fn install_membind(&self) -> bool {
        if let Platform::Host { host, .. } = self {
            let cpus: Vec<usize> =
                host.nodes.iter().filter_map(|n| n.cpus.first().copied()).collect();
            if cpus.len() == host.n_nodes() {
                membind::install_first_touch(cpus);
                return true;
            }
        }
        false
    }
}

impl From<Topology> for Platform {
    fn from(t: Topology) -> Platform {
        Platform::Simulated(t)
    }
}

/// All ways to write `n` as `r` ordered positive parts — the contiguous
/// `r`-way node splits [`Platform::node_groups`] scores. `n` is a NUMA
/// node count (single digits), so exhaustive enumeration is cheap.
fn compositions(n: usize, r: usize) -> Vec<Vec<usize>> {
    fn rec(left: usize, parts: usize, prefix: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if parts == 1 {
            prefix.push(left);
            out.push(prefix.clone());
            prefix.pop();
            return;
        }
        // leave at least one node for each remaining part
        for take in 1..=(left - (parts - 1)) {
            prefix.push(take);
            rec(left - take, parts - 1, prefix, out);
            prefix.pop();
        }
    }
    let mut out = Vec::new();
    if r >= 1 && n >= r {
        rec(n, r, &mut Vec::new(), &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_platform_reports_the_model() {
        let p = Platform::simulated();
        assert_eq!(p.name(), "simulated");
        assert!(!p.is_host());
        assert_eq!(p.topology().n_nodes(), 4);
        assert!(p.cpu_map(&[p.topology().core(0)]).is_none());
        assert!(p.supports_threads(192));
        assert!(!p.supports_threads(193));
        assert!(!p.install_membind());
    }

    #[test]
    fn detect_falls_back_to_simulated_without_host_support() {
        let p = Platform::detect();
        if !affinity::available() {
            assert_eq!(p.name(), "simulated");
        }
        // either way the lowered model is usable
        assert!(p.topology().n_nodes() >= 1);
        assert!(p.topology().n_cores() >= 1);
    }

    #[test]
    fn host_for_refuses_without_detection_or_capacity() {
        if !affinity::available() {
            // stub builds: detection itself is the refusal reason
            assert!(Platform::host_for(1).is_err());
        }
        // an absurd thread count is refused on every machine
        let err = Platform::host_for(usize::MAX).unwrap_err();
        assert!(!err.is_empty());
        // the one-call resolver falls back with the reason (no
        // global-map assertion here: membind's own tests exercise the
        // map concurrently in this binary)
        let (p, note) = Platform::host_with_membind(usize::MAX);
        assert_eq!(p.name(), "simulated");
        assert!(note.is_some());
    }

    #[test]
    fn node_groups_partition_the_machine() {
        let p = Platform::simulated(); // 4 nodes
        assert_eq!(p.node_groups(None), vec![vec![0], vec![1], vec![2], vec![3]]);
        assert_eq!(p.node_groups(Some(2)), vec![vec![0, 1], vec![2, 3]]);
        assert_eq!(p.node_groups(Some(3)), vec![vec![0, 1], vec![2], vec![3]]);
        // clamped to the machine: 0 → 1 group, 99 → one per node
        assert_eq!(p.node_groups(Some(0)), vec![vec![0, 1, 2, 3]]);
        assert_eq!(p.node_groups(Some(99)).len(), 4);
        // every node exactly once, in order
        let flat: Vec<usize> = p.node_groups(Some(3)).concat();
        assert_eq!(flat, vec![0, 1, 2, 3]);
    }

    #[test]
    fn node_groups_follow_the_bandwidth_matrix() {
        // four nodes, fast fabric except a crawling 2↔3 link
        let mut bw = vec![vec![80.0; 4]; 4];
        for i in 0..4 {
            bw[i][i] = 100.0;
        }
        bw[2][3] = 5.0;
        bw[3][2] = 5.0;
        let p: Platform = Topology::from_bandwidth_gb(bw, 4).into();
        // auto merges across fast links but splits at the slow one
        assert_eq!(p.node_groups(None), vec![vec![0, 1, 2], vec![3]]);
        // an explicit 2-way split avoids co-placing 2 and 3: the even
        // chunk [01|23] would bottleneck on the 5 GB/s link, so the
        // tuned split [012|3] wins
        assert_eq!(p.node_groups(Some(2)), vec![vec![0, 1, 2], vec![3]]);
        // every node still lands exactly once, in order
        assert_eq!(p.node_groups(Some(3)).concat(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn compositions_enumerate_contiguous_splits() {
        assert_eq!(compositions(4, 1), vec![vec![4]]);
        assert_eq!(compositions(4, 2), vec![vec![1, 3], vec![2, 2], vec![3, 1]]);
        assert_eq!(compositions(3, 3), vec![vec![1, 1, 1]]);
        assert!(compositions(2, 3).is_empty());
        // C(n-1, r-1) splits, all summing to n
        assert_eq!(compositions(6, 3).len(), 10);
        assert!(compositions(6, 3).iter().all(|s| s.iter().sum::<usize>() == 6));
    }

    #[test]
    fn cached_calibration_relowers_a_host_platform() {
        use crate::numa::BandwidthSource;
        let host = HostTopology {
            nodes: vec![
                HostNode { id: 0, cpus: vec![0, 1], mem_total_kb: 1 },
                HostNode { id: 1, cpus: vec![2, 3], mem_total_kb: 1 },
            ],
            distance: vec![vec![10, 20], vec![20, 10]],
        };
        let dir = std::env::temp_dir().join(format!("arclight-platcal-{}", std::process::id()));
        let cache = dir.join("bandwidth.json");
        // no cache on disk: the placeholder lowering passes through
        let p = Platform::from_host(host.clone()).with_cached_calibration(&cache);
        assert_eq!(p.topology().bw_source, BandwidthSource::SlitPlaceholder);
        // with a matching calibration cached, the lowering is measured
        bench::Calibration {
            fingerprint: host.fingerprint(),
            matrix_gb: vec![vec![87.0, 6.5], vec![6.0, 91.0]],
        }
        .store(&cache)
        .unwrap();
        let p = Platform::from_host(host.clone()).with_cached_calibration(&cache);
        assert_eq!(p.topology().bw_source, BandwidthSource::Measured);
        assert_eq!(p.topology().bandwidth(0, 1), 6.5e9);
        // simulated platforms never consult the cache
        let s = Platform::simulated().with_cached_calibration(&cache);
        assert_eq!(s.topology().bw_source, BandwidthSource::Simulated);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn from_topology_wraps_simulated() {
        let p: Platform = Topology::uniform(2, 4, 100.0, 25.0).into();
        assert_eq!(p.name(), "simulated");
        assert_eq!(p.topology().n_cores(), 8);
    }
}
