//! Real-machine NUMA topology discovery from the Linux sysfs tree.
//!
//! The kernel exports one directory per NUMA node under
//! `/sys/devices/system/node/node<N>/` with (among others):
//!
//! * `cpulist` — the node's online cpus as a range list (`0-3,8-11`);
//! * `meminfo` — per-node memory counters (`Node 0 MemTotal: ... kB`);
//! * `distance` — the node's row of the ACPI SLIT matrix (local is
//!   conventionally 10, remote 2–4× that).
//!
//! [`HostTopology::from_root`] parses an **injectable root directory**
//! so the parser is unit-testable in CI against fixture trees (a 1-node
//! laptop, a 2-node Xeon with hyperthread-split cpulists, a 4-node
//! Kunpeng-920 with offline cpus — see `tests/hw_topology.rs`);
//! [`HostTopology::discover`] points it at the live `/sys` when the
//! `host` feature is on and the target is Linux, and returns `None`
//! otherwise so every caller degrades to the simulated testbed.
//!
//! [`HostTopology::to_topology`] lowers the detected machine into the
//! existing [`crate::numa::Topology`] cost model so `Strategy`
//! binding, the cost model and every bench run unchanged on detected
//! hardware. Bandwidth *ratios* come from the SLIT distances
//! (`bw[i][j] = local · d[i][i] / d[i][j]`); the absolute scale is the
//! [`DEFAULT_LOCAL_GB`] placeholder until measured (the Table-1 bench
//! can calibrate it). Everything else (compute rates, barrier costs)
//! inherits the Kunpeng-920 calibration — see DESIGN.md "Host
//! platform layer" for exactly what stays simulated.

use std::path::Path;

use crate::numa::{BandwidthSource, Core, NodeId, Topology};

/// Assumed local-node bandwidth (GB/s) when lowering SLIT distances
/// into a bandwidth matrix. Only the *ratios* are measured (distances);
/// the absolute scale is this placeholder until a streaming benchmark
/// calibrates it per machine.
pub const DEFAULT_LOCAL_GB: f64 = 100.0;

/// One detected NUMA node.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostNode {
    pub id: NodeId,
    /// OS cpu ids of the node, ascending. May be non-contiguous
    /// (hyperthread sibling enumeration, offline cpus).
    pub cpus: Vec<usize>,
    /// The node's `MemTotal` in kB (0 when `meminfo` is absent).
    pub mem_total_kb: u64,
}

/// The detected machine: nodes plus the SLIT distance matrix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostTopology {
    /// Nodes in id order (ids are contiguous from 0).
    pub nodes: Vec<HostNode>,
    /// `distance[i][j]` — ACPI SLIT relative memory distance (local is
    /// conventionally 10).
    pub distance: Vec<Vec<u32>>,
}

impl HostTopology {
    /// Discover the live machine from `/sys/devices/system/node`.
    /// `None` when the `host` feature is off, off-Linux, or the sysfs
    /// NUMA tree is absent/unparseable — callers fall back to the
    /// simulated testbed.
    pub fn discover() -> Option<HostTopology> {
        if cfg!(all(feature = "host", target_os = "linux")) {
            Self::from_root(Path::new("/sys/devices/system/node"))
        } else {
            None
        }
    }

    /// Parse a sysfs-node-style directory tree (the injectable fixture
    /// root). Returns `None` unless the tree holds ≥ 1 `node<N>`
    /// directory with contiguous ids from 0, each with ≥ 1 cpu and a
    /// full `distance` row.
    pub fn from_root(root: &Path) -> Option<HostTopology> {
        let mut found: Vec<(usize, std::path::PathBuf)> = Vec::new();
        for entry in std::fs::read_dir(root).ok()?.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(id) = name.strip_prefix("node") else { continue };
            let Ok(id) = id.parse::<usize>() else { continue };
            found.push((id, entry.path()));
        }
        if found.is_empty() {
            return None;
        }
        found.sort_by_key(|(id, _)| *id);
        let n = found.len();
        if found.last().map(|(id, _)| *id) != Some(n - 1) {
            return None; // non-contiguous node ids (memory holes)
        }
        let mut nodes = Vec::with_capacity(n);
        let mut distance = Vec::with_capacity(n);
        for (id, dir) in found {
            let cpus = parse_cpulist(&std::fs::read_to_string(dir.join("cpulist")).ok()?);
            if cpus.is_empty() {
                return None; // cpu-less (memory-only) nodes unsupported
            }
            let row = parse_distance(&std::fs::read_to_string(dir.join("distance")).ok()?);
            if row.len() != n {
                return None;
            }
            let mem_total_kb = std::fs::read_to_string(dir.join("meminfo"))
                .ok()
                .and_then(|s| parse_meminfo_total_kb(&s))
                .unwrap_or(0);
            nodes.push(HostNode { id, cpus, mem_total_kb });
            distance.push(row);
        }
        Some(HostTopology { nodes, distance })
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// All online cpus across nodes.
    pub fn total_cpus(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).sum()
    }

    /// Cores per node in the lowered model: the *minimum* across nodes,
    /// so every simulated core maps onto a real cpu even when offline
    /// cpus leave the nodes unequal.
    pub fn cores_per_node(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).min().unwrap_or(1).max(1)
    }

    /// Lower the detected machine into the simulated-platform model:
    /// same node count, [`HostTopology::cores_per_node`] cores, and a
    /// bandwidth matrix whose ratios follow the SLIT distances
    /// (`bw[i][j] = DEFAULT_LOCAL_GB · d[i][i] / d[i][j]`). Cost-model
    /// calibration constants inherit the Kunpeng-920 defaults.
    pub fn to_topology(&self) -> Topology {
        let n = self.n_nodes();
        let bw_gb: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                let local = self.distance[i][i].max(1) as f64;
                (0..n)
                    .map(|j| DEFAULT_LOCAL_GB * local / self.distance[i][j].max(1) as f64)
                    .collect()
            })
            .collect();
        Topology::from_bandwidth_gb(bw_gb, self.cores_per_node())
            .with_bw_source(BandwidthSource::SlitPlaceholder)
    }

    /// Lower the detected machine with a **measured** node-pair
    /// bandwidth matrix (GB/s, `matrix_gb[core_node][mem_node]`) in
    /// place of the SLIT-ratio placeholder — the calibrated path fed by
    /// [`crate::hw::bench`]. The matrix must be square over the node
    /// count; every other constant inherits the Kunpeng-920 defaults
    /// exactly like [`HostTopology::to_topology`].
    pub fn to_topology_measured(&self, matrix_gb: &[Vec<f64>]) -> Topology {
        let n = self.n_nodes();
        assert_eq!(matrix_gb.len(), n, "measured matrix node count mismatch");
        assert!(matrix_gb.iter().all(|r| r.len() == n), "measured matrix must be square");
        Topology::from_bandwidth_gb(matrix_gb.to_vec(), self.cores_per_node())
            .with_bw_source(BandwidthSource::Measured)
    }

    /// Stable fingerprint of the machine for keying the calibration
    /// cache: node count, per-node cpulists and the SLIT matrix. Any
    /// change (cpus offlined, different machine, BIOS NUMA config)
    /// produces a different string and invalidates cached measurements.
    pub fn fingerprint(&self) -> String {
        let mut s = format!("nodes={}", self.n_nodes());
        for n in &self.nodes {
            s.push_str(&format!(";n{}={}", n.id, format_cpulist(&n.cpus)));
        }
        for (i, row) in self.distance.iter().enumerate() {
            let cells: Vec<String> = row.iter().map(|d| d.to_string()).collect();
            s.push_str(&format!(";d{}={}", i, cells.join(",")));
        }
        s
    }

    /// The OS cpu backing one simulated core of the lowered topology
    /// (`None` when the core is out of range).
    pub fn os_cpu(&self, core: Core) -> Option<usize> {
        let node = self.nodes.get(core.node)?;
        let idx = core.id.checked_sub(core.node * self.cores_per_node())?;
        node.cpus.get(idx).copied()
    }

    /// OS cpus backing `cores` in order; `None` when any core has no
    /// backing cpu (callers then run unpinned).
    pub fn cpu_map(&self, cores: &[Core]) -> Option<Vec<usize>> {
        cores.iter().map(|&c| self.os_cpu(c)).collect()
    }
}

/// Parse a sysfs cpulist (`"0-3,8-11"`) into ascending cpu ids.
/// Malformed pieces are skipped; an empty/blank list parses to `[]`.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for piece in s.trim().split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        match piece.split_once('-') {
            Some((a, b)) => {
                if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                    if a <= b {
                        cpus.extend(a..=b);
                    }
                }
            }
            None => {
                if let Ok(v) = piece.parse::<usize>() {
                    cpus.push(v);
                }
            }
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

/// Render cpu ids back into the compact sysfs range form
/// (`[0,1,2,3,8]` → `"0-3,8"`) for `arclight topo` output.
pub fn format_cpulist(cpus: &[usize]) -> String {
    let mut out = String::new();
    let mut i = 0;
    while i < cpus.len() {
        let start = cpus[i];
        let mut end = start;
        while i + 1 < cpus.len() && cpus[i + 1] == end + 1 {
            i += 1;
            end = cpus[i];
        }
        if !out.is_empty() {
            out.push(',');
        }
        if start == end {
            out.push_str(&start.to_string());
        } else {
            out.push_str(&format!("{start}-{end}"));
        }
        i += 1;
    }
    out
}

/// One SLIT row: whitespace-separated distances.
fn parse_distance(s: &str) -> Vec<u32> {
    s.split_whitespace().filter_map(|t| t.parse().ok()).collect()
}

/// Extract `MemTotal` (kB) from a per-node `meminfo` blob
/// (`"Node 0 MemTotal:  32624132 kB"`).
fn parse_meminfo_total_kb(s: &str) -> Option<u64> {
    for line in s.lines() {
        let mut toks = line.split_whitespace();
        while let Some(t) = toks.next() {
            if t == "MemTotal:" {
                return toks.next().and_then(|v| v.parse().ok());
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parses_ranges_singletons_and_blanks() {
        assert_eq!(parse_cpulist("0-3,8-11\n"), vec![0, 1, 2, 3, 8, 9, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist("3,1,2"), vec![1, 2, 3]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("  \n"), Vec::<usize>::new());
        // malformed pieces are skipped, not fatal
        assert_eq!(parse_cpulist("0-1,x,4"), vec![0, 1, 4]);
    }

    #[test]
    fn cpulist_formats_back_to_ranges() {
        for list in ["0-3,8-11", "5", "0", "0-191", "1,3,5"] {
            assert_eq!(format_cpulist(&parse_cpulist(list)), list);
        }
        assert_eq!(format_cpulist(&[]), "");
    }

    #[test]
    fn meminfo_total_is_extracted() {
        let blob = "Node 2 MemUsed:  100 kB\nNode 2 MemTotal:       32624132 kB\n";
        assert_eq!(parse_meminfo_total_kb(blob), Some(32624132));
        assert_eq!(parse_meminfo_total_kb("no such field"), None);
    }

    #[test]
    fn distance_row_parses() {
        assert_eq!(parse_distance("10 21 21 21\n"), vec![10, 21, 21, 21]);
        assert_eq!(parse_distance("10"), vec![10]);
    }

    #[test]
    fn missing_root_is_none() {
        assert!(HostTopology::from_root(Path::new("/definitely/not/here")).is_none());
    }

    fn two_node_host() -> HostTopology {
        HostTopology {
            nodes: vec![
                HostNode { id: 0, cpus: (0..4).collect(), mem_total_kb: 1 },
                HostNode { id: 1, cpus: (4..8).collect(), mem_total_kb: 1 },
            ],
            distance: vec![vec![10, 20], vec![20, 10]],
        }
    }

    #[test]
    fn lowerings_carry_bandwidth_provenance() {
        let h = two_node_host();
        let placeholder = h.to_topology();
        assert_eq!(placeholder.bw_source, BandwidthSource::SlitPlaceholder);
        assert_eq!(placeholder.bandwidth(0, 0), DEFAULT_LOCAL_GB * 1e9);
        let measured =
            h.to_topology_measured(&[vec![87.0, 5.5], vec![5.0, 91.0]]);
        assert_eq!(measured.bw_source, BandwidthSource::Measured);
        assert_eq!(measured.bandwidth(0, 1), 5.5e9);
        assert_eq!(measured.bandwidth(1, 1), 91e9);
        assert_eq!(measured.cores_per_node, 4);
    }

    #[test]
    fn fingerprint_tracks_cpus_and_distances() {
        let a = two_node_host();
        let fp = a.fingerprint();
        assert!(fp.contains("nodes=2"));
        assert!(fp.contains("0-3"));
        assert_eq!(fp, two_node_host().fingerprint(), "fingerprint must be deterministic");
        // offlining a cpu changes it
        let mut b = two_node_host();
        b.nodes[1].cpus.pop();
        assert_ne!(fp, b.fingerprint());
        // a different SLIT matrix changes it
        let mut c = two_node_host();
        c.distance[0][1] = 21;
        assert_ne!(fp, c.fingerprint());
    }
}
