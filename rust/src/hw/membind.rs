//! First-touch node-local arena placement.
//!
//! Linux commits anonymous memory to a physical NUMA node when a page
//! is **first written**, on the node of the writing cpu — not when it
//! is allocated. `vec![0u8; n]`-style zeroed allocation therefore
//! decides placement implicitly: if the zeroing touches pages from the
//! allocating thread, every "node-tagged" arena lands on that thread's
//! node and the cross-NUMA memory wall the paper is about is neither
//! mitigated nor measurable.
//!
//! [`alloc_arena`] is the **single** allocation path every
//! [`crate::memory::Arena`] goes through, and it makes the contract
//! explicit:
//!
//! 1. allocate through `alloc_zeroed` — for arena-sized requests the
//!    allocator serves mmap'd pages backed by the kernel zero page, so
//!    nothing is committed yet and placement stays undecided (the
//!    first-touch hazard `vec![0u8; n]` hid is gone even in the
//!    default build);
//! 2. when a first-touch map is installed
//!    ([`install_first_touch`], done by the CLI/benches under `--pin`
//!    on a detected host), fault every page in from a short-lived
//!    thread pinned to a cpu of the arena's node, so weight shards and
//!    KV slabs physically live on their tagged node;
//! 3. with the `host-mbind` feature the faulting thread additionally
//!    asks the kernel to bind the range via `mbind(2)` (best effort —
//!    first-touch already placed the pages; `mbind` pins the policy
//!    for any page the fault loop missed).
//!
//! [`node_local_bytes`] counts the bytes placed this way for the
//! serving metrics and bench JSON.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::numa::NodeId;

/// Fault-in stride: one write per page commits it.
const PAGE: usize = 4096;

static NODE_LOCAL_BYTES: AtomicU64 = AtomicU64::new(0);
static FIRST_TOUCH: Mutex<Option<Vec<usize>>> = Mutex::new(None);

/// Install the first-touch placement map: one representative OS cpu
/// per NUMA node (`cpu_of_node[node]`). Arenas allocated afterwards
/// fault their pages in from that cpu. Installing replaces any
/// previous map; [`clear_first_touch`] removes it.
pub fn install_first_touch(cpu_of_node: Vec<usize>) {
    *FIRST_TOUCH.lock().unwrap() = Some(cpu_of_node);
}

/// Remove the placement map (arenas go back to lazy kernel-zero-page
/// placement).
pub fn clear_first_touch() {
    *FIRST_TOUCH.lock().unwrap() = None;
}

/// Whether a first-touch map is installed.
pub fn first_touch_installed() -> bool {
    FIRST_TOUCH.lock().unwrap().is_some()
}

/// Bytes of arena storage faulted in from a thread pinned to the
/// arena's tagged node, cumulative since process start (engines that
/// were since dropped are still counted — snapshot and subtract to
/// attribute a single engine). Placement is guaranteed for freshly
/// mapped pages (arena-sized allocations in practice); small recycled
/// heap allocations may already be committed on another node, which
/// first-touch cannot move — the `host-mbind` feature's
/// `MPOL_MF_MOVE` path handles those.
pub fn node_local_bytes() -> u64 {
    NODE_LOCAL_BYTES.load(Ordering::Relaxed)
}

/// Allocate the zeroed backing store of one arena tagged with `node`.
/// The single, centralized place arena placement is decided — see the
/// module docs for the three-step contract.
pub fn alloc_arena(node: NodeId, capacity: usize) -> Box<[u8]> {
    let mut data = alloc_zeroed_untouched(capacity);
    if !data.is_empty() {
        let cpu = FIRST_TOUCH.lock().unwrap().as_ref().and_then(|m| m.get(node).copied());
        if let Some(cpu) = cpu {
            if fault_in_from(cpu, node, &mut data) {
                NODE_LOCAL_BYTES.fetch_add(data.len() as u64, Ordering::Relaxed);
            }
        }
    }
    data
}

/// Zeroed allocation with **no page touched by this thread**: a direct
/// `alloc_zeroed` (what `vec![0u8; n]` lowers to via specialization,
/// spelled out because placement correctness depends on it). For
/// arena-sized requests the allocator mmaps fresh zero pages and the
/// kernel commits nothing until somebody writes.
fn alloc_zeroed_untouched(capacity: usize) -> Box<[u8]> {
    if capacity == 0 {
        return Vec::new().into_boxed_slice();
    }
    let layout = std::alloc::Layout::array::<u8>(capacity).expect("arena capacity overflows");
    // Safety: layout is non-zero-sized; alloc_zeroed returns `capacity`
    // initialized (zero) bytes, and `Box<[u8]>` frees with the same
    // `Layout::array::<u8>` layout.
    unsafe {
        let ptr = std::alloc::alloc_zeroed(layout);
        if ptr.is_null() {
            std::alloc::handle_alloc_error(layout);
        }
        Box::from_raw(std::ptr::slice_from_raw_parts_mut(ptr, capacity))
    }
}

/// Commit every page of `data` from a thread pinned to `cpu` (a cpu of
/// `node`). Returns `true` only when the pin succeeded — an unpinned
/// fault-in would *wrongly* place the pages, so it is skipped and the
/// pages stay lazy.
fn fault_in_from(cpu: usize, node: NodeId, data: &mut [u8]) -> bool {
    if !super::affinity::available() {
        return false;
    }
    std::thread::scope(|s| {
        s.spawn(|| {
            if !super::affinity::pin_current_thread(cpu) {
                return false;
            }
            mbind_to_node(data, node);
            let ptr = data.as_mut_ptr();
            let mut off = 0;
            while off < data.len() {
                // volatile: a plain zero store into known-zero memory
                // could be elided, and the whole point is the fault
                unsafe { std::ptr::write_volatile(ptr.add(off), 0u8) };
                off += PAGE;
            }
            // an unaligned base shifts page boundaries relative to the
            // stride, which can leave the buffer's final page untouched;
            // the last byte commits it (len > 0: caller checks)
            unsafe { std::ptr::write_volatile(ptr.add(data.len() - 1), 0u8) };
            true
        })
        .join()
        .unwrap_or(false)
    })
}

/// Optional `mbind(2)` policy bind of the page-aligned interior of
/// `data` to `node` (`host-mbind` feature). Best effort: errors are
/// ignored — first-touch placement still applies.
#[cfg(all(feature = "host-mbind", target_os = "linux"))]
fn mbind_to_node(data: &mut [u8], node: NodeId) {
    #[cfg(target_arch = "x86_64")]
    const SYS_MBIND: std::ffi::c_long = 237;
    #[cfg(target_arch = "aarch64")]
    const SYS_MBIND: std::ffi::c_long = 235;
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    const SYS_MBIND: std::ffi::c_long = -1;
    const MPOL_BIND: usize = 2;
    // migrate pages already committed elsewhere (recycled heap
    // memory) onto the bound node, not just future faults
    const MPOL_MF_MOVE: usize = 2;
    if SYS_MBIND < 0 || node >= 64 {
        return;
    }
    let start = data.as_ptr() as usize;
    let lo = (start + PAGE - 1) & !(PAGE - 1);
    let hi = (start + data.len()) & !(PAGE - 1);
    if hi <= lo {
        return; // allocation smaller than one aligned page
    }
    // Two words: the kernel's get_nodes historically decrements
    // maxnode before sizing its copy, so declaring 65 bits needs one
    // long — but a second zeroed word keeps the call safe under
    // either reading of the quirk.
    let nodemask: [u64; 2] = [1u64 << node, 0];
    extern "C" {
        fn syscall(num: std::ffi::c_long, ...) -> std::ffi::c_long;
    }
    // Safety: the [lo, hi) range lies inside our live allocation and
    // the nodemask outlives the call.
    let mask_ptr = nodemask.as_ptr();
    unsafe {
        let _ = syscall(SYS_MBIND, lo, hi - lo, MPOL_BIND, mask_ptr, 65usize, MPOL_MF_MOVE);
    }
}

#[cfg(not(all(feature = "host-mbind", target_os = "linux")))]
fn mbind_to_node(_data: &mut [u8], _node: NodeId) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_zeroed_and_sized() {
        let b = alloc_arena(0, 8192);
        assert_eq!(b.len(), 8192);
        assert!(b.iter().all(|&x| x == 0));
        assert_eq!(alloc_arena(3, 0).len(), 0);
    }

    #[test]
    fn first_touch_map_is_optional_and_replaceable() {
        // no map installed (the default): allocation works, nothing is
        // counted as node-local
        clear_first_touch();
        assert!(!first_touch_installed());
        let before = node_local_bytes();
        let _ = alloc_arena(1, 4 * PAGE);
        if !crate::hw::affinity::available() {
            assert_eq!(node_local_bytes(), before);
        }
        // installed map routes allocations through the fault-in path;
        // on stub builds the pin fails and the counter must not move
        install_first_touch(vec![0, 0]);
        assert!(first_touch_installed());
        let b = alloc_arena(1, 4 * PAGE);
        assert!(b.iter().all(|&x| x == 0), "fault-in must preserve zeroing");
        // a node beyond the map is simply not first-touched
        let _ = alloc_arena(7, PAGE);
        clear_first_touch();
        assert!(!first_touch_installed());
    }
}
