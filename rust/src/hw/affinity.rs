//! Host core pinning.
//!
//! With the `host` feature on Linux, [`pin_current_thread`] binds the
//! calling thread to one OS cpu through `sched_setaffinity(2)` (the
//! symbol is declared directly against the libc the std runtime
//! already links — no external crate). Everywhere else it is a no-op
//! returning `false`, so the thread pool's pin bookkeeping degrades
//! gracefully: workers simply run unpinned and report it.
//!
//! Pinning is *best effort by design*: on shared/containerized hosts
//! the allowed-cpu mask may exclude the requested cpu and the call
//! fails — callers must treat a `false` as "keep running, unpinned",
//! never as an error.

/// Bind the calling thread to `cpu`. Returns `true` when the kernel
/// accepted the mask; `false` on failure or on builds without host
/// support (feature off, non-Linux, cpu id beyond the fixed mask).
#[cfg(all(feature = "host", target_os = "linux"))]
pub fn pin_current_thread(cpu: usize) -> bool {
    // glibc's cpu_set_t is a fixed 1024-bit mask.
    let mut mask = [0u64; 1024 / 64];
    if cpu >= 1024 {
        return false;
    }
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    extern "C" {
        // int sched_setaffinity(pid_t pid, size_t cpusetsize, const cpu_set_t *mask);
        // pid 0 == the calling thread.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// No-op fallback: feature off or non-Linux target.
#[cfg(not(all(feature = "host", target_os = "linux")))]
pub fn pin_current_thread(_cpu: usize) -> bool {
    false
}

/// Whether this build can pin at all (`host` feature on Linux). The
/// runtime call may still fail per-cpu on restricted hosts.
pub fn available() -> bool {
    cfg!(all(feature = "host", target_os = "linux"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinning_degrades_gracefully() {
        // Feature-off / non-Linux: always false. Host builds: pinning
        // cpu 0 on the current thread should succeed on any runner
        // whose allowed mask includes cpu 0; when it does not (heavily
        // restricted container) false is still the correct, non-fatal
        // answer. Either way the call must not panic.
        let ok = pin_current_thread(0);
        if !available() {
            assert!(!ok, "stub build must never report a successful pin");
        }
        // out-of-range cpu ids are refused, not UB
        assert!(!pin_current_thread(usize::MAX));
    }
}
