//! STREAM-triad bandwidth measurement + on-disk calibration cache.
//!
//! The host lowering in [`super::topology`] gets bandwidth *ratios*
//! from the SLIT matrix but the absolute scale from the
//! [`super::topology::DEFAULT_LOCAL_GB`] placeholder — good enough for
//! "local beats remote", useless for choosing *between* strategies
//! whose costs differ by tens of percent. This module measures the
//! real matrix the way the paper's Table 1 does:
//!
//! * for every (core node, memory node) pair, probe threads pin onto
//!   the core node's cpus, first-touch three stream buffers on the
//!   memory node (pin to a memory-node cpu, write every page, re-pin),
//!   and run a timed STREAM triad (`a[i] = b[i] + s·c[i]`, 3 streamed
//!   arrays — 24 bytes per element);
//! * per-pair GB/s is the sum of per-thread best-of-`reps` rates, i.e.
//!   the *aggregate* node-to-node bandwidth [`crate::numa::Topology`]
//!   models (`bw[core_node][mem_node]`), not a single core's.
//!
//! Pinning is best effort (the [`super::affinity`] contract): on
//! builds without host support, or for fixture topologies whose cpu
//! ids don't exist, the probes simply run unpinned — the numbers lose
//! node attribution but every code path stays exercised and testable.
//!
//! Measurements are cached to disk as a small JSON blob keyed by
//! [`super::HostTopology::fingerprint`] (node count, cpulists, SLIT
//! matrix), so repeat runs pay nothing: [`calibrate`] loads the cache,
//! checks the fingerprint against the live machine, and only streams
//! when the cache is missing, corrupt, stale, or `force`d.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use crate::util::json::{obj, Json};

use super::affinity;
use super::topology::HostTopology;

/// Cache-format version; bumping invalidates every existing cache.
const CACHE_VERSION: usize = 1;

/// Measurement parameters.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Total f64 elements streamed per node pair, split across the
    /// probe threads (three buffers of this total are allocated, so a
    /// pair touches `24 · elems` bytes).
    pub elems: usize,
    /// Timed repetitions per pair; each thread keeps its best rate.
    pub reps: usize,
    /// Probe threads per pair; 0 = one per cpu of the core node (the
    /// aggregate-bandwidth configuration).
    pub probe_threads: usize,
    /// Pin probe threads to node cpus (best effort — see module docs).
    pub pin: bool,
}

impl Default for BenchOpts {
    fn default() -> Self {
        // 3 × 64 MiB per pair: far past LLC so the triad streams DRAM
        BenchOpts { elems: 8 << 20, reps: 3, probe_threads: 0, pin: true }
    }
}

impl BenchOpts {
    /// Tiny buffers, one rep, one probe thread — the CI smoke
    /// configuration (`arclight calibrate --quick`). Exercises every
    /// code path in milliseconds; the resulting numbers are cache-hot
    /// and **not** meaningful bandwidths.
    pub fn quick() -> Self {
        BenchOpts { elems: 32 << 10, reps: 1, probe_threads: 1, pin: true }
    }
}

/// The STREAM triad over three equal-length f64 slices.
fn triad(a: &mut [f64], b: &[f64], c: &[f64]) {
    const S: f64 = 3.0;
    for ((x, y), z) in a.iter_mut().zip(b).zip(c) {
        *x = *y + S * *z;
    }
}

/// Measure one (core node, memory node) pair: aggregate GB/s of the
/// core node's probe threads streaming buffers resident on the memory
/// node.
fn measure_pair(host: &HostTopology, core_node: usize, mem_node: usize, opts: &BenchOpts) -> f64 {
    let cpus = &host.nodes[core_node].cpus;
    let nthreads = match opts.probe_threads {
        0 => cpus.len(),
        n => n.min(cpus.len()),
    }
    .max(1);
    let elems_per = (opts.elems / nthreads).max(1 << 10);
    let mem_cpu = host.nodes[mem_node].cpus[0];
    let reps = opts.reps.max(1);
    let pin = opts.pin;
    let start = Arc::new(Barrier::new(nthreads));
    let mut handles = Vec::with_capacity(nthreads);
    for t in 0..nthreads {
        let cpu = cpus[t % cpus.len()];
        let start = start.clone();
        handles.push(std::thread::spawn(move || {
            // first-touch the stream buffers on the memory node: pages
            // fault where the writing thread runs
            if pin {
                affinity::pin_current_thread(mem_cpu);
            }
            let mut a = vec![0.0f64; elems_per];
            let mut b = vec![0.0f64; elems_per];
            let mut c = vec![0.0f64; elems_per];
            for i in 0..elems_per {
                a[i] = 1.0;
                b[i] = (i % 97) as f64;
                c[i] = (i % 89) as f64;
            }
            // move onto the probing core node and stream
            if pin {
                affinity::pin_current_thread(cpu);
            }
            triad(&mut a, &b, &c); // warmup (faults already paid above)
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                start.wait(); // all probes stream simultaneously
                let t0 = Instant::now();
                triad(&mut a, &b, &c);
                best = best.min(t0.elapsed().as_secs_f64().max(1e-9));
            }
            std::hint::black_box(a[0] + b[0] + c[0]);
            // 2 loads + 1 store per element
            (3 * elems_per * 8) as f64 / best
        }));
    }
    let sum: f64 = handles.into_iter().map(|h| h.join().unwrap_or(0.0)).sum();
    sum / 1e9
}

/// Measure the full node-pair bandwidth matrix of `host`, pair by pair
/// (pairs run sequentially so they never contend with each other).
/// `matrix[i][j]` is GB/s from cores of node `i` to memory of node `j`.
pub fn measure_matrix(host: &HostTopology, opts: &BenchOpts) -> Vec<Vec<f64>> {
    let n = host.n_nodes();
    let mut m = vec![vec![0.0; n]; n];
    for (i, row) in m.iter_mut().enumerate() {
        for (j, cell) in row.iter_mut().enumerate() {
            *cell = measure_pair(host, i, j, opts);
        }
    }
    m
}

/// One stored calibration: the measured matrix plus the fingerprint of
/// the machine it was measured on.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    /// [`HostTopology::fingerprint`] at measurement time.
    pub fingerprint: String,
    /// Measured `matrix[core_node][mem_node]` in GB/s.
    pub matrix_gb: Vec<Vec<f64>>,
}

impl Calibration {
    /// Serialize to the cache JSON blob (deterministic key order).
    pub fn to_json(&self) -> Json {
        let rows = self
            .matrix_gb
            .iter()
            .map(|r| Json::Arr(r.iter().map(|&g| Json::Num(g)).collect()))
            .collect();
        obj(vec![
            ("version", CACHE_VERSION.into()),
            ("fingerprint", self.fingerprint.as_str().into()),
            ("matrix_gb", Json::Arr(rows)),
        ])
    }

    /// Strict parse of a cache blob. Anything short of a well-formed,
    /// current-version object with a square matrix of finite positive
    /// numbers is an error — corrupt or truncated caches must fall
    /// back to re-measurement, never feed garbage into the cost model.
    pub fn parse(text: &str) -> Result<Calibration, String> {
        let j = Json::parse(text)?;
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .ok_or("calibration cache: missing version")?;
        if version != CACHE_VERSION {
            return Err(format!("calibration cache: unsupported version {version}"));
        }
        let fingerprint = j
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or("calibration cache: missing fingerprint")?
            .to_string();
        let rows = j
            .get("matrix_gb")
            .and_then(Json::as_arr)
            .ok_or("calibration cache: missing matrix_gb")?;
        let n = rows.len();
        if n == 0 {
            return Err("calibration cache: empty matrix".into());
        }
        let mut matrix_gb = Vec::with_capacity(n);
        for row in rows {
            let row = row.as_arr().ok_or("calibration cache: matrix row is not an array")?;
            if row.len() != n {
                return Err("calibration cache: matrix is not square".into());
            }
            let mut out = Vec::with_capacity(n);
            for v in row {
                let g = v.as_f64().ok_or("calibration cache: non-numeric bandwidth")?;
                if !g.is_finite() || g <= 0.0 {
                    return Err(format!("calibration cache: bad bandwidth {g}"));
                }
                out.push(g);
            }
            matrix_gb.push(out);
        }
        Ok(Calibration { fingerprint, matrix_gb })
    }

    /// Load and parse the cache at `path`.
    pub fn load(path: &Path) -> Result<Calibration, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Write the cache at `path`, creating parent directories.
    pub fn store(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
    }
}

/// Result of [`calibrate`]: the calibration plus whether it came off
/// disk (`true` ⇒ zero re-measurement this run).
#[derive(Clone, Debug)]
pub struct CalibrationOutcome {
    pub cal: Calibration,
    pub from_cache: bool,
}

/// [`calibrate`] with an injectable measurement function — the seam
/// the cache tests use to count (and fake) measurements.
pub fn calibrate_with<F>(
    host: &HostTopology,
    path: &Path,
    force: bool,
    measure: F,
) -> std::io::Result<CalibrationOutcome>
where
    F: FnOnce(&HostTopology) -> Vec<Vec<f64>>,
{
    let fingerprint = host.fingerprint();
    if !force {
        if let Ok(cal) = Calibration::load(path) {
            if cal.fingerprint == fingerprint && cal.matrix_gb.len() == host.n_nodes() {
                return Ok(CalibrationOutcome { cal, from_cache: true });
            }
        }
    }
    let matrix_gb = measure(host);
    let cal = Calibration { fingerprint, matrix_gb };
    cal.store(path)?;
    Ok(CalibrationOutcome { cal, from_cache: false })
}

/// The calibrated matrix for `host`, measured at most once: a cache at
/// `path` whose fingerprint matches the live machine is returned as
/// is; a missing, corrupt, or stale cache (or `force`) triggers one
/// streaming measurement whose result is stored back.
pub fn calibrate(
    host: &HostTopology,
    path: &Path,
    force: bool,
    opts: &BenchOpts,
) -> std::io::Result<CalibrationOutcome> {
    calibrate_with(host, path, force, |h| measure_matrix(h, opts))
}

/// Load-only lookup: the cached measured matrix for `host`, or `None`
/// when the cache is absent, unparseable, or fingerprint-stale. Never
/// measures — this is the startup path of `run`/`serve`, which must
/// not spend seconds streaming; users run `arclight calibrate` once.
pub fn cached_matrix(host: &HostTopology, path: &Path) -> Option<Vec<Vec<f64>>> {
    let cal = Calibration::load(path).ok()?;
    (cal.fingerprint == host.fingerprint() && cal.matrix_gb.len() == host.n_nodes())
        .then_some(cal.matrix_gb)
}

/// Default on-disk cache location: `$ARCLIGHT_CALIBRATION_CACHE`, else
/// `$XDG_CACHE_HOME/arclight/bandwidth.json`, else
/// `$HOME/.cache/arclight/bandwidth.json`, else a file in the working
/// directory.
pub fn default_cache_path() -> PathBuf {
    if let Some(p) = std::env::var_os("ARCLIGHT_CALIBRATION_CACHE") {
        return PathBuf::from(p);
    }
    let base = std::env::var_os("XDG_CACHE_HOME")
        .map(PathBuf::from)
        .or_else(|| std::env::var_os("HOME").map(|h| PathBuf::from(h).join(".cache")));
    match base {
        Some(b) => b.join("arclight").join("bandwidth.json"),
        None => PathBuf::from("arclight-bandwidth.json"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::topology::HostNode;

    fn fake_host() -> HostTopology {
        HostTopology {
            nodes: vec![
                HostNode { id: 0, cpus: vec![0, 1], mem_total_kb: 1 },
                HostNode { id: 1, cpus: vec![2, 3], mem_total_kb: 1 },
            ],
            distance: vec![vec![10, 20], vec![20, 10]],
        }
    }

    fn tiny_opts() -> BenchOpts {
        // smallest legal measurement: keeps the unit test in the
        // millisecond range (pinning fails harmlessly off-host)
        BenchOpts { elems: 1 << 10, reps: 1, probe_threads: 1, pin: true }
    }

    #[test]
    fn triad_computes_the_stream_kernel() {
        let b = [1.0, 2.0, 3.0];
        let c = [10.0, 20.0, 30.0];
        let mut a = [0.0; 3];
        triad(&mut a, &b, &c);
        assert_eq!(a, [31.0, 62.0, 93.0]);
    }

    #[test]
    fn measurement_fills_a_positive_square_matrix() {
        let m = measure_matrix(&fake_host(), &tiny_opts());
        assert_eq!(m.len(), 2);
        assert!(m.iter().all(|r| r.len() == 2));
        assert!(m.iter().flatten().all(|&g| g.is_finite() && g > 0.0), "{m:?}");
    }

    #[test]
    fn calibration_roundtrips_through_json() {
        let cal = Calibration {
            fingerprint: "nodes=2;n0=0-1".into(),
            matrix_gb: vec![vec![101.5, 22.25], vec![23.0, 99.0]],
        };
        let text = cal.to_json().to_string();
        let back = Calibration::parse(&text).unwrap();
        assert_eq!(back, cal);
    }

    #[test]
    fn corrupt_or_truncated_caches_are_rejected() {
        // outright garbage
        assert!(Calibration::parse("not json").is_err());
        // truncated mid-object
        let good = Calibration {
            fingerprint: "fp".into(),
            matrix_gb: vec![vec![100.0, 20.0], vec![20.0, 100.0]],
        }
        .to_json()
        .to_string();
        assert!(Calibration::parse(&good[..good.len() / 2]).is_err());
        // structurally valid JSON, wrong shape
        assert!(Calibration::parse(r#"{"version":1,"fingerprint":"x","matrix_gb":[]}"#).is_err());
        assert!(Calibration::parse(
            r#"{"version":1,"fingerprint":"x","matrix_gb":[[100.0,20.0],[20.0]]}"#
        )
        .is_err());
        // non-positive and non-finite bandwidths are poison
        assert!(Calibration::parse(r#"{"version":1,"fingerprint":"x","matrix_gb":[[0.0]]}"#)
            .is_err());
        // unknown version
        assert!(Calibration::parse(r#"{"version":9,"fingerprint":"x","matrix_gb":[[1.0]]}"#)
            .is_err());
        // missing fields
        assert!(Calibration::parse(r#"{"version":1,"matrix_gb":[[1.0]]}"#).is_err());
    }

    #[test]
    fn calibrate_measures_once_then_serves_from_cache() {
        let dir = std::env::temp_dir().join(format!("arclight-bench-{}", std::process::id()));
        let path = dir.join("sub").join("bandwidth.json");
        let host = fake_host();
        let measured = std::sync::atomic::AtomicUsize::new(0);
        let fake = |_: &HostTopology| {
            measured.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            vec![vec![100.0, 10.0], vec![10.0, 100.0]]
        };
        // first run measures and stores (creating parent dirs)
        let first = calibrate_with(&host, &path, false, fake).unwrap();
        assert!(!first.from_cache);
        assert_eq!(measured.load(std::sync::atomic::Ordering::Relaxed), 1);
        // second run is a pure cache hit: zero re-measurement
        let second = calibrate_with(&host, &path, false, |_| {
            measured.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            unreachable!("cache hit must not re-measure")
        })
        .unwrap();
        assert!(second.from_cache);
        assert_eq!(second.cal, first.cal);
        assert_eq!(measured.load(std::sync::atomic::Ordering::Relaxed), 1);
        // load-only lookup agrees
        assert_eq!(cached_matrix(&host, &path), Some(first.cal.matrix_gb.clone()));
        // force re-measures even with a valid cache
        let forced = calibrate_with(&host, &path, true, |_| vec![vec![9.0, 9.0], vec![9.0, 9.0]])
            .unwrap();
        assert!(!forced.from_cache);
        assert_eq!(forced.cal.matrix_gb[0][0], 9.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_mismatch_invalidates_the_cache() {
        let dir = std::env::temp_dir().join(format!("arclight-bench-fp-{}", std::process::id()));
        let path = dir.join("bandwidth.json");
        let host = fake_host();
        calibrate_with(&host, &path, false, |_| vec![vec![100.0, 10.0], vec![10.0, 100.0]])
            .unwrap();
        // same machine minus one cpu: different fingerprint
        let mut changed = fake_host();
        changed.nodes[1].cpus.pop();
        assert_eq!(cached_matrix(&changed, &path), None, "stale cache must not be served");
        let re = calibrate_with(&changed, &path, false, |_| {
            vec![vec![50.0, 5.0], vec![5.0, 50.0]]
        })
        .unwrap();
        assert!(!re.from_cache, "fingerprint mismatch must re-measure");
        // the cache now carries the new machine; the old one is stale
        assert_eq!(cached_matrix(&host, &path), None);
        assert!(cached_matrix(&changed, &path).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_cache_file_falls_back_to_measurement() {
        let dir = std::env::temp_dir().join(format!("arclight-bench-bad-{}", std::process::id()));
        let path = dir.join("bandwidth.json");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, "{\"version\":1,\"finger").unwrap();
        let host = fake_host();
        assert_eq!(cached_matrix(&host, &path), None);
        let out = calibrate_with(&host, &path, false, |_| {
            vec![vec![80.0, 8.0], vec![8.0, 80.0]]
        })
        .unwrap();
        assert!(!out.from_cache);
        // and the rewrite repaired the file
        assert!(cached_matrix(&host, &path).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
