//! IEEE 754 binary16 ⇄ binary32 conversion.
//!
//! Q4_0 block scales are stored as f16 on disk (ggml/ALF layout); the
//! engine widens them to f32 once at load time. The conversions here are
//! bit-exact with the hardware/`numpy` semantics (round-to-nearest-even
//! on narrowing), which keeps the Rust loader byte-compatible with the
//! Python writer.

/// Widen an IEEE binary16 (as raw bits) to f32.
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = u32::from(bits >> 15) << 31;
    let exp = (bits >> 10) & 0x1F;
    let frac = u32::from(bits & 0x3FF);
    let out = match exp {
        0 => {
            if frac == 0 {
                sign // ±0
            } else {
                // subnormal: value = frac * 2^-24
                let v = frac as f32 * (-24f32).exp2();
                return if sign != 0 { -v } else { v };
            }
        }
        0x1F => sign | 0x7F80_0000 | (frac << 13), // inf / nan
        _ => sign | ((u32::from(exp) + 112) << 23) | (frac << 13),
    };
    f32::from_bits(out)
}

/// Narrow an f32 to IEEE binary16 bits, round-to-nearest-even.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // inf / nan
        let nan = if frac != 0 { 0x200 } else { 0 };
        return sign | 0x7C00 | nan | ((frac >> 13) as u16 & 0x3FF);
    }

    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow → inf
    }
    if unbiased >= -14 {
        // normal range
        let mut mant = frac >> 13;
        let rest = frac & 0x1FFF;
        // round to nearest even
        if rest > 0x1000 || (rest == 0x1000 && (mant & 1) == 1) {
            mant += 1;
        }
        let mut e16 = (unbiased + 15) as u32;
        if mant == 0x400 {
            mant = 0;
            e16 += 1;
            if e16 >= 0x1F {
                return sign | 0x7C00;
            }
        }
        return sign | ((e16 as u16) << 10) | (mant as u16);
    }
    if unbiased >= -25 {
        // subnormal
        let full = frac | 0x80_0000;
        let shift = (-14 - unbiased + 13) as u32;
        let mant = full >> shift;
        let rest = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut mant = mant;
        if rest > half || (rest == half && (mant & 1) == 1) {
            mant += 1;
        }
        return sign | (mant as u16);
    }
    sign // underflow → ±0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0xBC00), -1.0);
        assert_eq!(f16_to_f32(0x4000), 2.0);
        assert_eq!(f16_to_f32(0x0000), 0.0);
        assert_eq!(f16_to_f32(0x3800), 0.5);
        assert_eq!(f16_to_f32(0x7BFF), 65504.0); // f16 max
        assert!(f16_to_f32(0x7C00).is_infinite());
        assert!(f16_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn narrowing_known() {
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.0), 0xC000);
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF);
        assert_eq!(f32_to_f16(1e6), 0x7C00); // overflow
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn roundtrip_exact_for_all_f16() {
        // every finite f16 must survive f16 -> f32 -> f16 exactly
        for bits in 0..=0xFFFFu16 {
            let exp = (bits >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan compare differently
            }
            let x = f16_to_f32(bits);
            let back = f32_to_f16(x);
            // +0/-0 both fine, otherwise exact
            if bits == 0x8000 && back == 0x8000 || bits == back {
                continue;
            }
            panic!("roundtrip failed: {bits:#06x} -> {x} -> {back:#06x}");
        }
    }

    #[test]
    fn subnormals() {
        let tiny = f16_to_f32(0x0001); // smallest positive subnormal
        assert!(tiny > 0.0 && tiny < 1e-7);
        assert_eq!(f32_to_f16(tiny), 0x0001);
    }

    #[test]
    fn rounding_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between two f16 values; ties-to-even
        // keeps the even mantissa (1.0).
        let x = 1.0 + (-11f32).exp2();
        assert_eq!(f32_to_f16(x), 0x3C00);
        // 1 + 3*2^-11 halfway -> rounds up to even (mantissa 2)
        let y = 1.0 + 3.0 * (-11f32).exp2();
        assert_eq!(f32_to_f16(y), 0x3C02);
    }
}
