//! Deterministic PRNG (xoshiro256**) for synthetic weights, workload
//! generation and property tests.
//!
//! Everything in this repo that needs randomness takes an explicit seed
//! so benchmark figures and tests are bit-reproducible run to run.

/// xoshiro256** — fast, high-quality, no dependencies.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (-53f64).exp2()
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Fill a slice with N(0, scale²) values.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out {
            *v = self.normal() * scale;
        }
    }

    /// Exponentially distributed with the given mean (Poisson arrivals).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.next_f64()).ln()
    }
}

/// A stateless deterministic hash → [0,1) used for per-(worker, op)
/// execution jitter in the simulator: the same (seed, a, b) always gives
/// the same value, so simulated runs are exactly reproducible.
pub fn unit_hash(seed: u64, a: u64, b: u64) -> f64 {
    let mut z = seed ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ b.rotate_left(32);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 * (-53f64).exp2()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.range(3, 9);
            assert!((3..=9).contains(&n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn unit_hash_stable_and_spread() {
        assert_eq!(unit_hash(1, 2, 3), unit_hash(1, 2, 3));
        assert_ne!(unit_hash(1, 2, 3), unit_hash(1, 2, 4));
        let mut lo = 0;
        for i in 0..1000u64 {
            if unit_hash(9, i, 0) < 0.5 {
                lo += 1;
            }
        }
        assert!((400..600).contains(&lo));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.2, "mean {mean}");
    }
}
