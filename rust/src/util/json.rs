//! Minimal JSON parser + writer (std-only).
//!
//! Used for the ALF metadata blob, the AOT manifest, runtime config
//! files, and the server wire protocol. Supports the full JSON grammar
//! except `\u` surrogate pairs outside the BMP are passed through
//! unchecked.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys use a BTreeMap so serialization is
/// deterministic (stable goldens, stable manifests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access that propagates as Option.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors used all over the server/report code.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| "invalid utf8")?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"config":{"dim":64,"eps":1e-06},"tensors":[{"name":"a","shape":[3,4]}]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""élétrange \"q\"""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "élétrange \"q\"");
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn obj_builder() {
        let j = obj(vec![("x", 1usize.into()), ("y", "z".into())]);
        assert_eq!(j.to_string(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src =
            r#"{"config":{"dim":64},"decode":{"args":[{"name":"t","dtype":"i32","shape":[]}]}}"#;
        let j = Json::parse(src).unwrap();
        let args = j.get("decode").unwrap().get("args").unwrap().as_arr().unwrap();
        assert_eq!(args[0].get("dtype").unwrap().as_str(), Some("i32"));
        assert_eq!(args[0].get("shape").unwrap().as_arr().unwrap().len(), 0);
    }
}
