//! Summary statistics for benchmarks and serving metrics.

/// Online summary of a series of samples plus percentile support.
/// Keeps all samples (benchmark scale, not production scale).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Linear-interpolation percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let n = self.samples.len();
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.samples[lo]
        } else {
            let w = rank - lo as f64;
            self.samples[lo] * (1.0 - w) + self.samples[hi] * w
        }
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Format a seconds value for human-readable bench output.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for x in 1..=100 {
            s.add(x as f64);
        }
        assert_eq!(s.p50(), 50.5);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn empty_safe() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.0), "2.000 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(3.2e-6), "3.200 µs");
        assert_eq!(fmt_duration(5e-9), "5.0 ns");
    }
}
