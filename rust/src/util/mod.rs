//! Small self-contained utilities shared across the engine.
//!
//! The build environment vendors only the `xla` dependency chain, so
//! anything an ordinary project would pull from crates.io (f16
//! conversion, a PRNG, JSON, summary statistics) lives here as a tiny
//! std-only implementation.

pub mod f16;
pub mod json;
pub mod rng;
pub mod stats;

pub use f16::{f16_to_f32, f32_to_f16};
pub use rng::Rng;

/// Round `n` up to the next multiple of `align` (power of two not required).
pub fn align_up(n: usize, align: usize) -> usize {
    debug_assert!(align > 0);
    n.div_ceil(align) * align
}

/// Split `n` items into `parts` contiguous chunks as evenly as possible;
/// returns the `[start, end)` range of chunk `idx`. The first `n % parts`
/// chunks get one extra item — the same policy llama.cpp and ArcLight use
/// to hand rows to worker threads.
pub fn chunk_range(n: usize, parts: usize, idx: usize) -> (usize, usize) {
    debug_assert!(idx < parts);
    let base = n / parts;
    let rem = n % parts;
    let start = idx * base + idx.min(rem);
    let len = base + usize::from(idx < rem);
    (start, (start + len).min(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_up_basics() {
        assert_eq!(align_up(0, 64), 0);
        assert_eq!(align_up(1, 64), 64);
        assert_eq!(align_up(64, 64), 64);
        assert_eq!(align_up(65, 64), 128);
        assert_eq!(align_up(10, 3), 12);
    }

    #[test]
    fn chunk_range_covers_everything_once() {
        for n in [0usize, 1, 7, 48, 100, 193] {
            for parts in [1usize, 2, 3, 7, 48] {
                let mut covered = 0;
                let mut prev_end = 0;
                for i in 0..parts {
                    let (s, e) = chunk_range(n, parts, i);
                    assert_eq!(s, prev_end, "n={n} parts={parts} i={i}");
                    assert!(e >= s);
                    covered += e - s;
                    prev_end = e;
                }
                assert_eq!(covered, n);
                assert_eq!(prev_end, n);
            }
        }
    }

    #[test]
    fn chunk_range_is_balanced() {
        for i in 0..5 {
            let (s, e) = chunk_range(17, 5, i);
            assert!(e - s == 3 || e - s == 4);
        }
    }
}
