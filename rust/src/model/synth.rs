//! Weight loading: from ALF files or from the deterministic synthetic
//! generator (bench geometries, where values are irrelevant but
//! numerical stability is not).
//!
//! Both paths honour the TP shard table: row shards slice the logical
//! Q4_0 stream by rows, column shards by 32-element blocks, so a TP
//! build holds byte-identical data to the single-node build — the basis
//! of the TP-equivalence integration tests.

use anyhow::{bail, Result};

use crate::tensor::DType;
use crate::util::Rng;

use super::alf::AlfFile;
use super::config::ModelConfig;
use super::qwen3::{ModelGraphs, ShardInfo, ShardKind};

/// Logical (dtype, n, k) of a weight by its ALF name. `k == 0` marks a
/// 1-D f32 vector.
pub fn logical_shape(cfg: &ModelConfig, name: &str) -> Result<(DType, usize, usize)> {
    let leaf = name.rsplit('.').next().unwrap_or(name);
    Ok(match leaf {
        "tok_emb" => (DType::F32, cfg.vocab, cfg.dim),
        "lm_head" => (DType::Q4_0, cfg.vocab, cfg.dim),
        "final_norm" | "attn_norm" | "mlp_norm" => (DType::F32, cfg.dim, 0),
        "q_norm" | "k_norm" => (DType::F32, cfg.head_dim, 0),
        "wq" => (DType::Q4_0, cfg.q_dim(), cfg.dim),
        "wk" | "wv" => (DType::Q4_0, cfg.kv_dim(), cfg.dim),
        "wo" => (DType::Q4_0, cfg.dim, cfg.q_dim()),
        "w_gate" | "w_up" => (DType::Q4_0, cfg.ffn_dim, cfg.dim),
        "w_down" => (DType::Q4_0, cfg.dim, cfg.ffn_dim),
        _ => bail!("unknown weight '{name}'"),
    })
}

/// FNV-1a for per-tensor seeds.
fn name_seed(global: u64, name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ global.wrapping_mul(0x100_0000_01b3);
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Logical payload of one weight: (dtype, n, k, bytes).
type Payload = (DType, usize, usize, Vec<u8>);

/// Generate the logical payload of one weight.
fn synth_payload(cfg: &ModelConfig, name: &str, seed: u64) -> Result<Payload> {
    let (dtype, n, k) = logical_shape(cfg, name)?;
    let mut rng = Rng::new(name_seed(seed, name));
    let leaf = name.rsplit('.').next().unwrap_or(name);
    let payload = match dtype {
        DType::F32 if k == 0 => {
            // norm gains: near 1
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.1);
            v.iter().map(|x| 1.0 + x).flat_map(|x| x.to_le_bytes()).collect()
        }
        DType::F32 => {
            // embedding table
            let mut v = vec![0.0f32; n * k];
            rng.fill_normal(&mut v, 0.02);
            v.iter().flat_map(|x| x.to_le_bytes()).collect()
        }
        DType::Q4_0 => {
            let scale = match leaf {
                "wo" => 1.0 / (cfg.q_dim() as f32).sqrt(),
                "w_down" => 1.0 / (cfg.ffn_dim as f32).sqrt(),
                _ => 1.0 / (cfg.dim as f32).sqrt(),
            };
            let mut row = vec![0.0f32; k];
            let mut out = Vec::with_capacity(DType::Q4_0.tensor_bytes(&[n, k]));
            for _ in 0..n {
                rng.fill_normal(&mut row, scale);
                crate::quant::quantize_row_q4_0(&row, &mut out);
            }
            out
        }
        _ => bail!("unsupported synth dtype {dtype}"),
    };
    Ok((dtype, n, k, payload))
}

/// Slice a shard out of a logical payload.
fn shard_bytes(
    dtype: DType,
    n: usize,
    k: usize,
    payload: &[u8],
    kind: &ShardKind,
) -> Vec<u8> {
    match kind {
        ShardKind::Full => payload.to_vec(),
        ShardKind::Rows(r0, r1) => {
            let rb = dtype.row_bytes(k.max(1));
            payload[r0 * rb..r1 * rb].to_vec()
        }
        ShardKind::Cols(c0, c1) => {
            let rb = dtype.row_bytes(k);
            let b0 = dtype.row_bytes(*c0);
            let b1 = dtype.row_bytes(*c1);
            let mut out = Vec::with_capacity(n * (b1 - b0));
            for r in 0..n {
                out.extend_from_slice(&payload[r * rb + b0..r * rb + b1]);
            }
            out
        }
    }
}

fn write_shard(m: &ModelGraphs, id: crate::tensor::TensorId, bytes: &[u8]) {
    let pool = m.pool.as_ref().expect("real buffers required");
    let buf = m.decode.buf(id);
    assert_eq!(buf.len, bytes.len(), "shard size mismatch for {}", m.decode.meta(id).name);
    unsafe {
        pool.arena(buf.arena).bytes_mut(buf.off, buf.len).copy_from_slice(bytes);
    }
}

/// Fill every weight leaf with deterministic synthetic data.
pub fn fill_synthetic(m: &ModelGraphs, seed: u64) -> Result<()> {
    // group shards by logical tensor so each is generated once
    type ShardRef<'a> = &'a (crate::tensor::TensorId, ShardInfo);
    let mut by_logical: std::collections::BTreeMap<&str, Vec<ShardRef>> = Default::default();
    for ws in &m.weights {
        by_logical.entry(ws.1.logical.as_str()).or_default().push(ws);
    }
    for (logical, shards) in by_logical {
        let (dtype, n, k, payload) = synth_payload(&m.cfg, logical, seed)?;
        for (id, info) in shards {
            write_shard(m, *id, &shard_bytes(dtype, n, k, &payload, &info.kind));
        }
    }
    Ok(())
}

/// Fill every weight leaf from an ALF file (paper path: Qwen3 Q4_0).
pub fn load_alf(m: &ModelGraphs, alf: &AlfFile) -> Result<()> {
    for (id, info) in &m.weights {
        let t = alf.tensor(&info.logical)?;
        let bytes = match &info.kind {
            ShardKind::Full => alf.payload(t).to_vec(),
            ShardKind::Rows(r0, r1) => alf.rows(t, *r0, *r1).to_vec(),
            ShardKind::Cols(c0, c1) => alf.col_slice(t, *c0, *c1),
        };
        write_shard(m, *id, &bytes);
    }
    Ok(())
}

/// Zero all KV caches (between sequences).
pub fn reset_kv(m: &ModelGraphs) {
    let pool = m.pool.as_ref().expect("real buffers required");
    for id in &m.kv_ids {
        let buf = m.decode.buf(*id);
        unsafe {
            pool.arena(buf.arena).bytes_mut(buf.off, buf.len).fill(0);
        }
    }
}

/// Write a synthetic model to an ALF file (the `arclight generate` CLI).
pub fn generate_alf(cfg: &ModelConfig, seed: u64, path: &std::path::Path) -> Result<()> {
    use crate::util::json::{obj, Json};
    let mut names = vec!["tok_emb".to_string()];
    for l in 0..cfg.n_layers {
        let layer_weights = [
            "attn_norm",
            "wq",
            "wk",
            "wv",
            "wo",
            "q_norm",
            "k_norm",
            "mlp_norm",
            "w_gate",
            "w_up",
            "w_down",
        ];
        for s in layer_weights {
            names.push(format!("layers.{l}.{s}"));
        }
    }
    names.push("final_norm".into());
    names.push("lm_head".into());

    let mut tensors = Vec::new();
    for name in names {
        let (dtype, n, k, payload) = synth_payload(cfg, &name, seed)?;
        let shape = if k == 0 { vec![n] } else { vec![n, k] };
        tensors.push((name, dtype, shape, payload));
    }
    let config = obj(vec![
        ("dim", cfg.dim.into()),
        ("n_layers", cfg.n_layers.into()),
        ("n_heads", cfg.n_heads.into()),
        ("n_kv_heads", cfg.n_kv_heads.into()),
        ("head_dim", cfg.head_dim.into()),
        ("ffn_dim", cfg.ffn_dim.into()),
        ("vocab", cfg.vocab.into()),
        ("max_seq", cfg.max_seq.into()),
        ("rope_theta", Json::Num(cfg.rope_theta as f64)),
        ("norm_eps", Json::Num(cfg.norm_eps as f64)),
    ]);
    super::alf::write_alf(path, config, &tensors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::qwen3::BuildSpec;

    #[test]
    fn synthetic_fill_is_deterministic() {
        let m1 = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 1));
        let m2 = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 1));
        fill_synthetic(&m1, 7).unwrap();
        fill_synthetic(&m2, 7).unwrap();
        let id1 = m1.decode.find("layers.0.wq").unwrap();
        let id2 = m2.decode.find("layers.0.wq").unwrap();
        let (b1, b2) = (m1.decode.buf(id1), m2.decode.buf(id2));
        unsafe {
            let p1 = m1.pool.as_ref().unwrap().arena(b1.arena).bytes(b1.off, b1.len);
            let p2 = m2.pool.as_ref().unwrap().arena(b2.arena).bytes(b2.off, b2.len);
            assert_eq!(p1, p2);
        }
    }

    #[test]
    fn tp_shards_equal_logical_slices() {
        let single = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 1));
        let tp = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 2));
        fill_synthetic(&single, 3).unwrap();
        fill_synthetic(&tp, 3).unwrap();
        // wq part 1 == rows 32..64 of the logical wq
        let full_id = single.decode.find("layers.0.wq").unwrap();
        let part_id = tp.decode.find("layers.0.wq.1").unwrap();
        let fb = single.decode.buf(full_id);
        let pb = tp.decode.buf(part_id);
        unsafe {
            let full = single.pool.as_ref().unwrap().arena(fb.arena).bytes(fb.off, fb.len);
            let part = tp.pool.as_ref().unwrap().arena(pb.arena).bytes(pb.off, pb.len);
            assert_eq!(&full[full.len() / 2..], part);
        }
    }

    #[test]
    fn generate_alf_then_load_roundtrip() {
        let dir = std::env::temp_dir().join("arclight_synth_alf");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.alf");
        let cfg = ModelConfig::tiny();
        generate_alf(&cfg, 11, &path).unwrap();
        let alf = AlfFile::open(&path).unwrap();
        assert_eq!(ModelConfig::from_json(&alf.config).unwrap(), cfg);

        let m = ModelGraphs::build(BuildSpec::arclight(cfg.clone(), 1));
        load_alf(&m, &alf).unwrap();
        // loaded bytes equal direct synthesis
        let m2 = ModelGraphs::build(BuildSpec::arclight(cfg, 1));
        fill_synthetic(&m2, 11).unwrap();
        let i1 = m.decode.find("lm_head").unwrap();
        let i2 = m2.decode.find("lm_head").unwrap();
        let (b1, b2) = (m.decode.buf(i1), m2.decode.buf(i2));
        unsafe {
            assert_eq!(
                m.pool.as_ref().unwrap().arena(b1.arena).bytes(b1.off, b1.len),
                m2.pool.as_ref().unwrap().arena(b2.arena).bytes(b2.off, b2.len)
            );
        }
    }

    #[test]
    fn kv_reset_zeroes() {
        let m = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 1));
        let id = m.kv_ids[0];
        let b = m.decode.buf(id);
        unsafe {
            m.pool.as_ref().unwrap().arena(b.arena).bytes_mut(b.off, b.len).fill(7);
        }
        reset_kv(&m);
        unsafe {
            let bytes = m.pool.as_ref().unwrap().arena(b.arena).bytes(b.off, b.len);
            assert!(bytes.iter().all(|&x| x == 0));
        }
    }

    #[test]
    fn unknown_weight_name_rejected() {
        assert!(logical_shape(&ModelConfig::tiny(), "layers.0.bogus").is_err());
    }
}
