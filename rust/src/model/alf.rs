//! ALF (ArcLight Format) weight-file reader/writer — byte-compatible
//! with `python/compile/alf.py` (the repo's GGUF stand-in).
//!
//! Layout: `"ALF1"` magic, u32 version, u64 meta length, JSON metadata
//! (config + tensor table), zero padding to 64, then 64-byte-aligned
//! tensor payloads. Q4_0 payloads are the ggml block stream.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::DType;
use crate::util::align_up;
use crate::util::json::{obj, Json};

const MAGIC: &[u8; 4] = b"ALF1";
const VERSION: u32 = 1;
const ALIGN: usize = 64;

/// One tensor record.
#[derive(Clone, Debug)]
pub struct AlfTensor {
    pub name: String,
    pub dtype: DType,
    /// Logical shape (Q4_0: `[N, K]` with K the quantized axis).
    pub shape: Vec<usize>,
    /// Byte range within the file's data region.
    pub offset: usize,
    pub nbytes: usize,
}

/// A parsed ALF file, payload held in memory.
pub struct AlfFile {
    pub config: Json,
    pub tensors: BTreeMap<String, AlfTensor>,
    data: Vec<u8>,
    data_start: usize,
}

impl AlfFile {
    pub fn open(path: impl AsRef<Path>) -> Result<AlfFile> {
        let raw = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(raw)
    }

    pub fn parse(raw: Vec<u8>) -> Result<AlfFile> {
        if raw.len() < 16 || &raw[..4] != MAGIC {
            bail!("not an ALF file");
        }
        let version = u32::from_le_bytes(raw[4..8].try_into()?);
        if version != VERSION {
            bail!("unsupported ALF version {version}");
        }
        let meta_len = u64::from_le_bytes(raw[8..16].try_into()?) as usize;
        let meta_str = std::str::from_utf8(&raw[16..16 + meta_len])?;
        let meta = Json::parse(meta_str).map_err(|e| anyhow::anyhow!("bad ALF metadata: {e}"))?;
        let data_start = align_up(16 + meta_len, ALIGN);

        let mut tensors = BTreeMap::new();
        for t in meta.get("tensors").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = t.get("name").and_then(Json::as_str).context("tensor name")?.to_string();
            let dtype = DType::parse(t.get("dtype").and_then(Json::as_str).unwrap_or(""))
                .context("tensor dtype")?;
            let shape: Vec<usize> = t
                .get("shape")
                .and_then(Json::as_arr)
                .context("tensor shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let offset = t.get("offset").and_then(Json::as_usize).context("offset")?;
            let nbytes = t.get("nbytes").and_then(Json::as_usize).context("nbytes")?;
            if data_start + offset + nbytes > raw.len() {
                bail!("tensor '{name}' exceeds file size");
            }
            let expect = dtype.tensor_bytes(&shape);
            if expect != nbytes {
                bail!("tensor '{name}': nbytes {nbytes} != {expect} for {dtype} {shape:?}");
            }
            tensors.insert(name.clone(), AlfTensor { name, dtype, shape, offset, nbytes });
        }
        let config = meta.get("config").cloned().unwrap_or(Json::Obj(Default::default()));
        Ok(AlfFile { config, tensors, data: raw, data_start })
    }

    pub fn tensor(&self, name: &str) -> Result<&AlfTensor> {
        self.tensors.get(name).with_context(|| format!("tensor '{name}' not in ALF"))
    }

    /// Raw payload bytes of a tensor.
    pub fn payload(&self, t: &AlfTensor) -> &[u8] {
        &self.data[self.data_start + t.offset..self.data_start + t.offset + t.nbytes]
    }

    /// Payload of rows `[r0, r1)` (both dtypes are row-contiguous).
    pub fn rows(&self, t: &AlfTensor, r0: usize, r1: usize) -> &[u8] {
        let k = crate::tensor::row_len(&t.shape);
        let rb = t.dtype.row_bytes(k);
        let p = self.payload(t);
        &p[r0 * rb..r1 * rb]
    }

    /// Column slice `[c0, c1)` of every row, concatenated — used for
    /// the TP column partition of W_o/W_down (§3.2). For Q4_0, `c0`
    /// and `c1` must be multiples of 32.
    pub fn col_slice(&self, t: &AlfTensor, c0: usize, c1: usize) -> Vec<u8> {
        let k = crate::tensor::row_len(&t.shape);
        let n = crate::tensor::rows(&t.shape);
        let rb = t.dtype.row_bytes(k);
        let b0 = t.dtype.row_bytes(c0);
        let b1 = t.dtype.row_bytes(c1);
        let p = self.payload(t);
        let mut out = Vec::with_capacity(n * (b1 - b0));
        for r in 0..n {
            out.extend_from_slice(&p[r * rb + b0..r * rb + b1]);
        }
        out
    }

    /// f32 view of an f32 tensor's payload.
    pub fn f32s(&self, t: &AlfTensor) -> Vec<f32> {
        assert_eq!(t.dtype, DType::F32);
        self.payload(t)
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect()
    }
}

/// Write an ALF file (the Rust side of `arclight generate`).
pub fn write_alf(
    path: impl AsRef<Path>,
    config: Json,
    tensors: &[(String, DType, Vec<usize>, Vec<u8>)],
) -> Result<()> {
    let mut table = Vec::new();
    let mut offset = 0usize;
    for (name, dtype, shape, payload) in tensors {
        offset = align_up(offset, ALIGN);
        table.push(obj(vec![
            ("name", Json::Str(name.clone())),
            ("dtype", Json::Str(dtype.to_string())),
            ("shape", Json::Arr(shape.iter().map(|&s| Json::Num(s as f64)).collect())),
            ("offset", Json::Num(offset as f64)),
            ("nbytes", Json::Num(payload.len() as f64)),
        ]));
        offset += payload.len();
    }
    let meta = obj(vec![("config", config), ("tensors", Json::Arr(table.clone()))]).to_string();
    let header_len = 16 + meta.len();
    let data_start = align_up(header_len, ALIGN);

    let mut f = std::fs::File::create(path.as_ref())?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(meta.len() as u64).to_le_bytes())?;
    f.write_all(meta.as_bytes())?;
    f.write_all(&vec![0u8; data_start - header_len])?;
    let mut pos = 0usize;
    for (i, (_, _, _, payload)) in tensors.iter().enumerate() {
        let want = table[i].get("offset").and_then(Json::as_usize).unwrap();
        f.write_all(&vec![0u8; want - pos])?;
        f.write_all(payload)?;
        pos = want + payload.len();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_file(dir: &std::path::Path) -> std::path::PathBuf {
        let path = dir.join("t.alf");
        let a: Vec<u8> = (0..12u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
        let q = crate::quant::quantize_matrix_q4_0(&vec![0.5; 2 * 64], 2, 64);
        write_alf(
            &path,
            obj(vec![("dim", 64usize.into())]),
            &[
                ("a".into(), DType::F32, vec![3, 4], a),
                ("w".into(), DType::Q4_0, vec![2, 64], q),
            ],
        )
        .unwrap();
        path
    }

    #[test]
    fn roundtrip_rust_writer_reader() {
        let dir = std::env::temp_dir().join("alf_test_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = sample_file(&dir);
        let f = AlfFile::open(&path).unwrap();
        assert_eq!(f.config.get("dim").unwrap().as_usize(), Some(64));
        let a = f.tensor("a").unwrap();
        assert_eq!(f.f32s(a)[5], 5.0);
        let w = f.tensor("w").unwrap();
        assert_eq!(f.payload(w).len(), 2 * 2 * 18);
    }

    #[test]
    fn row_and_col_slicing() {
        let dir = std::env::temp_dir().join("alf_test_slice");
        std::fs::create_dir_all(&dir).unwrap();
        let path = sample_file(&dir);
        let f = AlfFile::open(&path).unwrap();
        let w = f.tensor("w").unwrap();
        // rows 1..2 = second half of the stream
        assert_eq!(f.rows(w, 1, 2), &f.payload(w)[36..]);
        // cols 32..64 of each row: block 1 of each row
        let cs = f.col_slice(w, 32, 64);
        assert_eq!(cs.len(), 2 * 18);
        assert_eq!(&cs[..18], &f.payload(w)[18..36]);
        assert_eq!(&cs[18..], &f.payload(w)[54..72]);
    }

    #[test]
    fn rejects_corrupt() {
        assert!(AlfFile::parse(b"NOPE".to_vec()).is_err());
        let truncated = b"ALF1\x02\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00".to_vec();
        assert!(AlfFile::parse(truncated).is_err());
    }

    #[test]
    fn python_compatible_header_math() {
        // mirror python: header is 16 + meta, data aligned to 64
        let dir = std::env::temp_dir().join("alf_test_hdr");
        std::fs::create_dir_all(&dir).unwrap();
        let path = sample_file(&dir);
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..4], b"ALF1");
        let meta_len = u64::from_le_bytes(raw[8..16].try_into().unwrap()) as usize;
        let ds = align_up(16 + meta_len, 64);
        // first tensor payload at data_start (offset 0): value 0.0f32
        assert_eq!(&raw[ds..ds + 4], &0.0f32.to_le_bytes());
    }
}
