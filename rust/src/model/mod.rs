//! Model definition layer (the paper's decoding frontend §2.1):
//! weight loading, model definition via the graph builder, and the
//! Qwen3 architecture the paper evaluates.

pub mod alf;
pub mod config;
pub mod qwen3;
pub mod synth;

pub use alf::AlfFile;
pub use config::ModelConfig;
pub use qwen3::{BuildSpec, ModelGraphs, ShardInfo, ShardKind, WeightMode};
