//! Qwen3-family model geometry (mirrors `python/compile/model.py`).

use crate::util::json::Json;

/// Decoder geometry. `dim`, `n_heads·head_dim` and `ffn_dim` must be
/// multiples of 32 (Q4_0 blocks along contraction axes).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
}

impl ModelConfig {
    /// The tiny geometry the AOT artifacts are built at (must match
    /// `python/compile/model.py::TINY`).
    pub fn tiny() -> Self {
        ModelConfig {
            dim: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            ffn_dim: 128,
            vocab: 512,
            max_seq: 64,
            rope_theta: 1e6,
            norm_eps: 1e-6,
        }
    }

    /// A ~25M-parameter model for the real-execution serving example.
    pub fn small_25m() -> Self {
        ModelConfig {
            dim: 512,
            n_layers: 8,
            n_heads: 8,
            n_kv_heads: 4,
            head_dim: 64,
            ffn_dim: 1408,
            vocab: 4096,
            max_seq: 512,
            rope_theta: 1e6,
            norm_eps: 1e-6,
        }
    }

    /// Qwen3-4B — the paper's evaluation model (§4). Simulator-only in
    /// this environment (the weights would be ~2.3 GB in Q4_0).
    pub fn qwen3_4b() -> Self {
        ModelConfig {
            dim: 2560,
            n_layers: 36,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            ffn_dim: 9728,
            vocab: 151_936,
            max_seq: 1024,
            rope_theta: 1e6,
            norm_eps: 1e-6,
        }
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [("dim", self.dim), ("q_dim", self.q_dim()), ("ffn_dim", self.ffn_dim)] {
            if v % 32 != 0 {
                return Err(format!("{name}={v} is not a multiple of 32 (Q4_0)"));
            }
        }
        if self.n_heads % self.n_kv_heads != 0 {
            return Err("n_heads must be a multiple of n_kv_heads (GQA)".into());
        }
        Ok(())
    }

    /// Approximate parameter count (for sanity checks / reporting).
    pub fn n_params(&self) -> usize {
        let per_layer = self.dim * self.q_dim()      // wq
            + 2 * self.dim * self.kv_dim()           // wk, wv
            + self.q_dim() * self.dim                // wo
            + 3 * self.dim * self.ffn_dim            // gate, up, down
            + 2 * self.dim + 2 * self.head_dim;      // norms
        self.vocab * self.dim * 2 + self.n_layers * per_layer + self.dim
    }

    /// Q4_0 matmul-weight bytes per decode token — the bandwidth-bound
    /// working set the paper's throughput analysis is built on.
    pub fn q4_weight_bytes(&self) -> usize {
        use crate::tensor::DType;
        let per_layer = DType::Q4_0.tensor_bytes(&[self.q_dim(), self.dim])
            + 2 * DType::Q4_0.tensor_bytes(&[self.kv_dim(), self.dim])
            + DType::Q4_0.tensor_bytes(&[self.dim, self.q_dim()])
            + 2 * DType::Q4_0.tensor_bytes(&[self.ffn_dim, self.dim])
            + DType::Q4_0.tensor_bytes(&[self.dim, self.ffn_dim]);
        self.n_layers * per_layer + DType::Q4_0.tensor_bytes(&[self.vocab, self.dim])
    }

    /// Parse the `config` object of an ALF/manifest JSON.
    pub fn from_json(j: &Json) -> Result<ModelConfig, String> {
        let get = |k: &str| -> Result<usize, String> {
            j.get(k).and_then(Json::as_usize).ok_or_else(|| format!("missing config.{k}"))
        };
        Ok(ModelConfig {
            dim: get("dim")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            n_kv_heads: get("n_kv_heads")?,
            head_dim: get("head_dim")?,
            ffn_dim: get("ffn_dim")?,
            vocab: get("vocab")?,
            max_seq: get("max_seq")?,
            rope_theta: j.get("rope_theta").and_then(Json::as_f64).unwrap_or(1e6) as f32,
            norm_eps: j.get("norm_eps").and_then(Json::as_f64).unwrap_or(1e-6) as f32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        ModelConfig::tiny().validate().unwrap();
        ModelConfig::small_25m().validate().unwrap();
        ModelConfig::qwen3_4b().validate().unwrap();
    }

    #[test]
    fn qwen3_4b_matches_paper_scale() {
        let c = ModelConfig::qwen3_4b();
        // ~4B params, ~2.3 GB in Q4_0 — the numbers in the paper's setup
        assert!(c.n_params() > 3_500_000_000 && c.n_params() < 4_500_000_000);
        let gb = c.q4_weight_bytes() as f64 / 1e9;
        assert!(gb > 1.6 && gb < 2.6, "{gb} GB");
    }

    #[test]
    fn small_model_is_servable_scale() {
        let c = ModelConfig::small_25m();
        assert!(c.n_params() > 15_000_000 && c.n_params() < 40_000_000);
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"dim":64,"n_layers":2,"n_heads":4,"n_kv_heads":2,"head_dim":16,
                "ffn_dim":128,"vocab":512,"max_seq":64,"rope_theta":1000000.0,
                "norm_eps":1e-06}"#,
        )
        .unwrap();
        assert_eq!(ModelConfig::from_json(&j).unwrap(), ModelConfig::tiny());
    }

    #[test]
    fn invalid_geometry_rejected() {
        let mut c = ModelConfig::tiny();
        c.dim = 48;
        assert!(c.validate().is_err());
        let mut c2 = ModelConfig::tiny();
        c2.n_kv_heads = 3;
        assert!(c2.validate().is_err());
    }
}
