//! Qwen3 model definition over the graph builder, with the paper's
//! cross-NUMA TP weight partitioning (§3.2):
//!
//! * `W_q`, `W_k`, `W_v`, `W_gate`, `W_up` — **row**-partitioned
//!   (by attention head / ffn feature) across NUMA nodes;
//! * `W_o`, `W_down` — **column**-partitioned; each node produces a
//!   full-width partial summed by Gather;
//! * KV caches — sharded by KV head, node-local;
//! * QK-norm gains — replicated per node (bytes are negligible, reads
//!   become local).
//!
//! The same construction code covers all execution strategies — with
//! one group there are no Scatter/Gather nodes and every entry has
//! width 1 (llama.cpp's single-graph mode); placements are the only
//! other variable. That makes strategy comparisons apples-to-apples,
//! exactly like the paper's benchmark setup.

use std::sync::Arc;

use crate::graph::{Graph, GraphBuilder, KvCacheSet, KvSpec};
use crate::memory::{MemoryPool, PlanMode};
use crate::numa::{NodeId, Placement};
use crate::tensor::{DType, TensorBundle, TensorId};

use super::config::ModelConfig;

/// How weight tensors are placed on the simulated machine.
#[derive(Clone, Debug, PartialEq)]
pub enum WeightMode {
    /// All weights in one node's local memory (ArcLight, single node).
    NodeLocal(NodeId),
    /// Per-node shards (ArcLight cross-NUMA TP, §3.2). Requires > 1 group.
    TpSharded,
    /// llama.cpp `-numa distribute`: the UMA buffer's pages land where
    /// first touched — row shards matching the even thread partition
    /// over `nodes` nodes (Fig. 7).
    FirstTouch { nodes: usize },
}

/// Which slice of the logical weight a leaf holds (drives both the ALF
/// loader and the synthetic generator).
#[derive(Clone, Debug, PartialEq)]
pub enum ShardKind {
    Full,
    /// Rows `[r0, r1)` of the logical `[N, K]` matrix.
    Rows(usize, usize),
    /// Columns `[c0, c1)` (K slice) of every row.
    Cols(usize, usize),
}

/// Loader directions for one weight leaf.
#[derive(Clone, Debug)]
pub struct ShardInfo {
    /// Logical tensor name (matches the ALF file).
    pub logical: String,
    pub kind: ShardKind,
}

/// Everything needed to build one model instance.
#[derive(Clone, Debug)]
pub struct BuildSpec {
    pub cfg: ModelConfig,
    /// NUMA node of each TP group; `[0]` = no TP.
    pub group_nodes: Vec<NodeId>,
    /// Total simulated NUMA nodes (arena count / placement domain).
    pub n_nodes: usize,
    pub weight_mode: WeightMode,
    /// Placement of single-mode activations.
    pub act_placement: Placement,
    /// KV-cache placement when not TP-sharded.
    pub kv_placement: Placement,
    /// Build without real buffers (virtual-time simulation only).
    pub sim_only: bool,
    /// Also build a prefill graph ingesting this many tokens.
    pub prefill_rows: Option<usize>,
    pub plan_mode: PlanMode,
    /// Sequence slots in the KV pool. With `> 1` a batched decode graph
    /// is built that processes one token of up to `batch_slots` live
    /// sequences per pass (continuous batching).
    pub batch_slots: usize,
    /// Tokens per KV page (paged cache granularity).
    pub page_size: usize,
    /// KV arena size in pages; `None` sizes it for `batch_slots`
    /// full-length sequences.
    pub kv_pages: Option<usize>,
}

impl BuildSpec {
    /// ArcLight on `nodes` NUMA node(s): TP when `nodes > 1`.
    pub fn arclight(cfg: ModelConfig, nodes: usize) -> BuildSpec {
        let group_nodes: Vec<NodeId> = (0..nodes.max(1)).collect();
        let weight_mode = if nodes > 1 { WeightMode::TpSharded } else { WeightMode::NodeLocal(0) };
        BuildSpec {
            cfg,
            group_nodes,
            n_nodes: nodes.max(1),
            weight_mode,
            act_placement: Placement::Node(0),
            kv_placement: Placement::Node(0),
            sim_only: false,
            prefill_rows: None,
            plan_mode: PlanMode::DoubleBuffered,
            batch_slots: 1,
            page_size: 16,
            kv_pages: None,
        }
    }

    /// llama.cpp strategy (see `crate::baseline` for the full mapping).
    pub fn llama_cpp(cfg: ModelConfig, nodes: usize, total_nodes: usize) -> BuildSpec {
        let weight_mode = if nodes > 1 {
            WeightMode::FirstTouch { nodes }
        } else {
            WeightMode::NodeLocal(0)
        };
        BuildSpec {
            cfg,
            group_nodes: vec![0],
            n_nodes: total_nodes,
            weight_mode,
            // the UMA buffer: OS-placed pages spread over every node of
            // the machine regardless of where threads run (§3.1)
            act_placement: Placement::Interleaved(total_nodes),
            kv_placement: Placement::Interleaved(total_nodes),
            sim_only: false,
            prefill_rows: None,
            plan_mode: PlanMode::DoubleBuffered,
            batch_slots: 1,
            page_size: 16,
            kv_pages: None,
        }
    }

    pub fn with_sim_only(mut self, v: bool) -> Self {
        self.sim_only = v;
        self
    }

    pub fn with_prefill(mut self, rows: usize) -> Self {
        self.prefill_rows = Some(rows);
        self
    }

    /// Enable continuous batching with `slots` KV-pool sequence slots.
    pub fn with_batch(mut self, slots: usize) -> Self {
        assert!(slots >= 1, "batch_slots must be at least 1");
        self.batch_slots = slots;
        self
    }

    /// Tokens per KV page.
    pub fn with_page_size(mut self, page_size: usize) -> Self {
        assert!(page_size >= 1, "page size must be at least 1 token");
        self.page_size = page_size;
        self
    }

    /// Size the KV arena in pages instead of full-length sequences.
    pub fn with_kv_pages(mut self, pages: usize) -> Self {
        assert!(pages >= 1, "a page arena needs at least one page");
        self.kv_pages = Some(pages);
        self
    }

    /// Shift every node-addressed placement by `base` nodes — how a
    /// cluster replica claims *its* NUMA node group: the spec is built
    /// as if for nodes `0..k` and then translated to `base..base+k`.
    /// `n_nodes` (the arena/placement domain) is unchanged, so the
    /// shifted ids must stay inside it. OS-managed placements
    /// (`Interleaved`, `FirstTouch`) are left to the OS as before.
    pub fn with_base_node(mut self, base: usize) -> Self {
        if base == 0 {
            return self;
        }
        let shift = |p: Placement| match p {
            Placement::Node(n) => Placement::Node(n + base),
            Placement::RowShards(shards) => Placement::RowShards(
                shards.into_iter().map(|(s, e, n)| (s, e, n + base)).collect(),
            ),
            other => other,
        };
        self.group_nodes = self.group_nodes.iter().map(|&n| n + base).collect();
        if let WeightMode::NodeLocal(n) = self.weight_mode {
            self.weight_mode = WeightMode::NodeLocal(n + base);
        }
        self.act_placement = shift(self.act_placement.clone());
        self.kv_placement = shift(self.kv_placement.clone());
        let top = self.group_nodes.iter().copied().max().unwrap_or(base);
        assert!(
            top < self.n_nodes,
            "base node {base} pushes group node {top} outside the {}-node machine",
            self.n_nodes
        );
        self
    }

    /// Physical pages the KV arena holds (default: `batch_slots`
    /// full-length sequences' worth).
    pub fn kv_pages_total(&self) -> usize {
        let ps = self.page_size.min(self.cfg.max_seq.max(1));
        self.kv_pages.unwrap_or_else(|| self.batch_slots * self.cfg.max_seq.div_ceil(ps))
    }

    pub fn n_groups(&self) -> usize {
        self.group_nodes.len()
    }
}

/// Per-layer weight handles (bundles of width G inside TP regions).
#[derive(Clone)]
struct LayerW {
    attn_norm: TensorBundle,
    wq: TensorBundle,
    wk: TensorBundle,
    wv: TensorBundle,
    wo: TensorBundle,
    q_norm: TensorBundle,
    k_norm: TensorBundle,
    mlp_norm: TensorBundle,
    w_gate: TensorBundle,
    w_up: TensorBundle,
    w_down: TensorBundle,
}

#[derive(Clone)]
struct ModelW {
    tok_emb: TensorBundle,
    layers: Vec<LayerW>,
    final_norm: TensorBundle,
    lm_head: TensorBundle,
}

/// A fully-built model: decode (+ optional prefill and batched-decode)
/// graphs over shared weight/cache storage.
pub struct ModelGraphs {
    pub cfg: ModelConfig,
    pub spec: BuildSpec,
    pub decode: Arc<Graph>,
    pub prefill: Option<Arc<Graph>>,
    /// Continuous-batching decode graph: `batch_slots` rows per pass,
    /// one logits row per lane (built when `spec.batch_slots > 1`).
    pub decode_batch: Option<Arc<Graph>>,
    pub pool: Option<Arc<MemoryPool>>,
    pub decode_tokens: TensorId,
    pub decode_logits: TensorId,
    pub prefill_tokens: Option<TensorId>,
    pub prefill_logits: Option<TensorId>,
    pub decode_batch_tokens: Option<TensorId>,
    pub decode_batch_logits: Option<TensorId>,
    /// Weight leaves (decode-graph ids; prefill shares buffers).
    pub weights: Vec<(TensorId, ShardInfo)>,
    /// KV cache leaves (decode-graph ids) for reset between sequences.
    pub kv_ids: Vec<TensorId>,
    /// Physical pages in the KV arena (capacity = pages · page_size).
    pub kv_pages: usize,
    /// Tokens per KV page.
    pub kv_page_size: usize,
    /// Peak activation bytes the build reserved.
    pub act_footprint: usize,
}

impl ModelGraphs {
    /// Build decode (rows = 1), optional prefill and optional batched
    /// decode graphs over one shared weight/KV-pool storage.
    pub fn build(spec: BuildSpec) -> ModelGraphs {
        spec.cfg.validate().expect("invalid model config");
        let g = spec.n_groups();
        assert!(
            spec.cfg.n_heads % g == 0 && spec.cfg.n_kv_heads % g == 0,
            "heads not divisible by {g} TP groups"
        );
        assert!(spec.cfg.ffn_dim % (32 * g) == 0, "ffn not shardable into {g}");

        let pool = if spec.sim_only { None } else { Some(Self::sized_pool(&spec)) };
        let mut b = if spec.sim_only {
            GraphBuilder::sim(spec.group_nodes.clone(), spec.act_placement.clone())
        } else {
            GraphBuilder::new(pool, spec.group_nodes.clone(), spec.act_placement.clone())
        }
        .with_plan_mode(spec.plan_mode);

        // ---- weights + caches (decode graph owns the leaves) ----
        let (weights_handles, shard_table) = create_weights(&mut b, &spec);
        let kv = KvCacheSet::create(
            &mut b,
            &KvSpec::for_model(
                spec.cfg.n_layers,
                spec.cfg.n_kv_heads,
                spec.cfg.head_dim,
                spec.cfg.max_seq,
            )
            .page_size(spec.page_size.min(spec.cfg.max_seq.max(1)))
            .pages(spec.kv_pages_total())
            .placement(spec.kv_placement.clone()),
        );
        let kv_ids = kv.all_ids();
        let (kv_pages, kv_page_size) = (kv.pages, kv.page_size);

        // ---- decode graph (single sequence, slot 0) ----
        let decode_tokens = b.leaf("input.tokens", DType::I32, vec![1], Placement::Node(0));
        let decode_logits =
            build_forward(&mut b, &spec.cfg, &weights_handles, &kv, decode_tokens, 1, false);
        let act_footprint = b.activation_footprint();
        let (decode_graph, pool) = b.finish();

        let sub_builder = |pool: Option<MemoryPool>| {
            if spec.sim_only {
                GraphBuilder::sim(spec.group_nodes.clone(), spec.act_placement.clone())
            } else {
                GraphBuilder::new(pool, spec.group_nodes.clone(), spec.act_placement.clone())
            }
            .with_plan_mode(spec.plan_mode)
        };

        // ---- prefill graph (imports the same leaves) ----
        let (prefill, prefill_tokens, prefill_logits, pool) = if let Some(rows) = spec.prefill_rows
        {
            let mut pb = sub_builder(pool);
            let w2 = import_model_w(&mut pb, &decode_graph, &weights_handles);
            let kv2 = import_kv(&mut pb, &decode_graph, &kv);
            let toks = pb.leaf("input.tokens", DType::I32, vec![rows], Placement::Node(0));
            let logits = build_forward(&mut pb, &spec.cfg, &w2, &kv2, toks, rows, false);
            let (pg, pool) = pb.finish();
            (Some(Arc::new(pg)), Some(toks), Some(logits), pool)
        } else {
            (None, None, None, pool)
        };

        // ---- batched decode graph (continuous batching) ----
        let (decode_batch, decode_batch_tokens, decode_batch_logits, pool) =
            if spec.batch_slots > 1 {
                let rows = spec.batch_slots;
                let mut bb = sub_builder(pool);
                let w2 = import_model_w(&mut bb, &decode_graph, &weights_handles);
                let kv2 = import_kv(&mut bb, &decode_graph, &kv);
                let toks =
                    bb.leaf("input.tokens.batch", DType::I32, vec![rows], Placement::Node(0));
                let logits = build_forward(&mut bb, &spec.cfg, &w2, &kv2, toks, rows, true);
                let (bg, pool) = bb.finish();
                (Some(Arc::new(bg)), Some(toks), Some(logits), pool)
            } else {
                (None, None, None, pool)
            };

        ModelGraphs {
            cfg: spec.cfg.clone(),
            spec,
            decode: Arc::new(decode_graph),
            prefill,
            decode_batch,
            pool: pool.map(Arc::new),
            decode_tokens,
            decode_logits,
            prefill_tokens,
            prefill_logits,
            decode_batch_tokens,
            decode_batch_logits,
            weights: shard_table,
            kv_ids,
            kv_pages,
            kv_page_size,
            act_footprint,
        }
    }

    /// Sequence slots in the KV pool (1 = single-sequence engine).
    pub fn batch_slots(&self) -> usize {
        self.spec.batch_slots
    }

    fn sized_pool(spec: &BuildSpec) -> MemoryPool {
        let c = &spec.cfg;
        let slack = 1 << 18;
        let batch = spec.batch_slots;
        // weights: everything could land on one node in single mode
        let wbytes = c.q4_weight_bytes()
            + c.vocab * c.dim * 4            // tok_emb f32
            + c.n_layers * (2 * c.dim + 2 * c.head_dim) * 4
            + c.dim * 4
            + 64 * (c.n_layers * 16 + 8)
            + (spec.prefill_rows.unwrap_or(1) + 1 + batch + 1) * 4 // token buffers
            + slack;
        // the KV arena holds `kv_pages_total` pages per layer
        let ps = spec.page_size.min(c.max_seq.max(1));
        let kvbytes = c.n_layers * 2 * c.n_kv_heads * spec.kv_pages_total() * ps * c.head_dim * 4
            + 64 * c.n_layers * 4
            + slack;
        // activations: per-parity bound × (decode + prefill + batch rows)
        let rows = 1 + spec.prefill_rows.unwrap_or(0) + if batch > 1 { batch } else { 0 };
        let per_row = (8 * c.dim + 6 * c.q_dim() + 8 * c.kv_dim() + 6 * c.ffn_dim) * 4;
        let logits_rows = 2 + if batch > 1 { batch } else { 0 };
        let abytes = rows * per_row + 2 * (c.vocab * 4 * logits_rows) + 256 * 64 + slack;
        MemoryPool::new(spec.n_nodes, wbytes, kvbytes, abytes * 2)
    }
}

// ---------------------------------------------------------------------------
// weight creation / import
// ---------------------------------------------------------------------------

/// Create one logical weight as 1 or G leaves per the build spec.
#[allow(clippy::too_many_arguments)]
fn weight_leaves(
    b: &mut GraphBuilder,
    spec: &BuildSpec,
    table: &mut Vec<(TensorId, ShardInfo)>,
    logical: &str,
    dtype: DType,
    n: usize,
    k: usize,
    shard: Option<ShardKind>, // None = never sharded (single-mode weight)
) -> TensorBundle {
    let g = spec.n_groups();
    let tp = g > 1 && shard.is_some() && spec.weight_mode == WeightMode::TpSharded;
    if tp {
        let mut ids = Vec::with_capacity(g);
        for part in 0..g {
            let node = spec.group_nodes[part];
            let (shape, kind) = match shard.as_ref().unwrap() {
                ShardKind::Rows(..) => {
                    let (r0, r1) = crate::util::chunk_range(n, g, part);
                    (vec![r1 - r0, k], ShardKind::Rows(r0, r1))
                }
                ShardKind::Cols(..) => {
                    let (c0, c1) = crate::util::chunk_range(k / 32, g, part);
                    (vec![n, (c1 - c0) * 32], ShardKind::Cols(c0 * 32, c1 * 32))
                }
                ShardKind::Full => (vec![n, k], ShardKind::Full),
            };
            let id = b.leaf(&format!("{logical}.{part}"), dtype, shape, Placement::Node(node));
            table.push((id, ShardInfo { logical: logical.into(), kind }));
            ids.push(id);
        }
        TensorBundle::new(ids)
    } else {
        let placement = match &spec.weight_mode {
            WeightMode::NodeLocal(node) => Placement::Node(*node),
            WeightMode::TpSharded => {
                // single-mode weight under TP: bind row shards to the
                // group nodes so whole-pool matmuls read locally
                if n >= spec.n_groups() * 32 {
                    Placement::even_shards(n, spec.n_groups())
                } else {
                    Placement::Node(spec.group_nodes[0])
                }
            }
            WeightMode::FirstTouch { nodes } => {
                if n >= *nodes {
                    Placement::even_shards(n, *nodes)
                } else {
                    Placement::Interleaved(*nodes)
                }
            }
        };
        let shape = if k == 0 { vec![n] } else { vec![n, k] };
        let id = b.leaf(logical, dtype, shape, placement);
        table.push((id, ShardInfo { logical: logical.into(), kind: ShardKind::Full }));
        TensorBundle::one(id)
    }
}

/// Replicated small gain vector: one copy per group (local reads).
fn replicated_leaves(
    b: &mut GraphBuilder,
    spec: &BuildSpec,
    table: &mut Vec<(TensorId, ShardInfo)>,
    logical: &str,
    len: usize,
) -> TensorBundle {
    let g = spec.n_groups();
    if g > 1 && spec.weight_mode == WeightMode::TpSharded {
        let mut ids = Vec::with_capacity(g);
        for part in 0..g {
            let id = b.leaf(
                &format!("{logical}.{part}"),
                DType::F32,
                vec![len],
                Placement::Node(spec.group_nodes[part]),
            );
            table.push((id, ShardInfo { logical: logical.into(), kind: ShardKind::Full }));
            ids.push(id);
        }
        TensorBundle::new(ids)
    } else {
        weight_leaves(b, spec, table, logical, DType::F32, len, 0, None)
    }
}

fn create_weights(b: &mut GraphBuilder, spec: &BuildSpec) -> (ModelW, Vec<(TensorId, ShardInfo)>) {
    let c = &spec.cfg;
    let q4 = DType::Q4_0;
    let rows0 = Some(ShardKind::Rows(0, 0));
    let cols0 = Some(ShardKind::Cols(0, 0));
    let mut table = Vec::new();
    let tok_emb = weight_leaves(b, spec, &mut table, "tok_emb", DType::F32, c.vocab, c.dim, None);
    let mut layers = Vec::with_capacity(c.n_layers);
    for l in 0..c.n_layers {
        let p = |s: &str| format!("layers.{l}.{s}");
        let t = &mut table;
        layers.push(LayerW {
            attn_norm: weight_leaves(b, spec, t, &p("attn_norm"), DType::F32, c.dim, 0, None),
            wq: weight_leaves(b, spec, t, &p("wq"), q4, c.q_dim(), c.dim, rows0.clone()),
            wk: weight_leaves(b, spec, t, &p("wk"), q4, c.kv_dim(), c.dim, rows0.clone()),
            wv: weight_leaves(b, spec, t, &p("wv"), q4, c.kv_dim(), c.dim, rows0.clone()),
            wo: weight_leaves(b, spec, t, &p("wo"), q4, c.dim, c.q_dim(), cols0.clone()),
            q_norm: replicated_leaves(b, spec, t, &p("q_norm"), c.head_dim),
            k_norm: replicated_leaves(b, spec, t, &p("k_norm"), c.head_dim),
            mlp_norm: weight_leaves(b, spec, t, &p("mlp_norm"), DType::F32, c.dim, 0, None),
            w_gate: weight_leaves(b, spec, t, &p("w_gate"), q4, c.ffn_dim, c.dim, rows0.clone()),
            w_up: weight_leaves(b, spec, t, &p("w_up"), q4, c.ffn_dim, c.dim, rows0.clone()),
            w_down: weight_leaves(b, spec, t, &p("w_down"), q4, c.dim, c.ffn_dim, cols0.clone()),
        });
    }
    let final_norm = weight_leaves(b, spec, &mut table, "final_norm", DType::F32, c.dim, 0, None);
    let lm_head = weight_leaves(b, spec, &mut table, "lm_head", q4, c.vocab, c.dim, None);
    (ModelW { tok_emb, layers, final_norm, lm_head }, table)
}

fn import_bundle(pb: &mut GraphBuilder, src: &Graph, bundle: &TensorBundle) -> TensorBundle {
    TensorBundle::new(bundle.iter().map(|id| pb.import_leaf(src.meta(id))).collect())
}

fn import_model_w(pb: &mut GraphBuilder, src: &Graph, w: &ModelW) -> ModelW {
    ModelW {
        tok_emb: import_bundle(pb, src, &w.tok_emb),
        layers: w
            .layers
            .iter()
            .map(|l| LayerW {
                attn_norm: import_bundle(pb, src, &l.attn_norm),
                wq: import_bundle(pb, src, &l.wq),
                wk: import_bundle(pb, src, &l.wk),
                wv: import_bundle(pb, src, &l.wv),
                wo: import_bundle(pb, src, &l.wo),
                q_norm: import_bundle(pb, src, &l.q_norm),
                k_norm: import_bundle(pb, src, &l.k_norm),
                mlp_norm: import_bundle(pb, src, &l.mlp_norm),
                w_gate: import_bundle(pb, src, &l.w_gate),
                w_up: import_bundle(pb, src, &l.w_up),
                w_down: import_bundle(pb, src, &l.w_down),
            })
            .collect(),
        final_norm: import_bundle(pb, src, &w.final_norm),
        lm_head: import_bundle(pb, src, &w.lm_head),
    }
}

fn import_kv(pb: &mut GraphBuilder, src: &Graph, kv: &KvCacheSet) -> KvCacheSet {
    KvCacheSet {
        layers: kv
            .layers
            .iter()
            .map(|l| crate::graph::kv_cache::LayerKv {
                k: import_bundle(pb, src, &l.k),
                v: import_bundle(pb, src, &l.v),
                heads_per_part: l.heads_per_part,
            })
            .collect(),
        max_seq: kv.max_seq,
        pages: kv.pages,
        page_size: kv.page_size,
    }
}

// ---------------------------------------------------------------------------
// forward construction (shared by decode and prefill)
// ---------------------------------------------------------------------------

/// Build the forward pass for `rows` tokens; returns the logits tensor.
/// With `all_rows == false` (single-sequence decode/prefill) only the
/// last row reaches the LM head ([1, vocab]); with `all_rows == true`
/// (batched decode, each row a different sequence) every row gets
/// logits ([rows, vocab]).
fn build_forward(
    b: &mut GraphBuilder,
    c: &ModelConfig,
    w: &ModelW,
    kv: &KvCacheSet,
    tokens: TensorId,
    rows: usize,
    all_rows: bool,
) -> TensorId {
    let g = b.n_groups();
    let heads_g = c.n_heads / g;
    let kv_heads_g = c.n_kv_heads / g;
    // attention/store stride over the whole KV pool, not one slot
    let cap = kv.capacity();

    let mut x = b.embed(&w.tok_emb, &TensorBundle::one(tokens));
    for l in 0..c.n_layers {
        b.enter_layer(l);
        let lw = &w.layers[l];
        let cache = kv.layer(l).clone();

        // ---- attention block ----
        let h = b.rmsnorm(&x, &lw.attn_norm, c.norm_eps);
        let hs = b.scatter(&h);
        let q = b.matmul(&hs, &lw.wq);
        let k = b.matmul(&hs, &lw.wk);
        let v = b.matmul(&hs, &lw.wv);
        let qn = b.rmsnorm_heads(&q, &lw.q_norm, heads_g, c.head_dim, c.norm_eps);
        let kn = b.rmsnorm_heads(&k, &lw.k_norm, kv_heads_g, c.head_dim, c.norm_eps);
        let qr = b.rope(&qn, heads_g, c.head_dim, c.rope_theta);
        let kr = b.rope(&kn, kv_heads_g, c.head_dim, c.rope_theta);
        b.store_kv(&kr, &cache.k, kv_heads_g, c.head_dim, cap);
        b.store_kv(&v, &cache.v, kv_heads_g, c.head_dim, cap);
        let ao = b.attention(&qr, &cache.k, &cache.v, heads_g, kv_heads_g, c.head_dim, cap);
        let partial = b.matmul(&ao, &lw.wo);
        let attn_out = b.gather(&partial);
        x = b.add(&x, &attn_out);

        // ---- MLP block ----
        let h2 = b.rmsnorm(&x, &lw.mlp_norm, c.norm_eps);
        let h2s = b.scatter(&h2);
        let gate = b.matmul(&h2s, &lw.w_gate);
        let up = b.matmul(&h2s, &lw.w_up);
        let act = b.swiglu(&gate, &up);
        let partial2 = b.matmul(&act, &lw.w_down);
        let mlp_out = b.gather(&partial2);
        x = b.add(&x, &mlp_out);
    }
    b.enter_layer(c.n_layers);
    let last = if rows > 1 && !all_rows { b.slice_row(&x, rows - 1) } else { x };
    let xf = b.rmsnorm(&last, &w.final_norm, c.norm_eps);
    let logits = b.matmul(&xf, &w.lm_head);
    logits.single()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_single_builds() {
        let m = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 1).with_prefill(8));
        assert!(m.decode.check_topological().is_ok());
        assert!(m.prefill.as_ref().unwrap().check_topological().is_ok());
        let logits = m.decode.meta(m.decode_logits);
        assert_eq!(logits.shape, vec![1, 512]);
        // no scatter/gather in single mode
        assert!(m.decode.exec.iter().all(|e| e.bundle.width() == 1));
        assert!(m.act_footprint > 0);
    }

    #[test]
    fn tiny_tp2_builds_with_parallel_entries() {
        let m = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 2));
        assert!(m.decode.check_topological().is_ok());
        let widths: Vec<usize> = m.decode.exec.iter().map(|e| e.bundle.width()).collect();
        assert!(widths.contains(&2), "no TP entries");
        assert!(widths.contains(&1), "no single entries");
        // per-layer: 2 scatters, 2 gathers
        let gathers = m
            .decode
            .tensors
            .iter()
            .filter(|t| matches!(t.op, crate::graph::OpKind::AddN))
            .count();
        assert_eq!(gathers, 2 * ModelConfig::tiny().n_layers);
    }

    #[test]
    fn tp_shards_cover_logical_weights() {
        let m = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 2));
        let c = ModelConfig::tiny();
        // wq shards: rows 0..32 and 32..64 of [64, 64]
        let wq: Vec<_> = m
            .weights
            .iter()
            .filter(|(_, s)| s.logical == "layers.0.wq")
            .collect();
        assert_eq!(wq.len(), 2);
        assert_eq!(wq[0].1.kind, ShardKind::Rows(0, c.q_dim() / 2));
        assert_eq!(wq[1].1.kind, ShardKind::Rows(c.q_dim() / 2, c.q_dim()));
        // wo shards: column slices
        let wo: Vec<_> = m
            .weights
            .iter()
            .filter(|(_, s)| s.logical == "layers.0.wo")
            .collect();
        assert_eq!(wo[0].1.kind, ShardKind::Cols(0, c.q_dim() / 2));
        // shards live on their group's node
        assert_eq!(m.decode.meta(wq[1].0).placement, Placement::Node(1));
    }

    #[test]
    fn llama_spec_places_interleaved() {
        let m = ModelGraphs::build(
            BuildSpec::llama_cpp(ModelConfig::tiny(), 4, 4).with_sim_only(true),
        );
        // weights: first-touch row shards over 4 nodes
        let (wq, _) = m
            .weights
            .iter()
            .find(|(id, _)| m.decode.meta(*id).name == "layers.0.wq")
            .unwrap();
        match &m.decode.meta(*wq).placement {
            Placement::RowShards(s) => assert_eq!(s.len(), 4),
            p => panic!("expected shards, got {p:?}"),
        }
        // activations: interleaved
        let some_act = m.decode.meta(m.decode_logits);
        assert_eq!(some_act.placement, Placement::Interleaved(4));
    }

    #[test]
    fn sim_only_4b_builds_fast_without_memory() {
        let m = ModelGraphs::build(
            BuildSpec::arclight(ModelConfig::qwen3_4b(), 4).with_sim_only(true).with_prefill(300),
        );
        assert!(m.pool.is_none());
        assert!(m.decode.n_tensors() > 36 * 20);
        assert!(m.decode.check_topological().is_ok());
    }

    #[test]
    fn batch_spec_builds_pooled_kv_and_batch_graph() {
        let m = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 1).with_batch(4));
        assert_eq!(m.batch_slots(), 4);
        let bg = m.decode_batch.as_ref().unwrap();
        assert!(bg.check_topological().is_ok());
        // batched logits: one row per lane
        let logits = bg.meta(m.decode_batch_logits.unwrap());
        assert_eq!(logits.shape, vec![4, 512]);
        // KV pool: per-layer cache spans 4 slots × max_seq positions
        let c = ModelConfig::tiny();
        let kv = m.decode.meta(m.kv_ids[0]);
        assert_eq!(kv.shape, vec![c.n_kv_heads, 4 * c.max_seq, c.head_dim]);
        // attention ops in every graph stride over the whole pool
        let cap = 4 * c.max_seq;
        for t in bg.tensors.iter().chain(m.decode.tensors.iter()) {
            if let crate::graph::OpKind::Attention { max_seq, .. } = &t.op {
                assert_eq!(*max_seq, cap);
            }
        }
    }

    #[test]
    fn batch_graph_shares_cache_buffers_with_decode() {
        let m = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 1).with_batch(2));
        let bg = m.decode_batch.as_ref().unwrap();
        let d = &m.decode;
        let kd = d.find("kv.0.k.0").unwrap();
        let kb = bg.find("kv.0.k.0").unwrap();
        assert_eq!(d.buf(kd), bg.buf(kb));
    }

    #[test]
    fn tp_batch_graph_builds() {
        let m = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 2).with_batch(3));
        let bg = m.decode_batch.as_ref().unwrap();
        assert!(bg.check_topological().is_ok());
        let widths: Vec<usize> = bg.exec.iter().map(|e| e.bundle.width()).collect();
        assert!(widths.contains(&2), "no TP entries in batch graph");
    }

    #[test]
    fn prefill_shares_weight_buffers() {
        let m = ModelGraphs::build(BuildSpec::arclight(ModelConfig::tiny(), 1).with_prefill(4));
        let d = &m.decode;
        let p = m.prefill.as_ref().unwrap();
        let wd = d.find("layers.0.wq").unwrap();
        let wp = p.find("layers.0.wq").unwrap();
        assert_eq!(d.buf(wd), p.buf(wp));
    }
}
