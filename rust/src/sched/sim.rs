//! Virtual-time graph execution on the simulated many-core machine.
//!
//! Consumes the same compiled [`PassPlan`] as [`super::RealExecutor`]
//! — identical steps, kernels and unit counts — charging each worker's
//! `Kernel::traffic` to the [`CostModel`] and advancing per-worker
//! virtual clocks through the plan's barrier structure. Because both
//! backends read their partition surface off one plan,
//! `StepReport::unit_counts` is bit-identical across them by
//! construction. The output is the pass latency the paper's figures
//! are built from (tokens/s = 1 / decode-pass latency).

use std::sync::Arc;

use crate::graph::Graph;
use crate::numa::cost::Traffic;
use crate::numa::{Core, CostModel};
use crate::ops::kernel::{op_traffic, TrafficEnv};
use crate::threads::Organization;
use crate::util::chunk_range;

use super::plan::{PassPlan, PlanStep};
use super::{ExecParams, Executor, StepReport, SyncMode};

/// Breakdown of where virtual time went during a pass.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    /// Wall-clock (virtual) seconds for the pass.
    pub elapsed: f64,
    /// Σ per-worker busy seconds (op execution).
    pub busy: f64,
    /// Σ per-worker seconds lost waiting at barriers (straggler skew).
    pub wait: f64,
    /// Σ barrier protocol cost (latency of the barrier itself × workers).
    pub barrier: f64,
    /// Total bytes moved, by (core_node, mem_node) channel.
    pub channel_bytes: Vec<Vec<f64>>,
    /// Operators executed.
    pub ops: usize,
}

impl SimReport {
    /// Fraction of remote (off-node) traffic — the paper's "cross-NUMA
    /// memory access" share. Guarded against zero-traffic passes: a
    /// report that moved no bytes returns 0.0, never NaN.
    pub fn remote_fraction(&self) -> f64 {
        let mut local = 0.0;
        let mut total = 0.0;
        for (cn, row) in self.channel_bytes.iter().enumerate() {
            for (mn, b) in row.iter().enumerate() {
                total += b;
                if cn == mn {
                    local += b;
                }
            }
        }
        if total == 0.0 {
            0.0
        } else {
            1.0 - local / total
        }
    }
}

/// The virtual-time executor.
pub struct SimExecutor {
    pub model: CostModel,
    pub cores: Vec<Core>,
    pub org_single: Organization,
    pub org_tp: Organization,
    pub sync: SyncMode,
}

impl SimExecutor {
    pub fn new(
        model: CostModel,
        cores: Vec<Core>,
        org_single: Organization,
        org_tp: Organization,
        sync: SyncMode,
    ) -> Self {
        SimExecutor { model, cores, org_single, org_tp, sync }
    }

    /// Simulate one pass with full virtual-time detail; `step_tag`
    /// seeds the per-op jitter (pass the decode step index so
    /// successive tokens draw fresh jitter). The [`Executor`] trait
    /// wraps this, taking the tag from `ExecParams::seed`. Compiles a
    /// fresh [`PassPlan`] — use [`SimExecutor::simulate_plan`] to share
    /// one with other consumers.
    pub fn simulate(&self, graph: &Graph, params: &ExecParams, step_tag: u64) -> SimReport {
        let plan = PassPlan::compile(graph, params, self.cores.len(), &self.org_tp, self.sync);
        self.simulate_plan(graph, &plan, params, step_tag)
    }

    /// Charge one compiled pass to the cost model — the same plan the
    /// real executor's workers walk, so unit accounting cannot drift
    /// between backends.
    pub fn simulate_plan(
        &self,
        graph: &Graph,
        plan: &PassPlan,
        params: &ExecParams,
        step_tag: u64,
    ) -> SimReport {
        let w = self.cores.len();
        let nn = self.model.n_nodes();
        let mut clocks = vec![0.0f64; w];
        let mut rep = SimReport {
            channel_bytes: vec![vec![0.0; nn]; nn],
            ..Default::default()
        };

        for step in &plan.steps {
            if step.width == 1 {
                self.step_single(graph, params, plan, step, step_tag, &mut clocks, &mut rep);
            } else {
                let lock = self.sync == SyncMode::SyncA;
                self.step_parallel(
                    graph, params, plan, step, step_tag, lock, &mut clocks, &mut rep,
                );
                if step.region_end {
                    // region boundary: the Gather (or next single op)
                    // starts only after every group finished — global
                    // barrier
                    self.global_sync(&mut clocks, &mut rep);
                }
            }
        }
        rep.elapsed = clocks.iter().copied().fold(0.0, f64::max);
        rep
    }

    fn env(&self, co_readers: usize) -> TrafficEnv {
        TrafficEnv {
            n_nodes: self.model.n_nodes(),
            co_readers,
            bcast_amort: self.model.topo.bcast_amort,
        }
    }

    /// Width-1 plan step: whole pool, global barrier after. Units come
    /// precomputed (and partition-checked) from the plan part.
    #[allow(clippy::too_many_arguments)]
    fn step_single(
        &self,
        graph: &Graph,
        params: &ExecParams,
        plan: &PassPlan,
        step: &PlanStep,
        step_tag: u64,
        clocks: &mut [f64],
        rep: &mut SimReport,
    ) {
        let part = &plan.parts[step.part0];
        let w = self.cores.len();
        let nn = self.model.n_nodes();
        // co-located readers per node for the shared-stream amortization
        let mut per_node = vec![0usize; nn];
        for core in &self.cores {
            per_node[core.node] += 1;
        }
        let mut workers: Vec<(usize, Traffic)> = Vec::with_capacity(w);
        for (wi, core) in self.cores.iter().enumerate() {
            let (u0, u1) = chunk_range(part.units, w, wi);
            let env = self.env(per_node[core.node]);
            let t = op_traffic(graph, part.id, params, u0, u1, &env);
            workers.push((core.id, t));
        }
        self.advance(&workers, step.entry as u64 + step_tag * 131_071, clocks, rep, None);
        self.global_sync(clocks, rep);
        rep.ops += 1;
    }

    /// Width-G plan step: each group computes its part. `lockstep ==
    /// true` (Sync A) adds a global barrier; otherwise each group syncs
    /// locally only.
    #[allow(clippy::too_many_arguments)]
    fn step_parallel(
        &self,
        graph: &Graph,
        params: &ExecParams,
        plan: &PassPlan,
        step: &PlanStep,
        step_tag: u64,
        lockstep: bool,
        clocks: &mut [f64],
        rep: &mut SimReport,
    ) {
        let nn = self.model.n_nodes();
        let mut per_node = vec![0usize; nn];
        for core in &self.cores {
            per_node[core.node] += 1;
        }
        let mut workers: Vec<(usize, Traffic)> = Vec::new();
        let mut worker_idx: Vec<usize> = Vec::new();
        for (wi, core) in self.cores.iter().enumerate() {
            if let Some((gi, rank)) = self.org_tp.assignment(wi) {
                let part = &plan.parts[step.part0 + gi];
                let size = self.org_tp.groups[gi].size();
                let (u0, u1) = chunk_range(part.units, size, rank);
                let env = self.env(per_node[core.node]);
                let t = op_traffic(graph, part.id, params, u0, u1, &env);
                workers.push((core.id, t));
                worker_idx.push(wi);
            }
        }
        let tag = step.entry as u64 + step_tag * 131_071;
        self.advance_indexed(&workers, &worker_idx, tag, clocks, rep);
        if lockstep {
            self.global_sync(clocks, rep);
        } else {
            // local barriers per group
            for g in &self.org_tp.groups {
                let cost = self.model.topo.barrier_cost(g.size(), 1);
                let max = g.workers.iter().map(|&w| clocks[w]).fold(0.0, f64::max);
                for &w in &g.workers {
                    rep.wait += max - clocks[w];
                    clocks[w] = max + cost;
                    rep.barrier += cost;
                }
            }
        }
        rep.ops += 1;
    }

    fn advance(
        &self,
        workers: &[(usize, Traffic)],
        tag: u64,
        clocks: &mut [f64],
        rep: &mut SimReport,
        _unused: Option<()>,
    ) {
        let idx: Vec<usize> = (0..workers.len()).collect();
        self.advance_indexed(workers, &idx, tag, clocks, rep);
    }

    fn advance_indexed(
        &self,
        workers: &[(usize, Traffic)],
        worker_idx: &[usize],
        tag: u64,
        clocks: &mut [f64],
        rep: &mut SimReport,
    ) {
        let times = self.model.op_times(workers, tag);
        for (i, t) in times.iter().enumerate() {
            clocks[worker_idx[i]] += t;
            rep.busy += t;
        }
        // channel accounting
        for (core, traffic) in workers {
            let cn = self.model.topo.node_of_core(*core);
            for (mn, b) in traffic.bytes.iter().enumerate() {
                rep.channel_bytes[cn][mn] += b;
            }
        }
    }

    fn global_sync(&self, clocks: &mut [f64], rep: &mut SimReport) {
        let span = self.org_single.nodes_spanned(&self.cores);
        let cost = self.model.topo.barrier_cost(clocks.len(), span);
        let max = clocks.iter().copied().fold(0.0, f64::max);
        for c in clocks.iter_mut() {
            rep.wait += max - *c;
            *c = max + cost;
            rep.barrier += cost;
        }
    }
}

impl Executor for SimExecutor {
    fn name(&self) -> &'static str {
        "sim"
    }

    /// One simulated pass; `elapsed` is virtual seconds and `sim`
    /// carries the full [`SimReport`]. The jitter tag comes from
    /// `ExecParams::seed`. Unit counts are read off the compiled
    /// [`PassPlan`] — the same surface the real executor reports, so
    /// parity holds by construction. `dispatches` is 1: the plan the
    /// real backend walks under one dispatch is the plan charged here.
    fn run(&self, graph: &Arc<Graph>, params: &ExecParams) -> StepReport {
        let plan = PassPlan::compile(graph, params, self.cores.len(), &self.org_tp, self.sync);
        let rep = self.simulate_plan(graph, &plan, params, params.seed);
        StepReport {
            elapsed: rep.elapsed,
            ops: rep.ops,
            unit_counts: plan.unit_counts,
            dispatches: 1,
            plan_cached: false,
            tier: crate::simd::KernelTier::active(),
            sim: Some(rep),
            // strategy/bandwidth provenance is engine-stamped
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::numa::{Placement, Topology};
    use crate::tensor::{DType, TensorBundle};

    fn sim_for(topo: Topology, threads: usize, nodes: usize, sync: SyncMode) -> SimExecutor {
        let cores = topo.bind_cores(threads, nodes > 1, nodes);
        let org_single = Organization::single(&cores);
        let org_tp = if nodes > 1 {
            Organization::by_node(&cores)
        } else {
            Organization::single(&cores)
        };
        SimExecutor::new(CostModel::new(topo), cores, org_single, org_tp, sync)
    }

    /// A graph with one big local matmul.
    fn local_matmul_graph(weight_placement: Placement) -> Graph {
        let mut b = GraphBuilder::sim(vec![0], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 4096], Placement::Node(0));
        let w = b.leaf("w", DType::Q4_0, vec![4096, 4096], weight_placement);
        b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        b.finish().0
    }

    #[test]
    fn local_weights_beat_remote_weights() {
        let topo = Topology::kunpeng920();
        let sim = sim_for(topo, 48, 1, SyncMode::SyncA);
        let p = ExecParams::dense(0, 1);
        let local = sim.simulate(&local_matmul_graph(Placement::Node(0)), &p, 0);
        let remote = sim.simulate(&local_matmul_graph(Placement::Node(1)), &p, 0);
        let ratio = remote.elapsed / local.elapsed;
        // Table 1: local ≈ 102 GB/s vs remote 26 GB/s → ≈ 3.9×
        assert!(ratio > 3.0 && ratio < 5.0, "ratio {ratio}");
    }

    #[test]
    fn more_threads_scale_single_node() {
        let topo = Topology::kunpeng920();
        let p = ExecParams::dense(0, 1);
        let t6 = sim_for(topo.clone(), 6, 1, SyncMode::SyncA)
            .simulate(&local_matmul_graph(Placement::Node(0)), &p, 0)
            .elapsed;
        let t48 = sim_for(topo, 48, 1, SyncMode::SyncA)
            .simulate(&local_matmul_graph(Placement::Node(0)), &p, 0)
            .elapsed;
        // bandwidth-bound: scaling helps but saturates (shared channel)
        assert!(t6 > t48, "6 threads {t6} vs 48 {t48}");
    }

    #[test]
    fn remote_fraction_detects_interleaved_activations() {
        let topo = Topology::kunpeng920();
        let sim = sim_for(topo, 64, 4, SyncMode::SyncA);
        let mut b = GraphBuilder::sim(vec![0, 1, 2, 3], Placement::Interleaved(4));
        let x = b.leaf("x", DType::F32, vec![1, 4096], Placement::Interleaved(4));
        let w = b.leaf("w", DType::Q4_0, vec![4096, 4096], Placement::even_shards(4096, 4));
        b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let g = b.finish().0;
        let rep = sim.simulate(&g, &ExecParams::dense(0, 1), 0);
        // activations interleaved → ~3/4 of activation reads are remote
        assert!(rep.remote_fraction() > 0.05, "{}", rep.remote_fraction());
    }

    #[test]
    fn sync_b_is_not_slower_than_sync_a() {
        // two groups with imbalanced streams: B hides the straggler
        let topo = Topology::uniform(2, 4, 100.0, 25.0);
        let mut b = GraphBuilder::sim(vec![0, 1], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 1024], Placement::Node(0));
        let w0 = b.leaf("w0", DType::Q4_0, vec![1024, 1024], Placement::Node(0));
        let w1 = b.leaf("w1", DType::Q4_0, vec![1024, 1024], Placement::Node(1));
        let xs = b.scatter(&TensorBundle::one(x));
        let mut cur = xs;
        for _ in 0..6 {
            cur = b.matmul(&cur, &TensorBundle::new(vec![w0, w1]));
            // keep K consistent: output [1,1024] feeds next matmul
        }
        b.gather(&cur);
        let g = b.finish().0;
        let p = ExecParams::dense(0, 1);
        let a = sim_for(topo.clone(), 8, 2, SyncMode::SyncA).simulate(&g, &p, 3).elapsed;
        let bt = sim_for(topo, 8, 2, SyncMode::SyncB).simulate(&g, &p, 3).elapsed;
        assert!(bt <= a * 1.001, "syncB {bt} vs syncA {a}");
    }

    #[test]
    fn report_accounts_channels() {
        let topo = Topology::kunpeng920();
        let sim = sim_for(topo, 8, 1, SyncMode::SyncA);
        let rep =
            sim.simulate(&local_matmul_graph(Placement::Node(0)), &ExecParams::dense(0, 1), 0);
        let total: f64 = rep.channel_bytes.iter().flatten().sum();
        // at least the weight bytes must be accounted
        assert!(total >= 4096.0 * 4096.0 * 0.5625);
        assert_eq!(rep.ops, 1);
        assert!(rep.elapsed > 0.0);
    }

    #[test]
    fn remote_fraction_guards_zero_traffic() {
        // a default (zero-channel) report must report 0.0, not NaN
        let rep = SimReport::default();
        assert_eq!(rep.remote_fraction(), 0.0);
        assert!(rep.remote_fraction().is_finite());
        // a pass over a graph with no executable entries charges no
        // traffic and must be equally well-behaved
        let b = GraphBuilder::sim(vec![0], Placement::Node(0));
        let g = b.finish().0;
        let sim = sim_for(Topology::kunpeng920(), 4, 1, SyncMode::SyncA);
        let rep = sim.simulate(&g, &ExecParams::dense(0, 1), 0);
        assert_eq!(rep.remote_fraction(), 0.0);
        assert!(rep.remote_fraction().is_finite());
    }

    #[test]
    fn trait_run_carries_sim_detail_and_seed() {
        let topo = Topology::kunpeng920();
        let sim = sim_for(topo, 8, 1, SyncMode::SyncA);
        let g = Arc::new(local_matmul_graph(Placement::Node(0)));
        let p = ExecParams::dense(0, 1).with_seed(9);
        let via_trait = Executor::run(&sim, &g, &p);
        let direct = sim.simulate(&g, &p, 9);
        assert_eq!(via_trait.elapsed, direct.elapsed);
        assert_eq!(via_trait.ops, direct.ops);
        // the matmul partitions its 4096 output features
        assert_eq!(via_trait.unit_counts, vec![4096]);
        assert!(via_trait.sim.is_some());
        assert_eq!(Executor::name(&sim), "sim");
    }
}
