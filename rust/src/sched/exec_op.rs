//! Real execution of one operator slice.
//!
//! `run_op(graph, pool, id, params, u0, u1)` computes work units
//! `[u0, u1)` of tensor `id`'s producing operator. Workers of a group
//! call this with disjoint unit ranges; unit semantics per op are
//! defined by [`super::partition_units`].
//!
//! Safety: each invocation writes only the output region its unit range
//! owns; inputs are read-only. Disjointness across concurrent calls is
//! guaranteed by the partitioner (chunk_range), which is what makes the
//! raw-pointer arena views sound.

use crate::graph::{Graph, OpKind};
use crate::memory::MemoryPool;
use crate::ops;
use crate::tensor::{DType, TensorId};

use super::ExecParams;

/// Fetch an f32 view of a tensor's whole buffer.
///
/// # Safety
/// Caller must ensure no concurrent overlapping writer (see module docs).
unsafe fn f32s<'a>(pool: &'a MemoryPool, graph: &Graph, id: TensorId) -> &'a [f32] {
    let b = graph.buf(id);
    pool.arena(b.arena).f32s(b.off, b.len / 4)
}

#[allow(clippy::mut_from_ref)]
unsafe fn f32s_mut<'a>(pool: &'a MemoryPool, graph: &Graph, id: TensorId) -> &'a mut [f32] {
    let b = graph.buf(id);
    pool.arena(b.arena).f32s_mut(b.off, b.len / 4)
}

unsafe fn bytes<'a>(pool: &'a MemoryPool, graph: &Graph, id: TensorId) -> &'a [u8] {
    let b = graph.buf(id);
    pool.arena(b.arena).bytes(b.off, b.len)
}

/// Execute units `[u0, u1)` of the operator producing `id`.
pub fn run_op(
    graph: &Graph,
    pool: &MemoryPool,
    id: TensorId,
    params: &ExecParams,
    u0: usize,
    u1: usize,
) {
    if u0 >= u1 {
        return;
    }
    let meta = graph.meta(id);
    let src = &meta.src;
    unsafe {
        match &meta.op {
            OpKind::Leaf => {}
            OpKind::Embed => {
                let table = f32s(pool, graph, src[0]);
                let toks_buf = graph.buf(src[1]);
                let toks_raw = pool.arena(toks_buf.arena).bytes(toks_buf.off, toks_buf.len);
                let tokens: &[i32] = std::slice::from_raw_parts(
                    toks_raw.as_ptr() as *const i32,
                    toks_raw.len() / 4,
                );
                let out = f32s_mut(pool, graph, id);
                let d = meta.row_len();
                ops::common::embed_rows(table, tokens, out, d, u0, u1);
            }
            OpKind::RmsNorm { eps } => {
                let x = f32s(pool, graph, src[0]);
                let g = f32s(pool, graph, src[1]);
                let out = f32s_mut(pool, graph, id);
                ops::norm::rmsnorm(x, g, out, meta.row_len(), *eps, u0, u1);
            }
            OpKind::RmsNormHeads { eps, heads, head_dim } => {
                let x = f32s(pool, graph, src[0]);
                let g = f32s(pool, graph, src[1]);
                let out = f32s_mut(pool, graph, id);
                let rows = meta.rows().min(params.rows.max(1));
                ops::norm::rmsnorm_heads(x, g, out, rows, *heads, *head_dim, *eps, u0, u1);
            }
            OpKind::MatMul => {
                let x = f32s(pool, graph, src[0]);
                let out = f32s_mut(pool, graph, id);
                let k = graph.meta(src[1]).row_len();
                let n = graph.meta(src[1]).rows();
                // only the active rows of a partially-filled batch step
                let m = graph.meta(src[0]).rows().min(params.rows.max(1));
                match graph.meta(src[1]).dtype {
                    DType::F32 => {
                        let w = f32s(pool, graph, src[1]);
                        ops::gemm::gemm_f32(x, w, out, m, k, n, u0, u1);
                    }
                    DType::Q4_0 => {
                        let w = bytes(pool, graph, src[1]);
                        ops::gemm::gemm_q4_0(x, w, out, m, k, n, u0, u1);
                    }
                    DType::Q8_0 => {
                        let w = bytes(pool, graph, src[1]);
                        ops::gemm::gemm_q8_0(x, w, out, m, k, n, u0, u1);
                    }
                    DType::I32 => panic!("i32 weights unsupported"),
                }
            }
            OpKind::Rope { theta, heads, head_dim } => {
                let x = f32s(pool, graph, src[0]);
                let out = f32s_mut(pool, graph, id);
                // copy the head range, then rotate in place
                let rows = meta.rows().min(params.rows.max(1));
                let d = heads * head_dim;
                for r in 0..rows {
                    let lo = r * d + u0 * head_dim;
                    let hi = r * d + u1 * head_dim;
                    out[lo..hi].copy_from_slice(&x[lo..hi]);
                }
                match &params.batch {
                    Some(bv) => {
                        ops::rope::rope_rows(out, *heads, *head_dim, &bv.pos, *theta, u0, u1)
                    }
                    None => {
                        ops::rope::rope(out, rows, *heads, *head_dim, params.pos, *theta, u0, u1)
                    }
                }
            }
            OpKind::StoreKv { kv_heads, head_dim, max_seq } => {
                let kv = f32s(pool, graph, src[0]);
                // output aliases the cache (src[1]) buffer
                let cache = f32s_mut(pool, graph, src[1]);
                let rows = graph.meta(src[0]).rows().min(params.rows.max(1));
                match &params.batch {
                    Some(bv) => ops::attention::store_kv_rows(
                        kv,
                        cache,
                        *kv_heads,
                        *head_dim,
                        *max_seq,
                        &bv.kv_base,
                        &bv.pos,
                        u0,
                        u1,
                    ),
                    None => ops::attention::store_kv(
                        kv,
                        cache,
                        rows,
                        *kv_heads,
                        *head_dim,
                        *max_seq,
                        params.pos,
                        u0,
                        u1,
                    ),
                }
            }
            OpKind::Attention { heads, kv_heads, head_dim, max_seq } => {
                let q = f32s(pool, graph, src[0]);
                let k = f32s(pool, graph, src[1]);
                let v = f32s(pool, graph, src[2]);
                let out = f32s_mut(pool, graph, id);
                let rows = graph.meta(src[0]).rows().min(params.rows.max(1));
                match &params.batch {
                    Some(bv) => ops::attention::attention_rows(
                        q,
                        k,
                        v,
                        out,
                        *heads,
                        *kv_heads,
                        *head_dim,
                        *max_seq,
                        &bv.kv_base,
                        &bv.pos,
                        u0,
                        u1,
                    ),
                    None => ops::attention::attention(
                        q,
                        k,
                        v,
                        out,
                        rows,
                        *heads,
                        *kv_heads,
                        *head_dim,
                        *max_seq,
                        params.pos,
                        u0,
                        u1,
                    ),
                }
            }
            OpKind::Silu => {
                let a = f32s(pool, graph, src[0]);
                let out = f32s_mut(pool, graph, id);
                ops::elementwise::silu(a, out, u0, u1);
            }
            OpKind::Add => {
                let a = f32s(pool, graph, src[0]);
                let b = f32s(pool, graph, src[1]);
                let out = f32s_mut(pool, graph, id);
                ops::elementwise::add(a, b, out, u0, u1);
            }
            OpKind::Mul => {
                let a = f32s(pool, graph, src[0]);
                let b = f32s(pool, graph, src[1]);
                let out = f32s_mut(pool, graph, id);
                ops::elementwise::mul(a, b, out, u0, u1);
            }
            OpKind::SwiGlu => {
                let g = f32s(pool, graph, src[0]);
                let u = f32s(pool, graph, src[1]);
                let out = f32s_mut(pool, graph, id);
                ops::elementwise::swiglu(g, u, out, u0, u1);
            }
            OpKind::Copy => {
                let a = f32s(pool, graph, src[0]);
                let out = f32s_mut(pool, graph, id);
                out[u0..u1].copy_from_slice(&a[u0..u1]);
            }
            OpKind::SliceRow { row } => {
                let a = f32s(pool, graph, src[0]);
                let out = f32s_mut(pool, graph, id);
                let d = meta.row_len();
                out[u0..u1].copy_from_slice(&a[row * d + u0..row * d + u1]);
            }
            OpKind::AddN => {
                let out = f32s_mut(pool, graph, id);
                let first = f32s(pool, graph, src[0]);
                out[u0..u1].copy_from_slice(&first[u0..u1]);
                for s in &src[1..] {
                    let p = f32s(pool, graph, *s);
                    ops::common::accumulate(p, out, u0, u1);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::numa::Placement;
    use crate::tensor::TensorBundle;

    /// Build a tiny graph, fill leaves, execute serially, check numbers.
    #[test]
    fn serial_execution_of_small_chain() {
        let pool = MemoryPool::new(1, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 4], Placement::Node(0));
        let w = b.leaf("w", DType::F32, vec![2, 4], Placement::Node(0));
        let y = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let z = b.add(&y, &y);
        let (graph, pool) = b.finish();
        let pool = pool.unwrap();

        unsafe {
            f32s_mut(&pool, &graph, x).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
            f32s_mut(&pool, &graph, w)
                .copy_from_slice(&[1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        }
        let params = ExecParams::dense(0, 1);
        for entry in &graph.exec {
            for id in entry.bundle.iter() {
                let units = super::super::partition_units(graph.meta(id), &params);
                run_op(&graph, &pool, id, &params, 0, units);
            }
        }
        unsafe {
            assert_eq!(f32s(&pool, &graph, y.single()), &[1.0, 2.0]);
            assert_eq!(f32s(&pool, &graph, z.single()), &[2.0, 4.0]);
        }
    }

    #[test]
    fn addn_sums_partials() {
        let pool = MemoryPool::new(2, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0, 1], Placement::Node(0));
        let p0 = b.leaf("p0", DType::F32, vec![1, 4], Placement::Node(0));
        let p1 = b.leaf("p1", DType::F32, vec![1, 4], Placement::Node(1));
        let z = b.gather(&TensorBundle::new(vec![p0, p1]));
        let (graph, pool) = b.finish();
        let pool = pool.unwrap();
        unsafe {
            f32s_mut(&pool, &graph, p0).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
            f32s_mut(&pool, &graph, p1).copy_from_slice(&[10.0, 20.0, 30.0, 40.0]);
        }
        let params = ExecParams::dense(0, 1);
        run_op(&graph, &pool, z.single(), &params, 0, 4);
        unsafe {
            assert_eq!(f32s(&pool, &graph, z.single()), &[11.0, 22.0, 33.0, 44.0]);
        }
    }

    #[test]
    fn batched_store_kv_targets_per_row_slots() {
        // pooled cache of 2 slots × 4 positions; two rows land in their
        // own slot's position (slot 0 pos 2, slot 1 pos 0)
        let pool = MemoryPool::new(1, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let kvsrc = b.leaf("kv", DType::F32, vec![2, 4], Placement::Node(0));
        let cache = b.kv_leaf("cache", vec![1, 8, 4], Placement::Node(0));
        let stored = b.store_kv(&TensorBundle::one(kvsrc), &TensorBundle::one(cache), 1, 4, 8);
        let (graph, pool) = b.finish();
        let pool = pool.unwrap();
        unsafe {
            f32s_mut(&pool, &graph, kvsrc)
                .copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        }
        let view = crate::sched::BatchView::new(vec![0, 4], vec![2, 0]);
        let params = ExecParams::batched(view);
        run_op(&graph, &pool, stored.single(), &params, 0, 1);
        unsafe {
            let c = f32s(&pool, &graph, cache);
            // row 0 → slot 0 position 2
            assert_eq!(&c[2 * 4..3 * 4], &[1.0, 2.0, 3.0, 4.0]);
            // row 1 → slot 1 (base 4) position 0
            assert_eq!(&c[4 * 4..5 * 4], &[5.0, 6.0, 7.0, 8.0]);
        }
    }

    #[test]
    fn store_kv_aliases_cache() {
        let pool = MemoryPool::new(1, 1 << 20, 1 << 20, 1 << 20);
        let mut b = GraphBuilder::new(Some(pool), vec![0], Placement::Node(0));
        let kvsrc = b.leaf("kv", DType::F32, vec![1, 2 * 4], Placement::Node(0));
        let cache = b.kv_leaf("cache", vec![2, 8, 4], Placement::Node(0));
        let stored = b.store_kv(
            &TensorBundle::one(kvsrc),
            &TensorBundle::one(cache),
            2,
            4,
            8,
        );
        let (graph, pool) = b.finish();
        let pool = pool.unwrap();
        assert_eq!(graph.buf(stored.single()), graph.buf(cache));
        unsafe {
            f32s_mut(&pool, &graph, kvsrc)
                .copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        }
        let params = ExecParams::dense(3, 1);
        run_op(&graph, &pool, stored.single(), &params, 0, 2);
        unsafe {
            let c = f32s(&pool, &graph, cache);
            // head 0 slot 3
            assert_eq!(&c[3 * 4..4 * 4], &[1.0, 2.0, 3.0, 4.0]);
            // head 1 slot 3 (head stride = 8 slots × 4)
            assert_eq!(&c[8 * 4 + 3 * 4..8 * 4 + 4 * 4], &[5.0, 6.0, 7.0, 8.0]);
        }
    }
}
