//! Pass compilation: the execution list lowered to a [`PassPlan`].
//!
//! The per-operator dispatch model (one pool job + completion latch per
//! operator) pays an mpsc send, a closure allocation and a mutex/condvar
//! round trip **per operator** — hundreds of heavyweight dispatches per
//! decoded token, the first-order CPU-inference tax the paper's thread
//! scheduler is built to avoid (§3.3–3.4). A [`PassPlan`] removes it:
//! the pass is compiled once into a flat step list with everything the
//! workers need resolved up front — the kernel reference, the unit
//! count, and the barrier each step ends with — so the executor makes
//! **one** pool dispatch per pass and the workers walk the plan
//! themselves, synchronizing on spin barriers only.
//!
//! Barrier discipline per step (Fig. 6/9):
//!
//! * width-1 steps end at the pool-**global** barrier (every worker
//!   computed a slice of the same operator);
//! * width-G steps under **Sync A** end at the global barrier (all
//!   groups in lockstep after every operator);
//! * width-G steps under **Sync B** end at the **group-local** barrier,
//!   except the last step of the region, which ends at the global
//!   barrier (the Gather boundary) — a global barrier subsumes the
//!   local one, so the region exit needs no double wait.
//!
//! The plan is also the cross-backend accounting surface:
//! [`PassPlan::unit_counts`] is computed here once and consumed
//! verbatim by the real executor, the simulator and the trace layer,
//! so `StepReport::unit_counts` cannot drift between backends.

use crate::graph::Graph;
use crate::memory::MemoryPool;
use crate::ops::kernel::{Kernel, OpCtx};
use crate::threads::{Organization, SpinBarrier};
use crate::util::chunk_range;

use super::{debug_check_partition, ExecParams, SyncMode};

/// Which barrier a worker passes after finishing a plan step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepBarrier {
    /// The pool-wide barrier ([`crate::threads::ThreadPool::global_barrier`]).
    Global,
    /// The worker's group barrier ([`crate::threads::GroupView::barrier`]);
    /// workers idle under the TP view skip it.
    Local,
}

/// One resolved operator instance: everything a worker needs to execute
/// its slice without touching the registry or the tensor table.
#[derive(Clone, Copy)]
pub struct PlanPart {
    /// Output tensor of the operator.
    pub id: crate::tensor::TensorId,
    /// Kernel resolved at graph build.
    pub kernel: &'static dyn Kernel,
    /// Work units the operator partitions across its thread group.
    pub units: usize,
}

/// One step of a compiled pass: an execution-list entry plus its
/// precomputed barrier discipline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanStep {
    /// Index into `graph.exec` (also the simulator's jitter tag input).
    pub entry: usize,
    /// 1 (whole pool) or the TP group count.
    pub width: usize,
    /// First of `width` consecutive entries in [`PassPlan::parts`].
    pub part0: usize,
    /// Barrier the step ends with.
    pub barrier: StepBarrier,
    /// Last step of a width-G region (the Gather boundary — the
    /// simulator charges the region's global barrier here).
    pub region_end: bool,
}

/// A pass compiled for one `(graph, params)` pair: the flat step list
/// the persistent workers walk under a single pool dispatch.
pub struct PassPlan {
    pub steps: Vec<PlanStep>,
    /// Flat per-group parts; step `s` owns `parts[s.part0 .. s.part0 + s.width]`.
    pub parts: Vec<PlanPart>,
    /// Work units of every part in execution order (TP entries
    /// contribute one count per group) — the partition-parity surface
    /// every backend reports verbatim.
    pub unit_counts: Vec<usize>,
    /// Synchronization discipline the plan was compiled under.
    pub sync: SyncMode,
}

impl PassPlan {
    /// Compile `graph`'s execution list for one pass. `pool_size` is
    /// the worker count splitting width-1 entries; `org_tp` supplies
    /// the group sizes splitting width-G entries. Panics when a
    /// width-G entry does not match the TP view's group count (the
    /// same build-time invariant the per-op walk asserted).
    pub fn compile(
        graph: &Graph,
        params: &ExecParams,
        pool_size: usize,
        org_tp: &Organization,
        sync: SyncMode,
    ) -> PassPlan {
        let n_groups = org_tp.n_groups();
        let exec = &graph.exec;
        let mut steps = Vec::with_capacity(exec.len());
        let mut parts = Vec::with_capacity(exec.len());
        let mut unit_counts = Vec::with_capacity(exec.len());
        let mut i = 0;
        while i < exec.len() {
            let width = exec[i].bundle.width();
            if width == 1 {
                let id = exec[i].bundle.single();
                let kernel = graph.kernel(id);
                let units = kernel.units(graph.meta(id), params);
                debug_check_partition(units, pool_size);
                unit_counts.push(units);
                steps.push(PlanStep {
                    entry: i,
                    width: 1,
                    part0: parts.len(),
                    barrier: StepBarrier::Global,
                    region_end: false,
                });
                parts.push(PlanPart { id, kernel, units });
                i += 1;
            } else {
                assert_eq!(width, n_groups, "entry width {} vs {} groups", width, n_groups);
                // maximal run of parallel entries: one TP region
                let mut j = i;
                while j < exec.len() && exec[j].bundle.width() == width {
                    j += 1;
                }
                for e in i..j {
                    let part0 = parts.len();
                    for gi in 0..width {
                        let id = exec[e].bundle.get(gi);
                        let kernel = graph.kernel(id);
                        let units = kernel.units(graph.meta(id), params);
                        debug_check_partition(units, org_tp.groups[gi].size());
                        unit_counts.push(units);
                        parts.push(PlanPart { id, kernel, units });
                    }
                    let region_end = e + 1 == j;
                    let barrier = match sync {
                        SyncMode::SyncA => StepBarrier::Global,
                        SyncMode::SyncB if region_end => StepBarrier::Global,
                        SyncMode::SyncB => StepBarrier::Local,
                    };
                    steps.push(PlanStep { entry: e, width, part0, barrier, region_end });
                }
                i = j;
            }
        }
        PassPlan { steps, parts, unit_counts, sync }
    }

    /// Execution-list entries the plan covers (`StepReport::ops`).
    pub fn ops(&self) -> usize {
        self.steps.len()
    }

    /// Structural equality of two compiled plans: same step list, same
    /// unit accounting, same resolved kernels (compared by identity —
    /// both plans resolve through the same graph's kernel table, so a
    /// matching part must hold the very same `&'static` reference).
    /// This is the cached-vs-fresh assertion surface of the executor's
    /// plan cache: a cache hit in debug builds recompiles and demands
    /// `same_as`, which is what proves unit counts are
    /// position-independent for a given `(graph, rows)` shape.
    pub fn same_as(&self, other: &PassPlan) -> bool {
        self.sync == other.sync
            && self.steps == other.steps
            && self.unit_counts == other.unit_counts
            && self.parts.len() == other.parts.len()
            && self.parts.iter().zip(&other.parts).all(|(a, b)| {
                a.id == b.id
                    && a.units == b.units
                    && std::ptr::eq(
                        a.kernel as *const dyn Kernel as *const u8,
                        b.kernel as *const dyn Kernel as *const u8,
                    )
            })
    }

    /// Pool dispatches the legacy per-operator walk would have issued
    /// for this plan: one per width-1 or Sync-A entry, one per Sync-B
    /// region — the `dispatches` baseline the single-dispatch model is
    /// measured against.
    pub fn legacy_dispatches(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| {
                s.width == 1 || self.sync == SyncMode::SyncA || s.region_end
            })
            .count()
    }

    /// Walk the whole plan as pool worker `worker` — the body of the
    /// single per-pass dispatch. Every worker of the pool runs this
    /// with the same plan, so all of them pass the same sequence of
    /// global barriers; workers idle under the TP view skip width-G
    /// compute and local barriers but still park at every global one.
    ///
    /// **Panic discipline.** A panicking kernel must not strand the
    /// other workers at a spin barrier (they would wait for an arrival
    /// that never comes). The panic is caught and *deferred*: this
    /// worker stops computing but keeps walking the remaining barrier
    /// schedule, then re-raises after the walk — so its peers complete
    /// the pass, the pool's completion latch poisons, and the leader
    /// surfaces the panic instead of deadlocking.
    ///
    /// # Safety contract
    ///
    /// Soundness of the concurrent arena writes is the [`OpCtx`]
    /// argument: `compile` asserted (debug builds) that every step's
    /// unit ranges are disjoint and tile `[0, units)`, and the barrier
    /// ending step `k` orders its writes before every read in step
    /// `k+1` (release/acquire pairs inside [`SpinBarrier::wait`]).
    /// Under Sync B, groups drift between local barriers — but a
    /// group's stream only reads tensors its own group produced, and
    /// cross-group reads happen only after the region's global barrier.
    pub fn run_worker(
        &self,
        graph: &Graph,
        pool: &MemoryPool,
        params: &ExecParams,
        org_tp: &Organization,
        pool_size: usize,
        worker: usize,
        global: &SpinBarrier,
    ) {
        use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
        let assignment = org_tp.assignment(worker);
        // one relaxed load per pass; the per-step traced work below is
        // skipped entirely when off
        let tracing = crate::trace::enabled();
        let mut deferred: Option<Box<dyn std::any::Any + Send>> = None;
        for step in &self.steps {
            if deferred.is_none() {
                // resolve this worker's slice of the step up front so
                // both the compute closure and the trace span agree on
                // kernel, group and unit range
                let slice = if step.width == 1 {
                    let part = &self.parts[step.part0];
                    let (u0, u1) = chunk_range(part.units, pool_size, worker);
                    Some((part, u0, u1, u32::MAX))
                } else if let Some((gi, rank)) = assignment {
                    let part = &self.parts[step.part0 + gi];
                    let size = org_tp.groups[gi].size();
                    let (u0, u1) = chunk_range(part.units, size, rank);
                    Some((part, u0, u1, gi as u32))
                } else {
                    None
                };
                let t0 = if tracing { crate::trace::now_ns() } else { 0 };
                let r = catch_unwind(AssertUnwindSafe(|| {
                    if let Some((part, u0, u1, _)) = slice {
                        if u0 < u1 {
                            let op = OpCtx { graph, pool, id: part.id, params };
                            unsafe { part.kernel.run(&op, u0, u1) };
                        }
                    }
                }));
                if tracing {
                    // every worker records exactly one kernel span per
                    // step (idle workers included), so spans-per-pass
                    // is exactly steps × pool size
                    match slice {
                        Some((part, u0, u1, group)) => crate::trace::record_kernel(
                            part.kernel.name(),
                            t0,
                            group,
                            step.entry as u32,
                            u0 as u32,
                            u1 as u32,
                        ),
                        None => crate::trace::record_kernel(
                            "idle",
                            t0,
                            u32::MAX,
                            step.entry as u32,
                            0,
                            0,
                        ),
                    }
                }
                if let Err(p) = r {
                    deferred = Some(p);
                }
            }
            match step.barrier {
                StepBarrier::Global => {
                    global.wait();
                }
                StepBarrier::Local => {
                    if let Some((gi, _)) = assignment {
                        org_tp.groups[gi].barrier().wait();
                    }
                }
            }
        }
        if let Some(p) = deferred {
            resume_unwind(p);
        }
    }
}

impl std::fmt::Debug for PassPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PassPlan")
            .field("steps", &self.steps.len())
            .field("parts", &self.parts.len())
            .field("sync", &self.sync)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::numa::{Placement, Topology};
    use crate::tensor::{DType, TensorBundle};

    /// scatter → 3 parallel matmuls → gather, with a width-1 matmul on
    /// each side of the TP region.
    fn mixed_graph() -> Graph {
        let mut b = GraphBuilder::sim(vec![0, 1], Placement::Node(0));
        let x = b.leaf("x", DType::F32, vec![1, 8], Placement::Node(0));
        let w = b.leaf("w", DType::F32, vec![8, 8], Placement::Node(0));
        let w0 = b.leaf("w0", DType::F32, vec![4, 8], Placement::Node(0));
        let w1 = b.leaf("w1", DType::F32, vec![4, 8], Placement::Node(1));
        let wq0 = b.leaf("wq0", DType::F32, vec![4, 4], Placement::Node(0));
        let wq1 = b.leaf("wq1", DType::F32, vec![4, 4], Placement::Node(1));
        let w2 = b.leaf("w2", DType::F32, vec![8, 4], Placement::Node(0));
        let h = b.matmul(&TensorBundle::one(x), &TensorBundle::one(w));
        let hs = b.scatter(&h);
        let mut cur = b.matmul(&hs, &TensorBundle::new(vec![w0, w1]));
        for _ in 0..2 {
            cur = b.matmul(&cur, &TensorBundle::new(vec![wq0, wq1]));
        }
        let g = b.gather(&cur);
        b.matmul(&g, &TensorBundle::one(w2));
        b.finish().0
    }

    fn org2() -> (Organization, usize) {
        let t = Topology::uniform(2, 2, 100.0, 25.0);
        let cores: Vec<_> = (0..4).map(|i| t.core(i)).collect();
        (Organization::by_node(&cores), cores.len())
    }

    #[test]
    fn compile_matches_the_legacy_per_op_walk() {
        let g = mixed_graph();
        let (org, n) = org2();
        let params = ExecParams::dense(0, 1);
        let plan = PassPlan::compile(&g, &params, n, &org, SyncMode::SyncB);
        assert_eq!(plan.ops(), g.exec.len(), "one step per exec entry");
        // unit counts: identical to walking exec and asking each kernel
        let mut want = Vec::new();
        for entry in &g.exec {
            for id in entry.bundle.iter() {
                want.push(g.kernel(id).units(g.meta(id), &params));
            }
        }
        assert_eq!(plan.unit_counts, want);
        assert_eq!(plan.parts.len(), want.len());
        for (part, &u) in plan.parts.iter().zip(&want) {
            assert_eq!(part.units, u);
        }
    }

    #[test]
    fn sync_b_regions_end_globally_and_sync_locally_inside() {
        let g = mixed_graph();
        let (org, n) = org2();
        let plan = PassPlan::compile(&g, &ExecParams::dense(0, 1), n, &org, SyncMode::SyncB);
        let wide: Vec<&PlanStep> = plan.steps.iter().filter(|s| s.width == 2).collect();
        assert!(wide.len() >= 4, "scatter + 3 matmuls expected in the region");
        for s in &wide[..wide.len() - 1] {
            assert_eq!(s.barrier, StepBarrier::Local);
            assert!(!s.region_end);
        }
        let last = wide.last().unwrap();
        assert_eq!(last.barrier, StepBarrier::Global);
        assert!(last.region_end);
        for s in plan.steps.iter().filter(|s| s.width == 1) {
            assert_eq!(s.barrier, StepBarrier::Global);
            assert!(!s.region_end);
        }
    }

    #[test]
    fn sync_a_uses_the_global_barrier_everywhere() {
        let g = mixed_graph();
        let (org, n) = org2();
        let plan = PassPlan::compile(&g, &ExecParams::dense(0, 1), n, &org, SyncMode::SyncA);
        assert!(plan.steps.iter().all(|s| s.barrier == StepBarrier::Global));
        // sync choice must not change the accounting surface
        let plan_b = PassPlan::compile(&g, &ExecParams::dense(0, 1), n, &org, SyncMode::SyncB);
        assert_eq!(plan.unit_counts, plan_b.unit_counts);
        assert_eq!(plan.ops(), plan_b.ops());
    }

    #[test]
    fn recompiled_plans_are_structurally_identical() {
        // the plan-cache debug assertion: compiling the same (graph,
        // params) twice — or at a different position with the same row
        // count — must yield step-for-step identical plans
        let g = mixed_graph();
        let (org, n) = org2();
        let a = PassPlan::compile(&g, &ExecParams::dense(0, 1), n, &org, SyncMode::SyncB);
        let b = PassPlan::compile(&g, &ExecParams::dense(0, 1), n, &org, SyncMode::SyncB);
        assert!(a.same_as(&b));
        let later = PassPlan::compile(&g, &ExecParams::dense(7, 1), n, &org, SyncMode::SyncB);
        assert!(a.same_as(&later), "unit counts must be position-independent");
        // a different sync discipline is a different plan
        let sync_a = PassPlan::compile(&g, &ExecParams::dense(0, 1), n, &org, SyncMode::SyncA);
        assert!(!a.same_as(&sync_a));
    }

    #[test]
    fn legacy_dispatch_baseline_counts_ops_not_regions() {
        let g = mixed_graph();
        let (org, n) = org2();
        let params = ExecParams::dense(0, 1);
        let a = PassPlan::compile(&g, &params, n, &org, SyncMode::SyncA);
        // Sync A: every entry was its own dispatch
        assert_eq!(a.legacy_dispatches(), g.exec.len());
        let b = PassPlan::compile(&g, &params, n, &org, SyncMode::SyncB);
        // Sync B: the 4-entry region was one dispatch
        assert_eq!(b.legacy_dispatches(), g.exec.len() - 3);
        assert!(b.legacy_dispatches() > 1, "the reduction target is > 1");
    }
}
